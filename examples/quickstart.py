"""Quickstart: randomized distributed mean estimation in 30 lines.

Estimates the mean of n node vectors under different communication budgets
and prints the accuracy-vs-bits trade-off (the paper's core object).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import EncoderSpec, CommSpec, MeanEstimator, empirical_mse

N, D = 16, 512


def main():
    xs = jax.random.normal(jax.random.PRNGKey(0), (N, D))
    print(f"estimating the mean of {N} vectors in R^{D}\n")
    print(f"{'protocol':32s} {'bits':>10s} {'bits/coord':>10s} "
          f"{'MSE (closed)':>12s} {'MSE (emp)':>10s}")
    configs = [
        ("full (Ex. 5)", EncoderSpec(kind="identity"), CommSpec("naive")),
        ("log-MSE p=1/log d (Ex. 6)",
         EncoderSpec(kind="bernoulli", fraction=1 / jnp.log(D).item()),
         CommSpec("sparse_seed")),
        ("1-bit/coord p=1/r (Ex. 7)",
         EncoderSpec(kind="bernoulli", fraction=1 / 16),
         CommSpec("sparse_seed")),
        ("below-1-bit p=1/d (Ex. 9)",
         EncoderSpec(kind="bernoulli", fraction=1 / D),
         CommSpec("sparse_seed")),
        ("binary quantization (Ex. 4)",
         EncoderSpec(kind="binary"), CommSpec("binary")),
        ("fixed-k k=d/16 (Eq. 4)",
         EncoderSpec(kind="fixed_k", fraction=1 / 16),
         CommSpec("sparse_seed")),
        ("optimal p, B=d (Thm 6.1)",
         EncoderSpec(kind="bernoulli", fraction=1 / 16, probs="optimal"),
         CommSpec("sparse")),
    ]
    for name, enc, comm in configs:
        est = MeanEstimator(enc, comm, budget=float(D))
        rep = est.estimate(jax.random.PRNGKey(1), xs)
        emp = float(empirical_mse(jax.random.PRNGKey(2), xs, est, trials=200))
        print(f"{name:32s} {rep.expected_bits:10.0f} "
              f"{rep.expected_bits / (N * D):10.3f} "
              f"{rep.expected_mse:12.4f} {emp:10.4f}")


if __name__ == "__main__":
    main()
