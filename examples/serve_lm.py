"""Serving example: prefill a prompt batch and greedily decode tokens with
the production engine (KV cache, vocab-parallel sampling).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, ShapeSpec
from repro.configs.registry import smoke_config
from repro.core import types as core_types
from repro.serving import engine
from repro.train import train_step as ts


def main():
    cfg = smoke_config("qwen3-4b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = RunConfig(microbatches=1, model_parallel=True, seq_shard=False,
                    attn_chunk_q=16, attn_chunk_k=16, remat=False,
                    compression=core_types.CompressionConfig(mode="none"))
    shape = ShapeSpec("serve", "decode", seq_len=64, global_batch=4)

    prefill_fn, decode_fn, specs, info = engine.build_serve_fns(
        mesh, cfg, run, shape)
    _, init_fn, _, _, _ = ts.build_train_step(
        mesh, cfg, run, ShapeSpec("t", "train", 32, 4))
    params, _, _ = init_fn(jax.random.PRNGKey(0))

    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    cache, logits = prefill_fn(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print("prompt shape:", prompt.shape, "-> first sampled token:",
          tok.ravel().tolist())

    out = [tok]
    for i in range(16):
        tok, cache = decode_fn(params, cache, tok, jnp.int32(16 + i))
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated (greedy, random weights):")
    for row in gen.tolist():
        print("  ", row)


if __name__ == "__main__":
    main()
