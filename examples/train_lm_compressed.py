"""End-to-end driver: train a small LM with compressed gradient aggregation
on 8 simulated data-parallel workers, comparing the paper's 1-bit-style
operating point against exact synchronization.

    python examples/train_lm_compressed.py [--steps 200]

(Device count is locked at first jax init, so this script sets XLA_FLAGS
itself and must be the process entry point.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ArchConfig, RunConfig, ShapeSpec  # noqa: E402
from repro.core import types as core_types  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.optim.optimizers import AdamWConfig  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402

CFG = ArchConfig(name="lm-8m", family="dense", num_layers=4, d_model=256,
                 num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
                 vocab_size=2048, tie_embeddings=True)
SHAPE = ShapeSpec("train", "train", seq_len=128, global_batch=32)


def run(steps: int, compression: core_types.CompressionConfig, label: str):
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    run_cfg = RunConfig(microbatches=1, model_parallel=False, seq_shard=False,
                        attn_chunk_q=128, attn_chunk_k=128, remat=False,
                        compression=compression)
    tcfg = TrainerConfig(steps=steps, log_every=max(1, steps // 10),
                         ckpt_dir=None, seed=0)
    tr = Trainer(mesh, CFG, run_cfg, SHAPE, tcfg,
                 AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps))
    _, _, hist = tr.fit()
    print(f"\n== {label} ==")
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  ({h['sec']:.0f}s)")
    if compression.error_feedback and isinstance(tr.ef_state, dict):
        # per-bucket error-feedback residual norms (the compression error
        # the wire codec recycles each step — repro.core.wire.ef); bounded
        # residuals are what make the EF estimates asymptotically unbiased.
        if len(hist) > 1:
            # difference two logged entries: the first one absorbs the jit
            # compile, so this is the steady-state step time.
            sec_per_step = ((hist[-1]["sec"] - hist[0]["sec"])
                            / max(1, hist[-1]["step"] - hist[0]["step"]))
        else:
            sec_per_step = hist[-1]["sec"] / max(1, hist[-1]["step"] + 1)
        for bid in sorted(tr.ef_state):
            e = tr.ef_state[bid]
            print(f"  ef residual ‖e‖ {float(jnp.linalg.norm(e)):9.4f}  "
                  f"({e.size} coords)  bucket {bid}  "
                  f"[{sec_per_step * 1e3:.0f} ms/step]")
    return hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default=None,
                    help="run a named wire preset from "
                         "repro.configs.registry.COMPRESSION_PRESETS "
                         "(e.g. rotated_binary, ef_rotated_binary, "
                         "ternary_opt) instead of the default "
                         "exact-vs-fixed-k comparison; ef_* presets print "
                         "per-bucket residual norms")
    args = ap.parse_args()

    if args.preset:
        from repro.configs import registry
        cfg = dataclasses.replace(
            registry.compression_preset(args.preset, axes=("data",)),
            min_compress_size=1024)
        hist = run(args.steps, cfg, f"preset {args.preset}")
        print(f"\nfinal loss — {args.preset}: {hist[-1]['loss']:.4f}")
        return

    exact = run(args.steps, core_types.CompressionConfig(mode="none"),
                "exact gradient mean (baseline)")
    comp = core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1 / 16,
                                       center="mean"),
        mode="shared_support", axes=("data",), min_compress_size=1024,
        error_feedback=True)
    compressed = run(args.steps, comp,
                     "fixed-k 1/16 + error feedback (1-bit-class wire cost)")

    print(f"\nfinal loss — exact: {exact[-1]['loss']:.4f}   "
          f"compressed(1/16 + EF): {compressed[-1]['loss']:.4f}")
    print("wire bytes per step (gradient sync): exact = 2(n-1)/n·|g|·4B; "
          "compressed ≈ |g|/16·4B + scalars  (×~32 reduction)")


if __name__ == "__main__":
    main()
