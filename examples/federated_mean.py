"""Federated-style mean estimation with stragglers and per-node budgets —
the paper's §1 motivating setting, end to end.

    PYTHONPATH=src python examples/federated_mean.py
"""
import jax
import jax.numpy as jnp

from repro.core import (CommSpec, EncoderSpec, MeanEstimator, decoders,
                        encoders, mse, optimal)

N, D = 32, 1024


def main():
    key = jax.random.PRNGKey(0)
    # heterogeneous nodes: different scales (non-iid, as in federated setups)
    scales = jnp.exp(jax.random.normal(key, (N, 1)) * 0.5)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (N, D)) * scales
    x_true = jnp.mean(xs, axis=0)

    # --- per-node budgets (Remark 5): each node solves its own problem ----
    mus = jnp.mean(xs, axis=-1)
    B_total = 0.05 * N * D
    p = optimal.optimal_probs(xs, mus, B_total)
    print(f"budget Σp = {float(jnp.sum(p)):.0f} of {N * D} coordinates "
          f"(5%); closed-form MSE = {float(mse.mse_bernoulli(xs, p, mus)):.4f}")

    # --- one communication round ------------------------------------------
    enc = encoders.encode_batch(jax.random.fold_in(key, 2), xs,
                                EncoderSpec(kind="bernoulli", probs="optimal",
                                            fraction=0.05),
                                probs=p, mus=mus)
    est = decoders.averaging_decoder(enc.y)
    err = float(jnp.sum((est - x_true) ** 2))
    print(f"one-round squared error: {err:.4f}")

    # --- stragglers: drop 25% of nodes, reweight (unbiased partial mean) ---
    alive = (jax.random.uniform(jax.random.fold_in(key, 3), (N,)) > 0.25)
    est_partial = decoders.weighted_partial_decoder(enc.y, alive)
    # compare against the live nodes' true mean (the estimand under drop)
    live_true = jnp.sum(xs * alive[:, None], axis=0) / jnp.sum(alive)
    err_p = float(jnp.sum((est_partial - live_true) ** 2))
    print(f"straggler round ({int(jnp.sum(alive))}/{N} alive): "
          f"error vs live-mean {err_p:.4f} (still unbiased)")

    # --- elasticity: the decoder is n-agnostic ----------------------------
    half = MeanEstimator(EncoderSpec(kind="fixed_k", fraction=0.05),
                         CommSpec("sparse_seed"))
    rep = half.estimate(jax.random.fold_in(key, 4), xs[: N // 2])
    print(f"elastic round with n/2 nodes: bits={rep.bits:.0f} "
          f"mse_closed={rep.expected_mse:.4f} (MSE ∝ 1/n: double of full-n)")


if __name__ == "__main__":
    main()
