"""Figure 1 reproduction: communication-vs-MSE trade-off curves on
Gaussian / Laplace / chi-squared data (n=16, d=512, r=16), for
(i) uniform p + mean centers, (ii) optimal p + mean centers,
(iii) optimal p + optimal centers (alternating minimization),
plus the binary-quantization point (Example 4)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import centers, comm_cost, mse, optimal, types

N, D, R = 16, 512, 16


def _data(kind: str, key):
    if kind == "gaussian":
        return jax.random.normal(key, (N, D))
    if kind == "laplace":
        return jax.random.laplace(key, (N, D))
    if kind == "chi2":
        g = jax.random.normal(key, (N, D, 2))
        return jnp.sum(g * g, axis=-1)  # chi^2(2)
    raise ValueError(kind)


def curves(kind: str, budgets=None):
    xs = _data(kind, jax.random.PRNGKey(hash(kind) % 2**31))
    mus = jnp.mean(xs, axis=-1)
    budgets = budgets or [N * D * f for f in
                          (0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.7)]
    rows = []
    spec = types.CommSpec(protocol="sparse", r_bits=R)
    for B in budgets:
        p_uni = jnp.full((N, D), B / (N * D))
        m_uni = float(mse.mse_bernoulli(xs, p_uni, mus))
        p_opt = optimal.optimal_probs(xs, mus, B)
        m_opt = float(mse.mse_bernoulli(xs, p_opt, mus))
        p_j, mu_j, _ = optimal.alternating_minimization(xs, B, iters=12)
        m_joint = float(mse.mse_bernoulli(xs, p_j, mu_j))
        bits = comm_cost.cost_sparse(p_uni, spec, D)
        rows.append({"dist": kind, "budget_B": float(B), "bits": bits,
                     "mse_uniform": m_uni, "mse_opt_p": m_opt,
                     "mse_opt_p_mu": m_joint})
    return rows, xs


def rows():
    out = []
    for kind in ("gaussian", "laplace", "chi2"):
        t0 = time.perf_counter()
        curve, xs = curves(kind)
        dt = (time.perf_counter() - t0) * 1e6 / len(curve)
        # invariants from the paper: optimal ≤ uniform everywhere; joint ≤
        # fixed-centers; symmetric data ⇒ joint ≈ fixed-centers.
        ok = all(r["mse_opt_p"] <= r["mse_uniform"] * 1.001 and
                 r["mse_opt_p_mu"] <= r["mse_opt_p"] * 1.01 for r in curve)
        # binary quantization single point (Example 4)
        bq_mse = float(mse.mse_binary(xs))
        bq_bits = comm_cost.cost_binary(N, D, types.CommSpec(r_bits=R))
        mid = curve[len(curve) // 2]
        out.append({
            "name": f"tradeoff.{kind}",
            "us_per_call": dt,
            "derived": (f"B={mid['budget_B']:.0f}: uni={mid['mse_uniform']:.3f} "
                        f"opt_p={mid['mse_opt_p']:.3f} "
                        f"opt_p_mu={mid['mse_opt_p_mu']:.3f} | "
                        f"bq=({bq_bits:.0f}b, {bq_mse:.3f})"),
            "check": ok,
            "curve": curve,
        })
    return out
