"""Modeled per-device step time per registry preset at d = 2²⁰ — the
compressed-beats-dense gate of the fused-kernel work.

The 8-virtual-device CPU sweeps (bench_collectives/bench_bucketing) time
all devices serialized on one core with a free in-memory "wire", so they
can never show the win compression buys on a real link.  This bench models
one device's step instead:

    modeled_us = pack_us + decode_us (+ unpack_us for stateful EF codecs,
                 their residual reconstruction) + wire_us

* ``pack_us``/``decode_us``/``unpack_us`` — measured, jitted, single
  device, on the SAME codec entry points the production collective calls
  (pack → decode_gathered / decode_reduced), at the production wire dtype.
  Timing discipline: 2 warm-up calls (compile + allocator settle), REPS
  timed calls, block_until_ready at the end — identical to the other bench
  sections so µs are comparable across the JSON record.
* ``wire_us`` — a ring-collective model over the measured buffer bytes:
  all-gather moves n·b·(s−1)/s, all-reduce 2·b·(s−1)/s (hlo_cost's
  roofline convention) at ``BENCH_LINK_MBPS`` (default 100 Mbit/s — a
  deliberately thin DCN-class link; the paper's regime is wire-bound).

Gate (enforced by benchmarks/run.py --smoke AND the full run): every
compressed preset's modeled step beats the dense-f32 baselines ("none"
exact all-reduce and "binary_dense" dense simulation).  This is the
success metric of the encode/decode wall-clock fix: compression must pay
for its codec compute at the link the accounting assumes.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

N = 8
D_DEFAULT = 1 << 20
REPS = 3
DENSE_BASELINES = ("none", "binary_dense")


def _link_mbps() -> float:
    return float(os.environ.get("BENCH_LINK_MBPS", 100.0))


def _time(fn, *args) -> float:
    """µs/call: 2 warm calls, REPS timed, block_until_ready at the end."""
    jax.block_until_ready(fn(*args))
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS * 1e6


def _wire_us(row_bytes: float, reduce: str, n: int) -> float:
    ring = (2.0 if reduce == "psum" else float(n)) * row_bytes * (n - 1) / n
    return ring * 8.0 / _link_mbps()


def _preset_cfgs():
    from repro.configs import registry as cfg_registry
    from repro.core import types

    out = {}
    for name in sorted(cfg_registry.COMPRESSION_PRESETS):
        out[name] = cfg_registry.compression_preset(name, axes=("data",))
    out["fixed_k_gather"] = dataclasses.replace(
        out["fixed_k_1bit"], mode="gather_decode")
    out["binary_dense"] = dataclasses.replace(
        out["binary_packed"], mode="dense_sim")
    out = {k: dataclasses.replace(v, min_compress_size=0)
           for k, v in out.items()}
    out["none"] = types.CompressionConfig(mode="none")
    return out


_CACHE: dict = {}


def collect(d: int = D_DEFAULT) -> dict:
    """{preset: {pack_us, decode_us, unpack_us, wire_us, modeled_us,
    row_bytes}} at dimension d (memoized per d)."""
    if d in _CACHE:
        return _CACHE[d]
    from repro.core import wire

    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (d,), jnp.float32) * 0.3
    res = {"d": d, "n": N, "link_mbps": _link_mbps(), "presets": {}}
    for name, cfg in sorted(_preset_cfgs().items()):
        if cfg.mode == "none":
            # exact f32 all-reduce: no codec compute, dense psum wire.
            entry = {"pack_us": 0.0, "decode_us": 0.0, "unpack_us": 0.0,
                     "row_bytes": d * 4, "wire_us": _wire_us(d * 4, "psum", N)}
        else:
            codec = wire.resolve(cfg)
            pack = jax.jit(lambda f, k, c=codec, g=cfg: c.pack(f, k, 0, g))
            pack_us = _time(pack, flat, key)
            rows = jnp.stack([codec.pack(flat, key, i, cfg)
                              for i in range(N)])
            row_bytes = int(rows[0].size) * rows[0].dtype.itemsize
            if codec.reduce == "psum":
                wire_buf = jnp.mean(rows.astype(jnp.float32), axis=0)
                dec = jax.jit(lambda w, k, c=codec, g=cfg:
                              c.decode_reduced(w, k, g, d))
                decode_us = _time(dec, wire_buf, key)
            else:
                dec = jax.jit(lambda r, k, c=codec, g=cfg:
                              c.decode_gathered(r, k, g, d, N))
                decode_us = _time(dec, rows, key)
            unpack_us = 0.0
            if codec.stateful:
                # EF reconstructs its own contribution for the residual.
                unp = jax.jit(lambda r, k, c=codec, g=cfg:
                              c.unpack(r, 0, k, g, d))
                unpack_us = _time(unp, rows[0], key)
            entry = {"pack_us": pack_us, "decode_us": decode_us,
                     "unpack_us": unpack_us, "row_bytes": row_bytes,
                     "wire_us": _wire_us(row_bytes, codec.reduce, N)}
        entry["modeled_us"] = (entry["pack_us"] + entry["decode_us"]
                               + entry["unpack_us"] + entry["wire_us"])
        res["presets"][name] = {k: round(v, 1) if isinstance(v, float) else v
                                for k, v in entry.items()}
    _CACHE[d] = res
    return res


def check_compressed_beats_dense(res: dict) -> list:
    """Presets whose modeled step does NOT beat the dense-f32 baselines
    (must be empty): the fused-kernel success metric."""
    p = res["presets"]
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES if b in p)
    return [f"{name}: modeled {e['modeled_us']:.0f}us >= dense "
            f"{dense_us:.0f}us"
            for name, e in sorted(p.items())
            if name not in DENSE_BASELINES
            and not e["modeled_us"] < dense_us]


def rows():
    t0 = time.perf_counter()
    res = collect()
    dt = (time.perf_counter() - t0) * 1e6
    p = res["presets"]
    bad = check_compressed_beats_dense(res)
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES)
    worst = max((e["modeled_us"], n) for n, e in p.items()
                if n not in DENSE_BASELINES)
    return [{
        "name": f"device_step.d{res['d']}",
        "us_per_call": dt,
        "derived": (f"dense={dense_us / 1e3:.0f}ms worst-compressed="
                    f"{worst[1]}:{worst[0] / 1e3:.0f}ms @"
                    f"{res['link_mbps']:.0f}Mbps"
                    + (f"; FAIL {bad}" if bad else
                       "; every compressed preset beats dense")),
        "check": not bad,
    }]
