"""Modeled per-device step time per registry preset at d = 2²⁰ — the
compressed-beats-dense gate of the fused-kernel work.

The 8-virtual-device CPU sweeps (bench_collectives/bench_bucketing) time
all devices serialized on one core with a free in-memory "wire", so they
can never show the win compression buys on a real link.  This bench models
one device's step instead:

    modeled_us = pack_us + decode_us (+ unpack_us for stateful EF codecs,
                 their residual reconstruction) + wire_us
                 (+ shard_gather_us for §12 flat-scatter presets)

* ``pack_us``/``decode_us``/``unpack_us`` — measured, jitted, single
  device, on the SAME codec entry points the production collective calls
  (pack → decode_gathered / decode_reduced), at the production wire dtype.
  Presets with no unpack stage report ``unpack_us: null`` — only stateful
  EF codecs reconstruct their own contribution, everything else has no
  such stage and gets no fake 0.0 measurement.  Timing discipline: 2
  warm-up calls (compile + allocator settle), REPS timed calls,
  block_until_ready at the end — identical to the other bench sections so
  µs are comparable across the JSON record.
* flat-scatter presets (``cfg.scatter_decode`` on the main axes, §12/§13)
  decode only their own shard per device (⌈d/n⌉ coordinates, word-aligned
  for the packed planes); their ``decode_us`` is the measured per-shard
  work, broken down in ``decode_stages`` per codec family:
    - bernoulli: ``regenerate_us`` (scattered Threefry support draws,
      kernels.bernoulli_wire.ops.support_shard) + ``accumulate_us``
      (select+accumulate over all n peer rows, decode_sum_shard);
    - binary / ternary (§13): ``unpack_us`` (word-window slice + center
      tail / 2-bit symbol extraction) + ``accumulate_us`` (the fused
      unpack+center-select+accumulate pass, kernels.bitplane binary_accum
      resp. bitplane.ternary_decode_shard);
    - rotated wrappers add ``unrotate_us`` — the ONE inverse FWHT applied
      to the reassembled rotated estimate (shards live in rotated space
      at the padded length);
    - other partitionable codecs (fixed_k's analytic window):
      ``accumulate_us`` alone, the collective-free shard call;
  plus the modeled ``shard_gather_us`` of the extra scatter collectives
  (count exchange where the codec needs one + the decoded f32 shard
  gather, exactly the codec's ``scatter_bits``) at ``BENCH_MESH_MBPS``
  (default 10 Gbit/s — the shard gather rides the fast intra-mesh fabric,
  not the thin cross-host link the wire model charges).  Non-scatter
  presets report ``decode_stages: null``.
* fused-twin EF presets (ef_binary/ef_ternary/ef_rotated_binary) report
  ``unpack_us`` as the INCREMENTAL cost of the residual reconstruction:
  the twin pack emitting (buffer, recon) minus the same entry emitting
  the buffer alone — the §13 fusion derives recon from encode-side
  intermediates, so the old full unpack round trip (plane unpack + for
  the rotated stack a second FWHT) is gone from the production path.
* ``wire_us`` — a ring-collective model over the measured buffer bytes:
  all-gather moves n·b·(s−1)/s, all-reduce 2·b·(s−1)/s (hlo_cost's
  roofline convention) at ``BENCH_LINK_MBPS`` (default 100 Mbit/s — a
  deliberately thin DCN-class link; the paper's regime is wire-bound).

``collect`` also emits a ``decode_n_sweep`` section for the Bernoulli
seed codec AND the packed binary codec: full O(n·d) decode vs the
per-shard O(d) scatter decode across n ∈ {2,4,8,16} at a fixed d, so the
decode-scaling claim of the flat-scatter work is visible in the JSON
trajectory for both families, and :func:`check_decode_scaling` gates
every flat-scatter preset's decode_us against the committed
BENCH_collectives.json baseline.

Gate (enforced by benchmarks/run.py --smoke AND the full run): every
compressed preset's modeled step beats the dense-f32 baselines ("none"
exact all-reduce and "binary_dense" dense simulation).  This is the
success metric of the encode/decode wall-clock fix: compression must pay
for its codec compute at the link the accounting assumes.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

N = 8
D_DEFAULT = 1 << 20
REPS = 3
DENSE_BASELINES = ("none", "binary_dense")
SWEEP_D = 1 << 18
SWEEP_NS = (2, 4, 8, 16)


def _link_mbps() -> float:
    return float(os.environ.get("BENCH_LINK_MBPS", 100.0))


def _mesh_mbps() -> float:
    return float(os.environ.get("BENCH_MESH_MBPS", 10_000.0))


def _time(fn, *args) -> float:
    """µs/call: 2 warm calls, REPS timed, block_until_ready at the end."""
    jax.block_until_ready(fn(*args))
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS * 1e6


def _wire_us(row_bytes: float, reduce: str, n: int) -> float:
    ring = (2.0 if reduce == "psum" else float(n)) * row_bytes * (n - 1) / n
    return ring * 8.0 / _link_mbps()


def _preset_cfgs():
    from repro.configs import registry as cfg_registry
    from repro.core import types

    out = {}
    for name in sorted(cfg_registry.COMPRESSION_PRESETS):
        out[name] = cfg_registry.compression_preset(name, axes=("data",))
    out["fixed_k_gather"] = dataclasses.replace(
        out["fixed_k_1bit"], mode="gather_decode")
    out["binary_dense"] = dataclasses.replace(
        out["binary_packed"], mode="dense_sim", scatter_decode=False)
    out = {k: dataclasses.replace(v, min_compress_size=0)
           for k, v in out.items()}
    out["none"] = types.CompressionConfig(mode="none")
    return out


def _bernoulli_shard_stage_us(rows, key, p: float, cap: int, d: int,
                              n: int):
    """(regenerate_us, accumulate_us) of one node's ⌈d/n⌉ shard decode.

    Times the two per-device compute stages of the §12 scatter decode on
    the same kernel entry points the codec dispatches to.  The rank-offset
    counts exchange and the decoded-shard reassembly are collectives — they
    are modeled as shard_gather_us, not measured here.
    """
    from repro.kernels.bernoulli_wire import ops as bw_ops

    ds = -(-d // n)
    keys = jnp.stack([jax.random.fold_in(key, i) for i in range(n)])
    rows32 = rows.astype(jnp.float32)
    regen = jax.jit(lambda k: bw_ops.support_shard(k, p, d, 0, ds))
    regenerate_us = _time(regen, keys)
    sent = regen(keys)
    prior = jnp.zeros((n,), jnp.int32)
    acc = jax.jit(lambda r, s, pr: bw_ops.decode_sum_shard(
        r[:, :-1], r[:, -1], keys, s, pr, 0, p=p, cap=cap, d=d))
    accumulate_us = _time(acc, rows32, sent, prior)
    return regenerate_us, accumulate_us


def _plane_shard_stage_us(codec, cfg, rows, d: int, n: int):
    """(unpack_us, accumulate_us) of one node's word-aligned bit-plane
    shard decode (§13), on the same collective-free entry points the codec
    dispatches to.  The counts exchange (ternary) and the decoded-shard
    reassembly are collectives — modeled as shard_gather_us, not measured.
    """
    from repro.core import bitplane, comm_cost, wire
    from repro.core.wire import codecs as wire_codecs
    from repro.kernels.bitplane import ops as bp_ops

    if isinstance(codec, wire_codecs.TernaryCodec):
        ds = wire.scatter_shard_len(d, n, bitplane.TERNARY_ALIGN)
        cap = comm_cost.bernoulli_capacity(d, float(cfg.encoder.fraction))
        unp = jax.jit(lambda r: bitplane.ternary_shard_syms(r, d, 0, ds, n))
        unpack_us = _time(unp, rows)
        syms = unp(rows)
        prior = jnp.zeros((n,), jnp.int32)
        acc = jax.jit(lambda r, s, pr: bitplane.ternary_decode_shard(
            r, s, pr, d, cap, cfg.wire_dtype, 0))
        return unpack_us, _time(acc, rows, syms, prior)
    # binary: the word-window + center-tail prep vs the fused
    # unpack+center-select+accumulate pass over all n peer windows.
    ds = wire.scatter_shard_len(d, n, bitplane.BINARY_ALIGN)
    pw = bp_ops.num_words(d, 1)
    ws = ds // 32
    prep = jax.jit(lambda r: (
        bitplane._plane_window(r[:, :pw], n, ws, 0),
        jax.vmap(lambda t: bitplane.words_to_floats(t, 2, cfg.wire_dtype))(
            r[:, pw:])))
    unpack_us = _time(prep, rows)
    win, c = prep(rows)
    acc = jax.jit(lambda w, cl, ch: bp_ops.binary_accum(w, cl, ch, ds))
    return unpack_us, _time(acc, win, c[:, 0], c[:, 1])


def _scatter_stage_us(codec, cfg, rows, key, d: int, n: int) -> dict:
    """Per-device decode stages of a flat-scatter preset, per codec family.

    Unwraps the delegating wrappers first: EF (its decode IS the inner
    decode) and rotation (shards live in ROTATED space at the padded
    length; the single inverse FWHT on the reassembled estimate is timed
    as ``unrotate_us``).  ``rows`` must be the inner wire rows — which is
    what ``codec.pack`` emits for every wrapper (EF's twin and the rotated
    pack both produce inner-format buffers at the padded length).
    """
    from repro.core import rotation
    from repro.core.wire import codecs as wire_codecs
    from repro.core.wire import ef as wire_ef
    from repro.core.wire import rotated as wire_rotated

    inner, dd, rotated = codec, d, False
    while True:
        if isinstance(inner, wire_ef.EFCodec):
            inner = inner.inner
        elif isinstance(inner, wire_rotated.RotatedCodec):
            rotated = True
            dd = rotation.padded_dim(dd)
            inner = inner.inner
        else:
            break
    if isinstance(inner, wire_codecs.BernoulliCodec):
        from repro.core import comm_cost
        p = float(cfg.encoder.fraction)
        cap = comm_cost.bernoulli_capacity(dd, p)
        regen_us, acc_us = _bernoulli_shard_stage_us(rows, key, p, cap,
                                                     dd, n)
        stages = {"regenerate_us": regen_us, "accumulate_us": acc_us}
    elif isinstance(inner, (wire_codecs.BinaryCodec,
                            wire_codecs.TernaryCodec)):
        unpack_us, acc_us = _plane_shard_stage_us(inner, cfg, rows, dd, n)
        stages = {"unpack_us": unpack_us, "accumulate_us": acc_us}
    else:
        # analytic-window codecs (fixed_k): the shard call is already
        # collective-free, one fused stage.
        dec = jax.jit(lambda r, k, c=inner, g=cfg:
                      c.decode_gathered_shard(r, k, g, dd, n, 0, n))
        stages = {"accumulate_us": _time(dec, rows, key)}
    if rotated:
        zbar = jax.random.normal(jax.random.PRNGKey(2), (dd,), jnp.float32)
        unrot = jax.jit(lambda z, k: rotation.unrotate(
            rotation.rotation_key(k), z, d))
        stages["unrotate_us"] = _time(unrot, zbar, key)
    return stages


_CACHE: dict = {}


def collect(d: int = D_DEFAULT) -> dict:
    """{preset: {pack_us, decode_us, unpack_us, wire_us, modeled_us,
    row_bytes, decode_stages}} at dimension d plus the Bernoulli
    decode_n_sweep (memoized per d)."""
    if d in _CACHE:
        return _CACHE[d]
    from repro.core import wire

    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (d,), jnp.float32) * 0.3
    res = {"d": d, "n": N, "link_mbps": _link_mbps(),
           "mesh_mbps": _mesh_mbps(), "presets": {}}
    for name, cfg in sorted(_preset_cfgs().items()):
        if cfg.mode == "none":
            # exact f32 all-reduce: no codec compute, dense psum wire.
            entry = {"pack_us": 0.0, "decode_us": 0.0, "unpack_us": None,
                     "row_bytes": d * 4, "wire_us": _wire_us(d * 4, "psum", N),
                     "decode_stages": None}
        else:
            codec = wire.resolve(cfg)
            pack = jax.jit(lambda f, k, c=codec, g=cfg: c.pack(f, k, 0, g))
            pack_us = _time(pack, flat, key)
            rows = jnp.stack([codec.pack(flat, key, i, cfg)
                              for i in range(N)])
            row_bytes = int(rows[0].size) * rows[0].dtype.itemsize
            stages = None
            if codec.reduce == "psum":
                wire_buf = jnp.mean(rows.astype(jnp.float32), axis=0)
                dec = jax.jit(lambda w, k, c=codec, g=cfg:
                              c.decode_reduced(w, k, g, d))
                decode_us = _time(dec, wire_buf, key)
            elif cfg.scatter_decode and not cfg.inner_axes:
                # §12/§13 flat scatter: per-device decode is the shard view.
                stages = _scatter_stage_us(codec, cfg, rows, key, d, N)
                decode_us = sum(stages.values())
                stages["shard_gather_us"] = (codec.scatter_bits(N, d, cfg)
                                             * (N - 1) / N / _mesh_mbps())
            else:
                dec = jax.jit(lambda r, k, c=codec, g=cfg:
                              c.decode_gathered(r, k, g, d, N))
                decode_us = _time(dec, rows, key)
            unpack_us = None
            if codec.stateful:
                # EF reconstructs its own contribution for the residual.
                from repro.core.wire import ef as wire_ef
                if isinstance(codec, wire_ef.EFCodec) and \
                        wire_ef.twin_recon_fused(codec.inner):
                    # §13 fused twin: recon is derived from encode-side
                    # intermediates, so its true cost is the increment of
                    # emitting (buffer, recon) over the buffer alone (the
                    # [0]-projection DCEs the recon branch exactly like the
                    # stateless production path does).
                    both = jax.jit(lambda f, k, c=codec.inner, g=cfg:
                                   wire_ef._twin_pack_recon(c, f, k, 0, g))
                    only = jax.jit(lambda f, k, c=codec.inner, g=cfg:
                                   wire_ef._twin_pack_recon(c, f, k, 0, g)[0])
                    unpack_us = max(_time(both, flat, key)
                                    - _time(only, flat, key), 1.0)
                else:
                    unp = jax.jit(lambda r, k, c=codec, g=cfg:
                                  c.unpack(r, 0, k, g, d))
                    unpack_us = _time(unp, rows[0], key)
            entry = {"pack_us": pack_us, "decode_us": decode_us,
                     "unpack_us": unpack_us, "row_bytes": row_bytes,
                     "wire_us": _wire_us(row_bytes, codec.reduce, N),
                     "decode_stages": stages}
        entry["modeled_us"] = (
            entry["pack_us"] + entry["decode_us"] + (entry["unpack_us"] or 0.0)
            + entry["wire_us"]
            + (entry["decode_stages"] or {}).get("shard_gather_us", 0.0))
        res["presets"][name] = {
            k: (round(v, 1) if isinstance(v, float) else
                {s: round(u, 1) for s, u in v.items()}
                if isinstance(v, dict) else v)
            for k, v in entry.items()}
    res["decode_n_sweep"] = _decode_n_sweep()
    _CACHE[d] = res
    return res


def _decode_n_sweep(d: int = SWEEP_D, ns: tuple = SWEEP_NS) -> dict:
    """Full O(n·d) vs per-shard O(d) decode across n, per codec family.

    ``full_us`` times ``decode_gathered`` over all n peer rows (every
    coordinate); ``shard_us`` the §12/§13 per-device work (the measured
    decode stages over one shard — ⌈d/n⌉ coordinates, word-aligned for
    the packed plane).  full_us grows ~linearly in n while shard_us stays
    ~flat — the decode-scaling claim in one table, for the seed-trick
    codec (bernoulli) and the packed-plane codec (binary) alike.
    """
    import dataclasses as dc

    from repro.configs import registry as cfg_registry
    from repro.core import wire

    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (d,), jnp.float32) * 0.3
    out = {"d": d, "codecs": {}}
    for cname, preset in (("bernoulli", "bernoulli_seed_1bit"),
                          ("binary", "binary_packed")):
        cfg = dc.replace(cfg_registry.compression_preset(
            preset, axes=("data",)), min_compress_size=0)
        flat_cfg = dc.replace(cfg, scatter_decode=False)
        codec = wire.resolve(cfg)
        ns_out = {}
        for n in ns:
            rows = jnp.stack([codec.pack(flat, key, i, cfg)
                              for i in range(n)])
            dec = jax.jit(lambda r, k, c=codec, g=flat_cfg, m=n:
                          c.decode_gathered(r, k, g, d, m))
            full_us = _time(dec, rows, key)
            stages = _scatter_stage_us(codec, cfg, rows, key, d, n)
            ns_out[str(n)] = {"full_us": round(full_us, 1),
                              "shard_us": round(sum(stages.values()), 1)}
        out["codecs"][cname] = {"ns": ns_out}
    return out


def check_compressed_beats_dense(res: dict) -> list:
    """Presets whose modeled step does NOT beat the dense-f32 baselines
    (must be empty): the fused-kernel success metric."""
    p = res["presets"]
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES if b in p)
    return [f"{name}: modeled {e['modeled_us']:.0f}us >= dense "
            f"{dense_us:.0f}us"
            for name, e in sorted(p.items())
            if name not in DENSE_BASELINES
            and not e["modeled_us"] < dense_us]


# flat-scatter presets whose decode_us the smoke gate holds to the
# committed baseline — the full §12 + §13 scatter family.
GATED_DECODE_PRESETS = ("bernoulli_seed_1bit", "binary_packed",
                        "ternary_packed", "ef_binary", "ef_ternary",
                        "ef_rotated_binary")


def check_decode_scaling(res: dict, baseline: dict | None) -> list:
    """Every flat-scatter preset's decode_us must not regress above the
    committed BENCH_collectives.json baseline (must be empty).

    ``baseline`` is the previously-committed JSON record, read BEFORE the
    run overwrites it; ``BENCH_DECODE_TOL`` (default 2.0) absorbs
    machine-to-machine noise without letting an O(n·d) decode sneak back
    in (the scatter shard decodes are ≥5× under the old full decodes, so
    2× headroom still catches any structural regression).
    """
    out = []
    tol = float(os.environ.get("BENCH_DECODE_TOL", 2.0))
    for name in GATED_DECODE_PRESETS:
        try:
            base = baseline["device_step"]["presets"][name]["decode_us"]
        except (KeyError, TypeError):
            continue  # no committed baseline to gate against
        new = res["presets"][name]["decode_us"]
        if new > base * tol:
            out.append(f"{name}: decode {new:.0f}us > {tol:.1f}x "
                       f"committed baseline {base:.0f}us")
    return out


def rows():
    t0 = time.perf_counter()
    res = collect()
    dt = (time.perf_counter() - t0) * 1e6
    p = res["presets"]
    bad = check_compressed_beats_dense(res)
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES)
    worst = max((e["modeled_us"], n) for n, e in p.items()
                if n not in DENSE_BASELINES)
    parts, ok_sweep = [], True
    for cname, rec in sorted(res["decode_n_sweep"]["codecs"].items()):
        top = max(rec["ns"], key=int)
        e = rec["ns"][top]
        parts.append(f"n={top} {cname} full={e['full_us'] / 1e3:.1f}ms "
                     f"shard={e['shard_us'] / 1e3:.1f}ms "
                     f"(x{e['full_us'] / max(e['shard_us'], 1):.1f})")
        # the per-shard decode must beat the full decode at the largest n.
        ok_sweep = ok_sweep and e["shard_us"] < e["full_us"]
    return [{
        "name": f"device_step.d{res['d']}",
        "us_per_call": dt,
        "derived": (f"dense={dense_us / 1e3:.0f}ms worst-compressed="
                    f"{worst[1]}:{worst[0] / 1e3:.0f}ms @"
                    f"{res['link_mbps']:.0f}Mbps"
                    + (f"; FAIL {bad}" if bad else
                       "; every compressed preset beats dense")),
        "check": not bad,
    }, {
        "name": f"device_step.decode_n_sweep.d{res['decode_n_sweep']['d']}",
        "us_per_call": dt,
        "derived": "; ".join(parts),
        "check": ok_sweep,
    }]
