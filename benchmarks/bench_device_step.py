"""Modeled per-device step time per registry preset at d = 2²⁰ — the
compressed-beats-dense gate of the fused-kernel work.

The 8-virtual-device CPU sweeps (bench_collectives/bench_bucketing) time
all devices serialized on one core with a free in-memory "wire", so they
can never show the win compression buys on a real link.  This bench models
one device's step instead:

    modeled_us = pack_us + decode_us (+ unpack_us for stateful EF codecs,
                 their residual reconstruction) + wire_us
                 (+ shard_gather_us for §12 flat-scatter presets)

* ``pack_us``/``decode_us``/``unpack_us`` — measured, jitted, single
  device, on the SAME codec entry points the production collective calls
  (pack → decode_gathered / decode_reduced), at the production wire dtype.
  Presets with no unpack stage report ``unpack_us: null`` — only stateful
  EF codecs reconstruct their own contribution, everything else has no
  such stage and gets no fake 0.0 measurement.  Timing discipline: 2
  warm-up calls (compile + allocator settle), REPS timed calls,
  block_until_ready at the end — identical to the other bench sections so
  µs are comparable across the JSON record.
* flat-scatter presets (``cfg.scatter_decode`` on the main axes, §12)
  decode only their own ⌈d/n⌉-coordinate shard per device; their
  ``decode_us`` is the measured per-shard work, broken down in
  ``decode_stages`` as ``regenerate_us`` (scattered Threefry support
  draws, kernels.bernoulli_wire.ops.support_shard) + ``accumulate_us``
  (select+accumulate over all n peer rows, decode_sum_shard), plus the
  modeled ``shard_gather_us`` of the two extra collectives the scatter
  path ships (i32 rank-offset counts + the decoded f32 shard gather,
  exactly the codec's ``scatter_bits``) at ``BENCH_MESH_MBPS`` (default
  10 Gbit/s — the shard gather rides the fast intra-mesh fabric, not the
  thin cross-host link the wire model charges).  Non-scatter presets
  report ``decode_stages: null``.
* ``wire_us`` — a ring-collective model over the measured buffer bytes:
  all-gather moves n·b·(s−1)/s, all-reduce 2·b·(s−1)/s (hlo_cost's
  roofline convention) at ``BENCH_LINK_MBPS`` (default 100 Mbit/s — a
  deliberately thin DCN-class link; the paper's regime is wire-bound).

``collect`` also emits a ``decode_n_sweep`` section for the Bernoulli
seed codec: full O(n·d) decode vs the per-shard O(d) scatter decode
across n ∈ {2,4,8,16} at a fixed d, so the decode-scaling claim of the
flat-scatter work is visible in the JSON trajectory, and
:func:`check_decode_scaling` gates `bernoulli_seed_1bit` decode_us
against the committed BENCH_collectives.json baseline.

Gate (enforced by benchmarks/run.py --smoke AND the full run): every
compressed preset's modeled step beats the dense-f32 baselines ("none"
exact all-reduce and "binary_dense" dense simulation).  This is the
success metric of the encode/decode wall-clock fix: compression must pay
for its codec compute at the link the accounting assumes.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

N = 8
D_DEFAULT = 1 << 20
REPS = 3
DENSE_BASELINES = ("none", "binary_dense")
SWEEP_D = 1 << 18
SWEEP_NS = (2, 4, 8, 16)


def _link_mbps() -> float:
    return float(os.environ.get("BENCH_LINK_MBPS", 100.0))


def _mesh_mbps() -> float:
    return float(os.environ.get("BENCH_MESH_MBPS", 10_000.0))


def _time(fn, *args) -> float:
    """µs/call: 2 warm calls, REPS timed, block_until_ready at the end."""
    jax.block_until_ready(fn(*args))
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS * 1e6


def _wire_us(row_bytes: float, reduce: str, n: int) -> float:
    ring = (2.0 if reduce == "psum" else float(n)) * row_bytes * (n - 1) / n
    return ring * 8.0 / _link_mbps()


def _preset_cfgs():
    from repro.configs import registry as cfg_registry
    from repro.core import types

    out = {}
    for name in sorted(cfg_registry.COMPRESSION_PRESETS):
        out[name] = cfg_registry.compression_preset(name, axes=("data",))
    out["fixed_k_gather"] = dataclasses.replace(
        out["fixed_k_1bit"], mode="gather_decode")
    out["binary_dense"] = dataclasses.replace(
        out["binary_packed"], mode="dense_sim")
    out = {k: dataclasses.replace(v, min_compress_size=0)
           for k, v in out.items()}
    out["none"] = types.CompressionConfig(mode="none")
    return out


def _bernoulli_shard_stage_us(rows, key, p: float, cap: int, d: int,
                              n: int):
    """(regenerate_us, accumulate_us) of one node's ⌈d/n⌉ shard decode.

    Times the two per-device compute stages of the §12 scatter decode on
    the same kernel entry points the codec dispatches to.  The rank-offset
    counts exchange and the decoded-shard reassembly are collectives — they
    are modeled as shard_gather_us, not measured here.
    """
    from repro.kernels.bernoulli_wire import ops as bw_ops

    ds = -(-d // n)
    keys = jnp.stack([jax.random.fold_in(key, i) for i in range(n)])
    rows32 = rows.astype(jnp.float32)
    regen = jax.jit(lambda k: bw_ops.support_shard(k, p, d, 0, ds))
    regenerate_us = _time(regen, keys)
    sent = regen(keys)
    prior = jnp.zeros((n,), jnp.int32)
    acc = jax.jit(lambda r, s, pr: bw_ops.decode_sum_shard(
        r[:, :-1], r[:, -1], keys, s, pr, 0, p=p, cap=cap, d=d))
    accumulate_us = _time(acc, rows32, sent, prior)
    return regenerate_us, accumulate_us


_CACHE: dict = {}


def collect(d: int = D_DEFAULT) -> dict:
    """{preset: {pack_us, decode_us, unpack_us, wire_us, modeled_us,
    row_bytes, decode_stages}} at dimension d plus the Bernoulli
    decode_n_sweep (memoized per d)."""
    if d in _CACHE:
        return _CACHE[d]
    from repro.core import comm_cost, wire

    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (d,), jnp.float32) * 0.3
    res = {"d": d, "n": N, "link_mbps": _link_mbps(),
           "mesh_mbps": _mesh_mbps(), "presets": {}}
    for name, cfg in sorted(_preset_cfgs().items()):
        if cfg.mode == "none":
            # exact f32 all-reduce: no codec compute, dense psum wire.
            entry = {"pack_us": 0.0, "decode_us": 0.0, "unpack_us": None,
                     "row_bytes": d * 4, "wire_us": _wire_us(d * 4, "psum", N),
                     "decode_stages": None}
        else:
            codec = wire.resolve(cfg)
            pack = jax.jit(lambda f, k, c=codec, g=cfg: c.pack(f, k, 0, g))
            pack_us = _time(pack, flat, key)
            rows = jnp.stack([codec.pack(flat, key, i, cfg)
                              for i in range(N)])
            row_bytes = int(rows[0].size) * rows[0].dtype.itemsize
            stages = None
            if codec.reduce == "psum":
                wire_buf = jnp.mean(rows.astype(jnp.float32), axis=0)
                dec = jax.jit(lambda w, k, c=codec, g=cfg:
                              c.decode_reduced(w, k, g, d))
                decode_us = _time(dec, wire_buf, key)
            elif cfg.scatter_decode and not cfg.inner_axes:
                # §12 flat scatter: per-device decode is the shard view.
                p = float(cfg.encoder.fraction)
                cap = comm_cost.bernoulli_capacity(d, p)
                regen_us, acc_us = _bernoulli_shard_stage_us(
                    rows, key, p, cap, d, N)
                gather_us = (codec.scatter_bits(N, d, cfg)
                             * (N - 1) / N / _mesh_mbps())
                stages = {"regenerate_us": regen_us,
                          "accumulate_us": acc_us,
                          "shard_gather_us": gather_us}
                decode_us = regen_us + acc_us
            else:
                dec = jax.jit(lambda r, k, c=codec, g=cfg:
                              c.decode_gathered(r, k, g, d, N))
                decode_us = _time(dec, rows, key)
            unpack_us = None
            if codec.stateful:
                # EF reconstructs its own contribution for the residual.
                unp = jax.jit(lambda r, k, c=codec, g=cfg:
                              c.unpack(r, 0, k, g, d))
                unpack_us = _time(unp, rows[0], key)
            entry = {"pack_us": pack_us, "decode_us": decode_us,
                     "unpack_us": unpack_us, "row_bytes": row_bytes,
                     "wire_us": _wire_us(row_bytes, codec.reduce, N),
                     "decode_stages": stages}
        entry["modeled_us"] = (
            entry["pack_us"] + entry["decode_us"] + (entry["unpack_us"] or 0.0)
            + entry["wire_us"]
            + (entry["decode_stages"] or {}).get("shard_gather_us", 0.0))
        res["presets"][name] = {
            k: (round(v, 1) if isinstance(v, float) else
                {s: round(u, 1) for s, u in v.items()}
                if isinstance(v, dict) else v)
            for k, v in entry.items()}
    res["decode_n_sweep"] = _decode_n_sweep()
    _CACHE[d] = res
    return res


def _decode_n_sweep(d: int = SWEEP_D, ns: tuple = SWEEP_NS) -> dict:
    """Full O(n·d) vs per-shard O(d) Bernoulli seed decode across n.

    ``full_us`` times ``decode_gathered`` over all n peer rows (every
    coordinate); ``shard_us`` the §12 per-device work (support_shard +
    decode_sum_shard over one ⌈d/n⌉ shard).  full_us grows ~linearly in
    n while shard_us stays ~flat — the decode-scaling claim in one table.
    """
    import dataclasses as dc

    from repro.configs import registry as cfg_registry
    from repro.core import comm_cost, wire

    cfg = dc.replace(cfg_registry.compression_preset(
        "bernoulli_seed_1bit", axes=("data",)), min_compress_size=0)
    flat_cfg = dc.replace(cfg, scatter_decode=False)
    codec = wire.resolve(cfg)
    p = float(cfg.encoder.fraction)
    cap = comm_cost.bernoulli_capacity(d, p)
    key = jax.random.PRNGKey(0)
    flat = jax.random.normal(key, (d,), jnp.float32) * 0.3
    out = {"d": d, "codec": "bernoulli", "ns": {}}
    for n in ns:
        rows = jnp.stack([codec.pack(flat, key, i, cfg) for i in range(n)])
        dec = jax.jit(lambda r, k, c=codec, g=flat_cfg, m=n:
                      c.decode_gathered(r, k, g, d, m))
        full_us = _time(dec, rows, key)
        regen_us, acc_us = _bernoulli_shard_stage_us(rows, key, p, cap, d, n)
        out["ns"][str(n)] = {"full_us": round(full_us, 1),
                             "shard_us": round(regen_us + acc_us, 1)}
    return out


def check_compressed_beats_dense(res: dict) -> list:
    """Presets whose modeled step does NOT beat the dense-f32 baselines
    (must be empty): the fused-kernel success metric."""
    p = res["presets"]
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES if b in p)
    return [f"{name}: modeled {e['modeled_us']:.0f}us >= dense "
            f"{dense_us:.0f}us"
            for name, e in sorted(p.items())
            if name not in DENSE_BASELINES
            and not e["modeled_us"] < dense_us]


def check_decode_scaling(res: dict, baseline: dict | None) -> list:
    """`bernoulli_seed_1bit` decode_us must not regress above the committed
    BENCH_collectives.json baseline (must be empty).

    ``baseline`` is the previously-committed JSON record, read BEFORE the
    run overwrites it; ``BENCH_DECODE_TOL`` (default 2.0) absorbs
    machine-to-machine noise without letting an O(n·d) decode sneak back
    in (the flat-scatter shard decode is ~10× under the old full decode,
    so 2× headroom still catches any structural regression).
    """
    try:
        base = baseline["device_step"]["presets"]["bernoulli_seed_1bit"][
            "decode_us"]
    except (KeyError, TypeError):
        return []  # no committed baseline to gate against
    new = res["presets"]["bernoulli_seed_1bit"]["decode_us"]
    tol = float(os.environ.get("BENCH_DECODE_TOL", 2.0))
    if new > base * tol:
        return [f"bernoulli_seed_1bit: decode {new:.0f}us > {tol:.1f}x "
                f"committed baseline {base:.0f}us"]
    return []


def rows():
    t0 = time.perf_counter()
    res = collect()
    dt = (time.perf_counter() - t0) * 1e6
    p = res["presets"]
    bad = check_compressed_beats_dense(res)
    dense_us = min(p[b]["modeled_us"] for b in DENSE_BASELINES)
    worst = max((e["modeled_us"], n) for n, e in p.items()
                if n not in DENSE_BASELINES)
    sweep = res["decode_n_sweep"]["ns"]
    top = max(sweep, key=int)
    e = sweep[top]
    return [{
        "name": f"device_step.d{res['d']}",
        "us_per_call": dt,
        "derived": (f"dense={dense_us / 1e3:.0f}ms worst-compressed="
                    f"{worst[1]}:{worst[0] / 1e3:.0f}ms @"
                    f"{res['link_mbps']:.0f}Mbps"
                    + (f"; FAIL {bad}" if bad else
                       "; every compressed preset beats dense")),
        "check": not bad,
    }, {
        "name": f"device_step.decode_n_sweep.d{res['decode_n_sweep']['d']}",
        "us_per_call": dt,
        "derived": (f"n={top} bernoulli full={e['full_us'] / 1e3:.1f}ms "
                    f"shard={e['shard_us'] / 1e3:.1f}ms "
                    f"(x{e['full_us'] / max(e['shard_us'], 1):.1f})"),
        # the per-shard decode must beat the full decode at the largest n.
        "check": e["shard_us"] < e["full_us"],
    }]
