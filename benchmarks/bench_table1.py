"""Table 1 reproduction: communication cost & MSE at the paper's named
operating points (Examples 5–9), closed-form vs empirical."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_cost, mse, protocol, types

N, D, R = 16, 512, 16


def rows():
    key = jax.random.PRNGKey(0)
    xs = jax.random.normal(key, (N, D))
    mus = jnp.mean(xs, axis=-1)
    Rfac = float(mse.r_factor(xs, mus))
    spec = types.CommSpec(protocol="sparse_seed", r_bits=R)
    out = []
    points = [
        ("Ex5_full", 1.0),
        ("Ex6_log_mse", 1.0 / np.log(D)),
        ("Ex7_1bit", 1.0 / R),
        ("Ex9_below_1bit", 1.0 / D),
    ]
    for name, p in points:
        t0 = time.perf_counter()
        est = protocol.MeanEstimator(
            types.EncoderSpec(kind="bernoulli", fraction=float(p),
                              center="mean"),
            types.CommSpec(protocol="naive" if p == 1.0 else "sparse_seed",
                           r_bits=R))
        emp = float(protocol.empirical_mse(jax.random.PRNGKey(1), xs, est,
                                           trials=400))
        dt = (time.perf_counter() - t0) * 1e6 / 400
        bits = (comm_cost.cost_naive(N, D, spec) if p == 1.0 else
                comm_cost.cost_sparse_seed_uniform_p(N, D, float(p), spec))
        closed = float(mse.mse_bernoulli(xs, float(p), mus))
        table_mse = (1.0 / p - 1.0) * Rfac / N  # the Table 1 column
        out.append({
            "name": f"table1.{name}",
            "us_per_call": dt,
            "derived": (f"p={p:.5f} bits={bits:.0f} "
                        f"bits_per_coord={bits / (N * D):.3f} "
                        f"mse_closed={closed:.4f} mse_table={table_mse:.4f} "
                        f"mse_emp={emp:.4f}"),
            "check": abs(emp - closed) / max(closed, 1e-9) < 0.25
                     if p < 1 else emp < 1e-9,
        })
    return out
