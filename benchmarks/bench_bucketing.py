"""Collective-launch count + step time of the bucketed gradient sync vs the
per-leaf reference path, on an 8-device CPU mesh (subprocess: the device
count is locked at first jax init).

A realistic grad pytree has hundreds of leaves; the per-leaf rule issues one
collective per leaf while the bucketed rule issues one per bucket (a few).
The launch count is read from compiled HLO (loop-aware, launch/hlo_cost);
wall time is measured on the jitted sync alone.

Second sweep (:func:`collect_overlap`): overlapped vs post-backward issue
schedule (``BucketSpec.overlap``, DESIGN.md §9) per compression preset —
one grad+sync step of an MLP chain with each schedule, ms/step + launch
counts, recorded into BENCH_collectives.json's ``overlap`` section so the
perf trajectory tracks the schedule across PRs.  (On the single-stream CPU
backend the two schedules execute the same op set, so the times bound the
schedule's overhead rather than demonstrate the hiding a multi-stream
accelerator gets; the check asserts parity, not a win.)
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import types
from repro.launch import hlo_cost
from repro.train import bucketing
from repro.train import train_step as ts

mesh = jax.make_mesh((8,), ("data",))
MESH_AXES = ("data",)

# 96 small + 24 large leaves — the shape of a real transformer grad tree.
SHAPES = {f"s_{i:03d}": (4096,) for i in range(96)}
SHAPES.update({f"l_{i:03d}": (65536,) for i in range(24)})
SPECS = {n: (None,) for n in SHAPES}

cmp = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=1 / 16),
    mode="shared_support", axes=("data",), min_compress_size=65536)
plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, {"data": 8}, cmp)

key0 = jax.random.PRNGKey(0)
XS = {n: jax.random.normal(jax.random.fold_in(key0, i), (8,) + SHAPES[n])
      for i, n in enumerate(sorted(SHAPES))}
IN_SPECS = {n: P("data", None) for n in SHAPES}
OUT_SPECS = {n: P() for n in SHAPES}


def make(fn):
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(IN_SPECS, P()), out_specs=OUT_SPECS,
                       check_vma=False)
    def wrapped(xs, key):
        grads = {n: xs[n].reshape(SHAPES[n]) for n in xs}
        return fn(grads, key)
    return jax.jit(wrapped)


def perleaf(grads, key):
    out, _ = ts.sync_grads(grads, SPECS, MESH_AXES, cmp, key, ())
    return out


def bucketed(grads, key):
    out, _ = bucketing.sync_grads_bucketed(grads, plan, cmp, key)
    return out


def measure(fn):
    f = make(fn)
    comp = f.lower(XS, key0).compile()
    colls = sum(hlo_cost.analyze_text(comp.as_text()).coll_exec.values())
    f(XS, key0)  # warmup via the jit cache
    reps = 20
    t0 = time.perf_counter()
    for i in range(reps):
        out = f(XS, jax.random.fold_in(key0, i))
    jax.block_until_ready(out)
    return {"colls": colls, "us": (time.perf_counter() - t0) / reps * 1e6}

res = {"perleaf": measure(perleaf), "bucketed": measure(bucketed),
       "n_leaves": len(SHAPES), "n_buckets": len(plan.buckets)}
print(json.dumps(res))
"""


# --------------------------------------------------------------------------- #
# Overlapped vs post-backward issue schedule, per preset (subprocess).
# --------------------------------------------------------------------------- #

_OVERLAP_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, math, time
import jax

# the SAME step construction overlap_check.py validates (PYTHONPATH
# includes tests/distributed_checks): bench and check cannot diverge.
import overlap_harness as oh
from repro.launch import hlo_cost
from repro.train import bucketing

mesh = jax.make_mesh((8,), ("data",))
L = int(os.environ.get("BENCH_OVERLAP_L", 8))
M = int(os.environ.get("BENCH_OVERLAP_M", 128))
REPS = int(os.environ.get("BENCH_OVERLAP_REPS", 30))
PRESETS = ["none", "fixed_k_1bit", "bernoulli_seed_1bit", "binary_packed",
           "ternary_opt", "ef_rotated_binary"]

SHAPES, SPECS = oh.build_tree(L, M)
PARAMS = oh.init_params(SHAPES)
X = jax.random.normal(jax.random.PRNGKey(1), (32, M))
# total grad dimension of the synced tree — recorded per entry so the
# JSON's overlap times are never read against the presets section's
# BENCH_D-sized buckets (they measure a much smaller model end to end).
D_TOTAL = sum(math.prod(s) for s in SHAPES.values())

res = {}
for preset in PRESETS:
    cfg = oh.mkcfg(preset, M)
    plan = bucketing.build_plan(SHAPES, SPECS, ("data",), {"data": 8}, cfg)
    ef0 = bucketing.init_ef_state(plan, cfg) if cfg.error_feedback else {}
    post, ovl = oh.make_sync_steps(mesh, L, cfg, plan)

    entry = {"buckets": len(plan.buckets), "schedule": list(plan.schedule()),
             "layers": L, "width": M, "d_total": D_TOTAL}
    for label, fj in (("post_us", post), ("overlap_us", ovl)):
        comp = fj.lower(PARAMS, ef0, X, jax.random.PRNGKey(2)).compile()
        launches = sum(hlo_cost.analyze_text(comp.as_text()).coll_exec.values())
        out = fj(PARAMS, ef0, X, jax.random.PRNGKey(2))
        jax.block_until_ready(out)
        # second warm call: same discipline as the presets/device_step
        # sections (compile, then allocator settle, then the timed reps).
        jax.block_until_ready(fj(PARAMS, ef0, X, jax.random.PRNGKey(2)))
        t0 = time.perf_counter()
        for i in range(REPS):
            out = fj(PARAMS, ef0, X, jax.random.fold_in(jax.random.PRNGKey(2), i))
        jax.block_until_ready(out)
        entry[label] = (time.perf_counter() - t0) / REPS * 1e6
        entry[label.replace("_us", "_launches")] = launches
    res[preset] = entry
print(json.dumps(res))
"""


def _run_inner(script, extra_env=None, timeout=900):
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    # src for repro.*; tests/distributed_checks for the shared
    # overlap_harness module (also imported by overlap_check.py).
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root / "tests" / "distributed_checks")])
    env.pop("XLA_FLAGS", None)
    env.update(extra_env or {})
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


_OVERLAP_CACHE = {}


def collect_overlap(*, smoke: bool = False) -> dict:
    """{preset: {overlap_us, post_us, *_launches, buckets, schedule}} — the
    machine-readable record benchmarks/run.py embeds as the JSON's
    ``overlap`` section.  Raises RuntimeError on subprocess failure.
    Memoized either way, so run.py's rows() + collect() pair never pays
    (or re-fails) the subprocess twice."""
    if smoke in _OVERLAP_CACHE:
        out = _OVERLAP_CACHE[smoke]
        if isinstance(out, RuntimeError):
            raise out
        return out
    extra = {"BENCH_OVERLAP_L": "4", "BENCH_OVERLAP_M": "64",
             "BENCH_OVERLAP_REPS": "2"} if smoke else None
    proc = _run_inner(_OVERLAP_INNER, extra)
    if proc.returncode != 0:
        err = RuntimeError(f"overlap bench failed: {proc.stderr[-500:]}")
        _OVERLAP_CACHE[smoke] = err
        raise err
    _OVERLAP_CACHE[smoke] = json.loads(proc.stdout.strip().splitlines()[-1])
    return _OVERLAP_CACHE[smoke]


def rows():
    t0 = time.perf_counter()
    proc = _run_inner(_INNER, timeout=600)
    dt = (time.perf_counter() - t0) * 1e6
    if proc.returncode != 0:
        return [{"name": "bucketing.launches", "us_per_call": dt,
                 "derived": f"FAILED: {proc.stderr[-300:]}", "check": False}]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    pl, bk = res["perleaf"], res["bucketed"]
    out = [{
        "name": "bucketing.launches",
        "us_per_call": dt,
        "derived": (f"perleaf={pl['colls']:.0f} colls/{pl['us']:.0f}us "
                    f"bucketed={bk['colls']:.0f} colls/{bk['us']:.0f}us "
                    f"({res['n_leaves']} leaves -> {res['n_buckets']} buckets,"
                    f" x{pl['us'] / max(bk['us'], 1):.1f} step-time)"),
        # the tentpole claims: ≤ 1 collective launch per bucket (the wire is
        # fused: values + μ ride one buffer) — deterministic, read from HLO.
        # Step time is only bounded, not asserted as a win: on the
        # single-stream CPU backend the wire is free and devices serialize
        # on one core, so bucketing's launch savings can't show while its
        # concat/split overhead does, and the per-leaf time swings ~2×
        # run-to-run (120 tiny collectives vs scheduler noise).  The same
        # parity-not-win convention as the overlap section below; the
        # wall-clock story lives in bench_device_step's modeled gate.
        "check": (bk["colls"] <= res["n_buckets"]
                  and bk["colls"] < pl["colls"] / 10
                  and bk["us"] < 2.0 * pl["us"]),
    }]
    t0 = time.perf_counter()
    try:
        ov = collect_overlap()
    except RuntimeError as e:
        return out + [{"name": "bucketing.overlap", "us_per_call": 0.0,
                       "derived": str(e)[-300:], "check": False}]
    dt = (time.perf_counter() - t0) * 1e6
    worst = max(e["overlap_us"] / e["post_us"] for e in ov.values())
    derived = " ".join(
        f"{p}:{e['overlap_us']:.0f}us(ovl)/{e['post_us']:.0f}us(post)"
        for p, e in sorted(ov.items()))
    out.append({
        "name": "bucketing.overlap",
        "us_per_call": dt,
        "derived": derived + f" worst-ratio x{worst:.2f}",
        # schedule parity: same launch count per schedule, and the
        # overlapped schedule costs ≤ 2× post-backward even on the
        # single-stream CPU backend (identical op set; the slack absorbs
        # CPU dispatch jitter on these sub-10ms graphs).
        "check": (worst < 2.0
                  and all(e["overlap_launches"] == e["post_launches"]
                          for e in ov.values())),
    })
    return out
