"""Collective-launch count + step time of the bucketed gradient sync vs the
per-leaf reference path, on an 8-device CPU mesh (subprocess: the device
count is locked at first jax init).

A realistic grad pytree has hundreds of leaves; the per-leaf rule issues one
collective per leaf while the bucketed rule issues one per bucket (a few).
The launch count is read from compiled HLO (loop-aware, launch/hlo_cost);
wall time is measured on the jitted sync alone.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import types
from repro.launch import hlo_cost
from repro.train import bucketing
from repro.train import train_step as ts

mesh = jax.make_mesh((8,), ("data",))
MESH_AXES = ("data",)

# 96 small + 24 large leaves — the shape of a real transformer grad tree.
SHAPES = {f"s_{i:03d}": (4096,) for i in range(96)}
SHAPES.update({f"l_{i:03d}": (65536,) for i in range(24)})
SPECS = {n: (None,) for n in SHAPES}

cmp = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=1 / 16),
    mode="shared_support", axes=("data",), min_compress_size=65536)
plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, {"data": 8}, cmp)

key0 = jax.random.PRNGKey(0)
XS = {n: jax.random.normal(jax.random.fold_in(key0, i), (8,) + SHAPES[n])
      for i, n in enumerate(sorted(SHAPES))}
IN_SPECS = {n: P("data", None) for n in SHAPES}
OUT_SPECS = {n: P() for n in SHAPES}


def make(fn):
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(IN_SPECS, P()), out_specs=OUT_SPECS,
                       check_vma=False)
    def wrapped(xs, key):
        grads = {n: xs[n].reshape(SHAPES[n]) for n in xs}
        return fn(grads, key)
    return jax.jit(wrapped)


def perleaf(grads, key):
    out, _ = ts.sync_grads(grads, SPECS, MESH_AXES, cmp, key, ())
    return out


def bucketed(grads, key):
    out, _ = bucketing.sync_grads_bucketed(grads, plan, cmp, key)
    return out


def measure(fn):
    f = make(fn)
    comp = f.lower(XS, key0).compile()
    colls = sum(hlo_cost.analyze_text(comp.as_text()).coll_exec.values())
    f(XS, key0)  # warmup via the jit cache
    reps = 20
    t0 = time.perf_counter()
    for i in range(reps):
        out = f(XS, jax.random.fold_in(key0, i))
    jax.block_until_ready(out)
    return {"colls": colls, "us": (time.perf_counter() - t0) / reps * 1e6}

res = {"perleaf": measure(perleaf), "bucketed": measure(bucketed),
       "n_leaves": len(SHAPES), "n_buckets": len(plan.buckets)}
print(json.dumps(res))
"""


def rows():
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", _INNER], env=env,
                          capture_output=True, text=True, timeout=600)
    dt = (time.perf_counter() - t0) * 1e6
    if proc.returncode != 0:
        return [{"name": "bucketing.launches", "us_per_call": dt,
                 "derived": f"FAILED: {proc.stderr[-300:]}", "check": False}]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    pl, bk = res["perleaf"], res["bucketed"]
    return [{
        "name": "bucketing.launches",
        "us_per_call": dt,
        "derived": (f"perleaf={pl['colls']:.0f} colls/{pl['us']:.0f}us "
                    f"bucketed={bk['colls']:.0f} colls/{bk['us']:.0f}us "
                    f"({res['n_leaves']} leaves -> {res['n_buckets']} buckets,"
                    f" x{pl['us'] / max(bk['us'], 1):.1f} step-time)"),
        # the tentpole claims: ≤ 1 collective launch per bucket (the wire is
        # fused: values + μ ride one buffer), and a step-time win.
        "check": (bk["colls"] <= res["n_buckets"]
                  and bk["colls"] < pl["colls"] / 10
                  and bk["us"] < pl["us"]),
    }]
