"""Wire bytes + step time of the compressed-mean collective per registry
preset, measured from lowered HLO and timed execution on an 8-device mesh
(subprocess: device count is locked at first jax init, and benchmarks must
see 1 device by default).

The preset sweep comes from repro.configs.registry.COMPRESSION_PRESETS —
i.e. the same codec registry the production dispatch consults — plus three
reference points ("none" exact, "fixed_k_gather", "binary_dense" dense
simulation).  Two byte conventions are reported per preset:

* ``wire_bytes`` — ring-adjusted per-device wire traffic (hlo_cost's
  roofline convention: all-reduce pays 2·b·(s−1)/s, all-gather b·(s−1)/s);
* ``payload_bytes`` — the star-protocol payload Σ_i |message_i| that the
  paper's C sums charge (all-gather: the gathered result size; all-reduce:
  n × the reduced buffer).  Every preset's payload must equal the resolved
  codec's ``wire_bits + scatter_bits`` accounting exactly (scatter_bits is
  nonzero only for the §12 flat-scatter presets: the i32 rank-offset counts
  plus the decoded f32 shard gather), binary must undercut the dense
  f32 simulation ≥ 8× (it lands at ~32×), the §7.2 rotated presets must
  cost exactly their un-rotated codec's payload (seed-only overhead), and
  the error-feedback presets must cost exactly their EF-free codec's
  payload byte-for-byte (residuals are local — repro.core.wire.ef), with
  ``ternary_opt`` equal to ``ternary_packed`` (the §6 split rides the
  plane).

The ``robust`` section times the decode-policy hook (DESIGN.md §14):
trim(1)/trim(2) vs plain-mean decode µs for every gather preset at the
same d/n — the wire is policy-blind, so the delta is pure
order-statistics cost on the gathered stack.

:func:`collect` is the machine-readable entry point benchmarks/run.py uses
to emit BENCH_collectives.json.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, functools, json, re, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import registry as cfg_registry
from repro.core import collectives, types, wire
from repro.launch import hlo_cost

mesh = jax.make_mesh((8,), ("data",))
N = 8
D = int(os.environ.get("BENCH_D", 1 << 20))
REPS = int(os.environ.get("BENCH_REPS", 3))

def preset_cfgs():
    out = {"none": types.CompressionConfig(mode="none")}
    for name in sorted(cfg_registry.COMPRESSION_PRESETS):
        out[name] = cfg_registry.compression_preset(name, axes=("data",))
    # reference points: the fixed-k star path and the dense simulation.
    out["fixed_k_gather"] = dataclasses.replace(
        out["fixed_k_1bit"], mode="gather_decode")
    out["binary_dense"] = dataclasses.replace(
        out["binary_packed"], mode="dense_sim", scatter_decode=False)
    # f32 wire for the sweep: the CPU backend lowers bf16 collectives at
    # f32 (the measured bytes would be 2x the bf16 accounting), so the
    # payload==accounting equality is only byte-exact at f32 — same
    # normalization as tests/distributed_checks/*.  TPU keeps bf16 native;
    # the shipped presets themselves stay bf16.
    return {k: dataclasses.replace(v, min_compress_size=0,
                                   wire_dtype="float32")
            for k, v in out.items()}

res = {"schema": 1, "n": N, "d": D, "reps": REPS, "wire_dtype": "float32",
       "presets": {}}
xs = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32) * 0.3
key = jax.random.PRNGKey(1)
for name, cfg in preset_cfgs().items():
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(x, k):
        return collectives.compressed_mean(x.reshape(D), k, cfg)
    fj = jax.jit(f)
    comp = fj.lower(jax.ShapeDtypeStruct((N, D), jnp.float32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
    txt = comp.as_text()
    hc = hlo_cost.analyze_text(txt)
    # star payload Σ_i |message_i|, read straight off the collective result
    # shapes (all-gather: the gathered result; all-reduce: N × the reduced
    # buffer).  Deliberately NOT via hlo_cost's ring bytes: those apply the
    # TPU-normalization heuristics of DESIGN.md §6 (large f32 gathers are
    # assumed to be CPU-legalized bf16 and charged half), which would
    # misprice this sweep's genuine f32 wire buffers.
    nbytes = {"f32": 4, "u32": 4, "s32": 4, "bf16": 2}
    payload = 0.0
    for dt, dims, op in re.findall(
            r"= (f32|u32|s32|bf16)\[([\d,]+)\]\S* (all-gather|all-reduce)"
            r"(?:-start)?\(", txt):
        b = nbytes[dt]
        for x in dims.split(","):
            b *= int(x)
        payload += b * (N if op == "all-reduce" else 1)
    fj(xs, key).block_until_ready()  # warm: compile + first-touch allocs
    fj(xs, key).block_until_ready()  # settle — same discipline as the
    # overlap + device_step sections, so µs are comparable in kind.  NOTE
    # step_time_us is still 8 virtual devices serialized on one core at
    # BENCH_D with a free in-memory wire: absolute µs are NOT comparable
    # to the overlap section (a whole L-layer MLP step at a much smaller
    # total grad dim) — bench_device_step models the per-device step.
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fj(xs, key)
    out.block_until_ready()
    step_us = (time.perf_counter() - t0) / REPS * 1e6
    entry = {"wire_bytes": hc.coll_wire_bytes, "payload_bytes": payload,
             "step_time_us": step_us,
             "ops": {k: round(v) for k, v in hc.coll_exec.items()}}
    if cfg.mode != "none":
        codec = wire.resolve(cfg)
        entry["codec"] = codec.name
        entry["reduce"] = codec.reduce
        # flat-scatter presets (§12) ship two extra collectives — the
        # i32 rank-offset counts and the decoded f32 shard gather —
        # billed by scatter_bits; hier/non-scatter presets add 0.
        entry["accounted_payload_bytes"] = (
            codec.wire_bits(N, D, cfg) + codec.scatter_bits(N, D, cfg)) / 8
        # recorded separately so the cross-preset equalities (rotation is
        # seed-only, EF rides the inner format) can compare wire payloads
        # net of the scatter-decode gathers.
        entry["scatter_payload_bytes"] = codec.scatter_bits(N, D, cfg) / 8
    res["presets"][name] = entry

# robust decode overhead: trimmed vs mean decode us per gather preset at
# the same d/n (f = 0 is the mean round already timed above; trim(f) only
# changes the DECODE reduction — the wire is policy-blind, so any delta is
# pure order-statistics cost on the gathered stack).
res["robust"] = {}
for name, cfg in preset_cfgs().items():
    if cfg.mode == "none":
        continue
    if wire.resolve(cfg).reduce != "all_gather":
        continue  # psum codecs reject robust policies (no per-peer rows)
    entry = {"mean_us": res["presets"][name]["step_time_us"]}
    for f_, tag in ((1, "trim1_us"), (2, "trim2_us")):
        rcfg = dataclasses.replace(cfg, decode_policy=f"trim({f_})")
        @functools.partial(compat.shard_map, mesh=mesh,
                           in_specs=(P("data"), P()), out_specs=P(),
                           check_vma=False)
        def f(x, k, rcfg=rcfg):
            return collectives.compressed_mean(x.reshape(D), k, rcfg)
        fj = jax.jit(f)
        fj(xs, key).block_until_ready()
        fj(xs, key).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(REPS):
            out = fj(xs, key)
        out.block_until_ready()
        entry[tag] = (time.perf_counter() - t0) / REPS * 1e6
    entry["trim_overhead_x"] = entry["trim1_us"] / max(entry["mean_us"],
                                                       1e-9)
    res["robust"][name] = entry
print(json.dumps(res))
"""


# One forced-device-count process per simulated node count: flat vs
# hierarchical cross-host traffic + wall-clock for the linear gather codecs
# (docs/DESIGN.md §11).  Cross-host = any collective whose replica group
# spans two inner blocks (device linear id = pod*n_in + data).
_NODE_INNER = r"""
import os
N = int(os.environ["BENCH_N"])
N_IN = int(os.environ.get("BENCH_N_IN", 2))
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N}"
import dataclasses, functools, json, re, time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.configs import registry as cfg_registry
from repro.core import collectives, wire

D = int(os.environ.get("BENCH_D", 1 << 18))
REPS = int(os.environ.get("BENCH_REPS", 3))
mesh = Mesh(np.array(jax.devices()).reshape(N // N_IN, N_IN),
            ("pod", "data"))
MSIZES = {"pod": N // N_IN, "data": N_IN}

def cross_host_bytes(txt):
    nbytes = {"f32": 4, "u32": 4, "bf16": 2}
    total = 0.0
    for line in txt.splitlines():
        m = re.search(r"= (f32|u32|bf16)\[([\d,]*)\]\S* "
                      r"(all-gather|all-reduce)(?:-start)?\(", line)
        if not m:
            continue
        g = re.search(r"replica_groups=\{\{([\d,{} ]*)\}\}", line)
        if not g:
            continue
        groups = [[int(v) for v in grp.split(",") if v.strip()]
                  for grp in g.group(1).split("},{")]
        if not any(len({i // N_IN for i in grp}) > 1 for grp in groups):
            continue
        b = nbytes[m.group(1)]
        for v in m.group(2).split(","):
            if v:
                b *= int(v)
        total += b * (N if m.group(3) == "all-reduce" else 1)
    return total

def bench(cfg):
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P(("pod", "data")), P()), out_specs=P(),
                       check_vma=False, check_rep=False)
    def f(x, k):
        return collectives.compressed_mean(x.reshape(D), k, cfg)
    fj = jax.jit(f)
    comp = fj.lower(jax.ShapeDtypeStruct((N, D), jnp.float32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
    xs = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32) * 0.3
    key = jax.random.PRNGKey(1)
    fj(xs, key).block_until_ready()
    fj(xs, key).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fj(xs, key)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / REPS * 1e6
    return us, cross_host_bytes(comp.as_text())

res = {"n": N, "n_in": N_IN, "d": D, "reps": REPS, "codecs": {}}
for name in ("bernoulli", "fixed_k"):
    hier = dataclasses.replace(
        cfg_registry.compression_preset("hier_" + name),
        wire_dtype="float32", min_compress_size=0)
    flat = dataclasses.replace(hier, axes=("pod", "data"), inner_axes=(),
                               scatter_decode=False)
    flat_us, flat_cross = bench(flat)
    hier_us, hier_cross = bench(hier)
    n_eff = wire.effective_nodes(hier, N, MSIZES)
    res["codecs"][name] = {
        "flat_us": flat_us, "hier_us": hier_us,
        "flat_payload_bytes": flat_cross,
        "hier_cross_bytes": hier_cross,
        "accounted_cross_bytes":
            wire.resolve(hier).wire_bits(n_eff, D, hier) / 8,
    }
print(json.dumps(res))
"""


_CACHE: dict = {}


def collect(d: int | None = None, reps: int = 3, timeout: int = 900) -> dict:
    """Run the 8-device sweep in a subprocess; returns the JSON payload.

    Memoized per (d, reps) so run.py's CSV rows and JSON record share one
    sweep.
    """
    if (d, reps) in _CACHE:
        return _CACHE[(d, reps)]
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    if d is not None:
        env["BENCH_D"] = str(d)
    env["BENCH_REPS"] = str(reps)
    proc = subprocess.run([sys.executable, "-c", _INNER], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_collectives subprocess failed:\n"
                           f"{proc.stderr[-2000:]}")
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    _CACHE[(d, reps)] = res
    return res


def collect_node_sweep(ns: tuple = (4, 8, 16), d: int = 1 << 18,
                       reps: int = 3, timeout: int = 900) -> dict:
    """Flat vs hierarchical collectives across simulated node counts.

    One subprocess per n (the fake-device count is locked at jax init), a
    (n/2, 2)-mesh each; returns ``{str(n): record}`` for the JSON
    ``node_sweep`` section.  Memoized per (ns, d, reps) like collect().
    """
    ck = ("node_sweep", tuple(ns), d, reps)
    if ck in _CACHE:
        return _CACHE[ck]
    root = pathlib.Path(__file__).resolve().parent.parent
    out = {}
    for n in ns:
        env = dict(os.environ)
        env["PYTHONPATH"] = str(root / "src")
        env.pop("XLA_FLAGS", None)
        env["BENCH_N"] = str(n)
        env["BENCH_D"] = str(d)
        env["BENCH_REPS"] = str(reps)
        proc = subprocess.run([sys.executable, "-c", _NODE_INNER], env=env,
                              capture_output=True, text=True,
                              timeout=timeout)
        if proc.returncode != 0:
            raise RuntimeError(f"node_sweep subprocess (n={n}) failed:\n"
                               f"{proc.stderr[-2000:]}")
        out[str(n)] = json.loads(proc.stdout.strip().splitlines()[-1])
    _CACHE[ck] = out
    return out


def check_node_scaling(sweep: dict) -> list:
    """Node-sweep invariants (must be empty):

    * the hierarchy's cross-host bytes equal the effective-n accounting
      exactly and shrink by the inner-group factor vs flat, at every n;
    * at the largest simulated n, the reduce-scatter decode beats the flat
      gather decode wall-clock for bernoulli — the codec whose decode
      regenerates n·d support draws when flat but only n_eff·(d/n_in) when
      sharded, so decode FLOPs dominate and the O(n·d) → O(d) win shows up
      even on fake single-core meshes.
    """
    bad = []
    for n, rec in sweep.items():
        for name, e in rec["codecs"].items():
            if e["hier_cross_bytes"] != e["accounted_cross_bytes"]:
                bad.append(f"node_sweep n={n} {name}: cross bytes "
                           f"{e['hier_cross_bytes']:.0f} != accounted "
                           f"{e['accounted_cross_bytes']:.0f}")
            if e["flat_payload_bytes"] != rec["n_in"] * e["hier_cross_bytes"]:
                bad.append(f"node_sweep n={n} {name}: flat payload "
                           f"{e['flat_payload_bytes']:.0f} != n_in x hier "
                           f"{rec['n_in'] * e['hier_cross_bytes']:.0f}")
    top = max(sweep, key=int)
    e = sweep[top]["codecs"]["bernoulli"]
    if not e["hier_us"] < e["flat_us"]:
        bad.append(f"node_sweep n={top} bernoulli: hier {e['hier_us']:.0f}us "
                   f"not faster than flat {e['flat_us']:.0f}us")
    return bad


def check_payload_accounting(res: dict) -> list:
    """Presets whose HLO payload ≠ the codec registry's wire_bits (must be
    empty), plus the §7.2 seed-only-overhead equalities."""
    bad = []
    presets = res["presets"]

    def wire_pl(name):
        # wire payload net of the scatter-decode gathers: the equalities
        # below are statements about the ENCODED message format, which
        # presets shipping scatter_decode (extra decoded-shard/counts
        # gathers, billed separately by scatter_bits) share unchanged.
        e = presets[name]
        return e["payload_bytes"] - e.get("scatter_payload_bytes", 0.0)

    for name, e in presets.items():
        if "accounted_payload_bytes" in e and \
                e["payload_bytes"] != e["accounted_payload_bytes"]:
            bad.append(f"{name}: payload={e['payload_bytes']:.0f}B "
                       f"!= accounting={e['accounted_payload_bytes']:.0f}B")
    for rot, plain in (("rotated_binary", "binary_packed"),
                       ("rotated_fixed_k", "fixed_k_gather")):
        # d is a power of two in this bench → wire payloads must be equal.
        if wire_pl(rot) != wire_pl(plain):
            bad.append(f"{rot}: wire payload != {plain} "
                       f"({wire_pl(rot):.0f} vs {wire_pl(plain):.0f})")
    for efp, plain in (("ef_fixed_k", "fixed_k_gather"),
                       ("ef_bernoulli", "bernoulli_seed_1bit"),
                       ("ef_binary", "binary_packed"),
                       ("ef_ternary", "ternary_packed"),
                       ("ef_rotated_binary", "rotated_binary"),
                       ("ternary_opt", "ternary_packed")):
        # EF residuals are local and the §6 ternary split rides the plane:
        # wire payload must equal the plain codec byte-for-byte.
        if wire_pl(efp) != wire_pl(plain):
            bad.append(f"{efp}: wire payload != {plain} "
                       f"({wire_pl(efp):.0f} vs {wire_pl(plain):.0f})")
    return bad


def rows():
    t0 = time.perf_counter()
    try:
        res = collect()
    except RuntimeError as e:
        dt = (time.perf_counter() - t0) * 1e6
        return [{"name": "collectives.wire_bytes", "us_per_call": dt,
                 "derived": f"FAILED: {str(e)[-300:]}", "check": False}]
    dt = (time.perf_counter() - t0) * 1e6
    p = res["presets"]
    exact = p["none"]["wire_bytes"]
    shared = p["fixed_k_1bit"]["wire_bytes"]
    gather = p["fixed_k_gather"]["wire_bytes"]
    # wire payloads net of the scatter-decode gathers (recorded separately
    # in scatter_payload_bytes): the ratios below compare message formats.
    def _wire_pl(name):
        return p[name]["payload_bytes"] - p[name].get(
            "scatter_payload_bytes", 0.0)
    dense_pl = _wire_pl("binary_dense")
    bin_pl = _wire_pl("binary_packed")
    tern_pl = _wire_pl("ternary_packed")
    rot_pl = _wire_pl("rotated_binary")
    bad = check_payload_accounting(res)
    t1 = time.perf_counter()
    try:
        sweep = collect_node_sweep()
    except RuntimeError as e:
        node_row = {"name": "collectives.node_sweep",
                    "us_per_call": (time.perf_counter() - t1) * 1e6,
                    "derived": f"FAILED: {str(e)[-300:]}", "check": False}
    else:
        nbad = check_node_scaling(sweep)
        top = max(sweep, key=int)
        e = sweep[top]["codecs"]["bernoulli"]
        node_row = {
            "name": "collectives.node_sweep",
            "us_per_call": (time.perf_counter() - t1) * 1e6,
            "derived": (f"n={top} bernoulli flat={e['flat_us']:.0f}us "
                        f"hier={e['hier_us']:.0f}us "
                        f"(x{e['flat_us'] / max(e['hier_us'], 1):.1f}); "
                        f"cross B flat={e['flat_payload_bytes']:.2e} "
                        f"hier={e['hier_cross_bytes']:.2e}"
                        + ("; " + "; ".join(nbad) if nbad else "")),
            # cross-host bytes == effective-n accounting at every n AND the
            # reduce-scatter decode beats flat gather wall-clock at the
            # largest n.
            "check": not nbad,
        }
    rb = res.get("robust", {})
    if rb:
        ovh = sorted(e["trim_overhead_x"] for e in rb.values())
        med = ovh[len(ovh) // 2]
        worst = max(rb, key=lambda k: rb[k]["trim_overhead_x"])
        robust_row = {
            "name": "collectives.robust_decode",
            "us_per_call": dt,
            "derived": (f"{len(rb)} gather presets; trim(1)/mean decode "
                        f"overhead min=x{ovh[0]:.2f} med=x{med:.2f} "
                        f"max=x{ovh[-1]:.2f} ({worst})"),
            # presence + sanity only: every gather preset reports positive
            # trimmed-decode timings (wall-clock ratios on fake devices
            # are too noisy for a tight gate).
            "check": all(e["trim1_us"] > 0 and e["trim2_us"] > 0
                         for e in rb.values()),
        }
    else:
        robust_row = {"name": "collectives.robust_decode",
                      "us_per_call": dt,
                      "derived": "FAILED: no robust section in sweep",
                      "check": False}
    return [
        {
            "name": "collectives.wire_bytes",
            "us_per_call": dt,
            "derived": (f"exact={exact:.3e}B shared={shared:.3e}B "
                        f"(x{exact / max(shared, 1):.1f} less) "
                        f"gather={gather:.3e}B (x{exact / max(gather, 1):.1f})"),
            # shared-support at k/d = 1/16 must cut ≥8x vs exact all-reduce
            "check": shared * 8 < exact,
        },
        {
            "name": "collectives.packed_planes",
            "us_per_call": dt,
            "derived": (f"dense_sim={dense_pl:.3e}B binary={bin_pl:.3e}B "
                        f"(x{dense_pl / max(bin_pl, 1):.1f} less) "
                        f"ternary={tern_pl:.3e}B "
                        f"(x{dense_pl / max(tern_pl, 1):.1f})"),
            # ≥8x payload reduction for the packed 1-bit plane vs the dense
            # f32 simulation.
            "check": bin_pl * 8 <= dense_pl,
        },
        {
            "name": "collectives.registry_accounting",
            "us_per_call": dt,
            "derived": (f"{len(p)} presets; rotated_binary={rot_pl:.3e}B "
                        f"(== binary_packed: {rot_pl == bin_pl}); "
                        + ("; ".join(bad) if bad else "payload==wire_bits "
                           "for every codec-backed preset")),
            # every preset's HLO payload equals the codec registry's
            # accounting; rotated presets cost exactly their inner codec.
            "check": not bad,
        },
        robust_row,
        node_row,
    ]
