"""Wire bytes of the compressed-mean collective vs exact pmean, measured
from lowered HLO on an 8-device mesh (subprocess: device count is locked at
first jax init, and benchmarks must see 1 device by default).

Two byte conventions are reported per mode:

* ``wire_bytes`` — ring-adjusted per-device wire traffic (hlo_cost's
  roofline convention: all-reduce pays 2·b·(s−1)/s, all-gather b·(s−1)/s);
* ``payload_bytes`` — the star-protocol payload Σ_i |message_i| that the
  paper's C sums charge (all-gather: the gathered result size; all-reduce:
  n × the reduced buffer).  The packed bit-plane modes must match
  ``comm_cost`` accounting exactly in this convention, and binary must
  undercut the dense f32 simulation ≥ 8× (it lands at ~32×: 1 bit vs 32
  bits per coordinate).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, comm_cost, types
from repro.launch import hlo_cost

mesh = jax.make_mesh((8,), ("data",))
N = 8
D = 1 << 20
MODES = {
    "none": ("none", types.EncoderSpec(kind="fixed_k", fraction=1.0)),
    "shared_support": ("shared_support",
                       types.EncoderSpec(kind="fixed_k", fraction=1/16)),
    "gather_decode": ("gather_decode",
                      types.EncoderSpec(kind="fixed_k", fraction=1/16)),
    "binary_dense": ("dense_sim", types.EncoderSpec(kind="binary")),
    "binary_packed": ("gather_decode", types.EncoderSpec(kind="binary")),
    "ternary_packed": ("gather_decode",
                       types.EncoderSpec(kind="ternary", fraction=1/16)),
}
res = {}
for name, (mode, enc) in MODES.items():
    cfg = types.CompressionConfig(encoder=enc, mode=mode, axes=("data",),
                                  min_compress_size=0)
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    comp = lowered.compile()
    hc = hlo_cost.analyze_text(comp.as_text())
    # star payload: undo the per-op ring factors (group size 8).
    payload = (hc.coll_bytes_by_op.get("all-gather", 0.0) / (7 / 8)
               + hc.coll_bytes_by_op.get("all-reduce", 0.0)
               / (2 * 7 / 8) * N)
    res[name] = {"wire_bytes": hc.coll_wire_bytes,
                 "payload_bytes": payload,
                 "ops": {k: round(v) for k, v in hc.coll_exec.items()}}

# comm_cost accounting for the packed planes (bf16 wire -> r = 16).
spec16 = types.CommSpec(protocol="binary", r_bits=16)
res["_expect"] = {
    "binary_packed": comm_cost.cost_binary_packed(N, D, spec16) / 8,
    "ternary_packed": comm_cost.cost_ternary_packed(
        N, D, comm_cost.bernoulli_capacity(D, 1/16), spec16) / 8,
}
print(json.dumps(res))
"""


def rows():
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", _INNER], env=env,
                          capture_output=True, text=True, timeout=600)
    dt = (time.perf_counter() - t0) * 1e6
    if proc.returncode != 0:
        return [{"name": "collectives.wire_bytes", "us_per_call": dt,
                 "derived": f"FAILED: {proc.stderr[-300:]}", "check": False}]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    exact = res["none"]["wire_bytes"]
    shared = res["shared_support"]["wire_bytes"]
    gather = res["gather_decode"]["wire_bytes"]
    dense_pl = res["binary_dense"]["payload_bytes"]
    bin_pl = res["binary_packed"]["payload_bytes"]
    tern_pl = res["ternary_packed"]["payload_bytes"]
    expect = res["_expect"]
    return [
        {
            "name": "collectives.wire_bytes",
            "us_per_call": dt,
            "derived": (f"exact={exact:.3e}B shared={shared:.3e}B "
                        f"(x{exact / max(shared, 1):.1f} less) "
                        f"gather={gather:.3e}B (x{exact / max(gather, 1):.1f})"),
            # shared-support at k/d = 1/16 must cut ≥8x vs exact all-reduce
            "check": shared * 8 < exact,
        },
        {
            "name": "collectives.packed_planes",
            "us_per_call": dt,
            "derived": (f"dense_sim={dense_pl:.3e}B binary={bin_pl:.3e}B "
                        f"(x{dense_pl / max(bin_pl, 1):.1f} less) "
                        f"ternary={tern_pl:.3e}B "
                        f"(x{dense_pl / max(tern_pl, 1):.1f}); "
                        f"ring-wire binary={res['binary_packed']['wire_bytes']:.3e}B"
                        f" vs dense={res['binary_dense']['wire_bytes']:.3e}B"),
            # ≥8x payload reduction for the packed 1-bit plane vs the dense
            # f32 simulation, and both packed modes must match comm_cost
            # accounting exactly.
            "check": (bin_pl * 8 <= dense_pl
                      and bin_pl == expect["binary_packed"]
                      and tern_pl == expect["ternary_packed"]),
        },
    ]
