"""Wire bytes of the compressed-mean collective vs exact pmean, measured
from lowered HLO on an 8-device mesh (subprocess: device count is locked at
first jax init, and benchmarks must see 1 device by default)."""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, types
from repro.launch import hlo_cost

mesh = jax.make_mesh((8,), ("data",))
D = 1 << 20
res = {}
for mode, frac in (("none", 1.0), ("shared_support", 1/16),
                   ("gather_decode", 1/16)):
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="fixed_k", fraction=frac),
        mode=mode, axes=("data",), min_compress_size=0)
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    comp = lowered.compile()
    hc = hlo_cost.analyze_text(comp.as_text())
    res[mode] = {"wire_bytes": hc.coll_wire_bytes,
                 "ops": {k: round(v) for k, v in hc.coll_exec.items()}}
print(json.dumps(res))
"""


def rows():
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", _INNER], env=env,
                          capture_output=True, text=True, timeout=600)
    dt = (time.perf_counter() - t0) * 1e6
    if proc.returncode != 0:
        return [{"name": "collectives.wire_bytes", "us_per_call": dt,
                 "derived": f"FAILED: {proc.stderr[-300:]}", "check": False}]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    exact = res["none"]["wire_bytes"]
    shared = res["shared_support"]["wire_bytes"]
    gather = res["gather_decode"]["wire_bytes"]
    return [{
        "name": "collectives.wire_bytes",
        "us_per_call": dt,
        "derived": (f"exact={exact:.3e}B shared={shared:.3e}B "
                    f"(x{exact / max(shared, 1):.1f} less) "
                    f"gather={gather:.3e}B (x{exact / max(gather, 1):.1f})"),
        # shared-support at k/d = 1/16 must cut ≥8x vs exact all-reduce
        "check": shared * 8 < exact,
    }]
