"""Example 4 / Remark 3: binary quantization (Suresh et al. [10]) recovered
as a special case, its exact MSE vs the [10, Thm 1] bound, and the
Hadamard-rotation variant."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import mse, protocol, rotation, types

N, D = 16, 512


def rows():
    key = jax.random.PRNGKey(3)
    # skewed data (one hot-ish coordinates) where rotation helps most
    xs = jax.random.normal(key, (N, D)) * 0.1
    xs = xs.at[:, 0].add(5.0)
    out = []

    est = protocol.MeanEstimator(types.EncoderSpec(kind="binary"),
                                 types.CommSpec(protocol="binary"))
    t0 = time.perf_counter()
    emp = float(protocol.empirical_mse(jax.random.PRNGKey(4), xs, est,
                                       trials=300))
    dt = (time.perf_counter() - t0) * 1e6 / 300
    exact = float(mse.mse_binary(xs))
    bound = float(mse.mse_binary_bound(xs))
    out.append({
        "name": "quantization.binary",
        "us_per_call": dt,
        "derived": f"mse_emp={emp:.4f} mse_exact={exact:.4f} "
                   f"suresh_bound={bound:.4f}",
        "check": emp <= bound * 1.05 and abs(emp - exact) / exact < 0.25,
    })

    est_rot = protocol.MeanEstimator(
        types.EncoderSpec(kind="binary", rotation=True),
        types.CommSpec(protocol="binary"))
    t0 = time.perf_counter()
    emp_rot = float(protocol.empirical_mse(jax.random.PRNGKey(5), xs, est_rot,
                                           trials=300))
    dt = (time.perf_counter() - t0) * 1e6 / 300
    out.append({
        "name": "quantization.binary_rotated",
        "us_per_call": dt,
        "derived": f"mse_rotated={emp_rot:.4f} vs plain={emp:.4f} "
                   f"(rotation gain x{emp / max(emp_rot, 1e-12):.1f})",
        # Remark 3: rotation improves binary quantization on skewed data
        "check": emp_rot < emp,
    })

    # paper's headline: the 1-bit bernoulli point beats rotated binary
    # quantization in MSE-per-bit without the O(d log d) rotation.
    est_1bit = protocol.MeanEstimator(
        types.EncoderSpec(kind="bernoulli", fraction=1.0 / 16, center="mean"),
        types.CommSpec(protocol="sparse_seed"))
    emp_1bit = float(protocol.empirical_mse(jax.random.PRNGKey(6), xs,
                                            est_1bit, trials=300))
    out.append({
        "name": "quantization.paper_1bit_point",
        "us_per_call": dt,
        "derived": f"mse_1bit={emp_1bit:.4f} (r-1)R/n="
                   f"{15 * float(mse.r_factor(xs, jnp.mean(xs, -1))) / N:.4f}",
        "check": emp_1bit < emp,  # beats unrotated binary quantization
    })
    return out
