"""§1.1 claim: the paper's encoder is O(d); the rotation baseline is
O(d log d).  Wall-clock per-element time over a d sweep + kernel-path
throughput (oracle path on CPU; the Pallas kernels are the TPU target)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bernoulli_encode import ops as bern_ops
from repro.kernels.binary_quant import ops as bq_ops
from repro.kernels.fixed_k_encode import ops as fk_ops
from repro.kernels.fixed_k_encode import ref as fk_ref
from repro.kernels.hadamard import ops as h_ops


def _time(fn, reps=20):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def rows():
    out = []
    key = jax.random.PRNGKey(0)
    for d in (1 << 16, 1 << 20):
        x = jax.random.normal(key, (d,))
        t_bern = _time(jax.jit(
            lambda x=x: bern_ops.bernoulli_encode(x, 1 / 16, 0.0, 7)))
        nb = fk_ops.num_blocks(d)
        ids = fk_ref.sample_blocks(key, nb, max(1, nb // 16))
        t_fk = _time(jax.jit(
            lambda x=x, ids=ids: fk_ops.fixed_k_encode(x, ids, 0.0)))
        t_bq = _time(jax.jit(lambda x=x: bq_ops.binary_encode(x, 7)[0]))
        t_had = _time(jax.jit(lambda x=x: h_ops.fwht(x)))
        out.append({
            "name": f"encode_speed.d{d}",
            "us_per_call": t_bern * 1e6,
            "derived": (f"bern={t_bern * 1e9 / d:.2f}ns/el "
                        f"fixed_k={t_fk * 1e9 / d:.2f}ns/el "
                        f"binary={t_bq * 1e9 / d:.2f}ns/el "
                        f"hadamard={t_had * 1e9 / d:.2f}ns/el"),
            "check": t_bern > 0,
        })
    # O(d) vs O(d log d): per-element hadamard time should grow with d;
    # per-element bernoulli time should stay ~flat.
    return out
