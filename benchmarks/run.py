"""Benchmark driver — one module per paper table/figure.

Default run prints ``name,us_per_call,derived`` CSV (plus a check column)
for every bench module AND writes ``BENCH_collectives.json`` — the
machine-readable per-preset payload-bytes + step-time record that the perf
trajectory tracks across PRs.  Exits non-zero if any paper-invariant check
fails.

``--smoke`` runs the JSON-emitting collectives sweep at a small dimension,
validates the schema, AND runs the modeled device-step gate at d = 2²⁰
(bench_device_step): every compressed preset must beat the dense-f32
baseline in modeled µs/step — the success metric of the fused wire
kernels.  (No Table-1/tradeoff Monte Carlo.)

Flags:
  --smoke        small-d collectives sweep + schema check + the d=2²⁰
                 compressed-beats-dense device-step gate
  --json PATH    where to write the JSON record (default:
                 BENCH_collectives.json in the repo root)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; make the `benchmarks` package importable regardless of cwd.
_ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

SCHEMA_REQUIRED = {"schema", "n", "d", "presets", "overlap", "device_step",
                   "node_sweep", "robust"}
PRESET_REQUIRED = {"wire_bytes", "payload_bytes", "step_time_us", "ops"}
DEVICE_STEP_REQUIRED = {"pack_us", "decode_us", "unpack_us", "wire_us",
                        "modeled_us", "row_bytes", "decode_stages"}
# every flat-scatter breakdown has the accumulate + modeled-gather stages;
# the per-device prep stage is regenerate_us (bernoulli seed trick) or
# unpack_us (§13 bit-plane windows) — fixed_k's analytic window has none.
DECODE_STAGES_REQUIRED = {"accumulate_us", "shard_gather_us"}
# codecs + node counts the full-vs-shard decode sweep must cover.
DECODE_SWEEP_CODECS = {"bernoulli", "binary"}
DECODE_SWEEP_NS = {"2", "8"}
OVERLAP_REQUIRED = {"overlap_us", "post_us", "overlap_launches",
                    "post_launches", "buckets", "schedule"}
NODE_SWEEP_REQUIRED = {"flat_us", "hier_us", "flat_payload_bytes",
                       "hier_cross_bytes", "accounted_cross_bytes"}
ROBUST_REQUIRED = {"mean_us", "trim1_us", "trim2_us", "trim_overhead_x"}
# gather presets the robust decode-policy timing must cover (psum codecs
# reject robust policies at resolve, so they are rightly absent).
CORE_ROBUST_PRESETS = {"bernoulli_seed_1bit", "binary_packed",
                       "ternary_packed", "ternary_opt", "rotated_binary",
                       "rotated_fixed_k", "ef_fixed_k", "ef_bernoulli",
                       "ef_binary", "ef_ternary", "ef_rotated_binary",
                       "fixed_k_gather"}
# simulated node counts the hierarchical flat-vs-two-level sweep must cover.
CORE_NODE_COUNTS = {"4", "8", "16"}
# schedules that must stay in the overlap record for trajectory comparison.
CORE_OVERLAP_PRESETS = {"none", "fixed_k_1bit", "bernoulli_seed_1bit",
                        "binary_packed", "ternary_opt", "ef_rotated_binary"}
# presets that must be present for the trajectory to stay comparable.
CORE_PRESETS = {"none", "fixed_k_1bit", "bernoulli_seed_1bit",
                "binary_packed", "ternary_packed", "ternary_opt",
                "rotated_binary", "rotated_fixed_k",
                "ef_fixed_k", "ef_bernoulli", "ef_binary", "ef_ternary",
                "ef_rotated_binary", "fixed_k_gather", "binary_dense"}


def validate_schema(res: dict) -> list:
    """Schema violations in a collectives JSON record (empty == valid)."""
    bad = []
    missing = SCHEMA_REQUIRED - set(res)
    if missing:
        bad.append(f"missing top-level keys: {sorted(missing)}")
        return bad
    if res["schema"] != 1:
        bad.append(f"unknown schema version {res['schema']}")
    missing_presets = CORE_PRESETS - set(res["presets"])
    if missing_presets:
        bad.append(f"missing presets: {sorted(missing_presets)}")
    for name, e in res["presets"].items():
        miss = PRESET_REQUIRED - set(e)
        if miss:
            bad.append(f"preset {name}: missing {sorted(miss)}")
        elif not (e["payload_bytes"] > 0 and e["step_time_us"] > 0):
            bad.append(f"preset {name}: non-positive measurements {e}")
    ds = res.get("device_step", {})
    missing_ds = CORE_PRESETS - set(ds.get("presets", {}))
    if missing_ds:
        bad.append(f"device_step: missing presets {sorted(missing_ds)}")
    for name, e in ds.get("presets", {}).items():
        miss = DEVICE_STEP_REQUIRED - set(e)
        if miss:
            bad.append(f"device_step {name}: missing {sorted(miss)}")
        elif not (e["modeled_us"] > 0 and e["wire_us"] > 0):
            bad.append(f"device_step {name}: non-positive model {e}")
        elif e["unpack_us"] == 0.0:
            # presets with no unpack stage must report null, not a fake 0.
            bad.append(f"device_step {name}: unpack_us must be null or a "
                       f"real measurement, got 0.0")
        elif e["decode_stages"] is not None and \
                DECODE_STAGES_REQUIRED - set(e["decode_stages"]):
            bad.append(f"device_step {name}: decode_stages missing "
                       f"{sorted(DECODE_STAGES_REQUIRED - set(e['decode_stages']))}")
    sweep_codecs = ds.get("decode_n_sweep", {}).get("codecs", {})
    missing_sc = DECODE_SWEEP_CODECS - set(sweep_codecs)
    if missing_sc:
        bad.append(f"device_step.decode_n_sweep: missing codecs "
                   f"{sorted(missing_sc)}")
    for cname, rec in sweep_codecs.items():
        sweep_ns = rec.get("ns", {})
        missing_sw = DECODE_SWEEP_NS - set(sweep_ns)
        if missing_sw:
            bad.append(f"device_step.decode_n_sweep {cname}: missing node "
                       f"counts {sorted(missing_sw)}")
        for n, e in sweep_ns.items():
            if not (e.get("full_us", 0) > 0 and e.get("shard_us", 0) > 0):
                bad.append(f"device_step.decode_n_sweep {cname} n={n}: "
                           f"non-positive measurements {e}")
    sweep = res.get("node_sweep", {})
    missing_ns = CORE_NODE_COUNTS - set(sweep)
    if missing_ns:
        bad.append(f"node_sweep: missing node counts {sorted(missing_ns)}")
    for n, rec in sweep.items():
        for cname in ("bernoulli", "fixed_k"):
            e = rec.get("codecs", {}).get(cname)
            if e is None:
                bad.append(f"node_sweep n={n}: missing codec {cname}")
                continue
            miss = NODE_SWEEP_REQUIRED - set(e)
            if miss:
                bad.append(f"node_sweep n={n} {cname}: missing {sorted(miss)}")
            elif not (e["hier_us"] > 0 and e["hier_cross_bytes"] > 0):
                bad.append(f"node_sweep n={n} {cname}: "
                           f"non-positive measurements {e}")
    rb = res.get("robust", {})
    missing_rb = CORE_ROBUST_PRESETS - set(rb)
    if missing_rb:
        bad.append(f"robust: missing presets {sorted(missing_rb)}")
    for name, e in rb.items():
        miss = ROBUST_REQUIRED - set(e)
        if miss:
            bad.append(f"robust {name}: missing {sorted(miss)}")
        elif not (e["mean_us"] > 0 and e["trim1_us"] > 0
                  and e["trim2_us"] > 0):
            bad.append(f"robust {name}: non-positive measurements {e}")
    missing_ov = CORE_OVERLAP_PRESETS - set(res.get("overlap", {}))
    if missing_ov:
        bad.append(f"overlap: missing presets {sorted(missing_ov)}")
    for name, e in res.get("overlap", {}).items():
        miss = OVERLAP_REQUIRED - set(e)
        if miss:
            bad.append(f"overlap {name}: missing {sorted(miss)}")
        elif not (e["overlap_us"] > 0 and e["post_us"] > 0
                  and e["overlap_launches"] == e["post_launches"]):
            bad.append(f"overlap {name}: bad measurements {e}")
    return bad


def write_collectives_json(path: pathlib.Path, res: dict) -> list:
    from benchmarks import bench_collectives
    bad = validate_schema(res) + bench_collectives.check_payload_accounting(res)
    path.write_text(json.dumps(res, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path} ({len(res['presets'])} presets, d={res['d']})",
          file=sys.stderr)
    return bad


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast schema-checked collectives sweep only")
    ap.add_argument("--json", type=pathlib.Path,
                    default=pathlib.Path(__file__).resolve().parent.parent
                    / "BENCH_collectives.json")
    args = ap.parse_args(argv)

    from benchmarks import (bench_bucketing, bench_collectives,
                            bench_device_step)

    # committed baseline for the decode-scaling gate: read BEFORE the run
    # overwrites the JSON record.
    try:
        baseline = json.loads(args.json.read_text())
    except (OSError, ValueError):
        baseline = None

    if args.smoke:
        res = bench_collectives.collect(d=1 << 16, reps=1)
        res["smoke"] = True
        res["overlap"] = bench_bucketing.collect_overlap(smoke=True)
        # the device-step gate runs at the FULL d = 2²⁰ even in smoke —
        # it is the compressed-beats-dense success metric, and the model
        # is single-device (no 8-device mesh), so it stays CI-affordable.
        res["device_step"] = bench_device_step.collect()
        # flat-vs-hierarchical node sweep: the reduce-scatter decode must
        # beat the flat gather decode wall-clock at the largest simulated
        # n (kept at the full d — the decode-FLOP asymmetry IS the gate).
        res["node_sweep"] = bench_collectives.collect_node_sweep(reps=1)
        failed = write_collectives_json(args.json, res)
        failed += bench_device_step.check_compressed_beats_dense(
            res["device_step"])
        failed += bench_device_step.check_decode_scaling(
            res["device_step"], baseline)
        failed += bench_collectives.check_node_scaling(res["node_sweep"])
        if failed:
            print(f"FAILED smoke checks: {failed}", file=sys.stderr)
            sys.exit(1)
        print("BENCH smoke OK")
        return

    from benchmarks import (bench_encode_speed, bench_quantization,
                            bench_table1, bench_tradeoff)
    mods = [bench_table1, bench_tradeoff, bench_quantization,
            bench_encode_speed, bench_collectives, bench_bucketing,
            bench_device_step]
    print("name,us_per_call,derived,check")
    failed = []
    for m in mods:
        for r in m.rows():
            ok = bool(r.get("check", True))
            print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\","
                  f"{'ok' if ok else 'FAIL'}")
            if not ok:
                failed.append(r["name"])
    try:
        # memoized: reuses the sweeps the rows() calls above already ran.
        res = bench_collectives.collect()
        res["overlap"] = bench_bucketing.collect_overlap()
        res["device_step"] = bench_device_step.collect()
        res["node_sweep"] = bench_collectives.collect_node_sweep()
    except RuntimeError as e:
        failed.append(f"collectives.json: {str(e)[-300:]}")
    else:
        failed += write_collectives_json(args.json, res)
        failed += bench_device_step.check_decode_scaling(
            res["device_step"], baseline)
    if failed:
        print(f"FAILED checks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
