"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a check column); exits
non-zero if any paper-invariant check fails.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_bucketing, bench_collectives,
                            bench_encode_speed, bench_quantization,
                            bench_table1, bench_tradeoff)
    mods = [bench_table1, bench_tradeoff, bench_quantization,
            bench_encode_speed, bench_collectives, bench_bucketing]
    print("name,us_per_call,derived,check")
    failed = []
    for m in mods:
        for r in m.rows():
            ok = bool(r.get("check", True))
            print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\","
                  f"{'ok' if ok else 'FAIL'}")
            if not ok:
                failed.append(r["name"])
    if failed:
        print(f"FAILED checks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
