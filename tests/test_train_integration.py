"""Multi-device training integration — subprocess with 8 fake devices
(loss decrease under compression+EF, bit-identical restart, elastic
resharding)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_train_integration():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" /
             "train_integration_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL TRAIN INTEGRATION CHECKS PASSED" in res.stdout
