"""Compressed-mean collectives under shard_map, on 8 simulated devices.

The checks need >1 device, and jax locks the device count at first init, so
they run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(keeping this pytest process single-device for the smoke tests)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    return subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" / script)],
        env=env, capture_output=True, text=True, timeout=900)


@pytest.mark.distributed
def test_compressed_mean_collectives():
    res = _run("collectives_check.py")
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL COLLECTIVE CHECKS PASSED" in res.stdout
