"""Serving engine: batched greedy generation driver + cache consistency
(decode after prefill matches a from-scratch prefill of the longer prompt)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeSpec
from repro.configs.registry import smoke_config
from repro.core import types as core_types
from repro.serving import engine
from repro.train import train_step as ts


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = smoke_config("qwen3-4b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = RunConfig(microbatches=1, model_parallel=True, seq_shard=False,
                    attn_chunk_q=16, attn_chunk_k=16, remat=False,
                    compression=core_types.CompressionConfig(mode="none"))
    shape = ShapeSpec("serve", "decode", 64, 4)
    fns = engine.build_serve_fns(mesh, cfg, run, shape)
    _, init_fn, _, _, _ = ts.build_train_step(mesh, cfg, run,
                                           ShapeSpec("t", "train", 32, 4))
    params, _, _ = init_fn(jax.random.PRNGKey(0))
    return cfg, fns, params


def test_generate_driver():
    cfg, (prefill_fn, decode_fn, _, _), params = _setup()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    toks = engine.generate(prefill_fn, decode_fn, params,
                           {"tokens": prompt}, steps=5)
    assert toks.shape == (4, 5)
    assert np.isfinite(np.asarray(toks)).all()


def test_decode_consistent_with_prefill():
    """Teacher-forced decode over positions 16..31 must predict the same
    next token as a from-scratch prefill of the full 32-token prompt."""
    cfg, (prefill_fn, decode_fn, _, _), params = _setup()
    full = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    # path A: prefill the first half, then feed the known second half
    cache, _ = prefill_fn(params, {"tokens": full[:, :16]})
    tok_a = None
    for i in range(16, 32):
        tok_a, cache = decode_fn(params, cache, full[:, i:i + 1],
                                 jnp.int32(i))
    # path B: one prefill of the full prompt
    _, logits_b = prefill_fn(params, {"tokens": full})
    tok_b = jnp.argmax(logits_b, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(tok_a), np.asarray(tok_b))
