"""Regenerate the golden wire-format matrix (tests/test_golden_wire.py).

For every preset in ``repro.configs.registry.COMPRESSION_PRESETS`` this
packs a fixed-seed input through the *resolved* codec and records the raw
wire-buffer bytes.  The committed ``golden_wire.npz`` pins the bit-level
wire format of every shipped preset: any change to buffer layout, PRNG
fold_in chains, capacity rules, packing order or wire dtype flips the
bytes and fails the conformance test — drift that MSE/accounting tests
cannot see (an estimator can stay unbiased while the wire format silently
changes under peers' feet).

Regen (ONLY when a wire-format change is intentional):

    PYTHONPATH=src python tests/golden/regen_golden_wire.py

and commit the refreshed .npz together with the change that caused it.
"""
from __future__ import annotations

import pathlib

import numpy as np

D = 4096          # power of two: the rotated presets pad to 2^k anyway
N_RANKS = 2       # two rows exercise the per-rank fold_in chains
X_SEED = 1234
KEY_SEED = 99

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden_wire.npz"


def build_matrix():
    """{preset: (bytes uint8 [N_RANKS, nbytes], dtype str, slots int)}."""
    import jax
    import jax.numpy as jnp  # noqa: F401  (jax init before repro imports)

    from repro.configs.registry import COMPRESSION_PRESETS, compression_preset
    from repro.core import wire

    xs = np.asarray(
        jax.random.normal(jax.random.PRNGKey(X_SEED), (N_RANKS, D)) * 0.5)
    key = jax.random.PRNGKey(KEY_SEED)
    out = {}
    for name in sorted(COMPRESSION_PRESETS):
        cfg = compression_preset(name, axes=("data",))
        codec = wire.resolve(cfg)
        rows = []
        for r in range(N_RANKS):
            buf = np.asarray(codec.pack(jnp.asarray(xs[r]), key, r, cfg))
            rows.append(np.frombuffer(buf.tobytes(), np.uint8))
        out[name] = (np.stack(rows), str(buf.dtype),
                     int(codec.wire_slots(D, cfg)))
    return out


def main():
    mat = build_matrix()
    arrays = {}
    for name, (rows, dtype, slots) in mat.items():
        arrays[f"{name}.bytes"] = rows
        arrays[f"{name}.dtype"] = np.asarray(dtype)
        arrays[f"{name}.slots"] = np.asarray(slots)
    np.savez_compressed(GOLDEN, **arrays)
    total = sum(a.nbytes for a in arrays.values())
    print(f"wrote {GOLDEN} ({len(mat)} presets, {total} raw bytes)")


if __name__ == "__main__":
    main()
