"""Error feedback as a wire-layer composition (repro.core.wire.ef).

Meshless coverage of the EF plumbing — state shapes driven by the resolved
codec, the deprecated shim, the contractive-twin wire formats — plus the
8-device end-to-end run (tests/distributed_checks/ef_wire_check.py,
launched here as a subprocess: HLO payload identity, contraction,
registry-preset resolution).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import simulate_wire_round as _simulate_round
from repro.configs import registry as cfg_registry
from repro.core import types, wire
from repro.train import bucketing

ROOT = pathlib.Path(__file__).resolve().parent.parent
N = 8


def _cfg(kind, *, mode="gather_decode", center="mean", rotation=False,
         frac=0.25, ef=True):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=frac, center=center,
                                  rotation=rotation),
        mode=mode, axes=("data",), wire_dtype="float32",
        min_compress_size=1024, error_feedback=ef)


# --------------------------------------------------------------------------- #
# Codec-derived state plumbing (the one residual initializer).
# --------------------------------------------------------------------------- #

def test_ef_state_shapes_follow_resolved_codec():
    shapes = {"a": (4096,), "b": (4096,), "tiny": (64,)}
    specs = {k: (None,) for k in shapes}
    cfg = _cfg("binary", center="min")
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": N}, cfg)
    shp = bucketing.ef_state_shapes(plan, cfg)
    want = {b.bid: (b.size,) for b in plan.buckets if b.kind == "compressed"}
    assert shp == want and want  # tiny rides exact: no state for it
    state = bucketing.init_ef_state(plan, cfg)
    assert set(state) == set(want)
    for bid, v in state.items():
        assert v.shape == want[bid] and v.dtype == jnp.float32
        assert not v.any()


def test_ef_state_empty_without_compressed_buckets():
    shapes = {"tiny": (64,)}
    specs = {"tiny": (None,)}
    cfg = _cfg("fixed_k")
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": N}, cfg)
    assert bucketing.ef_state_shapes(plan, cfg) == {}
    assert bucketing.init_ef_state(plan, cfg) == {}
    cfg_none = types.CompressionConfig(mode="none")
    plan = bucketing.build_plan({"a": (4096,)}, {"a": (None,)}, ("data",),
                                {"data": N}, cfg_none)
    assert bucketing.init_ef_state(plan, cfg_none) == {}


def test_ef_round_residual_identity_single_node():
    """On a one-node 'mesh' (axes=()) the EF estimate is this node's own
    twin message, so the residual identity e' = (x + e) − est is exact —
    the telescoping invariant the EF recursion rests on."""
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="fixed_k", fraction=0.25,
                                  center="mean"),
        mode="gather_decode", axes=(), wire_dtype="float32",
        min_compress_size=0, error_feedback=True)
    codec = wire.resolve(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2048,))
    err = jax.random.normal(jax.random.PRNGKey(1), (2048,)) * 0.1
    est, new_err = codec.mean_flat_stateful(x, err, jax.random.PRNGKey(2),
                                            cfg)
    np.testing.assert_allclose(np.asarray(x + err - new_err),
                               np.asarray(est), rtol=1e-5, atol=1e-6)


def test_deprecated_shim_is_the_stateful_codec_round():
    """compressed_mean_ef forwards to compressed_mean_stateful with
    error_feedback forced on — the old fixed-k-only body is gone."""
    from repro.core import error_feedback
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="binary", center="min"),
        mode="gather_decode", axes=(), wire_dtype="float32",
        min_compress_size=0)  # note: error_feedback=False — the shim forces it
    x = jax.random.normal(jax.random.PRNGKey(5), (512,))
    err = jnp.zeros((512,))
    est, new_err = error_feedback.compressed_mean_ef(
        x, err, jax.random.PRNGKey(6), cfg)
    cfg_ef = dataclasses.replace(cfg, error_feedback=True)
    codec = wire.resolve(cfg_ef)
    want_est, want_err = codec.mean_flat_stateful(x, err,
                                                  jax.random.PRNGKey(6),
                                                  cfg_ef)
    np.testing.assert_allclose(np.asarray(est), np.asarray(want_est),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(want_err),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("name", ["ef_fixed_k", "ef_bernoulli", "ef_binary",
                                  "ef_ternary", "ef_rotated_binary"])
def test_ef_round_estimate_is_mean_of_twin_messages(name):
    """Meshless star round: decode_gathered of twin packs == the average of
    the per-node twin reconstructions (the m̄_t the telescoping sums)."""
    cfg_p = cfg_registry.compression_preset(name, axes=("data",))
    cfg = types.CompressionConfig(
        encoder=cfg_p.encoder, mode=cfg_p.mode, axes=("data",),
        wire_dtype="float32", min_compress_size=0, error_feedback=True)
    codec = wire.resolve(cfg)
    key = jax.random.PRNGKey(3)
    xs = jax.random.normal(jax.random.PRNGKey(4), (N, 2048)) * 0.4
    got = _simulate_round(codec, cfg, xs, key)
    want = jnp.mean(jnp.stack(
        [codec.unpack(codec.pack(xs[i], key, i, cfg), i, key, cfg, 2048)
         for i in range(N)]), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ef_twin_extension_hook():
    """A codec outside wire/ef.py composes with EF by declaring its own
    contractive twin (ef_twin_pack / ef_residual_bound) — no edit to the
    EF dispatch needed; codecs without one fail loudly at wrap time."""

    class IdentityCodec(wire.WireCodec):
        name = "identity_psum"
        reduce = "psum"

        def pack(self, flat, key, rank, cfg):
            return flat

        def unpack(self, row, peer, key, cfg, d):
            return row

        def decode_reduced(self, w, key, cfg, d):
            return w

        def ef_twin_pack(self, flat, key, rank, cfg):
            return flat  # lossless ⇒ the twin is the message itself

        def ef_residual_bound(self, flat, key, cfg):
            return jnp.zeros(())

    cfg = _cfg("identity")
    efc = wire.EFCodec(IdentityCodec())
    x = jnp.arange(8.0)
    buf = efc.pack(x, jax.random.PRNGKey(0), 0, cfg)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(x))
    assert float(efc.residual_bound(x, jax.random.PRNGKey(0), cfg)) == 0.0

    class OpaqueCodec(IdentityCodec):
        name = "opaque"
        ef_twin_pack = None

    with pytest.raises(ValueError, match="no contractive twin"):
        wire.EFCodec(OpaqueCodec()).pack(x, jax.random.PRNGKey(0), 0, cfg)


def test_preset_combinations_resolve():
    for name in ("ternary_opt", "ef_fixed_k", "ef_bernoulli", "ef_binary",
                 "ef_ternary", "ef_rotated_binary"):
        cfg = cfg_registry.compression_preset(name, axes=("data",))
        assert wire.resolve(cfg).name == name


# --------------------------------------------------------------------------- #
# The 8-device end-to-end check (also a CI matrix job of its own).
# --------------------------------------------------------------------------- #

@pytest.mark.distributed
def test_ef_wire_check_8dev():
    script = (ROOT / "tests" / "distributed_checks" / "ef_wire_check.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, \
        f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL EF WIRE CHECKS PASSED" in res.stdout
