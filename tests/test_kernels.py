"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import prng
from repro.kernels.bernoulli_encode import bernoulli_encode as bern_kernel
from repro.kernels.bernoulli_encode import ops as bern_ops
from repro.kernels.bernoulli_encode import ref as bern_ref
from repro.kernels.binary_quant import binary_quant as bq_kernel
from repro.kernels.binary_quant import ops as bq_ops
from repro.kernels.binary_quant import ref as bq_ref
from repro.kernels.fixed_k_encode import ops as fk_ops
from repro.kernels.fixed_k_encode import ref as fk_ref
from repro.kernels.hadamard import hadamard as h_kernel
from repro.kernels.hadamard import ref as h_ref

KEY = jax.random.PRNGKey(0)


# --------------------------- hadamard ------------------------------------ #

@pytest.mark.parametrize("d", [4, 16, 64, 256, 1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_pallas_matches_ref(d, dtype):
    x = jax.random.normal(KEY, (3, d)).astype(dtype)
    lg = d.bit_length() - 1
    d1, d2 = 1 << (lg // 2), 1 << (lg - lg // 2)
    got = h_kernel.fwht_pallas(x, d1=d1, d2=d2, interpret=True)
    want = h_ref.fwht(x.astype(jnp.float32)).astype(dtype)
    tol = 1e-4 * d if dtype == jnp.float32 else 0.05 * d
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_fwht_ref_matches_matrix():
    d = 32
    x = jax.random.normal(KEY, (2, d))
    H = h_ref.hadamard_matrix(d)
    np.testing.assert_allclose(h_ref.fwht(x), x @ H.T, atol=1e-4)


def test_fwht_involution():
    """H·H = d·I  ⇒  fwht(fwht(x)) = d·x."""
    d = 128
    x = jax.random.normal(KEY, (d,))
    np.testing.assert_allclose(h_ref.fwht(h_ref.fwht(x)), d * x, atol=1e-3)


def test_rotation_roundtrip():
    from repro.core import rotation
    x = jax.random.normal(KEY, (5, 200))  # non-power-of-two: pads to 256
    z = rotation.rotate(jax.random.PRNGKey(7), x)
    back = rotation.unrotate(jax.random.PRNGKey(7), z, 200)
    np.testing.assert_allclose(back, x, atol=1e-4)


def test_rotation_preserves_norm():
    from repro.core import rotation
    x = jax.random.normal(KEY, (256,))
    z = rotation.rotate(jax.random.PRNGKey(7), x)
    np.testing.assert_allclose(jnp.linalg.norm(z), jnp.linalg.norm(x), rtol=1e-5)


# --------------------------- bernoulli_encode ----------------------------- #

@pytest.mark.parametrize("rows", [512, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bernoulli_kernel_matches_ref(rows, dtype):
    x = jax.random.normal(KEY, (rows, 128)).astype(dtype)
    seed_u = jnp.uint32(0xDEADBEEF)
    scal = jnp.stack([jnp.float32(0.3), jnp.float32(0.1),
                      (seed_u >> jnp.uint32(16)).astype(jnp.float32),
                      (seed_u & jnp.uint32(0xFFFF)).astype(jnp.float32)]
                     ).reshape(1, 4)
    got = bern_kernel.bernoulli_encode_2d(x, scal, interpret=True)
    want = bern_ref.bernoulli_encode(x, 0.3, 0.1, 0xDEADBEEF)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.02 if dtype == jnp.bfloat16 else 1e-6)


def test_bernoulli_ops_arbitrary_shape():
    x = jax.random.normal(KEY, (3, 1000))
    got = bern_ops.bernoulli_encode(x, 0.5, 0.0, 123, force_pallas=True)
    want = bern_ref.bernoulli_encode(x.reshape(-1), 0.5, 0.0, 123)[:3000]
    np.testing.assert_allclose(got.reshape(-1), want, atol=1e-6)


def test_mask_statistics():
    """The in-kernel hash PRNG produces p-fraction masks, unbiased values."""
    n = 1 << 18
    x = jnp.ones((n,))
    for p in [0.1, 0.5]:
        y = bern_ref.bernoulli_encode(x, p, 0.0, 77)
        frac = float(jnp.mean((y != 0.0).astype(jnp.float32)))
        assert abs(frac - p) < 0.01
        assert abs(float(jnp.mean(y)) - 1.0) < 0.02  # unbiased


def test_hash_uniformity():
    u = prng.uniform_hash(jnp.uint32(9), jnp.arange(1 << 16, dtype=jnp.uint32))
    # mean ≈ 1/2, var ≈ 1/12, no mass outside [0, 1)
    assert abs(float(jnp.mean(u)) - 0.5) < 0.01
    assert abs(float(jnp.var(u)) - 1 / 12) < 0.01
    assert float(jnp.min(u)) >= 0.0 and float(jnp.max(u)) < 1.0


# --------------------------- binary_quant --------------------------------- #

@pytest.mark.parametrize("rows", [512])
def test_binary_kernel_matches_ref(rows):
    x = jax.random.normal(KEY, (rows, 128))
    vmin, vmax = jnp.min(x).astype(jnp.float32), jnp.max(x).astype(jnp.float32)
    seed_u = jnp.uint32(42)
    scal = jnp.stack([vmin, vmax,
                      (seed_u >> jnp.uint32(16)).astype(jnp.float32),
                      (seed_u & jnp.uint32(0xFFFF)).astype(jnp.float32)]
                     ).reshape(1, 4)
    got = bq_kernel.binary_encode_2d(x, scal, interpret=True)
    want, _, _ = bq_ref.binary_encode(x, 42)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1), np.asarray(want))


def test_binary_roundtrip_values():
    x = jax.random.normal(KEY, (4, 512))
    packed, vmin, vmax = bq_ops.binary_encode(x, 7)
    y = bq_ops.binary_decode(packed, vmin, vmax, x.shape)
    vals = np.unique(np.asarray(y))
    assert all(np.isclose(v, float(vmin)) or np.isclose(v, float(vmax))
               for v in vals), vals


def test_binary_unbiased_via_kernel():
    """Signed error averaged over seeds & coordinates ≈ 0 (unbiased)."""
    x = jax.random.normal(KEY, (1 << 14,))
    recon = []
    for seed in range(64):
        packed, vmin, vmax = bq_ops.binary_encode(x, seed)
        recon.append(bq_ops.binary_decode(packed, vmin, vmax, x.shape))
    err = jnp.mean(jnp.stack(recon), axis=0) - x
    # per-coordinate std ~ Δ/2/√64 ≈ 0.45: the signed grand mean over
    # 2^14 coordinates has std ≈ 0.45/√2^14 ≈ 0.004.
    assert abs(float(jnp.mean(err))) < 0.02
    assert float(jnp.mean(jnp.abs(err))) < 0.6


# --------------------------- fixed_k_encode ------------------------------- #

@pytest.mark.parametrize("d_blocks,kb", [(8, 2), (32, 8), (64, 64)])
def test_fixed_k_kernel_matches_ref(d_blocks, kb):
    d = d_blocks * fk_ref.BLOCK
    x = jax.random.normal(KEY, (d,))
    ids = fk_ref.sample_blocks(jax.random.PRNGKey(1), d_blocks, kb)
    got = fk_ops.fixed_k_encode(x, ids, 0.25, force_pallas=True)
    want = fk_ref.fixed_k_encode(x, ids, 0.25)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_fixed_k_roundtrip_unbiased():
    d = 16 * fk_ref.BLOCK
    x = jax.random.normal(KEY, (d,))
    mu = float(jnp.mean(x))
    recons = []
    for seed in range(200):
        ids = fk_ref.sample_blocks(jax.random.PRNGKey(seed), 16, 4)
        vals = fk_ops.fixed_k_encode(x, ids, mu)
        recons.append(fk_ops.fixed_k_decode(vals, ids, mu, (d,)))
    est = jnp.mean(jnp.stack(recons), axis=0)
    assert float(jnp.mean(jnp.abs(est - x))) < 0.25


def test_block_mse_matches_lemma34():
    """Block-structured support has exactly the Lemma 3.4 MSE (DESIGN §2)."""
    from repro.core import mse as mse_lib
    n, nb = 8, 16
    d = nb * fk_ref.BLOCK
    kb = 4
    k = kb * fk_ref.BLOCK
    xs = jax.random.normal(jax.random.PRNGKey(3), (n, d)) * 0.1
    mus = jnp.mean(xs, axis=-1)
    x_true = jnp.mean(xs, axis=0)

    def one(trial):
        ys = []
        for i in range(n):
            ids = fk_ref.sample_blocks(
                jax.random.fold_in(jax.random.PRNGKey(trial), i), nb, kb)
            vals = fk_ref.fixed_k_encode(xs[i], ids, mus[i])
            ys.append(fk_ref.fixed_k_decode(vals, ids, mus[i], d))
        err = jnp.mean(jnp.stack(ys), axis=0) - x_true
        return jnp.sum(err * err)

    errs = jnp.stack([jax.jit(one)(t) for t in range(300)])
    got = float(jnp.mean(errs))
    want = float(mse_lib.mse_fixed_k(xs, k, mus))
    se = float(jnp.std(errs)) / np.sqrt(300)
    assert abs(got - want) < max(5 * se, 0.05 * want), (got, want, se)
