"""Encoder protocol tests: unbiasedness (Lemmas 3.1/3.3/7.1) and structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoders, types

KEY = jax.random.PRNGKey(0)


def _mc_mean(encode_fn, trials=4000):
    def one(k):
        return encode_fn(k).y
    return jnp.mean(jax.lax.map(jax.jit(one), jax.random.split(KEY, trials)), axis=0)


@pytest.mark.parametrize("p", [0.1, 0.5, 1.0])
def test_bernoulli_unbiased(p):
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    mu = jnp.mean(x)
    est = _mc_mean(lambda k: encoders.encode_bernoulli(k, x, p, mu))
    np.testing.assert_allclose(est, x, atol=4 * np.sqrt((1 / p - 1)) * 0.05 + 0.02)


def test_bernoulli_p1_lossless():
    x = jax.random.normal(jax.random.PRNGKey(2), (128,))
    enc = encoders.encode_bernoulli(KEY, x, 1.0, jnp.mean(x))
    np.testing.assert_allclose(enc.y, x, rtol=1e-6)
    assert int(enc.nsent) == 128


@pytest.mark.parametrize("k", [1, 16, 64, 128])
def test_fixed_k_support_size(k):
    x = jax.random.normal(jax.random.PRNGKey(3), (128,))
    enc = encoders.encode_fixed_k(KEY, x, k, jnp.mean(x))
    assert int(enc.nsent) == k
    assert int(jnp.sum(enc.support)) == k


def test_fixed_k_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(4), (64,))
    mu = jnp.mean(x)
    est = _mc_mean(lambda k: encoders.encode_fixed_k(k, x, 16, mu), trials=6000)
    np.testing.assert_allclose(est, x, atol=0.15)


def test_fixed_k_support_uniform():
    """Every coordinate is included with probability k/d (Eq. 4 design)."""
    x = jnp.zeros((64,))
    def one(k):
        return encoders.encode_fixed_k(k, x, 16, 0.0).support
    freq = jnp.mean(jax.lax.map(jax.jit(one), jax.random.split(KEY, 4000))
                    .astype(jnp.float32), axis=0)
    np.testing.assert_allclose(freq, 16 / 64, atol=0.03)


def test_binary_matches_eq12():
    """Example 4: values ∈ {min, max}; P(max) = (x − min)/Δ."""
    x = jax.random.normal(jax.random.PRNGKey(5), (32,))
    vmin, vmax = float(jnp.min(x)), float(jnp.max(x))

    def one(k):
        return encoders.encode_binary(k, x).y
    ys = jax.lax.map(jax.jit(one), jax.random.split(KEY, 3000))
    vals = np.unique(np.asarray(ys))
    assert all(np.isclose(v, vmin, atol=1e-5) or np.isclose(v, vmax, atol=1e-5)
               for v in vals), vals
    p_emp = jnp.mean((ys == vmax).astype(jnp.float32), axis=0)
    p_true = (x - vmin) / (vmax - vmin)
    np.testing.assert_allclose(p_emp, p_true, atol=0.04)


def test_binary_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(6), (64,))
    est = _mc_mean(lambda k: encoders.encode_binary(k, x), trials=8000)
    np.testing.assert_allclose(est, x, atol=0.12)


def test_ternary_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(7), (64,))
    est = _mc_mean(
        lambda k: encoders.encode_ternary(k, x, 0.3, 0.3, jnp.min(x), jnp.max(x)),
        trials=8000)
    np.testing.assert_allclose(est, x, atol=0.15)


def test_identity_exact():
    x = jax.random.normal(jax.random.PRNGKey(8), (64,))
    enc = encoders.encode_identity(x)
    np.testing.assert_array_equal(enc.y, x)


def test_batch_independent_nodes():
    """encode_batch folds per-node keys — node messages must differ."""
    xs = jnp.ones((4, 256))
    spec = types.EncoderSpec(kind="fixed_k", fraction=0.25)
    enc = encoders.encode_batch(KEY, xs, spec)
    supports = np.asarray(enc.support)
    assert not all((supports[0] == supports[i]).all() for i in range(1, 4))


def test_spec_dispatch_all_kinds():
    xs = jax.random.normal(jax.random.PRNGKey(9), (8, 128))
    for kind in types.ENCODERS:
        spec = types.EncoderSpec(kind=kind, fraction=0.25)
        enc = encoders.encode_batch(KEY, xs, spec)
        assert enc.y.shape == xs.shape
        assert bool(jnp.all(jnp.isfinite(enc.y)))
