"""Dry-run launcher pipeline on a small mesh (subprocess, 4 fake devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" / "dryrun_small_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "DRYRUN SMALL CHECK PASSED" in res.stdout
