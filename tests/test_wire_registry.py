"""The WireCodec registry (repro.core.wire): dispatch, accounting, wire
formats.

The accounting test is the one parametrized check that replaced the
per-protocol copies in test_comm_cost.py: for EVERY registered codec,

    comm_cost_bits == wire_bits + seed_bits        (analytic identity)
    wire_bits      == HLO-measured gathered bits   (gather codecs, one
                                                    8-device subprocess)
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import simulate_wire_round as _simulate_round
from repro.configs import registry as cfg_registry
from repro.core import collectives, comm_cost, encoders, rotation, types, wire

ROOT = pathlib.Path(__file__).resolve().parent.parent
N, D = 8, 5000  # D deliberately NOT a power of two nor a multiple of 32


def _cfg(kind, *, rotation=False, frac=0.125, center="min", wire="float32",
         mode="gather_decode", probs="uniform", ef=False):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=frac, center=center,
                                  rotation=rotation, probs=probs),
        mode=mode, axes=("data",), wire_dtype=wire, min_compress_size=0,
        error_feedback=ef)


# one config per registered codec, used by both accounting tests below.
CODEC_CFGS = {
    "fixed_k": _cfg("fixed_k"),
    "fixed_k_shared": _cfg("fixed_k", mode="shared_support"),
    "bernoulli": _cfg("bernoulli", center="mean"),
    "binary": _cfg("binary"),
    "ternary": _cfg("ternary"),
    "ternary_opt": _cfg("ternary", probs="optimal"),
    "dense": _cfg("bernoulli", center="mean", probs="optimal"),
    "rotated_binary": _cfg("binary", rotation=True),
    "rotated_fixed_k": _cfg("fixed_k", rotation=True),
    "ef_fixed_k": _cfg("fixed_k", ef=True),
    "ef_fixed_k_shared": _cfg("fixed_k", mode="shared_support", ef=True),
    "ef_bernoulli": _cfg("bernoulli", center="mean", ef=True),
    "ef_binary": _cfg("binary", ef=True),
    "ef_ternary": _cfg("ternary", ef=True),
    "ef_rotated_binary": _cfg("binary", rotation=True, ef=True),
}


# --------------------------------------------------------------------------- #
# Dispatch: resolve() is THE rule.
# --------------------------------------------------------------------------- #

def test_registry_contains_all_production_codecs():
    assert set(wire.names()) >= set(CODEC_CFGS)


def test_resolve_matches_expected_codec():
    for name, cfg in CODEC_CFGS.items():
        assert wire.resolve(cfg).name == name, (name, wire.resolve(cfg).name)


def test_resolve_rejects_uncompressed_modes():
    with pytest.raises(ValueError):
        wire.resolve(types.CompressionConfig(mode="none"))


def test_rotation_wraps_any_codec_without_nesting():
    rot = wire.resolve(_cfg("ternary", rotation=True))
    assert rot.name == "rotated_ternary" and rot.inner.name == "ternary"
    with pytest.raises(ValueError):
        wire.RotatedCodec(rot)


def test_ef_wraps_any_codec_without_nesting():
    # EF composes outermost over any base or rotated codec, on the fly for
    # combinations without a registered instance, and never over itself.
    eft = wire.resolve(_cfg("ternary", ef=True))
    assert eft.name == "ef_ternary" and eft.inner.name == "ternary"
    efo = wire.resolve(_cfg("ternary", probs="optimal", ef=True))
    assert efo.name == "ef_ternary_opt" and efo.inner.name == "ternary_opt"
    efr = wire.resolve(_cfg("fixed_k", rotation=True, ef=True))
    assert (efr.name == "ef_rotated_fixed_k"
            and efr.inner.name == "rotated_fixed_k")
    with pytest.raises(ValueError):
        wire.EFCodec(eft)
    with pytest.raises(ValueError):
        wire.EFCodec(wire.RotatedCodec(wire.get("ef_binary")))


@pytest.mark.parametrize("kind,rot", [("binary", False), ("ternary", False),
                                      ("binary", True), ("bernoulli", False)])
@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
def test_ef_twin_recon_matches_unpack(kind, rot, wire_dtype):
    """The fused EF residual reconstruction (derived from the twin's own
    intermediates, no plane unpack — DESIGN.md §13) is bit-for-bit the
    inner codec's unpack of the shipped bytes, so residual semantics and
    the golden wire bytes are unchanged."""
    from repro.core.wire import ef as ef_mod
    center = "mean" if kind == "bernoulli" else "min"
    cfg = _cfg(kind, rotation=rot, center=center, wire=wire_dtype)
    codec = wire.resolve(cfg)
    d = 1000
    key = jax.random.PRNGKey(11)
    v = jax.random.normal(jax.random.PRNGKey(12), (d,)) * 3.0
    buf, recon = ef_mod._twin_pack_recon(codec, v, key, 0, cfg)
    want_buf = ef_mod._twin_pack(codec, v, key, 0, cfg)
    assert np.array_equal(np.asarray(buf), np.asarray(want_buf))
    want = codec.unpack(buf, 0, key, cfg, d)
    assert np.array_equal(np.asarray(recon), np.asarray(want))
    assert ef_mod.twin_recon_fused(codec) == (kind in ("binary", "ternary"))


def test_gather_wire_kind_delegates_to_registry():
    # the historical dispatch-rule API survives, now registry-backed.
    assert collectives.gather_wire_kind(_cfg("binary")) == "binary"
    # §6 ternary optimal probs are wire-modelled now (the branch choices
    # ride the 2-bit plane): no more dense fallback.
    assert collectives.gather_wire_kind(
        _cfg("ternary", probs="optimal")) == "ternary_opt"
    assert collectives.gather_wire_kind(
        _cfg("bernoulli", center="optimal")) == "dense"
    # rotation composes on top; the base kind is unchanged.
    assert collectives.gather_wire_kind(_cfg("binary", rotation=True)) == "binary"


def test_rotated_presets_resolve_to_registered_instances():
    for name in ("rotated_binary", "rotated_fixed_k"):
        cfg = cfg_registry.compression_preset(name, axes=("data",))
        assert wire.resolve(cfg) is wire.get(name)


# --------------------------------------------------------------------------- #
# Accounting identity: analytic cost == wire payload + implicit seed bits.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", sorted(CODEC_CFGS))
@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("d", [31, 4096, 5000])
def test_wire_bits_plus_seed_is_comm_cost(name, wire_dtype, d):
    codec = wire.get(name)
    cfg = dataclasses.replace(CODEC_CFGS[name], wire_dtype=wire_dtype)
    got = codec.comm_cost_bits(N, d, cfg)
    want = codec.wire_bits(N, d, cfg) + codec.seed_bits(N, cfg)
    assert got == want, (name, d, got, want)
    # and cost_config routes the same number through the registry.
    assert comm_cost.cost_config(cfg, n=N, d=d) == got


def test_hierarchical_cost_is_billed_at_effective_nodes():
    """The flat-world-size accounting bugfix: a hierarchical config charges
    the codec at the cross-host group size — one helper
    (wire.effective_nodes) feeds cost_config and bucket_wire_bits, so the
    identity holds at n_eff, not at the flat n."""
    msz = {"pod": 4, "data": 2}
    for kind in ("fixed_k", "bernoulli"):
        cfg = dataclasses.replace(
            CODEC_CFGS[kind], axes=("pod",), inner_axes=("data",),
            scatter_decode=True)
        codec = wire.resolve(cfg)
        assert wire.effective_nodes(cfg, N, msz) == 4
        got = comm_cost.cost_config(cfg, n=N, d=D, mesh_sizes=msz)
        assert got == codec.wire_bits(4, D, cfg) + codec.seed_bits(4, cfg)
        # exactly half the flat bill at the same world size (both linear
        # in n), and the scatter decode never changes what's on the wire.
        flat = dataclasses.replace(cfg, inner_axes=(), scatter_decode=False)
        assert 2 * got == comm_cost.cost_config(flat, n=N, d=D)


def test_flat_scatter_cost_adds_scatter_bits():
    """§12/§13 accounting identity: a flat-scatter config bills its wire
    payload + seeds + the extra main-axis collectives (scatter_bits);
    hierarchical scatter bills 0 scatter (free inner link, §11)."""
    for kind in ("bernoulli", "fixed_k", "binary", "ternary"):
        cfg = dataclasses.replace(CODEC_CFGS[kind], scatter_decode=True)
        codec = wire.resolve(cfg)
        sb = codec.scatter_bits(N, D, cfg)
        assert sb > 0
        align = wire.scatter_word_align(cfg)
        ds = wire.scatter_shard_len(D, N, align)
        if kind == "bernoulli":
            # i32 rank-offset counts + the decoded f32 shard gather
            assert align == 1
            assert sb == N * N * 32 + N * ds * 32
        if kind == "binary":
            # word-aligned shard gather only — the plane travels, so no
            # bookkeeping exchange (§13)
            assert align == 32 and ds % 32 == 0
            assert sb == N * ds * 32
        if kind == "ternary":
            # i32 pass-through counts + the word-aligned shard gather
            assert align == 16 and ds % 16 == 0
            assert sb == N * N * 32 + N * ds * 32
        got = comm_cost.cost_config(cfg, n=N, d=D)
        assert got == (codec.wire_bits(N, D, cfg) + codec.seed_bits(N, cfg)
                       + sb)
        # scatter costs MORE than the plain flat config — never hidden.
        flat = dataclasses.replace(cfg, scatter_decode=False)
        assert got == comm_cost.cost_config(flat, n=N, d=D) + sb
        # hierarchical scatter: same codec, 0 scatter bill.
        hier = dataclasses.replace(cfg, axes=("pod",), inner_axes=("data",))
        assert wire.resolve(hier).scatter_bits(4, D, hier) == 0.0


def test_flat_scatter_preset_identity_holds():
    """The shipped flat-scatter presets satisfy the full §12/§13 identity
    and EF delegates scatter_bits verbatim (residuals are local)."""
    for name in ("bernoulli_seed_1bit", "ef_bernoulli", "binary_packed",
                 "ternary_packed", "ef_binary", "ef_ternary",
                 "ef_rotated_binary"):
        cfg = cfg_registry.compression_preset(name, axes=("data",))
        assert cfg.scatter_decode and not cfg.inner_axes
        codec = wire.resolve(cfg)
        assert codec.scatter_supported
        assert comm_cost.cost_config(cfg, n=N, d=D) == (
            codec.wire_bits(N, D, cfg) + codec.seed_bits(N, cfg)
            + codec.scatter_bits(N, D, cfg))
    for plain_name, ef_name in [("bernoulli_seed_1bit", "ef_bernoulli"),
                                ("binary_packed", "ef_binary"),
                                ("ternary_packed", "ef_ternary")]:
        plain = cfg_registry.compression_preset(plain_name, axes=("data",))
        ef = cfg_registry.compression_preset(ef_name, axes=("data",))
        assert wire.resolve(ef).scatter_bits(N, D, ef) == \
            wire.resolve(plain).scatter_bits(N, D, plain)


def test_rotated_scatter_bits_are_inner_at_padded_dim():
    # §13: rotated decodes scatter in rotated space, so the shard gather
    # is the inner codec's at the padded length.
    cfg = cfg_registry.compression_preset("ef_rotated_binary",
                                          axes=("data",))
    codec = wire.resolve(cfg)
    dp = rotation.padded_dim(D)
    ds = wire.scatter_shard_len(dp, N, wire.scatter_word_align(cfg))
    assert codec.scatter_bits(N, D, cfg) == N * ds * 32


def test_hier_presets_resolve_and_flatten():
    for name in ("hier_fixed_k", "hier_bernoulli"):
        cfg = cfg_registry.compression_preset(name)
        assert cfg.inner_axes == ("data",) and cfg.scatter_decode
        assert wire.resolve(cfg).scatter_supported
        # re-pointing onto the inner axis flattens the hierarchy but KEEPS
        # the scatter decode — it re-targets the flat-mesh form (§12), so
        # the flattened preset bills its shard collectives via
        # scatter_bits instead of falling back to the O(n·d) flat unpack.
        flat = cfg_registry.compression_preset(name, axes=("data",))
        assert flat.inner_axes == () and flat.scatter_decode
        codec = wire.resolve(flat)
        assert codec.scatter_bits(N, D, flat) > 0


def test_rotated_wire_bits_are_inner_at_padded_dim():
    for name in ("rotated_binary", "rotated_fixed_k"):
        codec = wire.get(name)
        cfg = CODEC_CFGS[name]
        for d in (31, 4096, 5000):
            dp = rotation.padded_dim(d)
            assert codec.wire_bits(N, d, cfg) == \
                codec.inner.wire_bits(N, dp, cfg)
            # power of two ⇒ payload identical to the un-rotated codec.
            if d == dp:
                plain = dataclasses.replace(
                    cfg, encoder=dataclasses.replace(cfg.encoder,
                                                     rotation=False))
                assert codec.wire_bits(N, d, cfg) == \
                    wire.resolve(plain).wire_bits(N, d, plain)


def test_ef_accounting_delegates_to_inner_exactly():
    """Residuals are wire-free: every EF codec's slots / payload / seed /
    analytic cost equal its inner codec's, at every probed geometry."""
    for name, cfg in CODEC_CFGS.items():
        if not name.startswith("ef_"):
            continue
        codec = wire.get(name)
        plain = dataclasses.replace(cfg, error_feedback=False)
        inner = wire.resolve(plain)
        assert codec.inner is inner, (name, codec.inner, inner)
        for d in (31, 4096, 5000):
            assert codec.wire_slots(d, cfg) == inner.wire_slots(d, plain)
            assert codec.wire_bits(N, d, cfg) == inner.wire_bits(N, d, plain)
            assert codec.comm_cost_bits(N, d, cfg) == \
                inner.comm_cost_bits(N, d, plain)
        assert codec.seed_bits(N, cfg) == inner.seed_bits(N, plain)


def test_ternary_opt_wire_bits_equal_ternary():
    """The §6-optimal split changes branch probabilities only — the plane,
    capacity and cost are the plain ternary codec's."""
    opt, plain = wire.get("ternary_opt"), wire.get("ternary")
    cfg_o, cfg_p = CODEC_CFGS["ternary_opt"], CODEC_CFGS["ternary"]
    for d in (31, 4096, 5000):
        assert opt.wire_slots(d, cfg_o) == plain.wire_slots(d, cfg_p)
        assert opt.wire_bits(N, d, cfg_o) == plain.wire_bits(N, d, cfg_p)
        assert opt.comm_cost_bits(N, d, cfg_o) == \
            plain.comm_cost_bits(N, d, cfg_p)


# --------------------------------------------------------------------------- #
# HLO: gathered bits == wire_bits, one subprocess for every gather codec.
# --------------------------------------------------------------------------- #

GATHER_CODECS = ["fixed_k", "bernoulli", "binary", "ternary", "ternary_opt",
                 "rotated_binary", "rotated_fixed_k",
                 "ef_fixed_k", "ef_bernoulli", "ef_binary", "ef_ternary",
                 "ef_rotated_binary"]

_INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, functools, json, re
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives, types

N, D = 8, 5000
mesh = jax.make_mesh((N,), ("data",))
CFGS = json.loads(os.environ["WIRE_CFGS"])
out = {}
for name, kw in CFGS.items():
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(**kw["encoder"]), mode="gather_decode",
        axes=("data",), wire_dtype=kw["wire_dtype"], min_compress_size=0,
        error_feedback=kw["error_feedback"])
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile().as_text()
    bits_of = {"f32": 32, "u32": 32, "bf16": 16}
    ms = re.findall(r"= (f32|u32|bf16)\[(\d+),(\d+)\]\{[^}]*\} all-gather",
                    txt)
    gathered = [int(n) * int(s) * bits_of[dt] for dt, n, s in ms]
    out[name] = {"launches": len(gathered), "bits": sum(gathered)}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def hlo_gathered_bits():
    cfgs = {}
    for name in GATHER_CODECS:
        cfg = CODEC_CFGS[name]
        cfgs[name] = {"encoder": dataclasses.asdict(cfg.encoder),
                      "wire_dtype": cfg.wire_dtype,
                      "error_feedback": cfg.error_feedback}
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["WIRE_CFGS"] = json.dumps(cfgs)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _INNER], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("name", GATHER_CODECS)
def test_hlo_gathered_bits_match_wire_bits(name, hlo_gathered_bits):
    got = hlo_gathered_bits[name]
    codec = wire.get(name)
    cfg = CODEC_CFGS[name]
    assert got["launches"] == 1, got
    assert got["bits"] == codec.wire_bits(N, D, cfg), \
        (name, got, codec.wire_bits(N, D, cfg))


# --------------------------------------------------------------------------- #
# Wire formats are meshless-testable: pack rows → decode_gathered.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", ["fixed_k", "bernoulli", "binary", "ternary",
                                  "ternary_opt"])
def test_decode_gathered_equals_dense_encoders(name):
    """At f32 wire the codec wire path reproduces the dense per-node
    encoders exactly: decode_gathered == mean_i encode(fold_in(key, i))."""
    cfg = CODEC_CFGS[name]
    key = jax.random.PRNGKey(5)
    xs = jax.random.normal(jax.random.PRNGKey(6), (N, 999)) * 0.4
    got = _simulate_round(wire.get(name), cfg, xs, key)

    def dense_y(i):
        kenc = jax.random.fold_in(key, i)
        if name == "fixed_k":
            codec = wire.get(name)
            return codec.unpack(codec.pack(xs[i], key, i, cfg), i, key, cfg,
                                xs.shape[1])
        if name == "bernoulli":
            return encoders.encode_bernoulli(
                kenc, xs[i], cfg.encoder.fraction, jnp.mean(xs[i])).y
        if name == "binary":
            return encoders.encode_binary(kenc, xs[i]).y
        return encoders.encode(kenc, xs[i], cfg.encoder).y

    want = jnp.mean(jnp.stack([dense_y(i) for i in range(N)]), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# EF residual contract: one step's residual ≤ the inner codec's worst-case
# per-step error (hypothesis property; the contraction EF stability needs).
# --------------------------------------------------------------------------- #

EF_CODECS = [n for n in CODEC_CFGS if n.startswith("ef_")]


@pytest.mark.parametrize("name", EF_CODECS)
def test_ef_one_step_residual_bounded(name):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    codec = wire.get(name)
    cfg = CODEC_CFGS[name]

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(33, 1500),
           scale=st.floats(1e-3, 1e3), spike=st.floats(0.0, 50.0))
    def prop(seed, d, scale, spike):
        key = jax.random.PRNGKey(seed)
        v = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * scale
        v = v.at[0].add(spike * scale)  # anisotropy stresses the quantizers
        buf = codec.pack(v, key, 0, cfg)
        recon = codec.unpack(buf, 0, key, cfg, d)
        res = float(jnp.linalg.norm(v - recon))
        bound = float(codec.residual_bound(v, key, cfg))
        assert res <= bound * (1 + 1e-5) + 1e-5 * scale, \
            (name, d, res, bound)

    prop()
