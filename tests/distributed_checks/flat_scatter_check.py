"""Multi-device (8 fake CPU devices) validation of the FLAT-mesh
reduce-scatter decode (docs/DESIGN.md §12).  Run by
tests/test_decode_scatter.py in a subprocess:

    python flat_scatter_check.py

Checks:
  * for every coordinate-partitionable flat-scatter config (bernoulli —
    the shipped `bernoulli_seed_1bit` preset — fixed_k, and the §13
    word-aligned bit-plane pair binary/ternary), the scatter-decode mean
    is BIT-exact vs the no-scatter flat reference across n ∈ {2, 4, 8}:
    each node decodes only its shard (⌈d/n⌉, word-aligned for the packed
    planes) of all n peer rows and one all_gather of decoded shards
    reassembles the mean;
  * per lowered HLO at n = 8: the scatter round launches exactly the
    expected extra all-gathers on top of the wire-row gather (bernoulli /
    ternary: i32 counts + decoded f32 shard; fixed_k / binary: decoded
    shard only — their coordinate windows are analytic), and the total
    gathered payload bits == codec.wire_bits + codec.scatter_bits ==
    cost_config − seed_bits — the honest billing of the extra intra-mesh
    traffic;
  * bucketed sync (sync_grads_bucketed) with a flat-scatter config
    launches exactly 3 gathers per compressed bucket and the summed HLO
    gather bits equal Σ bucket_wire_bits(plan, cfg, n) — per-bucket
    accounting includes the scatter collectives.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import registry as cfg_registry  # noqa: E402
from repro.core import collectives, comm_cost, types, wire  # noqa: E402
from repro.train import bucketing  # noqa: E402

D = 5000                # NOT a multiple of 8: the tail shard is short
SWEEP = (2, 4, 8)


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def scatter_cfg(kind):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=1.0 / 16,
                                  center="mean"),
        mode="gather_decode", axes=("data",), scatter_decode=True,
        wire_dtype="float32", min_compress_size=0)


def plane_cfg(kind):
    enc = (types.EncoderSpec(kind="binary", center="min")
           if kind == "binary" else
           types.EncoderSpec(kind="ternary", fraction=1.0 / 16,
                             center="min"))
    return types.CompressionConfig(
        encoder=enc, mode="gather_decode", axes=("data",),
        scatter_decode=True, wire_dtype="float32", min_compress_size=0)


# extra all-gathers the scatter round adds on top of the wire-row gather:
# bernoulli ships the i32 rank-offset counts + the decoded shard, ternary
# its i32 pass-through counts + the decoded shard; fixed_k's dump-row
# window and binary's word window are analytic, so only the decoded shard
# travels.
PRESETS = {
    "bernoulli": (scatter_cfg("bernoulli"), 2),
    "fixed_k": (scatter_cfg("fixed_k"), 1),
    "binary": (plane_cfg("binary"), 1),
    "ternary": (plane_cfg("ternary"), 2),
}


def mesh_for(n):
    return Mesh(np.array(jax.devices()[:n]), ("data",))


def run_mean(cfg, n, xs, key):
    @functools.partial(compat.shard_map, mesh=mesh_for(n),
                       in_specs=(P("data"), P()), out_specs=P(),
                       check_vma=False)
    def f(x, k):
        return collectives.compressed_mean(x.reshape(D), k, cfg)
    return jax.jit(f)


def gathers(txt):
    """[(dtype, bits)] of every all-gather in the lowered HLO."""
    nbits = {"f32": 32, "u32": 32, "s32": 32, "bf16": 16}
    out = []
    for dt, dims in re.findall(
            r"= (f32|u32|s32|bf16)\[([\d,]+)\]\S* all-gather"
            r"(?:-start)?\(", txt):
        b = nbits[dt]
        for v in dims.split(","):
            b *= int(v)
        out.append((dt, b))
    return out


# ---- scatter == no-scatter flat reference, bit for bit, across n ------------
for name, (cfg, _) in PRESETS.items():
    flat = dataclasses.replace(cfg, scatter_decode=False)
    for n in SWEEP:
        xs = jax.random.normal(jax.random.PRNGKey(n), (n, D)) * 0.3
        key = jax.random.PRNGKey(17)
        y_sc = np.asarray(run_mean(cfg, n, xs, key)(xs, key))
        y_fl = np.asarray(run_mean(flat, n, xs, key)(xs, key))
        check(f"{name}.scatter_bitexact[n={n}]",
              np.array_equal(y_sc, y_fl),
              f"max|diff|={np.max(np.abs(y_sc - y_fl)):.2e}")

# the shipped presets engage the flat scatter path out of the box
for pname in ("bernoulli_seed_1bit", "binary_packed", "ternary_packed",
              "ef_binary", "ef_ternary", "ef_rotated_binary"):
    preset = dataclasses.replace(
        cfg_registry.compression_preset(pname, axes=("data",)),
        wire_dtype="float32", min_compress_size=0)
    check(f"preset.{pname}_is_flat_scatter",
          preset.scatter_decode and not preset.inner_axes, f"{preset.mode}")

# ---- HLO: 3 gathers, payload == wire_bits + scatter_bits --------------------
N = 8
for name, (cfg, extra) in PRESETS.items():
    codec = wire.resolve(cfg)
    txt = run_mean(cfg, N, None, None).lower(
        jax.ShapeDtypeStruct((N, D), np.float32),
        jax.ShapeDtypeStruct((2,), np.uint32)).compile().as_text()
    ag = gathers(txt)
    flat_txt = run_mean(dataclasses.replace(cfg, scatter_decode=False),
                        N, None, None).lower(
        jax.ShapeDtypeStruct((N, D), np.float32),
        jax.ShapeDtypeStruct((2,), np.uint32)).compile().as_text()
    n_flat = len(gathers(flat_txt))
    check(f"{name}.extra_gathers", len(ag) == n_flat + extra,
          f"scatter round: {len(ag)} gathers (flat: {n_flat}, "
          f"want +{extra}); {ag}")
    want = codec.wire_bits(N, D, cfg) + codec.scatter_bits(N, D, cfg)
    got = sum(b for _, b in ag)
    check(f"{name}.payload_bits", got == want,
          f"hlo={got:.0f} accounting={want:.0f}")
    # cost_config bills exactly the HLO payload plus the out-of-band seeds
    cost = comm_cost.cost_config(cfg, n=N, d=D)
    check(f"{name}.cost_config", cost == want + codec.seed_bits(N, cfg),
          f"cost={cost:.0f} payload+seeds="
          f"{want + codec.seed_bits(N, cfg):.0f}")

# ---- bucketed sync: 3 gathers + honest bits per compressed bucket -----------
BIG, SMALL = 4096, 64
SHAPES = {f"big_{i}": (BIG,) for i in range(4)}
SHAPES.update({f"small_{i}": (SMALL,) for i in range(6)})
SPECS = {nm: (None,) for nm in SHAPES}
BCFG = dataclasses.replace(
    scatter_cfg("bernoulli"), min_compress_size=1024,
    bucket=types.BucketSpec(capacity=2 * BIG))
plan = bucketing.build_plan(SHAPES, SPECS, ("data",), {"data": N}, BCFG)
n_cmp = sum(1 for b in plan.buckets if b.kind == "compressed")
check("bucketed.plan", n_cmp == 2, f"compressed buckets={n_cmp} (want 2)")

key0 = jax.random.PRNGKey(1)
GXS = {nm: jax.random.normal(jax.random.fold_in(key0, h), (N,) + SHAPES[nm])
       for h, nm in enumerate(sorted(SHAPES))}
txt = jax.jit(
    functools.partial(compat.shard_map, mesh=mesh_for(N),
                      in_specs=({nm: P("data", None) for nm in SHAPES}, P()),
                      out_specs={nm: P() for nm in SHAPES},
                      check_vma=False, check_rep=False)(
        lambda xs, key: bucketing.sync_grads_bucketed(
            {nm: xs[nm].reshape(SHAPES[nm]) for nm in xs},
            plan, BCFG, key)[0])
).lower(GXS, jax.random.PRNGKey(0)).compile().as_text()
ag = gathers(txt)
check("bucketed.three_gathers_per_bucket", len(ag) == 3 * n_cmp,
      f"gathers={len(ag)} (want {3 * n_cmp})")
want_bits = bucketing.bucket_wire_bits(plan, BCFG, N)
check("bucketed.wire_bits_match_hlo",
      sum(b for _, b in ag) == sum(want_bits.values()),
      f"hlo={sum(b for _, b in ag):.0f} "
      f"accounting={sum(want_bits.values()):.0f}")

# bucketed scatter sync stays bit-exact vs the no-scatter bucketed sync
FCFG = dataclasses.replace(BCFG, scatter_decode=False)
fplan = bucketing.build_plan(SHAPES, SPECS, ("data",), {"data": N}, FCFG)


def sync(plan_, cfg_):
    @functools.partial(compat.shard_map, mesh=mesh_for(N),
                       in_specs=({nm: P("data", None) for nm in SHAPES},
                                 P()),
                       out_specs={nm: P() for nm in SHAPES},
                       check_vma=False, check_rep=False)
    def f(xs, key):
        return bucketing.sync_grads_bucketed(
            {nm: xs[nm].reshape(SHAPES[nm]) for nm in xs},
            plan_, cfg_, key)[0]
    return jax.jit(f)(GXS, jax.random.PRNGKey(0))


got = sync(plan, BCFG)
ref = sync(fplan, FCFG)
for nm in sorted(SHAPES):
    check(f"bucketed.bitexact[{nm}]",
          np.array_equal(np.asarray(got[nm]), np.asarray(ref[nm])), "")

print("ALL FLAT SCATTER CHECKS PASSED")
