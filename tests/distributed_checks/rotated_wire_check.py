"""Multi-device (8 fake CPU devices) validation of the §7.2 rotated wire
codecs (repro.core.wire.rotated) — the rotated_binary / rotated_fixed_k
presets end-to-end.  Run by tests/test_rotation_wire.py in a subprocess:

    python rotated_wire_check.py

Checks:
  * payload equality: the lowered HLO of the rotated presets gathers
    buffers of EXACTLY the un-rotated codec's shape (seed-only overhead —
    the rotation seed regenerates from the shared per-step key, the §4.4
    trick applied to Q), and exactly one all-gather launch either way;
  * analytic accounting: codec.wire_bits == bucket-style payload ==
    un-rotated wire_bits at the power-of-two bucket size, and
    comm_cost.cost_config == payload + seed bits;
  * Monte-Carlo wire-path MSE over the mesh == the §7.2 closed forms
    (the base protocol's exact form evaluated at QX, averaged over the
    same rotation seeds the wire draws: mse.mse_rotated_*);
  * unbiasedness of both rotated estimators.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import registry as cfg_registry  # noqa: E402
from repro.core import collectives, comm_cost, mse, rotation, types, wire  # noqa: E402

N = 8
D = 8192                # power of two: payload must equal the un-rotated codec
FRAC = 0.25             # fixed-k: kb = round(0.25 · 8 blocks) = 2 → k = 2048
TRIALS = 200

mesh = jax.make_mesh((N,), ("data",))

# anisotropic inputs: a few spiky coordinates — the regime §7.2 targets.
XS = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.3
XS = XS.at[:, :4].add(jnp.array([6.0, -5.0, 4.0, -3.0]))
TRUE = np.asarray(jnp.mean(XS, axis=0))


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def preset(name):
    cfg = cfg_registry.compression_preset(name, axes=("data",))
    enc = dataclasses.replace(cfg.encoder, fraction=FRAC)
    return dataclasses.replace(cfg, encoder=enc, wire_dtype="float32",
                               min_compress_size=0)


def lower_text(cfg):
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile().as_text()


def gathered_shapes(txt):
    return sorted(m.group(1) for m in
                  re.finditer(r"= (\S+\[[\d,]+\])\{[^}]*\} all-gather", txt))


K0 = jax.random.PRNGKey(13)
for name in ("rotated_binary", "rotated_fixed_k"):
    cfg_rot = preset(name)
    cfg_plain = dataclasses.replace(
        cfg_rot, encoder=dataclasses.replace(cfg_rot.encoder, rotation=False))
    codec_rot = wire.resolve(cfg_rot)
    codec_plain = wire.resolve(cfg_plain)
    check(f"{name}.resolves", codec_rot.name == name
          and codec_rot.reduce == codec_plain.reduce)

    # ---- HLO: gathered payload identical to the un-rotated codec ---------- #
    txt_rot = lower_text(cfg_rot)
    txt_plain = lower_text(cfg_plain)
    gr, gp = gathered_shapes(txt_rot), gathered_shapes(txt_plain)
    check(f"{name}.one_launch", len(gr) == 1 and len(gp) == 1,
          f"rot={gr} plain={gp}")
    check(f"{name}.payload_eq_unrotated_hlo", gr == gp,
          f"rot={gr} plain={gp}")

    # ---- analytic accounting --------------------------------------------- #
    wb_rot = codec_rot.wire_bits(N, D, cfg_rot)
    wb_plain = codec_plain.wire_bits(N, D, cfg_plain)
    check(f"{name}.payload_eq_unrotated_bits", wb_rot == wb_plain,
          f"rot={wb_rot:.0f} plain={wb_plain:.0f}")
    cost = comm_cost.cost_config(cfg_rot, n=N, d=D)
    seed = codec_rot.seed_bits(N, cfg_rot)
    check(f"{name}.seed_only_overhead",
          cost == wb_rot + seed
          and cost == comm_cost.cost_config(cfg_plain, n=N, d=D)
          + N * types.DEFAULT_RSEED_BITS,
          f"cost={cost:.0f} wire={wb_rot:.0f} seed={seed:.0f}")

    # ---- Monte-Carlo wire MSE == §7.2 closed form ------------------------- #
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=(P(), P()), check_vma=False)
    def trial_stats(xs, key, cfg=cfg_rot):
        x = xs.reshape(D)

        def one(t, carry):
            acc, sq = carry
            y = collectives.compressed_mean(x, jax.random.fold_in(key, t),
                                            cfg)
            err = y - jnp.asarray(TRUE)
            return acc + y, sq + jnp.sum(err * err)

        acc, sq = jax.lax.fori_loop(
            0, TRIALS, one, (jnp.zeros((D,)), jnp.zeros(())))
        return acc / TRIALS, sq / TRIALS

    mean_est, mse_emp = jax.jit(trial_stats)(XS, K0)
    mean_est, mse_emp = np.asarray(mean_est), float(mse_emp)

    # the same rotation seeds the wire derives: fold_in(key, t) → ROT tag.
    k_blocks = wire.get("fixed_k").wire_slots(D, cfg_rot) - 1  # kb·BLOCK

    def closed_form(t, name=name):
        krot = rotation.rotation_key(jax.random.fold_in(K0, t))
        if name == "rotated_binary":
            return mse.mse_rotated_binary(XS, krot)
        return mse.mse_rotated_fixed_k(XS, k_blocks, krot)

    want = float(jnp.mean(jax.lax.map(jax.jit(closed_form),
                                      jnp.arange(TRIALS))))
    check(f"{name}.mse_matches_72_closed_form",
          abs(mse_emp - want) < 0.15 * want,
          f"emp={mse_emp:.4f} want={want:.4f}")

    bias = float(np.max(np.abs(mean_est - TRUE)))
    check(f"{name}.unbiased", bias < 6 * np.sqrt(want / D),
          f"max|bias|={bias:.4f}")

# rotation must pay off where §7.2 says it does: rotated binary beats plain
# binary on these spiky inputs (compare the exact conditional forms).
want_plain = float(mse.mse_binary(XS))
want_rot = float(jnp.mean(jax.lax.map(
    jax.jit(lambda t: mse.mse_rotated_binary(
        XS, rotation.rotation_key(jax.random.fold_in(K0, t)))),
    jnp.arange(64))))
check("rotation_helps_binary", want_rot < want_plain,
      f"rotated={want_rot:.4f} plain={want_plain:.4f}")

print("ALL ROTATED WIRE CHECKS PASSED")
