"""Multi-device (8 fake CPU devices) validation of the compressed-mean
collectives.  Run by tests/test_collectives.py in a subprocess:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python collectives_check.py

Checks, per mode:
  * unbiasedness:  E[compressed_mean(x)] == exact pmean(x)
  * MSE == closed form (fixed-k / shared-support, f32 wire)
  * partial_mean over a live-mask
  * error-feedback residual identity
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives, error_feedback, mse, types  # noqa: E402
from repro.kernels.fixed_k_encode import ops as fk  # noqa: E402

N = 8
NB = 4                      # blocks per vector
D = NB * fk.BLOCK           # 4096, exactly block-aligned (no padding)
TRIALS = 400

mesh = jax.make_mesh((N,), ("data",))
XS = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.3
X_TRUE = np.asarray(jnp.mean(XS, axis=0))
MUS = jnp.mean(XS, axis=-1)


def run_mode(cfg: types.CompressionConfig):
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def trial_stats(xs, key):
        x = xs.reshape(D)

        def one(i, acc):
            est = collectives.compressed_mean(x, jax.random.fold_in(key, i), cfg)
            s, s2 = acc
            err = est - jnp.asarray(X_TRUE)
            return s + est, s2 + jnp.sum(err * err)

        s, s2 = jax.lax.fori_loop(
            0, TRIALS, one, (jnp.zeros(D), jnp.zeros(())))
        return s / TRIALS, s2 / TRIALS

    return jax.jit(trial_stats)(XS, jax.random.PRNGKey(7))


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


# ---- mode: none == exact ---------------------------------------------------
cfg = types.CompressionConfig(mode="none", min_compress_size=0)
mean_est, mse_emp = run_mode(cfg)
check("none.exact", np.allclose(np.asarray(mean_est), X_TRUE, atol=1e-5),
      f"mse={float(mse_emp):.3e}")

# ---- shared_support: unbiased + closed-form MSE ----------------------------
frac = 0.25
cfg = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=frac, center="mean"),
    mode="shared_support", axes=("data",), wire_dtype="float32",
    min_compress_size=0)
mean_est, mse_emp = run_mode(cfg)
k = int(frac * NB) * fk.BLOCK
want = float(mse.mse_fixed_k_shared(XS, k, MUS))
check("shared.unbiased",
      np.allclose(np.asarray(mean_est), X_TRUE, atol=6 * np.sqrt(want / D)),
      f"max|bias|={np.max(np.abs(np.asarray(mean_est) - X_TRUE)):.4f}")
check("shared.mse", abs(float(mse_emp) - want) < 0.12 * want,
      f"emp={float(mse_emp):.4f} want={want:.4f}")

# ---- gather_decode: unbiased + Lemma 3.4 MSE --------------------------------
cfg = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=frac, center="mean"),
    mode="gather_decode", axes=("data",), wire_dtype="float32",
    min_compress_size=0)
mean_est, mse_emp = run_mode(cfg)
want = float(mse.mse_fixed_k(XS, k, MUS))
check("gather.unbiased",
      np.allclose(np.asarray(mean_est), X_TRUE, atol=6 * np.sqrt(want / D)),
      f"max|bias|={np.max(np.abs(np.asarray(mean_est) - X_TRUE)):.4f}")
check("gather.mse", abs(float(mse_emp) - want) < 0.12 * want,
      f"emp={float(mse_emp):.4f} want={want:.4f}")

# independent supports must beat shared for these (incoherent) vectors? Not
# necessarily — but both must be the same order; sanity only.

# ---- dense_sim with bernoulli: unbiased + Lemma 3.2 MSE ---------------------
cfg = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="bernoulli", fraction=0.25, center="mean"),
    mode="dense_sim", axes=("data",), min_compress_size=0)
mean_est, mse_emp = run_mode(cfg)
want = float(mse.mse_bernoulli(XS, 0.25, MUS))
check("dense_sim.unbiased",
      np.allclose(np.asarray(mean_est), X_TRUE, atol=6 * np.sqrt(want / D)),
      f"max|bias|={np.max(np.abs(np.asarray(mean_est) - X_TRUE)):.4f}")
check("dense_sim.mse", abs(float(mse_emp) - want) < 0.12 * want,
      f"emp={float(mse_emp):.4f} want={want:.4f}")

# ---- gather_decode with bernoulli: the real §4.4 seed-trick wire path -------
# (capacity-padded value buffers; supports regenerate peer-side from seeds).
# Same estimate distribution as dense_sim (Lemma 3.2 MSE), but the wire only
# carries cap ≈ p·d + 6σ values + μ per node.
cfg = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="bernoulli", fraction=0.25, center="mean"),
    mode="gather_decode", axes=("data",), wire_dtype="float32",
    min_compress_size=0)
mean_est, mse_emp = run_mode(cfg)
want = float(mse.mse_bernoulli(XS, 0.25, MUS))
check("bern_wire.unbiased",
      np.allclose(np.asarray(mean_est), X_TRUE, atol=6 * np.sqrt(want / D)),
      f"max|bias|={np.max(np.abs(np.asarray(mean_est) - X_TRUE)):.4f}")
check("bern_wire.mse", abs(float(mse_emp) - want) < 0.12 * want,
      f"emp={float(mse_emp):.4f} want={want:.4f}")

# ---- partial_mean (straggler drop) ------------------------------------------
@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                   check_vma=False)
def partial(xs):
    x = xs.reshape(D)
    alive = (jax.lax.axis_index("data") < 6).astype(jnp.float32)
    return collectives.partial_mean(x * alive, alive, ("data",))

got = np.asarray(jax.jit(partial)(XS))
want_partial = np.asarray(jnp.mean(XS[:6], axis=0))
check("partial_mean", np.allclose(got, want_partial, atol=1e-5))

# ---- error feedback residual identity ---------------------------------------
cfg = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=0.25, center="mean"),
    mode="shared_support", axes=("data",), wire_dtype="float32",
    min_compress_size=0)

@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                   out_specs=(P(), P("data")), check_vma=False)
def ef_round(xs, key):
    x = xs.reshape(D)
    est, new_err = error_feedback.compressed_mean_ef(
        x, jnp.zeros(D), key, cfg)
    return est, new_err[None]

est, errs = jax.jit(ef_round)(XS, jax.random.PRNGKey(3))
# the EF residual must equal x − own-reconstruction; own recon lives on the
# sampled support, so the residual restricted to the support is −(μ-ish)…
# invariant we check: ||x − err|| == ||recon|| is finite and err != 0.
check("ef.shapes", errs.shape == (N, D) and bool(jnp.all(jnp.isfinite(errs))))
# EF over repeated rounds on a *constant* x must drive the aggregate error
# to zero (compression error is recycled):
@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                   out_specs=P(), check_vma=False)
def ef_many(xs, key):
    x = xs.reshape(D)

    def body(i, carry):
        err, acc = carry
        est, err = error_feedback.compressed_mean_ef(
            x, err, jax.random.fold_in(key, i), cfg)
        return err, acc + est

    _, acc = jax.lax.fori_loop(0, 64, body, (jnp.zeros(D), jnp.zeros(D)))
    return acc / 64

avg_est = np.asarray(jax.jit(ef_many)(XS, jax.random.PRNGKey(9)))
plain_err = float(mse_emp) ** 0.5 / np.sqrt(D)
ef_err = float(np.sqrt(np.mean((avg_est - X_TRUE) ** 2)))
check("ef.converges", ef_err < 0.05,
      f"ef_rmse={ef_err:.4f} (single-round rmse≈{plain_err:.4f})")

print("ALL COLLECTIVE CHECKS PASSED")
