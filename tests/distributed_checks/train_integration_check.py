"""Multi-device training integration (8 fake devices): loss decreases under
compressed gradient aggregation; checkpoint restart resumes identically;
elastic restart on a smaller mesh reproduces the state.

Run by tests/test_train_integration.py in a subprocess.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import ArchConfig, RunConfig, ShapeSpec  # noqa: E402
from repro.core import types as core_types  # noqa: E402
from repro.optim.optimizers import AdamWConfig  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402

CFG = ArchConfig(name="lm-tiny", family="dense", num_layers=2, d_model=128,
                 num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
                 vocab_size=512, tie_embeddings=True)
SHAPE = ShapeSpec("train", "train", seq_len=64, global_batch=16)
OPT = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=150)


def make_trainer(mesh_shape, compression, steps, ckpt_dir=None):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    run = RunConfig(microbatches=2, model_parallel=mesh_shape[1] > 1,
                    seq_shard=mesh_shape[1] > 1,
                    attn_chunk_q=64, attn_chunk_k=64, remat=True,
                    compression=compression)
    tcfg = TrainerConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=10,
                         log_every=5, seed=0)
    return Trainer(mesh, CFG, run, SHAPE, tcfg, OPT)


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(1)


# ---- 1. compressed training decreases loss (DP over 4, TP over 2) ---------
comp = core_types.CompressionConfig(
    encoder=core_types.EncoderSpec(kind="fixed_k", fraction=0.25),
    mode="shared_support", axes=("data",), min_compress_size=1024,
    error_feedback=True)
tr = make_trainer((4, 2), comp, steps=120)
_, _, hist = tr.fit()
first, last = hist[0]["loss"], hist[-1]["loss"]
check("compressed.loss_decreases", last < first - 0.8,
      f"{first:.3f} -> {last:.3f}")

# ---- 2. exact vs compressed gradients agree at step 0 (unbiasedness) -------
tr_e = make_trainer((4, 2), core_types.CompressionConfig(mode="none"),
                    steps=10)
_, _, hist_e = tr_e.fit()
check("exact.runs_finite", hist_e[-1]["loss"] < 10.0,
      f"{hist_e[0]['loss']:.3f} -> {hist_e[-1]['loss']:.3f}")

# ---- 3. checkpoint restart resumes bit-identically -------------------------
tmp = tempfile.mkdtemp()
try:
    tr1 = make_trainer((4, 2), comp, steps=20, ckpt_dir=tmp)
    p1, o1, _ = tr1.fit()     # saves at 10, 20

    tr2 = make_trainer((4, 2), comp, steps=20, ckpt_dir=tmp)
    # restore-from-20 then run 0 more steps: states must match exactly
    start, p2, o2, _ = tr2.init_or_restore()
    check("ckpt.resume_step", start == 20, f"start={start}")
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    check("ckpt.params_identical", max(diffs) == 0.0, f"max diff {max(diffs)}")

    # elastic: restore the same checkpoint on a (2,2) mesh (half the DP)
    tr3 = make_trainer((2, 2), comp, steps=20, ckpt_dir=tmp)
    start3, p3, _, _ = tr3.init_or_restore()
    diffs3 = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3))]
    check("ckpt.elastic_reshard", start3 == 20 and max(diffs3) == 0.0,
          f"max diff {max(diffs3)}")
finally:
    shutil.rmtree(tmp, ignore_errors=True)

print("ALL TRAIN INTEGRATION CHECKS PASSED")
