"""Shared harness for the overlapped-vs-post-backward sync schedules.

One construction of the MLP-chain grad tree and the two shard_map step
bodies, imported by BOTH subprocess entry points so the validator and the
benchmark can never measure different configurations:

  * tests/distributed_checks/overlap_check.py — bit-equality + HLO checks;
  * benchmarks/bench_bucketing.py (_OVERLAP_INNER) — ms/step + launch
    parity for BENCH_collectives.json's ``overlap`` section.

Importers MUST set XLA_FLAGS (device count) before importing this module —
it imports jax, and jax locks the device count at first init.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.registry import compression_preset
from repro.core import types
from repro.train import bucketing


def build_tree(n_layers: int, width: int):
    """(shapes, specs) of the L-layer MLP chain: w_[i] (M,M) + b_[i] (M,).

    All leaves unsharded → every mesh axis is a sync axis; the weights land
    in compressed buckets, the biases in one exact bucket.
    """
    shapes = {}
    for i in range(n_layers):
        shapes[f"w_{i:02d}"] = (width, width)
        shapes[f"b_{i:02d}"] = (width,)
    specs = {n: (None,) * len(s) for n, s in shapes.items()}
    return shapes, specs


def init_params(shapes, scale: float = 0.2):
    return {n: scale * jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(0), i), shapes[n])
        for i, n in enumerate(sorted(shapes))}


def make_loss(n_layers: int):
    def loss_fn(params, x):
        h = x
        for i in range(n_layers):
            h = jnp.tanh(h @ params[f"w_{i:02d}"] + params[f"b_{i:02d}"])
        return jnp.mean(h * h)

    return loss_fn


def mkcfg(preset: str, width: int) -> types.CompressionConfig:
    """The preset at f32 wire (the CPU backend legalizes bf16 collectives
    at f32 — same normalization as the other distributed checks), bucket
    capacity sized so the weight leaves split into multiple buckets."""
    cfg = (types.CompressionConfig(mode="none") if preset == "none"
           else compression_preset(preset, axes=("data",)))
    return dataclasses.replace(
        cfg, min_compress_size=1024, wire_dtype="float32",
        bucket=types.BucketSpec(capacity=2 * width * width))


def make_sync_steps(mesh, n_layers: int, cfg, plan):
    """(post_fn, ovl_fn), jitted: (params, ef, x, key) -> (grads, new_ef).

    ``post_fn`` is the reference schedule (grad, then sync_grads_bucketed);
    ``ovl_fn`` differentiates through bucketing.overlap_params — the
    overlapped schedule.  Both take the EF pytree positionally ({} when the
    config is EF-free) so callers drive every preset uniformly.
    """
    loss_fn = make_loss(n_layers)
    use_ef = cfg.error_feedback
    pspec = {s.name: P() for b in plan.buckets for s in b.slots}
    efspec = {b.bid: P() for b in plan.buckets
              if use_ef and b.kind == "compressed"}

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(pspec, efspec, P("data"), P()),
                       out_specs=(pspec, efspec), check_vma=False)
    def post(params, ef, x, key):
        grads = jax.grad(loss_fn)(params, x)
        g, new_ef = bucketing.sync_grads_bucketed(
            grads, plan, cfg, key, ef if use_ef else None)
        return g, (new_ef if use_ef else {})

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(pspec, efspec, P("data"), P()),
                       out_specs=(pspec, efspec), check_vma=False)
    def ovl(params, ef, x, key):
        def loss2(p, e):
            tagged = bucketing.overlap_params(
                p, plan, cfg, key, e if use_ef else None)
            return loss_fn(tagged, x)

        g, gef = jax.grad(loss2, argnums=(0, 1))(params, ef if use_ef else {})
        return g, (gef if use_ef else {})

    return jax.jit(post), jax.jit(ovl)
