"""Launcher smoke: lower_cell on a small (2,2) mesh with a reduced arch —
exercises the full dry-run pipeline (lower, compile, memory/cost analysis,
loop-aware HLO parse, roofline record) without the 512-device mesh."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax  # noqa: E402

import repro.configs.registry as registry  # noqa: E402
from repro.configs.base import RunConfig, SHAPES, ShapeSpec  # noqa: E402
from repro.core import types as core_types  # noqa: E402
from repro.launch import dryrun  # noqa: E402

# swap a reduced config in for the full one
smoke = registry.smoke_config("qwen3-4b")
registry._ARCHS["qwen3-4b-smoke"] = smoke
SHAPES["smoke_train"] = ShapeSpec("smoke_train", "train", 64, 8)
SHAPES["smoke_decode"] = ShapeSpec("smoke_decode", "decode", 64, 8)

run = RunConfig(microbatches=2, model_parallel=True, seq_shard=True,
                attn_chunk_q=32, attn_chunk_k=32, remat=True,
                compression=core_types.CompressionConfig(
                    encoder=core_types.EncoderSpec(kind="fixed_k",
                                                   fraction=0.25),
                    mode="shared_support", axes=("data",),
                    min_compress_size=0))

mesh = jax.make_mesh((2, 2), ("data", "model"))

for shp in ("smoke_train", "smoke_decode"):
    rec, compiled = dryrun.lower_cell(mesh, "qwen3-4b-smoke", shp,
                                      multi_pod=False, run_override=run)
    assert rec["status"] == "ok", rec
    rl = rec["roofline"]
    assert rl["compute_s"] >= 0 and rl["memory_s"] > 0
    assert rec["memory"]["total_dev"] > 0
    print(f"[ok] {shp}: dom={rl['dominant']} "
          f"colls={rec['collectives']['counts']}")

print("DRYRUN SMALL CHECK PASSED")
