"""Multi-device (16 fake CPU devices) validation of the hierarchical
two-level + reduce-scatter compressed collectives (docs/DESIGN.md §11).
Run by tests/test_hierarchical.py in a subprocess:

    python hierarchical_check.py

Checks:
  * node-count sweep n ∈ {4, 8, 16} over (pod, data) = (n/2, 2) meshes:
    the hierarchical path (exact pmean inside the data axis, codec across
    the pod axis, reduce-scatter decode sharded over the inner group) is
    BIT-exact vs the flat reference — pmean over the inner axis followed
    by the flat codec over the pod axis — for every linear preset,
    including the rotated and error-feedback compositions;
  * per lowered HLO at n = 8: exactly ONE cross-host collective per round
    (replica-groups classifier: a collective is cross-host iff some group
    spans two inner blocks), its payload bits == codec.wire_bits at the
    effective node count == cost_config(..., mesh_sizes) − seed_bits, and
    the cross-host bytes shrink by exactly the inner-group factor vs the
    flat all-axes config;
  * bucketed sync (sync_grads_bucketed) on the 2-level mesh issues exactly
    one cross-host collective per compressed bucket, with
    bucket_wire_bits(plan, cfg, n, mesh_sizes) matching the HLO bits.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import dataclasses  # noqa: E402
import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives, comm_cost, types, wire  # noqa: E402
from repro.train import bucketing  # noqa: E402

D = 5000                # NOT a power of two: exercises shard-pad tails
N_IN = 2                # inner (intra-host) group size of every sweep mesh
SWEEP = (4, 8, 16)      # total node counts; (pod, data) = (n/2, 2)


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def enc(kind, **kw):
    return types.EncoderSpec(kind=kind, fraction=1.0 / 16, center="mean",
                             **kw)


def hier_cfg(encoder, **kw):
    return types.CompressionConfig(
        encoder=encoder, mode="gather_decode", axes=("pod",),
        inner_axes=("data",), scatter_decode=True, wire_dtype="float32",
        min_compress_size=0, **kw)


# every linear preset + its rotated / EF compositions, plus the two-level
# schedule without the scatter decode (hierarchy and scatter are
# independently selectable).
PRESETS = {
    "fixed_k": hier_cfg(enc("fixed_k")),
    "bernoulli": hier_cfg(enc("bernoulli")),
    "rotated_fixed_k": hier_cfg(enc("fixed_k", rotation=True)),
    "ef_bernoulli": hier_cfg(enc("bernoulli"), error_feedback=True),
    "fixed_k_noscatter": dataclasses.replace(hier_cfg(enc("fixed_k")),
                                             scatter_decode=False),
}


def mesh_for(n):
    return Mesh(np.array(jax.devices()[:n]).reshape(n // N_IN, N_IN),
                ("pod", "data"))


def run_hier(cfg, mesh, xs, key):
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P(("pod", "data")), P()), out_specs=P(),
                       check_vma=False, check_rep=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    return jax.jit(f)


def run_ref(cfg, mesh):
    """pmean over the inner axis, then the FLAT codec across pod."""
    flat = dataclasses.replace(cfg, inner_axes=(), scatter_decode=False)

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P(("pod", "data")), P()), out_specs=P(),
                       check_vma=False, check_rep=False)
    def f(xs, key):
        v = jax.lax.pmean(xs.reshape(D), ("data",))
        return collectives.compressed_mean(v, key, flat)
    return jax.jit(f)


def parse_collectives(txt):
    """[(kind, bits, groups)] for every collective in the HLO text."""
    out = []
    for line in txt.splitlines():
        m = re.search(r"= (f32|bf16|u32|s32|u16|u8|pred)\[([\d,]*)\]\S* "
                      r"(all-gather|all-reduce|reduce-scatter)"
                      r"(?:-start)?\(", line)
        if not m:
            continue
        width = {"f32": 32, "u32": 32, "s32": 32, "bf16": 16,
                 "u16": 16, "u8": 8, "pred": 8}[m.group(1)]
        size = 1
        for v in m.group(2).split(","):
            if v:
                size *= int(v)
        g = re.search(r"replica_groups=\{\{([\d,{} ]*)\}\}", line)
        groups = []
        if g:
            for grp in g.group(1).split("},{"):
                groups.append([int(v) for v in grp.split(",") if v.strip()])
        out.append((m.group(3), size * width, groups))
    return out


def cross_host(txt, n_in):
    """Collectives whose replica groups span two inner blocks (the slow
    link): device linear id = pod·n_in + data, so a group is cross-host
    iff its ids disagree on id // n_in."""
    return [(kind, bits, groups)
            for kind, bits, groups in parse_collectives(txt)
            if any(len({i // n_in for i in grp}) > 1 for grp in groups)]


# ---- node-count sweep: hierarchical bit-exact vs the flat reference ---------
KEY = jax.random.PRNGKey(7)
for n in SWEEP:
    mesh = mesh_for(n)
    xs = jax.random.normal(jax.random.PRNGKey(n), (n, D)) * 0.5
    for name, cfg in PRESETS.items():
        got = np.asarray(run_hier(cfg, mesh, xs, KEY)(xs, KEY))
        want = np.asarray(run_ref(cfg, mesh)(xs, KEY))
        check(f"n{n}.{name}.bit_exact", np.array_equal(got, want),
              f"max|diff|={float(np.max(np.abs(got - want))):.2e}")

# ---- HLO: one cross-host collective, exact effective-n accounting -----------
N = 8
N_OUT = N // N_IN
mesh = mesh_for(N)
MSIZES = {"pod": N_OUT, "data": N_IN}
xs = jax.random.normal(jax.random.PRNGKey(N), (N, D)) * 0.5
for name, cfg in PRESETS.items():
    codec = wire.resolve(cfg)
    txt = run_hier(cfg, mesh, xs, KEY).lower(xs, KEY).compile().as_text()
    cross = cross_host(txt, N_IN)
    check(f"hlo.{name}.one_cross_host", len(cross) == 1,
          f"cross-host collectives={[(k, b) for k, b, _ in cross]}")
    bits = cross[0][1]
    want = codec.wire_bits(N_OUT, D, cfg)
    check(f"hlo.{name}.bits_eq_wire_bits", bits == want,
          f"hlo={bits} wire_bits(n_eff={N_OUT})={want:.0f}")
    cost = comm_cost.cost_config(cfg, n=N, d=D, mesh_sizes=MSIZES)
    check(f"hlo.{name}.bits_eq_cost_config",
          bits == cost - codec.seed_bits(N_OUT, cfg),
          f"hlo={bits} cost={cost:.0f} seed={codec.seed_bits(N_OUT, cfg):.0f}")

    # the flat all-axes config ships n messages over the slow link — the
    # hierarchy shrinks cross-host bytes by exactly the inner-group factor.
    flat_all = dataclasses.replace(cfg, axes=("pod", "data"), inner_axes=(),
                                   scatter_decode=False)
    txt_flat = run_hier(flat_all, mesh, xs, KEY).lower(
        xs, KEY).compile().as_text()
    flat_bits = sum(b for _, b, _ in cross_host(txt_flat, N_IN))
    check(f"hlo.{name}.shrink_by_inner_factor", flat_bits == N_IN * bits,
          f"flat={flat_bits} hier={bits} factor={flat_bits / bits:.2f} "
          f"(want {N_IN})")

# ---- bucketed sync: one cross-host collective per compressed bucket ---------
BIG, SMALL = 4096, 64
SHAPES = {f"big_{i}": (BIG,) for i in range(4)}
SHAPES.update({f"small_{i}": (SMALL,) for i in range(6)})
SPECS = {nm: (None,) for nm in SHAPES}
BCFG = dataclasses.replace(
    hier_cfg(enc("bernoulli")), min_compress_size=1024,
    bucket=types.BucketSpec(capacity=2 * BIG))
plan = bucketing.build_plan(SHAPES, SPECS, ("pod", "data"), MSIZES, BCFG)
n_cmp = sum(1 for b in plan.buckets if b.kind == "compressed")
check("bucketed.plan", n_cmp == 2,
      f"compressed buckets={n_cmp} (want 2)")

key0 = jax.random.PRNGKey(1)
GXS = {nm: jax.random.normal(jax.random.fold_in(key0, h), (N,) + SHAPES[nm])
       for h, nm in enumerate(sorted(SHAPES))}
txt = jax.jit(
    functools.partial(compat.shard_map, mesh=mesh,
                      in_specs=({nm: P(("pod", "data"), None)
                                 for nm in SHAPES}, P()),
                      out_specs={nm: P() for nm in SHAPES},
                      check_vma=False, check_rep=False)(
        lambda xs, key: bucketing.sync_grads_bucketed(
            {nm: xs[nm].reshape(SHAPES[nm]) for nm in xs},
            plan, BCFG, key)[0])
).lower(GXS, jax.random.PRNGKey(0)).compile().as_text()
cross = cross_host(txt, N_IN)
cross_ag = [c for c in cross if c[0] == "all-gather"]
cross_ar = [c for c in cross if c[0] != "all-gather"]
check("bucketed.one_cross_gather_per_compressed_bucket",
      len(cross_ag) == n_cmp,
      f"cross-host gathers={len(cross_ag)} (want {n_cmp})")
# the exact bucket's single pmean spans both axes — one cross-host
# all-reduce; nothing else may touch the slow link.
check("bucketed.exact_bucket_single_cross_reduce", len(cross_ar) == 1,
      f"cross-host reduces={[(k, b) for k, b, _ in cross_ar]} (want 1)")
want_bits = bucketing.bucket_wire_bits(plan, BCFG, N, MSIZES)
check("bucketed.wire_bits_match_hlo",
      sorted(b for _, b, _ in cross_ag) == sorted(want_bits.values()),
      f"hlo={sorted(b for _, b, _ in cross_ag)} "
      f"accounting={sorted(want_bits.values())}")

print("ALL HIERARCHICAL CHECKS PASSED")
