"""Multi-device (8 fake CPU devices) validation of the overlapped bucket
sync (BucketSpec.overlap → repro.train.bucketing.overlap_params).  Run by
tests/test_overlap.py in a subprocess:

    python overlap_check.py

Checks (ISSUE 5 acceptance):
  * schedule independence: overlapped grads == post-backward grads
    bit-for-bit for every tested preset — stateless psum (fixed_k_1bit),
    stateless gather (bernoulli_seed_1bit), packed plane (binary_packed)
    and the stateful DRIVE stack (ef_rotated_binary), whose per-bucket EF
    residuals must also match bit-for-bit across 3 chained steps even
    though buckets complete out of backward order;
  * HLO: the expected collective launches per bucket (compiled exec
    counts — 1 for psum/exact buckets, 2 for flat-scatter buckets whose
    decode re-gathers the decoded shards, DESIGN.md §13), and at the
    dependency level the per-bucket collectives *interleave* with
    backward — the first-ready bucket's collective is independent of the
    trailing backward dots (neither ancestor nor descendant), so it can be
    issued before the final backward op instead of after the loss graph;
  * the real train step (build_train_step, smoke model, EF shared_support)
    takes bit-identical steps with overlap ON and OFF.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

# the shared post-vs-overlapped step construction (same module the
# bench_bucketing overlap sweep imports, so check and bench agree).
import overlap_harness as oh  # noqa: E402

from repro.core import types  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.train import bucketing  # noqa: E402

N = 8
L, M = 6, 64           # 6-layer MLP chain: w_[i] (M,M) + b_[i] (M,)
STEPS = 3              # chained EF steps (state threads across rounds)

mesh = jax.make_mesh((N,), ("data",))
MESH_AXES = ("data",)
MSIZES = {"data": N}

SHAPES, SPECS = oh.build_tree(L, M)
PARAMS = oh.init_params(SHAPES)
X = jax.random.normal(jax.random.PRNGKey(1), (N * 4, M))


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def make_steps(cfg, plan):
    """(ref_fn, ovl_fn): one sync'd-grad round -> (grads, new_ef)."""
    return oh.make_sync_steps(mesh, L, cfg, plan)


# --------------------------------------------------------------------------- #
# Schedule independence: overlapped == post-backward, bit-for-bit.
# --------------------------------------------------------------------------- #

# every registered preset (the docstring's "every registered codec" claim
# is enforced, not sampled) + the exact baseline.
from repro.configs.registry import COMPRESSION_PRESETS  # noqa: E402

PRESETS = ["none"] + sorted(COMPRESSION_PRESETS)

for preset in PRESETS:
    cfg = oh.mkcfg(preset, M)
    use_ef = cfg.error_feedback
    plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg)
    ref, ovl = make_steps(cfg, plan)
    ef_r = ef_o = bucketing.init_ef_state(plan, cfg) if use_ef else {}
    g_r = g_o = None
    for stp in range(STEPS if use_ef else 1):
        key = jax.random.fold_in(jax.random.PRNGKey(7), stp)
        g_r, ef_r = ref(PARAMS, ef_r, X, key)
        g_o, ef_o = ovl(PARAMS, ef_o, X, key)
    ok_g = all(np.array_equal(np.asarray(g_r[n]), np.asarray(g_o[n]))
               for n in SHAPES)
    check(f"{preset}.grads_bit_identical", ok_g)
    if use_ef:
        ok_e = all(np.array_equal(np.asarray(ef_r[b]), np.asarray(ef_o[b]))
                   for b in ef_r)
        check(f"{preset}.ef_bit_identical_{STEPS}steps", ok_e,
              f"({len(ef_r)} bucket residuals)")


# --------------------------------------------------------------------------- #
# HLO: per-bucket launches + dependency-level interleaving with backward.
# --------------------------------------------------------------------------- #

def parse_computations(hlo: str):
    """{computation name: [(instr, op, [operand instrs])]} from HLO text."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s.*\{$", line.strip())
        if m and not line.startswith(" "):
            cur = m.group(2)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = re.match(
            r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(?:\([^=]*\)|\S+)\s+"
            r"([\w\-]+)\((.*)$",
            line)
        if not mi:
            continue
        name, op, rest = mi.groups()
        # operands: everything inside the op's first paren group
        depth, args = 1, ""
        for ch in rest:
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                break
            args += ch
        operands = re.findall(r"%?([\w\.\-]+)", args)
        comps[cur].append((name, op, operands))
    return comps


def interleave_stats(ovl, ef0):
    """(collectives, dots, {collective: #dots independent of it}).

    The first collective in emission order belongs to the *last-applied*
    sync point — the earliest-ready bucket (transpose order reverses the
    forward tag order; the earliest-ready bucket holds the highest-sorted
    leaves, tagged last).
    """
    hlo = ovl.lower(PARAMS, ef0, X, jax.random.PRNGKey(7)).as_text(
        dialect="hlo")
    comps = parse_computations(hlo)
    # the computation holding the inlined shard_map body (dots + colls)
    body = None
    for name, instrs in comps.items():
        ops = {op for _, op, _ in instrs}
        if ("dot" in ops) and ops & {"all-gather", "all-reduce"}:
            body = instrs
            break
    assert body is not None, "no computation with both dots and collectives"
    defs = {name: set(operands) for name, _, operands in body}
    known = set(defs)

    anc_cache = {}

    def ancestors(name):
        if name in anc_cache:
            return anc_cache[name]
        anc_cache[name] = set()          # cycle-safe (HLO is a DAG)
        out = set()
        for o in defs.get(name, ()):
            if o in known:
                out.add(o)
                out |= ancestors(o)
        anc_cache[name] = out
        return out

    colls = [name for name, op, _ in body
             if op in ("all-gather", "all-reduce")]
    dots = [name for name, op, _ in body if op == "dot"]
    indep = {}
    for c in colls:
        anc_c = ancestors(c)
        n = sum(1 for d in dots
                if d not in anc_c and c not in ancestors(d))
        indep[c] = n
    return colls, dots, indep


# Extra collectives per *compressed* bucket beyond the one wire gather /
# psum: ef_rotated_binary flat-scatters (§13) so each compressed bucket
# re-gathers its decoded shard — one extra all-gather (the binary family
# needs no counts exchange).  fixed_k_1bit is a single psum.
EXTRA_COLLS = {"fixed_k_1bit": 0, "ef_rotated_binary": 1}

for preset in ["fixed_k_1bit", "ef_rotated_binary"]:
    cfg = oh.mkcfg(preset, M)
    plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg)
    use_ef = cfg.error_feedback
    n_expect = sum(1 + (EXTRA_COLLS[preset] if b.kind == "compressed" else 0)
                   for b in plan.buckets)

    # the expected collective launches per bucket in the compiled module
    _, ovl = make_steps(cfg, plan)
    ef0 = bucketing.init_ef_state(plan, cfg) if use_ef else {}
    comp_txt = ovl.lower(PARAMS, ef0, X,
                         jax.random.PRNGKey(7)).compile().as_text()
    n_launch = sum(hlo_cost.analyze_text(comp_txt).coll_exec.values())
    check(f"{preset}.launch_per_bucket", n_launch == n_expect,
          f"launches={n_launch} expected={n_expect} "
          f"buckets={len(plan.buckets)}")

    colls, dots, indep = interleave_stats(ovl, ef0)
    check(f"{preset}.coll_count", len(colls) == n_expect,
          f"{len(colls)} collectives for {len(plan.buckets)} buckets"
          f" (expected {n_expect})")
    # Interleaved, not trailing: the first-issued (earliest-ready) bucket's
    # collective is independent of part of backward — it does not wait for
    # the final backward op the way a post-loss-graph sync stage would
    # force once grads are materialized as a unit.  The earliest-ready
    # bucket holds the *last* layers' weights, whose cotangents exist
    # before any earlier layer's backward dot runs.
    first = colls[0]
    check(f"{preset}.interleaves_backward", indep[first] >= 2,
          f"first collective independent of {indep[first]}/{len(dots)} dots"
          f" (per-bucket: {[indep[c] for c in colls]})")

# --------------------------------------------------------------------------- #
# The real train step: overlap ON == OFF, bit-for-bit (params + EF state).
# --------------------------------------------------------------------------- #

from repro.configs.base import RunConfig, ShapeSpec  # noqa: E402
from repro.configs.registry import smoke_config  # noqa: E402
from repro.train import train_step as ts  # noqa: E402

cfg_a = smoke_config("qwen3-4b")
shape = ShapeSpec("cli", "train", 64, 8)
comp = types.CompressionConfig(
    encoder=types.EncoderSpec(kind="fixed_k", fraction=1 / 16),
    mode="shared_support", axes=("data",), min_compress_size=1024,
    error_feedback=True)
batch = {"tokens": jnp.zeros((8, 64), jnp.int32) + 3,
         "labels": jnp.ones((8, 64), jnp.int32),
         "mask": jnp.ones((8, 64), jnp.float32)}
tmesh = jax.make_mesh((4, 2), ("data", "model"))
outs = {}
for overlap in (True, False):
    run = RunConfig(
        microbatches=1, model_parallel=True, seq_shard=True,
        attn_chunk_q=64, attn_chunk_k=64, remat=False,
        compression=dataclasses.replace(
            comp, bucket=types.BucketSpec(overlap=overlap)))
    step_fn, init_fn, _, _, _ = ts.build_train_step(tmesh, cfg_a, run, shape)
    params, opt, ef = init_fn(jax.random.PRNGKey(0))
    for stp in range(2):
        params, opt, ef, metrics = step_fn(params, opt, ef, batch,
                                           jnp.int32(stp))
    outs[overlap] = (jax.tree.map(np.asarray, params),
                     jax.tree.map(np.asarray, ef))

p_on, ef_on = outs[True]
p_off, ef_off = outs[False]
check("train_step.params_bit_identical",
      all(np.array_equal(p_on[k], p_off[k]) for k in p_on))
check("train_step.ef_bit_identical",
      set(ef_on) == set(ef_off)
      and all(np.array_equal(ef_on[k], ef_off[k]) for k in ef_on),
      f"({len(ef_on)} bucket residuals)")

print("ALL OVERLAP CHECKS PASSED")
