"""Multi-device (8 fake CPU devices) validation of the packed bit-plane
binary/ternary wire paths (repro.core.bitplane + collectives).  Run by
tests/test_quantized_wire.py in a subprocess:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python quantized_wire_check.py

Checks:
  * binary/ternary gather_decode means == dense_sim_mean to fp tolerance
    (same keys, f32 wire: the packed planes reproduce the dense encoders
    bit-for-bit, so only summation-order noise remains);
  * exactly ONE collective launch per bucket in the lowered HLO of a
    bucketed sync (one all-gather per compressed bucket, one all-reduce
    per exact bucket);
  * HLO-measured gather bits per bucket == bucketing.bucket_wire_bits ==
    comm_cost.cost_binary_packed / cost_ternary_packed (no seed-bit term:
    the planes travel explicitly, unlike the §4.4 Bernoulli path);
  * the packed wire is honestly sub-dense (binary < 1/8 of f32 bits).
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import bitplane, collectives, comm_cost, types  # noqa: E402
from repro.train import bucketing  # noqa: E402

N = 8
D = 5000                # deliberately NOT a multiple of 32: exercises tails
BIG = 4096
SMALL = 64

mesh = jax.make_mesh((N,), ("data",))
MESH_AXES = ("data",)
MSIZES = {"data": N}

XS = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.3


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def mkcfg(kind, mode, frac=0.125):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=frac, center="min"),
        mode=mode, axes=("data",), wire_dtype="float32", min_compress_size=0)


def run_mean(cfg):
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    return jax.jit(f)


# ---- wire path == dense simulation, per mode --------------------------------
for kind in ("binary", "ternary"):
    key = jax.random.PRNGKey(11)
    y_wire = np.asarray(run_mean(mkcfg(kind, "gather_decode"))(XS, key))
    y_dense = np.asarray(run_mean(mkcfg(kind, "dense_sim"))(XS, key))
    err = float(np.max(np.abs(y_wire - y_dense)))
    check(f"{kind}.wire_eq_dense", err < 1e-5, f"max|diff|={err:.2e}")
    # and both are plausible mean estimates (not garbage): bounded error
    mse = float(np.mean((y_wire - np.asarray(jnp.mean(XS, axis=0))) ** 2))
    check(f"{kind}.wire_sane", np.isfinite(mse) and mse < 1.0,
          f"mse={mse:.3e}")

# ---- one collective launch per bucket + exact bit accounting ----------------
SHAPES = {f"big_{i}": (BIG,) for i in range(4)}
SHAPES.update({f"small_{i}": (SMALL,) for i in range(6)})
SPECS = {n: (None,) for n in SHAPES}
key0 = jax.random.PRNGKey(1)
GXS = {n: jax.random.normal(jax.random.fold_in(key0, h), (N,) + SHAPES[n])
       for h, n in enumerate(sorted(SHAPES))}
IN_SPECS = {n: P("data", None) for n in SHAPES}
OUT_SPECS = {n: P() for n in SHAPES}

for kind in ("binary", "ternary"):
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=0.125, center="min"),
        mode="gather_decode", axes=("data",), wire_dtype="float32",
        min_compress_size=1024, bucket=types.BucketSpec(capacity=2 * BIG))
    plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg)
    n_cmp = sum(1 for b in plan.buckets if b.kind == "compressed")
    n_ex = sum(1 for b in plan.buckets if b.kind == "exact")
    check(f"{kind}.plan", n_cmp == 2 and n_ex == 1,
          f"compressed={n_cmp} exact={n_ex}")

    txt = jax.jit(
        functools.partial(compat.shard_map, mesh=mesh,
                          in_specs=(IN_SPECS, P()), out_specs=OUT_SPECS,
                          check_vma=False)(
            lambda xs, key, plan=plan, cfg=cfg: bucketing.sync_grads_bucketed(
                {n: xs[n].reshape(SHAPES[n]) for n in xs}, plan, cfg, key)[0])
    ).lower(GXS, jax.random.PRNGKey(0)).compile().as_text()

    # exactly one collective launch per bucket: one all-gather per
    # compressed bucket, one all-reduce per exact bucket.
    n_ag = len(re.findall(r"= \S+ all-gather(?:-start)?\(", txt))
    n_ar = len(re.findall(r"= \S+ all-reduce(?:-start)?\(", txt))
    check(f"{kind}.one_launch_per_bucket", n_ag == n_cmp and n_ar == n_ex,
          f"all-gather={n_ag} (want {n_cmp}) all-reduce={n_ar} (want {n_ex})")

    # HLO-measured gather bits == bucket_wire_bits == comm_cost packed form.
    want_bits = bucketing.bucket_wire_bits(plan, cfg, N)
    spec32 = types.CommSpec(protocol=kind, r_bits=32)
    measured = 0.0
    expect_cost = 0.0
    for b in plan.buckets:
        if b.kind != "compressed":
            continue
        if kind == "binary":
            w = bitplane.binary_wire_words(b.size, cfg.wire_dtype)
            expect_cost += comm_cost.cost_binary_packed(N, b.size, spec32)
        else:
            cap = comm_cost.bernoulli_capacity(b.size, 0.125)
            w = bitplane.ternary_wire_words(b.size, cap, cfg.wire_dtype)
            expect_cost += comm_cost.cost_ternary_packed(N, b.size, cap,
                                                         spec32)
        check(f"{kind}.hlo_gather[{b.bid}]", f"u32[{N},{w}]" in txt,
              f"expected an all-gather result u32[{N},{w}] on the wire")
        measured += N * w * 32
        check(f"{kind}.bucket_wire_bits[{b.bid}]",
              want_bits[b.bid] == N * w * 32,
              f"accounting={want_bits[b.bid]:.0f} wire={N * w * 32}")
    check(f"{kind}.bit_accounting", measured == expect_cost,
          f"measured={measured:.0f} want={expect_cost:.0f}")
    if kind == "binary":
        dense_bits = sum(32 * N * b.size for b in plan.buckets
                         if b.kind == "compressed")
        check("binary.sub_dense", measured * 8 < dense_bits,
              f"wire={measured:.0f} dense={dense_bits:.0f}")

print("ALL QUANTIZED WIRE CHECKS PASSED")
