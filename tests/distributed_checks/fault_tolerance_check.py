"""Straggler/failure-path check on 8 fake devices: robust_mean equals the
live-subset mean; a full training step survives a simulated dead node."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.distributed.fault_tolerance import FailurePlan, robust_mean  # noqa: E402

mesh = jax.make_mesh((8,), ("data",))
N, D = 8, 1024
XS = jax.random.normal(jax.random.PRNGKey(0), (N, D))
plan = FailurePlan(rate=0.3, seed=5)


@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"),),
                   out_specs=P(), check_vma=False)
def agg(xs):
    return robust_mean(xs.reshape(D), 3, ("data",), plan)


got = np.asarray(jax.jit(agg)(XS))
alive = np.asarray(plan.alive_mask(3, N))
want = np.asarray(XS)[alive].mean(axis=0)
assert alive.sum() < N, "plan should kill someone at rate 0.3"
np.testing.assert_allclose(got, want, atol=1e-5)
print(f"[ok] robust_mean over {int(alive.sum())}/{N} live nodes")
print("FAULT TOLERANCE CHECK PASSED")
