"""Straggler/failure-path check on 8 fake devices: robust_mean equals the
live-subset mean; the host-side and in-shard failure views agree at every
(step, rate) because they derive from one shared draw; the all-dead
partial_mean is NaN by contract, never a silent zero."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.distributed.fault_tolerance import (FailurePlan, partial_mean,  # noqa: E402
                                               robust_mean, survivor_index)

mesh = jax.make_mesh((8,), ("data",))
N, D = 8, 1024
XS = jax.random.normal(jax.random.PRNGKey(0), (N, D))
plan = FailurePlan(rate=0.3, seed=5)


@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"),),
                   out_specs=P(), check_vma=False)
def agg(xs):
    return robust_mean(xs.reshape(D), 3, ("data",), plan)


got = np.asarray(jax.jit(agg)(XS))
alive = np.asarray(plan.alive_mask(3, N))
want = np.asarray(XS)[alive].mean(axis=0)
assert alive.sum() < N, "plan should kill someone at rate 0.3"
np.testing.assert_allclose(got, want, atol=1e-5)
print(f"[ok] robust_mean over {int(alive.sum())}/{N} live nodes")

# alive_mask (host view) and local_alive (in-shard view) derive from ONE
# shared draw — the gathered per-shard scalars equal the host mask at
# every step and rate, including the 0.0 / 1.0 edges.
for rate in (0.0, 0.3, 0.7, 1.0):
    p = FailurePlan(rate=rate, seed=11)
    for step in range(5):

        @functools.partial(compat.shard_map, mesh=mesh,
                           in_specs=(P("data"),), out_specs=P("data"),
                           check_vma=False)
        def view(xs):
            del xs
            return p.local_alive(step, ("data",)).reshape(1)

        got = np.asarray(jax.jit(view)(XS))
        want = np.asarray(p.alive_mask(step, N)).astype(np.float32)
        assert np.array_equal(got, want), (rate, step, got, want)
        assert np.array_equal(
            np.asarray(p.drop_mask(step, N)), want), (rate, step)
        if rate == 1.0:
            assert want.sum() == 1, want  # the one-survivor rule
            key = jax.random.fold_in(jax.random.PRNGKey(p.seed), step)
            surv = int(survivor_index(jax.random.uniform(key, (N,))))
            assert want[surv] == 1.0, (step, surv, want)
print("[ok] local_alive == alive_mask == drop_mask across steps x rates")

# robust_mean tracks the plan's survivor set over a denser steps grid —
# every step's aggregate equals the numpy mean over that step's live rows
# (the same jit cache entry serves all steps: step enters via closure
# rebuild here, so assert value-correctness only).
p = FailurePlan(rate=0.5, seed=23)
for step in range(8):

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_vma=False)
    def agg_s(xs, _step=step):
        return robust_mean(xs.reshape(D), _step, ("data",), p)

    got = np.asarray(jax.jit(agg_s)(XS))
    alive = np.asarray(p.alive_mask(step, N))
    want = np.asarray(XS)[alive].mean(axis=0)
    np.testing.assert_allclose(got, want, atol=1e-5, err_msg=str(step))
print("[ok] robust_mean == live-subset mean across an 8-step grid")


# all-dead partial_mean is NaN by contract (0/0): an impossible state under
# FailurePlan's survivor rule must poison the step, not silently zero it.
@functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"),),
                   out_specs=P(), check_vma=False)
def all_dead(xs):
    return partial_mean(xs.reshape(D), jnp.float32(0.0), ("data",))


assert np.isnan(np.asarray(jax.jit(all_dead)(XS))).all()
print("[ok] all-dead partial_mean is NaN by contract")
print("FAULT TOLERANCE CHECK PASSED")
