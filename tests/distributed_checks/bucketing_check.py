"""Multi-device (8 fake CPU devices) validation of the bucketed gradient
sync (repro.train.bucketing).  Run by tests/test_bucketing.py in a
subprocess:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python bucketing_check.py

Checks:
  * mode "none": bucketed sync == per-leaf exact pmean, elementwise;
  * shared_support: unbiased per leaf + per-bucket closed-form MSE
    (mse_fixed_k_shared on the concatenated bucket vectors);
  * gather_decode with the Bernoulli wire path: unbiased, and the gathered
    wire buffer's measured bits == comm_cost.cost(sparse_seed, cap=…) minus
    the seed bits (which ride the implicit PRNG — the §4.4 seed trick);
  * error feedback keyed by bucket id: time-averaged estimates converge on
    constant inputs.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives, comm_cost, mse, types  # noqa: E402
from repro.train import bucketing  # noqa: E402

N = 8
BIG = 4096              # = 4 blocks of fk.BLOCK; >= min_compress_size below
SMALL = 64
TRIALS = 200

mesh = jax.make_mesh((N,), ("data",))
MESH_AXES = ("data",)
MSIZES = {"data": N}

SHAPES = {f"big_{i:02d}": (BIG,) for i in range(6)}
SHAPES.update({f"small_{i:02d}": (SMALL,) for i in range(20)})
SPECS = {n: (None,) for n in SHAPES}

key0 = jax.random.PRNGKey(0)
XS = {n: jax.random.normal(jax.random.fold_in(key0, h), (N,) + SHAPES[n]) * 0.3
      for h, n in enumerate(sorted(SHAPES))}
TRUE = {n: np.asarray(jnp.mean(XS[n], axis=0)) for n in XS}

IN_SPECS = {n: P("data", None) for n in SHAPES}
OUT_SPECS = {n: P() for n in SHAPES}


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def mkcfg(**kw):
    kw.setdefault("axes", ("data",))
    kw.setdefault("min_compress_size", 1024)
    kw.setdefault("wire_dtype", "float32")
    kw.setdefault("bucket", types.BucketSpec(capacity=2 * BIG))
    return types.CompressionConfig(**kw)


def local_tree(xs):
    return {n: xs[n].reshape(SHAPES[n]) for n in xs}


# ---- plan shape sanity ------------------------------------------------------
cfg = mkcfg(encoder=types.EncoderSpec(kind="fixed_k", fraction=0.25,
                                      center="mean"),
            mode="shared_support")
plan = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg)
n_cmp = sum(1 for b in plan.buckets if b.kind == "compressed")
n_ex = sum(1 for b in plan.buckets if b.kind == "exact")
check("plan.shape", n_cmp == 3 and n_ex == 1 and not plan.passthrough,
      f"compressed={n_cmp} exact={n_ex} (6 big / cap 2·BIG; 20 small)")
check("plan.coverage", set(plan.leaf_names()) == set(SHAPES))

# ---- mode none: bucketed == exact pmean ------------------------------------
cfg_none = mkcfg(mode="none")
plan_none = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg_none)
check("plan.none_all_exact",
      all(b.kind == "exact" for b in plan_none.buckets))


@functools.partial(compat.shard_map, mesh=mesh, in_specs=(IN_SPECS, P()),
                   out_specs=OUT_SPECS, check_vma=False)
def sync_once_none(xs, key):
    est, _ = bucketing.sync_grads_bucketed(local_tree(xs), plan_none,
                                           cfg_none, key)
    return est


est = jax.jit(sync_once_none)(XS, jax.random.PRNGKey(1))
err = max(float(jnp.max(jnp.abs(est[n] - TRUE[n]))) for n in SHAPES)
check("none.exact", err < 1e-5, f"max|err|={err:.2e}")


# ---- shared_support: unbiased + per-bucket closed-form MSE ------------------
@functools.partial(compat.shard_map, mesh=mesh, in_specs=(IN_SPECS, P()),
                   out_specs=(OUT_SPECS, P(), P()), check_vma=False)
def trial_stats(xs, key):
    grads = local_tree(xs)

    def one(i, carry):
        acc, sq, small_err = carry
        est, _ = bucketing.sync_grads_bucketed(
            grads, plan, cfg, jax.random.fold_in(key, i))
        sq_i = sum(jnp.sum((est[n] - jnp.asarray(TRUE[n])) ** 2)
                   for n in SHAPES if n.startswith("big"))
        sm_i = jnp.max(jnp.stack(
            [jnp.max(jnp.abs(est[n] - jnp.asarray(TRUE[n])))
             for n in SHAPES if n.startswith("small")]))
        return ({n: acc[n] + est[n] for n in acc}, sq + sq_i,
                jnp.maximum(small_err, sm_i))

    zero = {n: jnp.zeros(SHAPES[n]) for n in SHAPES}
    acc, sq, small_err = jax.lax.fori_loop(
        0, TRIALS, one, (zero, jnp.zeros(()), jnp.zeros(())))
    return {n: acc[n] / TRIALS for n in acc}, sq / TRIALS, small_err


mean_est, mse_emp, small_err = jax.jit(trial_stats)(XS, jax.random.PRNGKey(7))
check("shared.small_leaves_exact", float(small_err) < 1e-5,
      f"max|err|={float(small_err):.2e}")

# per-bucket closed form: each compressed bucket concatenates two big
# leaves; the shared-support MSE adds across buckets (independent keys).
want = 0.0
for b in plan.buckets:
    if b.kind != "compressed":
        continue
    xs_b = jnp.concatenate([XS[s.name] for s in b.slots], axis=1)
    k = int(0.25 * (b.size // 1024)) * 1024
    want += float(mse.mse_fixed_k_shared(xs_b, k, jnp.mean(xs_b, axis=-1)))
D_big = 6 * BIG
bias = max(float(jnp.max(jnp.abs(mean_est[n] - jnp.asarray(TRUE[n]))))
           for n in SHAPES if n.startswith("big"))
check("shared.unbiased", bias < 6 * np.sqrt(want / D_big),
      f"max|bias|={bias:.4f}")
check("shared.bucket_mse", abs(float(mse_emp) - want) < 0.15 * want,
      f"emp={float(mse_emp):.4f} want={want:.4f}")

# ---- gather_decode + bernoulli: the wire path under bucketing ---------------
cfg_b = mkcfg(encoder=types.EncoderSpec(kind="bernoulli", fraction=0.25,
                                        center="mean"),
              mode="gather_decode")
plan_b = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg_b)


@functools.partial(compat.shard_map, mesh=mesh, in_specs=(IN_SPECS, P()),
                   out_specs=OUT_SPECS, check_vma=False)
def trial_mean_bern(xs, key):
    grads = local_tree(xs)

    def one(i, acc):
        est, _ = bucketing.sync_grads_bucketed(
            grads, plan_b, cfg_b, jax.random.fold_in(key, i))
        return {n: acc[n] + est[n] for n in acc}

    zero = {n: jnp.zeros(SHAPES[n]) for n in SHAPES}
    acc = jax.lax.fori_loop(0, TRIALS, one, zero)
    return {n: acc[n] / TRIALS for n in acc}


mean_b = jax.jit(trial_mean_bern)(XS, jax.random.PRNGKey(11))
want_b = 0.0
for b in plan_b.buckets:
    if b.kind != "compressed":
        continue
    xs_b = jnp.concatenate([XS[s.name] for s in b.slots], axis=1)
    want_b += float(mse.mse_bernoulli(xs_b, 0.25, jnp.mean(xs_b, axis=-1)))
bias_b = max(float(jnp.max(jnp.abs(mean_b[n] - jnp.asarray(TRUE[n]))))
             for n in SHAPES if n.startswith("big"))
check("bern.unbiased", bias_b < 6 * np.sqrt(want_b / D_big),
      f"max|bias|={bias_b:.4f}")

# ---- bernoulli bit accounting: measured wire == cost − seed bits ------------
# Lower one bucketed sync and read the gathered buffer straight from HLO:
# each compressed bucket all_gathers (cap + 1) f32 slots per node (values +
# μ); supports never travel (regenerated from fold_in — the §4.4 trick), so
# measured bits must equal cost_sparse_seed_capacity minus n·r̄_s exactly.
txt = jax.jit(
    functools.partial(compat.shard_map, mesh=mesh, in_specs=(IN_SPECS, P()),
                      out_specs=OUT_SPECS, check_vma=False)(
        lambda xs, key: bucketing.sync_grads_bucketed(
            local_tree(xs), plan_b, cfg_b, key)[0])
).lower(XS, jax.random.PRNGKey(0)).compile().as_text()
spec_f32 = types.CommSpec(protocol="sparse_seed", r_bits=32, rbar_bits=32)
measured_bits = 0.0
expect_bits = 0.0
for b in plan_b.buckets:
    if b.kind != "compressed":
        continue
    cap = comm_cost.bernoulli_capacity(b.size, 0.25)
    check(f"bern.hlo_gather[{b.bid}]", f"f32[{N},{cap + 1}]" in txt,
          f"expected an all-gather result f32[{N},{cap + 1}] on the wire")
    measured_bits += N * (cap + 1) * 32
    expect_bits += (comm_cost.cost(spec_f32, n=N, d=b.size, cap=cap)
                    - N * spec_f32.rseed_bits)
check("bern.bit_accounting", measured_bits == expect_bits,
      f"measured={measured_bits:.0f} want={expect_bits:.0f}")
# and the wire is honestly sub-dense: < 0.5 · naive f32 bits at p = 0.25
naive_bits = sum(32 * N * b.size for b in plan_b.buckets
                 if b.kind == "compressed")
check("bern.sub_dense", measured_bits < 0.5 * naive_bits,
      f"wire={measured_bits:.0f} dense={naive_bits:.0f}")

# ---- error feedback keyed by bucket id --------------------------------------
cfg_ef = mkcfg(encoder=types.EncoderSpec(kind="fixed_k", fraction=0.25,
                                         center="mean"),
               mode="shared_support", error_feedback=True)
plan_ef = bucketing.build_plan(SHAPES, SPECS, MESH_AXES, MSIZES, cfg_ef)
check("ef.state_keys",
      set(bucketing.init_ef_state(plan_ef, cfg_ef))
      == {b.bid for b in plan_ef.buckets if b.kind == "compressed"})


@functools.partial(compat.shard_map, mesh=mesh, in_specs=(IN_SPECS, P()),
                   out_specs=OUT_SPECS, check_vma=False)
def ef_many(xs, key):
    grads = local_tree(xs)

    def body(i, carry):
        ef, acc = carry
        est, ef = bucketing.sync_grads_bucketed(
            grads, plan_ef, cfg_ef, jax.random.fold_in(key, i), ef)
        return ef, {n: acc[n] + est[n] for n in acc}

    zero = {n: jnp.zeros(SHAPES[n]) for n in SHAPES}
    _, acc = jax.lax.fori_loop(
        0, 64, body, (bucketing.init_ef_state(plan_ef, cfg_ef), zero))
    return {n: acc[n] / 64 for n in acc}


avg = jax.jit(ef_many)(XS, jax.random.PRNGKey(9))
ef_rmse = max(
    float(jnp.sqrt(jnp.mean((avg[n] - jnp.asarray(TRUE[n])) ** 2)))
    for n in SHAPES if n.startswith("big"))
check("ef.converges", ef_rmse < 0.05, f"rmse={ef_rmse:.4f}")

print("ALL BUCKETING CHECKS PASSED")
