"""Multi-device (8 fake CPU devices) adversarial matrix for the robust
decode subsystem (docs/DESIGN.md §14).  Run by tests/test_robust_decode.py
in a subprocess:

    python robust_decode_check.py

Checks, per gather preset (axes re-pointed at the flat 8-device mesh):
  * Byzantine round — one adversarial peer of 8 replaces its REAL wire row
    (post-pack, pre-gather: integer planes corrupted through the f32
    bitcast) per corruption mode {nan, inf, sign_flip, boost}.  The
    trim(1) decode's error stays ≤ 2× its own clean-round error, while
    the plain mean decode blows past 10× (nan/inf/boost) or takes a
    bounded hit (sign_flip — a pure −row against a mean of 8 shifts the
    estimate by −2·row/8, which for zero-mean quantized rows may not
    even raise the error).
    The clean-decode yardstick is the max of the mean, trim(1) and
    trim(2) decoders' clean (no-adversary) errors — the protocol's clean
    accuracy contract.  trim(2) belongs in the set because an
    f-consuming extreme adversary (nan/inf/boost) occupies one trim slot
    per coordinate, turning trim(1) over 8 rows into an asymmetric
    1-and-2 trim of the 7 honest rows — bracketed by the symmetric
    trim(2) clean decode; the damage stays ≤ 2× that ceiling.  An
    interior adversary (sign_flip: a quantized row's flipped values land
    inside the honest per-coordinate hull) cannot be trimmed at all —
    the order statistics can't tell it from an honest row — so its
    guarantee is containment in the hull, whose width on binary/ternary
    codecs is the quantization range: empirically ≤ 2.5× clean, asserted
    at ≤ 4× (hull-slack factor);
  * clean trim(1) error within the §14 ``mse_trimmed`` closed-form bound
    for the presets with exact base MSE forms (bernoulli, binary);
  * drop_mask decode: a dropped peer's data has ZERO bit influence —
    poisoning the dead peers' inputs leaves the masked output
    bit-identical (same jit cache entry, so the survivor computation is
    literally the same program on the same bytes = the survivor re-run);
    and the value equals the survivors-only host rerun with original
    peer indices (the seed-trick chains must not re-index) to f32
    tolerance — mesh-vs-eager-host bit equality is NOT the contract
    (XLA FMA-fuses the decode affine math under jit);
  * zero recompiles across masks and across adversary/mode operands: the
    mask, the adversary rank and the corruption selector are traced
    operands, so the jit cache stays at ONE entry for any schedule;
  * the robust round's lowered HLO carries exactly the mean round's
    all-gather payload — decode policies never touch the wire;
  * the mode="none" exact path renormalizes over survivors through the
    same drop_mask operand (partial_mean contract).
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import registry as cfg_registry  # noqa: E402
from repro.core import collectives, mse, types, wire  # noqa: E402
from repro.core.wire import base as wire_base  # noqa: E402
from repro.distributed import fault_tolerance as ft  # noqa: E402

N, D = 8, 5000
ROUNDS = 4
MODES = ft.CORRUPTION_MODES          # ("nan", "inf", "sign_flip", "boost")
NONFINITE_OR_BOOST = ("nan", "inf", "boost")

GATHER_PRESETS = sorted(
    nm for nm in cfg_registry.COMPRESSION_PRESETS
    if wire.resolve(cfg_registry.robust_preset(nm, "mean", axes=("data",)))
    .reduce == "all_gather")


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def preset(nm, policy):
    return dataclasses.replace(
        cfg_registry.robust_preset(nm, policy, axes=("data",)),
        wire_dtype="float32", min_compress_size=0)


MESH = Mesh(np.array(jax.devices()[:N]), ("data",))


def adversarial_round(cfg):
    """jit'd round: pack → corrupt the adversary's wire row → gather →
    policy decode.  ``adv`` (−1 = nobody), ``mode_idx`` and ``mask`` are
    all traced operands — one cache entry serves the whole matrix."""
    codec = wire.resolve(cfg)

    @functools.partial(compat.shard_map, mesh=MESH,
                       in_specs=(P("data"), P(), P(), P(), P()),
                       out_specs=P(), check_vma=False)
    def f(x, key, adv, mode_idx, mask):
        rank, n = wire_base.axis_rank_size(cfg.axes)
        buf = codec.pack(x.reshape(D), key, rank, cfg)
        variants = jnp.stack([ft.corrupt_wire_row(buf, m) for m in MODES])
        buf = jnp.where(rank == adv, variants[mode_idx], buf)
        return codec.gather_decode(buf, key, cfg, D, n, mask)
    return jax.jit(f)


def masked_mean_round(cfg):
    @functools.partial(compat.shard_map, mesh=MESH,
                       in_specs=(P("data"), P(), P()), out_specs=P(),
                       check_vma=False)
    def f(x, key, mask):
        return collectives.compressed_mean(x.reshape(D), key, cfg,
                                           drop_mask=mask)
    return jax.jit(f)


def gather_bits(txt):
    nbits = {"f32": 32, "u32": 32, "s32": 32, "bf16": 16}
    out = []
    for dt, dims in re.findall(
            r"= (f32|u32|s32|bf16)\[([\d,]+)\]\S* all-gather"
            r"(?:-start)?\(", txt):
        b = nbits[dt]
        for v in dims.split(","):
            b *= int(v)
        out.append(b)
    return sorted(out)


XS = jax.random.normal(jax.random.PRNGKey(1), (N, D), jnp.float32)
XBAR = np.asarray(XS.mean(0))
KEYS = [jax.random.PRNGKey(100 + r) for r in range(ROUNDS)]
NO_ADV = jnp.int32(-1)
FULL = jnp.ones((N,), jnp.float32)


def sq_err(y):
    return float(((np.asarray(y) - XBAR) ** 2).sum())


# ---- the Byzantine matrix ---------------------------------------------------
for nm in GATHER_PRESETS:
    cfg_t = preset(nm, "trim(1)")
    cfg_m = preset(nm, "mean")
    f_t = adversarial_round(cfg_t)
    f_m = adversarial_round(cfg_m)
    f_t2 = adversarial_round(preset(nm, "trim(2)"))
    clean_t = np.mean([sq_err(f_t(XS, k, NO_ADV, jnp.int32(0), FULL))
                       for k in KEYS])
    clean_m = np.mean([sq_err(f_m(XS, k, NO_ADV, jnp.int32(0), FULL))
                       for k in KEYS])
    clean_t2 = np.mean([sq_err(f_t2(XS, k, NO_ADV, jnp.int32(0), FULL))
                        for k in KEYS])
    ceiling = max(clean_m, clean_t, clean_t2)
    for mi, mode in enumerate(MODES):
        adv, midx = jnp.int32(3), jnp.int32(mi)
        err_t = np.mean([sq_err(f_t(XS, k, adv, midx, FULL)) for k in KEYS])
        errs_m = [sq_err(f_m(XS, k, adv, midx, FULL)) for k in KEYS]
        err_m = np.mean(errs_m)
        fac = 4.0 if mode == "sign_flip" else 2.0
        check(f"{nm}.trim_contained[{mode}]",
              np.isfinite(err_t) and err_t <= fac * ceiling,
              f"adv={err_t:.4f} clean_mean={clean_m:.4f} "
              f"clean_trim={clean_t:.4f} clean_trim2={clean_t2:.4f}")
        if mode in NONFINITE_OR_BOOST:
            blown = (not np.isfinite(err_m)) or err_m > 10.0 * clean_m
            check(f"{nm}.mean_blows_up[{mode}]", blown,
                  f"adv={err_m:.4g} clean={clean_m:.4g}")
        else:
            # sign_flip against the mean is a bounded −2·row/n hit, not
            # nuclear — and for zero-mean quantized rows (ternary) the
            # flipped row is statistically just another plausible row,
            # so the error may not even rise.  Assert finite + bounded.
            check(f"{nm}.mean_bounded[{mode}]",
                  np.isfinite(err_m) and err_m <= 4.0 * ceiling,
                  f"adv={err_m:.4f} clean={clean_m:.4f}")
    # one cache entry served the whole (adv, mode, mask) matrix
    for f, tag in ((f_t, "trim"), (f_m, "mean")):
        check(f"{nm}.no_recompiles[{tag}]", f._cache_size() == 1,
              f"cache={f._cache_size()}")

# ---- clean trim error within the §14 closed-form bound ----------------------
for nm, bound in (
        ("bernoulli_seed_1bit", lambda cfg: mse.mse_trimmed_bernoulli(
            XS, float(cfg.encoder.fraction), jnp.mean(XS, axis=-1), 1)),
        ("binary_packed", lambda cfg: mse.mse_trimmed_binary(XS, 1))):
    cfg_t = preset(nm, "trim(1)")
    f_t = adversarial_round(cfg_t)
    errs = [sq_err(f_t(XS, k, NO_ADV, jnp.int32(0), FULL)) for k in KEYS]
    b = float(bound(cfg_t))
    check(f"{nm}.within_mse_trimmed", np.mean(errs) <= b,
          f"err={np.mean(errs):.4f} bound={b:.4f}")

# ---- drop_mask: bit-identical to the survivors-only rerun, no recompiles ----
for nm in GATHER_PRESETS:
    cfg = preset(nm, "mean")
    codec = wire.resolve(cfg)
    f = masked_mean_round(cfg)
    key = KEYS[0]
    masks = [FULL,
             jnp.asarray([1, 1, 1, 0, 1, 1, 1, 1], jnp.float32),
             jnp.asarray([0, 1, 1, 1, 0, 1, 1, 1], jnp.float32),
             jnp.asarray([1, 0, 0, 1, 1, 1, 0, 1], jnp.float32)]
    outs = [np.asarray(f(XS, key, m)) for m in masks]
    check(f"{nm}.mask_no_recompiles", f._cache_size() == 1,
          f"cache={f._cache_size()}")
    # the FULL mask equals the unmasked production round in value (the
    # unmasked path lowers the FUSED decode, the masked path the stacked
    # reduction — different programs, same mean).
    @functools.partial(compat.shard_map, mesh=MESH,
                       in_specs=(P("data"), P()), out_specs=P(),
                       check_vma=False)
    def plain_f(x, k):
        return collectives.compressed_mean(x.reshape(D), k, cfg)

    plain_out = np.asarray(jax.jit(plain_f)(XS, key))
    check(f"{nm}.full_mask_matches_unmasked",
          np.allclose(outs[0], plain_out, rtol=1e-5, atol=1e-5),
          f"max|diff|={np.max(np.abs(outs[0] - plain_out)):.2e}")
    # host-side survivor rerun: pack per rank with ORIGINAL indices, keep
    # only surviving rows, decode through the same policy hook.
    rows = jnp.stack([codec.pack(XS[i], key, i, cfg) for i in range(N)])
    for m, out in zip(masks[1:], outs[1:]):
        tag = "".join(str(int(v)) for v in m)
        # zero bit influence: poison the dropped peers' inputs; the same
        # cache entry must produce the identical bits.
        mm = np.asarray(m)
        xs_p = np.array(XS)
        xs_p[mm == 0] = 1e9 + np.arange(D, dtype=np.float32)
        out_p = np.asarray(f(jnp.asarray(xs_p), key, m))
        check(f"{nm}.mask_bitexact[{tag}]", np.array_equal(out, out_p),
              f"max|diff|={np.max(np.abs(out - out_p)):.2e}")
        ref = np.asarray(codec.decode_rows_reduce(
            rows, key, cfg, D, N, drop_mask=m))
        check(f"{nm}.mask_matches_host[{tag}]",
              np.allclose(out, ref, rtol=1e-5, atol=1e-5),
              f"max|diff|={np.max(np.abs(out - ref)):.2e}")
    check(f"{nm}.mask_no_recompiles[poisoned]", f._cache_size() == 1,
          f"cache={f._cache_size()}")

# the hook itself equals an ascending survivors-only loop (the "re-run
# without the dropped peer" reference), bit for bit — meshless companion
# assertions live in tests/test_robust_decode.py for every preset; here we
# close the chain through the mesh for one linear and one rotated preset.
for nm in ("bernoulli_seed_1bit", "rotated_binary"):
    cfg = preset(nm, "mean")
    codec = wire.resolve(cfg)
    key = KEYS[1]
    m = jnp.asarray([1, 1, 0, 1, 1, 1, 1, 1], jnp.float32)
    got = np.asarray(masked_mean_round(cfg)(XS, key, m))
    rows = jnp.stack([codec.pack(XS[i], key, i, cfg) for i in range(N)])
    from repro.core import rotation
    inner = codec.inner if isinstance(codec, wire.RotatedCodec) else codec
    dim = rotation.padded_dim(D) if inner is not codec else D
    stack = inner.decode_rows(rows, key, cfg, dim, N)
    acc = jnp.zeros((dim,), jnp.float32)
    for i in range(N):
        if float(m[i]) > 0:
            acc = acc + stack[i]
    ref = acc / float(m.sum())
    if inner is not codec:
        ref = rotation.unrotate(rotation.rotation_key(key), ref, D)
    ref = np.asarray(ref)
    check(f"{nm}.mask_equals_survivor_rerun",
          np.allclose(got, ref, rtol=1e-5, atol=1e-5),
          f"max|diff|={np.max(np.abs(got - ref)):.2e}")

# ---- decode policies never touch the wire (HLO payload identity) ------------
for nm in ("bernoulli_seed_1bit", "binary_packed", "ef_rotated_binary"):
    bits = {}
    for policy in ("mean", "trim(1)", "median"):
        cfg = preset(nm, policy)
        txt = masked_mean_round(cfg).lower(
            jax.ShapeDtypeStruct((N, D), np.float32),
            jax.ShapeDtypeStruct((2,), np.uint32),
            jax.ShapeDtypeStruct((N,), np.float32)).compile().as_text()
        bits[policy] = gather_bits(txt)
    check(f"{nm}.policy_blind_payload",
          bits["mean"] == bits["trim(1)"] == bits["median"],
          f"{bits}")

# ---- exact path + FailurePlan integration -----------------------------------
cfg_none = types.CompressionConfig(mode="none", axes=("data",))
f_none = masked_mean_round(cfg_none)
m = jnp.asarray([1, 0, 1, 1, 1, 1, 0, 1], jnp.float32)
got = np.asarray(f_none(XS, KEYS[0], m))
want = np.asarray(XS[np.asarray(m) > 0].mean(0))
check("none.masked_exact_mean",
      np.allclose(got, want, rtol=1e-6, atol=1e-6),
      f"max|diff|={np.max(np.abs(got - want)):.2e}")
check("none.mask_no_recompiles",
      f_none(XS, KEYS[0], FULL) is not None and f_none._cache_size() == 1,
      f"cache={f_none._cache_size()}")

plan = ft.FailurePlan(rate=0.5, seed=4)
cfg_b = preset("bernoulli_seed_1bit", "trim(1)")


@functools.partial(compat.shard_map, mesh=MESH,
                   in_specs=(P("data"), P()), out_specs=P(),
                   check_vma=False)
def plan_round(x, key):
    return ft.robust_compressed_mean(x.reshape(D), key, cfg_b, 3, plan)


out = np.asarray(jax.jit(plan_round)(XS, KEYS[2]))
alive = np.asarray(plan.alive_mask(3, N))
check("failure_plan.robust_round_finite",
      np.isfinite(out).all() and alive.sum() >= 1,
      f"alive={alive.astype(int)}")

print("ALL ROBUST DECODE CHECKS PASSED")
