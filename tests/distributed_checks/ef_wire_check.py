"""Multi-device (8 fake CPU devices) validation of the error-feedback wire
layer (repro.core.wire.ef) — every registered EF codec end-to-end.  Run by
tests/test_ef_wire.py in a subprocess and directly in the CI matrix:

    python ef_wire_check.py

Checks, per EF codec:
  * payload identity: the lowered HLO of the STATEFUL round (residual as a
    real carried input) gathers buffers of EXACTLY the inner codec's
    shapes, in exactly one launch — the residual never travels, EF is
    wire-free by construction;
  * analytic accounting: wire_bits / comm_cost_bits equal the inner
    codec's, and bucket-style accounting (bucket_wire_bits) agrees;
  * multi-step contraction: over T rounds on constant inputs the
    time-averaged EF estimate's bias falls strictly below the EF-free
    codec's Monte-Carlo average at the same wire budget (the telescoping
    (1/T)Σ m̄_t = x̄ + (ē_0 − ē_T)/T versus the unbiased codec's √(MSE/T)
    noise floor), and below an absolute floor;
  * residual sanity: finite, nonzero (the compressor is lossy), and the
    state pytree round-trips through the shard_map carry.
Exits non-zero on failure.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import functools  # noqa: E402
import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import registry as cfg_registry  # noqa: E402
from repro.core import collectives, types, wire  # noqa: E402

N = 8
D = 8192                # power of two: rotated payloads equal un-rotated
FRAC = 0.25
TRIALS = 64

mesh = jax.make_mesh((N,), ("data",))

# anisotropic inputs: spiky coordinates are where the quantizer twins and
# the rotation earn their keep, and where the EF-free MC noise floor is
# highest — the regime EF is for.
XS = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.3
XS = XS.at[:, :4].add(jnp.array([6.0, -5.0, 4.0, -3.0]))
TRUE = np.asarray(jnp.mean(XS, axis=0))

# every registered EF codec, as a config the registry resolves back to it.
EF_PRESETS = {
    "ef_fixed_k": ("fixed_k", "gather_decode", {"center": "mean"}),
    "ef_fixed_k_shared": ("fixed_k", "shared_support", {"center": "mean"}),
    "ef_bernoulli": ("bernoulli", "gather_decode", {"center": "mean"}),
    "ef_binary": ("binary", "gather_decode", {"center": "min"}),
    "ef_ternary": ("ternary", "gather_decode", {"center": "min"}),
    "ef_rotated_binary": ("binary", "gather_decode",
                          {"center": "min", "rotation": True}),
}


def check(name, ok, detail=""):
    print(f"[{'ok' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        raise SystemExit(f"FAILED: {name} {detail}")


def mkcfg(kind, mode, extra, ef):
    enc = types.EncoderSpec(kind=kind, fraction=FRAC, **extra)
    return types.CompressionConfig(
        encoder=enc, mode=mode, axes=("data",), wire_dtype="float32",
        min_compress_size=0, error_feedback=ef)


def lower_stateful_text(cfg):
    """Lower ONE stateful round with the residual as a real carried input
    (not a constant-folded zero) — what the train step executes."""
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P("data"), P("data"), P()),
                       out_specs=(P(), P("data")), check_vma=False)
    def f(xs, state, key):
        return collectives.compressed_mean_stateful(
            xs.reshape(D), state.reshape(D), key, cfg)
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile().as_text()


def lower_plain_text(cfg):
    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    def f(xs, key):
        return collectives.compressed_mean(xs.reshape(D), key, cfg)
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile().as_text()


def gathered(txt):
    """(shape, bits) of every collective wire op in the lowered HLO."""
    bits_of = {"f32": 32, "u32": 32, "bf16": 16}
    out = []
    for dt, dims, op in re.findall(
            r"= (f32|u32|bf16)\[([\d,]+)\]\S* (all-gather|all-reduce)"
            r"(?:-start)?\(", txt):
        b = bits_of[dt]
        for x in dims.split(","):
            b *= int(x)
        out.append((f"{dt}[{dims}]:{op}", b * (N if op == "all-reduce" else 1)))
    return sorted(out)


K0 = jax.random.PRNGKey(13)
for name, (kind, mode, extra) in EF_PRESETS.items():
    cfg_ef = mkcfg(kind, mode, extra, ef=True)
    cfg_plain = mkcfg(kind, mode, extra, ef=False)
    codec = wire.resolve(cfg_ef)
    inner = wire.resolve(cfg_plain)
    check(f"{name}.resolves", codec.name == name and codec.inner is inner
          and codec.stateful and codec.state_shape(D, cfg_ef) == (D,))

    # ---- HLO: the stateful round's wire == the inner codec's, 1 launch --- #
    g_ef = gathered(lower_stateful_text(cfg_ef))
    g_plain = gathered(lower_plain_text(cfg_plain))
    check(f"{name}.one_launch", len(g_ef) == 1 and len(g_plain) == 1,
          f"ef={g_ef} plain={g_plain}")
    check(f"{name}.residual_never_travels", g_ef == g_plain,
          f"ef={g_ef} plain={g_plain}")
    check(f"{name}.hlo_bits_match_accounting",
          g_ef[0][1] == codec.wire_bits(N, D, cfg_ef)
          and codec.wire_bits(N, D, cfg_ef) == inner.wire_bits(N, D, cfg_plain),
          f"hlo={g_ef[0][1]} codec={codec.wire_bits(N, D, cfg_ef):.0f}")

    # ---- contraction: EF time-average beats the EF-free MC average -------- #
    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P("data"), P()), out_specs=(P(), P(), P()),
                       check_vma=False)
    def trial(xs, key, cfg_ef=cfg_ef, cfg_plain=cfg_plain):
        x = xs.reshape(D)

        def body(t, carry):
            err, acc_ef, acc_pl = carry
            kt = jax.random.fold_in(key, t)
            est, err = collectives.compressed_mean_stateful(
                x, err, kt, cfg_ef)
            est_pl = collectives.compressed_mean(x, kt, cfg_plain)
            return err, acc_ef + est, acc_pl + est_pl

        err, acc_ef, acc_pl = jax.lax.fori_loop(
            0, TRIALS, body, (jnp.zeros(D), jnp.zeros(D), jnp.zeros(D)))
        return acc_ef / TRIALS, acc_pl / TRIALS, jnp.sum(err * err)

    avg_ef, avg_pl, err_ss = jax.jit(trial)(XS, K0)
    rmse_ef = float(np.sqrt(np.mean((np.asarray(avg_ef) - TRUE) ** 2)))
    rmse_pl = float(np.sqrt(np.mean((np.asarray(avg_pl) - TRUE) ** 2)))
    check(f"{name}.ef_beats_plain_time_average", rmse_ef < 0.6 * rmse_pl,
          f"ef={rmse_ef:.5f} plain={rmse_pl:.5f}")
    # absolute floor: un-rotated 1-bit keeps an O(range) residual on spiky
    # inputs (its two cluster centers can't capture the outliers — exactly
    # the deficiency §7.2 rotation fixes, cf. ef_rotated_binary's floor).
    floor = 0.12 if name == "ef_binary" else 0.02
    check(f"{name}.ef_converges", rmse_ef < floor, f"rmse={rmse_ef:.5f}")
    check(f"{name}.residual_finite_nonzero",
          np.isfinite(float(err_ss)) and float(err_ss) > 0.0,
          f"|e|^2={float(err_ss):.3e}")

# ---- the registry presets resolve to these codecs end-to-end --------------- #
for pname in ("ef_fixed_k", "ef_bernoulli", "ef_binary", "ef_ternary",
              "ef_rotated_binary"):
    pcfg = cfg_registry.compression_preset(pname, axes=("data",))
    check(f"preset.{pname}", wire.resolve(pcfg).name == pname
          and pcfg.error_feedback)

print("ALL EF WIRE CHECKS PASSED")
