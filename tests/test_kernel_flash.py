"""Flash-attention kernel vs oracle: shape/dtype/mask sweeps in interpret
mode, plus equivalence with the model's XLA chunked path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.models.attention import chunked_attention

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, sk, hq, hkv, hd, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, sq, hq, hd), dtype)
    k = jax.random.normal(kk, (b, sk, hkv, hd), dtype)
    v = jax.random.normal(kv, (b, sk, hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("sq,sk,hq,hkv,hd", [
    (256, 256, 4, 2, 128),
    (512, 512, 2, 2, 128),
    (256, 512, 8, 2, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(sq, sk, hq, hkv, hd, causal):
    if not causal and sq != sk:
        pytest.skip("bidirectional rectangular covered elsewhere")
    q, k, v = _qkv(2, sq, sk, hq, hkv, hd)
    got = fa_ops.flash_attention(q, k, v, causal=causal, block_q=128,
                                 block_k=128, force_pallas=True,
                                 interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_bf16():
    q, k, v = _qkv(1, 256, 256, 4, 2, 128, jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, causal=True, block_q=128,
                                 block_k=128, force_pallas=True,
                                 interpret=True)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_flash_sliding_window():
    q, k, v = _qkv(1, 512, 512, 2, 1, 64)
    got = fa_ops.flash_attention(q, k, v, causal=True, window=128,
                                 block_q=128, block_k=128,
                                 force_pallas=True, interpret=True)
    want = fa_ref.attention(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_q_offset_decode_window():
    """Chunked-prefill style: q block starting mid-sequence."""
    q, k, v = _qkv(1, 128, 512, 2, 2, 64)
    got = fa_ops.flash_attention(q, k, v, causal=True, q_offset=256,
                                 block_q=128, block_k=128,
                                 force_pallas=True, interpret=True)
    want = fa_ref.attention(q, k, v, causal=True, q_offset=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_xla_chunked_matches_ref():
    """The model's XLA online-softmax path is equivalent math."""
    q, k, v = _qkv(2, 256, 256, 4, 2, 64)
    got = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


# --------------------------- backward kernels ------------------------------ #

def _grads(fn, q, k, v):
    def loss(q, k, v):
        o = fn(q, k, v)
        return jnp.sum(o.astype(jnp.float32) ** 2)
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("sq,sk,hq,hkv,hd,causal", [
    (256, 256, 2, 2, 128, True),
    (256, 256, 4, 2, 64, True),     # GQA group accumulation
    (256, 256, 2, 2, 128, False),
])
def test_flash_backward_matches_ref(sq, sk, hq, hkv, hd, causal):
    q, k, v = _qkv(2, sq, sk, hq, hkv, hd)
    flash = lambda q, k, v: fa_ops.flash_attention(
        q, k, v, causal=causal, block_q=128, block_k=128,
        force_pallas=True, interpret=True)
    ref = lambda q, k, v: fa_ref.attention(q, k, v, causal=causal)
    got = _grads(flash, q, k, v)
    want = _grads(ref, q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-2, rtol=5e-3,
                                   err_msg=f"d{name}")


def test_flash_backward_window():
    q, k, v = _qkv(1, 256, 256, 2, 1, 64)
    flash = lambda q, k, v: fa_ops.flash_attention(
        q, k, v, causal=True, window=96, block_q=64, block_k=64,
        force_pallas=True, interpret=True)
    ref = lambda q, k, v: fa_ref.attention(q, k, v, causal=True, window=96)
    got = _grads(flash, q, k, v)
    want = _grads(ref, q, k, v)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-2, rtol=5e-3,
                                   err_msg=f"d{name}")
