"""Gradient-bucketing plan + pack/scatter tests (single device), plus the
multi-device subprocess check (distributed_checks/bucketing_check.py).

The plan invariants and the bit-exact pack→scatter round trip run against
*every config in the registry* (smoke-scale param trees for materialized
round trips; the plan is a pure function of abstract shapes, so full-scale
trees are covered by construction)."""
import functools
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import RunConfig
from repro.configs.registry import list_archs, smoke_config
from repro.core import types as core_types
from repro.models import model as model_lib
from repro.train import bucketing
from repro.train import train_step as ts

ROOT = pathlib.Path(__file__).resolve().parent.parent

MSIZES = {"data": 1, "model": 1}
MESH_AXES = ("data", "model")

CMP = core_types.CompressionConfig(
    encoder=core_types.EncoderSpec(kind="fixed_k", fraction=0.25),
    mode="shared_support", axes=("data",), min_compress_size=2048,
    bucket=core_types.BucketSpec(capacity=1 << 15))


@functools.lru_cache(maxsize=None)
def _abstract_tree(arch: str):
    cfg = smoke_config(arch)
    run = RunConfig(model_parallel=arch != "mamba2-130m", seq_shard=False,
                    attn_chunk_q=16, attn_chunk_k=16, compression=CMP)
    ctx = model_lib.make_ctx(cfg, run, MSIZES)
    aparams, specs = ts.abstract_specs(jax.random.PRNGKey(0), cfg, ctx,
                                       MSIZES, run)
    return aparams, specs


def _materialize(aparams):
    rng = np.random.default_rng(0)
    out = {}
    for k, v in aparams.items():
        if jnp.issubdtype(v.dtype, jnp.floating):
            out[k] = jnp.asarray(
                rng.standard_normal(v.shape, dtype=np.float32)).astype(v.dtype)
        else:
            out[k] = jnp.zeros(v.shape, v.dtype)
    return out


# --------------------------------------------------------------------------- #
# Plan invariants — every registry config.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", list_archs())
def test_plan_invariants(arch):
    aparams, specs = _abstract_tree(arch)
    plan = bucketing.build_plan(aparams, specs, MESH_AXES, MSIZES, CMP)

    # coverage: every leaf exactly once (buckets + passthrough)
    placed = [s.name for b in plan.buckets for s in b.slots]
    assert sorted(placed + list(plan.passthrough)) == sorted(aparams)
    assert plan.leaf_names() == tuple(sorted(aparams))

    cap = CMP.bucket.capacity
    for b in plan.buckets:
        # offsets are contiguous and sum to the bucket size
        off = 0
        for s in b.slots:
            assert s.offset == off
            assert s.size == int(np.prod(s.shape)) if s.shape else s.size == 1
            off += s.size
        assert off == b.size
        # capacity respected except for dedicated oversize buckets
        assert b.size <= cap or len(b.slots) == 1
        if b.kind == "compressed":
            assert b.caxes and all(a in CMP.axes for a in b.caxes)
            assert all(s.size >= CMP.min_compress_size for s in b.slots)
        else:
            assert b.caxes == ()
            assert b.eaxes

    # deterministic: the plan is a pure function of its inputs
    assert plan == bucketing.build_plan(aparams, specs, MESH_AXES, MSIZES, CMP)


def test_plan_respects_min_compress_and_mode():
    aparams, specs = _abstract_tree("qwen3-4b")
    cmp_none = core_types.CompressionConfig(
        mode="none", bucket=core_types.BucketSpec(capacity=1 << 15))
    plan = bucketing.build_plan(aparams, specs, MESH_AXES, MSIZES, cmp_none)
    assert all(b.kind == "exact" for b in plan.buckets)
    assert bucketing.plan_for_run(
        aparams, specs, MESH_AXES, MSIZES,
        core_types.CompressionConfig(
            mode="none",
            bucket=core_types.BucketSpec(enabled=False))) is None


def test_local_shape_divides_sharded_dims():
    assert bucketing.local_shape((8, 6), ("data", "model"),
                                 {"data": 4, "model": 3}) == (2, 2)
    assert bucketing.local_shape((8,), (("data", "model"),),
                                 {"data": 2, "model": 2}) == (2,)
    with pytest.raises(ValueError):
        bucketing.local_shape((7,), ("data",), {"data": 2})


# --------------------------------------------------------------------------- #
# Pack → scatter round trip — bit-exact, every registry config.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch", list_archs())
def test_pack_scatter_roundtrip_bit_exact(arch):
    aparams, specs = _abstract_tree(arch)
    plan = bucketing.build_plan(aparams, specs, MESH_AXES, MSIZES, CMP)
    grads = _materialize(aparams)

    out = {n: grads[n] for n in plan.passthrough}
    for b in plan.buckets:
        vec = bucketing.pack_bucket(grads, b)
        assert vec.shape == (b.size,) and vec.dtype == jnp.float32
        out.update(bucketing.unpack_bucket(vec, b, grads))

    assert set(out) == set(grads)
    for n in grads:
        assert out[n].dtype == grads[n].dtype, n
        assert out[n].shape == grads[n].shape, n
        np.testing.assert_array_equal(np.asarray(out[n]),
                                      np.asarray(grads[n]), err_msg=n)


def test_bucketed_sync_identity_on_one_device():
    """mode 'none' on a 1-device mesh: sync must be the exact identity."""
    mesh = jax.make_mesh((1,), ("data",))
    shapes = {"a": (256, 17), "b": (4096,), "c": (3,)}
    specs = {n: (None,) * len(s) for n, s in shapes.items()}
    cmp = core_types.CompressionConfig(
        mode="none", bucket=core_types.BucketSpec(capacity=1 << 12))
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": 1}, cmp)
    grads = {n: jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(0), i),
                                  s).astype(jnp.bfloat16 if n == "c"
                                            else jnp.float32)
             for i, (n, s) in enumerate(sorted(shapes.items()))}

    from jax.sharding import PartitionSpec as P
    pspecs = {n: P() for n in shapes}

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(pspecs, P()),
                       out_specs=pspecs, check_vma=False)
    def sync(g, key):
        est, _ = bucketing.sync_grads_bucketed(g, plan, cmp, key)
        return est

    out = jax.jit(sync)(grads, jax.random.PRNGKey(0))
    for n in grads:
        np.testing.assert_array_equal(np.asarray(out[n]),
                                      np.asarray(grads[n]), err_msg=n)


# --------------------------------------------------------------------------- #
# Multi-device behavior (subprocess: 8 fake CPU devices).
# --------------------------------------------------------------------------- #

@pytest.mark.distributed
def test_bucketed_sync_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" / "bucketing_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL BUCKETING CHECKS PASSED" in res.stdout
