"""§7.2 rotation through the *wire path* (repro.core.wire.rotated).

Covers what tests/test_kernels.py-style rotate/unrotate round trips cannot:
the pad-to-power-of-two handling must survive pack → gather → unpack (the
wire buffer lives in the padded rotated basis), and the composed
estimator's MSE must match the §7.2 closed forms.  The 8-device
end-to-end run is tests/distributed_checks/rotated_wire_check.py,
launched here as a subprocess.
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import simulate_wire_round as _simulate_round
from repro.core import comm_cost, mse, rotation, types, wire

ROOT = pathlib.Path(__file__).resolve().parent.parent
N = 8
KEY = jax.random.PRNGKey(0)


def _cfg(kind, *, frac=0.25, center="min"):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=frac, center=center,
                                  rotation=True),
        mode="gather_decode", axes=("data",), wire_dtype="float32",
        min_compress_size=0)


# --------------------------------------------------------------------------- #
# Non-power-of-two d through the wire: pad/truncate must survive the trip.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("kind", ["binary", "fixed_k", "bernoulli", "ternary"])
@pytest.mark.parametrize("d", [37, 300, 1000])
def test_nonpow2_roundtrip_through_wire_path(kind, d):
    """rotated codec at non-power-of-two d: the wire buffer is sized for
    the padded basis, decode truncates back, and the lossless operating
    point recovers x exactly — so pad → pack → gather → unpack → unrotate
    is the identity, not just rotate∘unrotate in isolation."""
    cfg = _cfg(kind, frac=1.0 if kind != "ternary" else 0.999999)
    codec = wire.resolve(cfg)
    dp = rotation.padded_dim(d)
    assert codec.wire_slots(d, cfg) == codec.inner.wire_slots(dp, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(d), (N, d)) * 0.5
    # identical inputs on every node: any unbiased estimator that is exact
    # at full budget must return x itself (binary/ternary quantize, so for
    # those assert unbiasedness-level closeness over a small average).
    xs_same = jnp.broadcast_to(xs[0], (N, d))
    got = _simulate_round(codec, cfg, xs_same, KEY)
    assert got.shape == (d,)
    if kind in ("fixed_k", "bernoulli"):
        # p = 1 / k = d: lossless — the round trip must be exact to fp.
        np.testing.assert_allclose(np.asarray(got), np.asarray(xs_same[0]),
                                   rtol=2e-4, atol=2e-4)
    else:
        assert bool(jnp.all(jnp.isfinite(got)))


@pytest.mark.parametrize("d", [37, 300, 1000])
def test_nonpow2_rotated_binary_unbiased_through_wire(d):
    """Monte-Carlo unbiasedness of the full non-pow2 wire path (the padded
    coordinates carry rotation mass that must be returned, not dropped)."""
    cfg = _cfg("binary")
    codec = wire.resolve(cfg)
    xs = jax.random.normal(jax.random.PRNGKey(d + 1), (N, d)) * 0.5
    xs = xs.at[:, 0].add(3.0)
    true = np.asarray(jnp.mean(xs, axis=0))

    def one(k):
        return _simulate_round(codec, cfg, xs, k)

    trials = 400
    ys = jax.lax.map(jax.jit(one), jax.random.split(KEY, trials))
    bias = np.max(np.abs(np.asarray(jnp.mean(ys, axis=0)) - true))
    # per-coordinate std of the mean estimate ~ sqrt(MSE/d / trials)
    tol = 6 * float(jnp.sqrt(jnp.mean(jnp.var(ys, axis=0)) / trials)) + 1e-4
    assert bias < tol, (bias, tol)


# --------------------------------------------------------------------------- #
# §7.2 closed forms (power-of-two d: the conditional form is exact).
# --------------------------------------------------------------------------- #

def _mc_mse(sample_y, xs, trials=3000):
    x_true = jnp.mean(xs, axis=0)

    def one(k):
        err = sample_y(k) - x_true
        return jnp.sum(err * err)

    errs = jax.lax.map(jax.jit(one), jax.random.split(KEY, trials))
    return float(jnp.mean(errs)), float(jnp.std(errs) / np.sqrt(trials))


def test_rotated_binary_wire_mse_matches_closed_form():
    """Wire-path MSE == Example 4's form at QX, averaged over the same
    rotation seeds the wire derives (mse.mse_rotated_binary)."""
    d = 64
    xs = jax.random.normal(jax.random.PRNGKey(42), (N, d))
    xs = xs.at[:, 0].add(5.0)  # anisotropic: rotation matters here
    cfg = _cfg("binary")
    codec = wire.resolve(cfg)
    got, se = _mc_mse(lambda k: _simulate_round(codec, cfg, xs, k), xs)
    keys = jax.random.split(KEY, 3000)
    want = float(jnp.mean(jax.lax.map(
        jax.jit(lambda k: mse.mse_rotated_binary(xs, rotation.rotation_key(k))),
        keys)))
    assert abs(got - want) < max(5 * se, 0.03 * want), (got, want, se)
    # and the §7.2 win is real on this data:
    assert want < float(mse.mse_binary(xs))


def test_rotated_fixed_k_wire_mse_matches_closed_form():
    """Wire-path MSE == Lemma 3.4 at QX in the rotated basis
    (mse.mse_rotated_fixed_k) — block-structured k, power-of-two d."""
    d = 2048  # 2 blocks of fk.BLOCK; frac 0.5 → k = 1 block
    xs = jax.random.normal(jax.random.PRNGKey(43), (N, d)) * 0.3
    cfg = _cfg("fixed_k", frac=0.5, center="mean")
    codec = wire.resolve(cfg)
    k = codec.inner.wire_slots(d, cfg) - 1  # kb·BLOCK
    got, se = _mc_mse(lambda kk: _simulate_round(codec, cfg, xs, kk), xs,
                      trials=1500)
    keys = jax.random.split(KEY, 1500)
    want = float(jnp.mean(jax.lax.map(
        jax.jit(lambda kk: mse.mse_rotated_fixed_k(
            xs, k, rotation.rotation_key(kk))), keys)))
    assert abs(got - want) < max(5 * se, 0.05 * want), (got, want, se)


def test_reference_protocol_and_wire_closed_form_agree():
    """The single-host reference stack (protocol.MeanEstimator with
    rotation) and the wire codec share the same §7.2 math: identical
    conditional closed forms."""
    xs = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    krot = jax.random.PRNGKey(9)
    zs = rotation.rotate(krot, xs)
    np.testing.assert_allclose(
        float(mse.mse_rotated_binary(xs, krot)), float(mse.mse_binary(zs)),
        rtol=1e-6)


# --------------------------------------------------------------------------- #
# Seed-only payload overhead (accounting, incl. non-pow2).
# --------------------------------------------------------------------------- #

def test_rotated_payload_is_seed_only_overhead():
    cfg = _cfg("binary")
    plain = dataclasses.replace(
        cfg, encoder=dataclasses.replace(cfg.encoder, rotation=False))
    for d in (64, 4096):  # powers of two: payload must be equal exactly
        assert (comm_cost.cost_config(cfg, n=N, d=d)
                == comm_cost.cost_config(plain, n=N, d=d)
                + N * types.DEFAULT_RSEED_BITS)
    # non-pow2: the payload is the inner codec's at padded_dim.
    d = 5000
    rot = wire.resolve(cfg)
    assert rot.wire_bits(N, d, cfg) == \
        wire.resolve(plain).wire_bits(N, rotation.padded_dim(d), plain)


# --------------------------------------------------------------------------- #
# Multi-device end-to-end (subprocess: 8 fake CPU devices).
# --------------------------------------------------------------------------- #

@pytest.mark.distributed
def test_rotated_wire_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" / "rotated_wire_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL ROTATED WIRE CHECKS PASSED" in res.stdout
