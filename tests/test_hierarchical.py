"""Hierarchical two-level + reduce-scatter wire path (docs/DESIGN.md §11).

The multi-device half (bit-exactness vs the flat reference across node
counts, cross-host HLO accounting, bucketed sync) runs in a subprocess
with 16 fake CPU devices — tests/distributed_checks/hierarchical_check.py.
The units below cover the meshless pieces: effective-node accounting,
config/registry validation, and the reduce-scatter decode kernels
(stitched shards == the flat decode, bit for bit).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, types, wire
from repro.kernels.bernoulli_wire import ref as bw_ref
from repro.kernels.threefry import ref as tf_ref

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_hierarchical_check():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" /
                             "hierarchical_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL HIERARCHICAL CHECKS PASSED" in res.stdout


def _cfg(kind, **kw):
    return types.CompressionConfig(
        encoder=types.EncoderSpec(kind=kind, fraction=1.0 / 16,
                                  center="mean"),
        mode="gather_decode", axes=("pod",), inner_axes=("data",),
        scatter_decode=True, wire_dtype="float32", min_compress_size=0,
        **kw)


# --------------------------------------------------------------------------- #
# effective-node accounting (the flat-world-size bugfix).
# --------------------------------------------------------------------------- #

def test_effective_nodes_flat_is_identity():
    flat = dataclasses.replace(_cfg("fixed_k"), inner_axes=(),
                               scatter_decode=False)
    assert wire.effective_nodes(flat, 8) == 8
    # flat configs ignore mesh_sizes entirely
    assert wire.effective_nodes(flat, 8, {"bogus": 3}) == 8


def test_effective_nodes_divides_by_inner_group():
    cfg = _cfg("fixed_k")
    assert wire.effective_nodes(cfg, 8, {"pod": 4, "data": 2}) == 4
    assert wire.effective_nodes(cfg, 16, {"pod": 2, "data": 8}) == 2


def test_effective_nodes_requires_mesh_sizes():
    cfg = _cfg("fixed_k")
    with pytest.raises(ValueError, match="mesh_sizes"):
        wire.effective_nodes(cfg, 8)
    with pytest.raises(ValueError, match="missing from mesh_sizes"):
        wire.effective_nodes(cfg, 8, {"pod": 4})
    with pytest.raises(ValueError, match="not divisible"):
        wire.effective_nodes(cfg, 8, {"pod": 4, "data": 3})


def test_cost_config_threads_mesh_sizes():
    cfg = _cfg("bernoulli")
    codec = wire.resolve(cfg)
    got = comm_cost.cost_config(cfg, n=8, d=4096,
                                mesh_sizes={"pod": 4, "data": 2})
    assert got == codec.wire_bits(4, 4096, cfg) + codec.seed_bits(4, cfg)
    with pytest.raises(ValueError, match="mesh_sizes"):
        comm_cost.cost_config(cfg, n=8, d=4096)


# --------------------------------------------------------------------------- #
# config / registry validation.
# --------------------------------------------------------------------------- #

def test_inner_axes_must_be_disjoint_from_axes():
    with pytest.raises(ValueError, match="disjoint"):
        dataclasses.replace(_cfg("fixed_k"), inner_axes=("pod", "data"))


def test_scatter_decode_flat_resolves_for_linear_codecs():
    # §12: flat (single-axis) scatter is legal for coordinate-partitionable
    # codecs — the decode shards over cfg.axes itself.
    for kind in ("fixed_k", "bernoulli"):
        flat = dataclasses.replace(_cfg(kind), inner_axes=())
        codec = wire.resolve(flat)
        assert codec.scatter_supported
        assert wire.scatter_axes(flat) == ("pod",)
    # hier configs still shard over the inner axes
    assert wire.scatter_axes(_cfg("fixed_k")) == ("data",)


def test_scatter_decode_resolves_for_bitplane_codecs():
    # §13: the packed plane decodes partition too, on word-aligned shards.
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="binary", center="min"),
        mode="gather_decode", axes=("pod",), inner_axes=("data",),
        scatter_decode=True)
    codec = wire.resolve(cfg)
    assert codec.scatter_supported
    assert wire.scatter_word_align(cfg) == 32
    tern = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="ternary", fraction=1.0 / 16,
                                  center="min"),
        mode="gather_decode", axes=("pod",), scatter_decode=True)
    assert wire.scatter_word_align(tern) == 16


def test_resolve_rejects_scatter_for_psum_codec():
    # psum codecs decode a reduced wire — there are no per-peer rows to
    # shard, so scatter_decode cannot compose with them.
    cfg = types.CompressionConfig(
        encoder=types.EncoderSpec(kind="fixed_k", fraction=1.0 / 16,
                                  center="mean"),
        mode="shared_support", axes=("pod",), scatter_decode=True)
    with pytest.raises(ValueError, match="scatter_decode"):
        wire.resolve(cfg)
    # the same schedule WITHOUT scatter is fine
    wire.resolve(dataclasses.replace(cfg, scatter_decode=False))


# --------------------------------------------------------------------------- #
# reduce-scatter decode kernels, meshless: stitched shards == flat decode.
# --------------------------------------------------------------------------- #

def test_fixed_k_shard_concat_matches_flat_decode():
    d, n = 5000, 4
    cfg = _cfg("fixed_k")
    codec = wire.resolve(cfg)
    key = jax.random.PRNGKey(3)
    xs = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    rows = jnp.stack([codec.pack(xs[i], key, i, cfg) for i in range(n)])
    want = np.asarray(codec.decode_gathered(rows, key, cfg, d, n))
    for nshards in (2, 4):
        parts = [codec.decode_gathered_shard(rows, key, cfg, d, n,
                                             s, nshards)
                 for s in range(nshards)]
        got = np.asarray(jnp.concatenate(parts))[:d]
        assert np.array_equal(got, want), nshards


def test_bernoulli_support_shards_stitch_to_full_draw():
    d, n, p = 1000, 3, 1.0 / 16
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(5), i)
                      for i in range(n)])
    full = np.asarray(jax.vmap(
        lambda k: jax.random.uniform(k, (d,), dtype=jnp.float32))(keys) < p)
    for nshards in (2, 3):
        ds = -(-d // nshards)
        parts = [bw_ref.support_shard(keys, p, d, s * ds, ds)
                 for s in range(nshards)]
        got = np.asarray(jnp.concatenate(parts, axis=1))
        assert not got[:, d:].any()      # padding lanes decode dead
        assert np.array_equal(got[:, :d], full), nshards


def test_bernoulli_shard_decode_matches_flat_decode():
    d, n, p = 1000, 3, 1.0 / 16
    cap = comm_cost.bernoulli_capacity(d, p)
    k0 = jax.random.PRNGKey(6)
    keys = jnp.stack([jax.random.fold_in(k0, i) for i in range(n)])
    bufs = jax.random.normal(jax.random.fold_in(k0, 100), (n, cap))
    mus = jax.random.normal(jax.random.fold_in(k0, 101), (n,))
    want = np.asarray(bw_ref.decode_sum(bufs, mus, keys, p, cap, d))
    for nshards in (2, 3):
        ds = -(-d // nshards)
        sent = [bw_ref.support_shard(keys, p, d, s * ds, ds)
                for s in range(nshards)]
        # the rank offset the scatter path derives from its one inner
        # all_gather: each peer's support count strictly before the shard
        counts = jnp.stack([jnp.sum(s.astype(jnp.int32), axis=1)
                            for s in sent])
        prior = jnp.cumsum(counts, axis=0) - counts
        parts = [bw_ref.decode_sum_shard(bufs, mus, sent[s], prior[s], cap)
                 for s in range(nshards)]
        got = np.asarray(jnp.concatenate(parts))[:d]
        assert np.array_equal(got, want), nshards


def test_uniform_at_matches_batch_uniform():
    # the random-access Threefry draw the sharded support regenerates from
    # must be bit-exact vs the batch draw peers encode with
    key = jax.random.PRNGKey(7)
    for d in (1, 2, 255, 256, 257, 1000):
        want = np.asarray(jax.random.uniform(key, (d,), dtype=jnp.float32))
        got = np.asarray(tf_ref.uniform_at(
            key, jnp.arange(d, dtype=jnp.int32), d))
        assert np.array_equal(got, want), d
