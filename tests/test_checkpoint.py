"""Checkpoint save/restore: atomic commit, retention, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as ckpt
from repro.optim import optimizers as opt


def _state(key, d=64):
    params = {"w": jax.random.normal(key, (d, d)),
              "layers.norm": jnp.ones((4, d))}
    return params, opt.adamw_init(params)


def test_save_restore_roundtrip(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    params, st = _state(jax.random.PRNGKey(0))
    specs = {"w": (None, None), "layers.norm": (None, None)}
    ckpt.save(str(tmp_path), 7, params, st, specs)
    step, p2, st2, extra = ckpt.restore(str(tmp_path), mesh, specs, st)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
    np.testing.assert_array_equal(np.asarray(st2.m["w"]),
                                  np.asarray(st.m["w"]))


def test_latest_and_retention(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    params, st = _state(jax.random.PRNGKey(1))
    specs = {k: (None,) * v.ndim for k, v in params.items()}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, params, st, specs, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step-"))
    assert len(kept) == 2


def test_elastic_reshard(tmp_path):
    """Save on a 1-way mesh, restore sharded on a 2-way mesh (elastic)."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via distributed_checks)")
    mesh1 = jax.make_mesh((1,), ("data",))
    params, st = _state(jax.random.PRNGKey(2))
    specs = {"w": ("data", None), "layers.norm": (None, None)}
    ckpt.save(str(tmp_path), 3, params, st, specs)
    mesh2 = jax.make_mesh((2,), ("data",))
    step, p2, _, _ = ckpt.restore(str(tmp_path), mesh2, specs, st)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_async_checkpointer(tmp_path):
    params, st = _state(jax.random.PRNGKey(3))
    specs = {k: (None,) * v.ndim for k, v in params.items()}
    ac = ckpt.AsyncCheckpointer()
    ac.save(str(tmp_path), 11, params, st, specs)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 11
