"""Pins the DenseSimCodec accounting contract: the psum wire is float32
regardless of ``cfg.wire_dtype``, and ``wire_bits`` charges the matching
32 bits/slot.  Guards against the drift where pack() casts f32 while the
accounting silently follows the (inapplicable) wire_dtype knob — the bits
charged must always describe the buffer actually reduced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import types as t
from repro.core.wire import codecs

D, N = 257, 8


def _cfg(wire_dtype):
    return t.CompressionConfig(
        encoder=t.EncoderSpec(kind="bernoulli", fraction=0.25,
                              center="mean"),
        mode="dense_sim", wire_dtype=wire_dtype)


@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16", "float16"))
def test_pack_is_always_f32(wire_dtype):
    codec = codecs.DenseSimCodec()
    buf = codec.pack(jnp.ones((D,)), jax.random.PRNGKey(0), 3,
                     _cfg(wire_dtype))
    assert buf.dtype == jnp.float32
    assert buf.shape == (D,)


@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16", "float16"))
def test_wire_bits_charge_the_f32_buffer(wire_dtype):
    codec = codecs.DenseSimCodec()
    cfg = _cfg(wire_dtype)
    assert codec.wire_slots(D, cfg) == D
    assert codec.wire_bits(N, D, cfg) == float(
        N * D * codecs.DenseSimCodec.WIRE_BITS_PER_SLOT)
    assert codecs.DenseSimCodec.WIRE_BITS_PER_SLOT == 32


def test_accounting_matches_buffer_bytes():
    """bits == n · buffer.size · buffer.itemsize · 8 — the invariant the
    class doc promises, checked against the real packed array."""
    codec = codecs.DenseSimCodec()
    cfg = _cfg("bfloat16")
    buf = np.asarray(codec.pack(jnp.ones((D,)), jax.random.PRNGKey(1), 0,
                                cfg))
    assert codec.wire_bits(N, D, cfg) == N * buf.size * buf.itemsize * 8
