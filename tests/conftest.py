"""Shared test helpers."""
import jax.numpy as jnp


def simulate_wire_round(codec, cfg, xs, key):
    """The star protocol without a mesh: pack per rank, stack the rows as
    an all_gather would, run the codec's averaging decode.

    Exercises the full wire format (buffer layout, seed-trick regeneration,
    pad/truncate) with none of the shard_map machinery — the mesh execution
    itself is covered by tests/distributed_checks/.
    """
    n, d = xs.shape
    rows = jnp.stack([codec.pack(xs[i], key, i, cfg) for i in range(n)])
    return codec.decode_gathered(rows, key, cfg, d, n)
