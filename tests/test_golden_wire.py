"""Golden-bytes codec conformance matrix.

One parametrized test per registered compression preset: pack a fixed-seed
input through the resolved codec and compare the raw wire-buffer bytes
against the committed golden (tests/golden/golden_wire.npz).  Catches
silent wire-format drift — layout, fold_in chains, capacity rules, wire
dtype — that MSE/accounting tests can't see.  On an *intentional* format
change, regenerate via

    PYTHONPATH=src python tests/golden/regen_golden_wire.py

and commit the refreshed .npz alongside the change.
"""
import importlib.util
import pathlib

import numpy as np
import pytest

from repro.configs.registry import COMPRESSION_PRESETS

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def _regen_module():
    spec = importlib.util.spec_from_file_location(
        "regen_golden_wire", GOLDEN_DIR / "regen_golden_wire.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def golden():
    path = GOLDEN_DIR / "golden_wire.npz"
    assert path.exists(), (
        "golden_wire.npz missing — run tests/golden/regen_golden_wire.py")
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


@pytest.fixture(scope="module")
def current():
    return _regen_module().build_matrix()


def test_golden_covers_every_registered_preset(golden):
    """Adding/renaming a preset without a golden regen must fail loudly."""
    have = {k[:-len(".bytes")] for k in golden if k.endswith(".bytes")}
    assert have == set(COMPRESSION_PRESETS), (
        f"golden matrix covers {sorted(have)} but the registry ships "
        f"{sorted(COMPRESSION_PRESETS)} — regenerate tests/golden")


@pytest.mark.parametrize("preset", sorted(COMPRESSION_PRESETS))
def test_wire_bytes_match_golden(preset, golden, current):
    rows, dtype, slots = current[preset]
    want = golden[f"{preset}.bytes"]
    assert str(golden[f"{preset}.dtype"]) == dtype, (
        f"{preset}: wire dtype changed to {dtype}")
    assert int(golden[f"{preset}.slots"]) == slots, (
        f"{preset}: wire_slots changed to {slots}")
    assert rows.shape == want.shape, (
        f"{preset}: wire buffer is now {rows.shape[1]} bytes/rank "
        f"(golden: {want.shape[1]})")
    if not np.array_equal(rows, want):
        bad = int(np.sum(rows != want))
        pytest.fail(f"{preset}: wire bytes drifted ({bad}/{want.size} bytes "
                    "differ) — if intentional, regen tests/golden")
