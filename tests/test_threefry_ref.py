"""Bit-exactness of the Threefry-2x32 reimplementation the fused wire
kernels inline (repro.kernels.threefry.ref) against JAX's own PRNG.

The golden wire bytes pin ``jax.random.uniform`` support draws, so any
drift here silently changes the wire format — every check is exact
uint32/float32 equality, never allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.threefry import ref as tref

SEEDS = (0, 1, 7, 123456789, 2**31 - 1)
# odd and even lengths, tiny through multi-block, around the half split
LENGTHS = (1, 2, 3, 31, 32, 33, 255, 256, 1000, 1001, 4096, 5000)


def _raw(seed):
    return jax.random.key_data(jax.random.PRNGKey(seed))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("d", LENGTHS)
def test_random_bits_bit_exact(seed, d):
    key = jax.random.PRNGKey(seed)
    want = jax.random.bits(key, (d,), jnp.uint32)
    got = tref.random_bits(_raw(seed), d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("d", LENGTHS)
def test_uniform_bit_exact(seed, d):
    key = jax.random.PRNGKey(seed)
    want = jax.random.uniform(key, (d,), jnp.float32)
    got = tref.uniform(_raw(seed), d)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", (0, 42))
def test_uniform_after_fold_in(seed):
    """The wire paths always draw from fold_in(key, rank) — the folded raw
    key words must reproduce the same stream."""
    for rank in (0, 1, 5):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), rank)
        want = jax.random.uniform(key, (777,), jnp.float32)
        got = tref.uniform(jax.random.key_data(key), 777)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", SEEDS[:3])
@pytest.mark.parametrize("d", (1, 31, 32, 33, 1000))
def test_counter_words_match_flat_layout(seed, d):
    """counter_words(idx, d) evaluated at scattered idx must reproduce the
    exact per-coordinate bits of the flat (d,) draw — this is the identity
    the in-kernel blocks rely on."""
    key = _raw(seed)
    flat = tref.random_bits(key, d)
    idx = jnp.asarray(
        np.random.default_rng(seed).permutation(d).astype(np.uint32))
    c0, c1, lo = tref.counter_words(idx, d)
    o0, o1 = tref.threefry2x32(key[0], key[1], c0, c1)
    got = jnp.where(lo, o0, o1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(flat[idx]))


@pytest.mark.parametrize("seed", SEEDS[:3])
@pytest.mark.parametrize("d", (1, 31, 32, 33, 1000))
def test_uniform_at_random_access_bit_exact(seed, d):
    """uniform_at(key, idx, d) — the random-access draw the reduce-scatter
    Bernoulli decode regenerates shard supports from (DESIGN.md §11) —
    must equal the flat (d,) uniform at those indices, bit for bit."""
    key = jax.random.PRNGKey(seed)
    flat = jax.random.uniform(key, (d,), jnp.float32)
    idx = jnp.asarray(
        np.random.default_rng(seed).permutation(d).astype(np.int32))
    got = tref.uniform_at(key, idx, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(flat)[idx])


def test_bits_to_uniform_edge_values():
    """All-ones bits stay < 1; all-zero bits clamp at exactly 0."""
    u = tref.bits_to_uniform(jnp.array([0, 0xFFFFFFFF, 1 << 9], jnp.uint32))
    vals = np.asarray(u)
    assert vals[0] == 0.0
    assert 0.0 < vals[2] < vals[1] < 1.0
