"""Straggler mitigation / failure-drop path (subprocess, 8 fake devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_fault_tolerance():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" /
                             "fault_tolerance_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "FAULT TOLERANCE CHECK PASSED" in res.stdout
