"""Straggler mitigation / failure-drop path (subprocess, 8 fake devices)
plus meshless units for the FailurePlan draw and partial_mean's contract."""
import functools
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed.fault_tolerance import (FailurePlan, partial_mean,
                                               survivor_index)

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_fault_tolerance():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" /
                             "fault_tolerance_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "FAULT TOLERANCE CHECK PASSED" in res.stdout


def test_survivor_index_tie_rule():
    # THE explicit contract: smallest index among the maxima.
    assert int(survivor_index(jnp.asarray([1.0, 3.0, 3.0, 2.0]))) == 1
    assert int(survivor_index(jnp.asarray([3.0, 1.0, 3.0, 3.0]))) == 0
    assert int(survivor_index(jnp.zeros((5,)))) == 0  # all tied -> first
    assert int(survivor_index(jnp.asarray([-1.0, -1.0, -2.0]))) == 0
    assert int(survivor_index(jnp.asarray([0.0, 0.0, 7.0]))) == 2


def test_survivor_index_properties():
    # bit-compatible with the historical bare argmax on tie-free draws,
    # always a maximum, stable under appending smaller values.
    for seed in range(25):
        u = jax.random.uniform(jax.random.PRNGKey(seed), (8,))
        i = int(survivor_index(u))
        assert i == int(jnp.argmax(u))
        assert float(u[i]) == float(jnp.max(u))
        longer = jnp.concatenate([u, u - 1.0])
        assert int(survivor_index(longer)) == i


def test_drop_mask_matches_alive_mask_grid():
    # drop_mask is alive_mask in traced-operand f32 form — one draw,
    # two consumers — across a rates x steps x sizes grid, survivor
    # clamp included at rate 1.0.
    for rate in (0.0, 0.25, 0.5, 0.9, 1.0):
        plan = FailurePlan(rate=rate, seed=7)
        for step in (0, 1, 5, 17):
            for n in (2, 8):
                dm = np.asarray(plan.drop_mask(step, n))
                am = np.asarray(plan.alive_mask(step, n))
                assert dm.dtype == np.float32
                assert np.array_equal(dm, am.astype(np.float32))
                assert dm.sum() >= 1  # never-kill-everyone
                if rate == 0.0:
                    assert dm.sum() == n
                if rate == 1.0:
                    # exactly the survivor_index node lives
                    key = jax.random.fold_in(
                        jax.random.PRNGKey(plan.seed), step)
                    u = jax.random.uniform(key, (n,))
                    want = np.zeros((n,), np.float32)
                    want[int(survivor_index(u))] = 1.0
                    assert np.array_equal(dm, want)


def test_failure_plan_edge_rates():
    # rate 0.0: everyone lives; rate 1.0: exactly the one argmax survivor.
    for step in range(10):
        assert np.asarray(FailurePlan(rate=0.0, seed=3)
                          .alive_mask(step, 8)).all()
        assert np.asarray(FailurePlan(rate=1.0, seed=3)
                          .alive_mask(step, 8)).sum() == 1


def test_failure_plan_views_share_one_draw():
    # local_alive indexes the SAME draw alive_mask returns — meshless
    # equivalence via the rank the (trivial) 1-device axis reports.
    plan = FailurePlan(rate=0.5, seed=9)
    mesh = jax.make_mesh((1,), ("data",))
    for step in range(6):

        @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_vma=False)
        def local(x):
            del x
            return plan.local_alive(step, ("data",))

        want = float(np.asarray(plan.alive_mask(step, 1))[0])
        assert float(jax.jit(local)(jnp.zeros(()))) == want


def _pmean_1dev(x, alive):
    mesh = jax.make_mesh((1,), ("data",))

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P(None), P()),
                       out_specs=P(), check_vma=False)
    def f(x, alive):
        return partial_mean(x * alive, alive, ("data",))

    return np.asarray(jax.jit(f)(x, alive))


def test_partial_mean_all_dead_is_nan():
    # 0/0 by contract: no clamp, no silent all-zero step.
    out = _pmean_1dev(jnp.ones((4,), jnp.float32), jnp.float32(0.0))
    assert np.isnan(out).all()


def test_partial_mean_single_survivor_is_exact():
    x = jnp.asarray([1.5, -2.0, 0.25, 3.0], jnp.float32)
    out = _pmean_1dev(x, jnp.float32(1.0))
    assert np.array_equal(out, np.asarray(x))
