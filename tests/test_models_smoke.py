"""Per-architecture smoke tests: reduced config, one-device mesh, real
train steps (forward+backward+optimizer, with the compressed-mean path
exercised on the degenerate axes) and prefill+decode — asserting shapes and
finiteness.  The FULL configs are exercised only via the dry-run."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig, ShapeSpec
from repro.configs.registry import list_archs, smoke_config
from repro.core import types as core_types
from repro.data.pipeline import SyntheticLM
from repro.serving import engine
from repro.train import train_step as ts

SMOKE_TRAIN = ShapeSpec("smoke_train", "train", 32, 4)
SMOKE_DECODE = ShapeSpec("smoke_decode", "decode", 32, 4)


@functools.lru_cache(maxsize=1)
def smoke_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def smoke_run(arch: str, compress: bool = False) -> RunConfig:
    comp = core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="fixed_k", fraction=0.25),
        mode="shared_support", axes=("data",), min_compress_size=0,
    ) if compress else core_types.CompressionConfig(mode="none")
    return RunConfig(microbatches=1, fsdp=False,
                     model_parallel=arch != "mamba2-130m",
                     seq_shard=False, attn_chunk_q=16, attn_chunk_k=16,
                     remat=True, compression=comp)


def _steps(arch, compress=False, n=2):
    cfg = smoke_config(arch)
    mesh = smoke_mesh()
    run = smoke_run(arch, compress)
    step_fn, init_fn, specs, bspecs, _ = ts.build_train_step(
        mesh, cfg, run, SMOKE_TRAIN)
    params, opt_state, ef = init_fn(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, SMOKE_TRAIN)
    losses = []
    for i in range(n):
        batch = data.device_batch(i, mesh, bspecs)
        params, opt_state, ef, metrics = step_fn(params, opt_state, ef,
                                                 batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    return params, losses


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    params, losses = _steps(arch)
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[0] > 0
    for p in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(p)))


@pytest.mark.parametrize("arch", ["qwen3-4b", "qwen2-moe-a2.7b", "mamba2-130m"])
def test_train_step_smoke_compressed(arch):
    _, losses = _steps(arch, compress=True)
    assert all(np.isfinite(l) for l in losses), losses


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    mesh = smoke_mesh()
    run = smoke_run(arch)
    prefill_fn, decode_fn, specs, info = engine.build_serve_fns(
        mesh, cfg, run, SMOKE_DECODE)
    # init params via the train builder (same specs)
    _, init_fn, _, _, _ = ts.build_train_step(mesh, cfg, run, SMOKE_TRAIN)
    params, _, _ = init_fn(jax.random.PRNGKey(0))

    data = SyntheticLM(cfg, ShapeSpec("p", "train", 16, 4))
    host = data.host_batch(0)
    batch = {"tokens": jnp.asarray(host["tokens"])}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(host["patches"])
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(host["frames"])

    cache, logits = prefill_fn(params, batch)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    toks = []
    for i in range(3):
        tok, cache = decode_fn(params, cache, tok, jnp.int32(16 + i))
        toks.append(np.asarray(tok))
    toks = np.concatenate(toks, axis=1)
    assert toks.shape == (4, 3)
    assert (toks >= 0).all() and (toks < cfg.vocab_padded(1)).all()
