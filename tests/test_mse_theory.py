"""Empirical MSE of each encoder == the paper's closed forms.

These are the strongest paper-faithfulness checks: Lemma 3.2, Lemma 3.4,
Example 4's exact MSE and [10]-bound, the (corrected) Lemma 7.2, and our
shared-support variant's closed form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane, comm_cost, decoders, encoders, mse

KEY = jax.random.PRNGKey(0)
N, D = 8, 64
XS = jax.random.normal(jax.random.PRNGKey(42), (N, D))
MUS = jnp.mean(XS, axis=-1)
X_TRUE = jnp.mean(XS, axis=0)


def _mc_mse(sample_y, trials=6000):
    """Monte-Carlo E||Y − X||² with Y = averaging_decoder(sample_y(key))."""
    def one(k):
        err = decoders.averaging_decoder(sample_y(k)) - X_TRUE
        return jnp.sum(err * err)
    errs = jax.lax.map(jax.jit(one), jax.random.split(KEY, trials))
    return float(jnp.mean(errs)), float(jnp.std(errs) / np.sqrt(trials))


def _node_keys(k):
    return jax.vmap(lambda i: jax.random.fold_in(k, i))(jnp.arange(N))


@pytest.mark.parametrize("p", [0.25, 0.5, 0.9])
def test_bernoulli_matches_lemma32(p):
    def sample(k):
        ks = _node_keys(k)
        return jax.vmap(lambda kk, x, m: encoders.encode_bernoulli(kk, x, p, m).y)(
            ks, XS, MUS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_bernoulli(XS, p, MUS))
    assert abs(got - want) < max(5 * se, 0.02 * want), (got, want, se)


def test_bernoulli_nonuniform_probs_lemma32():
    probs = jax.random.uniform(jax.random.PRNGKey(3), (N, D), minval=0.2, maxval=1.0)

    def sample(k):
        ks = _node_keys(k)
        return jax.vmap(lambda kk, x, pp, m: encoders.encode_bernoulli(kk, x, pp, m).y)(
            ks, XS, probs, MUS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_bernoulli(XS, probs, MUS))
    assert abs(got - want) < max(5 * se, 0.02 * want), (got, want, se)


@pytest.mark.parametrize("k", [8, 16, 32])
def test_fixed_k_matches_lemma34(k):
    def sample(kk):
        ks = _node_keys(kk)
        return jax.vmap(lambda k1, x, m: encoders.encode_fixed_k(k1, x, k, m).y)(
            ks, XS, MUS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_fixed_k(XS, k, MUS))
    assert abs(got - want) < max(5 * se, 0.03 * want), (got, want, se)


def test_fixed_k_shared_support_closed_form():
    """Our TPU-native variant: all nodes share one support (DESIGN.md §2)."""
    k = 16

    def sample(kk):
        return jax.vmap(lambda x, m: encoders.encode_fixed_k(kk, x, k, m).y)(XS, MUS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_fixed_k_shared(XS, k, MUS))
    assert abs(got - want) < max(5 * se, 0.03 * want), (got, want, se)


def test_binary_matches_example4():
    def sample(k):
        ks = _node_keys(k)
        return jax.vmap(lambda kk, x: encoders.encode_binary(kk, x).y)(ks, XS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_binary(XS))
    assert abs(got - want) < max(5 * se, 0.02 * want), (got, want, se)
    # and the Example 4 / [10, Thm 1] bound dominates it:
    assert want <= float(mse.mse_binary_bound(XS)) + 1e-6


def test_ternary_matches_empirical():
    """Corrected Lemma 7.2 (see mse.mse_ternary docstring)."""
    p1 = p2 = 0.3
    c1s = jnp.min(XS, axis=-1)
    c2s = jnp.max(XS, axis=-1)

    def sample(k):
        ks = _node_keys(k)
        return jax.vmap(
            lambda kk, x, c1, c2: encoders.encode_ternary(kk, x, p1, p2, c1, c2).y)(
            ks, XS, c1s, c2s)
    got, se = _mc_mse(sample)
    want = float(mse.mse_ternary(XS, p1, p2, c1s, c2s))
    assert abs(got - want) < max(5 * se, 0.03 * want), (got, want, se)


def test_ternary_printed_lemma72_fails_sanity():
    """Documents the paper's typo: printed third term (p'c1+p''c2)² gives a
    nonzero 'MSE' for a provably lossless configuration."""
    xs = jnp.full((1, 4), 3.0)
    c1 = jnp.array([3.0])  # X == c1, p'' = 0: encoder is lossless
    c2 = jnp.array([5.0])
    printed = float(jnp.sum(0.5 * (xs - c1[:, None]) ** 2 + 0.0
                            + (0.5 * c1[:, None] + 0.0 * c2[:, None]) ** 2))
    assert printed > 0  # the printed formula is wrong here…
    corrected = float(mse.mse_ternary(xs, 0.5, 0.0, c1, c2))
    assert corrected == pytest.approx(0.0, abs=1e-9)  # …ours is exact.


def test_binary_wire_path_matches_example4():
    """The packed 1-bit-plane *wire path* (pack → gather → unpack →
    average, repro.core.bitplane) has Example 4's exact MSE and respects
    the [10, Thm 1] bound — not just the dense encoder."""
    def sample(k):
        ks = _node_keys(k)

        def one(kk, x):
            buf = bitplane.binary_pack(x, kk, "float32")
            return bitplane.binary_unpack(buf, D, "float32")
        return jax.vmap(one)(ks, XS)
    got, se = _mc_mse(sample)
    want = float(mse.mse_binary(XS))
    assert abs(got - want) < max(5 * se, 0.02 * want), (got, want, se)
    assert got <= float(mse.mse_binary_bound(XS)) * 1.05


def test_ternary_wire_path_matches_eq21():
    """The packed 2-bit-plane wire path has the (corrected) Lemma 7.2 MSE
    of Eq. (21) with c1/c2 = per-node min/max, p1 = p2 = (1 − p_pass)/2."""
    p_pass = 0.25
    half = (1.0 - p_pass) / 2.0
    cap = comm_cost.bernoulli_capacity(D, p_pass)

    def sample(k):
        ks = _node_keys(k)

        def one(kk, x):
            buf = bitplane.ternary_pack(x, kk, p_pass, cap, "float32")
            return bitplane.ternary_unpack(buf, D, cap, "float32")
        return jax.vmap(one)(ks, XS)
    got, se = _mc_mse(sample)
    c1s = jnp.min(XS, axis=-1)
    c2s = jnp.max(XS, axis=-1)
    want = float(mse.mse_ternary(XS, half, half, c1s, c2s))
    assert abs(got - want) < max(5 * se, 0.03 * want), (got, want, se)


def test_table1_mse_columns():
    """Table 1: MSE at p ∈ {1, 1/log d, 1/r, 1/d} equals (1/p − 1)·R/n."""
    r_bits = 16
    R = float(mse.r_factor(XS, MUS))
    for p in [1.0, 1.0 / np.log(D), 1.0 / r_bits, 1.0 / D]:
        want = (1.0 / p - 1.0) * R / N
        got = float(mse.mse_bernoulli(XS, p, MUS))
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_fixed_k_equals_bernoulli_at_p_eq_kd():
    """§3.2: fixed-k MSE == variable-support MSE at p = k/d."""
    k = 16
    got_fixed = float(mse.mse_fixed_k(XS, k, MUS))
    got_bern = float(mse.mse_bernoulli(XS, k / D, MUS))
    np.testing.assert_allclose(got_fixed, got_bern, rtol=1e-5)
