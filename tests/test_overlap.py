"""Overlapped bucket sync: fast plan/readiness invariants (single device)
plus the multi-device subprocess check (distributed_checks/overlap_check.py,
which proves overlapped == post-backward bit-for-bit per preset and that
the per-bucket collectives interleave with backward at the HLO level)."""
import functools
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import types as core_types
from repro.train import bucketing

ROOT = pathlib.Path(__file__).resolve().parent.parent

SHAPES = {f"w_{i:02d}": (64, 64) for i in range(6)}
SHAPES.update({f"b_{i:02d}": (64,) for i in range(6)})
SPECS = {n: (None,) * len(s) for n, s in SHAPES.items()}
CMP = core_types.CompressionConfig(
    encoder=core_types.EncoderSpec(kind="fixed_k", fraction=0.25),
    mode="shared_support", axes=("data",), min_compress_size=1024,
    bucket=core_types.BucketSpec(capacity=2 * 64 * 64))


def test_readiness_schedule_orders_backward():
    """ready = backward index of the bucket's last-produced leaf; the
    schedule issues latest-sorted (earliest-backward) buckets first."""
    plan = bucketing.build_plan(SHAPES, SPECS, ("data",), {"data": 8}, CMP)
    n_leaves = len(SHAPES)
    names = sorted(SHAPES)
    for b in plan.buckets:
        want = max(n_leaves - 1 - names.index(s.name) for s in b.slots)
        assert b.ready == want, b.bid
    sched = plan.schedule()
    assert sorted(sched) == sorted(b.bid for b in plan.buckets)
    readiness = {b.bid: b.ready for b in plan.buckets}
    assert [readiness[bid] for bid in sched] == sorted(readiness.values())
    # the last weight pair has the smallest backward index -> issued first
    first = next(b for b in plan.buckets if b.bid == sched[0])
    assert any(s.name == "w_05" for s in first.slots)


def test_overlap_identity_on_one_device():
    """1-device mesh, mode none: differentiating through the sync points
    returns the unsynced grads exactly (identity collective)."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    cmp = core_types.CompressionConfig(
        mode="none", bucket=core_types.BucketSpec(capacity=1 << 12))
    shapes = {"a": (32, 8), "b": (256,)}
    specs = {n: (None,) * len(s) for n, s in shapes.items()}
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": 1}, cmp)
    params = {n: jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(0),
                                                      i), s)
              for i, (n, s) in enumerate(sorted(shapes.items()))}
    pspec = {n: P() for n in shapes}

    def loss(p):
        return jnp.sum(p["a"]) + jnp.sum(jnp.sin(p["b"]))

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(pspec, P()),
                       out_specs=(pspec, pspec), check_vma=False)
    def grads_both(p, key):
        g_ref = jax.grad(loss)(p)
        g_ovl = jax.grad(
            lambda q: loss(bucketing.overlap_params(q, plan, cmp, key)))(p)
        return g_ref, g_ovl

    g_ref, g_ovl = jax.jit(grads_both)(params, jax.random.PRNGKey(1))
    for n in shapes:
        np.testing.assert_array_equal(np.asarray(g_ref[n]),
                                      np.asarray(g_ovl[n]), err_msg=n)


@pytest.mark.distributed
def test_overlap_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" / "overlap_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OVERLAP CHECKS PASSED" in res.stdout
