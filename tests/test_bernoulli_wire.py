"""Single-device tests of the §4.4 seed-trick Bernoulli wire path:
capacity sizing, pack→unpack round trip against the reference encoder, and
the capacity-padded bit accounting (comm_cost.cost_sparse_seed_capacity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives, comm_cost, encoders, types

D = 4096
P_FRAC = 0.25  # exactly representable in f32 -> bit-exact scaling math


def _x(seed=0, d=D):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.7


# --------------------------------------------------------------------------- #
# Capacity.
# --------------------------------------------------------------------------- #

def test_capacity_bounds_and_monotonicity():
    for d in (64, 1024, 1 << 20):
        prev = 0
        for p in (0.01, 0.05, 0.25, 0.5, 1.0):
            cap = comm_cost.bernoulli_capacity(d, p)
            assert p * d <= cap <= d, (d, p, cap)
            assert cap >= prev  # monotone in p at fixed slack
            prev = cap
        assert comm_cost.bernoulli_capacity(d, 1.0) == d  # p=1: zero variance


def test_capacity_rejects_bad_p():
    with pytest.raises(ValueError):
        comm_cost.bernoulli_capacity(D, 0.0)
    with pytest.raises(ValueError):
        comm_cost.bernoulli_capacity(D, 1.5)


def test_capacity_covers_realized_support():
    """cap at 6σ slack must exceed the realized |S_i| for many keys."""
    cap = comm_cost.bernoulli_capacity(D, P_FRAC)
    x = _x()
    mu = jnp.mean(x)
    nsents = []
    for s in range(200):
        enc = encoders.encode_bernoulli(jax.random.PRNGKey(s), x, P_FRAC, mu)
        nsents.append(int(enc.nsent))
    assert max(nsents) <= cap
    # ... while staying within the documented slack of the expectation
    assert cap - P_FRAC * D <= 6 * np.sqrt(D * P_FRAC * (1 - P_FRAC)) + 1


# --------------------------------------------------------------------------- #
# Pack / unpack round trip.
# --------------------------------------------------------------------------- #

def test_pack_unpack_matches_reference_encoder():
    """Wire-path reconstruction == dense Eq. (1) encoder output, per key."""
    x = _x().astype(jnp.float32)
    mu = jnp.mean(x)
    cap = comm_cost.bernoulli_capacity(D, P_FRAC)
    for s in range(5):
        key = jax.random.PRNGKey(100 + s)
        buf = collectives.bernoulli_pack(x, key, P_FRAC, cap, mu)
        y = collectives.bernoulli_unpack(buf, key, P_FRAC, cap, mu, D)
        enc = encoders.encode_bernoulli(key, x, P_FRAC, mu)
        np.testing.assert_allclose(np.asarray(y), np.asarray(enc.y),
                                   rtol=1e-6, atol=1e-6)


def test_overflow_drops_symmetrically():
    """cap < |S_i|: both sides treat overflow ranks as unsent (-> μ)."""
    x = _x(1).astype(jnp.float32)
    mu = jnp.mean(x)
    key = jax.random.PRNGKey(7)
    cap = 16  # far below E[|S|] = 1024: massive forced overflow
    buf = collectives.bernoulli_pack(x, key, P_FRAC, cap, mu)
    y = collectives.bernoulli_unpack(buf, key, P_FRAC, cap, mu, D)
    enc = encoders.encode_bernoulli(key, x, P_FRAC, mu)
    sent = np.asarray(enc.support)
    pos = np.cumsum(sent) - 1
    kept = sent & (pos < cap)
    np.testing.assert_allclose(np.asarray(y)[kept],
                               np.asarray(enc.y)[kept], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y)[~kept], float(mu), rtol=1e-6)
    assert int(kept.sum()) == cap  # buffer fully used before dropping


# --------------------------------------------------------------------------- #
# Bit accounting.
# --------------------------------------------------------------------------- #

def test_capacity_cost_bounds_eq10():
    """Eq. (10) ≤ capacity cost ≤ Eq. (10) + n·r·(6σ + 1): the price of
    static shapes is exactly the slack, never more."""
    spec = types.CommSpec(protocol="sparse_seed")
    for n in (1, 8, 64):
        for p in (0.05, 0.25, 0.9):
            cap = comm_cost.bernoulli_capacity(D, p)
            c_cap = comm_cost.cost(spec, n=n, d=D, cap=cap)
            c_p = comm_cost.cost(spec, n=n, d=D, p=p)
            sigma = np.sqrt(D * p * (1 - p))
            assert c_p <= c_cap <= c_p + n * spec.r_bits * (6 * sigma + 1) + 1e-6


def test_capacity_cost_below_naive():
    """The whole point: sub-naive wire at p < 1 (§4.1 vs §4.4)."""
    spec = types.CommSpec(protocol="sparse_seed")
    cap = comm_cost.bernoulli_capacity(D, 1 / 16)
    assert (comm_cost.cost_sparse_seed_capacity(8, cap, spec)
            < 0.25 * comm_cost.cost_naive(8, D, spec))
