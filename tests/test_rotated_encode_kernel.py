"""Oracle-equivalence for the fused §7.2 rotate+encode kernel pair
(repro.kernels.rotated_encode) and consistency of the fused dispatch with
the CPU production chain.

Kernel ↔ oracle is EXACT (interpret mode): the oracle deliberately uses the
same Kronecker-factorized FWHT as the TPU hadamard kernel.  Fused ↔ CPU
production (butterfly FWHT) agrees on every plane bit and allclose on the
(vmin, vmax) tail — the two FWHT formulations differ by f32 rounding, which
moves the bracket scalars by an ulp but (empirically and by the ~2⁻²⁴
threshold-crossing probability) not the stochastic bits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane, rotation
from repro.kernels.hadamard import ops as hops
from repro.kernels.rotated_encode import kernel, ops, ref


def _setup(seed, dp):
    c = min(dp, hops.MAX_D)
    d1, d2 = hops._factorize(c)
    scale = float(np.sqrt(np.float32(c)))
    key = jax.random.PRNGKey(seed)
    krot = rotation.rotation_key(key)
    x = jax.random.normal(jax.random.PRNGKey(seed + 50), (dp,), jnp.float32)
    signs = rotation.rademacher_diag(krot, dp, jnp.float32)
    return key, x.reshape(-1, c), signs.reshape(-1, c), d1, d2, scale


@pytest.mark.parametrize("seed", (0, 5))
@pytest.mark.parametrize("dp", (256, 1024, 4096))
def test_rotate_minmax_kernel_exact(seed, dp):
    key, x2, s2, d1, d2, scale = _setup(seed, dp)
    z_r, mn_r, mx_r = ref.rotate_minmax(x2, s2, d1=d1, d2=d2, scale=scale)
    z_k, mm = kernel.rotate_minmax_pallas(x2, s2, d1=d1, d2=d2, scale=scale,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
    np.testing.assert_array_equal(np.asarray(mm[:, 0]), np.asarray(mn_r))
    np.testing.assert_array_equal(np.asarray(mm[:, 1]), np.asarray(mx_r))


@pytest.mark.parametrize("seed", (0, 5))
@pytest.mark.parametrize("dp", (256, 1024, 4096))
def test_encode_pack_kernel_exact(seed, dp):
    key, x2, s2, d1, d2, scale = _setup(seed, dp)
    z, mn, mx = ref.rotate_minmax(x2, s2, d1=d1, d2=d2, scale=scale)
    z = z.reshape(-1)
    vmin, vmax = jnp.min(mn), jnp.max(mx)
    kenc = jax.random.fold_in(key, 2)
    want = ref.binary_plane(z, kenc, vmin, vmax, dp)
    got = kernel.encode_pack_pallas(z, kenc, vmin, vmax, dp=dp,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_encode_pack_degenerate_delta_zero():
    """Constant z ⇒ Δ = 0 ⇒ p = 0 everywhere ⇒ an all-zero plane (the
    guarded-threshold branch of encode_binary)."""
    dp = 512
    z = jnp.full((dp,), 0.25, jnp.float32)
    got = kernel.encode_pack_pallas(z, jax.random.PRNGKey(0),
                                    jnp.float32(0.25), jnp.float32(0.25),
                                    dp=dp, interpret=True)
    assert not np.asarray(got).any()


@pytest.mark.parametrize("seed", (0, 3))
@pytest.mark.parametrize("d", (300, 1000, 4096, 5000))
@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16"))
def test_fused_pack_binary_consistent_with_production(seed, d, wire_dtype):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 9), (d,), jnp.float32)
    prod = ops.pack_binary(x, key, 1, wire_dtype)
    fused = ops.pack_binary(x, key, 1, wire_dtype, force_pallas=True)
    assert prod.shape == fused.shape and prod.dtype == fused.dtype
    dp = rotation.padded_dim(d)
    nplane = -(-dp // 32)
    # every stochastic plane bit identical; only the tail scalars may move
    np.testing.assert_array_equal(np.asarray(fused[:nplane]),
                                  np.asarray(prod[:nplane]))
    r1 = np.asarray(bitplane.binary_unpack(prod, dp, wire_dtype))
    r2 = np.asarray(bitplane.binary_unpack(fused, dp, wire_dtype))
    np.testing.assert_allclose(r2, r1, rtol=1e-5, atol=1e-6)


def test_small_dp_uses_production_chain_verbatim():
    """dp < 256 (degenerate MXU tiles) must fall back to the exact CPU
    chain even under force_pallas."""
    d = 100
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (d,), jnp.float32)
    a = ops.pack_binary(x, key, 0, "float32")
    b = ops.pack_binary(x, key, 0, "float32", force_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
