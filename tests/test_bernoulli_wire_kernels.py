"""Oracle-equivalence + roundtrip properties for the fused §4.4 Bernoulli
wire kernels (repro.kernels.bernoulli_wire), mirroring tests/test_bitplane.py.

Three layers of pinning:

* ``rank_select`` (the searchsorted gather that replaced the historical
  d-wide scatter) is byte-identical to that scatter — tested against an
  inline reimplementation of the old op chain;
* the Pallas encode/decode kernels in interpret mode equal their jnp
  oracles — EXACTLY when 1/p is a power of two (every shipped preset;
  x·(1/p) is then exact so XLA's FMA contraction of the rescale is a
  no-op) or when ``scaled=False`` (EF twin, no rescale).  For arbitrary
  scaled p the rescale's contraction is fusion-context-dependent, so the
  contract weakens to exact fill structure + allclose values (see
  kernels/bernoulli_wire/kernel.py);
* the full codec roundtrip (pack → 16-bit bfloat16 packed-halves wire →
  decode) stays consistent between the batched decode and the sequential
  unpack chain, including the cap-overflow drop path.

The deterministic parametrized sweeps below always run (they are what the
CI kernel-interpret job exercises); the hypothesis layer widens the input
space when hypothesis is installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, types as t
from repro.core.wire import codecs
from repro.kernels.bernoulli_wire import ops, ref

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # optional dep — the parametrized sweeps still pin
    HAS_HYPOTHESIS = False

# edge lengths: scalar, around the Threefry half split, around the kernel's
# (8, 128) = 1024-coordinate block, and a generic non-round size
DIMS = (1, 31, 33, 1000, 1023, 1024, 1025, 4096, 5000)
# 1/p power of two (exact contract) — every production preset is 1/16
P_POW2 = (0.5, 0.0625)
P_ANY = (0.3, 0.9)


def _flat(seed, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.7


def _key(seed, rank=0):
    return jax.random.fold_in(jax.random.PRNGKey(seed), rank)


def _legacy_scatter(values, sent, cap):
    """The historical core.bitplane.rank_scatter op chain, verbatim."""
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    slot = jnp.where(sent & (pos < cap), pos, cap)
    return jnp.zeros((cap,), jnp.float32).at[slot].set(
        values.astype(jnp.float32), mode="drop")


# --------------------------------------------------------------------------- #
# rank_select == legacy scatter, byte for byte.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("d", DIMS)
@pytest.mark.parametrize("cap_frac", (0.1, 1.0, 2.0))
def test_rank_select_equals_legacy_scatter(d, cap_frac):
    vals = _flat(d, d)
    sent = jax.random.uniform(_key(d + 1), (d,)) < 0.3
    cap = max(1, int(d * cap_frac))
    got = ref.rank_select(vals.astype(jnp.float32), sent, cap)
    want = _legacy_scatter(vals, sent, cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# Encode kernel vs oracle (interpret mode).
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("d", DIMS)
@pytest.mark.parametrize("p", P_POW2)
@pytest.mark.parametrize("scaled", (True, False))
def test_encode_kernel_exact_for_pow2_inv_p(d, p, scaled):
    cap = comm_cost.bernoulli_capacity(d, p)
    flat = _flat(d, d)
    mu = jnp.mean(flat)
    want = ref.encode(flat, _key(d), p, cap, mu, scaled=scaled)
    got = ops.encode(flat, _key(d), p, cap, mu, scaled=scaled,
                     force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d", (33, 1000, 4096))
@pytest.mark.parametrize("p", P_ANY)
def test_encode_kernel_structure_and_values_any_p(d, p):
    """Arbitrary scaled p: exact fill structure (same slots populated, same
    zeros), values allclose — the FMA-contraction carve-out."""
    cap = comm_cost.bernoulli_capacity(d, p)
    flat = _flat(d, d)
    mu = jnp.mean(flat)
    want = np.asarray(ref.encode(flat, _key(d), p, cap, mu))
    got = np.asarray(ops.encode(flat, _key(d), p, cap, mu,
                                force_pallas=True))
    np.testing.assert_array_equal(got == 0.0, want == 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_encode_cap_overflow_drops_tail_ranks():
    """cap below the realized support size: the kernel and oracle drop the
    identical overflow tail, keeping exactly the first cap support ranks."""
    d, p = 2048, 0.5
    flat = _flat(3, d)
    mu = jnp.mean(flat)
    sent = np.asarray(jax.random.uniform(_key(3), (d,)) < p)
    cap = int(sent.sum()) // 2          # force overflow
    want = ref.encode(flat, _key(3), p, cap, mu)
    got = ops.encode(flat, _key(3), p, cap, mu, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every slot is filled and holds the rescale of the first cap sent
    # coordinates, in support order.
    idx = np.nonzero(sent)[0][:cap]
    vals = np.asarray(flat)[idx] / p - (1 - p) / p * float(mu)
    np.testing.assert_allclose(np.asarray(got), vals, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------- #
# Decode kernel vs oracle (interpret mode).
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("d", (1, 33, 1000, 1025, 4096))
@pytest.mark.parametrize("n", (1, 3, 8))
def test_decode_kernel_exact_vs_sequential_oracle(d, n):
    p = 0.0625 if d > 64 else 0.5
    cap = comm_cost.bernoulli_capacity(d, p)
    keys = jnp.stack([jax.random.key_data(_key(d, i)) for i in range(n)])
    mus = jnp.stack([jnp.mean(_flat(d + i, d)) for i in range(n)])
    bufs = jnp.stack([
        ref.encode(_flat(d + i, d), _key(d, i), p, cap, mus[i])
        for i in range(n)])
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = ops.decode_sum(bufs, mus, keys, p, cap, d, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the tiled production decode accumulates peers in the same linear
    # order (ref._peer_sum) — bit-exact vs the sequential oracle, not
    # merely allclose.
    batched = ref.decode_sum(bufs, mus, keys, p, cap, d)
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(want))


# --------------------------------------------------------------------------- #
# Full codec roundtrip on the 16-bit (bfloat16 packed-halves) wire.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16"))
def test_codec_roundtrip_decode_gathered_matches_unpack(wire_dtype):
    """BernoulliCodec.decode_gathered (fused batched path) must equal the
    sequential unpack→mean chain on the wire-dtype-quantized rows."""
    d, n = 1500, 4
    cfg = t.CompressionConfig(
        encoder=t.EncoderSpec(kind="bernoulli", fraction=1.0 / 16,
                              center="mean"),
        mode="gather_decode", wire_dtype=wire_dtype)
    codec = codecs.BernoulliCodec()
    key = jax.random.PRNGKey(11)
    rows = jnp.stack([codec.pack(_flat(100 + i, d), key, i, cfg)
                      for i in range(n)])
    assert rows.dtype == jnp.dtype(wire_dtype)
    want = jnp.mean(jnp.stack([codec.unpack(rows[i], i, key, cfg, d)
                               for i in range(n)]), axis=0)
    got = codec.decode_gathered(rows, key, cfg, d, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# Hypothesis layer (optional): widens the sweep when available.
# --------------------------------------------------------------------------- #

if HAS_HYPOTHESIS:
    SET = settings(max_examples=15, deadline=None)

    @SET
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 3000),
           cap_frac=st.sampled_from((0.1, 0.5, 1.0, 2.0)),
           p=st.sampled_from((0.05, 0.3, 0.9)))
    def test_hyp_rank_select_equals_legacy_scatter(seed, d, cap_frac, p):
        vals = _flat(seed, d)
        sent = jax.random.uniform(_key(seed + 1), (d,)) < p
        cap = max(1, int(d * cap_frac))
        got = ref.rank_select(vals.astype(jnp.float32), sent, cap)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(_legacy_scatter(vals, sent, cap)))

    @SET
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 5000),
           p=st.sampled_from(P_POW2), scaled=st.booleans())
    def test_hyp_encode_kernel_exact_for_pow2_inv_p(seed, d, p, scaled):
        cap = comm_cost.bernoulli_capacity(d, p)
        flat = _flat(seed, d)
        mu = jnp.mean(flat)
        want = ref.encode(flat, _key(seed), p, cap, mu, scaled=scaled)
        got = ops.encode(flat, _key(seed), p, cap, mu, scaled=scaled,
                         force_pallas=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
