"""Fused unpack+accumulate kernel for the §13 binary scatter decode.

``repro.kernels.bitplane.ops.binary_accum`` folds all n peers' 1-bit plane
windows + per-peer centers into one (d,) f32 accumulator in a single pass.
The ref.py oracle pins the peer-linear add chain (ascending-peer fori, the
exact order of the sequential flat decode); the Pallas kernel (interpret
mode here, the CI kernel-interpret job points at this file) must match it
BIT FOR BIT across word-tile padding, partial last words and peer counts.

Deterministic sweeps only — no hypothesis dependence, so the kernel job
runs the full file unconditionally.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitplane import bitplane as kern
from repro.kernels.bitplane import ops, ref

TILE = kern.BM_ACCUM * kern.LANES * 32   # coords per padded word tile


def _case(seed, n, d):
    """(words, c_lo, c_hi): arbitrary plane windows + centers."""
    k = jax.random.PRNGKey(seed)
    nw = ref.num_words(d, 1)
    words = jax.random.bits(jax.random.fold_in(k, 0), (n, nw),
                            dtype=jnp.uint32)
    # zero the pad bits of the last word: real planes come from pack_bits,
    # which zero-pads, and the shard window contract relies on it
    tail = d % 32
    if tail:
        mask = jnp.uint32((1 << tail) - 1)
        words = words.at[:, -1].set(words[:, -1] & mask)
    c_lo = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 0.5
    c_hi = c_lo + jnp.abs(jax.random.normal(jax.random.fold_in(k, 2), (n,)))
    return words, c_lo, c_hi


def _sequential(words, c_lo, c_hi, d):
    """Python-loop gold: the flat decode's acc + where(bit, hi, lo) chain."""
    acc = jnp.zeros((d,), jnp.float32)
    for i in range(words.shape[0]):
        bits = ref.unpack_bits(words[i], 1, d)
        acc = acc + jnp.where(bits > 0, c_hi[i], c_lo[i])
    return acc


# d crosses: single partial word, exact word, exact kernel tile (no pad),
# multi-tile with remainder, sub-tile with remainder.
CASES = ((1, 1), (31, 2), (32, 1), (33, 4), (1000, 3), (4103, 8),
         (TILE, 2), (TILE + 40, 4))


@pytest.mark.parametrize("d,n", CASES)
def test_ref_accum_equals_sequential(d, n):
    words, c_lo, c_hi = _case(d + n, n, d)
    want = _sequential(words, c_lo, c_hi, d)
    got = ref.binary_accum(words, c_lo, c_hi, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d,n", CASES)
def test_pallas_accum_interpret_equals_ref(d, n):
    words, c_lo, c_hi = _case(d, n, d)
    want = ref.binary_accum(words, c_lo, c_hi, d)
    got = ops.binary_accum(words, c_lo, c_hi, d, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_accum_kernel_direct_no_padding():
    """The 2D kernel entry at an exact (BM_ACCUM, LANES) word tiling —
    exercises the grid path with zero host-side padding."""
    n, r = 3, 2 * kern.BM_ACCUM
    d = r * kern.LANES * 32
    words, c_lo, c_hi = _case(5, n, d)
    c = jnp.zeros((n, kern.LANES), jnp.float32)
    c = c.at[:, 0].set(c_lo).at[:, 1].set(c_hi)
    got = kern.binary_accum_2d(words.reshape(n, r, kern.LANES), c,
                               interpret=True)
    want = ref.binary_accum(words, c_lo, c_hi, d)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1),
                                  np.asarray(want))


def test_accum_matches_unpack_centers_semantics():
    """bit=1 selects c_hi, bit=0 selects c_lo — pinned with a one-peer
    alternating plane so a swapped select cannot cancel across peers."""
    d = 64
    bits = jnp.arange(d, dtype=jnp.uint32) % 2
    words = ref.pack_bits(bits, 1).reshape(1, -1)
    got = np.asarray(ops.binary_accum(words, jnp.array([-2.0]),
                                      jnp.array([3.0]), d))
    want = np.where(np.arange(d) % 2 == 1, 3.0, -2.0).astype(np.float32)
    np.testing.assert_array_equal(got, want)
