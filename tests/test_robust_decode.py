"""Robust decode-policy properties (DESIGN.md §14), meshless.

Covers the decode-reduction hook across every registered gather preset:
policy parsing/normalization, the reduce_rows order statistics against a
numpy reference, permutation invariance, the JACM86 containment/breakdown
property, trim(0) == mean bit-for-bit, trim∘scatter_decode == flat trimmed
decode bit-for-bit across word-aligned shard windows, the masked-mean
bit-identity against a survivors-only reference, and the payload/cost
invariance of decode policies.  Mesh execution + the adversarial matrix
live in tests/distributed_checks/robust_decode_check.py.

The fuzzing section degrades to plain seeds when hypothesis isn't
installed (it is pinned in requirements-dev.txt, so CI fuzzes for real).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, mse, rotation
from repro.core import types as t
from repro.core import wire
from repro.core.wire import base as wire_base
from repro.core.wire import robust
from repro.configs.registry import COMPRESSION_PRESETS, robust_preset

ROOT = pathlib.Path(__file__).resolve().parent.parent

N, D = 8, 5000
KEY = jax.random.PRNGKey(3)


@pytest.mark.distributed
def test_robust_decode_check():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" /
                             "robust_decode_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL ROBUST DECODE CHECKS PASSED" in res.stdout

GATHER_PRESETS = sorted(
    name for name in COMPRESSION_PRESETS
    if wire.resolve(robust_preset(name, "mean", axes=("data",))).reduce
    == "all_gather")
PSUM_PRESETS = sorted(set(COMPRESSION_PRESETS) - set(GATHER_PRESETS))


def _cfg(name, policy):
    return robust_preset(name, policy, axes=("data",))


def _xs(seed=1, n=N, d=D, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d),
                             jnp.float32) * scale


def _rows(codec, cfg, xs, key=KEY):
    return jnp.stack([codec.pack(xs[i], key, i, cfg)
                      for i in range(xs.shape[0])])


# --------------------------------------------------------------------------- #
# Policy parsing.
# --------------------------------------------------------------------------- #

def test_parse_normalizes_trim0_to_mean():
    assert t.parse_decode_policy("trim(0)") == ("mean", 0)
    assert t.parse_decode_policy("mean") == ("mean", 0)


def test_parse_mean_trim0_is_not_mean():
    # mean_trim(0) is the (min+max)/2 midpoint — a different estimator.
    assert t.parse_decode_policy("mean_trim(0)") == ("mean_trim", 0)


def test_parse_policies_and_rejects():
    assert t.parse_decode_policy("trim(3)") == ("trim", 3)
    assert t.parse_decode_policy("median") == ("median", 0)
    # whitespace-tolerant by design (strip), everything else rejects.
    assert t.parse_decode_policy(" trim(1) ") == ("trim", 1)
    for bad in ("trim", "trim(-1)", "trim(1.5)", "avg", "meantrim(1)"):
        with pytest.raises(ValueError):
            t.parse_decode_policy(bad)


def test_config_validates_policy_at_construction():
    with pytest.raises(ValueError):
        dataclasses.replace(COMPRESSION_PRESETS["binary_packed"],
                            decode_policy="trimm(1)")


def test_resolve_rejects_robust_policy_on_psum_codecs():
    for name in PSUM_PRESETS:
        with pytest.raises(ValueError, match="per-peer wire rows"):
            wire.resolve(_cfg(name, "trim(1)"))
        # the normalized-to-mean spelling stays allowed.
        wire.resolve(_cfg(name, "trim(0)"))


# --------------------------------------------------------------------------- #
# reduce_rows against a numpy reference.
# --------------------------------------------------------------------------- #

def _np_reduce(stack, kind, f, keep=None):
    stack = np.asarray(stack, np.float64)
    if keep is not None:
        stack = stack[np.asarray(keep) > 0]
    s = np.sort(stack, axis=0)
    m = s.shape[0]
    if kind == "mean":
        return stack.mean(0)
    if kind == "trim":
        return s[f:m - f].mean(0)
    if kind == "median":
        return 0.5 * (s[(m - 1) // 2] + s[m // 2])
    return 0.5 * (s[f] + s[m - 1 - f])  # mean_trim


@pytest.mark.parametrize("kind,f", [("mean", 0), ("trim", 1), ("trim", 2),
                                    ("median", 0), ("mean_trim", 1),
                                    ("mean_trim", 0)])
@pytest.mark.parametrize("masked", [False, True])
def test_reduce_rows_matches_numpy(kind, f, masked):
    stack = _xs(seed=11, d=97)
    keep = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1], jnp.float32) if masked \
        else None
    got = np.asarray(robust.reduce_rows(stack, kind, f, keep))
    want = _np_reduce(stack, kind, f, keep)
    np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)


def test_reduce_rows_permutation_invariant():
    # sorting forgets peer order: order-statistic reductions are bit-exact
    # under any permutation of the stacked rows (mask permuted alongside).
    stack = _xs(seed=5, d=211)
    keep = jnp.asarray([1, 0, 1, 1, 1, 1, 0, 1], jnp.float32)
    perm = jnp.asarray([6, 2, 0, 7, 4, 1, 5, 3])
    for kind, f in (("trim", 1), ("trim", 2), ("median", 0),
                    ("mean_trim", 1)):
        a = np.asarray(robust.reduce_rows(stack, kind, f, keep))
        b = np.asarray(robust.reduce_rows(stack[perm], kind, f, keep[perm]))
        assert (a == b).all(), (kind, f)


def test_reduce_rows_undefined_is_nan():
    stack = _xs(seed=7, d=13)
    # over-trimmed: m = 2 kept ≤ 2f.
    keep = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], jnp.float32)
    assert np.isnan(np.asarray(
        robust.reduce_rows(stack, "trim", 1, keep))).all()
    # all-dead: every policy NaNs (the partial_mean 0/0 contract).
    dead = jnp.zeros((8,), jnp.float32)
    for kind, f in (("mean", 0), ("trim", 1), ("median", 0),
                    ("mean_trim", 1)):
        assert np.isnan(np.asarray(
            robust.reduce_rows(stack, kind, f, dead))).all(), kind


# --------------------------------------------------------------------------- #
# Breakdown / containment (the JACM86 f-of-n property).
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("kind", ["trim", "mean_trim"])
@pytest.mark.parametrize("attack", [np.nan, np.inf, -np.inf, 1e30, -1e30])
def test_containment_under_f_corrupt_rows(kind, attack):
    # with c ≤ f corrupt rows and m > 2f kept, every post-trim value lies
    # within the honest per-coordinate range — the estimate is contained
    # in the honest convex hull no matter what the adversary sends.
    f = 1
    honest = np.asarray(_xs(seed=13, n=N - f, d=151), np.float64)
    corrupt = np.full((f, 151), attack, np.float32)
    stack = jnp.asarray(np.concatenate([honest, corrupt]), jnp.float32)
    est = np.asarray(robust.reduce_rows(stack, kind, f))
    lo, hi = honest.min(0), honest.max(0)
    assert np.isfinite(est).all()
    assert (est >= lo - 1e-5).all() and (est <= hi + 1e-5).all()


def test_mean_has_breakdown_zero_but_trim_does_not():
    stack = np.asarray(_xs(seed=17, d=64)).copy()
    stack[0] = 1e30
    est_mean = np.asarray(robust.reduce_rows(jnp.asarray(stack), "mean", 0))
    est_trim = np.asarray(robust.reduce_rows(jnp.asarray(stack), "trim", 1))
    assert np.abs(est_mean).max() > 1e27
    assert np.abs(est_trim).max() < 1e3


# --------------------------------------------------------------------------- #
# Codec-level: the decode hook over real wire rows, every gather preset.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", GATHER_PRESETS)
def test_trim0_is_fused_mean_bit_for_bit(name):
    cfg = _cfg(name, "trim(0)")
    codec = wire.resolve(cfg)
    xs = _xs()
    rows = _rows(codec, cfg, xs)
    got = codec.decode_rows_reduce(rows, KEY, cfg, D, N)
    want = codec.decode_gathered(rows, KEY, cfg, D, N)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("name", GATHER_PRESETS)
def test_robust_decode_finite_and_close(name):
    # trim(1) over honest rows stays a sane estimator: finite everywhere
    # and within a constant factor of the plain decode's error.
    cfg = _cfg(name, "trim(1)")
    codec = wire.resolve(cfg)
    xs = _xs()
    rows = _rows(codec, cfg, xs)
    est = np.asarray(codec.decode_rows_reduce(rows, KEY, cfg, D, N))
    assert est.shape == (D,) and np.isfinite(est).all()


def _unwrap_rotated(codec):
    c = codec
    while isinstance(c, wire.EFCodec):
        c = c.inner
    if isinstance(c, wire.RotatedCodec):
        return c.inner, True
    return c, False


@pytest.mark.parametrize("name", GATHER_PRESETS)
@pytest.mark.parametrize("d", [D, 4999])  # word-aligned + non-divisible tail
@pytest.mark.parametrize("nshards", [4, 3])
def test_trim_scatter_equals_flat_bit_for_bit(name, d, nshards):
    # the §12/§13 reduce-scatter decomposition composes with trimming:
    # per-shard reductions over the word-aligned shard windows, concatenated
    # in shard order (rotated codecs shard in ROTATED space at the padded
    # length, one unrotate at the end — the gather_decode convention),
    # reproduce the flat trimmed decode bit-for-bit.
    cfg = _cfg(name, "trim(1)")
    codec = wire.resolve(cfg)
    xs = _xs(d=d)
    rows = _rows(codec, cfg, xs)
    flat = codec.decode_rows_reduce(rows, KEY, cfg, d, N)
    shard_codec, rot = _unwrap_rotated(codec)
    dsp = rotation.padded_dim(d) if rot else d
    ds = wire_base.scatter_shard_len(dsp, nshards, shard_codec.scatter_align(cfg))
    parts = [robust.reduce_rows(
        codec.decode_rows_shard(rows, KEY, cfg, dsp, N, sh * ds, ds, nshards)
        if not rot else
        shard_codec.decode_rows_shard(rows, KEY, cfg, dsp, N, sh * ds, ds,
                                      nshards),
        "trim", 1) for sh in range(nshards)]
    full = jnp.concatenate(parts)[:dsp]
    if rot:
        full = rotation.unrotate(rotation.rotation_key(KEY), full, d)
    assert (np.asarray(full) == np.asarray(flat)).all()


@pytest.mark.parametrize("name", GATHER_PRESETS)
def test_masked_mean_bit_identical_to_survivor_rerun(name):
    # excluding peers via drop_mask must equal re-running the decode with
    # only the survivors' rows — with their ORIGINAL peer indices, so the
    # seed-trick regeneration chains stay intact — bit for bit.  For
    # rotated codecs "re-running" means the production order: survivor
    # average in ROTATED space at the padded length, ONE unrotate.
    cfg = _cfg(name, "mean")
    codec = wire.resolve(cfg)
    xs = _xs(seed=23)
    rows = _rows(codec, cfg, xs)
    drop = jnp.asarray([1, 1, 1, 0, 1, 1, 1, 1], jnp.float32)
    got = np.asarray(codec.decode_rows_reduce(rows, KEY, cfg, D, N,
                                              drop_mask=drop))
    inner, rot = _unwrap_rotated(codec)
    dim = rotation.padded_dim(D) if rot else D
    stack = (inner if rot else codec).decode_rows(rows, KEY, cfg, dim, N)
    acc = jnp.zeros((dim,), jnp.float32)
    for i in range(N):
        if float(drop[i]) > 0:
            acc = acc + stack[i]
    want = acc / float(drop.sum())
    if rot:
        want = rotation.unrotate(rotation.rotation_key(KEY), want, D)
    assert (got == np.asarray(want)).all()


def test_decode_policy_never_changes_the_payload():
    # cost_config and the wire geometry are policy-blind: trimming happens
    # after the gather, on the same rows.
    for name in GATHER_PRESETS:
        base_cfg = _cfg(name, "mean")
        trim_cfg = _cfg(name, "trim(2)")
        codec = wire.resolve(base_cfg)
        assert codec is wire.resolve(trim_cfg)
        assert (comm_cost.cost_config(base_cfg, n=N, d=D)
                == comm_cost.cost_config(trim_cfg, n=N, d=D))
        assert (codec.wire_slots(D, base_cfg)
                == codec.wire_slots(D, trim_cfg))


# --------------------------------------------------------------------------- #
# mse_trimmed closed-form bounds.
# --------------------------------------------------------------------------- #

def test_mse_trimmed_f0_is_base_exactly():
    xs = _xs(seed=29, d=128)
    base = mse.mse_binary(xs)
    assert float(mse.mse_trimmed(base, xs, 0)) == float(base)


def test_mse_trimmed_rejects_overtrim():
    xs = _xs(seed=29, n=4, d=16)
    with pytest.raises(ValueError):
        mse.mse_trimmed(1.0, xs, 2)


@pytest.mark.parametrize("name,bound_fn", [
    ("bernoulli_seed_1bit",
     lambda xs, cfg, f: mse.mse_trimmed_bernoulli(
         xs, float(cfg.encoder.fraction),
         jnp.mean(xs, axis=-1), f)),
    ("binary_packed", lambda xs, cfg, f: mse.mse_trimmed_binary(xs, f)),
])
def test_trimmed_decode_error_within_closed_form_bound(name, bound_fn):
    # clean-regime empirical check of the §14 bound: the trim(1) decode's
    # mean squared error over independent rounds stays below the closed
    # form (which is deliberately loose — Cauchy–Schwarz over n terms).
    f = 1
    cfg = _cfg(name, f"trim({f})")
    codec = wire.resolve(cfg)
    xs = _xs(seed=31, d=512)
    xbar = np.asarray(xs.mean(0))
    bound = float(bound_fn(xs, cfg, f))
    errs = []
    for r in range(20):
        key = jax.random.PRNGKey(100 + r)
        rows = _rows(codec, cfg, xs, key)
        est = np.asarray(codec.decode_rows_reduce(rows, key, cfg, 512, N))
        errs.append(float(((est - xbar) ** 2).sum()))
    assert np.mean(errs) <= bound


# --------------------------------------------------------------------------- #
# Hypothesis fuzzing (skips gracefully without the package).
# --------------------------------------------------------------------------- #

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - CI always has hypothesis
    HAVE_HYP = False

if HAVE_HYP:
    SET = settings(max_examples=25, deadline=None)

    @SET
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 12),
           d=st.integers(1, 97), f=st.integers(0, 2),
           kind=st.sampled_from(["trim", "median", "mean_trim"]))
    def test_fuzz_reduce_rows_matches_numpy(seed, n, d, f, kind):
        if n <= 2 * f:
            return
        stack = jax.random.normal(jax.random.PRNGKey(seed), (n, d),
                                  jnp.float32) * 3.0
        got = np.asarray(robust.reduce_rows(stack, kind, f))
        want = _np_reduce(stack, kind, f)
        np.testing.assert_allclose(got, want, rtol=3e-6, atol=3e-6)

    @SET
    @given(seed=st.integers(0, 2**31 - 1), f=st.integers(1, 2),
           nbad=st.integers(0, 2), scale=st.floats(1e-3, 1e3))
    def test_fuzz_containment(seed, f, nbad, scale):
        if nbad > f:
            return
        n = 8
        rng = np.random.default_rng(seed)
        honest = rng.normal(size=(n - nbad, 31)) * scale
        bad = rng.choice([np.nan, np.inf, -np.inf, 1e30])
        stack = np.concatenate(
            [honest, np.full((nbad, 31), bad)]).astype(np.float32)
        est = np.asarray(robust.reduce_rows(jnp.asarray(stack), "trim", f))
        lo, hi = honest.min(0), honest.max(0)
        pad = 1e-4 * scale
        assert (est >= lo - pad).all() and (est <= hi + pad).all()
