"""§6: optimal probabilities, optimal centers, alternating minimization,
Theorem 6.1 bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import centers, mse, optimal

XS = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
MUS = jnp.mean(XS, axis=-1)


def test_optimal_probs_budget_tight():
    B = 100.0
    p = optimal.optimal_probs(XS, MUS, B)
    assert float(jnp.sum(p)) <= B * 1.001
    assert float(jnp.sum(p)) >= B * 0.995  # tight (B « |S|)
    assert float(jnp.max(p)) <= 1.0
    assert float(jnp.min(p)) >= 0.0


def test_optimal_probs_proportional_when_uncapped():
    """Ultra-low budget ⇒ p_ij = a_ij·B/W exactly (§6.1 / Thm 6.1)."""
    B = 0.5  # B ≤ 1 ⇒ no p hits the cap
    a = jnp.abs(XS - MUS[:, None])
    p = optimal.optimal_probs(XS, MUS, B)
    want = a * B / jnp.sum(a)
    np.testing.assert_allclose(p, want, rtol=1e-3, atol=1e-8)


def test_optimal_beats_uniform():
    """Optimal probabilities dominate uniform at equal budget (Fig. 1)."""
    for B in [50.0, 150.0, 300.0]:
        p_opt = optimal.optimal_probs(XS, MUS, B)
        p_uni = jnp.full(XS.shape, B / XS.size)
        m_opt = float(mse.mse_bernoulli(XS, p_opt, MUS))
        m_uni = float(mse.mse_bernoulli(XS, p_uni, MUS))
        assert m_opt <= m_uni * 1.0001, (B, m_opt, m_uni)


def test_optimal_centers_beat_mean_centers():
    """Eq. (16) centers dominate plain means for fixed probabilities."""
    p = jax.random.uniform(jax.random.PRNGKey(1), XS.shape, minval=0.1, maxval=0.9)
    mu_opt = centers.optimal_centers(XS, p)
    m_opt = float(mse.mse_bernoulli(XS, p, mu_opt))
    m_mean = float(mse.mse_bernoulli(XS, p, MUS))
    assert m_opt <= m_mean * 1.0001


def test_optimal_centers_reduce_to_mean_for_uniform_p():
    p = jnp.full(XS.shape, 0.3)
    mu_opt = centers.optimal_centers(XS, p)
    np.testing.assert_allclose(mu_opt, MUS, rtol=1e-5)


def test_alternating_minimization_monotone():
    _, _, trace = optimal.alternating_minimization(XS, B=100.0, iters=10)
    tr = np.asarray(trace)
    assert np.all(tr[1:] <= tr[:-1] * 1.0001), tr


def test_thm61_bounds_hold():
    B = 100.0
    p = optimal.optimal_probs(XS, MUS, B)
    m = float(mse.mse_bernoulli(XS, p, MUS))
    lo, hi = mse.thm61_bounds(XS, MUS, B)
    assert float(lo) - 1e-6 <= m <= float(hi) + 1e-6, (float(lo), m, float(hi))


def test_thm61_exact_low_budget():
    """Eq. (20) exact optimum in the ultra-low-communication regime."""
    a = jnp.abs(XS - MUS[:, None])
    Bmax = float(jnp.sum(a) / jnp.max(a))
    B = min(1.0, Bmax / 2)
    p = optimal.optimal_probs(XS, MUS, B)
    m = float(mse.mse_bernoulli(XS, p, MUS))
    want = float(mse.thm61_exact_low_budget(XS, MUS, B))
    np.testing.assert_allclose(m, want, rtol=5e-3)


def test_full_budget_zero_mse():
    """B ≥ |S| ⇒ p = 1 on S ⇒ MSE = 0 (§6.1)."""
    p = optimal.optimal_probs(XS, MUS, float(XS.size))
    m = float(mse.mse_bernoulli(XS, p, MUS))
    assert m == pytest.approx(0.0, abs=1e-6)


def test_per_node_budgets_remark5():
    """Remark 5: per-node optimization is feasible and never beats the
    joint optimum at equal total budget."""
    budgets = jnp.array([5.0, 10.0, 15.0, 20.0, 10.0, 10.0, 15.0, 15.0])
    p = optimal.optimal_probs_per_node(XS, MUS, budgets)
    # per-node constraints hold
    row_sums = jnp.sum(p, axis=-1)
    assert bool(jnp.all(row_sums <= budgets * 1.01)), row_sums
    m_per_node = float(mse.mse_bernoulli(XS, p, MUS))
    p_joint = optimal.optimal_probs(XS, MUS, float(jnp.sum(budgets)))
    m_joint = float(mse.mse_bernoulli(XS, p_joint, MUS))
    assert m_joint <= m_per_node * 1.0001, (m_joint, m_per_node)


def test_per_node_budgets_jit_compiles_single_trace():
    """The vmapped per-node solver jits with traced budgets (no Python
    float() per node, no O(n) retraces) and matches the per-row solver."""
    budgets = jnp.array([5.0, 10.0, 15.0, 20.0, 10.0, 10.0, 15.0, 15.0])
    traces = []

    @jax.jit
    def solve(xs, mus, budgets):
        traces.append(None)  # counts retraces
        return optimal.optimal_probs_per_node(xs, mus, budgets)

    p = solve(XS, MUS, budgets)
    # re-invoking with different traced values must hit the cache …
    p2 = solve(XS + 1.0, MUS + 1.0, budgets[::-1])
    assert len(traces) == 1 and p.shape == XS.shape and p2.shape == XS.shape
    # … and the vmap matches solving each node's §6.1 problem separately.
    for i in range(XS.shape[0]):
        want = optimal.optimal_probs(XS[i:i + 1], MUS[i:i + 1],
                                     float(budgets[i]))
        np.testing.assert_allclose(p[i], want[0], rtol=1e-6, atol=1e-8)


def test_rotation_plus_optimal_probs():
    """§7.2: rotation composes with the optimal encoder; on skewed data the
    rotated+optimal MSE beats unrotated+optimal at equal budget."""
    from repro.core import protocol, types
    xs = jax.random.normal(jax.random.PRNGKey(5), (8, 64)) * 0.1
    xs = xs.at[:, 0].add(4.0)  # skew
    est_plain = protocol.MeanEstimator(
        types.EncoderSpec(kind="bernoulli", probs="optimal", fraction=0.1),
        types.CommSpec("sparse"), budget=0.1 * xs.size)
    est_rot = protocol.MeanEstimator(
        types.EncoderSpec(kind="bernoulli", probs="optimal", fraction=0.1,
                          rotation=True),
        types.CommSpec("sparse"), budget=0.1 * xs.size)
    m_plain = float(protocol.empirical_mse(jax.random.PRNGKey(6), xs,
                                           est_plain, trials=150))
    m_rot = float(protocol.empirical_mse(jax.random.PRNGKey(7), xs,
                                         est_rot, trials=150))
    # rotation spreads the outlier coordinate; with per-coordinate optimal
    # probs both are decent, but rotation must not catastrophically hurt
    # and typically helps on this data
    assert m_rot < m_plain * 1.5, (m_rot, m_plain)


def test_ternary_optimal_probs_dominate_mid_split():
    """§6-optimal (p1, p2) for the ternary encoder: valid probabilities
    with the configured pass mass, and per-coordinate variance never above
    the default mid-split — strictly below off the midpoint
    (mse.mse_ternary is exact, so the dominance check is exact too)."""
    for seed, q in [(0, 1 / 16), (1, 0.125), (2, 0.5)]:
        xs = jax.random.normal(jax.random.PRNGKey(seed), (4, 257)) * 0.4
        xs = xs.at[:, 0].add(3.0)  # skew off the midpoint
        p1, p2 = jax.vmap(lambda x: optimal.ternary_optimal_probs(x, q))(xs)
        np.testing.assert_allclose(np.asarray(p1 + p2), 1.0 - q, rtol=1e-5)
        assert float(jnp.min(p1)) >= -1e-6 and float(jnp.min(p2)) >= -1e-6
        c1s = jnp.min(xs, axis=-1)
        c2s = jnp.max(xs, axis=-1)
        half = (1.0 - q) / 2.0
        m_opt = float(mse.mse_ternary(xs, p1, p2, c1s, c2s))
        m_mid = float(mse.mse_ternary(xs, half, half, c1s, c2s))
        assert m_opt <= m_mid * (1 + 1e-6), (q, m_opt, m_mid)
        assert m_opt < 0.95 * m_mid, (q, m_opt, m_mid)  # strict on skew


def test_ternary_optimal_probs_constant_vector_lossless():
    """Degenerate all-equal vector: any split is lossless (Y ≡ x)."""
    x = jnp.full((64,), 1.7)
    p1, p2 = optimal.ternary_optimal_probs(x, 0.25)
    m = float(mse.mse_ternary(x[None], p1[None], p2[None],
                              jnp.min(x)[None], jnp.max(x)[None]))
    assert abs(m) < 1e-10, m
