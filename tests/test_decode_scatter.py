"""Bit-exactness of the tiled + sharded Bernoulli seed decode (§12).

The production decode paths rewritten for the flat-mesh reduce-scatter
work must equal ``decode_sum_sequential`` — the peer-major fori oracle
whose accumulation order the fused kernels pin — BIT FOR BIT:

* the tiled batched ``ref.decode_sum`` (streams d-tiles through a fused
  regenerate+select+accumulate body with the matmul-cumsum rank
  arithmetic and linear-order peer adds);
* the shard decomposition: ``support_shard`` + rank-offset priors +
  ``decode_sum_shard`` per contiguous ⌈d/nshards⌉ window, shards
  concatenated — including non-divisible d/nshards remainders, where the
  tail shard is short and padding lanes must vanish;
* the Pallas shard-view kernel (interpret mode), which regenerates the
  identical Threefry lanes in-kernel.

Decode equality needs no encode: ``bufs`` are arbitrary (n, cap) value
buffers — using random buffers (not roundtripped packs) exercises every
rank/cap combination directly, including cap-overflow drops (counts past
``cap`` fall back to μ symmetrically in every path).

The deterministic sweeps always run (the CI kernel-interpret job points
here); the hypothesis layer widens the input space when installed, same
pattern as tests/test_bernoulli_wire_kernels.py.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane, comm_cost
from repro.core.wire import scatter_shard_len
from repro.kernels.bernoulli_wire import ops, ref

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # optional dep — the parametrized sweeps still pin
    HAS_HYPOTHESIS = False

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.distributed
def test_flat_scatter_check():
    """8-fake-device half: bit-exactness vs the no-scatter flat reference,
    HLO collective counts and payload-bit accounting, bucketed sync —
    tests/distributed_checks/flat_scatter_check.py."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed_checks" /
                             "flat_scatter_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, \
        f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL FLAT SCATTER CHECKS PASSED" in res.stdout


def _case(seed, n, d, cap):
    """Arbitrary (bufs, mus, keys) decode inputs — no encode involved."""
    k = jax.random.PRNGKey(seed)
    bufs = jax.random.normal(jax.random.fold_in(k, 0), (n, cap)) * 0.7
    mus = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 0.1
    keys = jnp.stack([jax.random.key_data(
        jax.random.fold_in(jax.random.PRNGKey(seed + 7), i))
        for i in range(n)])
    return bufs, mus, keys


def _shard_stitch(bufs, mus, keys, p, cap, d, nshards, force_pallas=False):
    """Concatenate the nshards shard decodes — the §12 reassembly."""
    n = bufs.shape[0]
    ds = -(-d // nshards)
    sent_all = jnp.stack([ref.support_shard(keys, p, d, s * ds, ds)
                          for s in range(nshards)])
    counts = jnp.sum(sent_all.astype(jnp.int32), axis=2)   # (nshards, n)
    prior = jnp.cumsum(counts, axis=0) - counts
    parts = [ops.decode_sum_shard(bufs, mus, keys, sent_all[s], prior[s],
                                  s * ds, p=p, cap=cap, d=d,
                                  force_pallas=force_pallas)
             for s in range(nshards)]
    return jnp.concatenate(parts)[:d]


# --------------------------------------------------------------------------- #
# tiled batched decode == sequential oracle, bit for bit.
# --------------------------------------------------------------------------- #

# d crosses the 8192-coordinate tile boundary (tiled fori path) and the
# 32-lane matmul-cumsum group, with non-round remainders throughout.
@pytest.mark.parametrize("d", (1, 33, 1000, 4103, 8192, 8200, 20000))
@pytest.mark.parametrize("n", (1, 2, 8))
@pytest.mark.parametrize("p", (0.0625, 0.5, 0.9))
def test_tiled_decode_sum_equals_sequential(d, n, p):
    cap = max(1, int(d * p * 1.1))
    bufs, mus, keys = _case(d + n, n, d, cap)
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = ref.decode_sum(bufs, mus, keys, p, cap, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiled_decode_sum_cap_overflow_drops():
    """cap far below the expected support: the overflow tail must fall
    back to μ in both paths identically (and μ must actually appear)."""
    d, n, p = 5000, 4, 0.5
    cap = 100
    bufs, mus, keys = _case(5, n, d, cap)
    want = np.asarray(ref.decode_sum_sequential(bufs, mus, keys, p, cap, d))
    got = np.asarray(ref.decode_sum(bufs, mus, keys, p, cap, d))
    np.testing.assert_array_equal(got, want)
    # with ~2500 sends against cap=100 the tail is all-μ: the last
    # coordinates equal Σ_i μ_i exactly in the oracle too.
    assert np.array_equal(got[-1], np.asarray(ref.decode_sum_sequential(
        bufs, mus, keys, p, cap, d))[-1])


# --------------------------------------------------------------------------- #
# shard decomposition == sequential oracle, incl. remainders.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("d,nshards", (
    (64, 1), (1000, 2), (4103, 3), (4103, 8), (1 << 13, 8), (97, 8)))
@pytest.mark.parametrize("n", (1, 2, 8))
def test_shard_stitch_equals_sequential(d, nshards, n):
    p = 0.3
    cap = max(1, int(d * p * 1.2))
    bufs, mus, keys = _case(d + nshards, n, d, cap)
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = _shard_stitch(bufs, mus, keys, p, cap, d, nshards)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_stitch_cap_overflow_crosses_shards():
    """The overflow boundary lands mid-shard: rank offsets must carry the
    drop across shard windows exactly."""
    d, n, p, nshards = 3000, 3, 0.5, 4
    cap = 200                      # overflows inside the first shard
    bufs, mus, keys = _case(9, n, d, cap)
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = _shard_stitch(bufs, mus, keys, p, cap, d, nshards)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# Pallas shard-view kernel (interpret) == ref shard decode.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("d,nshards", ((1000, 2), (4103, 8), (1 << 13, 8)))
@pytest.mark.parametrize("p", (0.0625, 0.9))
def test_shard_kernel_interpret_equals_sequential(d, nshards, p):
    n = 4
    cap = max(1, int(d * p * 1.1))
    bufs, mus, keys = _case(d, n, d, cap)
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = _shard_stitch(bufs, mus, keys, p, cap, d, nshards,
                        force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_kernel_interpret_single_shard_is_full_decode():
    d, n, p = 2000, 3, 0.3
    cap = max(1, int(d * p * 1.2))
    bufs, mus, keys = _case(21, n, d, cap)
    want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
    got = _shard_stitch(bufs, mus, keys, p, cap, d, 1, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# §13 word-aligned bit-plane shard decode: stitched shards == flat unpack.
#
# Real roundtripped wire rows (unlike the Bernoulli decode-only cases
# above: the plane layout IS the contract under test — the word windows,
# the center tail past the plane, and for ternary the rank positions the
# pass-through counts offset across shard boundaries).
# --------------------------------------------------------------------------- #

TERN_P = 1.0 / 16


def _plane_rows(kind, seed, n, d, wire_dtype, cap=None):
    k = jax.random.PRNGKey(seed)
    xs = jax.random.normal(jax.random.fold_in(k, 0), (n, d)) * 0.4
    pack = (
        (lambda x, kk: bitplane.binary_pack(x, kk, wire_dtype))
        if kind == "binary" else
        (lambda x, kk: bitplane.ternary_pack(x, kk, TERN_P, cap, wire_dtype)))
    return jnp.stack([pack(xs[i], jax.random.fold_in(k, i + 1))
                      for i in range(n)])


def _binary_stitch(rows, d, nshards, wire_dtype, force_pallas=False):
    ds = scatter_shard_len(d, nshards, bitplane.BINARY_ALIGN)
    parts = [bitplane.binary_decode_shard(rows, d, wire_dtype, s * ds, ds,
                                          nshards, force_pallas=force_pallas)
             for s in range(nshards)]
    return jnp.concatenate(parts)[:d]


def _ternary_stitch(rows, d, cap, nshards, wire_dtype):
    ds = scatter_shard_len(d, nshards, bitplane.TERNARY_ALIGN)
    syms = jnp.stack([bitplane.ternary_shard_syms(rows, d, s * ds, ds,
                                                  nshards)
                      for s in range(nshards)])          # (nshards, n, ds)
    # the per-shard pass-through counts the scatter path all_gathers,
    # exclusive-cumsum'd into each peer's global rank offset
    counts = jnp.sum((syms == 2).astype(jnp.int32), axis=2)
    prior = jnp.cumsum(counts, axis=0) - counts
    parts = [bitplane.ternary_decode_shard(rows, syms[s], prior[s], d, cap,
                                           wire_dtype, s * ds)
             for s in range(nshards)]
    return jnp.concatenate(parts)[:d]


def _flat_sum(kind, rows, d, wire_dtype, cap=None):
    """Σ_i unpack(rows[i]) in ascending peer order — the flat add chain."""
    unpack = ((lambda r: bitplane.binary_unpack(r, d, wire_dtype))
              if kind == "binary" else
              (lambda r: bitplane.ternary_unpack(r, d, cap, wire_dtype)))
    acc = jnp.zeros((d,), jnp.float32)
    for i in range(rows.shape[0]):
        acc = acc + unpack(rows[i])
    return acc


# d values hit: shards past d entirely (97/8), d not divisible by 32·n,
# word-boundary-exact d (8192), sub-word tails (33, 4103).
PLANE_CASES = ((33, 1), (97, 2), (97, 8), (1000, 3), (4103, 8), (1 << 13, 8))


@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16"))
@pytest.mark.parametrize("d,nshards", PLANE_CASES)
def test_binary_shard_stitch_equals_flat(d, nshards, wire_dtype):
    n = 4
    rows = _plane_rows("binary", d + nshards, n, d, wire_dtype)
    want = _flat_sum("binary", rows, d, wire_dtype)
    got = _binary_stitch(rows, d, nshards, wire_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("wire_dtype", ("float32", "bfloat16"))
@pytest.mark.parametrize("d,nshards", PLANE_CASES)
def test_ternary_shard_stitch_equals_flat(d, nshards, wire_dtype):
    n = 4
    cap = comm_cost.bernoulli_capacity(d, TERN_P)
    rows = _plane_rows("ternary", d + nshards, n, d, wire_dtype, cap=cap)
    want = _flat_sum("ternary", rows, d, wire_dtype, cap=cap)
    got = _ternary_stitch(rows, d, cap, nshards, wire_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ternary_stitch_cap_overflow_crosses_shards():
    """cap far below the pass-through mass: the μ-substitute fallback
    engages mid-stream and the rank offsets must carry the overflow
    boundary across shard windows exactly (it lands inside a shard)."""
    d, n, nshards = 3000, 3, 4
    cap = 8
    rows = _plane_rows("ternary", 11, n, d, "float32", cap=cap)
    want = _flat_sum("ternary", rows, d, "float32", cap=cap)
    got = _ternary_stitch(rows, d, cap, nshards, "float32")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d,nshards", ((1000, 2), (4103, 8), (1 << 13, 8)))
def test_binary_shard_kernel_interpret_equals_flat(d, nshards):
    """force_pallas routes through the fused unpack+accumulate kernel in
    interpret mode — same bits as the ref fold."""
    n = 4
    rows = _plane_rows("binary", d, n, d, "float32")
    want = _flat_sum("binary", rows, d, "float32")
    got = _binary_stitch(rows, d, nshards, "float32", force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# Hypothesis layer (optional): widens the sweep when available.
# --------------------------------------------------------------------------- #

if HAS_HYPOTHESIS:
    SET = settings(max_examples=20, deadline=None)

    @SET
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 3000),
           n=st.sampled_from((1, 2, 8)),
           p=st.floats(0.05, 1.0),
           cap_frac=st.sampled_from((0.05, 0.5, 1.2)))
    def test_hyp_tiled_decode_sum_equals_sequential(seed, d, n, p, cap_frac):
        cap = max(1, int(d * cap_frac))
        bufs, mus, keys = _case(seed, n, d, cap)
        want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
        got = ref.decode_sum(bufs, mus, keys, p, cap, d)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @SET
    @given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 3000),
           n=st.sampled_from((1, 2, 8)),
           nshards=st.sampled_from((1, 2, 3, 8)),
           p=st.floats(0.05, 1.0),
           cap_frac=st.sampled_from((0.05, 0.5, 1.2)))
    def test_hyp_shard_stitch_equals_sequential(seed, d, n, nshards, p,
                                                cap_frac):
        cap = max(1, int(d * cap_frac))
        bufs, mus, keys = _case(seed, n, d, cap)
        want = ref.decode_sum_sequential(bufs, mus, keys, p, cap, d)
        got = _shard_stitch(bufs, mus, keys, p, cap, d, nshards)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
