"""Seed-replay forensics: replay_support reconstructs a dropped node's
seed-trick support bit-exactly from the fold_in chain (DESIGN.md §14).

Cross-checked three ways: against the per-coordinate Threefry reference
(:func:`repro.kernels.threefry.ref.uniform_at` — the same scattered-lane
primitive the reduce-scatter decode uses), against the codec's own
``unpack`` of a real packed buffer (the slot map must lift the buffer back
to the dense message), and against a forced-small-capacity encode (the
overflow-drop path, which the natural ≈6σ capacity makes a ~1e-9 event).
Runs in the CI kernel-interpret job too (REPRO_KERNEL_BACKEND=
pallas_interpret), where bernoulli encode goes through the fused Pallas
kernel in interpret mode — replay must agree with those bytes as well.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, rotation
from repro.core import wire
from repro.core.wire import codecs as wire_codecs
from repro.configs.registry import robust_preset
from repro.distributed.fault_tolerance import ReplaySupport, replay_support
from repro.kernels.bernoulli_wire import ops as bw_ops
from repro.kernels.threefry import ref as tf_ref

D = 5000
KEY = jax.random.PRNGKey(11)


def _cfg(name):
    return robust_preset(name, "mean", axes=("data",))


# --------------------------------------------------------------------------- #
# Bernoulli: support, overflow drops, slot map.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("peer", [0, 3, 7])
@pytest.mark.parametrize("d", [D, 4999, 257])
def test_bernoulli_replay_bit_exact_vs_threefry_ref(peer, d):
    cfg = _cfg("bernoulli_seed_1bit")
    rs = replay_support(cfg, KEY, peer, d)
    assert rs.dim == d
    p = float(cfg.encoder.fraction)
    kenc = jax.random.fold_in(KEY, peer)
    # the scattered-lane Threefry reference regenerates the identical
    # uniforms the encoder thresholded — support equality is bit-exact.
    u = tf_ref.uniform_at(kenc, jnp.arange(d), d)
    assert (np.asarray(rs.support) == np.asarray(u < p)).all()
    # natural capacity (≈6σ slack): nothing overflows, kept == support.
    assert (np.asarray(rs.kept) == np.asarray(rs.support)).all()


def test_bernoulli_replay_slots_lift_the_real_buffer():
    cfg = _cfg("bernoulli_seed_1bit")
    codec = wire.resolve(cfg)
    peer = 5
    x = jax.random.normal(jax.random.PRNGKey(1), (D,), jnp.float32)
    row = codec.pack(x, KEY, peer, cfg)
    dense = np.asarray(codec.unpack(row, peer, KEY, cfg, D))
    rs = replay_support(cfg, KEY, peer, D)
    kept = np.asarray(rs.kept)
    slot = np.asarray(rs.slot)
    buf = np.asarray(row.astype(jnp.float32))
    mu = buf[-1]
    lifted = np.where(kept, buf[np.clip(slot, 0, len(buf) - 1)], mu)
    assert (lifted == dense).all()
    assert (slot[~kept] == -1).all()
    # slots are a bijection onto the occupied buffer prefix.
    used = np.sort(slot[kept])
    assert (used == np.arange(kept.sum())).all()


def test_bernoulli_cap_overflow_drop_path():
    # the natural capacity makes overflow a ~1e-9 event, so force a tiny
    # cap through the encode entry point and check replay's kept/slot
    # logic reproduces the encoder's drop rule exactly: support ranks
    # ≥ cap are dropped, the rest keep their rank slots.
    d, p, cap = 1024, 0.25, 16
    kenc = jax.random.fold_in(KEY, 2)
    x = jax.random.normal(jax.random.PRNGKey(2), (d,), jnp.float32)
    mu = jnp.mean(x)
    buf = bw_ops.encode(x, kenc, p, cap, mu)
    dense = np.asarray(wire_codecs.bernoulli_unpack(
        buf, kenc, p, cap, mu, d))
    sent = np.asarray(
        jax.random.uniform(kenc, (d,), dtype=jnp.float32) < p)
    pos = np.cumsum(sent) - 1
    kept = sent & (pos < cap)
    assert sent.sum() > cap  # the drop path is actually exercised
    lifted = np.where(kept, np.asarray(buf)[np.clip(pos, 0, cap - 1)],
                      float(mu))
    assert (lifted == dense).all()


# --------------------------------------------------------------------------- #
# fixed-k (gather + shared) and the rotated/EF compositions.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name,folded", [("rotated_fixed_k", True),
                                         ("ef_fixed_k", True),
                                         ("fixed_k_1bit", False)])
def test_fixed_k_replay_matches_unpack_support(name, folded):
    cfg = _cfg(name)
    rs = replay_support(cfg, KEY, 4, D)
    rot = bool(cfg.encoder.rotation)
    dim = rotation.padded_dim(D) if rot else D
    assert rs.dim == dim
    # fixed-k never overflows: kept == support, block-structured.
    assert (np.asarray(rs.kept) == np.asarray(rs.support)).all()
    # cross-check against the inner codec's unpack: unpack a buffer of
    # slot indices and confirm every supported coordinate reads its slot.
    inner = wire_codecs.FixedKGatherCodec() if folded \
        else wire_codecs.FixedKSharedCodec()
    slots = inner.wire_slots(dim, cfg)
    probe = jnp.concatenate([jnp.arange(slots - 1, dtype=jnp.float32),
                             jnp.zeros((1,), jnp.float32)])  # μ = 0
    dense = np.asarray(inner.unpack(probe, 4, KEY, cfg, dim))
    sup = np.asarray(rs.support)
    slot = np.asarray(rs.slot)
    assert (dense[sup] == slot[sup]).all()
    assert (slot[~sup] == -1).all()


def test_replay_deterministic_sweep():
    # same inputs, same bits — across peers and dims, twice each.
    cfg = _cfg("bernoulli_seed_1bit")
    for d in (257, 1000):
        for peer in range(4):
            a = replay_support(cfg, KEY, peer, d)
            b = replay_support(cfg, KEY, peer, d)
            assert (np.asarray(a.support) == np.asarray(b.support)).all()
            assert (np.asarray(a.slot) == np.asarray(b.slot)).all()


def test_replay_rejects_data_dependent_wires():
    for name in ("binary_packed", "ternary_packed", "ef_rotated_binary"):
        with pytest.raises(ValueError, match="no seed-derivable support"):
            replay_support(_cfg(name), KEY, 0, D)


def test_replay_support_is_frozen_record():
    rs = replay_support(_cfg("bernoulli_seed_1bit"), KEY, 0, 257)
    assert isinstance(rs, ReplaySupport)
    with pytest.raises(dataclasses.FrozenInstanceError):
        rs.dim = 1
