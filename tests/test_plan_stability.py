"""Bucket-plan stability: the plan — bucket ids, slot offsets AND the
readiness schedule — is a pure function of the (abstract shapes, specs,
mesh, config) *set*, independent of the insertion order of the input
mappings.  This is the cross-process determinism both the EF bucket-id
keying and the overlap schedule rely on: every process must derive the
identical plan from its own traversal of the param tree.

Property-based (hypothesis) over random leaf populations + a deterministic
seeded-shuffle test so the invariant stays covered where hypothesis isn't
installed (it skips gracefully, same convention as tests/test_property.py).
"""
import random

import pytest

from repro.core import types as core_types
from repro.train import bucketing

MESH_AXES = ("pod", "data", "model")
MSIZES = {"pod": 2, "data": 4, "model": 2}

CMP = core_types.CompressionConfig(
    encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1 / 16),
    mode="shared_support", axes=("pod", "data"), min_compress_size=1024,
    bucket=core_types.BucketSpec(capacity=1 << 14))

# leaf spec vocabulary: unsharded, model-sharded, fully-covered (passthrough)
SPEC_CHOICES = [
    (None,), ("model",), (None, None), ("model", None),
    (("pod", "data"), "model"),
]


def _shapes_for(spec, size_hint):
    """A concrete shape matching the spec's sharded axes divisibility."""
    if len(spec) == 1:
        return (max(8, size_hint // 8 * 8),)
    return (max(8, size_hint // 8 * 8), 16)


def _population(rng: random.Random, n_leaves: int):
    shapes, specs = {}, {}
    for i in range(n_leaves):
        spec = rng.choice(SPEC_CHOICES)
        size = rng.choice([16, 64, 1024, 2048, 4096, 1 << 14, 1 << 15])
        shapes[f"leaf_{i:03d}"] = _shapes_for(spec, size)
        specs[f"leaf_{i:03d}"] = spec
    return shapes, specs


def _shuffled(mapping, rng: random.Random):
    keys = list(mapping)
    rng.shuffle(keys)
    return {k: mapping[k] for k in keys}


def _plan_fingerprint(plan):
    return (
        tuple((b.bid, b.kind, b.caxes, b.eaxes, b.size, b.ready,
               tuple((s.name, s.offset, s.size, s.shape) for s in b.slots))
              for b in plan.buckets),
        plan.passthrough,
        plan.schedule(),
    )


@pytest.mark.parametrize("seed", range(8))
def test_plan_invariant_under_insertion_order(seed):
    rng = random.Random(seed)
    shapes, specs = _population(rng, n_leaves=40)
    ref = bucketing.build_plan(shapes, specs, MESH_AXES, MSIZES, CMP)
    for trial in range(4):
        srng = random.Random(1000 * seed + trial)
        plan = bucketing.build_plan(_shuffled(shapes, srng),
                                    _shuffled(specs, srng),
                                    MESH_AXES, MSIZES, CMP)
        assert _plan_fingerprint(plan) == _plan_fingerprint(ref)
        assert plan == ref


def test_readiness_is_canonical_not_insertion_order():
    """ready comes from sorted-name backward order, never dict order."""
    shapes = {f"leaf_{i:03d}": (2048,) for i in range(6)}
    specs = {n: (None,) for n in shapes}
    reversed_insert = {n: shapes[n] for n in sorted(shapes, reverse=True)}
    p1 = bucketing.build_plan(shapes, specs, MESH_AXES, MSIZES, CMP)
    p2 = bucketing.build_plan(reversed_insert, specs, MESH_AXES, MSIZES, CMP)
    assert [b.ready for b in p1.buckets] == [b.ready for b in p2.buckets]
    assert p1.schedule() == p2.schedule()


def test_plan_stability_hypothesis():
    """Property form: arbitrary populations × arbitrary permutations."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    leaf = st.tuples(st.sampled_from(SPEC_CHOICES),
                     st.sampled_from([16, 64, 1024, 2048, 4096,
                                      1 << 14, 1 << 15]))

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(leaves=st.lists(leaf, min_size=1, max_size=48),
               perm_seed=st.integers(0, 2**31 - 1))
    def prop(leaves, perm_seed):
        shapes, specs = {}, {}
        for i, (spec, size) in enumerate(leaves):
            shapes[f"leaf_{i:03d}"] = _shapes_for(spec, size)
            specs[f"leaf_{i:03d}"] = spec
        ref = bucketing.build_plan(shapes, specs, MESH_AXES, MSIZES, CMP)
        srng = random.Random(perm_seed)
        plan = bucketing.build_plan(_shuffled(shapes, srng),
                                    _shuffled(specs, srng),
                                    MESH_AXES, MSIZES, CMP)
        assert _plan_fingerprint(plan) == _plan_fingerprint(ref)
        # structural sanity on every generated population: full coverage,
        # contiguous offsets, readiness within range
        n = len(shapes)
        placed = [s.name for b in plan.buckets for s in b.slots]
        assert sorted(placed + list(plan.passthrough)) == sorted(shapes)
        for b in plan.buckets:
            assert 0 <= b.ready < n
            assert b.ready == max(
                n - 1 - sorted(shapes).index(s.name) for s in b.slots)

    prop()
