"""Hypothesis property tests on the protocol family's invariants.

Degrades to a skip when hypothesis isn't installed (it is pinned in
requirements-dev.txt, so CI always runs these for real).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import centers, comm_cost, encoders, mse, optimal, types

SET = settings(max_examples=25, deadline=None)


def _xs(seed, n, d, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


@SET
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 257),
       p=st.floats(0.05, 1.0))
def test_p_one_is_lossless_and_p_scales_support(seed, d, p):
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    enc = encoders.encode_bernoulli(jax.random.PRNGKey(seed + 1), x, 1.0,
                                    jnp.mean(x))
    np.testing.assert_allclose(np.asarray(enc.y), np.asarray(x), rtol=1e-5)
    enc_p = encoders.encode_bernoulli(jax.random.PRNGKey(seed + 2), x, p,
                                      jnp.mean(x))
    assert 0 <= int(enc_p.nsent) <= d


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8),
       d=st.integers(2, 128), k=st.integers(1, 128))
def test_fixed_k_mse_monotone_in_k(seed, n, d, k):
    """More budget never hurts: MSE(k) ≥ MSE(k+1) (Lemma 3.4)."""
    k = min(k, d - 1) if d > 1 else 1
    xs = _xs(seed, n, d, 1.0)
    mus = jnp.mean(xs, axis=-1)
    m1 = float(mse.mse_fixed_k(xs, k, mus))
    m2 = float(mse.mse_fixed_k(xs, min(k + 1, d), mus))
    assert m2 <= m1 + 1e-9


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 6),
       d=st.integers(4, 64))
def test_optimal_probs_dominate_uniform(seed, n, d):
    xs = _xs(seed, n, d, 2.0)
    mus = jnp.mean(xs, axis=-1)
    B = max(1.0, 0.25 * n * d)
    p_opt = optimal.optimal_probs(xs, mus, B)
    assert float(jnp.sum(p_opt)) <= B * 1.01
    p_uni = jnp.full(xs.shape, B / (n * d))
    assert (float(mse.mse_bernoulli(xs, p_opt, mus))
            <= float(mse.mse_bernoulli(xs, p_uni, mus)) * 1.001)


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 6),
       d=st.integers(4, 64))
def test_optimal_centers_never_worse_than_mean(seed, n, d):
    xs = _xs(seed, n, d, 1.0)
    p = jax.random.uniform(jax.random.PRNGKey(seed + 9), (n, d),
                           minval=0.05, maxval=1.0)
    mu_mean = jnp.mean(xs, axis=-1)
    mu_opt = centers.optimal_centers(xs, p)
    assert (float(mse.mse_bernoulli(xs, p, mu_opt))
            <= float(mse.mse_bernoulli(xs, p, mu_mean)) * 1.001)


@SET
@given(n=st.integers(1, 32), d=st.integers(8, 4096), p=st.floats(0.01, 1.0))
def test_sparse_seed_cost_between_bounds(n, d, p):
    """0 < C(p) ≤ C_naive + seed overhead; monotone in p (§4.4)."""
    spec = types.CommSpec(protocol="sparse_seed")
    c = comm_cost.cost_sparse_seed_uniform_p(n, d, p, spec)
    c_full = comm_cost.cost_naive(n, d, spec) + n * (spec.rbar_bits + spec.rseed_bits)
    assert 0 < c <= c_full + 1e-6
    c2 = comm_cost.cost_sparse_seed_uniform_p(n, d, min(1.0, p * 1.5), spec)
    assert c2 >= c - 1e-9


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 8),
       d=st.integers(4, 64), scale=st.floats(0.1, 10.0))
def test_mse_scale_equivariance(seed, n, d, scale):
    """MSE(c·X) = c²·MSE(X) for mean centers (Lemma 3.2 homogeneity)."""
    xs = _xs(seed, n, d, 1.0)
    mus = jnp.mean(xs, axis=-1)
    m1 = float(mse.mse_bernoulli(xs, 0.3, mus))
    m2 = float(mse.mse_bernoulli(scale * xs, 0.3, scale * mus))
    np.testing.assert_allclose(m2, scale**2 * m1, rtol=1e-3)


@SET
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 512))
def test_thm61_lower_below_upper(seed, d):
    xs = _xs(seed, 4, d, 1.0)
    mus = jnp.mean(xs, axis=-1)
    B = max(1.0, d / 4)
    lo, hi = mse.thm61_bounds(xs, mus, B)
    assert float(lo) <= float(hi) + 1e-6
