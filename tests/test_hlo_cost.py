"""Validate the loop-aware HLO cost model against hand-computed counts."""
import re

import pytest

from repro.launch import hlo_cost

SAMPLE = open("/tmp/hlo_sample.txt").read() if __import__("os").path.exists(
    "/tmp/hlo_sample.txt") else None


def _mini_module():
    """Build a tiny scanned module on a 2-device mesh inside this process's
    single... Note: this test uses only the text parser on a static sample
    generated inline (no devices needed)."""
    return """
HloModule test

%cond (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p.1: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p.1 = (s32[], f32[4,8]) parameter(0)
  %i.1 = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i.1, %one)
  %x = f32[4,8]{1,0} get-tuple-element(%p.1), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,8]{1,0} all-reduce(%d), replica_groups=[2,8]<=[16], to_apply=%add_comp
  ROOT %t = (s32[], f32[4,8]) tuple(%i2, %ar)
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[4,8]) -> f32[4,8] {
  %arg = f32[4,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[4,8]) tuple(%zero, %arg)
  %w2 = (s32[], f32[4,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,8]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_trip_count_and_flops():
    cost = hlo_cost.analyze_text(_mini_module())
    # dot: 2 * (4*8 output) * 8 contraction = 512 flops, x5 trips
    assert cost.flops == pytest.approx(5 * 512)


def test_collective_multiplied_by_trips():
    cost = hlo_cost.analyze_text(_mini_module())
    assert cost.coll_counts == {"all-reduce": 1}
    assert cost.coll_exec == {"all-reduce": pytest.approx(5.0)}
    # ring all-reduce of 4*8*4 bytes in groups of 8: 2*(7/8)*128 = 224/op
    assert cost.coll_wire_bytes == pytest.approx(5 * 2 * (7 / 8) * 128)


def test_bytes_loop_aware():
    cost = hlo_cost.analyze_text(_mini_module())
    # body per trip: add(s32: 4+4+4) + dot(128 out + 128 lhs + 256 rhs)
    # + all-reduce(128 + 128); entry: while(tuple bytes) + gte skipped...
    assert cost.bytes > 5 * (512 + 256)  # at least the dot+ar traffic
    assert cost.bytes < 50_000


@pytest.mark.skipif(SAMPLE is None, reason="sample HLO not present")
def test_real_sample_flops_scale():
    cost = hlo_cost.analyze_text(SAMPLE)
    # 7-layer scan fwd (4x128 @ 128x8) + bwd dgrad + wgrad:
    # fwd: 2*4*8*128 = 8192/layer; bwd adds ~2x more.
    assert cost.flops >= 7 * 2 * 8192
    assert cost.flops <= 7 * 4 * 8192
    assert cost.coll_exec.get("all-gather", 0) >= 7
