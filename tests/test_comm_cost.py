"""Communication-cost models (§4): analytic forms + realized == expected,
plus the packed bit-plane accounting (HLO-measured gather bits == the
cost_binary_packed / cost_ternary_packed forms exactly)."""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, encoders, types

ROOT = pathlib.Path(__file__).resolve().parent.parent
KEY = jax.random.PRNGKey(0)
N, D = 8, 512
R = 16
SPEC = types.CommSpec(protocol="sparse", r_bits=R, rbar_bits=16, rseed_bits=32)


def test_naive_cost():
    assert comm_cost.cost_naive(N, D, SPEC) == N * D * R


def test_varying_uniform_p_closed_form():
    """§4.2: C = n(r̄ + d + p·d·r) for uniform p."""
    p = 0.25
    probs = jnp.full((N, D), p)
    got = comm_cost.cost_varying_length(probs, SPEC)
    want = N * (SPEC.rbar_bits + D + p * D * R)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sparse_uniform_p_closed_form():
    """§4.3: C = n·r̄ + (⌈log d⌉ + r)·n·d·p."""
    p = 1.0 / R
    probs = jnp.full((N, D), p)
    got = comm_cost.cost_sparse(probs, SPEC, D)
    want = N * SPEC.rbar_bits + (9 + R) * N * D * p  # ceil(log2 512) = 9
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sparse_seed_fixed_k_deterministic():
    """§4.4 Eq. (9): C = n(r̄ + r̄_s) + n·k·r, deterministic."""
    k = 32
    got = comm_cost.cost_sparse_seed_fixed_k(N, k, SPEC)
    assert got == N * (16 + 32) + N * k * R


def test_binary_cost_eq11():
    assert comm_cost.cost_binary(N, D, SPEC) == N * 2 * R + N * D


def test_realized_matches_expected_bernoulli():
    """E[measure_bits] == analytic cost (the §4 expectations)."""
    p = 0.25
    xs = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    spec = types.EncoderSpec(kind="bernoulli", fraction=p)

    def bits_one(k):
        enc = encoders.encode_batch(k, xs, spec)
        return jnp.sum(enc.nsent)
    nsent = jax.lax.map(jax.jit(bits_one), jax.random.split(KEY, 2000))
    mean_bits = (N * SPEC.rbar_bits
                 + (comm_cost.ceil_log2(D) + R) * float(jnp.mean(nsent)))
    want = comm_cost.cost_sparse(jnp.full((N, D), p), SPEC, D)
    np.testing.assert_allclose(mean_bits, want, rtol=0.02)


def test_realized_fixed_k_exactly_deterministic():
    xs = jax.random.normal(jax.random.PRNGKey(2), (N, D))
    spec = types.EncoderSpec(kind="fixed_k", fraction=0.125)
    cspec = types.CommSpec(protocol="sparse_seed")
    k = types.fixed_k_from_fraction(D, 0.125)
    for seed in range(3):
        enc = encoders.encode_batch(jax.random.PRNGKey(seed), xs, spec)
        got = comm_cost.measure_bits(enc, cspec, D)
        assert got == comm_cost.cost_sparse_seed_fixed_k(N, k, cspec)


def test_ternary_cost_closed_form():
    """§7.1: C = 2nr + 2nd + n·d·p_pass·r, dispatchable via protocol."""
    spec = types.CommSpec(protocol="ternary", r_bits=R)
    p_pass = 1.0 / R
    want = N * 2 * R + 2 * N * D + N * D * p_pass * R
    assert comm_cost.cost_ternary(N, D, p_pass, spec) == want
    assert comm_cost.cost(spec, n=N, d=D, p=p_pass) == want


def test_packed_costs_bound_ideal_forms():
    """Word padding is the only overhead of the packed realizations:
    ideal ≤ packed ≤ ideal + per-node padding slack."""
    for r in (16, 32):
        spec_b = types.CommSpec(protocol="binary", r_bits=r)
        for d in (31, 32, 512, 5000, 1 << 20):
            ideal = comm_cost.cost_binary(N, d, spec_b)
            packed = comm_cost.cost_binary_packed(N, d, spec_b)
            assert ideal <= packed <= ideal + N * 2 * 32
            p_pass = 0.125
            cap = comm_cost.bernoulli_capacity(d, p_pass)
            spec_t = types.CommSpec(protocol="ternary", r_bits=r)
            idealt = comm_cost.cost_ternary(N, d, p_pass, spec_t)
            packedt = comm_cost.cost_ternary_packed(N, d, cap, spec_t)
            sigma = np.sqrt(d * p_pass * (1 - p_pass))
            assert idealt <= packedt <= idealt + N * (
                r * (6 * sigma + 1) + 3 * 32) + 1e-6
            # packed=True dispatch is symmetric across both plane protocols
            assert packedt == comm_cost.cost(spec_t, n=N, d=d, cap=cap,
                                             packed=True)
            assert packed == comm_cost.cost(spec_b, n=N, d=d, packed=True)


def test_realized_matches_expected_ternary():
    """E[measure_bits] == cost_ternary: nsent counts the pass-through
    (full-precision) branch of Eq. (21)."""
    p_pass = 0.25
    xs = jax.random.normal(jax.random.PRNGKey(5), (N, D))
    spec = types.EncoderSpec(kind="ternary", fraction=p_pass)
    cspec = types.CommSpec(protocol="ternary", r_bits=R)

    def nsent_one(k):
        return jnp.sum(encoders.encode_batch(k, xs, spec).nsent)
    nsent = jax.lax.map(jax.jit(nsent_one), jax.random.split(KEY, 2000))
    mean_bits = N * 2 * R + 2 * N * D + R * float(jnp.mean(nsent))
    # one realized sample routed through measure_bits agrees by definition
    enc = encoders.encode_batch(KEY, xs, spec)
    assert comm_cost.measure_bits(enc, cspec, D) == (
        N * 2 * R + 2 * N * D + R * float(jnp.sum(enc.nsent)))
    want = comm_cost.cost_ternary(N, D, p_pass, cspec)
    np.testing.assert_allclose(mean_bits, want, rtol=0.02)


# NOTE: the per-protocol HLO-vs-accounting subprocess test that lived here
# (binary/ternary gathered words == the packed cost forms) was superseded
# by the single parametrized check over EVERY registered wire codec in
# tests/test_wire_registry.py::test_hlo_gathered_bits_match_wire_bits.


def test_table1_cost_column():
    """Table 1 rows: communication cost at the four named operating points."""
    rbar, rs = SPEC.rbar_bits, SPEC.rseed_bits
    seed_spec = types.CommSpec(protocol="sparse_seed", r_bits=R,
                               rbar_bits=rbar, rseed_bits=rs)
    # Example 5 (p = 1): naive == n·d·r
    assert comm_cost.cost_naive(N, D, SPEC) == N * D * R
    # Example 7 (p = 1/r): n(r̄s + r̄) + n·d
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, 1.0 / R, seed_spec)
    assert got == N * (rbar + rs) + N * D
    # Example 9 (p = 1/d): n(r̄s + r̄) + n·r
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, 1.0 / D, seed_spec)
    assert got == N * (rbar + rs) + N * R
    # Example 6 (p = 1/log d): n(r̄s + r̄) + n·d·r/log d
    p = 1.0 / np.log(D)
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, p, seed_spec)
    np.testing.assert_allclose(got, N * (rbar + rs) + N * D * R * p)
