"""Communication-cost models (§4): analytic forms + realized == expected."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost, encoders, types

KEY = jax.random.PRNGKey(0)
N, D = 8, 512
R = 16
SPEC = types.CommSpec(protocol="sparse", r_bits=R, rbar_bits=16, rseed_bits=32)


def test_naive_cost():
    assert comm_cost.cost_naive(N, D, SPEC) == N * D * R


def test_varying_uniform_p_closed_form():
    """§4.2: C = n(r̄ + d + p·d·r) for uniform p."""
    p = 0.25
    probs = jnp.full((N, D), p)
    got = comm_cost.cost_varying_length(probs, SPEC)
    want = N * (SPEC.rbar_bits + D + p * D * R)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sparse_uniform_p_closed_form():
    """§4.3: C = n·r̄ + (⌈log d⌉ + r)·n·d·p."""
    p = 1.0 / R
    probs = jnp.full((N, D), p)
    got = comm_cost.cost_sparse(probs, SPEC, D)
    want = N * SPEC.rbar_bits + (9 + R) * N * D * p  # ceil(log2 512) = 9
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sparse_seed_fixed_k_deterministic():
    """§4.4 Eq. (9): C = n(r̄ + r̄_s) + n·k·r, deterministic."""
    k = 32
    got = comm_cost.cost_sparse_seed_fixed_k(N, k, SPEC)
    assert got == N * (16 + 32) + N * k * R


def test_binary_cost_eq11():
    assert comm_cost.cost_binary(N, D, SPEC) == N * 2 * R + N * D


def test_realized_matches_expected_bernoulli():
    """E[measure_bits] == analytic cost (the §4 expectations)."""
    p = 0.25
    xs = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    spec = types.EncoderSpec(kind="bernoulli", fraction=p)

    def bits_one(k):
        enc = encoders.encode_batch(k, xs, spec)
        return jnp.sum(enc.nsent)
    nsent = jax.lax.map(jax.jit(bits_one), jax.random.split(KEY, 2000))
    mean_bits = (N * SPEC.rbar_bits
                 + (comm_cost.ceil_log2(D) + R) * float(jnp.mean(nsent)))
    want = comm_cost.cost_sparse(jnp.full((N, D), p), SPEC, D)
    np.testing.assert_allclose(mean_bits, want, rtol=0.02)


def test_realized_fixed_k_exactly_deterministic():
    xs = jax.random.normal(jax.random.PRNGKey(2), (N, D))
    spec = types.EncoderSpec(kind="fixed_k", fraction=0.125)
    cspec = types.CommSpec(protocol="sparse_seed")
    k = types.fixed_k_from_fraction(D, 0.125)
    for seed in range(3):
        enc = encoders.encode_batch(jax.random.PRNGKey(seed), xs, spec)
        got = comm_cost.measure_bits(enc, cspec, D)
        assert got == comm_cost.cost_sparse_seed_fixed_k(N, k, cspec)


def test_table1_cost_column():
    """Table 1 rows: communication cost at the four named operating points."""
    rbar, rs = SPEC.rbar_bits, SPEC.rseed_bits
    seed_spec = types.CommSpec(protocol="sparse_seed", r_bits=R,
                               rbar_bits=rbar, rseed_bits=rs)
    # Example 5 (p = 1): naive == n·d·r
    assert comm_cost.cost_naive(N, D, SPEC) == N * D * R
    # Example 7 (p = 1/r): n(r̄s + r̄) + n·d
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, 1.0 / R, seed_spec)
    assert got == N * (rbar + rs) + N * D
    # Example 9 (p = 1/d): n(r̄s + r̄) + n·r
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, 1.0 / D, seed_spec)
    assert got == N * (rbar + rs) + N * R
    # Example 6 (p = 1/log d): n(r̄s + r̄) + n·d·r/log d
    p = 1.0 / np.log(D)
    got = comm_cost.cost_sparse_seed_uniform_p(N, D, p, seed_spec)
    np.testing.assert_allclose(got, N * (rbar + rs) + N * D * R * p)
