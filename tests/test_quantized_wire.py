"""Single-device tests of the packed bit-plane binary/ternary wire paths
(repro.core.bitplane): pack→unpack equivalence against the dense encoders,
overflow handling, preset plumbing — plus the multi-device subprocess check
(distributed_checks/quantized_wire_check.py)."""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import bitplane, comm_cost, encoders, types

ROOT = pathlib.Path(__file__).resolve().parent.parent

D = 5000  # not a multiple of 32: exercises the plane tail


def _x(seed=0, d=D):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.7


# --------------------------------------------------------------------------- #
# Wire pack/unpack == dense encoder output.
# --------------------------------------------------------------------------- #

def test_binary_wire_matches_encoder_bit_exact():
    """f32 wire: the packed plane reproduces encode_binary per key."""
    x = _x().astype(jnp.float32)
    for s in range(5):
        key = jax.random.PRNGKey(100 + s)
        buf = bitplane.binary_pack(x, key, "float32")
        assert buf.dtype == jnp.uint32
        assert buf.shape == (bitplane.binary_wire_words(D, "float32"),)
        y = bitplane.binary_unpack(buf, D, "float32")
        enc = encoders.encode_binary(key, x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(enc.y))


def test_binary_wire_bf16_rounds_centers_only():
    """bf16 wire: the plane is exact; only vmin/vmax are bf16-rounded."""
    x = _x(1).astype(jnp.float32)
    key = jax.random.PRNGKey(3)
    y = bitplane.binary_unpack(bitplane.binary_pack(x, key, "bfloat16"), D,
                               "bfloat16")
    enc = encoders.encode_binary(key, x)
    vmin16 = enc.extras["vmin"].astype(jnp.bfloat16).astype(jnp.float32)
    vmax16 = enc.extras["vmax"].astype(jnp.bfloat16).astype(jnp.float32)
    want = jnp.where(enc.support, vmax16, vmin16)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


def test_ternary_wire_matches_encoder():
    """f32 wire: the 2-bit plane + value segment reproduce encoders.encode
    (kind='ternary') per key, at full capacity."""
    x = _x(2).astype(jnp.float32)
    p_pass = 0.125
    cap = comm_cost.bernoulli_capacity(D, p_pass)
    spec = types.EncoderSpec(kind="ternary", fraction=p_pass)
    for s in range(5):
        key = jax.random.PRNGKey(200 + s)
        buf = bitplane.ternary_pack(x, key, p_pass, cap, "float32")
        assert buf.shape == (bitplane.ternary_wire_words(D, cap, "float32"),)
        y = bitplane.ternary_unpack(buf, D, cap, "float32")
        enc = encoders.encode(key, x, spec)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(enc.y))


def test_ternary_overflow_drops_symmetrically():
    """cap < |pass-through set|: encoder drops overflow ranks, decoder
    substitutes (c1+c2)/2 for exactly those ranks — never misaligns."""
    x = _x(3).astype(jnp.float32)
    p_pass = 0.5
    cap = 16  # far below E[|pass|] = 2500: massive forced overflow
    key = jax.random.PRNGKey(7)
    buf = bitplane.ternary_pack(x, key, p_pass, cap, "float32")
    y = np.asarray(bitplane.ternary_unpack(buf, D, cap, "float32"))
    enc = encoders.encode(key, x, types.EncoderSpec(kind="ternary",
                                                    fraction=p_pass))
    sent = np.asarray(enc.support)
    pos = np.cumsum(sent) - 1
    kept = sent & (pos < cap)
    np.testing.assert_array_equal(y[~sent], np.asarray(enc.y)[~sent])
    np.testing.assert_array_equal(y[kept], np.asarray(enc.y)[kept])
    c_mid = 0.5 * float(jnp.min(x) + jnp.max(x))
    np.testing.assert_allclose(y[sent & ~kept], c_mid, rtol=1e-6)
    assert int(kept.sum()) == cap  # buffer fully used before dropping


# --------------------------------------------------------------------------- #
# Wire-bit accounting follows the real dispatch rule.
# --------------------------------------------------------------------------- #

def test_bucket_wire_bits_tracks_dispatch():
    """bucket_wire_bits must charge what compressed_mean actually ships:
    packed words for the plane paths, dense f32 for configs that fall back
    to dense_sim (gather_wire_kind is the single source of truth)."""
    from repro.core import collectives
    from repro.train import bucketing

    n = 8
    shapes = {"a": (4096,), "b": (4096,)}
    specs = {name: (None,) for name in shapes}

    def mk(**enc):
        return types.CompressionConfig(
            encoder=types.EncoderSpec(**enc), mode="gather_decode",
            axes=("data",), wire_dtype="float32", min_compress_size=1024)

    # packed binary: n * 32 * wire words per bucket
    cfg = mk(kind="binary", center="min")
    assert collectives.gather_wire_kind(cfg) == "binary"
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": n}, cfg)
    by_bid = {b.bid: b for b in plan.buckets}
    for bid, bits in bucketing.bucket_wire_bits(plan, cfg, n).items():
        want = n * 32 * bitplane.binary_wire_words(by_bid[bid].size,
                                                   "float32")
        assert bits == want

    # ternary with §6 optimal probs rides the same packed 2-bit plane as
    # uniform ternary (the data-dependent split travels as realized branch
    # choices), so the accounting charges ternary words — not dense bits.
    cfg = mk(kind="ternary", fraction=0.125, probs="optimal")
    assert collectives.gather_wire_kind(cfg) == "ternary_opt"
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": n}, cfg)
    by_bid = {b.bid: b for b in plan.buckets}
    for bid, bits in bucketing.bucket_wire_bits(plan, cfg, n).items():
        d_b = by_bid[bid].size
        cap = comm_cost.bernoulli_capacity(d_b, 0.125)
        assert bits == n * 32 * bitplane.ternary_wire_words(d_b, cap,
                                                            "float32")

    # bernoulli with optimal center still rides the dense simulation
    cfg = mk(kind="bernoulli", fraction=0.125, center="optimal")
    assert collectives.gather_wire_kind(cfg) == "dense"

    # error feedback is a wire-layer wrap whose residuals stay local: an
    # EF bucket is charged EXACTLY its inner codec's bits (the old rule —
    # every EF bucket billed the fixed-k EF buffer — is gone).
    cfg = dataclasses.replace(mk(kind="binary", center="min"),
                              error_feedback=True)
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": n}, cfg)
    by_bid = {b.bid: b for b in plan.buckets}
    for bid, bits in bucketing.bucket_wire_bits(plan, cfg, n).items():
        want = n * 32 * bitplane.binary_wire_words(by_bid[bid].size,
                                                   "float32")
        assert bits == want

    # non-gather modes have no gather wire to account
    cfg_none = types.CompressionConfig(mode="none")
    plan = bucketing.build_plan(shapes, specs, ("data",), {"data": n},
                                cfg_none)
    assert bucketing.bucket_wire_bits(plan, cfg_none, n) == {}


# --------------------------------------------------------------------------- #
# Registry presets exercise the packed wire paths.
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name,kind,mode", [
    ("binary_packed", "binary", "gather_decode"),
    ("ternary_packed", "ternary", "gather_decode"),
    ("bernoulli_seed_1bit", "bernoulli", "gather_decode"),
    ("fixed_k_1bit", "fixed_k", "shared_support"),
])
def test_compression_presets(name, kind, mode):
    cfg = registry.compression_preset(name)
    assert cfg.encoder.kind == kind and cfg.mode == mode
    assert registry.compression_preset(name, axes=("data",)).axes == ("data",)
    run = registry.get_run_config("qwen3-4b", "train_4k", compression=name)
    assert run.compression.encoder.kind == kind
    assert run.compression.axes == ("data",)


def test_compression_preset_unknown_raises():
    with pytest.raises(KeyError):
        registry.compression_preset("no_such_preset")


# --------------------------------------------------------------------------- #
# Multi-device behavior (subprocess: 8 fake CPU devices).
# --------------------------------------------------------------------------- #

@pytest.mark.distributed
def test_quantized_wire_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # the script sets its own
    res = subprocess.run(
        [sys.executable,
         str(ROOT / "tests" / "distributed_checks" / "quantized_wire_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL QUANTIZED WIRE CHECKS PASSED" in res.stdout
