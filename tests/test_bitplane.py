"""Hypothesis property tests for the bit-plane pack/unpack subsystem.

Invariants: bit-exact roundtrip for every field width at arbitrary lengths
(including non-multiple-of-32 tails), exact word counts, Pallas-kernel-vs-
ref.py equivalence, and the float<->word tail-slot helpers of the wire
format (repro.core.bitplane)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitplane as core_bp  # noqa: E402
from repro.kernels.bitplane import ops, ref  # noqa: E402

SET = settings(max_examples=25, deadline=None)
WIDTH = st.sampled_from(ref.WIDTHS)


def _symbols(seed, d, width):
    return jax.random.randint(jax.random.PRNGKey(seed), (d,), 0,
                              1 << width).astype(jnp.uint32)


# --------------------------------------------------------------------------- #
# Roundtrip + word-count invariants (ref path).
# --------------------------------------------------------------------------- #

@SET
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 4100), width=WIDTH)
def test_roundtrip_bit_exact(seed, d, width):
    v = _symbols(seed, d, width)
    words = ops.pack_bits(v, width)
    assert words.dtype == jnp.uint32
    assert words.shape == (ref.num_words(d, width),)
    back = ops.unpack_bits(words, width, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(v))


@SET
@given(d=st.integers(1, 10_000), width=WIDTH)
def test_word_count_exact(d, width):
    per = ref.WORD // width
    assert ref.num_words(d, width) == -(-d // per) == (d + per - 1) // per


@SET
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 1000), width=WIDTH)
def test_pack_masks_out_of_range_symbols(seed, d, width):
    """Symbols are masked to the field width: high bits never leak into
    neighbouring fields."""
    v = _symbols(seed, d, width)
    noise = (jax.random.randint(jax.random.PRNGKey(seed + 1), (d,), 0, 1 << 14)
             .astype(jnp.uint32) << jnp.uint32(width))
    np.testing.assert_array_equal(np.asarray(ops.pack_bits(v | noise, width)),
                                  np.asarray(ops.pack_bits(v, width)))


# --------------------------------------------------------------------------- #
# Pallas kernel == ref oracle (interpret mode).
# --------------------------------------------------------------------------- #

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.sampled_from([1, 31, 33, 127, 1000, 4097]),
       width=st.sampled_from([1, 2, 16]))
def test_pallas_pack_matches_ref(seed, d, width):
    v = _symbols(seed, d, width)
    got = ops.pack_bits(v, width, force_pallas=True)
    want = ref.pack_bits(v, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.sampled_from([1, 31, 33, 127, 1000, 4097]),
       width=st.sampled_from([1, 2, 16]))
def test_pallas_unpack_matches_ref(seed, d, width):
    v = _symbols(seed, d, width)
    words = ref.pack_bits(v, width)
    got = ops.unpack_bits(words, width, d, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


# --------------------------------------------------------------------------- #
# Tail-slot float <-> word helpers (wire format).
# --------------------------------------------------------------------------- #

@SET
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 33))
def test_floats_roundtrip_f32_exact(seed, m):
    v = jax.random.normal(jax.random.PRNGKey(seed), (m,)) * 10.0
    w = core_bp.floats_to_words(v, "float32")
    assert w.shape == (core_bp.float_words(m, "float32"),) == (m,)
    np.testing.assert_array_equal(
        np.asarray(core_bp.words_to_floats(w, m, "float32")), np.asarray(v))


@SET
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 33))
def test_floats_roundtrip_bf16_is_bf16_rounding(seed, m):
    """16-bit wire: roundtrip == one bf16 rounding, two floats per word."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (m,)) * 10.0
    w = core_bp.floats_to_words(v, "bfloat16")
    assert w.shape == (core_bp.float_words(m, "bfloat16"),) == ((m + 1) // 2,)
    back = core_bp.words_to_floats(w, m, "bfloat16")
    want = v.astype(jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(want))


def test_wire_bits_and_rejects_unsupported():
    assert core_bp.wire_bits("float32") == 32
    assert core_bp.wire_bits("bfloat16") == 16
    with pytest.raises(ValueError):
        core_bp.wire_bits("float64")
