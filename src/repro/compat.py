"""Cross-version JAX compatibility shims.

The only shim today is :func:`shard_map`.  The repo is written against the
jax ≥ 0.5 surface (``jax.shard_map`` with the ``check_vma`` keyword); on
0.4.x the same transform lives at ``jax.experimental.shard_map.shard_map``
and the replication-lint flag is called ``check_rep``.  Every call site in
src/, tests/ and benchmarks/ routes through here so the version split stays
in one place.
"""
from __future__ import annotations

import functools
from typing import Any

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax ≥ 0.5); on 0.4.x ``psum(1, axis)``, which
    constant-folds to the static axis size without emitting a collective."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f: Any = None, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, check_rep: bool | None = None,
              **kwargs):
    """Version-portable ``jax.shard_map``.

    Accepts either lint-flag spelling (``check_vma`` is the jax ≥ 0.5 name,
    ``check_rep`` the 0.4.x one) and forwards whichever the installed jax
    understands.  Usable directly or via ``functools.partial`` as a
    decorator, exactly like ``jax.shard_map``.
    """
    if f is None:
        return functools.partial(
            shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, check_rep=check_rep, **kwargs)
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        kwargs[_CHECK_KW] = check
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
