"""olmoe-1b-7b: 16L d2048 16H (kv=16, head_dim=128) v50304; 64 experts
top-8, expert ff=1024.  [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoECfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1024, vocab_size=50304,
    moe=MoECfg(num_experts=64, top_k=8, d_ff_expert=1024))
