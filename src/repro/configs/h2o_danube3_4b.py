"""h2o-danube-3-4b: 24L d3840 32H (kv=8, head_dim=120) ff10240 v32000 —
llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, head_dim=120, d_ff=10240, vocab_size=32000,
    window=4096, rope_theta=1e4, sub_quadratic=True)
