"""mistral-large-123b: 88L d12288 96H (kv=8, head_dim=128) ff28672 v32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense", num_layers=88, d_model=12288,
    num_heads=96, num_kv_heads=8, head_dim=128, d_ff=28672, vocab_size=32768,
    rope_theta=1e6)
