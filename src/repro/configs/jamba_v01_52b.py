"""jamba-v0.1-52b: 32L d4096; hybrid period-8 [m,m,m,a,m,m,m,m] (1:7
attn:mamba), attention 32H (kv=8, head_dim=128); MoE 16 experts top-2 every
other layer (expert ff=14336), dense ff=14336 otherwise; v65536.
Note: Jamba v0.1 uses Mamba-1 mixers; we use Mamba-2/SSD blocks (state-space
dual form) as the TPU-native equivalent — DESIGN.md §6.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoECfg
from repro.models.ssm import SSMCfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=65536,
    attn_every=8, attn_offset=3, sub_quadratic=True,
    moe=MoECfg(num_experts=16, top_k=2, d_ff_expert=14336, every_n=2),
    ssm=SSMCfg(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=256))
