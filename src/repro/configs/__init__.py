from repro.configs.base import ArchConfig, RunConfig, ShapeSpec, SHAPES  # noqa: F401
from repro.configs.registry import get_config, get_run_config, list_archs, smoke_config  # noqa: F401
