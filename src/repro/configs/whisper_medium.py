"""whisper-medium: enc-dec 24+24L d1024 16H (MHA kv=16, head_dim=64) ff4096
v51865 — conv/mel frontend STUBBED (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, tie_embeddings=True)
