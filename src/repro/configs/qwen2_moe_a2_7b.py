"""qwen2-moe-a2.7b: 24L d2048 16H (kv=16, head_dim=128) v151936; 60 routed
experts (padded to 64 for EP16; 4 inert) top-4, expert ff=1408, plus 4
shared experts (one dense ff=5632 MLP).  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=151936,
    moe=MoECfg(num_experts=60, top_k=4, d_ff_expert=1408,
               num_shared=4, d_ff_shared=5632))
