"""Architecture registry + per-(arch, shape) run configurations.

``get_run_config`` holds the production tunables discovered during the
dry-run / §Perf iterations (microbatches for activation memory, FSDP for
≥30B params, pure-DP for mamba2-130m, attention chunk sizes per context
length).  EXPERIMENTS.md records why each override exists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs import (h2o_danube3_4b, jamba_v01_52b, llava_next_34b,
                           mamba2_130m, minitron_4b, mistral_large_123b,
                           olmoe_1b_7b, qwen2_moe_a2_7b, qwen3_4b,
                           whisper_medium)
from repro.configs.base import SHAPES, ArchConfig, RunConfig
from repro.core import types as core_types
from repro.models.moe import MoECfg
from repro.models.ssm import SSMCfg

_ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen3_4b, h2o_danube3_4b, minitron_4b, mistral_large_123b,
              whisper_medium, qwen2_moe_a2_7b, olmoe_1b_7b, mamba2_130m,
              jamba_v01_52b, llava_next_34b)
}


def list_archs():
    return sorted(_ARCHS)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    return _ARCHS[name]


# --------------------------------------------------------------------------- #
# Run configs.
# --------------------------------------------------------------------------- #

# FSDP set: >8B params — replicated f32 optimizer states would not fit.
# qwen2-moe joined after the dry-run measured 24 GiB/dev at mb=4 (14.3B
# total params: 10.5 GiB/dev of master+m+v over model-sharding alone).
_BIG = {"mistral-large-123b", "jamba-v0.1-52b", "llava-next-34b",
        "qwen2-moe-a2.7b"}

# default compression for train shapes: the paper's 1-bit operating point
# (fraction = 1/r = 1/16, Example 7) across the pod axis; exact in-pod.
_TRAIN_COMPRESSION = core_types.CompressionConfig(
    encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1.0 / 16,
                                   center="mean"),
    mode="shared_support", axes=("pod",))

# Named wire-path presets spanning the paper's trade-off curve, selectable
# by string via get_run_config(compression="..."). All cross-pod by
# default; the axes are re-pointed at ("data",) for single-pod runs.
COMPRESSION_PRESETS: Dict[str, core_types.CompressionConfig] = {
    # Example 7: fixed-k at k/d = 1/r, TPU-native shared support (psum).
    "fixed_k_1bit": _TRAIN_COMPRESSION,
    # Eq. (1) at p = 1/r via the §4.4 seed trick (capacity-padded values).
    # Flat-mesh scatter decode (docs/DESIGN.md §12): each node decodes only
    # its ⌈d/n⌉ coordinate shard of all n peer rows — per-node decode FLOPs
    # and PRNG draws drop from O(n·d) to O(d); the decoded-shard all_gather
    # is billed honestly via the codec's scatter_bits.
    "bernoulli_seed_1bit": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="bernoulli", fraction=1.0 / 16,
                                       center="mean"),
        mode="gather_decode", axes=("pod",), scatter_decode=True),
    # §4.5 Eq. (11): packed 1-bit sign plane + (vmin, vmax) tail.
    # Word-aligned flat scatter decode (docs/DESIGN.md §13): shard
    # boundaries snap to uint32 word boundaries of the packed plane, each
    # node unpack+accumulates only its word window of all n rows (fused
    # kernel), and the decoded-shard all_gather is billed via scatter_bits.
    "binary_packed": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="binary", center="min"),
        mode="gather_decode", axes=("pod",), scatter_decode=True),
    # §7.1 Eq. (21): packed 2-bit plane, 1/16 pass-through mass; §13
    # scatter decode with the per-shard pass-through-count exchange.
    "ternary_packed": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="ternary", fraction=1.0 / 16,
                                       center="min"),
        mode="gather_decode", axes=("pod",), scatter_decode=True),
    # §7.2: seeded per-bucket Hadamard rotation composed onto the packed
    # 1-bit plane (Suresh et al.'s rotated one-bit estimator / DRIVE's
    # backbone) — payload identical to binary_packed at power-of-two
    # bucket sizes, wire overhead is the rotation seed only.
    "rotated_binary": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="binary", center="min",
                                       rotation=True),
        mode="gather_decode", axes=("pod",)),
    # §7.2 rotation composed onto the fixed-k seed-trick gather path.
    "rotated_fixed_k": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1.0 / 16,
                                       center="mean", rotation=True),
        mode="gather_decode", axes=("pod",)),
    # §6 per-coordinate optimal (p1, p2) on the ternary 2-bit plane
    # (optimal.ternary_optimal_probs): same wire format and capacity rule
    # as ternary_packed, strictly lower MSE at equal payload.
    "ternary_opt": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="ternary", fraction=1.0 / 16,
                                       probs="optimal", center="min"),
        mode="gather_decode", axes=("pod",)),
    # Error feedback as a wire-layer wrap (repro.core.wire.ef): residual-
    # recycling contractive messages in the inner codec's exact format —
    # payload byte-identical to the EF-free preset, residuals local.
    "ef_fixed_k": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1.0 / 16,
                                       center="mean"),
        mode="gather_decode", axes=("pod",), error_feedback=True),
    # flat scatter decode like bernoulli_seed_1bit (EF delegates the shard
    # decode to the inner codec; payload-equality with the EF-free preset
    # is preserved because both gain the same scatter collectives).
    "ef_bernoulli": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="bernoulli", fraction=1.0 / 16,
                                       center="mean"),
        mode="gather_decode", axes=("pod",), error_feedback=True,
        scatter_decode=True),
    # §13 word-aligned scatter decode via EF's delegation to the plane
    # codecs (same collectives as the EF-free presets).
    "ef_binary": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="binary", center="min"),
        mode="gather_decode", axes=("pod",), error_feedback=True,
        scatter_decode=True),
    "ef_ternary": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="ternary", fraction=1.0 / 16,
                                       center="min"),
        mode="gather_decode", axes=("pod",), error_feedback=True,
        scatter_decode=True),
    # EF ∘ rotation ∘ binary — the DRIVE-style stack: rotate, 1-bit
    # quantize, recycle the residual (EF outermost; docs/DESIGN.md §8).
    # Scatter decode runs in ROTATED space at the padded length (§13);
    # one inverse FWHT after the reassembling all_gather.
    "ef_rotated_binary": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="binary", center="min",
                                       rotation=True),
        mode="gather_decode", axes=("pod",), error_feedback=True,
        scatter_decode=True),
    # Hierarchical two-level presets (docs/DESIGN.md §11): exact pmean
    # inside the host ("data") axis, compressed codec only across the
    # "pod" axis, reduce-scatter decode sharded over the inner group.
    # On a single-axis mesh, compression_preset(name, axes=...) flattens
    # these to the plain codec (colliding inner axes are dropped).
    "hier_fixed_k": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="fixed_k", fraction=1.0 / 16,
                                       center="mean"),
        mode="gather_decode", axes=("pod",), inner_axes=("data",),
        scatter_decode=True),
    "hier_bernoulli": core_types.CompressionConfig(
        encoder=core_types.EncoderSpec(kind="bernoulli", fraction=1.0 / 16,
                                       center="mean"),
        mode="gather_decode", axes=("pod",), inner_axes=("data",),
        scatter_decode=True),
}


def compression_preset(name: str,
                       axes: Tuple[str, ...] | None = None
                       ) -> core_types.CompressionConfig:
    """Resolve a named preset, optionally re-pointing its mesh axes.

    Re-pointing onto an axis a hierarchical preset uses as an inner axis
    flattens the hierarchy: the colliding inner axes are dropped, so e.g.
    the ``hier_*`` presets degrade to their plain flat codec on a
    single-axis mesh — every all-preset enumeration (benchmarks, golden
    wire matrix, distributed checks) keeps working unchanged.
    ``scatter_decode`` survives the flattening: the scatter decomposition
    simply re-targets the flat-mesh form (DESIGN.md §12), sharding over
    the re-pointed axes themselves with the shard collectives billed via
    ``scatter_bits`` — so a flattened ``hier_bernoulli`` keeps the sharded
    decode instead of falling back to the O(n·d) flat unpack.
    """
    if name not in COMPRESSION_PRESETS:
        raise KeyError(f"unknown compression preset {name!r}; "
                       f"have {sorted(COMPRESSION_PRESETS)}")
    cfg = COMPRESSION_PRESETS[name]
    if axes is None:
        return cfg
    inner = tuple(a for a in cfg.inner_axes if a not in axes)
    return dataclasses.replace(cfg, axes=axes, inner_axes=inner)


def robust_preset(name: str, policy: str,
                  axes: Tuple[str, ...] | None = None
                  ) -> core_types.CompressionConfig:
    """A named preset with a robust decode policy (DESIGN.md §14).

    ``policy`` is a decode-policy string ("trim(1)", "median",
    "mean_trim(1)", or "mean"/"trim(0)" for the plain decoder).  The wire
    format — payload bytes, seeds, scatter split — is exactly the base
    preset's: only the decode-time reduction changes, so every accounting
    identity and the golden wire matrix stay pinned.  Deliberately NOT a
    new COMPRESSION_PRESETS entry: the preset dict is the golden-coverage
    universe (tests assert golden keys == preset names exactly), and a
    decode policy is an orthogonal axis over it, not a new wire protocol.
    Raises like ``resolve`` for psum-reduce presets (fixed_k_1bit, dense
    simulation) under a non-mean policy — those sum rows inside the
    collective, leaving nothing to trim.
    """
    return dataclasses.replace(compression_preset(name, axes),
                               decode_policy=policy)


def get_run_config(arch: str, shape: str, *, multi_pod: bool = False,
                   compression: core_types.CompressionConfig | str | None = None
                   ) -> RunConfig:
    cfg = get_config(arch)
    kind = SHAPES[shape].kind

    if isinstance(compression, str):
        compression = compression_preset(
            compression, axes=("pod",) if multi_pod else ("data",))

    mb = 1
    if kind == "train":
        # microbatch counts sized from dry-run memory_analysis (§Dry-run):
        # qwen3/danube/minitron/qwen2-moe sat at 16.5–25.7 GiB with mb=2.
        mb = {"mistral-large-123b": 16, "llava-next-34b": 8,
              "jamba-v0.1-52b": 8, "qwen3-4b": 4, "h2o-danube-3-4b": 4,
              "minitron-4b": 4, "qwen2-moe-a2.7b": 4, "olmoe-1b-7b": 2,
              "whisper-medium": 1, "mamba2-130m": 1}.get(arch, 2)

    if compression is None:
        if kind == "train":
            axes = ("pod",) if multi_pod else ("data",)
            compression = dataclasses.replace(_TRAIN_COMPRESSION, axes=axes)
        else:
            compression = core_types.CompressionConfig(mode="none")

    chunk_q = chunk_k = 1024
    if SHAPES[shape].seq_len >= 32768 and kind != "decode":
        chunk_q, chunk_k = 1024, 2048

    return RunConfig(
        microbatches=mb,
        fsdp=cfg.name in _BIG,
        model_parallel=cfg.name != "mamba2-130m",
        seq_shard=cfg.name != "mamba2-130m",
        attn_chunk_q=chunk_q, attn_chunk_k=chunk_k,
        remat=(kind == "train"),
        compression=compression)


# --------------------------------------------------------------------------- #
# Reduced smoke variants: same family/topology, tiny dims — one CPU
# forward/train step per arch (tests/test_models_smoke.py).
# --------------------------------------------------------------------------- #

def smoke_config(name: str) -> ArchConfig:
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke", family=cfg.family,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, qk_norm=cfg.qk_norm,
        window=16 if cfg.window else None, rope_theta=cfg.rope_theta,
        tie_embeddings=cfg.tie_embeddings, sub_quadratic=cfg.sub_quadratic)
    if cfg.moe is not None:
        kw["moe"] = MoECfg(num_experts=4, top_k=2, d_ff_expert=64,
                           num_shared=(2 if cfg.moe.num_shared else 0),
                           d_ff_shared=(64 if cfg.moe.num_shared else 0),
                           every_n=cfg.moe.every_n)
    if cfg.ssm is not None:
        kw["ssm"] = SSMCfg(d_state=16, head_dim=16, expand=2,
                           conv_width=4, chunk=16)
    if cfg.family == "hybrid":
        kw["num_layers"] = 4
        kw["attn_every"] = 4
        kw["attn_offset"] = 1
    if cfg.family == "encdec":
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 24
    if cfg.family == "vlm":
        kw["num_patches"] = 8
    return ArchConfig(**kw)
