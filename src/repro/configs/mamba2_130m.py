"""mamba2-130m: 24L d768 attention-free SSD, ssm_state=128, d_inner=1536
(24 heads x 64), v50280 (padded to 50288 for TP when used; this arch runs
pure-DP: model axis folds into batch — DESIGN.md §4).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig
from repro.models.ssm import SSMCfg

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=1, num_kv_heads=1, head_dim=64, d_ff=0, vocab_size=50280,
    tie_embeddings=True, sub_quadratic=True,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256))
