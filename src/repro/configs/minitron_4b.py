"""minitron-4b: 32L d3072 24H (kv=8, head_dim=128) ff9216 v256000 — pruned
nemotron.  24 q-heads pad to 32 for TP16 (+33% attn flops, logged in
roofline useful-FLOPs ratio).  [arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=9216, vocab_size=256000,
    rope_theta=1e4)
