"""Architecture + shape + run configuration schema.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :data:`SHAPES`.  ``RunConfig`` carries the
per-(arch × shape × mesh) tunables the perf loop iterates on
(microbatches, remat, chunk sizes, compression axes, FSDP).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import types as core_types
from repro.models.moe import MoECfg
from repro.models.ssm import SSMCfg


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads
    qk_norm: bool = False
    window: Optional[int] = None      # sliding-window attention width
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    attn_every: Optional[int] = None  # hybrid: one attn layer per this many
    attn_offset: int = 0              # position of attn layer within period
    encoder_layers: int = 0           # enc-dec only
    encoder_seq: int = 0              # whisper frame count (stub frontend)
    num_patches: int = 0              # vlm: patch embeddings prepended (stub)
    # which shapes this arch supports (long_500k only for sub-quadratic)
    sub_quadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def vocab_padded(self, tp: int) -> int:
        return -(-self.vocab_size // tp) * tp

    def supports(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d, l = self.d_model, self.num_layers
        hd = self.hd
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        dense_mlp = 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = l if self.attn_every is None else l // self.attn_every
        if self.family in ("dense", "vlm"):
            per_layer = attn + dense_mlp
            total = l * per_layer
        elif self.family == "moe":
            m = self.moe
            experts = 3 * d * m.d_ff_expert * m.num_experts
            shared = 3 * d * m.d_ff_shared if m.num_shared else 0
            total = l * (attn + experts + shared + d * m.num_experts)
        elif self.family == "ssm":
            s = self.ssm
            din = s.d_inner(d)
            total = l * (2 * d * din + 2 * d * s.d_state + d * s.nheads(d)
                         + din * d)
        elif self.family == "hybrid":
            s = self.ssm
            m = self.moe
            din = s.d_inner(d)
            mamba = 2 * d * din + 2 * d * s.d_state + d * s.nheads(d) + din * d
            n_moe = l // m.every_n
            experts = 3 * d * m.d_ff_expert * m.num_experts
            total = (n_attn * attn + (l - n_attn) * mamba
                     + n_moe * experts + (l - n_moe) * dense_mlp)
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn + 2 * d * self.d_ff)
            dec = l * (2 * attn + 2 * d * self.d_ff)  # self + cross attn
            total = enc + dec
        else:
            raise ValueError(self.family)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if self.family not in ("moe", "hybrid"):
            return self.param_count()
        d, l = self.d_model, self.num_layers
        m = self.moe
        hd = self.hd
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            act_experts = 3 * d * m.d_ff_expert * m.top_k
            shared = 3 * d * m.d_ff_shared if m.num_shared else 0
            return int(l * (attn + act_experts + shared + d * m.num_experts) + emb)
        s = self.ssm
        din = s.d_inner(d)
        mamba = 2 * d * din + 2 * d * s.d_state + d * s.nheads(d) + din * d
        n_attn = l // self.attn_every
        n_moe = l // m.every_n
        act = 3 * d * m.d_ff_expert * m.top_k
        dense_mlp = 3 * d * self.d_ff
        return int(n_attn * attn + (l - n_attn) * mamba + n_moe * act
                   + (l - n_moe) * dense_mlp + emb)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Per-(arch × shape × mesh) execution tunables."""
    microbatches: int = 1
    fsdp: bool = False
    model_parallel: bool = True       # False: fold model axis into batch DP
    seq_shard: bool = True            # sequence-parallel residual stream
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 1024
    remat: bool = True
    # §Perf qwen3 iteration 1 (REFUTED): recomputing attention in backward
    # instead of storing softmax residuals RAISED HBM traffic 10.6->11.6s —
    # XLA cannot fuse dot->softmax->dot, so scores cross HBM once per sweep
    # either way and the recompute adds a sweep.  Kept as a knob; the real
    # fix is the fused Pallas flash kernel (kernels/flash_attention).
    remat_attention: bool = False
    # "flash": fused Pallas kernels on TPU (fwd + FA2-style bwd,
    # kernels/flash_attention); transparently falls back to the XLA
    # online-softmax path off-TPU.  "xla": force the chunked path.
    attn_impl: str = "flash"
    compression: core_types.CompressionConfig = dataclasses.field(
        default_factory=lambda: core_types.CompressionConfig(mode="none"))
    compute_dtype: str = "bfloat16"
