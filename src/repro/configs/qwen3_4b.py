"""qwen3-4b: 36L d2560 32H (GQA kv=8, head_dim=128) ff9728 v151936 — qk_norm.
[hf:Qwen/Qwen3-8B family; hf-verified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=9728,
    vocab_size=151936, qk_norm=True, rope_theta=1e6, tie_embeddings=True)
