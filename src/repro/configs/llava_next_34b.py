"""llava-next-34b: 60L d7168 56H (kv=8, head_dim=128) ff20480 v64000 — VLM;
anyres patch frontend STUBBED (input_specs provides patch embeddings,
num_patches=1152 prepended to the token stream).  56 q-heads pad to 64 for
TP16 (+14% attn flops, logged).  [hf:llava-hf family; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    rope_theta=5e6, num_patches=1152)
