"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis maps
onto DCN links between pods — it is the default compression axis for the
paper's gradient aggregation (DESIGN.md §2).

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests."""
    return jax.make_mesh((data, model), ("data", "model"))
