"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report [--out experiments/tables.md]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

HERE = os.path.dirname(__file__)
DRYRUN = os.path.abspath(os.path.join(HERE, "..", "..", "..", "experiments",
                                      "dryrun"))

ARCH_ORDER = ["qwen3-4b", "h2o-danube-3-4b", "minitron-4b",
              "mistral-large-123b", "whisper-medium", "qwen2-moe-a2.7b",
              "olmoe-1b-7b", "mamba2-130m", "jamba-v0.1-52b",
              "llava-next-34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh_tag: str) -> List[Dict]:
    out = []
    d = os.path.join(DRYRUN, mesh_tag)
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    key = {a: i for i, a in enumerate(ARCH_ORDER)}
    skey = {s: i for i, s in enumerate(SHAPE_ORDER)}
    out.sort(key=lambda r: (key.get(r["arch"], 99), skey.get(r["shape"], 9)))
    return out


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | status | HBM GiB/dev | collectives "
             "(exec counts) | wire GB/dev | compile s |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | "
                         f"{reason} | — | — |")
            continue
        colls = ", ".join(f"{k}×{round(v)}" for k, v in
                          sorted(r["collectives"]["counts"].items()))
        wire = r["roofline"]["wire_bytes_dev"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{fmt_bytes(r['memory']['total_dev'])} | {colls or '—'} | "
            f"{wire:.1f} | {r['compile_s']} |")
    return "\n".join(lines)


_FSDP_ARCHS = {"mistral-large-123b", "jamba-v0.1-52b", "llava-next-34b"}


def bottleneck_note(r: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    rl = r["roofline"]
    dom = rl["dominant"]
    arch, shape = r["arch"], r["shape"]
    kind = ("train" if "train" in shape
            else "prefill" if "prefill" in shape else "decode")
    if dom == "memory":
        if kind == "decode":
            if arch in _FSDP_ARCHS:
                return ("weight streaming dominates at 1 token/step: "
                        "grow decode batch or quantize weights (int8)")
            return ("KV-cache + weight streaming: fuse decode attention and "
                    "grow per-chip batch")
        if arch == "mamba2-130m":
            return ("SSD chunk intermediates: fuse the chunk scan into a "
                    "Pallas kernel / larger chunk size")
        return ("materialized attention-score tiles (XLA can't fuse "
                "dot-softmax-dot): flash-attention kernel (§Perf A)")
    if dom == "collective":
        if arch in _FSDP_ARCHS and kind == "train":
            return ("FSDP weight gathers × microbatches: fewer microbatches "
                    "(needs flash-kernel memory headroom, §Perf B)")
        if kind == "train":
            return ("SP gathers ∝ B_loc·(tp−1)/tp: re-factor mesh toward "
                    "more DP / less TP (§Perf A2)")
        return "weight gathers at 1 token/step: cache gathered weights"
    return ("compute-bound: cut remat recompute and causal-mask waste "
            "(causal-aware chunk scheduling)")


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful-FLOPs ratio | roofline fraction | "
             "what moves the dominant term |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {bottleneck_note(r)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    chunks = []
    for tag, title in (("pod16x16", "single pod (16×16 = 256 chips)"),
                       ("pod2x16x16", "multi-pod (2×16×16 = 512 chips)")):
        recs = load(tag)
        if not recs:
            continue
        chunks.append(f"### Dry-run — {title}\n\n{dryrun_table(recs)}\n")
        if tag == "pod16x16":
            chunks.append(f"### Roofline — {title}\n\n{roofline_table(recs)}\n")
    text = "\n".join(chunks)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
