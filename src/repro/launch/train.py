"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --steps 100 --ckpt-dir /tmp/ckpt [--smoke] [--devices 8]

``--smoke`` uses the arch's reduced config (runs on CPU); the full config
is only practical on real accelerators — the multi-pod configuration is
exercised via launch/dryrun.py.  Device simulation (``--devices``) must be
set before jax initializes, which is why this module parses argv before
importing jax.
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="simulate N host devices (CPU)")
    ap.add_argument("--data", type=int, default=0, help="data-axis size")
    ap.add_argument("--model", type=int, default=0, help="model-axis size")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable backward-pipelined bucket sync "
                         "(BucketSpec.overlap); keeps the post-backward "
                         "reference schedule")
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    from repro.configs.base import SHAPES, RunConfig, ShapeSpec
    from repro.configs.registry import (get_config, get_run_config,
                                        smoke_config)
    from repro.core import types as core_types
    from repro.optim.optimizers import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    n = jax.device_count()
    data = args.data or max(1, n // max(1, args.model or 1))
    model = args.model or (n // data)
    mesh = jax.make_mesh((data, model), ("data", "model"))

    if args.smoke:
        cfg = smoke_config(args.arch)
        shape = ShapeSpec("cli", "train", args.seq, args.batch)
        comp = (core_types.CompressionConfig(mode="none") if args.no_compress
                else core_types.CompressionConfig(
                    encoder=core_types.EncoderSpec(kind="fixed_k",
                                                   fraction=1 / 16),
                    mode="shared_support", axes=("data",),
                    min_compress_size=1024, error_feedback=True))
        run = RunConfig(microbatches=1, model_parallel=model > 1,
                        seq_shard=model > 1, attn_chunk_q=min(128, args.seq),
                        attn_chunk_k=min(128, args.seq), remat=False,
                        compression=comp)
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        run = get_run_config(args.arch, args.shape)
    if args.no_overlap:
        import dataclasses
        comp = run.compression
        run = dataclasses.replace(
            run, compression=dataclasses.replace(
                comp, bucket=dataclasses.replace(comp.bucket, overlap=False)))

    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         log_every=max(1, args.steps // 20))
    tr = Trainer(mesh, cfg, run, shape, tcfg,
                 AdamWConfig(lr=args.lr, total_steps=args.steps))
    _, _, hist = tr.fit()
    for h in hist:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  lr {h['lr']:.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
