"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds per step:

    compute    = HLO_FLOPs_per_device   / 197e12      (bf16 peak, TPU v5e)
    memory     = HLO_bytes_per_device   / 819e9       (HBM bw)
    collective = wire_bytes_per_device  / 50e9        (ICI per-link bw)

``cost_analysis`` is per-device for SPMD modules.  Collective bytes are not
in cost_analysis: we parse the compiled HLO text, take every collective
op's result shape and apply standard ring-cost factors with the group size
S parsed from replica_groups:

    all-gather        (S−1)/S · out_bytes      (out = gathered buffer)
    all-reduce        2·(S−1)/S · buf_bytes
    reduce-scatter    (S−1) · out_bytes        (out = scattered piece)
    all-to-all        (S−1)/S · buf_bytes
    collective-permute  1 · out_bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [G, S] <= [N]: rows are groups of size S
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_op: Dict[str, float]        # wire bytes per device
    result_bytes_by_op: Dict[str, float]
    lines: List[str]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    lines: List[str] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = shape_bytes(shape_str)
        s = group_size(line)
        if s <= 1:
            continue
        if op == "all-gather":
            w = b * (s - 1) / s
        elif op == "all-reduce":
            w = 2 * b * (s - 1) / s
        elif op == "reduce-scatter":
            w = b * (s - 1)
        elif op == "all-to-all":
            w = b * (s - 1) / s
        else:  # collective-permute
            w = b
        counts[op] = counts.get(op, 0) + 1
        wire[op] = wire.get(op, 0.0) + w
        raw[op] = raw.get(op, 0.0) + b
        lines.append(line.strip()[:160])
    return CollectiveStats(counts, wire, raw, lines)


@dataclasses.dataclass
class Roofline:
    flops_dev: float
    bytes_dev: float
    wire_bytes_dev: float
    model_flops_dev: float
    steps_per_call: int = 1

    @property
    def compute_s(self):
        return self.flops_dev / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.bytes_dev / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes_dev / LINK_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self):
        return self.model_flops_dev / self.flops_dev if self.flops_dev else 0.0

    @property
    def roofline_fraction(self):
        """Fraction of peak achieved *if* the step runs at its dominant
        bound: useful model flops / (bound_s · PEAK)."""
        if self.bound_s == 0:
            return 0.0
        return self.model_flops_dev / (self.bound_s * PEAK_FLOPS)

    def as_dict(self):
        return {
            "flops_dev": self.flops_dev, "bytes_dev": self.bytes_dev,
            "wire_bytes_dev": self.wire_bytes_dev,
            "model_flops_dev": self.model_flops_dev,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "raw_cost_analysis": getattr(self, "raw_cost_analysis", None),
        }


def analyze(compiled, model_flops_total: float, num_devices: int,
            hlo_text: Optional[str] = None) -> Tuple[Roofline, CollectiveStats]:
    """Roofline terms from the compiled artifact.

    Primary source is the loop-aware HLO text analysis (repro.launch.hlo_cost)
    — XLA's cost_analysis() counts while-loop bodies once, under-reporting
    scanned layers by the trip count.  The raw cost_analysis numbers are
    retained in the returned stats for cross-checking.
    """
    from repro.launch import hlo_cost
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = hlo_cost.analyze_text(text)
    colls = CollectiveStats(counts=hc.coll_counts,
                            bytes_by_op=hc.coll_bytes_by_op,
                            result_bytes_by_op={},
                            lines=[f"exec_counts={hc.coll_exec}"])
    rl = Roofline(flops_dev=hc.flops, bytes_dev=hc.bytes,
                  wire_bytes_dev=hc.coll_wire_bytes,
                  model_flops_dev=model_flops_total / num_devices)
    rl.raw_cost_analysis = {"flops": float(ca.get("flops", 0.0)),
                            "bytes": float(ca.get("bytes accessed", 0.0))}
    return rl, colls
