import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the real train/serve step (the same builders used by
the trainer and the serving engine), lower it against ShapeDtypeStruct
inputs (no allocation), compile, and record:

  * memory_analysis()  — per-device bytes (proves the cell fits HBM),
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline,
  * the collective schedule parsed from the compiled HLO (wire bytes).

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the
EXPERIMENTS.md tables are generated from these by launch/report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import get_config, get_run_config, list_archs  # noqa: E402
from repro.launch import roofline as rl_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import encdec as encdec_lib  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim import optimizers as opt_lib  # noqa: E402
from repro.serving import engine  # noqa: E402
from repro.train import bucketing  # noqa: E402
from repro.train import train_step as ts  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, pspec))


def _param_sds(mesh, abstract_params, specs):
    return {k: _sds(v.shape, v.dtype, mesh, P(*specs[k]))
            for k, v in abstract_params.items()}


def batch_sds(mesh, cfg, shape, bspecs, *, with_labels=True):
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.family == "vlm":
        s_text = s - cfg.num_patches
        out["tokens"] = _sds((b, s_text), jnp.int32, mesh, bspecs["tokens"])
        out["patches"] = _sds((b, cfg.num_patches, cfg.d_model), jnp.float32,
                              mesh, bspecs["patches"])
        if with_labels:
            out["labels"] = _sds((b, s_text), jnp.int32, mesh, bspecs["labels"])
            out["mask"] = _sds((b, s_text), jnp.float32, mesh, bspecs["mask"])
    elif cfg.family == "encdec":
        enc_s = encdec_lib.enc_seq_padded(cfg, 16)
        out["tokens"] = _sds((b, s), jnp.int32, mesh, bspecs["tokens"])
        out["frames"] = _sds((b, enc_s, cfg.d_model), jnp.float32, mesh,
                             bspecs["frames"])
        if with_labels:
            out["labels"] = _sds((b, s), jnp.int32, mesh, bspecs["labels"])
            out["mask"] = _sds((b, s), jnp.float32, mesh, bspecs["mask"])
    else:
        out["tokens"] = _sds((b, s), jnp.int32, mesh, bspecs["tokens"])
        if with_labels:
            out["labels"] = _sds((b, s), jnp.int32, mesh, bspecs["labels"])
            out["mask"] = _sds((b, s), jnp.float32, mesh, bspecs["mask"])
    return out


def lower_cell(mesh, arch: str, shape_name: str, *, multi_pod: bool,
               run_override=None):
    """Lower + compile one cell; returns (record dict, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: long_500k excluded "
                          "(DESIGN.md §4)"}, None
    run = run_override or get_run_config(arch, shape_name, multi_pod=multi_pod)
    msizes = ts.mesh_sizes_of(mesh)
    n_dev = 1
    for v in msizes.values():
        n_dev *= v
    ctx = model_lib.make_ctx(cfg, run, msizes)
    t0 = time.time()
    sync_info = None

    if shape.kind == "train":
        step_fn, _, specs, bspecs, plan = ts.build_train_step(
            mesh, cfg, run, shape)
        aparams, _ = ts.abstract_specs(jax.random.PRNGKey(0), cfg, ctx,
                                       msizes, run)
        p_sds = _param_sds(mesh, aparams, specs)
        opt_sds = opt_lib.AdamWState(
            step=_sds((), jnp.int32, mesh, P()),
            m={k: _sds(v.shape, jnp.float32, mesh, P(*specs[k]))
               for k, v in aparams.items()},
            v={k: _sds(v.shape, jnp.float32, mesh, P(*specs[k]))
               for k, v in aparams.items()})
        use_ef = run.compression.error_feedback
        if plan is not None:
            # the issue schedule the lowered step executes (DESIGN.md §9):
            # per-bucket readiness order + whether sync is pipelined into
            # backward (microbatch accumulation forces post-backward).
            sync_info = {
                "buckets": len(plan.buckets),
                "compressed": sum(1 for b in plan.buckets
                                  if b.kind == "compressed"),
                "overlap": ts.overlap_enabled(plan, run),
                "schedule": list(plan.schedule()),
            }
        if use_ef and plan is not None:
            ef_sds = {bid: _sds(shp, jnp.float32, mesh, P())
                      for bid, shp in bucketing.ef_state_shapes(
                          plan, run.compression).items()}
        elif use_ef:
            ef_sds = {k: _sds(v.shape, jnp.float32, mesh, P(*specs[k]))
                      for k, v in aparams.items()}
        else:
            ef_sds = {k: _sds((), jnp.float32, mesh, P()) for k in aparams}
        b_sds = batch_sds(mesh, cfg, shape, bspecs)
        step_sds = _sds((), jnp.int32, mesh, P())
        lowered = step_fn.lower(p_sds, opt_sds, ef_sds, b_sds, step_sds)
        tokens = shape.global_batch * shape.seq_len
        mf = 6.0 * cfg.active_param_count() * tokens
    else:
        prefill_fn, decode_fn, specs, info = engine.build_serve_fns(
            mesh, cfg, run, shape)
        aparams, _ = ts.abstract_specs(jax.random.PRNGKey(0), cfg, ctx,
                                       msizes, run)
        # production serving stores weights in bf16 (layers cast at use
        # anyway); int/norm leaves keep their dtype.
        aparams = {k: jax.ShapeDtypeStruct(
            v.shape, jnp.bfloat16 if v.dtype == jnp.float32 else v.dtype)
            for k, v in aparams.items()}
        p_sds = _param_sds(mesh, aparams, specs)
        if shape.kind == "prefill":
            b_sds = batch_sds(mesh, cfg, shape, info["batch"],
                              with_labels=False)
            lowered = prefill_fn.lower(p_sds, b_sds)
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            cshapes = engine.global_cache_shapes(cfg, ctx, shape, msizes)
            c_sds = jax.tree.map(
                lambda s, ps: _sds(s.shape, s.dtype, mesh, ps),
                cshapes, engine.cache_pspecs(cfg, ctx, info["baxes"]))
            tok_sds = _sds((shape.global_batch, 1), jnp.int32, mesh,
                           info["tok"])
            pos_sds = _sds((), jnp.int32, mesh, P())
            lowered = decode_fn.lower(p_sds, c_sds, tok_sds, pos_sds)
            tokens = shape.global_batch  # one new token per sequence
        mf = 2.0 * cfg.active_param_count() * tokens

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hlo = compiled.as_text()
    rl, colls = rl_lib.analyze(compiled, mf, n_dev, hlo_text=hlo)
    ma = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(msizes[a]) for a in mesh.axis_names),
        "multi_pod": multi_pod,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "args_bytes_dev": int(ma.argument_size_in_bytes),
            "output_bytes_dev": int(ma.output_size_in_bytes),
            "temp_bytes_dev": int(ma.temp_size_in_bytes),
            "alias_bytes_dev": int(ma.alias_size_in_bytes),
            "total_dev": int(ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes
                             - ma.alias_size_in_bytes),
        },
        "roofline": rl.as_dict(),
        "collectives": {"counts": colls.counts,
                        "wire_bytes_by_op": colls.bytes_by_op},
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "compression": dataclasses_to_str(run.compression),
        "grad_sync": sync_info,
    }
    return rec, compiled


def dataclasses_to_str(c):
    if c.mode == "none":
        return "none"
    s = (f"{c.mode}:{c.encoder.kind}:f={c.encoder.fraction:.4f}:"
         f"axes={','.join(c.axes)}")
    if c.bucket.enabled:
        s += f":bucketed[overlap={'on' if c.bucket.overlap else 'off'}]"
    return s


def run_cell(arch, shape_name, multi_pod, outdir):
    tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(outdir, tag), exist_ok=True)
    path = os.path.join(outdir, tag, f"{arch}__{shape_name}.json")
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rec, _ = lower_cell(mesh, arch, shape_name, multi_pod=multi_pod)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "error", "error": repr(e),
               "trace": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dom={r['dominant']} comp={r['compute_s']:.3f}s "
                 f"mem={r['memory_s']:.3f}s coll={r['collective_s']:.3f}s "
                 f"hbm={rec['memory']['total_dev'] / 2**30:.2f}GiB "
                 f"compile={rec['compile_s']}s")
    print(f"[{status}] {tag} {arch} {shape_name}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        for a, s in cells:
            run_cell(a, s, mp, args.out)


if __name__ == "__main__":
    main()
