"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — a
layer-scanned transformer therefore under-reports FLOPs/bytes/collectives
by the trip count (≈ num_layers × microbatches).  This module re-derives
the three roofline inputs from the HLO text with loop multiplicity:

  1. split the module into computations; build per-computation symbol
     tables (instruction name → shape) including header parameters;
  2. build the call graph (fusion ``calls=``, while ``condition=/body=``,
     ``to_apply=``) and propagate execution multipliers from ENTRY, where a
     while body's multiplier is the parent's × trip count (trip = the
     largest integer constant in the condition computation — the loop
     bound jax emits for scan/fori/map);
  3. FLOPs: every ``dot`` op contributes 2·|result|·|contraction| × mult;
  4. bytes: for every instruction in non-fused computations, operand+result
     bytes × mult (fusion bodies are skipped — their internals stay in
     registers/cache; the fusion call site is counted) — the same
     definition XLA's per-op "bytes accessed" uses;
  5. collectives: per-op ring-cost wire bytes × mult (see ring factors in
     repro.launch.roofline).

Validated against hand-computed counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\))|(?:[\w\[\]\{\},]+))\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    # copies of loop-carried tuples are elided/aliased by buffer assignment;
    # counting them would charge full stacked-parameter arrays per layer.
    "copy", "copy-start", "copy-done",
}


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str       # result shape string
    op: str
    rest: str        # full text after '='


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]
    symbols: Dict[str, str]


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HEAD_RE.match(line) if line and not line.startswith(" ") else None
            if m and line.endswith("{"):
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\]\{\},]+))",
                                      m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), params, [], dict(params))
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, rest = im.group(1), im.group(2)
        om = _OP_RE.match(rest)
        if not om:
            continue
        shape, op = om.group(1), om.group(2)
        cur.symbols[name] = shape
        cur.instrs.append(Instr(name, shape, op, rest))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def trip_count(cond: Computation) -> int:
    best = 1
    for i in cond.instrs:
        for cm in _CONST_INT_RE.finditer(i.rest):
            best = max(best, int(cm.group(1)))
    return best


def multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = {entry: 1.0}
    fused_bodies = set()
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for i in comp.instrs:
            edges: List[Tuple[str, float]] = []
            cm = _CALLS_RE.search(i.rest)
            if cm:
                edges.append((cm.group(1), 1.0))
                if i.op == "fusion":
                    fused_bodies.add(cm.group(1))
            am = _APPLY_RE.search(i.rest)
            if am:
                edges.append((am.group(1), 1.0))
                fused_bodies.add(am.group(1))  # scalar reduce bodies
            bm = _BODY_RE.search(i.rest)
            condm = _COND_RE.search(i.rest)
            if bm and condm and condm.group(1) in comps:
                t = trip_count(comps[condm.group(1)])
                edges.append((bm.group(1), float(t)))
                edges.append((condm.group(1), float(t)))
            for child, w in edges:
                mult[child] = mult.get(child, 0.0) + m * w
                if child not in seen:
                    seen.add(child)
                    order.append(child)
    mult["__fused__"] = 0.0  # marker storage
    multipliers.fused_bodies = fused_bodies  # type: ignore[attr-defined]
    return mult


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    coll_wire_bytes: float
    coll_counts: Dict[str, int]        # static op counts
    coll_exec: Dict[str, float]        # execution counts (× trip)
    coll_bytes_by_op: Dict[str, float]


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def _wire_bytes(op: str, b: float, s: int) -> float:
    if s <= 1:
        return 0.0
    if op == "all-gather":
        return b * (s - 1) / s
    if op == "all-reduce":
        return 2 * b * (s - 1) / s
    if op == "reduce-scatter":
        return b * (s - 1)
    if op == "all-to-all":
        return b * (s - 1) / s
    return float(b)  # collective-permute


def _operand_names(i: Instr) -> List[str]:
    args = i.rest.split("(", 1)
    if len(args) < 2:
        return []
    return _OPERAND_RE.findall(args[1].split(")", 1)[0])


_CHAIN_OPS = ("convert", "bitcast", "copy", "reshape", "transpose")
# convert chains matter doubly on this CPU dry-run: XLA:CPU legalizes bf16
# by converting whole buffers to f32, which would charge phantom f32 cache
# copies that do not exist on the TPU target.  Resolving through the chain
# restores the TPU-native accounting (DESIGN.md §6 assumptions log).


def _resolver(fcomp: Computation):
    """Map every symbol to its chain-source (through convert/bitcast/...)."""
    src: Dict[str, str] = {}

    def resolve(name: str) -> str:
        seen = name
        while True:
            d = src.get(seen)
            if d is None or d == seen:
                return seen
            seen = d

    for fi in fcomp.instrs:
        if fi.op in _CHAIN_OPS:
            ops = _operand_names(fi)
            if len(ops) == 1:
                src[fi.name] = ops[0]
    return lambda n: _follow(src, n)


def _follow(src: Dict[str, str], n: str) -> str:
    while n in src:
        n = src[n]
    return n


def _fusion_bytes(res: float, ops: List[str], comp: Computation,
                  fcomp: Computation) -> float:
    """Traffic of one fusion call, alias-aware.

    Scan residual stacking / in-place accumulation appears as fused
    dynamic-update-slice whose operand 0 is (a convert/bitcast chain of) a
    fusion parameter: the big buffer is aliased in place and only the
    update window moves.  Parameters consumed only through
    dynamic-slice/gather are charged the slice, not the stack.
    """
    resolve = _resolver(fcomp)

    # uses attributed to chain-sources; chain ops themselves don't count
    uses: Dict[str, List[Tuple[Instr, int]]] = {}
    for fi in fcomp.instrs:
        if fi.op in _CHAIN_OPS:
            continue
        for idx, o in enumerate(_operand_names(fi)):
            uses.setdefault(resolve(o), []).append((fi, idx))

    dus_alias = set()
    dus_windows = 0.0
    dus_roots = set()
    for fi in fcomp.instrs:
        if fi.op != "dynamic-update-slice":
            continue
        fo = _operand_names(fi)
        if fo and resolve(fo[0]) in fcomp.params:
            dus_alias.add(resolve(fo[0]))
            dus_roots.add(fi.name)
            if len(fo) > 1:
                # write + (worst-case) read of the window
                dus_windows += 2.0 * shape_bytes(fcomp.symbols.get(fo[1], ""))

    # result side: if the fusion's root is (a chain of) an aliasing dus,
    # only the windows move; otherwise charge the full result minus aliased
    # accumulator shapes (multi-output tuples fall back to the subtract).
    root = fcomp.instrs[-1] if fcomp.instrs else None
    if root is not None and (root.name in dus_roots
                             or resolve(root.name) in dus_roots):
        res_total = dus_windows
    else:
        res_total = float(res)
        for p in dus_alias:
            res_total -= shape_bytes(fcomp.params[p])
        res_total = max(res_total, 0.0) + dus_windows

    # operand side
    fparams = list(fcomp.params)
    total = res_total
    for idx, o in enumerate(ops):
        pname = fparams[idx] if idx < len(fparams) else None
        if pname is None:
            total += shape_bytes(comp.symbols.get(o, ""))
            continue
        us = uses.get(pname, [])
        if pname in dus_alias and all(
                u.op == "dynamic-update-slice" and j == 0 for u, j in us):
            continue  # pure in-place accumulator
        if us and all(u.op in ("dynamic-slice", "gather") for u, _ in us):
            total += sum(shape_bytes(u.shape) for u, _ in us)
        else:
            total += shape_bytes(fcomp.params[pname])
    return total


def _instr_bytes(i: Instr, comp: Computation,
                 comps: Dict[str, Computation]) -> float:
    """HBM traffic model per instruction (see module docstring)."""
    res = shape_bytes(i.shape)
    ops = _operand_names(i)
    if i.op == "dynamic-slice":
        return 2.0 * res
    if i.op == "dynamic-update-slice":
        upd = shape_bytes(comp.symbols.get(ops[1], "")) if len(ops) > 1 else res
        return 2.0 * upd  # read+write the updated window; rest is aliased
    if i.op == "fusion":
        cm = _CALLS_RE.search(i.rest)
        fcomp = comps.get(cm.group(1)) if cm else None
        if fcomp is not None:
            return _fusion_bytes(res, ops, comp, fcomp)
        return float(res) + sum(shape_bytes(comp.symbols.get(o, ""))
                                for o in ops)
    # default: operands + result
    return float(res) + sum(shape_bytes(comp.symbols.get(o, "")) for o in ops)


LEGALIZATION_SIZE_THRESHOLD = 1 << 20  # 1 MiB


def _legalized_dtype_factor(i: Instr, comp: Computation,
                            base_op: str = "") -> float:
    """XLA:CPU legalizes bf16 collectives by upcasting to f32 (insert
    convert → run the collective in f32); on the TPU target they run
    natively in bf16.  Detection: the operand's producer is a convert(-ish
    fusion) from bf16 — or, for all-gather / reduce-scatter / all-to-all /
    collective-permute buffers above 1 MiB, by construction: this
    framework's SP activation gathers/scatters, FSDP weight gathers and EP
    dispatch all carry bf16; its genuine f32 collectives are exactly the
    all-reduces (exact gradient/loss psums), which are exempt from the
    size heuristic.  Charge bf16 wire (factor 1/2)."""
    if "f32[" not in i.shape:
        return 1.0
    ops = _operand_names(i)
    if ops:
        for fi in comp.instrs:
            if fi.name != ops[0]:
                continue
            if "convert" in fi.name or fi.op == "convert":
                for o2 in _operand_names(fi):
                    if "bf16[" in comp.symbols.get(o2, ""):
                        return 0.5
            break
    if (base_op != "all-reduce"
            and shape_bytes(i.shape) > LEGALIZATION_SIZE_THRESHOLD):
        return 0.5
    return 1.0


def analyze_text(text: str) -> HloCost:
    comps = parse_computations(text)
    entry = _entry_name(comps, text)
    mult = multipliers(comps, entry)
    fused = getattr(multipliers, "fused_bodies", set())

    flops = 0.0
    byts = 0.0
    cw = 0.0
    ccounts: Dict[str, int] = {}
    cexec: Dict[str, float] = {}
    cbytes: Dict[str, float] = {}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for i in comp.instrs:
            base_op = i.op.replace("-start", "").replace("-done", "")
            # ---- flops: dots everywhere (incl. inside fusions)
            if base_op in ("dot", "dot_general") or i.op.startswith("dot"):
                lm = _LHS_CONTRACT_RE.search(i.rest)
                ops = _OPERAND_RE.findall(i.rest.split("(", 1)[1])
                lhs_shape = comp.symbols.get(ops[0]) if ops else None
                if lm is not None and lhs_shape:
                    sd = shape_dims(lhs_shape)
                    if sd:
                        dims = sd[0][1]
                        contract = 1
                        for idx in lm.group(1).split(","):
                            if idx:
                                contract *= dims[int(idx)]
                        out_elems = 1
                        for _, od in shape_dims(i.shape):
                            for d in od:
                                out_elems *= d
                        flops += 2.0 * out_elems * contract * m
            # ---- collectives
            if base_op in COLLECTIVE_OPS and "-done" not in i.op:
                b = shape_bytes(i.shape) * _legalized_dtype_factor(
                    i, comp, base_op)
                s = _group_size(i.rest)
                w = _wire_bytes(base_op, b, s)
                ccounts[base_op] = ccounts.get(base_op, 0) + 1
                cexec[base_op] = cexec.get(base_op, 0.0) + m
                cbytes[base_op] = cbytes.get(base_op, 0.0) + w * m
                cw += w * m
            # ---- bytes (skip fusion internals and bookkeeping ops)
            if in_fusion or i.op in _SKIP_BYTES_OPS:
                continue
            byts += _instr_bytes(i, comp, comps) * m
    return HloCost(flops=flops, bytes=byts, coll_wire_bytes=cw,
                   coll_counts=ccounts, coll_exec=cexec,
                   coll_bytes_by_op=cbytes)
