import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver for the three selected cells.

Runs named variants (mesh factorization, microbatching, compression
settings), extracts roofline terms per variant, and for attention archs
computes the *flash-kernel projection*: the measured XLA-path memory term
with materialized attention-score traffic (tensors whose trailing dims are
a (chunk_q, chunk_k) tile) replaced by the Pallas kernel's q+k+v+o
streaming traffic.  The kernel itself is validated in
tests/test_kernel_flash.py; XLA cannot express the dot→softmax→dot fusion,
so on the CPU-hosted dry-run the projection is arithmetic, clearly labeled.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell A|B|C
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import get_config, get_run_config  # noqa: E402
from repro.core import types as core_types  # noqa: E402
from repro.launch import dryrun, hlo_cost  # noqa: E402
from repro.launch import roofline as rl_lib  # noqa: E402

OUT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "..", "experiments", "hillclimb"))


def score_traffic_bytes(hlo_text: str, chunks=(512, 1024, 2048, 4096)) -> float:
    """Bytes of attention-score traffic: instructions whose result OR any
    operand has trailing dims forming a (chunk_q, chunk_k) score tile.
    The operand-side match catches the PV/dS dots and the softmax
    reduce-windows that *read* score tensors — all in-VMEM inside the
    flash kernel."""
    comps = hlo_cost.parse_computations(hlo_text)
    entry = hlo_cost._entry_name(comps, hlo_text)
    mult = hlo_cost.multipliers(comps, entry)
    fused = getattr(hlo_cost.multipliers, "fused_bodies", set())

    def tiled(shape_str: str) -> bool:
        for _, d in hlo_cost.shape_dims(shape_str):
            if len(d) >= 2 and d[-1] in chunks and d[-2] in chunks:
                return True
        return False

    total = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fused:
            continue
        for i in comp.instrs:
            if i.op in hlo_cost._SKIP_BYTES_OPS:
                continue
            is_score = tiled(i.shape)
            if not is_score:
                args = i.rest.split("(", 1)
                if len(args) > 1:
                    for o in hlo_cost._OPERAND_RE.findall(
                            args[1].split(")", 1)[0]):
                        if tiled(comp.symbols.get(o, "")):
                            is_score = True
                            break
            if is_score:
                total += hlo_cost._instr_bytes(i, comp, comps) * m
    return total


def flash_projection(rec, hlo_text, cfg, shape, n_dev):
    """memory term with score traffic replaced by kernel streaming traffic."""
    st = score_traffic_bytes(hlo_text)
    # kernel HBM traffic per sweep ≈ q+k+v+o; ≈ 3 sweeps (fwd, remat, bwd)
    tokens_dev = shape.global_batch * shape.seq_len / n_dev
    hq_frac = 1.0  # q,o full heads; k,v smaller (GQA) — bound with full
    qkvo = 4 * tokens_dev * cfg.num_heads * cfg.hd * 2 * 3 * hq_frac
    adj_bytes = rec["roofline"]["bytes_dev"] - st + qkvo * n_dev / n_dev
    return {
        "score_traffic_bytes_dev": st,
        "kernel_qkvo_bytes_dev": qkvo,
        "memory_s_flash": adj_bytes / rl_lib.HBM_BW,
        "bytes_dev_flash": adj_bytes,
    }


def run_variant(cell, name, arch, shape_name, mesh_axes, run_cfg,
                want_flash=False):
    mesh = jax.make_mesh(tuple(s for s, _ in mesh_axes),
                         tuple(a for _, a in mesh_axes))
    rec, compiled = dryrun.lower_cell(mesh, arch, shape_name,
                                      multi_pod=len(mesh_axes) == 3,
                                      run_override=run_cfg)
    if rec["status"] == "ok" and want_flash:
        cfg = get_config(arch)
        n_dev = 1
        for s, _ in mesh_axes:
            n_dev *= s
        rec["flash_projection"] = flash_projection(
            rec, compiled.as_text(), cfg, SHAPES[shape_name], n_dev)
    rec["variant"] = name
    rec["mesh_axes"] = [[s, a] for s, a in mesh_axes]
    os.makedirs(os.path.join(OUT, cell), exist_ok=True)
    with open(os.path.join(OUT, cell, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    fp = rec.get("flash_projection", {})
    extra = (f" | flash-mem={fp['memory_s_flash']:.3f}s"
             if fp else "")
    print(f"[{rec['status']}] {cell}:{name} "
          f"comp={r.get('compute_s', 0):.3f} mem={r.get('memory_s', 0):.3f} "
          f"coll={r.get('collective_s', 0):.3f} "
          f"hbm={rec.get('memory', {}).get('total_dev', 0) / 2**30:.2f}GiB"
          f"{extra}", flush=True)
    return rec


def cell_A():
    arch, shp = "qwen3-4b", "train_4k"
    base = get_run_config(arch, shp)
    run_variant("A", "A0_base_16x16", arch, shp,
                [(16, "data"), (16, "model")], base, want_flash=True)
    run_variant("A", "A1_remat_attn_16x16", arch, shp,
                [(16, "data"), (16, "model")],
                dataclasses.replace(base, remat_attention=True))
    run_variant("A", "A2_mesh_64x4", arch, shp,
                [(64, "data"), (4, "model")], base, want_flash=True)
    run_variant("A", "A3_mesh_32x8", arch, shp,
                [(32, "data"), (8, "model")], base, want_flash=True)
    # A2 blew HBM (params replicate over data without FSDP: ×4 vs tp=16);
    # A4 = 64×4 with FSDP — predicted +0.3s collective for bf16 weight
    # gathers, params/chip ÷64.
    run_variant("A", "A4_mesh_64x4_fsdp", arch, shp,
                [(64, "data"), (4, "model")],
                dataclasses.replace(base, fsdp=True), want_flash=True)
    # A4 leaves 10 GiB headroom: halve microbatches to halve the per-mb
    # FSDP gather wire (predicted coll 1.07 → ~0.75, activations ×2 ≈ 9 GiB)
    run_variant("A", "A5_mb2_64x4_fsdp", arch, shp,
                [(64, "data"), (4, "model")],
                dataclasses.replace(base, fsdp=True, microbatches=2),
                want_flash=True)


def cell_B():
    arch, shp = "jamba-v0.1-52b", "train_4k"
    base = get_run_config(arch, shp)
    run_variant("B", "B0_base_16x16", arch, shp,
                [(16, "data"), (16, "model")], base, want_flash=True)
    run_variant("B", "B1_mesh_32x8", arch, shp,
                [(32, "data"), (8, "model")], base, want_flash=True)
    run_variant("B", "B2_mb4_32x8", arch, shp,
                [(32, "data"), (8, "model")],
                dataclasses.replace(base, microbatches=4), want_flash=True)
    run_variant("B", "B3_mb4_16x16", arch, shp,
                [(16, "data"), (16, "model")],
                dataclasses.replace(base, microbatches=4), want_flash=True)
    # FSDP weight-gathers repeat per sweep (fwd + remat-fwd + bwd transpose);
    # dropping remat removes the re-gather sweep: predicted collective ×2/3
    # at the cost of storing activations (mb=8 keeps them ~10GiB).
    run_variant("B", "B4_noremat_16x16", arch, shp,
                [(16, "data"), (16, "model")],
                dataclasses.replace(base, remat=False), want_flash=True)


def cell_C():
    arch, shp = "mamba2-130m", "train_4k"
    base = get_run_config(arch, shp)
    mesh = [(16, "data"), (16, "model")]

    def comp(mode, frac, ef=False):
        if mode == "none":
            return core_types.CompressionConfig(mode="none")
        return core_types.CompressionConfig(
            encoder=core_types.EncoderSpec(kind="fixed_k", fraction=frac,
                                           center="mean"),
            mode=mode, axes=("data", "model"), error_feedback=ef)

    run_variant("C", "C0_exact", arch, shp, mesh,
                dataclasses.replace(base, compression=comp("none", 1)))
    run_variant("C", "C1_gather_1_16", arch, shp, mesh,
                dataclasses.replace(base,
                                    compression=comp("gather_decode", 1 / 16)))
    run_variant("C", "C2_shared_1_16", arch, shp, mesh,
                dataclasses.replace(base,
                                    compression=comp("shared_support", 1 / 16)))
    run_variant("C", "C3_shared_1_64_ef", arch, shp, mesh,
                dataclasses.replace(
                    base, compression=comp("shared_support", 1 / 64, ef=True)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="ABC")
    args = ap.parse_args()
    if "A" in args.cell:
        cell_A()
    if "B" in args.cell:
        cell_B()
    if "C" in args.cell:
        cell_C()


if __name__ == "__main__":
    main()
