"""Fault-tolerant checkpointing: sharded save, atomic commit, elastic restore.

Format: one .npz per leaf-group + JSON manifest (step, specs, mesh shape,
RNG key, data cursor).  Saves go to a temp dir and are committed by atomic
rename — a crash mid-save never corrupts the latest checkpoint.  Restore
device_puts with the *current* mesh's NamedShardings, so a job restarted on
a different data-parallel extent reshards transparently (elastic scaling).
``keep_last`` retention prunes old steps.  An optional background thread
(async_save) overlaps serialization with the next train steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MANIFEST = "manifest.json"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, step: int, params: Dict[str, Any], opt_state,
         specs: Dict[str, Any], extra: Optional[Dict] = None,
         keep_last: int = 3):
    """Synchronous checkpoint save with atomic commit."""
    tmp = f"{path}/tmp-{step}"
    final = f"{path}/step-{step:08d}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten({"params": params, "opt": {
        "step": opt_state.step, "m": opt_state.m, "v": opt_state.v}})
    arrays = {k.replace("/", "|"): np.asarray(jax.device_get(v))
              for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "specs": {k: list(v) for k, v in specs.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(path, keep_last)
    return final


def _prune(path: str, keep_last: int):
    steps = sorted(d for d in os.listdir(path) if d.startswith("step-"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path) if d.startswith("step-"))
    return int(steps[-1].split("-")[1]) if steps else None


def restore(path: str, mesh, specs: Dict[str, Any], opt_template,
            step: Optional[int] = None):
    """Load a checkpoint and device_put onto the *current* mesh (elastic).

    Returns (step, params, opt_state, extra).  ``opt_template`` is an
    AdamWState used only for structure.
    """
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    final = f"{path}/step-{step:08d}"
    with open(os.path.join(final, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))

    def put(name, arr, spec):
        sh = NamedSharding(mesh, P(*spec))
        return jax.device_put(arr, sh)

    params = {}
    m = {}
    v = {}
    opt_step = None
    for key in data.files:
        k = key.replace("|", "/")
        arr = data[key]
        if k.startswith("params/"):
            name = k[len("params/"):]
            params[name] = put(name, arr, manifest["specs"][name])
        elif k.startswith("opt/m/"):
            name = k[len("opt/m/"):]
            m[name] = put(name, arr, manifest["specs"][name])
        elif k.startswith("opt/v/"):
            name = k[len("opt/v/"):]
            v[name] = put(name, arr, manifest["specs"][name])
        elif k == "opt/step":
            opt_step = jax.device_put(arr, NamedSharding(mesh, P()))
    opt_state = type(opt_template)(step=opt_step, m=m, v=v)
    return manifest["step"], params, opt_state, manifest.get("extra", {})


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training (one in flight)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, *args, **kwargs):
        self.wait()
        # device_get before handing to the thread (values are immutable).
        self._thread = threading.Thread(
            target=save, args=args, kwargs=kwargs, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
