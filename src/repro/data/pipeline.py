"""Deterministic synthetic data pipeline.

Produces host-side numpy batches as a pure function of (seed, step), so a
restarted/elastically-resized job regenerates the identical stream from the
checkpointed step counter — the data-side half of fault tolerance.  Batches
are placed onto the mesh with jax.device_put + NamedSharding (per-shard
slices are materialized lazily by the runtime).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    shape: ShapeSpec
    seed: int = 0
    # tokens follow t_{i+1} = (7·t_i + e) mod V with e ~ U[0, noise): a
    # strong bigram structure (H(next|prev) = ln noise) so training loss has
    # a real signal to descend, while staying fully synthetic/deterministic.
    noise: int = 16

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _tokens(self, rng, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        noise = min(self.noise, v)
        t0 = rng.integers(0, v, (b, 1), dtype=np.int64)
        steps = rng.integers(0, noise, (b, s - 1), dtype=np.int64)
        out = [t0]
        for i in range(s - 1):
            out.append((out[-1] * 7 + steps[:, i:i + 1]) % v)
        return np.concatenate(out, axis=1).astype(np.int32)

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s = self.shape.global_batch, self.shape.seq_len
        cfg = self.cfg
        if cfg.family == "vlm":
            s_text = s - cfg.num_patches
            tokens = self._tokens(rng, b, s_text)
            batch = {
                "tokens": tokens,
                "labels": np.roll(tokens, -1, axis=1),
                "mask": np.ones((b, s_text), np.float32),
                "patches": rng.standard_normal(
                    (b, cfg.num_patches, cfg.d_model)).astype(np.float32),
            }
        elif cfg.family == "encdec":
            from repro.models import encdec as encdec_lib
            tokens = self._tokens(rng, b, s)
            enc_s = encdec_lib.enc_seq_padded(cfg, 16)
            batch = {
                "tokens": tokens,
                "labels": np.roll(tokens, -1, axis=1),
                "mask": np.ones((b, s), np.float32),
                "frames": rng.standard_normal(
                    (b, enc_s, cfg.d_model)).astype(np.float32),
            }
        else:
            tokens = self._tokens(rng, b, s)
            batch = {"tokens": tokens,
                     "labels": np.roll(tokens, -1, axis=1),
                     "mask": np.ones((b, s), np.float32)}
        return batch

    def device_batch(self, step: int, mesh, pspecs) -> Dict[str, jax.Array]:
        host = self.host_batch(step)
        out = {}
        for k, v in host.items():
            sh = jax.NamedSharding(mesh, pspecs[k])
            out[k] = jax.device_put(v, sh)
        return out
