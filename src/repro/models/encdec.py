"""Whisper-style encoder–decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, d_model).  The transformer
backbone is faithful: bidirectional encoder (GELU MLP), causal decoder with
cross-attention, sinusoidal positions (we use on-the-fly sinusoids for the
decoder as well so decode_32k-style cache shapes are well-defined beyond
whisper's learned 448 positions — an architectural stand-in, noted in
DESIGN.md).

Encoder frames are padded to a multiple of 96 = lcm-friendly tile so the
sequence shards over tp = 16 and chunks evenly (1500 → 1536).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import attention as attn_lib
from repro.models import common
from repro.models import mlp as mlp_lib
from repro.models import transformer as tfm
from repro.models.common import ParamBuilder, ShardCtx
from repro.models.transformer import sub


def enc_seq_padded(cfg: ArchConfig, tp: int) -> int:
    base = max(96, tp * 32)
    return -(-cfg.encoder_seq // base) * base


def init_encdec(key, cfg: ArchConfig, ctx: ShardCtx, mesh_sizes,
                run: RunConfig, abstract: bool = False):
    pb = ParamBuilder(key, ctx, mesh_sizes, abstract=abstract)
    fsdp = ctx.fsdp_axis if run.fsdp else None
    d = cfg.d_model
    tp = ctx.tp
    vp = cfg.vocab_padded(tp)
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, tp)

    vshard = "model" if tp > 1 else None
    pb.add("embed", (vp, d), (vshard, None), scale=0.02)
    if not cfg.tie_embeddings:
        pb.add("lm_head", (vp, d), (vshard, None), scale=d ** -0.5)
    pb.ones("final_norm", (d,), (None,))
    pb.ones("enc_final_norm", (d,), (None,))

    le, ld = cfg.encoder_layers, cfg.num_layers
    attn_lib.init_attention(pb, "enc.attn", le, d, dims, False, fsdp)
    mlp_lib.init_mlp(pb, "enc.mlp", le, d, cfg.d_ff, fsdp, gated=False)
    pb.ones("enc.norm1", (le, d), (None, None))
    pb.ones("enc.norm2", (le, d), (None, None))

    attn_lib.init_attention(pb, "dec.attn", ld, d, dims, False, fsdp)
    attn_lib.init_attention(pb, "dec.xattn", ld, d, dims, False, fsdp)
    mlp_lib.init_mlp(pb, "dec.mlp", ld, d, cfg.d_ff, fsdp, gated=False)
    pb.ones("dec.norm1", (ld, d), (None, None))
    pb.ones("dec.norm2", (ld, d), (None, None))
    pb.ones("dec.norm3", (ld, d), (None, None))
    return pb.params, pb.specs


def _rope_theta(cfg):
    return None  # whisper: absolute sinusoidal positions, no rope


def encode(ctx: ShardCtx, params, specs, cfg: ArchConfig, run: RunConfig,
           frames):
    """frames: (B, S_enc_padded, D) stub embeddings -> (B, S/tp, D) encoded."""
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    s = frames.shape[1]
    x = (frames.astype(ctx.compute_dtype)
         + common.sinusoidal_positions(s, cfg.d_model)[None]
         .astype(ctx.compute_dtype))
    x = ctx.slice_seq(x)
    lp = sub(params, "enc")
    ls = sub(specs, "enc")
    chunk = min(768, s)

    def body(x, layer):
        layer = common.gather_fsdp(layer, {k: v[1:] for k, v in ls.items()}, ctx)
        h = common.rms_norm(x, layer["norm1"])
        h_full = ctx.gather_seq(h)
        q, k, v = attn_lib.project_qkv(ctx, sub(layer, "attn"), h_full, dims,
                                       False, jnp.arange(s), None)
        o = attn_lib.chunked_attention(q, k, v, causal=False,
                                       chunk_q=chunk, chunk_k=chunk)
        x = x + ctx.scatter_seq(attn_lib.output_proj(ctx, sub(layer, "attn"), o))
        h2 = common.rms_norm(x, layer["norm2"])
        out = mlp_lib.mlp(ctx, sub(layer, "mlp"), ctx.gather_seq(h2), gated=False)
        return x + ctx.scatter_seq(out), None

    body_fn = jax.checkpoint(body) if run.remat else body
    x, _ = jax.lax.scan(body_fn, x, lp)
    return common.rms_norm(x, params["enc_final_norm"])


def _decoder_forward(ctx, params, specs, cfg, run, x_seq, enc_full, positions,
                     want_cache: bool):
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    lp = sub(params, "dec")
    ls = sub(specs, "dec")
    s_dec = positions.shape[0]

    def body(carry, layer):
        x = carry
        layer = common.gather_fsdp(layer, {k: v[1:] for k, v in ls.items()}, ctx)
        # self attention (causal)
        h = common.rms_norm(x, layer["norm1"])
        h_full = ctx.gather_seq(h)
        q, k, v = attn_lib.project_qkv(ctx, sub(layer, "attn"), h_full, dims,
                                       False, positions, None)
        o = attn_lib.chunked_attention(
            q, k, v, causal=True, chunk_q=min(run.attn_chunk_q, s_dec),
            chunk_k=min(run.attn_chunk_k, s_dec))
        x = x + ctx.scatter_seq(attn_lib.output_proj(ctx, sub(layer, "attn"), o))
        # cross attention to the encoder output
        h2 = common.rms_norm(x, layer["norm2"])
        h2_full = ctx.gather_seq(h2)
        qx = jnp.einsum("bsd,dhk->bshk", h2_full,
                        layer["xattn.wq"].astype(ctx.compute_dtype))
        kx = jnp.einsum("bsd,dhk->bshk", enc_full,
                        layer["xattn.wk"].astype(ctx.compute_dtype))
        vx = jnp.einsum("bsd,dhk->bshk", enc_full,
                        layer["xattn.wv"].astype(ctx.compute_dtype))
        kx, vx, _ = attn_lib._select_kv_group(ctx, kx, vx, dims)
        ox = attn_lib.chunked_attention(
            qx, kx, vx, causal=False, chunk_q=min(run.attn_chunk_q, s_dec),
            chunk_k=min(768, enc_full.shape[1]))
        ox = jnp.einsum("bshk,hkd->bsd", ox,
                        layer["xattn.wo"].astype(ctx.compute_dtype))
        x = x + ctx.scatter_seq(ox)
        # mlp (gelu)
        h3 = common.rms_norm(x, layer["norm3"])
        out = mlp_lib.mlp(ctx, sub(layer, "mlp"), ctx.gather_seq(h3), gated=False)
        x = x + ctx.scatter_seq(out)
        caches = (k, v, kx, vx) if want_cache else None
        return x, caches

    body_fn = jax.checkpoint(body) if run.remat else body
    x, caches = jax.lax.scan(body_fn, x_seq, lp)
    return common.rms_norm(x, params["final_norm"]), caches


def train_loss(ctx, params, specs, cfg, run, batch, global_token_count):
    frames = batch["frames"]
    tokens = batch["tokens"]
    s_dec = tokens.shape[1]
    enc = encode(ctx, params, specs, cfg, run, frames)
    enc_full = ctx.gather_seq(enc)
    x = tfm.embed_tokens(ctx, params, cfg, tokens)
    pos_emb = common.sinusoidal_positions(s_dec, cfg.d_model)[None]
    x = x + ctx.slice_seq(jnp.broadcast_to(
        pos_emb, (tokens.shape[0], s_dec, cfg.d_model))).astype(x.dtype)
    h, _ = _decoder_forward(ctx, params, specs, cfg, run, x,
                            enc_full, jnp.arange(s_dec), False)
    labels, mask = batch["labels"], batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    ce_sum, cnt = tfm.vocab_parallel_ce(ctx, params, cfg, h,
                                        ctx.slice_seq(labels),
                                        ctx.slice_seq(mask))
    loss = ce_sum / global_token_count
    return loss, {"ce_sum": ce_sum, "count": cnt,
                  "aux": jnp.zeros((), jnp.float32)}


def make_cache(ctx, cfg, b_local, s_max, dtype=jnp.bfloat16):
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    kv_keep = 1 if (dims.kv_replicated and ctx.tp > 1) else dims.kv_local
    L = cfg.num_layers
    s_enc = enc_seq_padded(cfg, ctx.tp)
    return {
        "k": jnp.zeros((L, b_local, s_max, kv_keep, cfg.hd), dtype),
        "v": jnp.zeros((L, b_local, s_max, kv_keep, cfg.hd), dtype),
        "xk": jnp.zeros((L, b_local, s_enc, kv_keep, cfg.hd), dtype),
        "xv": jnp.zeros((L, b_local, s_enc, kv_keep, cfg.hd), dtype),
    }


def prefill(ctx, params, specs, cfg, run, batch, s_max: Optional[int] = None):
    frames = batch["frames"]
    tokens = batch["tokens"]
    s_dec = tokens.shape[1]
    enc = encode(ctx, params, specs, cfg, run, frames)
    enc_full = ctx.gather_seq(enc)
    x = tfm.embed_tokens(ctx, params, cfg, tokens)
    pos_emb = common.sinusoidal_positions(s_dec, cfg.d_model)[None]
    x = x + ctx.slice_seq(jnp.broadcast_to(
        pos_emb, (tokens.shape[0], s_dec, cfg.d_model))).astype(x.dtype)
    h, caches = _decoder_forward(ctx, params, specs, cfg, run, x, enc_full,
                                 jnp.arange(s_dec), True)
    k, v, xk, xv = caches

    def pad_to(arr, n):
        if s_max is None or arr.shape[2] >= n:
            return arr
        pad = [(0, 0)] * arr.ndim
        pad[2] = (0, n - arr.shape[2])
        return jnp.pad(arr, pad)

    cache = {"k": pad_to(k.astype(jnp.bfloat16), s_max or k.shape[2]),
             "v": pad_to(v.astype(jnp.bfloat16), s_max or v.shape[2]),
             "xk": xk.astype(jnp.bfloat16), "xv": xv.astype(jnp.bfloat16)}
    h_full = ctx.gather_seq(h)
    logits = tfm.lm_head_logits(ctx, params, cfg, h_full[:, -1:])
    return cache, logits


def decode_step(ctx, params, specs, cfg, run, cache, tok, pos):
    ctx = dataclasses.replace(ctx, seq_shard=False)
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    x = tfm.embed_tokens(ctx, params, cfg, tok)
    pos_emb = common.sinusoidal_positions(1, cfg.d_model, offset=pos)[None]
    x = x + pos_emb.astype(x.dtype)
    lp = sub(params, "dec")
    ls = sub(specs, "dec")

    def body(carry, xs):
        x, kcs, vcs, li = carry
        layer, xk, xv = xs
        layer = common.gather_fsdp(layer, {k: v[1:] for k, v in ls.items()}, ctx)
        h = common.rms_norm(x, layer["norm1"])
        q, k, v = attn_lib.project_qkv(ctx, sub(layer, "attn"), h, dims,
                                       False, jnp.full((1,), pos), None)
        zero = jnp.int32(0)
        kcs = jax.lax.dynamic_update_slice(
            kcs, k.astype(kcs.dtype)[None], (li, zero, pos, zero, zero))
        vcs = jax.lax.dynamic_update_slice(
            vcs, v.astype(vcs.dtype)[None], (li, zero, pos, zero, zero))
        kc = jax.lax.dynamic_index_in_dim(kcs, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vcs, li, 0, keepdims=False)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1)
        x = x + ctx.psum_model(
            attn_lib.output_proj(ctx, sub(layer, "attn"), o))
        h2 = common.rms_norm(x, layer["norm2"])
        qx = jnp.einsum("bsd,dhk->bshk", h2,
                        layer["xattn.wq"].astype(ctx.compute_dtype))
        ox = attn_lib.decode_attention(qx, xk, xv, xk.shape[1])
        ox = jnp.einsum("bshk,hkd->bsd", ox,
                        layer["xattn.wo"].astype(ctx.compute_dtype))
        x = x + ctx.psum_model(ox)
        h3 = common.rms_norm(x, layer["norm3"])
        x = x + ctx.psum_model(
            mlp_lib.mlp(ctx, sub(layer, "mlp"), h3, gated=False))
        return (x, kcs, vcs, li + 1), None

    (x, kcs, vcs, _), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"], jnp.int32(0)),
        (lp, cache["xk"], cache["xv"]))
    new_cache = dict(cache, k=kcs, v=vcs)
    h = common.rms_norm(x, params["final_norm"])
    logits = tfm.lm_head_logits(ctx, params, cfg, h)
    nxt = tfm.greedy_sample(ctx, logits)
    return nxt, logits, new_cache
