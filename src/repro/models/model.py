"""Model facade: per-shard train-loss, prefill and decode drivers for all
families.  Every function here executes inside shard_map (all axes manual);
repro.train / repro.serving / repro.launch wrap them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import attention as attn_lib
from repro.models import common
from repro.models import encdec as encdec_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.common import ShardCtx
from repro.models.transformer import sub


def make_ctx(cfg: ArchConfig, run: RunConfig, mesh_sizes: Dict[str, int],
             dtype=jnp.bfloat16) -> ShardCtx:
    tp = mesh_sizes.get("model", 1) if run.model_parallel else 1
    return ShardCtx(tp=tp, fsdp=run.fsdp, compute_dtype=dtype,
                    seq_shard=run.seq_shard and tp > 1)


def init(key, cfg: ArchConfig, ctx: ShardCtx, mesh_sizes, run: RunConfig,
         abstract: bool = False):
    if cfg.family == "encdec":
        return encdec_lib.init_encdec(key, cfg, ctx, mesh_sizes, run, abstract)
    return tfm.init_lm(key, cfg, ctx, mesh_sizes, run, abstract)


# --------------------------------------------------------------------------- #
# Input embedding per family (returns sequence-sharded activations).
# --------------------------------------------------------------------------- #

def embed_inputs(ctx: ShardCtx, params, cfg: ArchConfig, batch):
    if cfg.family == "vlm":
        text = tfm.embed_tokens(ctx, params, cfg, batch["tokens"])
        patches = batch["patches"].astype(ctx.compute_dtype)
        patches = jnp.einsum("bpd,de->bpe", patches,
                             params["patch_proj"].astype(ctx.compute_dtype))
        # prepend patches, then re-shard the combined stream over seq
        text_full = ctx.gather_seq(text)
        x = jnp.concatenate([patches, text_full], axis=1)
        return ctx.slice_seq(x)
    return tfm.embed_tokens(ctx, params, cfg, batch["tokens"])


def _labels_local(ctx: ShardCtx, cfg: ArchConfig, batch, s_total: int):
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    if cfg.family == "vlm":
        # patch positions carry no labels
        b = labels.shape[0]
        pad_lab = jnp.zeros((b, cfg.num_patches), labels.dtype)
        pad_mask = jnp.zeros((b, cfg.num_patches), jnp.float32)
        labels = jnp.concatenate([pad_lab, labels], axis=1)
        mask = jnp.concatenate([pad_mask, mask.astype(jnp.float32)], axis=1)
    return ctx.slice_seq(labels), ctx.slice_seq(mask)


# --------------------------------------------------------------------------- #
# Train loss (per shard: local token-loss sum / global count).
# --------------------------------------------------------------------------- #

def train_loss(ctx: ShardCtx, params, specs, cfg: ArchConfig, run: RunConfig,
               batch, global_token_count: float):
    """Returns (loss, metrics).  loss = local CE sum / global count + aux, so
    that psum(grad) over all axes assembles the true global gradient."""
    if cfg.family == "encdec":
        return encdec_lib.train_loss(ctx, params, specs, cfg, run, batch,
                                     global_token_count)
    x = embed_inputs(ctx, params, cfg, batch)
    s_total = (batch["tokens"].shape[1] + cfg.num_patches
               if cfg.family == "vlm" else batch["tokens"].shape[1])
    positions = jnp.arange(s_total)
    h, aux, _ = tfm.forward(ctx, params, specs, cfg, run, x, positions)
    labels, mask = _labels_local(ctx, cfg, batch, s_total)
    ce_sum, cnt = tfm.vocab_parallel_ce(ctx, params, cfg, h, labels, mask)
    loss = ce_sum / global_token_count + aux / jnp.asarray(
        max(1, cfg.num_layers), jnp.float32)
    metrics = {"ce_sum": ce_sum, "count": cnt, "aux": aux}
    return loss, metrics


# --------------------------------------------------------------------------- #
# Decode caches.
# --------------------------------------------------------------------------- #

def make_cache(ctx: ShardCtx, cfg: ArchConfig, b_local: int, s_max: int,
               dtype=jnp.bfloat16):
    """Allocate (or shape-spec) the decode cache pytree."""
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    kv_keep = 1 if (dims.kv_replicated and ctx.tp > 1) else dims.kv_local
    if cfg.window is not None:
        s_max = min(s_max, cfg.window)

    def attn_cache(n):
        return {"k": jnp.zeros((n, b_local, s_max, kv_keep, cfg.hd), dtype),
                "v": jnp.zeros((n, b_local, s_max, kv_keep, cfg.hd), dtype)}

    def ssm_cache(n, scfg: ssm_lib.SSMCfg):
        d_in_loc = scfg.d_inner(cfg.d_model) // max(ctx.tp, 1)
        nh_loc = scfg.nheads(cfg.d_model) // max(ctx.tp, 1)
        gn = scfg.n_groups * scfg.d_state
        w = scfg.conv_width - 1
        return {
            "conv_x": jnp.zeros((n, b_local, w, d_in_loc), dtype),
            "conv_B": jnp.zeros((n, b_local, w, gn), dtype),
            "conv_C": jnp.zeros((n, b_local, w, gn), dtype),
            "state": jnp.zeros((n, b_local, nh_loc, scfg.head_dim,
                                scfg.d_state), jnp.float32),
        }

    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "moe"):
        return attn_cache(L)
    if cfg.family == "ssm":
        return ssm_cache(L, cfg.ssm)
    if cfg.family == "hybrid":
        per = cfg.attn_every
        np_ = L // per
        return {"attn": attn_cache(np_),
                "ssm": ssm_cache(np_ * (per - 1), cfg.ssm)}
    if cfg.family == "encdec":
        return encdec_lib.make_cache(ctx, cfg, b_local, s_max, dtype)
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------- #
# Decode step (one token; positions advance by `pos`).
# --------------------------------------------------------------------------- #

def _attn_decode_layer(ctx, cfg, p, x, kcs, vcs, li, pos, dims):
    """In-place decode attention.  kcs/vcs: the FULL stacked cache
    (L, B, S, kv, hd) carried through the layer scan; only the new token's
    slot is written (dynamic_update_slice on the carry aliases in place —
    no cache-sized temporaries; see EXPERIMENTS.md §Perf decode entry)."""
    h = common.rms_norm(x, p["norm1"])
    q, k, v = attn_lib.project_qkv(ctx, sub(p, "attn"), h, dims, cfg.qk_norm,
                                   jnp.full((1,), pos), cfg.rope_theta)
    write = pos if cfg.window is None else pos % kcs.shape[2]
    zero = jnp.int32(0)
    kcs = jax.lax.dynamic_update_slice(
        kcs, k.astype(kcs.dtype)[None], (li, zero, write, zero, zero))
    vcs = jax.lax.dynamic_update_slice(
        vcs, v.astype(vcs.dtype)[None], (li, zero, write, zero, zero))
    kc = jax.lax.dynamic_index_in_dim(kcs, li, 0, keepdims=False)
    vc = jax.lax.dynamic_index_in_dim(vcs, li, 0, keepdims=False)
    if cfg.window is None:
        o = attn_lib.decode_attention(q, kc, vc, pos + 1)
    else:
        # ring buffer: all slots valid once full; relative order is immaterial
        # to softmax except rope phases already baked into k.
        valid = jnp.minimum(pos + 1, kcs.shape[2])
        o = attn_lib.decode_attention(q, kc, vc, valid)
    o = attn_lib.output_proj(ctx, sub(p, "attn"), o)
    return x + ctx.psum_model(o), kcs, vcs


def _ffn_decode(ctx, cfg, p, x, kind):
    h = common.rms_norm(x, p["norm2"])
    if kind == "mlp":
        from repro.models import mlp as mlp_lib
        return x + ctx.psum_model(mlp_lib.mlp(ctx, sub(p, "mlp"), h))
    return x + moe_lib.moe_decode(ctx, sub(p, "moe"), h, cfg.moe)


def decode_step(ctx: ShardCtx, params, specs, cfg: ArchConfig, run: RunConfig,
                cache, tok, pos):
    """tok: (B, 1) int32; pos: () int32 current length.  Returns
    (next_token (B, 1), logits_local (B, 1, V_loc), new_cache)."""
    if cfg.family == "encdec":
        return encdec_lib.decode_step(ctx, params, specs, cfg, run, cache,
                                      tok, pos)
    ctx = dataclasses.replace(ctx, seq_shard=False)
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    x = tfm.embed_tokens(ctx, params, cfg, tok)

    if cfg.family in ("dense", "vlm", "moe"):
        lp = sub(params, "layers")
        ls = sub(specs, "layers")

        def body(carry, layer):
            x, kcs, vcs, li = carry
            layer = common.gather_fsdp(layer, {k: v[1:] for k, v in ls.items()},
                                       ctx)
            x, kcs, vcs = _attn_decode_layer(ctx, cfg, layer, x, kcs, vcs, li,
                                             pos, dims)
            x = _ffn_decode(ctx, cfg, layer, x,
                            "moe" if cfg.family == "moe" else "mlp")
            return (x, kcs, vcs, li + 1), None

        (x, kcs, vcs, _), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"], jnp.int32(0)), lp)
        new_cache = {"k": kcs, "v": vcs}
    elif cfg.family == "ssm":
        lp = sub(params, "layers")
        ls = sub(specs, "layers")

        def body(carry, layer):
            x, cxs, cbs, ccs, sts, li = carry
            layer = common.gather_fsdp(layer, {k: v[1:] for k, v in ls.items()},
                                       ctx)
            h = common.rms_norm(x, layer["norm1"])
            idx = lambda buf: jax.lax.dynamic_index_in_dim(buf, li, 0, False)
            out, ((cx2, cb2, cc2), st2) = _mamba_decode_unpack(
                ctx, sub(layer, "ssm"), h, cfg.ssm,
                idx(cxs), idx(cbs), idx(ccs), idx(sts))
            wr = lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                buf, v.astype(buf.dtype), li, 0)
            return (x + ctx.psum_model(out), wr(cxs, cx2), wr(cbs, cb2),
                    wr(ccs, cc2), wr(sts, st2), li + 1), None

        (x, cxs, cbs, ccs, sts, _), _ = jax.lax.scan(
            body, (x, cache["conv_x"], cache["conv_B"], cache["conv_C"],
                   cache["state"], jnp.int32(0)), lp)
        new_cache = {"conv_x": cxs, "conv_B": cbs, "conv_C": ccs, "state": sts}
    elif cfg.family == "hybrid":
        x, new_cache = _decode_hybrid(ctx, params, specs, cfg, run, cache, x,
                                      pos, dims)
    else:
        raise ValueError(cfg.family)

    h = common.rms_norm(x, params["final_norm"])
    logits = tfm.lm_head_logits(ctx, params, cfg, h)
    nxt = tfm.greedy_sample(ctx, logits)
    return nxt, logits, new_cache


def _mamba_decode_unpack(ctx, p, h, scfg, cx, cb, cc, st):
    out, (conv, st2) = ssm_lib.mamba_decode(
        ctx, p, h, scfg, {"x": cx, "B": cb, "C": cc}, st)
    return out, ((conv["x"], conv["B"], conv["C"]), st2)


def _decode_hybrid(ctx, params, specs, cfg, run, cache, x, pos, dims):
    per = cfg.attn_every
    np_ = cfg.num_layers // per
    nm = per - 1
    n_moe = per // cfg.moe.every_n
    pp = sub(params, "periods")
    ps = sub(specs, "periods")

    def reshape_stack(d, n_inner):
        return {k: v.reshape((np_, n_inner) + v.shape[1:]) for k, v in d.items()}

    stacked = {}
    stacked.update({f"attn.{k}": v for k, v in sub(pp, "attn").items()})
    stacked.update({f"ssm.{k}": v for k, v in
                    reshape_stack(sub(pp, "ssm"), nm).items()})
    stacked.update({f"moe.{k}": v for k, v in
                    reshape_stack(sub(pp, "moe"), n_moe).items()})
    stacked.update({f"mlp.{k}": v for k, v in
                    reshape_stack(sub(pp, "mlp"), per - n_moe).items()})
    stacked["norm1"] = pp["norm1"].reshape(np_, per, -1)
    stacked["norm2"] = pp["norm2"].reshape(np_, per, -1)

    def _g(period, group, idx=None):
        pl = sub(period, group)
        if idx is not None:
            pl = {k: v[idx] for k, v in pl.items()}
        return common.gather_fsdp(pl, {k: ps[f"{group}.{k}"][1:] for k in pl},
                                  ctx)

    a_cache = cache["attn"]
    s_cache = cache["ssm"]

    def body(carry, period):
        x, kcs, vcs, cxs, cbs, ccs, sts, pi = carry
        mi = fi_moe = fi_mlp = 0
        for i in range(per):
            pl = {"norm1": period["norm1"][i], "norm2": period["norm2"][i]}
            if i == cfg.attn_offset:
                pl.update({f"attn.{k}": v for k, v in _g(period, "attn").items()})
                x, kcs, vcs = _attn_decode_layer(ctx, cfg, pl, x, kcs, vcs,
                                                 pi, pos, dims)
            else:
                pssm = _g(period, "ssm", mi)
                h = common.rms_norm(x, pl["norm1"])
                si = pi * nm + mi
                idx = lambda buf: jax.lax.dynamic_index_in_dim(buf, si, 0, False)
                out, ((cx2, cb2, cc2), st2) = _mamba_decode_unpack(
                    ctx, pssm, h, cfg.ssm, idx(cxs), idx(cbs), idx(ccs),
                    idx(sts))
                x = x + ctx.psum_model(out)
                wr = lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                    buf, v.astype(buf.dtype), si, 0)
                cxs, cbs, ccs, sts = (wr(cxs, cx2), wr(cbs, cb2),
                                      wr(ccs, cc2), wr(sts, st2))
                mi += 1
            if n_moe > 0 and i % cfg.moe.every_n == 1 % cfg.moe.every_n:
                pl2 = {"norm2": period["norm2"][i]}
                pl2.update({f"moe.{k}": v for k, v in
                            _g(period, "moe", fi_moe).items()})
                x = _ffn_decode(ctx, cfg, pl2, x, "moe")
                fi_moe += 1
            else:
                pl2 = {"norm2": period["norm2"][i]}
                pl2.update({f"mlp.{k}": v for k, v in
                            _g(period, "mlp", fi_mlp).items()})
                x = _ffn_decode(ctx, cfg, pl2, x, "mlp")
                fi_mlp += 1
        return (x, kcs, vcs, cxs, cbs, ccs, sts, pi + 1), None

    (x, kcs, vcs, cxs, cbs, ccs, sts, _), _ = jax.lax.scan(
        body, (x, a_cache["k"], a_cache["v"], s_cache["conv_x"],
               s_cache["conv_B"], s_cache["conv_C"], s_cache["state"],
               jnp.int32(0)), stacked)
    new_cache = {
        "attn": {"k": kcs, "v": vcs},
        "ssm": {"conv_x": cxs, "conv_B": cbs, "conv_C": ccs, "state": sts},
    }
    return x, new_cache


# --------------------------------------------------------------------------- #
# Prefill: forward with cache capture, then assemble decode-ready caches.
# --------------------------------------------------------------------------- #

def prefill(ctx: ShardCtx, params, specs, cfg: ArchConfig, run: RunConfig,
            batch, s_max: Optional[int] = None):
    """Run the prompt through the model, return (cache, logits_last (B,1,V_loc)).

    The attention caches hold the prompt's K/V (padded to s_max when given);
    SSM caches hold the final conv window + state.
    """
    if cfg.family == "encdec":
        return encdec_lib.prefill(ctx, params, specs, cfg, run, batch, s_max)
    x = embed_inputs(ctx, params, cfg, batch)
    s_total = (batch["tokens"].shape[1] + cfg.num_patches
               if cfg.family == "vlm" else batch["tokens"].shape[1])
    positions = jnp.arange(s_total)
    h, _, caches = tfm.forward(ctx, params, specs, cfg, run, x, positions,
                               want_cache=True)
    # last-token logits: last shard holds the final S/tp slice
    h_full = ctx.gather_seq(h)
    logits = tfm.lm_head_logits(ctx, params, cfg, h_full[:, -1:])

    def pad_to(x, n, axis):
        if s_max is None or x.shape[axis] >= n:
            return x
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, n - x.shape[axis])
        return jnp.pad(x, pad)

    if cfg.family in ("dense", "vlm", "moe"):
        k, v = caches
        cache = {"k": pad_to(k.astype(jnp.bfloat16), s_max or k.shape[2], 2),
                 "v": pad_to(v.astype(jnp.bfloat16), s_max or v.shape[2], 2)}
    elif cfg.family == "ssm":
        conv, st = caches
        cache = {"conv_x": conv["x"].astype(jnp.bfloat16),
                 "conv_B": conv["B"].astype(jnp.bfloat16),
                 "conv_C": conv["C"].astype(jnp.bfloat16),
                 "state": st}
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        np_ = cfg.num_layers // per
        nm = per - 1
        # caches: tuple over period positions; one attn kv + nm ssm states
        attn_k, attn_v, cxs, cbs, ccs, sts = _regroup_hybrid_caches(
            caches, cfg)
        cache = {"attn": {"k": pad_to(attn_k.astype(jnp.bfloat16),
                                      s_max or attn_k.shape[2], 2),
                          "v": pad_to(attn_v.astype(jnp.bfloat16),
                                      s_max or attn_v.shape[2], 2)},
                 "ssm": {"conv_x": cxs.astype(jnp.bfloat16),
                         "conv_B": cbs.astype(jnp.bfloat16),
                         "conv_C": ccs.astype(jnp.bfloat16),
                         "state": sts}}
    else:
        raise ValueError(cfg.family)
    return cache, logits


def _regroup_hybrid_caches(caches, cfg: ArchConfig):
    """forward(hybrid) ys: tuple over intra-period slots, each stacked over
    periods.  Slot attn_offset is (k, v); the rest are ((convs), state)."""
    per = cfg.attn_every
    ks = vs = None
    cx, cb, cc, st = [], [], [], []
    for i, c in enumerate(caches):
        if i == cfg.attn_offset:
            ks, vs = c
        else:
            conv, s = c
            cx.append(conv["x"])
            cb.append(conv["B"])
            cc.append(conv["C"])
            st.append(s)
    # each list entry: (np_, B, ...) stacked over periods; want (np_*nm, ...)
    def pack(lst):
        arr = jnp.stack(lst, axis=1)  # (np_, nm, ...)
        return arr.reshape((-1,) + arr.shape[2:])
    return ks, vs, pack(cx), pack(cb), pack(cc), pack(st)
