"""Shared model substrate: shard context, collective helpers, init, norms, rope.

Design (DESIGN.md §4): all model code is written *per-shard* and executed
inside ``jax.shard_map`` with every mesh axis manual.  Tensor parallelism is
Megatron-style manual collectives over the ``model`` axis with
sequence-parallel residual streams; data/pod axes only appear in gradient
synchronization (repro.train) and FSDP parameter gathers.  The same code
runs on a (1, 1) mesh for CPU smoke tests.

Param bookkeeping: every initializer returns ``(params, specs)`` where specs
mirror params with a tuple of mesh-axis names per dim (None = replicated).
Specs drive shard_map in_specs, FSDP gathers, checkpoint resharding and the
gradient-sync rule (sync axes = mesh axes absent from the leaf's spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# Shard context.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static sharding context threaded through all layers.

    tp:  size of the model axis used for tensor parallelism (1 = no TP —
         e.g. mamba2-130m folds the model axis into data parallelism).
    fsdp: whether weight leaves marked with the data axis are
         gathered/scattered per layer (ZeRO-3).
    """

    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)
    tp: int = 1
    fsdp: bool = False
    fsdp_axis: str = "data"
    compute_dtype: Any = jnp.bfloat16
    # sequence parallelism for the residual stream (requires S % tp == 0)
    seq_shard: bool = True

    # ---- collectives (static no-ops when tp == 1) ------------------------ #
    def psum_model(self, x):
        return jax.lax.psum(x, self.model_axis) if self.tp > 1 else x

    def pmax_model(self, x):
        """Cross-shard max, differentiable (lax.pmax has no JVP rule; the
        gather+max form costs tp small buffers and transposes cleanly —
        used by the vocab-parallel CE's stability shift)."""
        if self.tp == 1:
            return x
        g = jax.lax.all_gather(x, self.model_axis)
        return jnp.max(g, axis=0)

    def model_rank(self):
        return jax.lax.axis_index(self.model_axis) if self.tp > 1 else jnp.int32(0)

    def gather_seq(self, x):
        """(B, S/tp, D) sequence-sharded -> (B, S, D) replicated-over-model."""
        if self.tp == 1 or not self.seq_shard:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=1, tiled=True)

    def scatter_seq(self, x):
        """(B, S, D) per-shard partial sums -> (B, S/tp, D), summed.

        The reverse-mode transpose of gather_seq; fusing the TP reduction
        with the sequence scatter (Megatron sequence parallelism).
        """
        if self.tp == 1 or not self.seq_shard:
            return x
        return jax.lax.psum_scatter(x, self.model_axis, scatter_dimension=1,
                                    tiled=True)

    def gather_heads(self, x, axis):
        if self.tp == 1:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def slice_seq(self, x, axis=1):
        """Take this shard's S/tp slice of a replicated sequence tensor."""
        if self.tp == 1 or not self.seq_shard:
            return x
        s_loc = x.shape[axis] // self.tp
        return jax.lax.dynamic_slice_in_dim(
            x, self.model_rank() * s_loc, s_loc, axis=axis)


def gather_fsdp(layer_params: Dict[str, Any], layer_specs: Dict[str, Any],
                ctx: ShardCtx):
    """all_gather FSDP-sharded leaves of one layer's params (ZeRO-3).

    Reverse mode turns each gather into a psum_scatter over the data axis —
    i.e. the gradient reduce-scatter of FSDP comes out of autodiff for free,
    and it is *exact* (in-pod ICI; the paper's compression is applied on the
    pod axis / non-FSDP leaves — DESIGN.md §2).
    Also casts to the compute dtype.
    """
    def one(w, spec):
        if ctx.fsdp and spec is not None and ctx.fsdp_axis in spec:
            dim = spec.index(ctx.fsdp_axis)
            # cast BEFORE the gather: ships bf16, not the f32 master —
            # halves FSDP weight-gather wire; the transpose reduce-scatters
            # bf16 cotangents (standard Megatron/FSDP practice).
            w = jax.lax.all_gather(w.astype(ctx.compute_dtype), ctx.fsdp_axis,
                                   axis=dim, tiled=True)
        return w.astype(ctx.compute_dtype)

    return jax.tree.map(one, layer_params, layer_specs,
                        is_leaf=lambda x: x is None)


# --------------------------------------------------------------------------- #
# Parameter initialization helpers.
# --------------------------------------------------------------------------- #

def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class ParamBuilder:
    """Accumulates (params, specs) with seeded normal init.

    Arrays are created at LOCAL (per-shard) shape directly — global shape
    divided by the mesh extent on sharded dims — so initialization never
    materializes a full 123B-parameter tensor on one host.  Seeds fold in
    the model-axis rank for sharded dims, keeping init deterministic and
    mesh-independent per logical slice.
    """

    def __init__(self, key, ctx: ShardCtx, mesh_sizes: Dict[str, int],
                 abstract: bool = False):
        """abstract=True records specs + global ShapeDtypeStructs without
        touching device state (usable outside shard_map; drives shard_map
        in/out_specs, dry-run param counting, checkpoint manifests)."""
        self.key = key
        self.ctx = ctx
        self.mesh_sizes = dict(mesh_sizes)
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}
        self._i = 0

    def _next_key(self):
        self._i += 1
        return jax.random.fold_in(self.key, self._i)

    def local_shape(self, shape, spec):
        out = []
        for s, ax in zip(shape, spec):
            if ax is None:
                out.append(s)
            else:
                axes = (ax,) if isinstance(ax, str) else ax
                div = 1
                for a in axes:
                    div *= self.mesh_sizes.get(a, 1)
                assert s % div == 0, (s, ax, div)
                out.append(s // div)
        return tuple(out)

    def add(self, name, shape, spec, scale=None, dtype=jnp.float32, zero=False):
        """Add a param with GLOBAL shape `shape` and per-dim spec."""
        assert len(spec) == len(shape), (name, shape, spec)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), dtype)
            self.specs[name] = tuple(spec)
            return None
        lshape = self.local_shape(shape, spec)
        if zero:
            arr = jnp.zeros(lshape, dtype)
        else:
            if scale is None:
                scale = shape[0] ** -0.5 if len(shape) > 1 else 0.02
            k = self._next_key()
            # fold shard identity so different shards draw different slices
            if self.ctx.tp > 1 and any(s is not None for s in spec):
                k = jax.random.fold_in(k, self.ctx.model_rank())
            arr = (jax.random.normal(k, lshape, jnp.float32) * scale).astype(dtype)
        self.params[name] = arr
        self.specs[name] = tuple(spec)
        return arr

    def ones(self, name, shape, spec):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
            self.specs[name] = tuple(spec)
            return
        lshape = self.local_shape(shape, spec)
        self.params[name] = jnp.ones(lshape, jnp.float32)
        self.specs[name] = tuple(spec)


# --------------------------------------------------------------------------- #
# Normalization / positional encodings.
# --------------------------------------------------------------------------- #

def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)          # (hd/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, hd/2)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int, offset=0):
    pos = jnp.arange(length, dtype=jnp.float32) + offset
    inv = 1e4 ** (-jnp.arange(0, d_model, 2, dtype=jnp.float32) / d_model)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
