"""Dense MLP blocks: SwiGLU (llama-family) and GELU (whisper) — TP over d_ff."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init_mlp(pb: common.ParamBuilder, prefix: str, layers: int, d_model: int,
             d_ff: int, fsdp, gated: bool = True):
    m = "model"
    pb.add(f"{prefix}.w_up", (layers, d_model, d_ff), (None, fsdp, m))
    if gated:
        pb.add(f"{prefix}.w_gate", (layers, d_model, d_ff), (None, fsdp, m))
    pb.add(f"{prefix}.w_down", (layers, d_ff, d_model),
           (None, m, fsdp), scale=d_ff ** -0.5)


def mlp(ctx: common.ShardCtx, p, x_full, gated: bool = True):
    """x_full: (B, S, D) -> partial (B, S, D); caller scatter_seq's."""
    cd = ctx.compute_dtype
    up = jnp.einsum("bsd,df->bsf", x_full, p["w_up"].astype(cd))
    if gated:
        gate = jnp.einsum("bsd,df->bsf", x_full, p["w_gate"].astype(cd))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd))
