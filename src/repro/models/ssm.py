"""Mamba-2 (SSD — state-space duality) layers: chunked train/prefill scan +
single-token decode recurrence.  TP shards d_inner / heads over the model
axis (requires nheads % tp == 0; mamba2-130m instead runs with tp = 1 and
the model axis folded into data parallelism — DESIGN.md §4).

SSD algorithm (Dao & Gu 2024): per head, with state S_t ∈ R^{p×n},

    S_t = a_t·S_{t−1} + Δ_t·X_t ⊗ B_t,      a_t = exp(Δ_t·A) ∈ (0, 1]
    y_t = S_t·C_t + D·x_t

Chunked evaluation over chunks of Q tokens (cum_t = Σ_{v≤t} log a_v):

    intra:  y_t += Σ_{u≤t} e^{cum_t−cum_u}·Δ_u·(C_t·B_u)·X_u   (masked matmul → MXU)
    inter:  y_t += e^{cum_t}·S_init·C_t
    carry:  S' = e^{cum_Q}·S_init + Σ_u e^{cum_Q−cum_u}·Δ_u·X_u ⊗ B_u

All decay math in f32 log-space; masked entries get −inf before exp.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1          # B/C groups; this implementation uses shared
    conv_width: int = 4        # B/C (n_groups = 1), the mamba2-130m setting
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


def init_ssm(pb: common.ParamBuilder, prefix: str, layers: int, d_model: int,
             cfg: SSMCfg, tp: int, fsdp):
    m = "model" if tp > 1 else None
    din = cfg.d_inner(d_model)
    nh = cfg.nheads(d_model)
    gn = cfg.n_groups * cfg.d_state
    if m:
        assert nh % tp == 0, (nh, tp)
    pb.add(f"{prefix}.w_z", (layers, d_model, din), (None, fsdp, m))
    pb.add(f"{prefix}.w_x", (layers, d_model, din), (None, fsdp, m))
    pb.add(f"{prefix}.w_B", (layers, d_model, gn), (None, fsdp, None))
    pb.add(f"{prefix}.w_C", (layers, d_model, gn), (None, fsdp, None))
    pb.add(f"{prefix}.w_dt", (layers, d_model, nh), (None, fsdp, m))
    pb.add(f"{prefix}.conv_x", (layers, cfg.conv_width, din), (None, None, m),
           scale=cfg.conv_width ** -0.5)
    pb.add(f"{prefix}.conv_B", (layers, cfg.conv_width, gn), (None, None, None),
           scale=cfg.conv_width ** -0.5)
    pb.add(f"{prefix}.conv_C", (layers, cfg.conv_width, gn), (None, None, None),
           scale=cfg.conv_width ** -0.5)
    pb.add(f"{prefix}.A_log", (layers, nh), (None, m), scale=1.0)
    pb.add(f"{prefix}.D", (layers, nh), (None, m), scale=1.0)
    pb.add(f"{prefix}.dt_bias", (layers, nh), (None, m), scale=1.0)
    pb.ones(f"{prefix}.norm", (layers, din), (None, m))
    pb.add(f"{prefix}.w_out", (layers, din, d_model), (None, m, fsdp),
           scale=din ** -0.5)


def _causal_conv(x, w, state: Optional[jax.Array] = None):
    """Depthwise causal conv + silu.  x: (B, S, C); w: (W, C).

    Returns (y, new_state); new_state = last W−1 inputs (for decode).
    """
    bw = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], bw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
            for i in range(bw))
    return jax.nn.silu(y), xp[:, -(bw - 1):]


def ssd_chunked(X, B, C, dt, log_a, cfg: SSMCfg, init_state=None):
    """Chunked SSD scan.

    X: (b, s, h, p) — h is this shard's local heads; B, C: (b, s, n) shared
    across heads (n_groups = 1); dt, log_a: (b, s, h).
    Returns (Y (b, s, h, p), final_state (b, h, p, n) f32).
    """
    b, s, h, hd = X.shape
    n = B.shape[-1]
    q = min(cfg.chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    Xc = jnp.moveaxis(X.reshape(b, nc, q, h, hd), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, nc, q, n), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, nc, q, n), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, h), 1, 0)
    lac = jnp.moveaxis(log_a.reshape(b, nc, q, h), 1, 0)
    mask = jnp.tril(jnp.ones((q, q), bool))  # t ≥ u

    def chunk_step(state, inp):
        xq, bq, cq, dtq, laq = inp           # (b, q, ...)
        xqf = xq.astype(jnp.float32)
        cum = jnp.cumsum(laq, axis=1)        # (b, q, h), ≤ 0, non-increasing
        total = cum[:, -1]                   # (b, h)

        # intra-chunk masked quadratic form
        scores = jnp.einsum("btn,bun->btu", cq, bq)             # (b, t, u)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]         # (b, t, u, h)
        ldiff = jnp.where(mask[None, :, :, None], ldiff, NEG_INF)
        m = scores[..., None] * jnp.exp(ldiff) * dtq[:, None, :, :]  # (b,t,u,h)
        y_intra = jnp.einsum("btuh,buhp->bthp", m, xqf)

        # inter-chunk: carried state
        y_inter = jnp.einsum("btn,bth,bhpn->bthp",
                             cq, jnp.exp(cum), state)

        # state carry
        wgt = jnp.exp(total[:, None, :] - cum) * dtq            # (b, q, h)
        s_new = (jnp.exp(total)[:, :, None, None] * state
                 + jnp.einsum("buhp,bun,buh->bhpn", xqf, bq, wgt))
        return s_new, (y_intra + y_inter).astype(X.dtype)

    s0 = (init_state if init_state is not None
          else jnp.zeros((b, h, hd, cfg.d_state), jnp.float32))
    final, ys = jax.lax.scan(chunk_step, s0, (Xc, Bc, Cc, dtc, lac))
    Y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    return Y, final


def ssd_decode_step(state, x, B, C, dt, log_a):
    """One-token recurrence.  state: (b, h, p, n) f32; x: (b, h, p);
    B, C: (b, n); dt, log_a: (b, h).  Returns (y (b, h, p), new_state)."""
    xf = x.astype(jnp.float32)
    a = jnp.exp(log_a)                                          # (b, h)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xf, B.astype(jnp.float32), dt)
    s_new = a[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, C.astype(jnp.float32))
    return y.astype(x.dtype), s_new


def _split_proj(ctx, p, x_full, cfg: SSMCfg, nh_loc: int):
    """Input projections (+conv on x/B/C) shared by prefill and train."""
    cd = ctx.compute_dtype
    z = jnp.einsum("bsd,de->bse", x_full, p["w_z"].astype(cd))
    xin = jnp.einsum("bsd,de->bse", x_full, p["w_x"].astype(cd))
    Braw = jnp.einsum("bsd,dg->bsg", x_full, p["w_B"].astype(cd))
    Craw = jnp.einsum("bsd,dg->bsg", x_full, p["w_C"].astype(cd))
    dt_raw = jnp.einsum("bsd,dh->bsh", x_full, p["w_dt"].astype(cd))
    return z, xin, Braw, Craw, dt_raw


def mamba_block(ctx: common.ShardCtx, p, x_seq, cfg: SSMCfg,
                conv_state=None, ssm_state=None, return_state: bool = False):
    """Full Mamba-2 block on a sequence (train or prefill).

    x_seq: (B, S/tp, D) sequence-sharded residual slice.
    Returns out (B, S/tp, D) [, (conv_states, ssm_state)].
    """
    x_full = ctx.gather_seq(x_seq)
    b, s, d = x_full.shape
    nh_loc = cfg.nheads(d) // (ctx.tp if ctx.tp > 1 else 1)
    z, xin, Braw, Craw, dt_raw = _split_proj(ctx, p, x_full, cfg, nh_loc)

    cs = conv_state or {}
    xin, cs_x = _causal_conv(xin, p["conv_x"], cs.get("x"))
    Braw, cs_b = _causal_conv(Braw, p["conv_B"], cs.get("B"))
    Craw, cs_c = _causal_conv(Craw, p["conv_C"], cs.get("C"))

    X = xin.reshape(b, s, nh_loc, cfg.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    log_a = dt * A[None, None, :]
    Y, final = ssd_chunked(X, Braw.astype(jnp.float32),
                           Craw.astype(jnp.float32), dt, log_a, cfg,
                           init_state=ssm_state)
    Y = Y + X * p["D"].astype(X.dtype)[None, None, :, None]
    y = Y.reshape(b, s, nh_loc * cfg.head_dim)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(ctx.compute_dtype))
    out = ctx.scatter_seq(out)
    if return_state:
        return out, ({"x": cs_x, "B": cs_b, "C": cs_c}, final)
    return out


def mamba_decode(ctx: common.ShardCtx, p, x_tok, cfg: SSMCfg, conv_state,
                 ssm_state):
    """One-token decode.  x_tok: (B, 1, D) replicated over model.

    conv_state: dict of (B, W−1, C) buffers; ssm_state: (B, h_loc, p, n).
    Returns (out (B, 1, D) partial-sum over model, new_states).
    """
    b, _, d = x_tok.shape
    nh_loc = cfg.nheads(d) // (ctx.tp if ctx.tp > 1 else 1)
    z, xin, Braw, Craw, dt_raw = _split_proj(ctx, p, x_tok, cfg, nh_loc)
    xin, cs_x = _causal_conv(xin, p["conv_x"], conv_state["x"])
    Braw, cs_b = _causal_conv(Braw, p["conv_B"], conv_state["B"])
    Craw, cs_c = _causal_conv(Craw, p["conv_C"], conv_state["C"])
    X = xin.reshape(b, nh_loc, cfg.head_dim)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (b, h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    log_a = dt * A[None, :]
    y, s_new = ssd_decode_step(ssm_state, X, Braw[:, 0], Craw[:, 0], dt, log_a)
    y = y + X * p["D"].astype(X.dtype)[None, :, None]
    y = y.reshape(b, 1, nh_loc * cfg.head_dim)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(ctx.compute_dtype))
    return out, ({"x": cs_x, "B": cs_b, "C": cs_c}, s_new)
