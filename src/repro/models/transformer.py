"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM families.

One per-shard code path (inside shard_map, all axes manual) serving train,
prefill and decode.  Layers are stacked and scanned (hybrids scan over
periods with a static intra-period structure), keeping HLO size and compile
time O(1) in depth.  Remat (jax.checkpoint) wraps the scanned body.

Losses: vocab-parallel cross-entropy — logits are never materialized at
full vocab width; each model shard computes its vocab slice for the full
token stream in sequence chunks, with max/logsumexp psums over the model
axis (f32).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.models import attention as attn_lib
from repro.models import common
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import ParamBuilder, ShardCtx


def sub(p: Dict[str, Any], prefix: str) -> Dict[str, Any]:
    pl = len(prefix) + 1
    return {k[pl:]: v for k, v in p.items() if k.startswith(prefix + ".")}


def take_layer(p: Dict[str, Any], i) -> Dict[str, Any]:
    return {k: v[i] for k, v in p.items()}


# --------------------------------------------------------------------------- #
# Init.
# --------------------------------------------------------------------------- #

def init_lm(key, cfg: ArchConfig, ctx: ShardCtx, mesh_sizes: Dict[str, int],
            run: RunConfig, abstract: bool = False):
    """Build (params, specs) for any decoder-only family."""
    pb = ParamBuilder(key, ctx, mesh_sizes, abstract=abstract)
    fsdp = ctx.fsdp_axis if run.fsdp else None
    tp = ctx.tp
    d = cfg.d_model
    vp = cfg.vocab_padded(tp)
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, tp)

    vshard = "model" if tp > 1 else None  # no vocab-TP when the model axis
    pb.add("embed", (vp, d), (vshard, None), scale=0.02)  # is folded into DP
    if not cfg.tie_embeddings:
        pb.add("lm_head", (vp, d), (vshard, None), scale=d ** -0.5)
    pb.ones("final_norm", (d,), (None,))

    L = cfg.num_layers
    if cfg.family in ("dense", "vlm"):
        attn_lib.init_attention(pb, "layers.attn", L, d, dims, cfg.qk_norm, fsdp)
        mlp_lib.init_mlp(pb, "layers.mlp", L, d, cfg.d_ff, fsdp)
        pb.ones("layers.norm1", (L, d), (None, None))
        pb.ones("layers.norm2", (L, d), (None, None))
        if cfg.family == "vlm":
            pb.add("patch_proj", (d, d), (None, None), scale=d ** -0.5)
    elif cfg.family == "moe":
        attn_lib.init_attention(pb, "layers.attn", L, d, dims, cfg.qk_norm, fsdp)
        moe_lib.init_moe(pb, "layers.moe", L, d, cfg.moe, tp, fsdp)
        pb.ones("layers.norm1", (L, d), (None, None))
        pb.ones("layers.norm2", (L, d), (None, None))
    elif cfg.family == "ssm":
        ssm_lib.init_ssm(pb, "layers.ssm", L, d, cfg.ssm, tp, fsdp)
        pb.ones("layers.norm1", (L, d), (None, None))
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        np_ = L // per
        nm = per - 1                       # mamba mixers per period
        # attention: one per period
        attn_lib.init_attention(pb, "periods.attn", np_, d, dims, cfg.qk_norm, fsdp)
        # mamba: stacked (periods, nm, ...): emulate by init with layers=np_*nm
        ssm_lib.init_ssm(pb, "periods.ssm", np_ * nm, d, cfg.ssm, tp, fsdp)
        # ffn: alternate MoE / dense per layer parity
        n_moe = per // cfg.moe.every_n
        n_mlp = per - n_moe
        moe_lib.init_moe(pb, "periods.moe", np_ * n_moe, d, cfg.moe, tp, fsdp)
        mlp_lib.init_mlp(pb, "periods.mlp", np_ * n_mlp, d, cfg.d_ff, fsdp)
        pb.ones("periods.norm1", (np_ * per, d), (None, None))
        pb.ones("periods.norm2", (np_ * per, d), (None, None))
    else:
        raise ValueError(cfg.family)
    return pb.params, pb.specs


# --------------------------------------------------------------------------- #
# Embedding / LM head (vocab-TP).
# --------------------------------------------------------------------------- #

def embed_tokens(ctx: ShardCtx, params, cfg: ArchConfig, tokens):
    """tokens (B, S) -> (B, S/tp, D) sequence-sharded embeddings."""
    vp = cfg.vocab_padded(ctx.tp)
    v_loc = vp // ctx.tp
    off = ctx.model_rank() * v_loc
    ids = tokens - off
    ok = (ids >= 0) & (ids < v_loc)
    emb = jnp.take(params["embed"], jnp.clip(ids, 0, v_loc - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0).astype(ctx.compute_dtype)
    if ctx.tp > 1 and ctx.seq_shard:
        return jax.lax.psum_scatter(emb, ctx.model_axis, scatter_dimension=1,
                                    tiled=True)
    return ctx.psum_model(emb)


def vocab_parallel_ce(ctx: ShardCtx, params, cfg: ArchConfig, h_seq, labels_seq,
                      mask_seq, chunk: int = 512):
    """Cross-entropy over the vocab-sharded head.

    h_seq: (B, S_loc, D) sequence-sharded final hidden states; labels/mask
    aligned to the same slice.  Returns (local loss sum f32, local count).
    Never materializes (tokens × vocab) logits: sequence chunks × local
    vocab slice only.
    """
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"]
    w = w.astype(ctx.compute_dtype)
    vp = cfg.vocab_padded(ctx.tp)
    v_loc = vp // ctx.tp
    off = ctx.model_rank() * v_loc
    b, s_loc, d = h_seq.shape
    chunk = min(chunk, s_loc)
    assert s_loc % chunk == 0
    nch = s_loc // chunk

    def one(args):
        h, y, m = args          # (B, chunk, D), (B, chunk), (B, chunk)
        logits = jnp.einsum("bsd,vd->bsv", h, w,
                            preferred_element_type=jnp.float32)
        # stop_gradient: the max is a numerical-stability shift whose
        # gradient contribution cancels exactly; pmax has no VJP rule.
        lmax = jax.lax.stop_gradient(
            ctx.pmax_model(jnp.max(logits, axis=-1)))
        lse = jnp.log(ctx.psum_model(
            jnp.sum(jnp.exp(logits - lmax[..., None]), axis=-1))) + lmax
        ids = y - off
        ok = (ids >= 0) & (ids < v_loc)
        tgt = jnp.take_along_axis(
            logits, jnp.clip(ids, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        tgt = ctx.psum_model(jnp.where(ok, tgt, 0.0))
        tok_loss = (lse - tgt) * m
        return jnp.sum(tok_loss), jnp.sum(m)

    hs = jnp.moveaxis(h_seq.reshape(b, nch, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels_seq.reshape(b, nch, chunk), 1, 0)
    ms = jnp.moveaxis(mask_seq.astype(jnp.float32).reshape(b, nch, chunk), 1, 0)
    sums, cnts = jax.lax.map(one, (hs, ys, ms))
    return jnp.sum(sums), jnp.sum(cnts)


def lm_head_logits(ctx: ShardCtx, params, cfg: ArchConfig, h):
    """h: (B, T, D) -> local-vocab logits (B, T, V_loc) f32 (for decode)."""
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"]
    return jnp.einsum("btd,vd->btv", h, w.astype(ctx.compute_dtype),
                      preferred_element_type=jnp.float32)


def greedy_sample(ctx: ShardCtx, logits):
    """Global argmax over the vocab-sharded logits.  (B, 1, V_loc) -> (B, 1)."""
    v_loc = logits.shape[-1]
    loc_max = jnp.max(logits, axis=-1)
    loc_arg = jnp.argmax(logits, axis=-1) + ctx.model_rank() * v_loc
    gmax = ctx.pmax_model(loc_max)
    cand = jnp.where(loc_max >= gmax, loc_arg, jnp.int32(2**30))
    if ctx.tp > 1:
        cand = -jax.lax.pmax(-cand, ctx.model_axis)  # global argmin of cand
    return cand.astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Layer bodies (shared by train forward & prefill).
# --------------------------------------------------------------------------- #

def _attn_sublayer(ctx, cfg: ArchConfig, run: RunConfig, p, x_seq, positions,
                   dims, cache: Optional[Tuple] = None):
    """norm → attention → residual.  Returns (x_seq, (k, v) for cache)."""
    h = common.rms_norm(x_seq, p["norm1"])
    h_full = ctx.gather_seq(h)
    q, k, v = attn_lib.project_qkv(ctx, sub(p, "attn"), h_full, dims,
                                   cfg.qk_norm, positions, cfg.rope_theta)
    if run.attn_impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        attn_fn = functools.partial(
            fa_ops.flash_attention, causal=True, window=cfg.window,
            block_q=run.attn_chunk_q, block_k=run.attn_chunk_k)
    else:
        attn_fn = functools.partial(
            attn_lib.chunked_attention, causal=True, window=cfg.window,
            chunk_q=run.attn_chunk_q, chunk_k=run.attn_chunk_k)
    if run.remat_attention:
        attn_fn = jax.checkpoint(attn_fn)
    o = attn_fn(q, k, v)
    o = attn_lib.output_proj(ctx, sub(p, "attn"), o)
    return x_seq + ctx.scatter_seq(o), (k, v)


def _ffn_sublayer(ctx, cfg, run, p, x_seq, kind: str):
    h = common.rms_norm(x_seq, p["norm2"])
    if kind == "mlp":
        out = ctx.scatter_seq(mlp_lib.mlp(ctx, sub(p, "mlp"), ctx.gather_seq(h)))
        return x_seq + out, 0.0
    out, aux = moe_lib.moe_block(ctx, sub(p, "moe"), h, cfg.moe)
    return x_seq + out, aux


# --------------------------------------------------------------------------- #
# Forward (train / prefill) — per family.
# --------------------------------------------------------------------------- #

def forward(ctx: ShardCtx, params, specs, cfg: ArchConfig, run: RunConfig,
            x_seq, positions, want_cache: bool = False):
    """Run all blocks.  x_seq: (B, S/tp, D).  Returns (h_seq, aux, caches)."""
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    L = cfg.num_layers

    if cfg.family in ("dense", "vlm", "moe"):
        lp = sub(params, "layers")
        ls = sub(specs, "layers")

        def body(carry, layer):
            x, aux = carry
            layer = common.gather_fsdp(layer, {k: v[1:] if v else v
                                               for k, v in ls.items()}, ctx)
            x, kv = _attn_sublayer(ctx, cfg, run, layer, x, positions, dims)
            kind = "moe" if cfg.family == "moe" else "mlp"
            x, a = _ffn_sublayer(ctx, cfg, run, layer, x, kind)
            out = kv if want_cache else None
            return (x, aux + a), out

        body_fn = jax.checkpoint(body) if run.remat else body
        (x, aux), caches = jax.lax.scan(
            body_fn, (x_seq, jnp.zeros((), jnp.float32)),
            jax.tree.map(lambda v: v, lp))
        return common.rms_norm(x, params["final_norm"]), aux, caches

    if cfg.family == "ssm":
        lp = sub(params, "layers")
        ls = sub(specs, "layers")

        def body(carry, layer):
            x, aux = carry
            layer = common.gather_fsdp(layer, {k: v[1:] if v else v
                                               for k, v in ls.items()}, ctx)
            h = common.rms_norm(x, layer["norm1"])
            if want_cache:
                out, st = ssm_lib.mamba_block(ctx, sub(layer, "ssm"), h,
                                              cfg.ssm, return_state=True)
            else:
                out, st = ssm_lib.mamba_block(ctx, sub(layer, "ssm"), h,
                                              cfg.ssm), None
            return (x + out, aux), st

        body_fn = jax.checkpoint(body) if run.remat else body
        (x, aux), caches = jax.lax.scan(
            body_fn, (x_seq, jnp.zeros((), jnp.float32)), lp)
        return common.rms_norm(x, params["final_norm"]), aux, caches

    if cfg.family == "hybrid":
        return _forward_hybrid(ctx, params, specs, cfg, run, x_seq, positions,
                               dims, want_cache)
    raise ValueError(cfg.family)


def _forward_hybrid(ctx, params, specs, cfg, run, x_seq, positions, dims,
                    want_cache):
    per = cfg.attn_every
    np_ = cfg.num_layers // per
    nm = per - 1
    n_moe = per // cfg.moe.every_n
    pp = sub(params, "periods")
    ps = sub(specs, "periods")

    def reshape_stack(d, n_inner):
        return {k: v.reshape((np_, n_inner) + v.shape[1:]) for k, v in d.items()}

    stacked = {}
    stacked.update({f"attn.{k}": v for k, v in sub(pp, "attn").items()})
    stacked.update({f"ssm.{k}": v for k, v in
                    reshape_stack(sub(pp, "ssm"), nm).items()})
    stacked.update({f"moe.{k}": v for k, v in
                    reshape_stack(sub(pp, "moe"), n_moe).items()})
    stacked.update({f"mlp.{k}": v for k, v in
                    reshape_stack(sub(pp, "mlp"), per - n_moe).items()})
    stacked["norm1"] = pp["norm1"].reshape(np_, per, -1)
    stacked["norm2"] = pp["norm2"].reshape(np_, per, -1)

    def _gathered(period, group: str, idx=None):
        """Per-sublayer param slice + FSDP gather (specs: strip the stack dim)."""
        pl = sub(period, group)
        if idx is not None:
            pl = {k: v[idx] for k, v in pl.items()}
        spec_map = {k: ps[f"{group}.{k}"][1:] for k in pl}
        return common.gather_fsdp(pl, spec_map, ctx)

    def body(carry, period):
        x, aux = carry
        caches = []
        mi = 0
        fi_moe = 0
        fi_mlp = 0
        for i in range(per):
            pl = {"norm1": period["norm1"][i], "norm2": period["norm2"][i]}
            if i == cfg.attn_offset:
                pl.update({f"attn.{k}": v for k, v in
                           _gathered(period, "attn").items()})
                x, kv = _attn_sublayer(ctx, cfg, run, pl, x, positions, dims)
                if want_cache:
                    caches.append(kv)
            else:
                pl_ssm = _gathered(period, "ssm", mi)
                h = common.rms_norm(x, pl["norm1"])
                if want_cache:
                    out, st = ssm_lib.mamba_block(ctx, pl_ssm, h, cfg.ssm,
                                                  return_state=True)
                    caches.append(st)
                else:
                    out = ssm_lib.mamba_block(ctx, pl_ssm, h, cfg.ssm)
                x = x + out
                mi += 1
            if n_moe > 0 and i % cfg.moe.every_n == 1 % cfg.moe.every_n:
                pl2 = {"norm2": period["norm2"][i]}
                pl2.update({f"moe.{k}": v for k, v in
                            _gathered(period, "moe", fi_moe).items()})
                x, a = _ffn_sublayer(ctx, cfg, run, pl2, x, "moe")
                aux = aux + a
                fi_moe += 1
            else:
                pl2 = {"norm2": period["norm2"][i]}
                pl2.update({f"mlp.{k}": v for k, v in
                            _gathered(period, "mlp", fi_mlp).items()})
                x, _ = _ffn_sublayer(ctx, cfg, run, pl2, x, "mlp")
                fi_mlp += 1
        out = tuple(caches) if want_cache else None
        return (x, aux), out

    body_fn = jax.checkpoint(body) if run.remat else body
    (x, aux), caches = jax.lax.scan(
        body_fn, (x_seq, jnp.zeros((), jnp.float32)), stacked)
    return common.rms_norm(x, params["final_norm"]), aux, caches
