"""Expert-parallel Mixture-of-Experts with all_to_all dispatch (GShard-style).

Experts are sharded over the model axis (EP).  Each shard routes its own
sequence slice's tokens, packs them into per-expert capacity slots, and an
all_to_all ships slots to the owning shard; expert FFNs run as one batched
einsum over local experts; a second all_to_all returns outputs, combined
with router weights.  Non-divisible expert counts (qwen2-moe's 60) are
padded to a tp multiple with inert experts (router logits masked to −inf;
DESIGN.md §4).

Shared experts (qwen2-moe) are a plain dense TP MLP added to the output.
An auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import mlp as mlp_lib


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int          # routed experts (pre-padding)
    top_k: int
    d_ff_expert: int
    num_shared: int = 0       # shared-expert copies (qwen2-moe: 4 → one MLP
    d_ff_shared: int = 0      # with d_ff_shared = 4·1408 = 5632)
    capacity_factor: float = 1.25
    every_n: int = 1          # MoE layer cadence (jamba: 2)
    router_aux_weight: float = 0.01

    def padded(self, tp: int) -> int:
        return common.ceil_to(self.num_experts, tp)


def init_moe(pb: common.ParamBuilder, prefix: str, layers: int, d_model: int,
             cfg: MoECfg, tp: int, fsdp):
    m = "model"
    ep = cfg.padded(tp)
    pb.add(f"{prefix}.router", (layers, d_model, ep), (None, None, None),
           scale=0.02)
    pb.add(f"{prefix}.w_up", (layers, ep, d_model, cfg.d_ff_expert),
           (None, m, fsdp, None))
    pb.add(f"{prefix}.w_gate", (layers, ep, d_model, cfg.d_ff_expert),
           (None, m, fsdp, None))
    pb.add(f"{prefix}.w_down", (layers, ep, cfg.d_ff_expert, d_model),
           (None, m, None, fsdp), scale=cfg.d_ff_expert ** -0.5)
    if cfg.num_shared:
        mlp_lib.init_mlp(pb, f"{prefix}.shared", layers, d_model,
                         cfg.d_ff_shared, fsdp)


def moe_block(ctx: common.ShardCtx, p, x_seq, cfg: MoECfg):
    """x_seq: (B, S_loc, D) this shard's sequence slice (tokens are already
    partitioned over the model axis by sequence parallelism — they double as
    the EP dispatch domain).  Returns (out (B, S_loc, D), aux_loss)."""
    cd = ctx.compute_dtype
    b, s_loc, d = x_seq.shape
    t = b * s_loc
    ep = cfg.padded(ctx.tp)
    e_loc = ep // ctx.tp
    x = x_seq.reshape(t, d)

    # ---- routing (f32) ---------------------------------------------------
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    inert = jnp.arange(ep) >= cfg.num_experts
    logits = jnp.where(inert[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)   # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E·Σ_e f_e·P_e over real experts.
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], ep), axis=0)
    p_mean = jnp.mean(probs, axis=0)
    aux = cfg.num_experts * jnp.sum(density * p_mean) * cfg.router_aux_weight

    # ---- capacity slotting ------------------------------------------------
    cap = max(1, int(cfg.capacity_factor * t * cfg.top_k / ep))
    flat_e = expert_ids.reshape(-1)                          # (t*k,)
    onehot = jax.nn.one_hot(flat_e, ep, dtype=jnp.int32)     # (t*k, ep)
    pos = jnp.cumsum(onehot, axis=0) - 1                     # slot per (t,k)
    slot = jnp.sum(pos * onehot, axis=-1)                    # (t*k,)
    keep = slot < cap
    gate_keep = gate_vals.reshape(-1) * keep

    # dispatch buffer (ep, cap, d)
    send = jnp.zeros((ep, cap, d), cd)
    tok_idx = jnp.repeat(jnp.arange(t), cfg.top_k)
    send = send.at[flat_e, jnp.clip(slot, 0, cap - 1)].add(
        jnp.where(keep[:, None], x.astype(cd)[tok_idx], 0))

    # ---- EP all_to_all ----------------------------------------------------
    if ctx.tp > 1:
        send = send.reshape(ctx.tp, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, ctx.model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # (tp, e_loc, cap, d): axis 0 is now the source shard.
        recv = jnp.moveaxis(recv, 0, 1).reshape(e_loc, ctx.tp * cap, d)
    else:
        recv = send.reshape(e_loc, cap, d)

    # ---- expert FFN (batched over local experts) --------------------------
    up = jnp.einsum("ecd,edf->ecf", recv, p["w_up"].astype(cd))
    gate = jnp.einsum("ecd,edf->ecf", recv, p["w_gate"].astype(cd))
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))

    # ---- return trip ------------------------------------------------------
    if ctx.tp > 1:
        out = out.reshape(e_loc, ctx.tp, cap, d)
        out = jnp.moveaxis(out, 1, 0)                        # (tp, e_loc, cap, d)
        out = jax.lax.all_to_all(out, ctx.model_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        out = out.reshape(ep, cap, d)
    else:
        out = out.reshape(ep, cap, d)

    # ---- combine -----------------------------------------------------------
    gathered = out[flat_e, jnp.clip(slot, 0, cap - 1)]       # (t*k, d)
    combined = jnp.sum(
        (gathered * gate_keep[:, None].astype(cd)).reshape(t, cfg.top_k, d),
        axis=1)

    y = combined.reshape(b, s_loc, d)
    if cfg.num_shared:
        # shared experts are a dense-TP MLP: need the full sequence view
        shared_in = ctx.gather_seq(x_seq)
        shared_p = {"w_up": p["shared.w_up"], "w_gate": p["shared.w_gate"],
                    "w_down": p["shared.w_down"]}
        y = y + ctx.scatter_seq(mlp_lib.mlp(ctx, shared_p, shared_in))
    return y, aux


def moe_decode(ctx: common.ShardCtx, p, x, cfg: MoECfg):
    """Decode-time MoE: tokens are replicated over the model axis (no
    sequence parallelism at T = 1), so instead of an all_to_all round-trip
    each shard computes its *local* experts densely for all tokens, masked
    by the router gates, and a single psum combines expert shards.  The
    redundancy (e_loc× extra FFN flops on a handful of tokens) is noise next
    to the weight streaming that dominates decode.

    x: (B, 1, D) replicated.  Returns the FFN output, already psum'd.
    """
    cd = ctx.compute_dtype
    b, one, d = x.shape
    t = b * one
    ep = cfg.padded(ctx.tp)
    e_loc = ep // ctx.tp
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    inert = jnp.arange(ep) >= cfg.num_experts
    logits = jnp.where(inert[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gmat = jnp.sum(gate_vals[..., None]
                   * jax.nn.one_hot(expert_ids, ep, dtype=jnp.float32),
                   axis=1)                                     # (t, ep)
    off = ctx.model_rank() * e_loc
    g_loc = jax.lax.dynamic_slice(gmat, (0, off), (t, e_loc))  # (t, e_loc)

    up = jnp.einsum("td,edf->etf", xt, p["w_up"].astype(cd))
    gate = jnp.einsum("td,edf->etf", xt, p["w_gate"].astype(cd))
    h = jax.nn.silu(gate) * up
    oute = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(cd))
    out = jnp.einsum("te,etd->td", g_loc.astype(cd), oute)

    if cfg.num_shared:
        shared_p = {"w_up": p["shared.w_up"], "w_gate": p["shared.w_gate"],
                    "w_down": p["shared.w_down"]}
        out = out + mlp_lib.mlp(ctx, shared_p, x).reshape(t, d)
    return ctx.psum_model(out.reshape(b, one, d))
