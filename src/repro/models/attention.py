"""GQA attention with qk-norm, sliding windows, chunked (memory-bounded)
softmax, cross-attention and KV-cache decode — manual TP over q-heads.

Memory-efficient attention: online-softmax over KV chunks inside a scan
(Rabe–Staats / flash-attention schedule expressed in XLA), so the compiled
buffer footprint is O(S·chunk) instead of O(S²) — required for the
prefill_32k dry-run cells to fit HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    """Per-shard attention dimensions (derived from config + ctx.tp)."""
    q_heads: int          # global, padded to a multiple of tp
    kv_heads: int         # global
    head_dim: int
    q_local: int
    kv_local: int         # local kv heads (>=1; replicated when kv < tp)
    kv_replicated: bool   # kv weights replicated over the model axis


def attn_dims(num_heads: int, num_kv_heads: int, head_dim: int, tp: int) -> AttnDims:
    qp = common.ceil_to(num_heads, tp)
    kv_rep = num_kv_heads < tp
    return AttnDims(
        q_heads=qp, kv_heads=num_kv_heads, head_dim=head_dim,
        q_local=qp // tp,
        kv_local=num_kv_heads if kv_rep else num_kv_heads // tp,
        kv_replicated=kv_rep)


def init_attention(pb: common.ParamBuilder, prefix: str, layers: int,
                   d_model: int, dims: AttnDims, qk_norm: bool,
                   fsdp: Optional[str], cross: bool = False):
    """Stacked (over `layers`) attention params.  TP shards q-heads; kv
    weights are head-sharded when kv_heads >= tp, else replicated (their
    gradient then syncs over the model axis via the spec rule)."""
    m = "model"
    kv_spec = None if dims.kv_replicated else m
    scale = d_model ** -0.5
    pb.add(f"{prefix}.wq", (layers, d_model, dims.q_heads, dims.head_dim),
           (None, fsdp, m, None), scale=scale)
    pb.add(f"{prefix}.wk", (layers, d_model, dims.kv_heads, dims.head_dim),
           (None, fsdp, kv_spec, None), scale=scale)
    pb.add(f"{prefix}.wv", (layers, d_model, dims.kv_heads, dims.head_dim),
           (None, fsdp, kv_spec, None), scale=scale)
    pb.add(f"{prefix}.wo", (layers, dims.q_heads, dims.head_dim, d_model),
           (None, m, None, fsdp), scale=(dims.q_heads * dims.head_dim) ** -0.5)
    if qk_norm:
        pb.ones(f"{prefix}.q_norm", (layers, dims.head_dim), (None, None))
        pb.ones(f"{prefix}.k_norm", (layers, dims.head_dim), (None, None))


def _select_kv_group(ctx: common.ShardCtx, k, v, dims: AttnDims):
    """When kv is replicated (kv < tp), pick this shard's kv group so local
    q-heads attend to their own kv head(s)."""
    if not dims.kv_replicated or ctx.tp == 1:
        return k, v, (dims.kv_heads if dims.kv_replicated else dims.kv_local)
    # kv < tp: kv projections are computed replicated; each shard keeps only
    # the kv head its q-head block attends to.  Requires tp % kv_heads == 0
    # so a shard's q block lies within one kv group.
    assert ctx.tp % dims.kv_heads == 0, (ctx.tp, dims.kv_heads)
    group_size = dims.q_heads // dims.kv_heads
    first_q = ctx.model_rank() * dims.q_local
    kv_start = first_q // group_size
    k = jax.lax.dynamic_slice_in_dim(k, kv_start, 1, axis=2)
    v = jax.lax.dynamic_slice_in_dim(v, kv_start, 1, axis=2)
    return k, v, 1


def project_qkv(ctx, p, x_full, dims: AttnDims, qk_norm: bool, positions,
                rope_theta: Optional[float]):
    """x_full: (B, S, D) -> q (B,S,ql,hd), k/v (B,S,kv_keep,hd) local."""
    cd = ctx.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x_full, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x_full, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x_full, p["wv"].astype(cd))
    if qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    if rope_theta is not None:
        q = common.apply_rope(q, positions, rope_theta)
        k = common.apply_rope(k, positions, rope_theta)
    k, v, _ = _select_kv_group(ctx, k, v, dims)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      q_offset=0, chunk_q: int = 1024, chunk_k: int = 1024,
                      bidirectional_len: Optional[int] = None):
    """Online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) with Hq % Hkv == 0.
    Returns (B, Sq, Hq, hd).  f32 accumulation, bf16 matmul inputs.
    ``window``: sliding-window (SWA) width — key positions ≤ q_pos − window
    are masked.  ``q_offset``: global position of q[0] (decode).
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    chunk_q = min(chunk_q, sq)
    chunk_k = min(chunk_k, sk)
    nq, nk = sq // chunk_q, sk // chunk_k
    assert sq % chunk_q == 0 and sk % chunk_k == 0, (sq, sk, chunk_q, chunk_k)

    qr = q.reshape(b, nq, chunk_q, hkv, g, hd)
    kr = k.reshape(b, nk, chunk_k, hkv, hd)
    vr = v.reshape(b, nk, chunk_k, hkv, hd)
    scale = hd ** -0.5

    def q_block(args):
        qi, qc = args  # index, (b, chunk_q, hkv, g, hd)
        q_pos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, kc, vc = kv
            k_pos = ki * chunk_k + jnp.arange(chunk_k)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, chunk_q, hd), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (b, hkv, g, chunk_q, hd)

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # (nq, b, hkv, g, chunk_q, hd) -> (b, sq, hq, hd)
    outs = jnp.moveaxis(outs, 0, 3)            # b hkv g nq cq hd
    outs = outs.reshape(b, hkv, g, sq, hd)
    outs = jnp.transpose(outs, (0, 3, 1, 2, 4)).reshape(b, sq, hq, hd)
    return outs.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """Single-token attention against a cache.

    q: (B, 1, Hq, hd); caches: (B, Smax, Hkv, hd); pos: () current length
    (number of valid cache entries).  Returns (B, 1, Hq, hd).
    """
    b, _, hq, hd = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = hq // hkv
    qr = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    k_pos = jnp.arange(smax)
    mask = k_pos[None] < pos
    if window is not None:
        mask &= k_pos[None] > pos - 1 - window
    s = jnp.where(mask[:, None, None, :] if mask.ndim == 2 else mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def output_proj(ctx, p, attn_out):
    """(B, S, Hq_local, hd) -> partial (B, S, D), then scatter_seq sums TP."""
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"].astype(ctx.compute_dtype))
