"""Fault tolerance & straggler mitigation (DESIGN.md §5).

The aggregation-level pieces live where they execute:
  * unbiased partial aggregation — :func:`repro.core.collectives.partial_mean`
    (mask-weighted mean over live nodes; the averaging decoder is
    n-agnostic, so dropping a straggling pod for a step stays unbiased);
  * deterministic per-step wire cost — the fixed-k encoder (§4.4), the
    production default (no long-tail packets);
  * checkpoint/restart + elastic resharding — :mod:`repro.checkpoint`.

This module adds the *simulation/testing* half: a straggler/failure
injector used by tests to exercise those paths deterministically.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.collectives import partial_mean  # noqa: F401  (re-export)
from repro.core.wire import base as wire_base


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Deterministic failure schedule: node i is down at step t iff
    hash(seed, t, i) < rate."""
    rate: float = 0.0
    seed: int = 0

    def _draw(self, step: int, n: int) -> jax.Array:
        """THE survivor rule: one (n,) boolean draw both views derive from.

        ``alive_mask`` (host view) and ``local_alive`` (in-shard view) used
        to duplicate this draw in two hand-kept copies — they now agree by
        construction (property-tested across steps and rates by
        tests/distributed_checks/fault_tolerance_check.py).
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        u = jax.random.uniform(key, (n,))
        alive = u >= self.rate
        # never kill everyone: node argmax(u) always survives
        return alive.at[jnp.argmax(u)].set(True)

    def alive_mask(self, step: int, n: int) -> jax.Array:
        return self._draw(step, n)

    def local_alive(self, step: int, axes) -> jax.Array:
        """Per-shard 0/1 scalar, callable inside shard_map."""
        rank, n = wire_base.axis_rank_size(axes)
        return self._draw(step, n)[rank].astype(jnp.float32)


def robust_mean(x, step: int, axes, plan: FailurePlan):
    """Exact mean over the nodes the failure plan left alive this step."""
    alive = plan.local_alive(step, axes)
    return partial_mean(x * alive, alive, axes)
