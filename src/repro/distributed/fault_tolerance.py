"""Fault tolerance & straggler mitigation (DESIGN.md §5, §14).

The aggregation-level pieces live where they execute:
  * unbiased partial aggregation — :func:`repro.core.collectives.partial_mean`
    (mask-weighted mean over live nodes; the averaging decoder is
    n-agnostic, so dropping a straggling pod for a step stays unbiased);
  * robust decode reductions — ``cfg.decode_policy`` dispatched through the
    wire-codec registry (:mod:`repro.core.wire.robust`): coordinate-wise
    f-of-n trimming / median over the gathered per-peer reconstructions;
  * decode-time peer exclusion — the ``drop_mask`` operand of
    :func:`repro.core.collectives.compressed_mean`: a traced (n,) 0/1 mask
    that excludes peers at decode with zero recompiles;
  * deterministic per-step wire cost — the fixed-k encoder (§4.4), the
    production default (no long-tail packets);
  * checkpoint/restart + elastic resharding — :mod:`repro.checkpoint`.

This module adds the simulation/forensics half:

  * :class:`FailurePlan` — the deterministic failure injector tests drive,
    now also the producer of decode-time drop masks
    (:meth:`FailurePlan.drop_mask`);
  * :func:`robust_compressed_mean` — one compressed round with the plan's
    mask threaded in (the elastic-decode entry point);
  * :func:`replay_support` — reconstruct a dropped node's seed-trick
    support from its fold_in chain alone, for post-mortem reconstruction
    of what the lost wire rows *would* have carried;
  * :func:`corrupt_wire_row` — the adversarial wire-row injector of the
    Byzantine test matrix (tests/distributed_checks/robust_decode_check).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import comm_cost, rotation
from repro.core import types as core_t
from repro.core.collectives import compressed_mean, partial_mean  # noqa: F401
from repro.core.wire import base as wire_base
from repro.core.wire import codecs as wire_codecs
from repro.core.wire import ef as wire_ef
from repro.core.wire import resolve as wire_resolve
from repro.core.wire import rotated as wire_rotated
from repro.kernels.fixed_k_encode import ops as fk


def survivor_index(u) -> jax.Array:
    """THE never-kill-everyone survivor: first index attaining max(u).

    The guaranteed survivor of a failure draw ``u`` (the per-node uniforms
    a :class:`FailurePlan` thresholds) is pinned to one explicit, testable
    rule: the smallest index among the maxima.  ``jnp.argmax`` alone
    already breaks ties this way, but only as an unstated implementation
    detail — spelling the rule out keeps the draw bit-compatible while
    making the tie semantics a contract (property-tested on crafted tied
    arrays by tests/test_fault_tolerance.py).  The max-u node is also the
    node the threshold rule kills *last*: alive = (u >= rate), so the
    designated survivor is a node every rate < 1 would have spared anyway,
    and forcing it alive changes nothing until the draw kills everyone.
    """
    u = jnp.asarray(u)
    return jnp.argmax(u == jnp.max(u))


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Deterministic failure schedule: node i is down at step t iff
    hash(seed, t, i) < rate."""
    rate: float = 0.0
    seed: int = 0

    def _draw(self, step: int, n: int) -> jax.Array:
        """THE survivor rule: one (n,) boolean draw both views derive from.

        ``alive_mask`` (host view) and ``local_alive`` (in-shard view) used
        to duplicate this draw in two hand-kept copies — they now agree by
        construction (property-tested across steps and rates by
        tests/distributed_checks/fault_tolerance_check.py).  The
        never-kill-everyone clamp goes through :func:`survivor_index`.
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        u = jax.random.uniform(key, (n,))
        alive = u >= self.rate
        return alive.at[survivor_index(u)].set(True)

    def alive_mask(self, step: int, n: int) -> jax.Array:
        return self._draw(step, n)

    def local_alive(self, step: int, axes) -> jax.Array:
        """Per-shard 0/1 scalar, callable inside shard_map."""
        rank, n = wire_base.axis_rank_size(axes)
        return self._draw(step, n)[rank].astype(jnp.float32)

    def drop_mask(self, step: int, n: int) -> jax.Array:
        """The (n,) f32 0/1 alive mask in ``compressed_mean`` drop_mask
        form (1 = keep the peer's decoded row).  Same draw as
        :meth:`alive_mask`; pass it as a traced operand so mask changes
        across steps never recompile (DESIGN.md §14)."""
        return self._draw(step, n).astype(jnp.float32)


def robust_mean(x, step: int, axes, plan: FailurePlan):
    """Exact mean over the nodes the failure plan left alive this step."""
    alive = plan.local_alive(step, axes)
    return partial_mean(x * alive, alive, axes)


def robust_compressed_mean(x, key, cfg: core_t.CompressionConfig,
                           step: int, plan: FailurePlan):
    """One compressed round with the plan's drop mask threaded to decode.

    The elastic-decode analogue of :func:`robust_mean`: the wire round runs
    at full strength (collective shapes are static), but peers the plan
    killed this step are excluded from the decode reduction and the
    estimate renormalizes over the survivors — composing with whatever
    ``cfg.decode_policy`` is set (trimming applies to the kept rows).
    Must run inside shard_map like ``compressed_mean`` itself.
    """
    _, n = wire_base.axis_rank_size(tuple(cfg.axes))
    return compressed_mean(x, key, cfg, drop_mask=plan.drop_mask(step, n))


# --------------------------------------------------------------------------- #
# Seed-trick support replay (post-mortem forensics for dropped peers).
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ReplaySupport:
    """A dropped node's reconstructed wire support (all in the WIRE basis).

    ``dim``     — dimension of the basis the support lives in: the model d
                  for plain codecs, ``rotation.padded_dim(d)`` for rotated
                  compositions (the support is drawn on rotated coords).
    ``support`` — (dim,) bool: the coordinates the encoder *sampled* (the
                  S_i of Eq. (1) / the fixed-k block subset).
    ``kept``    — (dim,) bool: the sampled coordinates whose values
                  actually made the wire buffer — ``support`` minus the
                  capacity-overflow drops of the Bernoulli wire (equal to
                  ``support`` for fixed-k, whose buffer never overflows).
    ``slot``    — (dim,) int32: wire-buffer value-slot index per kept
                  coordinate, −1 elsewhere — enough to lift a captured
                  buffer back to the dense message.
    """
    dim: int
    support: jax.Array
    kept: jax.Array
    slot: jax.Array


def _bernoulli_replay(cfg, kenc, dim: int) -> ReplaySupport:
    p = float(cfg.encoder.fraction)
    cap = comm_cost.bernoulli_capacity(dim, p)
    sent = jax.random.uniform(kenc, (dim,), dtype=jnp.float32) < p
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    kept = sent & (pos < cap)
    slot = jnp.where(kept, pos, -1)
    return ReplaySupport(dim=dim, support=sent, kept=kept, slot=slot)


def _fixed_k_replay(cfg, kenc, dim: int) -> ReplaySupport:
    nb = fk.num_blocks(dim)
    kb = wire_codecs.fixed_k_blocks(dim, cfg.encoder.fraction)
    ids = fk.sample_blocks(kenc, nb, kb)
    hit = jnp.zeros((nb,), bool).at[ids].set(True)
    # value-slot of block b = its rank among the sampled ids (sorted), so
    # slot(j) = rank(block(j))·BLOCK + (j mod BLOCK) for sampled blocks.
    rank_of = jnp.full((nb,), -1, jnp.int32).at[ids].set(
        jnp.arange(kb, dtype=jnp.int32))
    support = jnp.repeat(hit, fk.BLOCK)[:dim]
    off = jnp.arange(dim, dtype=jnp.int32) % fk.BLOCK
    slot = jnp.where(
        support,
        jnp.repeat(rank_of, fk.BLOCK)[:dim] * fk.BLOCK + off, -1)
    return ReplaySupport(dim=dim, support=support, kept=support, slot=slot)


def replay_support(cfg: core_t.CompressionConfig, key, peer: int,
                   d: int) -> ReplaySupport:
    """Reconstruct node ``peer``'s seed-trick support from the key chain.

    The §4.4 seed trick is what makes this possible at all: the sampled
    support is a pure function of ``fold_in(key, peer)`` (the exact chain
    ``pack`` uses — the same regeneration every surviving peer's ``unpack``
    already performs), so a node that died mid-round leaves enough behind
    to reconstruct *where* its lost values lived — including the
    capacity-overflow drop pattern of the Bernoulli wire, bit-exactly
    (tests/test_replay_support.py cross-checks against the threefry
    reference ``uniform_at`` and the shipped buffers).

    Dispatch mirrors ``registry.resolve``: EF delegates wholesale (the
    contractive twin rides the inner codec's exact format and fold_in
    chain); rotated compositions replay the inner support in ROTATED
    space at ``rotation.padded_dim(d)`` (see :class:`ReplaySupport.dim`);
    ``fixed_k_shared`` replays the shared (un-folded) key.  Codecs whose
    occupancy is data-dependent (binary/ternary planes, dense simulation)
    have no seed-derivable support and raise ValueError.
    """
    codec = wire_resolve(cfg)
    dim = d
    while True:
        if isinstance(codec, wire_ef.EFCodec):
            codec = codec.inner
        elif isinstance(codec, wire_rotated.RotatedCodec):
            dim = rotation.padded_dim(dim)
            codec = codec.inner
        else:
            break
    if isinstance(codec, wire_codecs.BernoulliCodec):
        return _bernoulli_replay(cfg, jax.random.fold_in(key, peer), dim)
    if isinstance(codec, wire_codecs.FixedKSharedCodec):
        return _fixed_k_replay(cfg, key, dim)
    if isinstance(codec, wire_codecs.FixedKGatherCodec):
        return _fixed_k_replay(cfg, jax.random.fold_in(key, peer), dim)
    raise ValueError(
        f"codec {codec.name!r} has no seed-derivable support to replay "
        "(data-dependent occupancy: bit-plane and dense wires)")


# --------------------------------------------------------------------------- #
# Adversarial wire-row injection (the Byzantine test matrix).
# --------------------------------------------------------------------------- #

CORRUPTION_MODES = ("nan", "inf", "sign_flip", "boost")


def corrupt_wire_row(row, mode: str):
    """One Byzantine peer's wire buffer: ``row`` corrupted in-place-shape.

    Operates on the REAL wire representation — integer plane buffers are
    bitcast to f32, corrupted, and bitcast back — so the corruption
    travels through the unmodified gather + unpack exactly like honest
    bytes (tests/distributed_checks/robust_decode_check.py injects it
    after ``pack`` inside shard_map).  Modes: "nan"/"inf" flood the
    buffer with non-finite values, "sign_flip" negates it, "boost"
    scales it by 1000.
    """
    if mode not in CORRUPTION_MODES:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         f"have {CORRUPTION_MODES}")
    as_words = jnp.issubdtype(row.dtype, jnp.integer)
    x = jax.lax.bitcast_convert_type(row, jnp.float32) if as_words \
        else row.astype(jnp.float32)
    if mode == "nan":
        x = jnp.full_like(x, jnp.nan)
    elif mode == "inf":
        x = jnp.full_like(x, jnp.inf)
    elif mode == "sign_flip":
        x = -x
    else:
        x = 1000.0 * x
    if as_words:
        return jax.lax.bitcast_convert_type(x, row.dtype)
    return x.astype(row.dtype)
