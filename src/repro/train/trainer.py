"""Trainer: step loop with checkpoint/restart, async saves, straggler hooks.

Fault-tolerance contract (DESIGN.md §5):
  * every `ckpt_every` steps the full (params, opt, step, rng-cursor) state
    is saved with atomic commit (AsyncCheckpointer overlaps with compute);
  * on (re)start the trainer auto-resumes from the newest valid checkpoint
    — data is a pure function of step, so the stream realigns exactly;
  * elastic restart on a different mesh works via restore-time resharding;
  * straggler mitigation is structural: the production encoder is fixed-k
    (deterministic per-step bytes, §4.4) and `partial_mean` allows dropping
    a dead pod's contribution for a step without bias (core/collectives).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointing as ckpt
from repro.configs.base import ArchConfig, RunConfig, ShapeSpec
from repro.data.pipeline import SyntheticLM
from repro.optim.optimizers import AdamWConfig
from repro.train import train_step as ts

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, mesh, cfg: ArchConfig, run: RunConfig,
                 shape: ShapeSpec, tcfg: TrainerConfig,
                 opt_cfg: Optional[AdamWConfig] = None):
        self.mesh = mesh
        self.cfg = cfg
        self.run = run
        self.shape = shape
        self.tcfg = tcfg
        # sync_plan is THE grad-sync plan the step executes (None =
        # per-leaf path): examples/diagnostics read bucket ids + the
        # readiness schedule from here.
        (self.step_fn, self.init_fn, self.specs, self.bspecs,
         self.sync_plan) = ts.build_train_step(mesh, cfg, run, shape,
                                               opt_cfg, base_seed=tcfg.seed)
        # (the schedule itself is logged by build_train_step)
        self.overlap = ts.overlap_enabled(self.sync_plan, run)
        self.data = SyntheticLM(cfg, shape, seed=tcfg.seed)
        self.ckpt = ckpt.AsyncCheckpointer()
        self.metrics_history = []
        # final error-feedback state (per-bucket residuals) after fit();
        # examples/diagnostics read the residual norms from here.
        self.ef_state = None

    def init_or_restore(self):
        params, opt_state, ef = self.init_fn(jax.random.PRNGKey(self.tcfg.seed))
        start = 0
        if self.tcfg.ckpt_dir and ckpt.latest_step(self.tcfg.ckpt_dir) is not None:
            start, params, opt_state, extra = ckpt.restore(
                self.tcfg.ckpt_dir, self.mesh, self.specs, opt_state)
            log.info("restored checkpoint at step %d", start)
        return start, params, opt_state, ef

    def fit(self):
        start, params, opt_state, ef = self.init_or_restore()
        t0 = time.time()
        for step in range(start, self.tcfg.steps):
            batch = self.data.device_batch(step, self.mesh, self.bspecs)
            params, opt_state, ef, metrics = self.step_fn(
                params, opt_state, ef, batch, jnp.int32(step))
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["sec"] = time.time() - t0
                self.metrics_history.append(m)
                log.info("step %d loss %.4f gnorm %.3f", step, m["loss"],
                         m["grad_norm"])
            if (self.tcfg.ckpt_dir
                    and (step + 1) % self.tcfg.ckpt_every == 0):
                self.ckpt.save(self.tcfg.ckpt_dir, step + 1, params,
                               opt_state, self.specs,
                               extra={"arch": self.cfg.name},
                               keep_last=self.tcfg.keep_last)
        self.ckpt.wait()
        self.ef_state = ef
        if self.tcfg.ckpt_dir:
            ckpt.save(self.tcfg.ckpt_dir, self.tcfg.steps, params, opt_state,
                      self.specs, extra={"arch": self.cfg.name},
                      keep_last=self.tcfg.keep_last)
        return params, opt_state, self.metrics_history
