"""Gradient bucketing: few flat collectives instead of one per pytree leaf.

The per-leaf gradient-sync rule (train_step.sync_grads) issues one
collective per pytree leaf — hundreds of tiny launches per step whose fixed
cost dwarfs the wire time for small leaves, while the paper's cost model
(§4–§6) only charges per communicated coordinate.  Suresh et al.
(arXiv:1611.00429) and DRIVE (arXiv:2105.08339) both operate on flat,
bucketized vectors for exactly this reason.

This module plans and executes that bucketing:

  * :func:`build_plan` — a *static* (host-side) partition of the grad tree
    into fixed-capacity f32 buckets.  Leaves are grouped by their sync
    signature — the mesh axes absent from their sharding spec, split into
    compressed axes (∩ cfg.axes, for leaves ≥ min_compress_size when a
    compression mode is on) and exact axes — and greedily packed in sorted
    name order.  Small leaves ride "exact" buckets (one plain psum-mean per
    bucket); a leaf larger than the capacity gets a dedicated oversize
    bucket (leaves are never split, so scatter is bit-exact).  The plan is
    a pure function of (abstract shapes, specs, mesh, config): identical
    across processes and across steps, which is what lets error-feedback
    state be keyed by bucket id.

  * :func:`pack_bucket` / :func:`unpack_bucket` — flatten leaves into the
    bucket's f32 vector and scatter results back to the original
    shapes/dtypes (bit-exact round trip for f32/bf16 grads: f32 holds
    every bf16 exactly).

  * :func:`sync_grads_bucketed` — the bucketed replacement for
    train_step.sync_grads: per bucket, pmean over the exact axes and one
    stateful codec round (encode → single fused collective → decode) over
    the compressed axes.  Error feedback is just the stateful codec case:
    the registry resolves an EF-wrapped codec
    (repro.core.wire.ef.EFCodec) and the per-bucket residuals come from
    :func:`init_ef_state`, whose shapes the resolved codec declares
    (``WireCodec.state_shape``).

  * :func:`overlap_params` — the *overlapped* issue schedule
    (``BucketSpec.overlap``, docs/DESIGN.md §9): instead of syncing the
    finished grad tree after backward, each bucket's leaves are wrapped in
    an identity sync point whose ``custom_vjp`` backward rule runs that
    bucket's :func:`_bucket_round`.  Differentiating the tagged params
    therefore emits every pack→collective→unpack *inside* the gradient
    computation, anchored only on its own leaves' cotangents — the bucket's
    collective becomes issuable the moment its last grad leaf exists
    (``Bucket.ready``) rather than after the whole loss graph.  The codec
    rounds and the ``fold_in`` chain are shared with
    :func:`sync_grads_bucketed` via :func:`_bucket_round`, so the two
    schedules agree bit-for-bit (enforced by tests/distributed_checks/
    overlap_check.py for stateless and stateful codecs alike).

Numerics vs the per-leaf path: identical for exact buckets (pmean is
elementwise, and mean-over-eaxes∘mean-over-caxes == mean over both); for
compressed buckets the estimate is the same protocol applied to the
concatenated vector — per-coordinate unbiasedness is unchanged (Lemmas
3.1/3.3 are coordinate-wise), only the node-center μ and the fixed-k
support are now drawn per bucket instead of per leaf.  Which wire format a
compressed bucket rides is decided by the codec registry
(repro.core.wire.registry.resolve — binary/ternary buckets land on packed
uint32 bit-plane buffers, §7.2-rotated configs on the composed rotated
codec): the per-bucket scalars (μ resp. vmin/vmax, c1/c2) are likewise
drawn per bucket, and :func:`bucket_wire_bits` charges each bucket the
resolved codec's exact gathered bits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives as coll
from repro.core import types as t
from repro.core import wire


# --------------------------------------------------------------------------- #
# Plan data model.
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's placement inside a bucket (local, per-shard extents)."""

    name: str
    offset: int
    size: int
    shape: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A flat f32 aggregation unit: one collective per step.

    kind "exact": a single pmean over ``eaxes`` (``caxes`` is empty).
    kind "compressed": pmean over ``eaxes`` (if any), then compressed_mean
    over ``caxes``.

    ``ready`` is the bucket's slot in the readiness schedule: the
    backward-order index of its last-produced leaf.  Leaves are produced in
    backward in the reverse of their (canonical, sorted-name) forward
    order, so a bucket's grads are all available once the leaf with the
    largest backward index has been produced — that index is when the
    overlapped schedule (:func:`overlap_params`) can issue the bucket's
    collective.  Purely static metadata: it never enters the numerics (the
    PRNG chain folds the bucket's *plan position*, not its readiness).
    """

    bid: str
    kind: str                      # "exact" | "compressed"
    caxes: Tuple[str, ...]
    eaxes: Tuple[str, ...]
    slots: Tuple[LeafSlot, ...]
    size: int
    ready: int = -1


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    passthrough: Tuple[str, ...]   # leaves whose spec covers every mesh axis

    def leaf_names(self) -> Tuple[str, ...]:
        return tuple(sorted(
            list(self.passthrough)
            + [s.name for b in self.buckets for s in b.slots]))

    def schedule(self) -> Tuple[str, ...]:
        """Bucket ids in readiness order — the order the overlapped
        backward can issue their collectives (ties broken by bid so the
        schedule is deterministic)."""
        return tuple(b.bid for b in sorted(self.buckets,
                                           key=lambda b: (b.ready, b.bid)))


# --------------------------------------------------------------------------- #
# Plan construction (host-side, static).
# --------------------------------------------------------------------------- #

def leaf_sync_axes(spec, mesh_axes: Sequence[str]) -> Tuple[str, ...]:
    """Mesh axes absent from the leaf's spec — the unreduced X_i axes."""
    present = set()
    for s in spec:
        if s is None:
            continue
        for a in ((s,) if isinstance(s, str) else s):
            present.add(a)
    return tuple(a for a in mesh_axes if a not in present)


def local_shape(shape: Sequence[int], spec,
                mesh_sizes: Mapping[str, int]) -> Tuple[int, ...]:
    """Per-shard extents of a leaf inside shard_map (global ÷ spec axes)."""
    out = []
    for j, dim in enumerate(shape):
        s = spec[j] if j < len(spec) else None
        axes = () if s is None else ((s,) if isinstance(s, str) else tuple(s))
        q = 1
        for a in axes:
            q *= mesh_sizes.get(a, 1)
        if q > 1 and dim % q:
            raise ValueError(
                f"dim {dim} not divisible by sharding {axes} (= {q})")
        out.append(dim // q if q > 1 else dim)
    return tuple(out)


def _bucket_id(kind: str, caxes, eaxes, idx: int) -> str:
    return (f"{kind}:{'+'.join(caxes) if caxes else '-'}"
            f":{'+'.join(eaxes) if eaxes else '-'}:{idx}")


def build_plan(shapes: Mapping[str, Sequence[int]], specs: Mapping[str, tuple],
               mesh_axes: Sequence[str], mesh_sizes: Mapping[str, int],
               cmp: t.CompressionConfig) -> BucketPlan:
    """Partition a grad tree (given by *global* leaf shapes + specs) into
    buckets.  Deterministic: leaves are visited in sorted-name order and
    packed first-fit into the open bucket of their signature.  The plan —
    bucket ids, slot offsets AND the readiness schedule — is a pure
    function of the *sorted* (shapes, specs, mesh, config): shuffling the
    insertion order of the input mappings cannot change it (hypothesis
    property in tests/test_plan_stability.py), which is what lets EF state
    be keyed by bucket id and the overlap schedule agree across processes.

    Readiness: leaf backward order is the reverse of the canonical
    sorted-name order (model param names sort by layer, and backward
    produces grads in reverse layer order); ``Bucket.ready`` is the largest
    backward index over the bucket's slots — the point in backward at which
    its last grad leaf exists.
    """
    cap = cmp.bucket.capacity
    names = sorted(shapes)
    # backward production index per leaf: last forward leaf is produced
    # first in backward.
    bwd_index = {name: len(names) - 1 - i for i, name in enumerate(names)}
    open_slots: Dict[tuple, list] = {}
    open_fill: Dict[tuple, int] = {}
    counts: Dict[tuple, int] = {}
    buckets = []
    passthrough = []

    def close(sig):
        slots = open_slots.pop(sig)
        fill = open_fill.pop(sig)
        idx = counts.get(sig, 0)
        counts[sig] = idx + 1
        kind = sig[0]
        caxes, eaxes = sig[1], sig[2]
        ready = max(bwd_index[s.name] for s in slots)
        buckets.append(Bucket(_bucket_id(kind, caxes, eaxes, idx), kind,
                              caxes, eaxes, tuple(slots), fill, ready))

    for name in names:
        shp = shapes[name]
        shp = tuple(shp.shape) if hasattr(shp, "shape") else tuple(shp)
        lshape = local_shape(shp, specs[name], mesh_sizes)
        size = 1
        for d in lshape:
            size *= d
        axes = leaf_sync_axes(specs[name], mesh_axes)
        if not axes:
            passthrough.append(name)
            continue
        caxes = tuple(a for a in axes if a in cmp.axes)
        eaxes = tuple(a for a in axes if a not in cmp.axes)
        compressed = (bool(caxes) and cmp.mode != "none"
                      and size >= cmp.min_compress_size)
        if compressed:
            sig = ("compressed", caxes, eaxes)
        else:
            sig = ("exact", (), axes)  # one pmean over all sync axes
        fill = open_fill.get(sig, 0)
        if fill and fill + size > cap:
            close(sig)
            fill = 0
        open_slots.setdefault(sig, []).append(
            LeafSlot(name, fill, size, lshape))
        open_fill[sig] = fill + size

    for sig in list(open_slots):
        close(sig)
    return BucketPlan(tuple(buckets), tuple(passthrough))


def plan_for_run(aparams: Mapping[str, jax.ShapeDtypeStruct],
                 specs: Mapping[str, tuple], mesh_axes: Sequence[str],
                 mesh_sizes: Mapping[str, int],
                 cmp: t.CompressionConfig) -> Optional[BucketPlan]:
    """The plan the train step uses, or None when bucketing is disabled."""
    if not cmp.bucket.enabled:
        return None
    return build_plan(aparams, specs, mesh_axes, mesh_sizes, cmp)


# --------------------------------------------------------------------------- #
# Pack / scatter.
# --------------------------------------------------------------------------- #

def pack_bucket(grads: Mapping[str, jax.Array], bucket: Bucket) -> jax.Array:
    """Concatenate the bucket's leaves into one flat f32 vector."""
    return jnp.concatenate(
        [grads[s.name].reshape(-1).astype(jnp.float32)
         for s in bucket.slots])


def unpack_bucket(vec: jax.Array, bucket: Bucket,
                  like: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
    """Scatter a bucket vector back to leaf shapes/dtypes (from ``like``)."""
    out = {}
    for s in bucket.slots:
        g = jax.lax.slice_in_dim(vec, s.offset, s.offset + s.size)
        out[s.name] = g.reshape(s.shape).astype(like[s.name].dtype)
    return out


# --------------------------------------------------------------------------- #
# Wire accounting.
# --------------------------------------------------------------------------- #

def bucket_wire_bits(plan: BucketPlan, cfg: t.CompressionConfig,
                     n: int, mesh_sizes: Optional[Mapping[str, int]] = None
                     ) -> Dict[str, float]:
    """Gathered wire bits per compressed bucket and round, keyed by bid.

    Star-protocol payload convention (the one the paper's C sums and the
    PR-1 capacity-accounting checks use): n × the per-node wire buffer
    bits — exactly what the lowered HLO's collective result shape shows.
    Only defined for gather_decode wire paths; other modes return {}.

    The per-bucket bits come straight from the codec registry
    (``wire.resolve(cfg).wire_bits``) — the same dispatch rule
    sync_grads_bucketed executes, so accounting can never drift from the
    wire (dense-sim fallbacks are charged dense f32 bits; rotated
    compositions the inner codec's payload at the rotated length;
    error-feedback wraps delegate to their inner codec — residuals are
    local, so EF costs exactly what the wrapped codec costs).

    ``n`` is the flat world size over the compression axes; hierarchical
    configs (``cfg.inner_axes``) are billed at the cross-host group size
    (:func:`repro.core.wire.effective_nodes`), which requires
    ``mesh_sizes`` — only the messages that cross the slow link exist.
    """
    if cfg.mode != "gather_decode":
        return {}
    n_eff = wire.effective_nodes(cfg, n, mesh_sizes)
    codec = wire.resolve(cfg)
    # flat-scatter buckets (§12) additionally ship the rank-offset counts
    # and the decoded-shard gather on the same axes — billed by
    # scatter_bits (0 for every non-scatter / hierarchical config, whose
    # extra collectives ride the free inner link per the §11 convention).
    return {b.bid: float(codec.wire_bits(n_eff, b.size, cfg)
                         + codec.scatter_bits(n_eff, b.size, cfg))
            for b in plan.buckets if b.kind == "compressed"}


# --------------------------------------------------------------------------- #
# The bucketed gradient-sync rule.
# --------------------------------------------------------------------------- #

def ef_state_shapes(plan: BucketPlan,
                    cfg: t.CompressionConfig) -> Dict[str, Tuple[int, ...]]:
    """Codec state shapes per compressed bucket, keyed by bucket id.

    THE source of truth for the error-feedback residual pytree: the
    resolved codec declares its state (``WireCodec.state_shape``), so the
    train step, the dry-run lowering and the initializer can never drift
    from what ``sync_grads_bucketed`` actually threads.  Empty for
    stateless configurations.
    """
    out = {}
    for b in plan.buckets:
        if b.kind != "compressed":
            continue
        lcfg = _bucket_cfg(b, cfg, error_feedback=True)
        shp = wire.resolve(lcfg).state_shape(b.size, lcfg)
        if shp is not None:
            out[b.bid] = shp
    return out


def init_ef_state(plan: BucketPlan,
                  cfg: t.CompressionConfig) -> Dict[str, jax.Array]:
    """Zero codec state (EF residuals), one f32 buffer per compressed
    bucket — shapes derived from the resolved codec via
    :func:`ef_state_shapes` (this replaced the two hand-rolled residual
    initializers that used to live here and in core.error_feedback)."""
    return {bid: jnp.zeros(shp, jnp.float32)
            for bid, shp in ef_state_shapes(plan, cfg).items()}


def _bucket_cfg(b: Bucket, cmp: t.CompressionConfig, *,
                error_feedback: bool) -> t.CompressionConfig:
    """The per-bucket codec config: compression axes narrowed to the
    bucket's caxes and the hierarchical inner axes narrowed to the ones
    the bucket actually syncs over (its eaxes) — a leaf already sharded
    over an inner axis has no inner group to pre-reduce, and hierarchical
    scatter_decode degrades with it (nothing to scatter over).  A flat
    config (no inner axes to begin with) keeps its scatter_decode: the
    flat-mesh scatter (DESIGN.md §12) shards over the bucket's caxes."""
    inner = tuple(a for a in b.eaxes if a in cmp.inner_axes)
    return dataclasses.replace(
        cmp, axes=b.caxes, inner_axes=inner,
        scatter_decode=cmp.scatter_decode
        and (bool(inner) == bool(cmp.inner_axes)),
        error_feedback=error_feedback)


def _bucket_round(grads: Mapping[str, jax.Array], b: Bucket, j: int,
                  cmp: t.CompressionConfig, key, ef):
    """ONE bucket's sync: pack → (pmean / codec round) → unpack.

    THE shared body of both issue schedules — :func:`sync_grads_bucketed`
    runs it per bucket after backward, :func:`overlap_params` runs it
    inside each sync point's backward rule — so the two cannot drift: same
    ops, same ``fold_in(key, j)`` chain (j = the bucket's *plan position*,
    never its readiness), hence bit-identical estimates.  ``ef`` is the
    bucket's residual (engages the stateful EF-wrapped codec) or None.
    Returns (synced leaf dict, new residual or None).

    Hierarchical configs: the bucket's exact axes that are codec inner
    axes ride the codec round (the codec pre-reduces them and, with
    scatter_decode, all_gathers decoded shards over them); only the
    remaining exact axes get the standalone pmean here.  Flat configs take
    the historical path op-for-op.
    """
    v = pack_bucket(grads, b)
    if b.kind == "exact":
        return unpack_bucket(jax.lax.pmean(v, b.eaxes), b, grads), ef
    lcfg = _bucket_cfg(b, cmp, error_feedback=ef is not None)
    pre = tuple(a for a in b.eaxes if a not in lcfg.inner_axes)
    if pre:
        v = jax.lax.pmean(v, pre)
    kb = jax.random.fold_in(key, j)
    if ef is not None:
        v, e = coll.compressed_mean_stateful(v, ef, kb, lcfg)
        return unpack_bucket(v, b, grads), e
    v = coll.compressed_mean(v, kb, lcfg)
    return unpack_bucket(v, b, grads), None


def sync_grads_bucketed(grads: Mapping[str, jax.Array], plan: BucketPlan,
                        cmp: t.CompressionConfig, key,
                        ef_state: Optional[Mapping[str, jax.Array]] = None):
    """Bucketed replacement for train_step.sync_grads (post-backward
    schedule; the overlapped schedule is :func:`overlap_params`).

    Must run inside shard_map with every mesh axis manual.  Returns
    (synced_grads, new_ef_state); new_ef_state is None iff ef_state is.
    Passing ``ef_state`` engages the error-feedback codec wrap (the
    registry resolves ``ef_*``); without it the plain codec runs.
    """
    out = {name: grads[name] for name in plan.passthrough}
    new_ef = {} if ef_state is not None else None
    for j, b in enumerate(plan.buckets):
        ef = (ef_state[b.bid]
              if ef_state is not None and b.kind == "compressed" else None)
        synced, e = _bucket_round(grads, b, j, cmp, key, ef)
        if ef is not None:
            new_ef[b.bid] = e
        out.update(synced)
    return out, new_ef


# --------------------------------------------------------------------------- #
# The overlapped issue schedule (BucketSpec.overlap; docs/DESIGN.md §9).
# --------------------------------------------------------------------------- #

def _sync_point(b: Bucket, j: int, cmp: t.CompressionConfig, stateful: bool):
    """A per-bucket identity whose backward rule IS the bucket's sync.

    Forward passes the bucket's leaves through untouched; the custom_vjp
    backward receives exactly those leaves' cotangents — available at the
    bucket's readiness point (``b.ready``), not after the full loss graph —
    and returns :func:`_bucket_round` of them.  The residual rides the
    ``ef`` argument: its "cotangent" is defined to be the bucket's new
    residual, so ``jax.grad`` w.r.t. the EF pytree returns the updated
    state (out-of-order bucket completion is safe by construction — each
    bucket's residual chain touches only its own slot; DESIGN.md §9).  The
    PRNG key's cotangent is the conventional float0 zero.
    """

    @jax.custom_vjp
    def tag(leaves, ef, key):
        return {n: leaves[n] for n in leaves}

    def fwd(leaves, ef, key):
        return {n: leaves[n] for n in leaves}, (ef, key)

    def bwd(res, g):
        ef, key = res
        synced, new_ef = _bucket_round(g, b, j, cmp, key,
                                       ef if stateful else None)
        if not stateful:
            new_ef = ef
        key_ct = np.zeros(jnp.shape(key), jax.dtypes.float0)
        return synced, new_ef, key_ct

    tag.defvjp(fwd, bwd)
    return tag


def overlap_params(params: Mapping[str, jax.Array], plan: BucketPlan,
                   cmp: t.CompressionConfig, key,
                   ef_state: Optional[Mapping[str, jax.Array]] = None):
    """Wrap the param tree with per-bucket sync points (overlap schedule).

    ``loss(overlap_params(p, ...))`` differentiates to the SAME synced
    grads :func:`sync_grads_bucketed` returns — bit-for-bit, every codec,
    stateful EF included — but each bucket's pack→collective→unpack is
    emitted *inside* the gradient computation, anchored only on that
    bucket's leaf cotangents, so it is issuable as soon as its last grad
    leaf exists instead of trailing the loss graph (HLO-verified by
    tests/distributed_checks/overlap_check.py).

    Usage (the train step's overlapped path)::

        def loss2(p, ef):
            return loss_fn(bucketing.overlap_params(p, plan, cmp, key, ef))
        (loss, aux), (grads, new_ef) = jax.value_and_grad(
            loss2, argnums=(0, 1), has_aux=True)(params, ef_state)

    With ``ef_state=None`` pass any pytree (e.g. ``{}``) as the second
    argument; its gradient is returned unchanged.  Passthrough leaves are
    left untagged — their grads flow through exactly as in the
    post-backward schedule.
    """
    tagged = dict(params)
    for j, b in enumerate(plan.buckets):
        stateful = (ef_state is not None and b.kind == "compressed"
                    and b.bid in ef_state)
        ef_b = ef_state[b.bid] if stateful else jnp.zeros((0,), jnp.float32)
        tag = _sync_point(b, j, cmp, stateful)
        sub = {s.name: params[s.name] for s in b.slots}
        tagged.update(tag(sub, ef_b, key))
    return tagged
