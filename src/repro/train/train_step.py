"""Distributed train step: shard_map (all axes manual) + microbatch
accumulation + per-leaf gradient synchronization with the paper's
compressed mean estimation.

Gradient-sync rule (DESIGN.md §4): after backward, a leaf's gradient is
already correct across every mesh axis that appears in its sharding spec
(TP/EP collectives transpose to the right reductions; FSDP all_gathers
transpose to exact in-data reduce_scatters).  The axes *absent* from the
spec still hold unreduced per-replica contributions — exactly the paper's
X_i.  Those axes are synchronized by:

  * compressed_mean (encode → collective → decode) on axes ∩ cfg.axes for
    leaves ≥ min_compress_size — the paper's technique on the wire, with
    the wire format resolved by the codec registry (repro.core.wire:
    fixed-k / Bernoulli seed-trick / packed bit-planes / §7.2-rotated
    compositions, per the config's encoder);
  * exact psum-mean on the remainder (small leaves, non-selected axes).

By default the rule executes *bucketed* (repro.train.bucketing, enabled by
cmp.bucket): leaves sharing a sync signature are packed into a few flat
f32 buckets and the step issues one collective per bucket instead of one
per leaf; sync_grads below is the per-leaf reference path (bucket.enabled
= False), kept for A/B tests and as executable documentation of the rule.

Issue schedule (docs/DESIGN.md §9): with ``cmp.bucket.overlap`` (default
ON) and no microbatch accumulation, the bucketed sync is *pipelined into
backward* — the step differentiates the loss of
``bucketing.overlap_params(params, ...)``, whose per-bucket sync points
emit each pack→collective→unpack inside the gradient computation at the
bucket's readiness point (``Bucket.ready``).  Overlapped and
post-backward schedules are bit-identical by construction (same codec
rounds, same fold_in chain); ``microbatches > 1`` always syncs the
accumulated grads after the scan (compressed codecs are nonlinear — one
codec round per step is the contract, not one per microbatch).
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import SHAPES, ArchConfig, RunConfig, ShapeSpec
from repro.core import collectives as coll
from repro.core import types as core_types
from repro.models import model as model_lib
from repro.models.common import ShardCtx
from repro.optim import optimizers as opt_lib
from repro.train import bucketing

log = logging.getLogger("repro.train_step")


# --------------------------------------------------------------------------- #
# Spec plumbing.
# --------------------------------------------------------------------------- #

def spec_to_pspec(spec) -> P:
    return P(*spec)


def mesh_sizes_of(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def abstract_specs(key, cfg: ArchConfig, ctx: ShardCtx, mesh_sizes, run):
    """Param spec tree (+ global ShapeDtypeStructs) without device state."""
    return model_lib.init(key, cfg, ctx, mesh_sizes, run, abstract=True)


def grad_sync_plan(mesh, run: RunConfig, aparams, specs):
    """The BucketPlan the train step will sync with (None = per-leaf path).

    Single source of truth for the plan derivation: build_train_step and
    launch/dryrun (which must mirror the step's ef_state pytree when
    lowering) both call this with the same abstract tree.
    """
    return bucketing.plan_for_run(aparams, specs, tuple(mesh.axis_names),
                                  mesh_sizes_of(mesh), run.compression)


def overlap_enabled(plan, run: RunConfig) -> bool:
    """THE eligibility rule for the backward-pipelined issue schedule.

    One predicate shared by the step builder, the Trainer and the dry-run
    record so they can never disagree about which schedule the lowered
    step executes: bucketed sync + the overlap knob + a single backward
    (grad accumulation must run its one codec round on the accumulated
    grads after the scan — DESIGN.md §9).
    """
    return (plan is not None and run.compression.bucket.overlap
            and run.microbatches == 1)


# --------------------------------------------------------------------------- #
# Batch sharding.
# --------------------------------------------------------------------------- #

def batch_axes_for(cfg: ArchConfig, run: RunConfig, shape: ShapeSpec,
                   mesh_sizes: Dict[str, int]) -> Tuple[str, ...]:
    """Largest prefix of candidate axes whose product divides global_batch."""
    if run.model_parallel:
        cands = [a for a in ("pod", "data") if a in mesh_sizes]
    else:
        cands = [a for a in ("data", "model") if a in mesh_sizes]
    chosen = []
    prod = 1
    for a in cands:
        if shape.global_batch % (prod * mesh_sizes[a]) == 0:
            chosen.append(a)
            prod *= mesh_sizes[a]
    return tuple(chosen)


def batch_pspec(cfg: ArchConfig, baxes) -> Dict[str, P]:
    tok = P(baxes if baxes else None)
    out = {"tokens": tok, "labels": tok, "mask": tok}
    if cfg.family == "vlm":
        out["patches"] = P(baxes if baxes else None, None, None)
    if cfg.family == "encdec":
        out["frames"] = P(baxes if baxes else None, None, None)
    return out


# --------------------------------------------------------------------------- #
# Gradient synchronization (the paper's technique lives here).
# --------------------------------------------------------------------------- #

def sync_grads(grads, specs, mesh_axes, cmp: core_types.CompressionConfig,
               key, batch_axes, ef_state=None):
    """Per-leaf: mean over spec-absent axes; compressed where configured.

    Axes that neither carry the batch nor appear in the leaf spec hold
    *identical* replicas (e.g. pod when batch doesn't span it) — a plain
    pmean there is a no-op numerically but keeps VMA/replication lint
    honest, so we just include them in the exact set.
    Returns (synced_grads, new_ef_state).
    """
    flat_specs = specs
    new_ef = {} if ef_state is not None else None

    out = {}
    for i, (name, g) in enumerate(sorted(grads.items())):
        spec = flat_specs[name]
        axes = bucketing.leaf_sync_axes(spec, mesh_axes)
        if not axes:
            out[name] = g
            continue
        caxes = tuple(a for a in axes if a in cmp.axes)
        eaxes = tuple(a for a in axes if a not in cmp.axes)
        if eaxes:
            g = jax.lax.pmean(g, eaxes)
        if caxes and cmp.mode != "none" and g.size >= cmp.min_compress_size:
            kleaf = jax.random.fold_in(key, i)
            if ef_state is not None:
                # error feedback == the stateful codec round (the registry
                # resolves the EF-wrapped codec; repro.core.wire.ef).
                lcfg = dataclasses.replace(cmp, axes=caxes,
                                           error_feedback=True)
                g, e = coll.compressed_mean_stateful(
                    g, ef_state[name], kleaf, lcfg)
                new_ef[name] = e
            else:
                lcfg = dataclasses.replace(cmp, axes=caxes,
                                           error_feedback=False)
                g = coll.compressed_mean(g, kleaf, lcfg)
        elif caxes:
            g = jax.lax.pmean(g, caxes)
            if ef_state is not None:
                new_ef[name] = ef_state[name]
        elif ef_state is not None:
            new_ef[name] = ef_state[name]
        out[name] = g
    return out, new_ef


# --------------------------------------------------------------------------- #
# The step builder.
# --------------------------------------------------------------------------- #

def build_train_step(mesh, cfg: ArchConfig, run: RunConfig, shape: ShapeSpec,
                     opt_cfg: Optional[opt_lib.AdamWConfig] = None,
                     base_seed: int = 0):
    """Returns (step_fn, init_fn, specs, batch_specs, sync_plan).

    step_fn(params, opt_state, ef_state, batch, step) -> (params, opt_state,
    ef_state, metrics); everything jit+shard_map'd over `mesh`.  sync_plan
    is the BucketPlan the step syncs with (None = per-leaf path) — returned
    so callers introspect/log THE plan the step executes instead of
    re-deriving it.
    """
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    msizes = mesh_sizes_of(mesh)
    mesh_axes = tuple(mesh.axis_names)
    ctx = model_lib.make_ctx(cfg, run, msizes)
    key0 = jax.random.PRNGKey(base_seed)
    aparams, specs = abstract_specs(key0, cfg, ctx, msizes, run)
    baxes = batch_axes_for(cfg, run, shape, msizes)
    dp = 1
    for a in baxes:
        dp *= msizes[a]
    global_tokens = float(shape.global_batch * shape.seq_len)
    use_ef = run.compression.error_feedback
    # Bucketed sync (repro.train.bucketing): static plan over the abstract
    # grad tree; one collective per bucket instead of one per leaf.
    plan = grad_sync_plan(mesh, run, aparams, specs)
    # Overlapped issue schedule: pipeline the per-bucket collectives into
    # backward (eligibility: the shared overlap_enabled predicate).
    use_overlap = overlap_enabled(plan, run)
    if plan is not None:
        n_cmp = sum(1 for b in plan.buckets if b.kind == "compressed")
        log.info(
            "grad sync: %d buckets (%d compressed), schedule=%s, overlap=%s",
            len(plan.buckets), n_cmp, plan.schedule(),
            "backward-pipelined" if use_overlap else "post-backward")

    param_ps = {k: spec_to_pspec(v) for k, v in specs.items()}
    bspecs = batch_pspec(cfg, baxes)

    def _local_batch(batch, mb, n_mb):
        def slc(x):
            b_loc = x.shape[0] // n_mb
            return jax.lax.dynamic_slice_in_dim(x, mb * b_loc, b_loc, axis=0)
        return {k: slc(v) for k, v in batch.items()}

    def sharded_step(params, opt_state, ef_state, batch, step):
        key = jax.random.fold_in(key0, step)

        def loss_fn(p, mb_batch):
            loss, metrics = model_lib.train_loss(
                ctx, p, specs, cfg, run, mb_batch, global_tokens)
            return loss, metrics

        n_mb = run.microbatches
        if use_overlap:
            # Overlapped schedule: differentiate the loss of the *tagged*
            # params — grads come back already synced (each sync point's
            # backward rule ran its bucket's collective inside the grad
            # computation), and the grad w.r.t. the EF pytree IS the new
            # residual state (bucketing.overlap_params).
            def loss_tagged(p, ef, mb_batch):
                tagged = bucketing.overlap_params(
                    p, plan, run.compression, key, ef if use_ef else None)
                return loss_fn(tagged, mb_batch)

            (loss, metrics), (grads, new_ef) = jax.value_and_grad(
                loss_tagged, argnums=(0, 1), has_aux=True)(
                    params, ef_state if use_ef else {}, batch)
            if not use_ef:
                new_ef = None
        elif n_mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def mb_body(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, _local_batch(batch, mb, n_mb))
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb_body, (g0, jnp.zeros(())), jnp.arange(n_mb))
            metrics = {}

        if not use_overlap:
            if plan is not None:
                grads, new_ef = bucketing.sync_grads_bucketed(
                    grads, plan, run.compression, key,
                    ef_state if use_ef else None)
            else:
                grads, new_ef = sync_grads(
                    grads, specs, mesh_axes, run.compression, key, baxes,
                    ef_state if use_ef else None)
        if use_ef:
            ef_state = new_ef
        # sharding-aware grad norm: per leaf, psum the sum-of-squares over
        # axes that hold disjoint slices (those in its spec); other axes are
        # replicated after sync.
        gss = jnp.zeros((), jnp.float32)
        for name, g in sorted(grads.items()):
            ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
            ax = tuple(a for s in specs[name] if s is not None
                       for a in ((s,) if isinstance(s, str) else s))
            if ax:
                ss = jax.lax.psum(ss, tuple(dict.fromkeys(ax)))
            gss = gss + ss
        gnorm = jnp.sqrt(gss)
        params, opt_state = opt_lib.adamw_update(
            opt_cfg, grads, opt_state, params, grad_norm=gnorm)
        # loss: local token-loss sums are disjoint across batch axes and
        # (with sequence parallelism) the model axis; replicated elsewhere.
        sum_axes = tuple(dict.fromkeys(
            baxes + (("model",) if ctx.seq_shard else ())))
        loss_all = jax.lax.psum(loss, sum_axes) if sum_axes else loss
        mean_axes = tuple(a for a in mesh_axes if a not in sum_axes)
        if mean_axes:
            loss_all = jax.lax.pmean(loss_all, mean_axes)
        out_metrics = {"loss": loss_all, "grad_norm": gnorm,
                       "lr": opt_lib.lr_at(opt_cfg, opt_state.step - 1)}
        return params, opt_state, ef_state, out_metrics

    def sharded_init(key):
        params, _ = model_lib.init(key, cfg, ctx, msizes, run)
        opt_state = opt_lib.adamw_init(params)
        if use_ef and plan is not None:
            ef_state = bucketing.init_ef_state(plan, run.compression)
        elif use_ef:
            ef_state = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)
        else:
            ef_state = jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                    params)
        return params, opt_state, ef_state

    opt_ps = opt_lib.AdamWState(step=P(), m=param_ps, v=param_ps)
    if use_ef and plan is not None:
        # per-bucket residuals (codec-declared state shapes): per-device
        # state; replication is claimed (P()) but not checked, same as the
        # per-leaf EF specs below.
        ef_ps = {bid: P()
                 for bid in bucketing.ef_state_shapes(plan, run.compression)}
    elif use_ef:
        ef_ps = param_ps
    else:
        ef_ps = jax.tree.map(lambda _: P(), param_ps)
    metrics_ps = {"loss": P(), "grad_norm": P(), "lr": P()}

    step_fn = jax.jit(compat.shard_map(
        sharded_step, mesh=mesh,
        in_specs=(param_ps, opt_ps, ef_ps, bspecs, P()),
        out_specs=(param_ps, opt_ps, ef_ps, metrics_ps),
        check_vma=False))
    init_fn = jax.jit(compat.shard_map(
        sharded_init, mesh=mesh, in_specs=(P(),),
        out_specs=(param_ps, opt_ps, ef_ps), check_vma=False))
    return step_fn, init_fn, specs, bspecs, plan
