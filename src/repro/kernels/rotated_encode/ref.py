"""jnp oracles for the fused §7.2 rotate + 1-bit encode kernels.

Two oracles, one per kernel in repro.kernels.rotated_encode.kernel:

* :func:`rotate_minmax` — the Kronecker-matmul FWHT (H_{d1} ⊗ H_{d2} as
  two MXU matmuls) with the Rademacher signs and 1/√c scale folded in,
  plus per-chunk (min, max) partials.  NOTE this is deliberately the
  TPU formulation (kernels/hadamard/hadamard.py), NOT the CPU butterfly in
  kernels/hadamard/ref.py: the two differ in f32 rounding, and the fused
  kernel replaces the TPU path.  The CPU production path
  (rotation.rotate → bitplane.binary_pack) is untouched, so the golden
  wire bytes — generated on CPU — never see either kernel.

* :func:`binary_plane` — the §4.5 stochastic 1-bit plane for a rotated
  vector given the global (vmin, vmax): exactly encode_binary's branch
  draw (same Threefry stream via repro.kernels.threefry.ref, same
  guarded-delta threshold ops) packed into uint32 words by the
  kernels/bitplane reference layout.

Kernel↔oracle equivalence is exact (interpret mode, CPU), pinned by
tests/test_rotated_encode_kernel.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitplane import ref as bp_ref
from repro.kernels.threefry import ref as tref

_HIGHEST = jax.lax.Precision.HIGHEST


def hadamard_matrix(m: int):
    """H_m as f32 from iota + popcount parity — the same construction the
    kernels materialize in VMEM (m ≤ 1024 ⇒ 10 parity bits)."""
    i = jnp.arange(m, dtype=jnp.int32)
    v = i[:, None] & i[None, :]
    parity = jnp.zeros_like(v)
    for s in range(10):
        parity = parity ^ ((v >> s) & 1)
    return (1 - 2 * parity).astype(jnp.float32)


def rotate_minmax(x2, signs2, *, d1: int, d2: int, scale: float):
    """Per-chunk z = H(x·signs)/scale with (min, max) partials.

    x2, signs2: (B, d1·d2) — one row per block-diagonal MAX_D chunk.
    Returns (z2 (B, d1·d2) f32, mins (B,) f32, maxs (B,) f32).  Sequential
    lax.map over rows so each row runs the kernel's exact per-chunk dots.
    """
    h1 = hadamard_matrix(d1)
    h2 = hadamard_matrix(d2)

    def one(args):
        x, s = args
        xs = ((x * s).astype(jnp.float32)).reshape(d1, d2)
        t = jax.lax.dot(xs, h2, precision=_HIGHEST)
        y = jax.lax.dot(h1, t, precision=_HIGHEST)
        z = y / jnp.float32(scale)
        return z.reshape(-1), jnp.min(z), jnp.max(z)

    return jax.lax.map(one, (x2, signs2))


def binary_plane(z, key, vmin, vmax, dp: int):
    """(dp,) rotated z + global (vmin, vmax) -> packed 1-bit plane words.

    The op chain of encoders.encode_binary with the min/max already
    reduced: p = (z − vmin)/Δ (guarded for Δ = 0), one Threefry uniform
    draw per coordinate, take-max bits packed 32/word little-endian.
    """
    delta = vmax - vmin
    p = jnp.where(delta > 0,
                  (z - vmin) / jnp.where(delta > 0, delta, 1.0), 0.0)
    u = tref.uniform(key, dp)
    bits = u < p
    return bp_ref.pack_bits(bits.astype(jnp.uint32), 1)
