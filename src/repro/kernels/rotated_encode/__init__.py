from repro.kernels.rotated_encode import ops, ref  # noqa: F401
