"""Fused Pallas TPU kernels for RotatedCodec(inner=binary) packing.

The pre-fusion TPU path made four HBM round trips per bucket: the FWHT
kernel wrote z, XLA re-read z for min/max, re-read it again for the
stochastic threshold (with a separate d-wide uniform tensor), and the
bit-plane pack kernel re-read the dense bits.  The fused pair makes two:

* ``rotate_minmax_pallas`` — per MAX_D chunk, one kernel applies the
  Rademacher signs, runs the Kronecker-factorized FWHT (two MXU matmuls
  with the H factors generated in-kernel from iota parity — the
  kernels/hadamard hardware adaptation), folds in the 1/√c scale, and
  emits (min, max) partials alongside z — so the bracket scalars cost no
  extra pass;

* ``encode_pack_pallas`` — one kernel turns z into wire words: the
  take-max probabilities, the Threefry branch draw
  (repro.kernels.threefry.ref inlined, bit-exact with
  ``jax.random.uniform``), and the 1-bit plane packing all happen
  in-register per (256, 128) block, writing only the packed words.

Global (vmin, vmax) needs all chunks' partials, so the two kernels cannot
merge for multi-chunk buckets (dp > MAX_D, block-diagonal Q) — the partial
reduce between them is a (nchunks, 2) jnp min/max, order-free and exact.

Oracle contract: bit-identical to repro.kernels.rotated_encode.ref in
interpret mode (tests/test_rotated_encode_kernel.py).  The oracle uses the
same Kronecker formulation as the TPU hadamard kernel — NOT the CPU
butterfly — so CPU production bytes (golden) are out of scope by design;
see ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bernoulli_wire import kernel as bw_kernel
from repro.kernels.hadamard.hadamard import _hadamard_in_kernel

LANES = 128
PACK_ROWS = 256            # (256, 128) coords -> (256, 4) u32 words per step
_HIGHEST = jax.lax.Precision.HIGHEST


def _rotate_kernel(x_ref, s_ref, z_ref, mm_ref, *, d1: int, d2: int,
                   scale: float):
    xs = (x_ref[0] * s_ref[0]).astype(jnp.float32)
    h1 = _hadamard_in_kernel(d1, jnp.float32)
    h2 = _hadamard_in_kernel(d2, jnp.float32)
    t = jax.lax.dot(xs, h2, precision=_HIGHEST)
    y = jax.lax.dot(h1, t, precision=_HIGHEST)
    z = y / jnp.float32(scale)
    z_ref[0] = z
    mm_ref[...] = jnp.stack([jnp.min(z), jnp.max(z)]).reshape(1, 2)


@functools.partial(jax.jit,
                   static_argnames=("d1", "d2", "scale", "interpret"))
def rotate_minmax_pallas(x2, signs2, *, d1: int, d2: int, scale: float,
                         interpret: bool = False):
    """x2, signs2: (B, d1·d2) -> (z2 (B, d1·d2) f32, mm (B, 2) f32) with
    mm[i] = (min, max) of chunk i after signs, FWHT and 1/scale."""
    b, c = x2.shape
    assert c == d1 * d2, (c, d1, d2)
    x3 = x2.reshape(b, d1, d2)
    s3 = signs2.reshape(b, d1, d2)
    z3, mm = pl.pallas_call(
        functools.partial(_rotate_kernel, d1=d1, d2=d2, scale=scale),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, d1, d2), jnp.float32),
                   jax.ShapeDtypeStruct((b, 2), jnp.float32)],
        interpret=interpret,
    )(x3, s3)
    return z3.reshape(b, c), mm


def _encode_pack_kernel(key_ref, par_ref, z_ref, o_ref, *, dp: int):
    i = pl.program_id(0)
    idx, mask = bw_kernel._block_coords(i, dp, rows=PACK_ROWS)
    u = bw_kernel._uniform_block(key_ref[0], key_ref[1], idx, dp)
    vmin = par_ref[0]
    delta = par_ref[1] - vmin
    z = z_ref[...]
    # encode_binary's guarded threshold, elementwise — delta is traced on
    # both kernel and oracle sides, so the division rounds identically.
    p = jnp.where(delta > 0,
                  (z - vmin) / jnp.where(delta > 0, delta, 1.0), 0.0)
    bits = (mask & (u < p)).astype(jnp.uint32)
    v3 = bits.reshape(PACK_ROWS, LANES // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    o_ref[...] = jnp.sum(v3 << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("dp", "interpret"))
def encode_pack_pallas(z, key, vmin, vmax, *, dp: int,
                       interpret: bool = False):
    """z: (dp,) f32 rotated vector; key: (2,) uint32 (rank-folded);
    vmin/vmax: f32 scalars.  Returns the (ceil(dp/32),) uint32 plane."""
    rows = -(-dp // LANES)
    rows = -(-rows // PACK_ROWS) * PACK_ROWS
    z2 = jnp.pad(z.astype(jnp.float32),
                 (0, rows * LANES - dp)).reshape(rows, LANES)
    key = jnp.asarray(key).reshape(2).astype(jnp.uint32)
    params = jnp.stack([jnp.asarray(vmin, jnp.float32),
                        jnp.asarray(vmax, jnp.float32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rows // PACK_ROWS,),
        in_specs=[pl.BlockSpec((PACK_ROWS, LANES), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((PACK_ROWS, LANES // 32),
                               lambda i, *_: (i, 0)),
        scratch_shapes=[],
    )
    words = pl.pallas_call(
        functools.partial(_encode_pack_kernel, dp=dp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES // 32), jnp.uint32),
        interpret=interpret,
    )(key, params, z2)
    return words.reshape(-1)[:-(-dp // 32)]
