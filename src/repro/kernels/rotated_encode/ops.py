"""Dispatch for the fused rotate+encode path of RotatedCodec(binary).

Off-TPU this is EXACTLY the historical two-stage chain
(rotation.rotate → bitplane.binary_pack) — same butterfly FWHT, same
encoder draws, same bytes (golden matrix).  On TPU (or when forced) the
two fused Pallas kernels in repro.kernels.rotated_encode.kernel replace
it, with the chunk partials reduced between them.  Backend policy:
repro.kernels.backend (module-level, never trace-time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane, rotation
from repro.kernels import backend
from repro.kernels.hadamard import ops as hops
from repro.kernels.rotated_encode import kernel


def pack_binary(flat, key, rank, wire_dtype, *, force_pallas: bool = False):
    """RotatedCodec(inner=binary).pack: (d,) f32 -> uint32 wire buffer
    [1-bit plane of dp = padded_dim(d) coords ‖ (vmin, vmax)]."""
    use_pallas, interpret = backend.choose(force_pallas)
    krot = rotation.rotation_key(key)
    kenc = jax.random.fold_in(key, rank)
    d = flat.shape[0]
    dp = rotation.padded_dim(d)
    if not use_pallas or dp < 256:
        # dp < 256: degenerate MXU tiles — not a kernel target (real
        # buckets sit far above min_compress_size anyway).
        z = rotation.rotate(krot, flat)
        return bitplane.binary_pack(z, kenc, wire_dtype)
    c = min(dp, hops.MAX_D)
    d1, d2 = hops._factorize(c)
    scale = float(np.sqrt(np.float32(c)))
    signs = rotation.rademacher_diag(krot, dp, jnp.float32)
    xp = jnp.pad(flat.astype(jnp.float32), (0, dp - d))
    z2, mm = kernel.rotate_minmax_pallas(
        xp.reshape(-1, c), signs.reshape(-1, c),
        d1=d1, d2=d2, scale=scale, interpret=interpret)
    vmin = jnp.min(mm[:, 0])
    vmax = jnp.max(mm[:, 1])
    plane = kernel.encode_pack_pallas(z2.reshape(-1), kenc, vmin, vmax,
                                      dp=dp, interpret=interpret)
    tail = bitplane.floats_to_words(jnp.stack([vmin, vmax]), wire_dtype)
    return jnp.concatenate([plane, tail])
