"""Module-level backend selection for the Pallas kernel layer.

Historically each kernels/*/ops.py asked ``jax.default_backend()`` *inside*
its dispatch functions.  Under ``jax.jit`` that query runs at trace time, so
whichever backend happened to be active when a caller first traced got baked
into the cached executable — a CPU-traced function shipped the slow lowered
interpret path to TPU callers and vice versa.  This module evaluates the
backend ONCE at import, before any tracing, and every kernel dispatcher
reads the resulting constants.

Explicit override, for tests and debugging, via ``REPRO_KERNEL_BACKEND``:

* ``auto``              — Pallas on TPU, jnp reference elsewhere (default);
* ``ref``               — always the jnp oracle;
* ``pallas``            — always the compiled Pallas kernel;
* ``pallas_interpret``  — always the Pallas kernel in interpret mode (how
  CI exercises kernel bodies on CPU; see the kernel-interpret tier-1 job).

Dispatchers also accept ``force_pallas=True`` per call, which upgrades
``auto``/``ref`` to the Pallas path (interpret mode off-TPU) without
touching the environment — the hook the oracle-equivalence tests use.
"""
from __future__ import annotations

import os

import jax

ON_TPU = jax.default_backend() == "tpu"

_VALID = ("auto", "ref", "pallas", "pallas_interpret")
MODE = os.environ.get("REPRO_KERNEL_BACKEND", "auto").lower()
if MODE not in _VALID:  # fail loudly: a typo silently falling back to
    raise ValueError(   # "auto" would make the CI interpret job vacuous.
        f"REPRO_KERNEL_BACKEND={MODE!r} not in {_VALID}")


def choose(force_pallas: bool = False):
    """Resolve to ``(use_pallas, interpret)`` for one dispatch site."""
    mode = MODE
    if force_pallas and mode in ("auto", "ref"):
        mode = "pallas" if ON_TPU else "pallas_interpret"
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, False
    if mode == "pallas_interpret":
        return True, True
    return (True, False) if ON_TPU else (False, False)
