"""Pallas TPU flash-attention (forward) kernel.

Why it exists here: the dry-run roofline shows every *_4k/32k attention
cell is MEMORY-bound, dominated by materialized (chunk_q × chunk_k) score
tensors — the XLA online-softmax path streams O(S²) bytes through HBM.
This kernel keeps scores/probabilities in VMEM: HBM traffic collapses to
q + k + v + o (O(S·d)), which is the §Perf headline for the qwen3 cell.

Schedule: grid = (B·Hq, nQ, nK) with the KV dimension innermost (TPU grids
execute sequentially over the trailing dim, so the (m, l, acc) online-
softmax state lives in VMEM scratch across the nK steps).  Causal/SWA
blocks that are fully masked are skipped with pl.when — no MXU work and no
HBM reads for the skipped K/V blocks beyond the pipelined prefetch.

GQA: the kv-head index map folds q-head → kv-head (h // group).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(bq, bk, q_start, k_start, causal, window):
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _block_live(q_start, k_start, bq, bk, causal, window):
    run = True
    if causal:
        run = jnp.logical_and(True, q_start + bq - 1 >= k_start)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)
    return run


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: Optional[int], bq: int, bk: int,
            nk: int, scale: float, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * bq
    k_start = ki * bk
    run = _block_live(q_start, k_start, bq, bk, causal, window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(bq, bk, q_start, k_start, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, q_offset: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Sk, hd).  Returns (B, Hq, Sq, hd).

    Sq % block_q == 0, Sk % block_k == 0; hd a multiple of 128 preferred.
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    bh = b * hq

    q4 = q.reshape(bh, sq, hd)
    # kv indexed by (bh → b, kv head): fold b and h into one grid dim
    k4 = k.reshape(b * hkv, sk, hd)
    v4 = v.reshape(b * hkv, sk, hd)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        return ((h // hq) * hkv + (h % hq) // g, j, 0)

    out, lse = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, bq=bq,
                          bk=bk, nk=nk, scale=hd ** -0.5, q_offset=q_offset),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k4, v4)
    return out.reshape(b, hq, sq, hd), lse.reshape(b, hq, sq)


# --------------------------------------------------------------------------- #
# Backward kernels (FlashAttention-2 style, two sweeps).
#
# Residuals: q, k, v, o, lse.  delta = rowsum(do ⊙ o) per q position.
#   p  = exp(q·kᵀ·scale − lse)
#   dv = pᵀ·do          dp = do·vᵀ          ds = p ⊙ (dp − delta)
#   dk = dsᵀ·q·scale    (sweep 1: grid over kv blocks, scan q-blocks×group)
#   dq = ds·k·scale     (sweep 2: grid over q blocks, scan kv blocks)
# All block masks/skips derive from program ids + static block sizes, as in
# the forward kernel.  GQA: sweep 1 folds the g q-heads of a kv head into
# the innermost (sequential) grid dim, accumulating dk/dv in VMEM scratch.
# --------------------------------------------------------------------------- #


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *,
                    causal, window, bq, bk, nq, nqg, scale, q_offset):
    ki = pl.program_id(1)
    jq = pl.program_id(2)           # jq = g_idx * nq + q_block
    qi = jq % nq

    @pl.when(jq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = q_offset + qi * bq
    k_start = ki * bk
    run = _block_live(q_start, k_start, bq, bk, causal, window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]                        # (bq,)
        delta = delta_ref[0]                    # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(bq, bk, q_start, k_start, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])           # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(jq == nqg - 1)
    def _fin():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *,
                   causal, window, bq, bk, nk, scale, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = q_offset + qi * bq
    k_start = ki * bk
    run = _block_live(q_start, k_start, bq, bk, causal, window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(bq, bk, q_start, k_start, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention_bwd(q, k, v, do, lse, delta, *, causal=True, window=None,
                        q_offset=0, block_q=512, block_k=512,
                        interpret=False):
    """Backward pass.  Layouts as flash_attention_fwd; lse/delta (B,Hq,Sq).

    Returns (dq (B,Hq,Sq,hd), dk, dv (B,Hkv,Sk,hd) in f32).
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq, nk = sq // bq, sk // bk

    q4 = q.reshape(b * hq, sq, hd)
    do4 = do.reshape(b * hq, sq, hd)
    lse2 = lse.reshape(b * hq, sq)
    delta2 = delta.reshape(b * hq, sq)
    k4 = k.reshape(b * hkv, sk, hd)
    v4 = v.reshape(b * hkv, sk, hd)

    # ---- sweep 1: dk, dv — grid (b·hkv, nk, g·nq)
    def qh_map(c, ki, jq):
        # q-head row for (batch, kv-head) = c and group index jq // nq
        return ((c // hkv) * hq + (c % hkv) * g + jq // nq, jq % nq, 0)

    def qh_vec_map(c, ki, jq):
        return ((c // hkv) * hq + (c % hkv) * g + jq // nq, jq % nq)

    def kv_map1(c, ki, jq):
        return (c, ki, 0)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, nq=nq, nqg=g * nq, scale=hd ** -0.5,
                          q_offset=q_offset),
        grid=(b * hkv, nk, g * nq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), qh_map),     # q
            pl.BlockSpec((1, bk, hd), kv_map1),    # k
            pl.BlockSpec((1, bk, hd), kv_map1),    # v
            pl.BlockSpec((1, bq, hd), qh_map),     # do
            pl.BlockSpec((1, bq), qh_vec_map),     # lse
            pl.BlockSpec((1, bq), qh_vec_map),     # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, hd), kv_map1),
            pl.BlockSpec((1, bk, hd), kv_map1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, sk, hd), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, sk, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, hd), jnp.float32),
            pltpu.VMEM((bk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k4, v4, do4, lse2, delta2)

    # ---- sweep 2: dq — grid (b·hq, nq, nk)
    def q_map(h, i, j):
        return (h, i, 0)

    def q_vec_map(h, i, j):
        return (h, i)

    def kv_map2(h, i, j):
        return ((h // hq) * hkv + (h % hq) // g, j, 0)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, scale=hd ** -0.5,
                          q_offset=q_offset),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map2),
            pl.BlockSpec((1, bk, hd), kv_map2),
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bq), q_vec_map),
            pl.BlockSpec((1, bq), q_vec_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q4, k4, v4, do4, lse2, delta2)

    return (dq.reshape(b, hq, sq, hd), dk.reshape(b, hkv, sk, hd),
            dv.reshape(b, hkv, sk, hd))
