"""Pure-jnp oracle for flash attention: full-softmax GQA attention with
causal / sliding-window masks."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, q_offset: int = 0):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd), Hq % Hkv == 0.

    Returns (B, Sq, Hq, hd).  f32 softmax, output in q.dtype.
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kf) * hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, sq, hq, hd).astype(q.dtype)
