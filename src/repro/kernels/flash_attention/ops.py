"""jit'd wrapper for flash attention (forward + custom-VJP training path).

TPU → the Pallas kernels; CPU → the model's XLA online-softmax path (the
same math, bounded memory) via repro.models.attention.chunked_attention.
Accepts the model's (B, S, H, hd) layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _kernel


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_train(q, k, v, causal, window, q_offset, block_q, block_k,
                 interpret):
    out, _ = _kernel.flash_attention_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_k,
               interpret):
    out, lse = _kernel.flash_attention_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, block_q, block_k, interpret,
               res, do):
    q, k, v, out, lse = res
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                       # (B, Hq, Sq)
    dq, dk, dv = _kernel.flash_attention_bwd(
        q, k, v, do, lse, delta, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_train.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    force_pallas: bool = False, interpret: bool | None = None):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) -> (B, Sq, Hq, hd).

    Differentiable: the backward pass runs the FA2-style Pallas kernels
    (scores recomputed blockwise in VMEM; residuals are only o and lse).
    """
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset,
                                 chunk_q=block_q, chunk_k=block_k)
    if interpret is None:
        interpret = not on_tpu
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_train(qt, kt, vt, causal, window, q_offset,
                       block_q, block_k, interpret)
    return out.transpose(0, 2, 1, 3)
