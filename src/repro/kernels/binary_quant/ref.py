"""Pure-jnp oracle for stochastic binary quantization (Example 4 / [10]).

encode: x -> (packed uint8 bits, vmin, vmax); decode: reconstruct Y where
Y(j) = vmax with probability (x(j)−vmin)/Δ else vmin — using the shared
hash PRNG so kernel and oracle are bit-identical.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import prng


def _bits(x, vmin, vmax, seed):
    flat = x.reshape(-1).astype(jnp.float32)
    delta = (vmax - vmin).astype(jnp.float32)
    dsafe = jnp.where(delta > 0, delta, 1.0)
    p = jnp.where(delta > 0, (flat - vmin) / dsafe, 0.0)
    idx = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    u = prng.uniform_hash(jnp.uint32(seed), idx)
    return (u < p).astype(jnp.uint8)


def binary_encode(x, seed):
    """x: (..., d) with d % 8 == 0 after flattening -> (n//8 uint8, vmin, vmax)."""
    vmin = jnp.min(x).astype(jnp.float32)
    vmax = jnp.max(x).astype(jnp.float32)
    bits = _bits(x, vmin, vmax, seed)
    n = bits.shape[0]
    assert n % 8 == 0, n
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    packed = jnp.sum(bits.reshape(-1, 8) * weights, axis=-1).astype(jnp.uint8)
    return packed, vmin, vmax


def binary_decode(packed, vmin, vmax, shape, dtype=jnp.float32):
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[:, None] >> shifts) & jnp.uint8(1)
    y = jnp.where(bits.reshape(-1) > 0, vmax, vmin).astype(dtype)
    return y.reshape(shape)
