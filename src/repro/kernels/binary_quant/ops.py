"""jit'd wrapper for binary quantization: encode -> (packed, vmin, vmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.binary_quant import binary_quant as _kernel
from repro.kernels.binary_quant import ref as _ref

_TILE = _kernel.BM * _kernel.LANES


def binary_encode(x, seed, *, force_pallas: bool = False):
    """Stochastic 1-bit quantization of any-shape x.

    Returns (packed uint8 of ceil(n/8) (padded) bytes, vmin, vmax).  Use
    :func:`binary_decode` with the original shape to reconstruct.
    """
    on_tpu = jax.default_backend() == "tpu"
    vmin = jnp.min(x).astype(jnp.float32)
    vmax = jnp.max(x).astype(jnp.float32)
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = (-n) % _TILE
    flat = jnp.pad(flat, (0, npad), constant_values=vmin)
    if not (on_tpu or force_pallas):
        packed, _, _ = _ref.binary_encode(flat, seed)
        return packed, vmin, vmax
    seed_u = jnp.asarray(seed, jnp.uint32)
    scal = jnp.stack([
        vmin, vmax,
        (seed_u >> jnp.uint32(16)).astype(jnp.float32),
        (seed_u & jnp.uint32(0xFFFF)).astype(jnp.float32),
    ]).reshape(1, 4)
    packed = _kernel.binary_encode_2d(flat.reshape(-1, _kernel.LANES), scal,
                                      interpret=not on_tpu)
    return packed.reshape(-1), vmin, vmax


def binary_decode(packed, vmin, vmax, shape, dtype=jnp.float32):
    """Inverse of binary_encode (dense Y_i of Example 4)."""
    n = 1
    for s in shape:
        n *= s
    y = _ref.binary_decode(packed.reshape(-1), vmin, vmax, (packed.size * 8,),
                           dtype)
    return y[:n].reshape(shape)
