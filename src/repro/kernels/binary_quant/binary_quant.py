"""Pallas TPU kernel: fused stochastic binary quantization + bit-packing.

Example 4 / Suresh et al. [10]: Y(j) ∈ {vmin, vmax}, P(vmax) = (x−vmin)/Δ.
The kernel fuses PRNG, threshold and 8:1 bit-packing so HBM traffic is
read d·4 bytes, write d/8 bytes — the packed buffer is what travels on the
wire (the §4.5 binary protocol's "1 bit per element" made literal on TPU).

vmin/vmax are computed by the caller (a cheap fused reduction) and passed
as scalars; the kernel is the memory-bound sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng

LANES = 128
BM = 512  # (512, 128) block -> packs to (512, 16) uint8.


def _kernel(x_ref, scal_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (BM, LANES)
    vmin = scal_ref[0, 0]
    vmax = scal_ref[0, 1]
    seed = (scal_ref[0, 2].astype(jnp.uint32) * jnp.uint32(65536)
            + scal_ref[0, 3].astype(jnp.uint32))
    bm, bn = x.shape
    delta = vmax - vmin
    dsafe = jnp.where(delta > 0, delta, 1.0)
    p = jnp.where(delta > 0, (x - vmin) / dsafe, 0.0)
    row = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1)
    base = (jnp.uint32(i) * jnp.uint32(bm)) * jnp.uint32(bn)
    idx = base + row * jnp.uint32(bn) + col
    u = prng.uniform_hash(seed, idx)
    bits = (u < p).astype(jnp.int32)
    # pack 8 lanes -> 1 byte; within-row packing keeps the layout lane-local.
    b3 = bits.reshape(bm, bn // 8, 8)
    weights = (1 << jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8), 2))
    packed = jnp.sum(b3 * weights, axis=-1)
    o_ref[...] = packed.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def binary_encode_2d(x, scal, *, interpret: bool = False):
    """x: (R, 128), R % BM == 0; scal: (1,4) [vmin, vmax, seed_hi, seed_lo]."""
    r, c = x.shape
    assert c == LANES and r % BM == 0, (r, c)
    return pl.pallas_call(
        _kernel,
        grid=(r // BM,),
        in_specs=[
            pl.BlockSpec((BM, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, LANES // 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c // 8), jnp.uint8),
        interpret=interpret,
    )(x, scal)
