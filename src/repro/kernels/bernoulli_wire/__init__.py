from repro.kernels.bernoulli_wire import ops, ref  # noqa: F401
