"""Dispatch for the fused Bernoulli wire kernels.

Backend policy lives in :mod:`repro.kernels.backend` (resolved once at
import, never inside a trace): TPU → fused Pallas kernels
(repro.kernels.bernoulli_wire.kernel), everything else → the fast jnp
reference (repro.kernels.bernoulli_wire.ref), which is byte-identical on
the wire to the historical codec op chain (golden matrix).  Tests force the
Pallas path off-TPU with ``force_pallas=True`` (interpret mode) or
``REPRO_KERNEL_BACKEND=pallas_interpret``.

``p``, ``cap`` and ``d`` are static Python values (they come from the
compression config), so these helpers are safe to call under an outer
``jax.jit``.
"""
from __future__ import annotations

from repro.kernels import backend
from repro.kernels.bernoulli_wire import kernel, ref


def encode(flat, key, p: float, cap: int, mu, *, scaled: bool = True,
           force_pallas: bool = False):
    """(d,) f32 + rank-folded (2,) key -> (cap,) f32 wire value buffer."""
    use_pallas, interpret = backend.choose(force_pallas)
    if use_pallas:
        return kernel.encode_pallas(flat, key, mu, p=p, cap=cap,
                                    scaled=scaled, interpret=interpret)
    return ref.encode(flat, key, p, cap, mu, scaled=scaled)


def decode_sum(bufs, mus, keys, p: float, cap: int, d: int, *,
               force_pallas: bool = False):
    """(n, cap) buffers + (n,) μ + (n, 2) keys -> Σ_i recon_i as (d,) f32.

    Caller divides by n for the mean.  The jnp path regenerates all peer
    supports in one batched Threefry dispatch; the Pallas path folds peers
    into the accumulator without dense per-peer intermediates.
    """
    use_pallas, interpret = backend.choose(force_pallas)
    if use_pallas:
        return kernel.decode_sum_pallas(bufs, mus, keys, p=p, cap=cap,
                                        d=d, interpret=interpret)
    return ref.decode_sum(bufs, mus, keys, p, cap, d)


def support_shard(keys, p: float, d: int, start, ds: int):
    """(n, ds) slice [start, start+ds) of every peer's support draw.

    The reduce-scatter decode's per-shard support regeneration (scattered
    Threefry lanes only, repro.kernels.threefry.ref.uniform_at).  jnp on
    every backend: the codec needs the per-shard counts BEFORE the decode
    (the rank-offset all_gather), so this stays a separate cheap dispatch;
    the shard decode kernel re-draws the same lanes in-kernel.
    """
    return ref.support_shard(keys, p, d, start, ds)


def decode_sum_shard(bufs, mus, keys, sent, prior, start, *, p: float,
                     cap: int, d: int, force_pallas: bool = False):
    """Shard-restricted Σ_i reconstruction_i as (ds,) f32.

    ``sent`` is the (n, ds) support slice from :func:`support_shard` (the
    caller already drew it for the rank-offset counts); ``prior`` the (n,)
    support counts strictly before the shard; ``start`` the (possibly
    traced) global shard offset.  The jnp path selects+accumulates against
    the precomputed ``sent``; the Pallas path runs the fused shard-view
    kernel, regenerating the identical supports in-kernel from ``keys``
    (bit-exact — same Threefry lanes).
    """
    use_pallas, interpret = backend.choose(force_pallas)
    if use_pallas:
        return kernel.decode_sum_shard_pallas(
            bufs, mus, keys, prior, start, p=p, cap=cap, d=d,
            ds=sent.shape[1], interpret=interpret)
    return ref.decode_sum_shard(bufs, mus, sent, prior, cap)
