"""Fused Pallas TPU kernels for the §4.4 seed-trick Bernoulli wire.

Encode (``encode_pallas``) fuses sample → select → rank-compact in ONE pass
over ``flat``: the Threefry support draw runs in-register
(repro.kernels.threefry.ref inlined into the kernel body), the support rank
comes from a running SMEM carry plus an in-block flat-order cumsum, and
kept values land directly in the (cap,) wire buffer — no d-wide uniform
tensor, no d-wide cumsum, no d-wide ``.at[].set`` scatter in HBM.

Decode (``decode_sum_pallas``) fuses regenerate → unpack → accumulate for
all n peer buffers: grid (n, nblocks) with peers on the slow axis, so each
peer's (cap,) buffer is fetched once and folded straight into the shared
(d,) f32 accumulator — per-peer dense reconstructions are never
materialized (the old path built n full (d,) vectors in HBM).

Hardware mapping notes (see /opt/skills/guides/pallas_guide.md):

* grids are sequential on TPU, which is what makes the SMEM rank carry and
  the read-modify-write accumulator correct;
* flat-order cumsum inside a (BM_ROWS, 128) block is two triangular-matrix
  matmuls (lane-inclusive within rows + row-exclusive prefix) — MXU work
  instead of a serial scan, exact in f32 below 2²⁴;
* rank-compaction is a one-hot matmul into a 128-aligned window of the
  output: kept ranks of one block provably span < BM + 128 slots starting
  at ``min(carry, cap)`` rounded down to a lane multiple, so a
  (WIN_ROWS, 128) dynamic-sliced RMW covers them.  One-hot matmuls touch
  each slot through exactly one nonzero product, so the result is
  bit-identical to the gather/scatter formulation in ref.py.

Bit-identity: both kernels reproduce the jnp oracles in
repro.kernels.bernoulli_wire.ref exactly — the Threefry stream is
bit-exact, supports/ranks are integer-exact, and one-hot matmuls and the
peer-major accumulate match the oracle op-for-op — with ONE carve-out: the
Eq. (1) affine rescale ``x/p − (1−p)/p·μ``.  XLA reserves the right to
contract that multiply-subtract into an FMA depending on surrounding
fusion, so for general p the kernel and oracle may disagree by 1 ulp on
*values* (never on which slots are filled).  When 1/p is a power of two —
every shipped preset uses fraction 1/16 — ``x·(1/p)`` is exact and the
contraction freedom collapses: kernel and oracle are equal bit-for-bit.
Pinned by tests/test_bernoulli_wire_kernels.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.threefry import ref as tref

LANES = 128
BM_ROWS = 8                  # sublane rows per grid step
BM = BM_ROWS * LANES         # 1024 coordinates per step
WIN_ROWS = BM_ROWS + 1       # rank window: BM slots + 128 for alignment slack
WIN = WIN_ROWS * LANES

_HIGHEST = jax.lax.Precision.HIGHEST


def num_coord_rows(d: int) -> int:
    """Sublane rows needed to hold d coordinates, padded to full blocks."""
    return -(-d // BM) * BM_ROWS


def num_buffer_rows(cap: int) -> int:
    """Sublane rows of a wire buffer padded so any RMW window fits."""
    return -(-cap // LANES) + WIN_ROWS


def _block_coords(step, d: int, rows: int = BM_ROWS):
    """Global flat coordinate of each (row, lane) slot + validity mask.

    ``rows`` lets other wire kernels (repro.kernels.rotated_encode) reuse
    the same row-major coordinate layout at their own block height.
    """
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, LANES), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (rows, LANES), 1)
    idx = (step * rows + r) * LANES + c
    return idx, idx < d


def _uniform_block(k0, k1, idx, d: int):
    """Threefry U[0,1) draw for scattered coordinates ``idx`` of a (d,)
    stream — bit-exact lanes of ``jax.random.uniform(key, (d,))``."""
    pair, c1, lo = tref.counter_words(idx.astype(jnp.uint32), d)
    o0, o1 = tref.threefry2x32(k0, k1, pair, c1)
    return tref.bits_to_uniform(jnp.where(lo, o0, o1))


def _flat_cumsum(sent):
    """Inclusive cumsum of a (BM_ROWS, LANES) bool block in flat row-major
    order, as int32.  Two triangular matmuls; block sums ≤ BM ⇒ exact."""
    s = sent.astype(jnp.float32)
    lane_le = (jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 0)
               <= jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 1)
               ).astype(jnp.float32)
    within = jax.lax.dot(s, lane_le, precision=_HIGHEST)
    row_lt = (jax.lax.broadcasted_iota(jnp.int32, (BM_ROWS, BM_ROWS), 1)
              < jax.lax.broadcasted_iota(jnp.int32, (BM_ROWS, BM_ROWS), 0)
              ).astype(jnp.float32)
    prefix = jax.lax.dot(row_lt, within[:, LANES - 1:LANES],
                         precision=_HIGHEST)
    return (within + prefix).astype(jnp.int32)


def _rank_window(carry, incl, sent, cap: int):
    """Shared rank bookkeeping: global ranks, keep mask, window row start
    and in-window slot index for this block's coordinates."""
    rank = carry + incl - 1
    keep = sent & (rank < cap)
    row_start = jnp.minimum(carry, cap) // LANES
    local = jnp.clip(rank - row_start * LANES, 0, WIN - 1)
    return keep, row_start, local


def _onehot(local, mask):
    """(BM, WIN) f32 selector: row k has a single 1 at column local[k]
    when mask[k], else all zeros."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (BM, WIN), 1)
    return ((local.reshape(BM, 1) == cols)
            & mask.reshape(BM, 1)).astype(jnp.float32)


def _encode_kernel(key_ref, par_ref, x_ref, o_ref, carry_ref, *,
                   d: int, cap: int, scaled: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0
        o_ref[...] = jnp.zeros_like(o_ref)

    idx, mask = _block_coords(i, d)
    p = par_ref[0]
    sent = mask & (_uniform_block(key_ref[0], key_ref[1], idx, d) < p)

    x = x_ref[...]
    # Bit-matches ref.encode's ``x / p − (1−p)/p · μ`` with p a Python
    # constant: XLA folds the division into multiply-by-f32-reciprocal and
    # binds the weak Python coefficient at f32, so the kernel multiplies by
    # the same host-rounded scalars (par_ref[3] = 1/p, par_ref[2] = (1−p)/p).
    vals = x * par_ref[3] - par_ref[2] * par_ref[1] if scaled else x

    carry = carry_ref[0]
    incl = _flat_cumsum(sent)
    keep, row_start, local = _rank_window(carry, incl, sent, cap)

    contrib = jax.lax.dot(vals.reshape(1, BM), _onehot(local, keep),
                          precision=_HIGHEST)
    win = o_ref[pl.ds(row_start, WIN_ROWS), :]
    o_ref[pl.ds(row_start, WIN_ROWS), :] = (
        win + contrib.reshape(WIN_ROWS, LANES))
    carry_ref[0] = carry + incl[BM_ROWS - 1, LANES - 1]


@functools.partial(jax.jit,
                   static_argnames=("p", "cap", "scaled", "interpret"))
def encode_pallas(flat, key, mu, *, p: float, cap: int,
                  scaled: bool = True, interpret: bool = False):
    """flat: (d,) f32; key: (2,) uint32 (rank-folded); mu: f32 scalar.
    Returns the (cap,) f32 wire value buffer of ref.encode."""
    d = flat.shape[0]
    rows_d = num_coord_rows(d)
    rows_cap = num_buffer_rows(cap)
    x2 = jnp.pad(flat.astype(jnp.float32),
                 (0, rows_d * LANES - d)).reshape(rows_d, LANES)
    key = jnp.asarray(key).reshape(2).astype(jnp.uint32)
    params = jnp.stack([
        jnp.float32(p),
        jnp.asarray(mu, jnp.float32),
        jnp.float32((1.0 - p) / p),
        jnp.asarray(np.float32(1.0) / np.float32(p)),
    ])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rows_d // BM_ROWS,),
        in_specs=[pl.BlockSpec((BM_ROWS, LANES), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((rows_cap, LANES), lambda i, *_: (0, 0)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_encode_kernel, d=d, cap=cap, scaled=scaled),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_cap, LANES), jnp.float32),
        interpret=interpret,
    )(key, params, x2)
    return out.reshape(-1)[:cap]


def _decode_kernel(keys_ref, mus_ref, par_ref, buf_ref, o_ref, carry_ref, *,
                   d: int, cap: int):
    i = pl.program_id(0)   # peer (slow axis: buffer stays resident)
    j = pl.program_id(1)   # coordinate block

    @pl.when(j == 0)
    def _reset():
        carry_ref[0] = 0

    idx, mask = _block_coords(j, d)
    p = par_ref[0]
    sent = mask & (_uniform_block(keys_ref[i, 0], keys_ref[i, 1], idx, d)
                   < p)

    carry = carry_ref[0]
    incl = _flat_cumsum(sent)
    valid, row_start, local = _rank_window(carry, incl, sent, cap)

    window = buf_ref[0, pl.ds(row_start, WIN_ROWS), :].reshape(WIN, 1)
    vals = jax.lax.dot(_onehot(local, valid), window,
                       precision=_HIGHEST).reshape(BM_ROWS, LANES)
    mu = mus_ref[i]
    recon = jnp.where(mask, jnp.where(valid, vals, mu), 0.0)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += recon
    carry_ref[0] = carry + incl[BM_ROWS - 1, LANES - 1]


@functools.partial(jax.jit, static_argnames=("p", "cap", "d", "interpret"))
def decode_sum_pallas(bufs, mus, keys, *, p: float, cap: int, d: int,
                      interpret: bool = False):
    """bufs: (n, cap) f32; mus: (n,) f32; keys: (n, 2) uint32.
    Returns Σ_i reconstruction_i as (d,) f32 — the peer-major accumulation
    of ref.decode_sum_sequential; caller divides by n."""
    n = bufs.shape[0]
    rows_d = num_coord_rows(d)
    rows_cap = num_buffer_rows(cap)
    bufs3 = jnp.pad(bufs.astype(jnp.float32),
                    ((0, 0), (0, rows_cap * LANES - cap))
                    ).reshape(n, rows_cap, LANES)
    keys = jnp.asarray(keys).reshape(n, 2).astype(jnp.uint32)
    mus = jnp.asarray(mus, jnp.float32)
    params = jnp.stack([jnp.float32(p)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n, rows_d // BM_ROWS),
        in_specs=[pl.BlockSpec((1, rows_cap, LANES),
                               lambda i, j, *_: (i, 0, 0))],
        out_specs=pl.BlockSpec((BM_ROWS, LANES), lambda i, j, *_: (j, 0)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, d=d, cap=cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_d, LANES), jnp.float32),
        interpret=interpret,
    )(keys, mus, params, bufs3)
    return out.reshape(-1)[:d]


def _decode_shard_kernel(keys_ref, mus_ref, par_ref, prior_ref, off_ref,
                         buf_ref, o_ref, carry_ref, *,
                         d: int, cap: int, ds: int):
    """Shard view of :func:`_decode_kernel`: decode coordinates
    [off, off+ds) of every peer's (d,) stream.

    Identical rank bookkeeping — the SMEM carry just starts at the peer's
    ``prior`` count (supports strictly before the shard, all_gathered by
    the caller) instead of 0, and the Threefry lanes draw at the global
    coordinate ``off + local``.  Shard-window lanes past d decode to μ
    (matching ref.decode_sum_shard; the caller truncates), block-padding
    lanes past ds contribute 0.
    """
    i = pl.program_id(0)   # peer (slow axis: buffer stays resident)
    j = pl.program_id(1)   # coordinate block within the shard

    @pl.when(j == 0)
    def _reset():
        carry_ref[0] = prior_ref[i]

    lidx, inblock = _block_coords(j, ds)
    gidx = off_ref[0] + lidx
    real = inblock & (gidx < d)
    p = par_ref[0]
    u = _uniform_block(keys_ref[i, 0], keys_ref[i, 1],
                       jnp.where(real, gidx, 0), d)
    sent = real & (u < p)

    carry = carry_ref[0]
    incl = _flat_cumsum(sent)
    valid, row_start, local = _rank_window(carry, incl, sent, cap)

    window = buf_ref[0, pl.ds(row_start, WIN_ROWS), :].reshape(WIN, 1)
    vals = jax.lax.dot(_onehot(local, valid), window,
                       precision=_HIGHEST).reshape(BM_ROWS, LANES)
    mu = mus_ref[i]
    recon = jnp.where(inblock, jnp.where(valid, vals, mu), 0.0)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += recon
    carry_ref[0] = carry + incl[BM_ROWS - 1, LANES - 1]


@functools.partial(jax.jit,
                   static_argnames=("p", "cap", "d", "ds", "interpret"))
def decode_sum_shard_pallas(bufs, mus, keys, prior, start, *, p: float,
                            cap: int, d: int, ds: int,
                            interpret: bool = False):
    """bufs: (n, cap) f32; mus: (n,) f32; keys: (n, 2) uint32; prior: (n,)
    int32 support counts strictly before the shard; start: int32 global
    offset (may be traced — the shard index inside shard_map).

    Returns the [start, start+ds) slice of Σ_i reconstruction_i as (ds,)
    f32, regenerating the shard supports in-kernel (fused regenerate +
    select + accumulate) — bit-exact vs ref.support_shard +
    ref.decode_sum_shard.  Caller divides by n.
    """
    n = bufs.shape[0]
    rows_ds = num_coord_rows(ds)
    rows_cap = num_buffer_rows(cap)
    bufs3 = jnp.pad(bufs.astype(jnp.float32),
                    ((0, 0), (0, rows_cap * LANES - cap))
                    ).reshape(n, rows_cap, LANES)
    keys = jnp.asarray(keys).reshape(n, 2).astype(jnp.uint32)
    mus = jnp.asarray(mus, jnp.float32)
    params = jnp.stack([jnp.float32(p)])
    prior = jnp.asarray(prior, jnp.int32).reshape(n)
    off = jnp.asarray(start, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n, rows_ds // BM_ROWS),
        in_specs=[pl.BlockSpec((1, rows_cap, LANES),
                               lambda i, j, *_: (i, 0, 0))],
        out_specs=pl.BlockSpec((BM_ROWS, LANES), lambda i, j, *_: (j, 0)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_decode_shard_kernel, d=d, cap=cap, ds=ds),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows_ds, LANES), jnp.float32),
        interpret=interpret,
    )(keys, mus, params, prior, off, bufs3)
    return out.reshape(-1)[:ds]
