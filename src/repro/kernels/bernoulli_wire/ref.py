"""jnp oracles for the fused §4.4 Bernoulli wire kernels.

Two jobs in one module:

* the **fast CPU production path** the wire codecs actually execute off-TPU
  (:func:`encode`, :func:`decode_sum`) — byte-identical to the historical
  ``uniform → cumsum → scatter`` chain in repro.core.wire.codecs /
  repro.core.bitplane but without its d-wide ``.at[].set`` scatter, which
  dominated encode wall-clock (~50 ms at d = 2²⁰ on one core: the XLA CPU
  scatter is serial).  ``rank_select`` replaces it with a
  searchsorted-driven *gather* of the identical values, so the (cap,)
  buffer — and therefore the golden wire bytes — is unchanged bit-for-bit
  (pinned by tests/test_golden_wire.py and the equivalence property in
  tests/test_bernoulli_wire_kernels.py);

* the **oracles** the Pallas kernels (repro.kernels.bernoulli_wire.kernel)
  are tested against in interpret mode (:func:`encode`,
  :func:`decode_sum_sequential`).  The kernels inline the bit-exact
  Threefry stream (repro.kernels.threefry.ref), so oracle equivalence is
  exact equality, not allclose.

Support semantics (must never drift — peers regenerate them from seeds):
``sent = uniform(key, (d,)) < p``; the j-th sent coordinate (support rank
j) occupies value slot j; ranks ≥ cap are dropped by both sides
symmetrically (≈6σ tail, repro.core.comm_cost.bernoulli_capacity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.threefry import ref as tf_ref


def rank_select(values, sent, cap: int):
    """(cap,) f32 with values[j] of each sent coordinate at its support
    rank; ranks ≥ cap dropped, unfilled slots 0.0.

    Equivalent to the historical scatter
    ``zeros(cap).at[where(sent & (pos < cap), pos, cap)].set(values,
    mode="drop")`` — slot k holds the value at the first coordinate whose
    inclusive support count reaches k+1 — but expressed as a gather:
    searchsorted over the inclusive cumsum finds that coordinate directly.
    Same values, same slots, same zeros ⇒ identical bytes, ~10× faster on
    the CPU backend (gathers vectorize; d-wide scatters do not).
    """
    d = values.shape[0]
    cum = jnp.cumsum(sent.astype(jnp.int32))
    src = jnp.searchsorted(cum, jnp.arange(1, cap + 1, dtype=jnp.int32),
                           side="left")
    filled = jnp.arange(cap, dtype=jnp.int32) < cum[-1]
    return jnp.where(filled, values[jnp.clip(src, 0, d - 1)], 0.0)


def encode(flat, key, p: float, cap: int, mu, *, scaled: bool = True):
    """One node's (cap,) Bernoulli value buffer (no μ tail, f32).

    The oracle for the fused encode kernel AND the CPU production path of
    repro.core.wire.codecs.bernoulli_pack: support from the node key,
    Eq. (1) unbiased rescale (or raw values for the EF twin), rank-ordered
    capacity-padded compaction.
    """
    d = flat.shape[0]
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    sent = u < p
    vals = flat / p - (1.0 - p) / p * mu if scaled else flat
    return rank_select(vals, sent, cap)


def decode_one(buf, key, p: float, cap: int, mu, d: int):
    """Reconstruct one peer's dense (d,) Y_i from its (cap,) value buffer.

    Exactly repro.core.wire.codecs.bernoulli_unpack's op chain.
    """
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    sent = u < p
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    valid = sent & (pos < cap)
    vals = buf[jnp.clip(pos, 0, cap - 1)]
    return jnp.where(valid, vals, mu)


def decode_sum(bufs, mus, keys, p: float, cap: int, d: int):
    """Σ_i reconstruction_i without materializing per-peer dense vectors
    one at a time: all peers' supports regenerate in one batched Threefry
    dispatch and fold into the accumulator in a single fused graph.

    bufs: (n, cap) f32 value buffers;  mus: (n,) f32;  keys: (n, 2) uint32
    (already rank-folded).  Caller divides by n.
    """
    u = jax.vmap(
        lambda k: jax.random.uniform(k, (d,), dtype=jnp.float32))(keys)
    sent = u < p
    pos = jnp.cumsum(sent.astype(jnp.int32), axis=1) - 1
    valid = sent & (pos < cap)
    vals = jnp.take_along_axis(bufs, jnp.clip(pos, 0, cap - 1), axis=1)
    recon = jnp.where(valid, vals, mus[:, None])
    return jnp.sum(recon, axis=0)


def support_shard(keys, p: float, d: int, start, ds: int):
    """(n, ds) support slice [start, start+ds) of every peer's (d,) draw.

    ``start`` may be traced (the shard offset inside shard_map); lanes past
    d are padding and come back False — the reduce-scatter decode's shards
    therefore concatenate to exactly the full supports.  Draws go through
    :func:`repro.kernels.threefry.ref.uniform_at`, bit-exact vs the
    ``jax.random.uniform(key, (d,)) < p`` rule peers encode with.
    """
    idx = start + jnp.arange(ds, dtype=jnp.int32)
    real = idx < d
    idxc = jnp.where(real, idx, 0)
    u = jax.vmap(lambda k: tf_ref.uniform_at(k, idxc, d))(keys)
    return (u < p) & real[None, :]


def decode_sum_shard(bufs, mus, sent, prior, cap: int):
    """Σ_i reconstruction_i restricted to one coordinate shard.

    ``sent``: (n, ds) support slice (from :func:`support_shard`);
    ``prior``: (n,) support counts of each peer strictly before the shard
    (the rank offset — a per-peer exclusive cumsum of per-shard counts,
    computed by the caller).  Same per-coordinate arithmetic as
    :func:`decode_sum`: rank = prior + within-shard cumsum − 1, ranks ≥
    cap fall back to μ.  Padding lanes (sent False) also decode to μ and
    must be truncated by the caller.
    """
    pos = prior[:, None] + jnp.cumsum(sent.astype(jnp.int32), axis=1) - 1
    valid = sent & (pos < cap)
    vals = jnp.take_along_axis(bufs, jnp.clip(pos, 0, cap - 1), axis=1)
    recon = jnp.where(valid, vals, mus[:, None])
    return jnp.sum(recon, axis=0)


def decode_sum_sequential(bufs, mus, keys, p: float, cap: int, d: int):
    """Peer-sequential Σ_i reconstruction_i — the fused decode kernel's
    exact accumulation order (peer-major fori), used as its oracle."""
    def body(i, acc):
        return acc + decode_one(bufs[i], keys[i], p, cap, mus[i], d)

    return jax.lax.fori_loop(0, bufs.shape[0], body,
                             jnp.zeros((d,), jnp.float32))
