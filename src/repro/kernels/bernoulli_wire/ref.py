"""jnp oracles for the fused §4.4 Bernoulli wire kernels.

Two jobs in one module:

* the **fast CPU production path** the wire codecs actually execute off-TPU
  (:func:`encode`, :func:`decode_sum`) — byte-identical to the historical
  ``uniform → cumsum → scatter`` chain in repro.core.wire.codecs /
  repro.core.bitplane but without its d-wide ``.at[].set`` scatter, which
  dominated encode wall-clock (~50 ms at d = 2²⁰ on one core: the XLA CPU
  scatter is serial).  ``rank_select`` replaces it with a
  searchsorted-driven *gather* of the identical values, so the (cap,)
  buffer — and therefore the golden wire bytes — is unchanged bit-for-bit
  (pinned by tests/test_golden_wire.py and the equivalence property in
  tests/test_bernoulli_wire_kernels.py);

* the **oracles** the Pallas kernels (repro.kernels.bernoulli_wire.kernel)
  are tested against in interpret mode (:func:`encode`,
  :func:`decode_sum_sequential`).  The kernels inline the bit-exact
  Threefry stream (repro.kernels.threefry.ref), so oracle equivalence is
  exact equality, not allclose.

Support semantics (must never drift — peers regenerate them from seeds):
``sent = uniform(key, (d,)) < p``; the j-th sent coordinate (support rank
j) occupies value slot j; ranks ≥ cap are dropped by both sides
symmetrically (≈6σ tail, repro.core.comm_cost.bernoulli_capacity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.threefry import ref as tf_ref


def rank_select(values, sent, cap: int):
    """(cap,) f32 with values[j] of each sent coordinate at its support
    rank; ranks ≥ cap dropped, unfilled slots 0.0.

    Equivalent to the historical scatter
    ``zeros(cap).at[where(sent & (pos < cap), pos, cap)].set(values,
    mode="drop")`` — slot k holds the value at the first coordinate whose
    inclusive support count reaches k+1 — but expressed as a gather:
    searchsorted over the inclusive cumsum finds that coordinate directly.
    Same values, same slots, same zeros ⇒ identical bytes, ~10× faster on
    the CPU backend (gathers vectorize; d-wide scatters do not).
    """
    d = values.shape[0]
    cum = jnp.cumsum(sent.astype(jnp.int32))
    src = jnp.searchsorted(cum, jnp.arange(1, cap + 1, dtype=jnp.int32),
                           side="left")
    filled = jnp.arange(cap, dtype=jnp.int32) < cum[-1]
    return jnp.where(filled, values[jnp.clip(src, 0, d - 1)], 0.0)


def encode(flat, key, p: float, cap: int, mu, *, scaled: bool = True):
    """One node's (cap,) Bernoulli value buffer (no μ tail, f32).

    The oracle for the fused encode kernel AND the CPU production path of
    repro.core.wire.codecs.bernoulli_pack: support from the node key,
    Eq. (1) unbiased rescale (or raw values for the EF twin), rank-ordered
    capacity-padded compaction.
    """
    d = flat.shape[0]
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    sent = u < p
    vals = flat / p - (1.0 - p) / p * mu if scaled else flat
    return rank_select(vals, sent, cap)


def decode_one(buf, key, p: float, cap: int, mu, d: int):
    """Reconstruct one peer's dense (d,) Y_i from its (cap,) value buffer.

    Exactly repro.core.wire.codecs.bernoulli_unpack's op chain.
    """
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    sent = u < p
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    valid = sent & (pos < cap)
    vals = buf[jnp.clip(pos, 0, cap - 1)]
    return jnp.where(valid, vals, mu)


# Coordinates per tile of the streamed decode accumulation.  Large enough
# that the per-tile Threefry dispatch amortizes, small enough that the
# (n, TILE) working set stays cache-resident instead of materializing the
# full (n, d) uniform matrix the historical vmap decode built.
DECODE_TILE = 8192
# Group width of the matmul cumsum: rows reshape to (·, L) and one
# (L, L)-triangular f32 matmul yields the within-group inclusive counts.
_CUMSUM_GROUP = 32


def _cumsum_rows(sent):
    """Inclusive int32 cumsum along axis 1 of an (n, T) bool matrix.

    Expressed as one f32 matmul against a triangular ones matrix per
    :data:`_CUMSUM_GROUP`-wide group plus a cheap group-prefix add — the
    XLA CPU int32 cumsum lowers to a serial scan, the matmul vectorizes
    (~2× the decode-shard wall-clock).  Exact because the f32 partial sums
    count 0/1 lanes and never exceed T ≤ 2²⁴; rows longer than that (or
    not group-aligned) fall back to the plain scan.
    """
    n, tl = sent.shape
    grp = _CUMSUM_GROUP
    if tl % grp or tl > (1 << 24):
        return jnp.cumsum(sent.astype(jnp.int32), axis=1)
    g = sent.reshape(n, tl // grp, grp).astype(jnp.float32)
    tri = jnp.triu(jnp.ones((grp, grp), jnp.float32))
    within = jax.lax.dot_general(
        g, tri, (((2,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST)
    totals = within[:, :, -1]
    prefix = jnp.cumsum(totals, axis=1) - totals
    return (within + prefix[:, :, None]).reshape(n, tl).astype(jnp.int32)


def _peer_sum(recon):
    """Peer-linear f32 sum over axis 0: ((r_0 + r_1) + r_2) + … .

    NOT ``jnp.sum(recon, axis=0)`` — XLA's reduce is free to tree-combine
    the peer axis, which reassociates the f32 adds and drifts from the
    peer-major accumulation order of :func:`decode_sum_sequential` and of
    the Pallas decode kernel (one grid step per peer).  Unrolling the
    (static, small) peer count pins the order, making the batched decodes
    bit-exact vs the sequential oracle.
    """
    acc = recon[0]
    for i in range(1, recon.shape[0]):
        acc = acc + recon[i]
    return acc


def decode_sum(bufs, mus, keys, p: float, cap: int, d: int):
    """Σ_i reconstruction_i, streamed tile-by-tile over the coordinates.

    bufs: (n, cap) f32 value buffers;  mus: (n,) f32;  keys: (n, 2) uint32
    (already rank-folded).  Caller divides by n.

    Each :data:`DECODE_TILE`-wide tile runs a fused regenerate → select →
    accumulate body: the peers' support slice regenerates via the
    random-access Threefry lanes (:func:`repro.kernels.threefry.ref
    .uniform_at` — bit-exact vs the ``jax.random.uniform(key, (d,)) < p``
    rule peers encode with), support ranks come from the carried per-peer
    prior count plus a within-tile matmul cumsum, and the tile's
    peer-linear sum lands in the accumulator.  Identical integers as the
    historical one-shot vmap decode (which materialized the full (n, d)
    uniform matrix) and the sequential oracle's per-coordinate f32 add
    order (:func:`_peer_sum`), so the result equals
    :func:`decode_sum_sequential` bit-for-bit — with an (n, TILE)
    working set instead of (n, d).
    """
    n = bufs.shape[0]
    grp = _CUMSUM_GROUP
    tile = min(DECODE_TILE, -(-d // grp) * grp)
    nt = -(-d // tile)

    def body(ti, carry):
        acc, prior = carry
        start = ti * tile
        idx = start + jnp.arange(tile, dtype=jnp.int32)
        real = idx < d
        idxc = jnp.where(real, idx, 0)
        u = jax.vmap(lambda k: tf_ref.uniform_at(k, idxc, d))(keys)
        sent = (u < p) & real[None, :]
        incl = _cumsum_rows(sent)
        pos = prior[:, None] + incl - 1
        valid = sent & (pos < cap)
        vals = jnp.take_along_axis(bufs, jnp.clip(pos, 0, cap - 1), axis=1)
        recon = jnp.where(valid, vals, mus[:, None])
        acc = jax.lax.dynamic_update_slice(
            acc, _peer_sum(recon), (start,))
        return acc, prior + incl[:, -1]

    acc, _ = jax.lax.fori_loop(
        0, nt, body, (jnp.zeros((nt * tile,), jnp.float32),
                      jnp.zeros((n,), jnp.int32)))
    return acc[:d]


def support_shard(keys, p: float, d: int, start, ds: int):
    """(n, ds) support slice [start, start+ds) of every peer's (d,) draw.

    ``start`` may be traced (the shard offset inside shard_map); lanes past
    d are padding and come back False — the reduce-scatter decode's shards
    therefore concatenate to exactly the full supports.  Draws go through
    :func:`repro.kernels.threefry.ref.uniform_at`, bit-exact vs the
    ``jax.random.uniform(key, (d,)) < p`` rule peers encode with.
    """
    idx = start + jnp.arange(ds, dtype=jnp.int32)
    real = idx < d
    idxc = jnp.where(real, idx, 0)
    u = jax.vmap(lambda k: tf_ref.uniform_at(k, idxc, d))(keys)
    return (u < p) & real[None, :]


def decode_sum_shard(bufs, mus, sent, prior, cap: int):
    """Σ_i reconstruction_i restricted to one coordinate shard.

    ``sent``: (n, ds) support slice (from :func:`support_shard`);
    ``prior``: (n,) support counts of each peer strictly before the shard
    (the rank offset — a per-peer exclusive cumsum of per-shard counts,
    computed by the caller).  Same per-coordinate arithmetic as
    :func:`decode_sum`: rank = prior + within-shard cumsum − 1 (the
    cumsum via the vectorized matmul form, identical integers), ranks ≥
    cap fall back to μ.  Padding lanes (sent False) also decode to μ and
    must be truncated by the caller.
    """
    pos = prior[:, None] + _cumsum_rows(sent) - 1
    valid = sent & (pos < cap)
    vals = jnp.take_along_axis(bufs, jnp.clip(pos, 0, cap - 1), axis=1)
    recon = jnp.where(valid, vals, mus[:, None])
    return _peer_sum(recon)


def decode_sum_sequential(bufs, mus, keys, p: float, cap: int, d: int):
    """Peer-sequential Σ_i reconstruction_i — the fused decode kernel's
    exact accumulation order (peer-major fori), used as its oracle."""
    def body(i, acc):
        return acc + decode_one(bufs[i], keys[i], p, cap, mus[i], d)

    return jax.lax.fori_loop(0, bufs.shape[0], body,
                             jnp.zeros((d,), jnp.float32))
