"""Counter-based in-kernel PRNG shared by the encoding kernels.

The encoders need one uniform variate per gradient coordinate.  Generating
them with jax.random *outside* the kernel would double HBM traffic (write u,
read u) on a memory-bound op, so the kernels synthesize randomness in
registers from (seed, coordinate-index) with a splitmix32/murmur3-style
integer hash.  The hash uses only uint32 ops available inside Pallas TPU
kernels (and in plain XLA, so kernel and oracle are bit-identical).

Statistical quality is adequate for unbiased sparsification masks (verified
empirically in tests/test_kernel_bernoulli.py::test_mask_statistics); it is
NOT a cryptographic or jax.random-grade generator and is never used for
model initialization.
"""
from __future__ import annotations

import jax.numpy as jnp

# Plain ints (not jnp arrays): Pallas kernels may not capture array
# constants from module scope; these fold to scalar literals at trace time.
_GOLDEN = 0x9E3779B9
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35


def hash_u32(seed, idx):
    """Murmur3 fmix32 of (seed-offset counter).  seed, idx: uint32 arrays."""
    h = (idx.astype(jnp.uint32) * jnp.uint32(_GOLDEN)) + seed.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(_M1)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(_M2)
    h = h ^ (h >> jnp.uint32(16))
    return h


def uniform_hash(seed, idx):
    """U[0,1) float32 from the top 24 bits of hash_u32."""
    bits = hash_u32(seed, idx) >> jnp.uint32(8)
    return bits.astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
