"""Pallas TPU kernels for the paper's compute hot spots (encode paths).

Each subpackage ships the kernel (pl.pallas_call + BlockSpec), a jit'd
``ops.py`` wrapper (TPU -> compiled kernel; CPU -> oracle / interpret mode),
and a pure-jnp ``ref.py`` oracle that tests assert against.
"""
