"""Pure-jnp oracle for the block-structured fixed-k encoder (Eq. (4), TPU form).

TPU adaptation (DESIGN.md §2): instead of k independent coordinates, the
support is kb = k/BLOCK tile-aligned blocks of BLOCK = 1024 contiguous
coordinates (one (8, 128) f32 TPU tile each), sampled uniformly without
replacement from the d/BLOCK blocks.  Every coordinate still has inclusion
probability exactly k/d, and since the MSE (Lemma 2.3) is a sum of
per-coordinate second moments, the Lemma 3.4 closed form
(d−k)/k · Σ(X−μ)²/n² holds *unchanged* — block sampling only introduces
cross-coordinate error correlations, which the squared-norm objective never
sees (verified: tests/test_kernel_fixed_k.py::test_block_mse_matches_lemma34).

encode: gather the selected blocks, rescaled to the unbiased wire values
        v = (d/k)·(x − μ) (so the decoder reconstructs Y = μ + scatter(v));
decode: scatter back, add μ.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 1024


def sample_blocks(key, num_blocks: int, kb: int):
    """Uniform kb-subset of block ids (Gumbel top-k), sorted."""
    g = jax.random.gumbel(key, (num_blocks,))
    _, ids = jax.lax.top_k(g, kb)
    return jnp.sort(ids)


def fixed_k_encode(x, block_ids, mu):
    """x: flat (d,) with d % BLOCK == 0 -> wire values (kb, BLOCK)."""
    d = x.shape[0]
    kb = block_ids.shape[0]
    k = kb * BLOCK
    blocks = x.reshape(-1, BLOCK)[block_ids]  # (kb, BLOCK)
    return (d / k) * (blocks - jnp.asarray(mu, x.dtype))


def fixed_k_decode(values, block_ids, mu, d: int):
    """Reconstruct dense Y_i = μ + scatter(values).  values: (kb, BLOCK)."""
    out = jnp.zeros((d // BLOCK, BLOCK), values.dtype).at[block_ids].set(values)
    return (out + jnp.asarray(mu, values.dtype)).reshape(d)
