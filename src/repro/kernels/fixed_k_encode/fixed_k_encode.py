"""Pallas TPU kernel: block-structured fixed-k gather-encode.

The selected block ids arrive via scalar prefetch and drive the input
BlockSpec's index_map — the classic Pallas gather pattern.  Each program
DMAs exactly one selected BLOCK-coordinate block (one (8, 128) f32 tile)
HBM→VMEM, applies the unbiased rescale v = (d/k)(x − μ), and writes the
compacted wire buffer.  HBM traffic is therefore k reads + k writes; the
dense-mask alternative reads all d coordinates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 128   # lane width
ROWS = 8   # sublane rows; ROWS*BS == ref.BLOCK


def _kernel(ids_ref, x_ref, scal_ref, o_ref):
    del ids_ref  # consumed by the index_map
    scale = scal_ref[0, 0]
    mu = scal_ref[0, 1]
    o_ref[...] = (scale * (x_ref[...].astype(jnp.float32) - mu)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fixed_k_gather_2d(x, block_ids, scal, *, interpret: bool = False):
    """x: (NB, ROWS, BS); block_ids: (kb,) int32; scal: (1, 2) [scale, mu].

    Returns (kb, ROWS, BS) wire values.
    """
    nb, rows, bs = x.shape
    assert rows == ROWS and bs == BS, (rows, bs)
    kb = block_ids.shape[0]
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(kb,),
            in_specs=[
                pl.BlockSpec((1, ROWS, BS), lambda i, ids: (ids[i], 0, 0)),
                pl.BlockSpec((1, 2), lambda i, ids: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, ROWS, BS), lambda i, ids: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((kb, ROWS, BS), x.dtype),
        interpret=interpret,
    )(block_ids, x, scal)
