"""jit'd wrapper: block-structured fixed-k encode/decode for any-shape arrays.

k is expressed in *blocks* (kb) of ref.BLOCK coordinates; the flat input is
zero-padded to a BLOCK multiple (padding joins the population like real
coordinates — harmless: its deviations are (0 − μ), reconstructed exactly
as μ-centred noise that is sliced away before use).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fixed_k_encode import fixed_k_encode as _kernel
from repro.kernels.fixed_k_encode import ref as _ref
from repro.kernels.fixed_k_encode.ref import sample_blocks  # noqa: F401  (re-export)

BLOCK = _ref.BLOCK


def num_blocks(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK


def fixed_k_encode(x, block_ids, mu, *, scale=None, force_pallas: bool = False):
    """Gather-encode: returns wire values scale·(x[S] − μ), (kb, BLOCK) f32.

    ``scale=None`` uses the unbiased d/k rescale of Eq. (4); ``scale=1.0``
    gives the *contractive* (biased) sparsifier used by error feedback.
    """
    on_tpu = jax.default_backend() == "tpu"
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    flat = jnp.pad(flat, (0, (-n) % BLOCK))
    d = flat.shape[0]
    k = block_ids.shape[0] * BLOCK
    if scale is None:
        scale = d / k
    if not (on_tpu or force_pallas):
        blocks = flat.reshape(-1, BLOCK)[block_ids]
        return scale * (blocks - jnp.asarray(mu, jnp.float32))
    scal = jnp.stack([jnp.asarray(scale, jnp.float32),
                      jnp.asarray(mu, jnp.float32)]).reshape(1, 2)
    x3 = flat.reshape(-1, _kernel.ROWS, _kernel.BS)
    out = _kernel.fixed_k_gather_2d(x3, block_ids, scal, interpret=not on_tpu)
    return out.reshape(-1, BLOCK)


def fixed_k_decode(values, block_ids, mu, shape, dtype=jnp.float32):
    """Scatter-decode dense Y_i and restore the original shape."""
    n = 1
    for s in shape:
        n *= s
    d = num_blocks(n) * BLOCK
    y = _ref.fixed_k_decode(values, block_ids, mu, d)
    return y[:n].reshape(shape).astype(dtype)
