"""Bit-exact jnp reimplementation of JAX's Threefry-2x32 PRNG.

The §4.4 seed-trick wire paths draw their supports with
``jax.random.uniform(key, (d,))`` — the committed golden wire bytes
(tests/golden/golden_wire.npz) pin those exact draws.  A fused Pallas
encode/decode kernel therefore cannot use a cheaper in-register hash (the
way the non-wire kernels use :mod:`repro.kernels.prng`): it must reproduce
XLA's Threefry stream bit-for-bit or the wire format silently drifts.

This module is that stream, written in plain uint32 jnp/lax ops that work
identically inside Pallas kernel bodies and in XLA — the single source of
truth the fused wire kernels (repro.kernels.bernoulli_wire,
repro.kernels.rotated_encode) inline and their oracles call.  Bit-exactness
against ``jax.random.uniform`` / ``jax.random.bits`` is pinned by
tests/test_threefry_ref.py across seeds, lengths and parities.

Counter layout (must match jax._src.prng.threefry_random_bits): for shape
(d,) the raw counter ``arange(d)`` is zero-padded to 2·⌈d/2⌉, split in
half — NOT interleaved — so lane j < half comes from cipher output x0 of
the pair (j, half + j) and lane j ≥ half from x1 of (j − half, j).  The
zero pad means the last x1 counter is 0 when d is odd.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Threefry-2x32 constants: key-schedule parity word and the 4-round
# rotation schedules (20 rounds = 5 groups of 4, alternating schedules).
_PARITY = 0x1BD11BDA
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """The 20-round Threefry-2x32 block cipher on uint32 arrays.

    ``k0, k1`` are the key words (scalars or arrays broadcastable to the
    counters), ``x0, x1`` the counter words.  Returns the two output words.
    Pure uint32 ops — usable verbatim inside a Pallas kernel body.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    x0 = jnp.asarray(x0, jnp.uint32) + ks[0]
    x1 = jnp.asarray(x1, jnp.uint32) + ks[1]
    for group in range(5):
        for r in _ROTATIONS[group % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(group + 1) % 3]
        x1 = x1 + ks[(group + 2) % 3] + jnp.uint32(group + 1)
    return x0, x1


def counter_words(idx, d: int):
    """The (x0, x1) counter words feeding coordinate ``idx`` of a (d,) draw.

    ``idx`` is any uint32 array of flat coordinate indices < d.  Encodes the
    split-halves layout above so callers (kernels) can evaluate scattered
    coordinate blocks without materializing the full counter array.
    """
    idx = jnp.asarray(idx, jnp.uint32)
    half = (d + 1) // 2
    lo = idx < half                       # lane from x0 of pair (idx, idx+half)
    pair = jnp.where(lo, idx, idx - half)
    c1 = pair + jnp.uint32(half)
    # zero pad: counter positions ≥ d hold 0 (odd-d last x1 word).
    c1 = jnp.where(c1 < d, c1, jnp.uint32(0))
    return pair, c1, lo


def random_bits(key, d: int):
    """Bit-exact ``jax.random.bits(key, (d,), 'uint32')`` for raw (2,) keys."""
    key = jnp.asarray(key).reshape(2).astype(jnp.uint32)
    half = (d + 1) // 2
    cnt = jnp.arange(d, dtype=jnp.uint32)
    cnt = jnp.pad(cnt, (0, 2 * half - d))
    o0, o1 = threefry2x32(key[0], key[1], cnt[:half], cnt[half:])
    return jnp.concatenate([o0, o1])[:d]


def bits_to_uniform(bits):
    """uint32 bits -> U[0, 1) float32, exactly as jax.random.uniform does:
    fill the f32 mantissa (value in [1, 2)), subtract 1, clamp at 0."""
    fbits = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    return jnp.maximum(
        jax.lax.bitcast_convert_type(fbits, jnp.float32) - jnp.float32(1.0),
        jnp.float32(0.0))


def uniform(key, d: int):
    """Bit-exact ``jax.random.uniform(key, (d,), jnp.float32)``."""
    return bits_to_uniform(random_bits(key, d))


def uniform_at(key, idx, d: int):
    """``jax.random.uniform(key, (d,), f32)[idx]`` without the (d,) draw.

    ``idx``: any int array of coordinate indices < d.  Evaluates only the
    cipher pairs feeding those lanes via :func:`counter_words` — the
    scattered-coordinate primitive the reduce-scatter decode shard uses to
    regenerate just its own slice of every peer's support.  Bit-exact vs
    the full draw (tests/test_threefry_ref.py).
    """
    key = jnp.asarray(key).reshape(2).astype(jnp.uint32)
    c0, c1, lo = counter_words(idx, d)
    o0, o1 = threefry2x32(key[0], key[1], c0, c1)
    return bits_to_uniform(jnp.where(lo, o0, o1))
