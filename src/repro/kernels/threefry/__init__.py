from repro.kernels.threefry import ref  # noqa: F401
