"""Pure-jnp oracle for the fast Walsh–Hadamard transform (Sylvester order).

H_1 = [1]; H_{2m} = [[H_m, H_m], [H_m, −H_m]].  fwht(x) = H_d @ x, unnormalized.
"""
from __future__ import annotations

import jax.numpy as jnp


# Butterfly levels fused per materialized pass.  Each radix-2^k superstage
# computes the identical binary add tree as k consecutive radix-2 stages —
# the per-element f32 operations and their order are unchanged, so results
# are bit-identical to the classic butterfly (golden wire bytes pinned on
# it) — but materializes the array once per k levels instead of per level.
# k = 2 measures fastest on the CPU path (deeper radices lose the savings
# to the wider stack); bumping this constant never changes results.
_RADIX_LEVELS = 2


def fwht(x):
    """O(d log d) butterfly, radix-2^k superstages.  x: (..., d), d = 2^m."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"d must be a power of two, got {d}"
    shape = x.shape
    x = x.reshape(-1, d)
    h = 1
    while h < d:
        lv = min(_RADIX_LEVELS, (d // h).bit_length() - 1)
        r = 1 << lv
        x = x.reshape(-1, d // (r * h), r, h)
        parts = [x[:, :, i, :] for i in range(r)]
        step = 1
        while step < r:
            parts = [parts[i ^ step] - parts[i] if i & step
                     else parts[i] + parts[i ^ step] for i in range(r)]
            step *= 2
        x = jnp.stack(parts, axis=2).reshape(-1, d)
        h *= r
    return x.reshape(shape)


def hadamard_matrix(d: int, dtype=jnp.float32):
    """Explicit H_d via the parity trick: H[i,j] = (−1)^{popcount(i & j)}."""
    i = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    bits = i & j
    # popcount parity of a 32-bit int
    v = bits
    parity = jnp.zeros_like(v)
    for s in range(32):
        parity = parity ^ ((v >> s) & 1)
    return (1 - 2 * parity).astype(dtype)
