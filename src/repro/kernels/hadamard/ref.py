"""Pure-jnp oracle for the fast Walsh–Hadamard transform (Sylvester order).

H_1 = [1]; H_{2m} = [[H_m, H_m], [H_m, −H_m]].  fwht(x) = H_d @ x, unnormalized.
"""
from __future__ import annotations

import jax.numpy as jnp


def fwht(x):
    """Classic O(d log d) butterfly.  x: (..., d), d a power of two."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"d must be a power of two, got {d}"
    shape = x.shape
    x = x.reshape(-1, d)
    h = 1
    while h < d:
        x = x.reshape(-1, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, d)
        h *= 2
    return x.reshape(shape)


def hadamard_matrix(d: int, dtype=jnp.float32):
    """Explicit H_d via the parity trick: H[i,j] = (−1)^{popcount(i & j)}."""
    i = jnp.arange(d)[:, None]
    j = jnp.arange(d)[None, :]
    bits = i & j
    # popcount parity of a 32-bit int
    v = bits
    parity = jnp.zeros_like(v)
    for s in range(32):
        parity = parity ^ ((v >> s) & 1)
    return (1 - 2 * parity).astype(dtype)
