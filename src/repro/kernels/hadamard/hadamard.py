"""Pallas TPU kernel for the Walsh–Hadamard transform.

Hardware adaptation (DESIGN.md §2): the textbook butterfly is a strided
VPU/reshape workload that maps poorly onto TPU (8,128) tiles.  We instead
use the Kronecker factorization

    H_d = H_{d1} ⊗ H_{d2},   d = d1·d2
    fwht(x) = H_{d1} @ X @ H_{d2},   X = x.reshape(d1, d2)

which turns the transform into two MXU matmuls per vector — O(d·(d1+d2))
MACs instead of O(d log d) adds, a winning trade on a 197-TFLOP/s MXU vs a
~4-TFLOP/s VPU, and with perfectly contiguous (lane-aligned) memory access.
The H factors are *generated in-kernel* from iota + popcount parity, so no
HBM traffic is spent on them.

Grid: one program per batch row; each program holds X (d1, d2), H_{d1} and
H_{d2} in VMEM.  Supported sizes: d1, d2 ≤ 1024 (⇒ d ≤ 2²⁰ per call; larger
vectors are chunked by the caller — see kernels/hadamard/ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hadamard_in_kernel(d: int, dtype):
    """Materialize H_d inside the kernel from 2-D iota (TPU needs ≥2-D)."""
    i = jax.lax.broadcasted_iota(jnp.int32, (d, d), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (d, d), 1)
    v = i & j
    parity = jnp.zeros_like(v)
    for s in range(10):  # d ≤ 1024 ⇒ 10 bits
        parity = parity ^ ((v >> s) & 1)
    return (1 - 2 * parity).astype(dtype)


def _fwht_kernel(x_ref, o_ref, *, d1: int, d2: int):
    x = x_ref[0]  # (d1, d2)
    acc_dtype = jnp.float32
    h1 = _hadamard_in_kernel(d1, acc_dtype)
    h2 = _hadamard_in_kernel(d2, acc_dtype)
    t = jax.lax.dot(x.astype(acc_dtype), h2,
                    precision=jax.lax.Precision.HIGHEST)
    y = jax.lax.dot(h1, t, precision=jax.lax.Precision.HIGHEST)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d1", "d2", "interpret"))
def fwht_pallas(x, *, d1: int, d2: int, interpret: bool = False):
    """x: (B, d1*d2) -> (B, d1*d2), unnormalized Walsh–Hadamard transform."""
    b, d = x.shape
    assert d == d1 * d2, (d, d1, d2)
    x3 = x.reshape(b, d1, d2)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, d1=d1, d2=d2),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, d1, d2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d1, d2), x.dtype),
        interpret=interpret,
    )(x3)
    return out.reshape(b, d)
