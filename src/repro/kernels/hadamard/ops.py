"""Public jit'd wrapper for the Walsh–Hadamard transform.

Dispatch policy:
* TPU backend        → Pallas MXU kernel (kron-factorized, hadamard.py).
* CPU / other        → Pallas kernel in interpret mode for small sizes in
  tests, but by default the pure-jnp oracle (ref.py) — identical results,
  no interpreter overhead.  The kernel is the TPU *target*; correctness is
  guaranteed by the allclose sweeps in tests/test_kernel_hadamard.py.

Vectors longer than MAX_D are processed in independent MAX_D chunks (a
block-diagonal rotation; standard bucketing — see DESIGN.md §2).
"""
from __future__ import annotations

from repro.kernels import backend
from repro.kernels.hadamard import hadamard as _kernel
from repro.kernels.hadamard import ref as _ref

MAX_D = 1 << 20


def _factorize(d: int):
    """Split d = d1·d2 with d1, d2 powers of two, as square as possible."""
    lg = d.bit_length() - 1
    l1 = lg // 2
    return 1 << l1, 1 << (lg - l1)


def fwht(x, *, force_pallas: bool = False, interpret: bool | None = None):
    """Unnormalized Walsh–Hadamard transform along the last axis.

    x: (..., d) with d a power of two, d ≤ 2**20.
    """
    d = x.shape[-1]
    if d & (d - 1):
        raise ValueError(f"fwht needs power-of-two length, got {d}")
    if d > MAX_D:
        raise ValueError(f"fwht supports d ≤ {MAX_D}; chunk the input "
                         "(repro.core.compression handles this)")
    use_pallas, auto_interpret = backend.choose(force_pallas)
    if not use_pallas:
        return _ref.fwht(x)
    if interpret is None:
        interpret = auto_interpret
    shape = x.shape
    x2 = x.reshape(-1, d)
    if d < 4:  # degenerate sizes: oracle
        return _ref.fwht(x).reshape(shape)
    d1, d2 = _factorize(d)
    return _kernel.fwht_pallas(x2, d1=d1, d2=d2,
                               interpret=interpret).reshape(shape)
