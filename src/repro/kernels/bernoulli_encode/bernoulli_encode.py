"""Pallas TPU kernel: fused Bernoulli sparsification encoder (Eq. (1)).

Fuses PRNG → mask → affine rescale into a single pass: one HBM read of the
gradient block and one write of the encoded block.  The unfused jnp version
materializes the uniform field and the mask (≥3 HBM round-trips on a purely
memory-bound op) — the fusion is a ~3× HBM-traffic reduction, which is the
relevant roofline term for encoder throughput at gradient scale (§1.1's
O(d) encode-time claim).

Layout: the flat gradient is viewed as (rows, LANES) with LANES = 128 and
tiled (BM, 128) per program; the PRNG counter is the global coordinate
index, so results are independent of the tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng

LANES = 128
BM = 512  # rows per program: (512, 128) f32 = 256 KiB in, 256 KiB out.


def _kernel(x_ref, scal_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]  # (BM, LANES)
    p = scal_ref[0, 0]
    mu = scal_ref[0, 1]
    # seed travels as two exact 16-bit halves (f32 represents ints < 2^24).
    seed = (scal_ref[0, 2].astype(jnp.uint32) * jnp.uint32(65536)
            + scal_ref[0, 3].astype(jnp.uint32))
    bm, bn = x.shape
    row = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1)
    base = (jnp.uint32(i) * jnp.uint32(bm)) * jnp.uint32(bn)
    idx = base + row * jnp.uint32(bn) + col
    u = prng.uniform_hash(seed, idx)
    sent = u < p
    xf = x.astype(jnp.float32)
    y = jnp.where(sent, xf / p - (1.0 - p) / p * mu, mu)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bernoulli_encode_2d(x, scal, *, interpret: bool = False):
    """x: (R, 128) with R % BM == 0; scal: (1, 4) f32 [p, mu, seed_bits, _]."""
    r, c = x.shape
    assert c == LANES and r % BM == 0, (r, c)
    return pl.pallas_call(
        _kernel,
        grid=(r // BM,),
        in_specs=[
            pl.BlockSpec((BM, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x, scal)
