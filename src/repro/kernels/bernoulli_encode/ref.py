"""Pure-jnp oracle for the fused Bernoulli encoder (Eq. (1), uniform p).

Bit-identical to the Pallas kernel: both draw the mask from
repro.kernels.prng.uniform_hash(seed, global_coordinate_index).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import prng


def bernoulli_encode(x, p, mu, seed):
    """x: (..., d) -> dense encoded Y (Eq. (1)) with hash-derived mask.

    Y(j) = X(j)/p − (1−p)/p·mu  if u_j < p else mu,  u_j = hash(seed, j).
    The coordinate index is global across the flattened input.
    """
    shape = x.shape
    flat = x.reshape(-1)
    idx = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    u = prng.uniform_hash(jnp.uint32(seed), idx)
    p32 = jnp.float32(p)
    mu32 = jnp.float32(mu)
    sent = u < p32
    y = jnp.where(sent, flat.astype(jnp.float32) / p32 - (1.0 - p32) / p32 * mu32,
                  mu32)
    return y.astype(x.dtype).reshape(shape)
