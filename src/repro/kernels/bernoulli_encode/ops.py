"""jit'd wrapper: fused Bernoulli encoder over arbitrary-shape arrays.

Pads the flat view to a (R, 128) grid multiple, runs the Pallas kernel
(interpret mode off-TPU), and restores the shape.  Padding coordinates are
encoded too (harmless — they decode to mu and are sliced away).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bernoulli_encode import bernoulli_encode as _kernel
from repro.kernels.bernoulli_encode import ref as _ref

_TILE = _kernel.BM * _kernel.LANES


def bernoulli_encode(x, p, mu, seed, *, force_pallas: bool = False):
    """Dense Eq.-(1) encoding of any-shape x with uniform probability p.

    Args:
      x: array, any shape/float dtype.
      p: scalar probability in (0, 1].
      mu: scalar node center.
      seed: uint32-compatible scalar seed.
    """
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return _ref.bernoulli_encode(x, p, mu, seed)
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = (-n) % _TILE
    flat = jnp.pad(flat, (0, npad))
    seed_u = jnp.asarray(seed, jnp.uint32)
    seed_hi = (seed_u >> jnp.uint32(16)).astype(jnp.float32)
    seed_lo = (seed_u & jnp.uint32(0xFFFF)).astype(jnp.float32)
    scal = jnp.stack([jnp.asarray(p, jnp.float32), jnp.asarray(mu, jnp.float32),
                      seed_hi, seed_lo]).reshape(1, 4)
    y = _kernel.bernoulli_encode_2d(flat.reshape(-1, _kernel.LANES), scal,
                                    interpret=not on_tpu)
    return y.reshape(-1)[:n].reshape(shape)
