"""jit-friendly wrappers for bit-plane pack/unpack of arbitrary lengths.

TPU -> fused Pallas kernel; CPU -> pure-jnp oracle (``force_pallas=True``
runs the kernel in interpret mode for equivalence tests).  Both produce the
identical word stream (verified in tests/test_bitplane.py), so wire buffers
are portable across backends.

Backend policy comes from repro.kernels.backend, which resolves the device
ONCE at import: the old per-call ``jax.default_backend()`` query ran at
*trace* time, so whichever backend first traced a caller got baked into the
cached executable.  ``REPRO_KERNEL_BACKEND`` overrides for tests/CI.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.bitplane import bitplane as _kernel
from repro.kernels.bitplane import ref as _ref

WIDTHS = _ref.WIDTHS
num_words = _ref.num_words


def pack_bits(vals, width: int, *, force_pallas: bool = False):
    """Pack (any-shape) unsigned symbols < 2**width into uint32 words.

    Returns (ceil(n*width/32),) uint32 with the ref.py layout.
    """
    use_pallas, interpret = backend.choose(force_pallas)
    flat = jnp.asarray(vals).reshape(-1).astype(jnp.uint32)
    d = flat.shape[0]
    if not use_pallas:
        return _ref.pack_bits(flat, width)
    nw = num_words(d, width)
    tile = _kernel.BM_PACK * _kernel.LANES
    flat = jnp.pad(flat, (0, (-d) % tile))
    packed = _kernel.pack_bits_2d(flat.reshape(-1, _kernel.LANES), width,
                                  interpret=interpret)
    return packed.reshape(-1)[:nw]


def unpack_bits(words, width: int, d: int, *, force_pallas: bool = False):
    """Inverse of :func:`pack_bits`: (nw,) uint32 words -> (d,) symbols."""
    use_pallas, interpret = backend.choose(force_pallas)
    flat = jnp.asarray(words).reshape(-1)
    if not use_pallas:
        return _ref.unpack_bits(flat, width, d)
    tile = _kernel.BM_UNPACK * _kernel.LANES
    flat = jnp.pad(flat, (0, (-flat.shape[0]) % tile))
    vals = _kernel.unpack_bits_2d(flat.reshape(-1, _kernel.LANES), width,
                                  interpret=interpret)
    return vals.reshape(-1)[:d]


def binary_accum(words, c_lo, c_hi, d: int, *, force_pallas: bool = False):
    """Fold n peers' (n, nw) 1-bit plane windows + per-peer centers into one
    (d,) f32 peer-linear sum — the fused unpack+accumulate of the §13
    scatter decode.  Pad words/coordinates beyond d are truncated."""
    use_pallas, interpret = backend.choose(force_pallas)
    words = jnp.asarray(words)
    c_lo = jnp.asarray(c_lo).astype(jnp.float32)
    c_hi = jnp.asarray(c_hi).astype(jnp.float32)
    if not use_pallas:
        return _ref.binary_accum(words, c_lo, c_hi, d)
    n, nw = words.shape
    tile = _kernel.BM_ACCUM * _kernel.LANES
    wp = jnp.pad(words, ((0, 0), (0, (-nw) % tile)))
    c = jnp.zeros((n, _kernel.LANES), jnp.float32)
    c = c.at[:, 0].set(c_lo).at[:, 1].set(c_hi)
    acc = _kernel.binary_accum_2d(wp.reshape(n, -1, _kernel.LANES), c,
                                  interpret=interpret)
    return acc.reshape(-1)[:d]
