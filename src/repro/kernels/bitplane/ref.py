"""Pure-jnp oracle for bit-plane packing (the §4.5 wire format made literal).

``pack_bits`` compresses a vector of small unsigned symbols (width w bits
each, w | 32) into uint32 words, 32/w symbols per word, little-endian within
the word: symbol j lands in word j // (32/w) at bit offset (j % (32/w)) * w.
``unpack_bits`` is the exact inverse.  The binary (w=1) and ternary (w=2)
quantized wire paths in :mod:`repro.core.bitplane` ride these planes.

Symbols must already be masked to w bits; packing is a disjoint-field sum,
so out-of-range inputs would corrupt neighbouring fields — callers pass
indicator / branch-index arrays which are in range by construction (the
kernel and this oracle both mask defensively anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
WIDTHS = (1, 2, 4, 8, 16)


def num_words(d: int, width: int) -> int:
    """uint32 words needed for d symbols of ``width`` bits."""
    assert width in WIDTHS, width
    per = WORD // width
    return -(-d // per)


def pack_bits(vals, width: int):
    """(d,) unsigned symbols < 2**width  ->  (ceil(d*width/32),) uint32."""
    assert width in WIDTHS, width
    per = WORD // width
    mask = jnp.uint32((1 << width) - 1)
    v = vals.reshape(-1).astype(jnp.uint32) & mask
    d = v.shape[0]
    npad = (-d) % per
    v = jnp.pad(v, (0, npad))
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(width)
    # fields are disjoint, so the sum is a bitwise OR (no carries).
    return jnp.sum(v.reshape(-1, per) << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words, width: int, d: int):
    """(nw,) uint32  ->  (d,) uint32 symbols; inverse of :func:`pack_bits`."""
    assert width in WIDTHS, width
    per = WORD // width
    mask = jnp.uint32((1 << width) - 1)
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(width)
    vals = (words.reshape(-1)[:, None] >> shifts) & mask
    return vals.reshape(-1)[:d]


def binary_accum(words, c_lo, c_hi, d: int):
    """Fold n peers' 1-bit plane windows into one (d,) f32 accumulator.

    ``words`` is (n, nw) uint32 — each row one peer's plane window covering
    ``d`` symbols; ``c_lo``/``c_hi`` are (n,) f32 per-peer centers.
    Returns ``Σ_i where(bit_ij, c_hi[i], c_lo[i])`` with peers folded in
    ascending order — the exact per-coordinate f32 add chain of the
    sequential flat decode (``acc + unpack(row_i)`` in
    ``WireCodec.decode_gathered``), so sharded and flat binary decodes
    agree bit-for-bit.  This is the oracle for the fused Pallas
    unpack+accumulate kernel (bitplane.binary_accum_2d).
    """
    def body(i, acc):
        bits = unpack_bits(words[i], 1, d)
        return acc + jnp.where(bits > 0, c_hi[i], c_lo[i])

    return jax.lax.fori_loop(0, words.shape[0], body,
                             jnp.zeros((d,), jnp.float32))
