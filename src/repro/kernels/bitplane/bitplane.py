"""Pallas TPU kernels: bit-plane pack / unpack (uint32 word planes).

The quantized wire paths (binary 1-bit, ternary 2-bit — §4.5 / §7.1) ship
their per-coordinate symbols as packed uint32 words.  These kernels fuse
the w-bit field packing so HBM traffic is read d·4 bytes, write d·w/8
bytes (pack) and the reverse (unpack) — the packed plane is exactly what
travels on the wire.

Layout matches the :mod:`repro.kernels.bitplane.ref` oracle bit-for-bit:
32/w symbols per word, little-endian fields, row-major over the (BM, 128)
tile — so flattening the 2D output reproduces the 1D word stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BM_PACK = 256    # (256, 128) u32 in -> (256, 128*w/32) u32 out
BM_UNPACK = 8    # (8, 128) u32 words in -> (8, 128*32/w) u32 out
BM_ACCUM = 8     # (n, 8, 128) u32 words -> (8, 128*32) f32 accumulator


def _pack_kernel(width, v_ref, o_ref):
    per = 32 // width
    v = v_ref[...].astype(jnp.uint32) & jnp.uint32((1 << width) - 1)
    bm, bn = v.shape
    v3 = v.reshape(bm, bn // per, per)
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (1, 1, per), 2)
              * jnp.uint32(width))
    o_ref[...] = jnp.sum(v3 << shifts, axis=-1, dtype=jnp.uint32)


def _unpack_kernel(width, w_ref, o_ref):
    per = 32 // width
    w = w_ref[...].astype(jnp.uint32)
    bm, bn = w.shape
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (1, 1, per), 2)
              * jnp.uint32(width))
    vals = (w[:, :, None] >> shifts) & jnp.uint32((1 << width) - 1)
    o_ref[...] = vals.reshape(bm, bn * per)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def pack_bits_2d(vals, width: int, *, interpret: bool = False):
    """vals: (R, 128) uint32 symbols, R % BM_PACK == 0 -> (R, 128*w/32)."""
    r, c = vals.shape
    assert c == LANES and r % BM_PACK == 0, (r, c)
    out_lanes = LANES * width // 32
    return pl.pallas_call(
        functools.partial(_pack_kernel, width),
        grid=(r // BM_PACK,),
        in_specs=[pl.BlockSpec((BM_PACK, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM_PACK, out_lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, out_lanes), jnp.uint32),
        interpret=interpret,
    )(vals)


def _accum_kernel(n, w_ref, c_ref, o_ref):
    per = 32
    bm = o_ref.shape[0]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, per), 2)

    def body(i, acc):
        w = w_ref[i]                                     # (BM, LANES) u32
        bits = (w[:, :, None] >> shifts) & jnp.uint32(1)
        sel = bits.reshape(bm, -1) > 0
        return acc + jnp.where(sel, c_ref[i, 1], c_ref[i, 0])

    o_ref[...] = jax.lax.fori_loop(0, n, body,
                                   jnp.zeros(o_ref.shape, jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def binary_accum_2d(words, centers, *, interpret: bool = False):
    """Fused unpack + center-select + peer accumulate (scatter decode §13).

    words: (n, R, 128) uint32 1-bit plane windows, R % BM_ACCUM == 0;
    centers: (n, 128) f32 with lane 0 = c_lo, lane 1 = c_hi per peer.
    One pass over the n×window word range folds every peer into a single
    (R, 128*32) f32 accumulator — peers added in ascending order, so the
    result matches the ref.binary_accum oracle (and the sequential flat
    decode) bit-for-bit.
    """
    n, r, c = words.shape
    assert c == LANES and r % BM_ACCUM == 0, (n, r, c)
    out_lanes = LANES * 32
    return pl.pallas_call(
        functools.partial(_accum_kernel, n),
        grid=(r // BM_ACCUM,),
        in_specs=[pl.BlockSpec((n, BM_ACCUM, LANES), lambda i: (0, i, 0)),
                  pl.BlockSpec((n, LANES), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((BM_ACCUM, out_lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, out_lanes), jnp.float32),
        interpret=interpret,
    )(words, centers)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def unpack_bits_2d(words, width: int, *, interpret: bool = False):
    """words: (R, 128) uint32, R % BM_UNPACK == 0 -> (R, 128*32/w) symbols."""
    r, c = words.shape
    assert c == LANES and r % BM_UNPACK == 0, (r, c)
    out_lanes = LANES * (32 // width)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, width),
        grid=(r // BM_UNPACK,),
        in_specs=[pl.BlockSpec((BM_UNPACK, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM_UNPACK, out_lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, out_lanes), jnp.uint32),
        interpret=interpret,
    )(words)
