"""Serving: prefill + decode step builders (shard_map'd), cache shardings,
and a batched greedy-generation driver.

Cache layout note: when kv_heads < tp the kv dimension of the cache is
declared with *global* extent kv_keep·tp and P("model") — each model shard
stores the single kv head its q-block attends to (heads are duplicated
across shards in the global view; decode only ever reads the local slice).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, RunConfig, ShapeSpec
from repro.models import attention as attn_lib
from repro.models import encdec as encdec_lib
from repro.models import model as model_lib
from repro.train import train_step as ts


def serve_batch_axes(cfg, run, shape, msizes):
    return ts.batch_axes_for(cfg, run, shape, msizes)


def cache_pspecs(cfg: ArchConfig, ctx, baxes) -> Dict:
    b = baxes if baxes else None

    def attn_spec():
        return {"k": P(None, b, None, "model", None),
                "v": P(None, b, None, "model", None)}

    def ssm_spec():
        m = "model" if ctx.tp > 1 else None
        return {"conv_x": P(None, b, None, m),
                "conv_B": P(None, b, None, None),
                "conv_C": P(None, b, None, None),
                "state": P(None, b, m, None, None)}

    if cfg.family in ("dense", "vlm", "moe"):
        return attn_spec()
    if cfg.family == "ssm":
        return ssm_spec()
    if cfg.family == "hybrid":
        return {"attn": attn_spec(), "ssm": ssm_spec()}
    if cfg.family == "encdec":
        sp = attn_spec()
        sp.update({"xk": P(None, b, None, "model", None),
                   "xv": P(None, b, None, "model", None)})
        return sp
    raise ValueError(cfg.family)


def global_cache_shapes(cfg: ArchConfig, ctx, shape: ShapeSpec,
                        msizes) -> Dict:
    """ShapeDtypeStructs of the GLOBAL decode cache for dry-run lowering."""
    dims = attn_lib.attn_dims(cfg.num_heads, cfg.num_kv_heads, cfg.hd, ctx.tp)
    kv_keep = 1 if (dims.kv_replicated and ctx.tp > 1) else dims.kv_local
    kv_glob = kv_keep * (ctx.tp if ctx.tp > 1 else 1)
    b = shape.global_batch
    s_max = shape.seq_len if cfg.window is None else min(shape.seq_len,
                                                         cfg.window)
    L = cfg.num_layers
    f = jnp.bfloat16

    def attn_shape(n, s):
        return {"k": jax.ShapeDtypeStruct((n, b, s, kv_glob, cfg.hd), f),
                "v": jax.ShapeDtypeStruct((n, b, s, kv_glob, cfg.hd), f)}

    def ssm_shape(n):
        s = cfg.ssm
        tpx = ctx.tp if ctx.tp > 1 else 1
        return {
            "conv_x": jax.ShapeDtypeStruct(
                (n, b, s.conv_width - 1, s.d_inner(cfg.d_model)), f),
            "conv_B": jax.ShapeDtypeStruct(
                (n, b, s.conv_width - 1, s.n_groups * s.d_state), f),
            "conv_C": jax.ShapeDtypeStruct(
                (n, b, s.conv_width - 1, s.n_groups * s.d_state), f),
            "state": jax.ShapeDtypeStruct(
                (n, b, s.nheads(cfg.d_model), s.head_dim, s.d_state),
                jnp.float32),
        }

    if cfg.family in ("dense", "vlm", "moe"):
        return attn_shape(L, s_max)
    if cfg.family == "ssm":
        return ssm_shape(L)
    if cfg.family == "hybrid":
        per = cfg.attn_every
        return {"attn": attn_shape(L // per, s_max),
                "ssm": ssm_shape((L // per) * (per - 1))}
    if cfg.family == "encdec":
        out = attn_shape(L, s_max)
        enc_s = encdec_lib.enc_seq_padded(cfg, ctx.tp)
        xc = attn_shape(L, enc_s)
        out["xk"] = xc["k"]
        out["xv"] = xc["v"]
        return out
    raise ValueError(cfg.family)


def build_serve_fns(mesh, cfg: ArchConfig, run: RunConfig, shape: ShapeSpec,
                    base_seed: int = 0):
    """Returns (prefill_fn, decode_fn, specs, input pspec info).

    prefill_fn(params, batch) -> (cache, logits_local)
    decode_fn(params, cache, tok, pos) -> (next_tok, cache)
    """
    msizes = ts.mesh_sizes_of(mesh)
    ctx = model_lib.make_ctx(cfg, run, msizes)
    key0 = jax.random.PRNGKey(base_seed)
    _, specs = ts.abstract_specs(key0, cfg, ctx, msizes, run)
    baxes = ts.batch_axes_for(cfg, run, shape, msizes)
    param_ps = {k: ts.spec_to_pspec(v) for k, v in specs.items()}
    cache_ps = cache_pspecs(cfg, ctx, baxes)
    b = baxes if baxes else None
    tok_ps = P(b, None)
    s_max = shape.seq_len if cfg.window is None else min(shape.seq_len,
                                                         cfg.window)

    def sharded_prefill(params, batch):
        cache, logits = model_lib.prefill(ctx, params, specs, cfg, run, batch,
                                          s_max=s_max)
        return cache, logits

    def sharded_decode(params, cache, tok, pos):
        nxt, _, cache = model_lib.decode_step(ctx, params, specs, cfg, run,
                                              cache, tok, pos)
        return nxt, cache

    bspec = ts.batch_pspec(cfg, baxes)
    del bspec["labels"], bspec["mask"]

    vax = "model" if ctx.tp > 1 else None
    prefill_fn = jax.jit(compat.shard_map(
        sharded_prefill, mesh=mesh, in_specs=(param_ps, bspec),
        out_specs=(cache_ps, P(b, None, vax)), check_vma=False))
    decode_fn = jax.jit(compat.shard_map(
        sharded_decode, mesh=mesh,
        in_specs=(param_ps, cache_ps, tok_ps, P()),
        out_specs=(tok_ps, cache_ps), check_vma=False),
        donate_argnums=(1,))
    return prefill_fn, decode_fn, specs, {"batch": bspec, "cache": cache_ps,
                                          "tok": tok_ps, "baxes": baxes}


def generate(prefill_fn, decode_fn, params, batch, steps: int):
    """Greedy generation driver (host loop; decode_fn donates the cache)."""
    cache, logits = prefill_fn(params, batch)
    prompt_len = batch["tokens"].shape[1]
    # greedy pick from the replicated last-position logits is done on host
    # via the decode_fn's internal sampling; seed decode with the prompt's
    # last token prediction:
    toks = []
    tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)  # local slice
    for i in range(steps):
        tok, cache = decode_fn(params, cache, tok, jnp.int32(prompt_len + i))
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
