"""Communication-cost models beta (§4).

Two views are provided:

* *analytic* expected costs ``C_{alpha,beta}`` as closed forms in the
  protocol parameters (Eqs. in §4.1–§4.5) — these are the quantities the
  paper's Table 1 tabulates; and
* *realized* costs ``measure_bits`` computed from an actual
  :class:`repro.core.encoders.Encoded` sample (the random variable
  Σ_i beta(alpha(X_i)) whose expectation the analytic forms give).

All costs are in **bits** for the full n-node round.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.types import CommSpec


def ceil_log2(d: int) -> int:
    return max(1, math.ceil(math.log2(d)))


# --- analytic expected costs (§4) ---------------------------------------- #

def cost_naive(n: int, d: int, spec: CommSpec) -> float:
    """§4.1:  C = n·d·r."""
    return float(n * d * spec.r_bits)


def cost_varying_length(probs, spec: CommSpec) -> float:
    """§4.2:  C = n·r̄ + Σ_ij (1 + r·p_ij).   probs: (n, d)."""
    n = probs.shape[0]
    return float(n * spec.rbar_bits + jnp.sum(1.0 + spec.r_bits * probs))


def cost_sparse(probs, spec: CommSpec, d: int) -> float:
    """§4.3 Eq. (8):  C = n·r̄ + (⌈log d⌉ + r)·Σ_ij p_ij."""
    n = probs.shape[0]
    return float(n * spec.rbar_bits
                 + (ceil_log2(d) + spec.r_bits) * jnp.sum(probs))


def cost_sparse_seed_fixed_k(n: int, k: int, spec: CommSpec) -> float:
    """§4.4 Eq. (9) (fixed-size support):  C = n(r̄ + r̄_s) + n·k·r.

    Deterministic — the straggler-friendly protocol.
    """
    return float(n * (spec.rbar_bits + spec.rseed_bits) + n * k * spec.r_bits)


def cost_sparse_seed_uniform_p(n: int, d: int, p: float, spec: CommSpec) -> float:
    """§4.4 Eq. (10) (uniform-p variable support):  C = n(r̄ + r̄_s) + n·d·p·r."""
    return float(n * (spec.rbar_bits + spec.rseed_bits) + n * d * p * spec.r_bits)


def cost_binary(n: int, d: int, spec: CommSpec) -> float:
    """§4.5 Eq. (11):  C = 2·n·r + n·d   (two scalars + 1 bit/coordinate)."""
    return float(n * 2 * spec.r_bits + n * d)


def cost_ternary(n: int, d: int, p_pass: float, spec: CommSpec) -> float:
    """§7.1 analogue of Eq. (11):  C = 2·n·r + 2·n·d + n·d·p_pass·r.

    Two centers (c1, c2), a 2-bit branch index per coordinate, and the
    expected p_pass·d full-precision pass-through values of Eq. (21).
    """
    return float(n * 2 * spec.r_bits + n * 2 * d + n * d * p_pass * spec.r_bits)


# --- §4.4 realized on SPMD hardware: capacity-padded value buffers -------- #

def bernoulli_capacity(d: int, p: float, slack_sigmas: float = 6.0) -> int:
    """Wire-buffer slots for the seed-trick Bernoulli protocol.

    SPMD collectives need static shapes, but the Bernoulli support size
    |S_i| ~ Binomial(d, p) is random.  The wire path therefore ships a
    fixed buffer of  cap = min(d, ⌈p·d + slack·σ⌉)  value slots with
    σ = √(d·p(1−p)); the (≈1e-9 at 6σ) overflow tail is dropped by both
    encoder and decoder symmetrically (see collectives.bernoulli_pack).
    """
    if not (0.0 < p <= 1.0):
        raise ValueError(f"p must be in (0, 1], got {p}")
    sigma = math.sqrt(max(d * p * (1.0 - p), 0.0))
    cap = int(math.ceil(p * d + slack_sigmas * sigma))
    return max(1, min(d, cap))


def cost_sparse_seed_capacity(n: int, cap: int, spec: CommSpec) -> float:
    """§4.4 with capacity padding:  C = n·(r̄ + r̄_s) + n·cap·r.

    The static-shape realization of Eq. (10): every node ships exactly
    ``cap`` value slots (from :func:`bernoulli_capacity`) plus its center
    and seed, instead of the random |S_i| ≈ p·d slots of the idealized
    protocol.  The overhead over Eq. (10) is ≤ n·r·(slack·σ + 1) bits.
    """
    return float(n * (spec.rbar_bits + spec.rseed_bits) + n * cap * spec.r_bits)


def _pad_words(bits: float) -> float:
    """Round a bit count up to whole uint32 wire words."""
    return 32.0 * math.ceil(bits / 32.0)


def cost_binary_packed(n: int, d: int, spec: CommSpec) -> float:
    """Eq. (11) realized as packed uint32 planes (repro.core.bitplane).

    C = n·(32·⌈d/32⌉ + 32·⌈2r/32⌉): the 1-bit sign plane rounded up to
    whole words, plus the (vmin, vmax) tail slots at wire precision.  The
    overhead over Eq. (11) at the same r is < 2·32 bits per node; there is
    no r̄_s seed term — the plane is data-dependent and travels explicitly.
    """
    return float(n * (_pad_words(d) + _pad_words(2 * spec.r_bits)))


def cost_ternary_packed(n: int, d: int, cap: int, spec: CommSpec) -> float:
    """Eq. (21) realized as a packed 2-bit plane + capacity-padded values.

    C = n·(32·⌈2d/32⌉ + 32·⌈cap·r/32⌉ + 32·⌈2r/32⌉) with ``cap`` from
    :func:`bernoulli_capacity` at p = p_pass — the static-shape realization
    of :func:`cost_ternary`, overhead ≤ n·r·(slack·σ + 1) + word padding.
    """
    return float(n * (_pad_words(2 * d) + _pad_words(cap * spec.r_bits)
                      + _pad_words(2 * spec.r_bits)))


def cost(spec: CommSpec, *, n: int, d: int, probs=None, k=None, p=None,
         cap=None, packed: bool = False) -> float:
    """Dispatch on ``spec.protocol``; see the per-protocol functions.

    ``packed=True`` selects the word-padded wire realizations for the
    plane protocols (cost_binary_packed / cost_ternary_packed, the latter
    requiring ``cap``); the ideal §4.5/§7.1 forms otherwise.  For
    ``sparse_seed``, passing ``cap`` selects the capacity-padded
    realization directly — that path has no separate plane to pad.
    """
    if spec.protocol == "naive":
        return cost_naive(n, d, spec)
    if spec.protocol == "varying":
        assert probs is not None
        return cost_varying_length(probs, spec)
    if spec.protocol == "sparse":
        assert probs is not None
        return cost_sparse(probs, spec, d)
    if spec.protocol == "sparse_seed":
        if cap is not None:
            return cost_sparse_seed_capacity(n, cap, spec)
        if k is not None:
            return cost_sparse_seed_fixed_k(n, k, spec)
        assert p is not None
        return cost_sparse_seed_uniform_p(n, d, p, spec)
    if spec.protocol == "binary":
        if packed:
            return cost_binary_packed(n, d, spec)
        return cost_binary(n, d, spec)
    if spec.protocol == "ternary":
        if packed:
            assert cap is not None, "packed ternary cost needs cap"
            return cost_ternary_packed(n, d, cap, spec)
        assert p is not None
        return cost_ternary(n, d, p, spec)
    raise ValueError(spec.protocol)


def cost_config(cfg, *, n: int, d: int, mesh_sizes=None) -> float:
    """Analytic cost of the wire codec the registry resolves for ``cfg``.

    The config-level companion of :func:`cost`: instead of hand-picking a
    protocol + kwargs, consult the one dispatch rule
    (repro.core.wire.registry.resolve) and charge what ``compressed_mean``
    will actually ship — the codec's gathered payload plus its implicit
    seed bits; for the §7.2 rotated compositions this is the inner codec's
    cost at the rotated length plus the rotation-seed term.  Identity
    (verified per codec by tests/test_wire_registry.py):

        cost_config == codec.wire_bits + codec.seed_bits.

    ``n`` is the flat world size over all compression axes.  Hierarchical
    configs (``cfg.inner_axes``) pre-reduce exactly inside the inner
    groups, so only the cross-host group's messages exist — the codec is
    billed at :func:`repro.core.wire.effective_nodes`, which needs
    ``mesh_sizes`` (axis name → size) to derive the split.  Flat configs
    ignore ``mesh_sizes``.

    A FLAT scatter decode (``cfg.scatter_decode`` with empty
    ``inner_axes``, DESIGN.md §12) runs its auxiliary collectives —
    decoded-shard all_gather + codec bookkeeping — over the main axes,
    so their bytes are billed too via ``codec.scatter_bits`` (zero for
    every other config; the hierarchical shard gather rides the free
    inner link per the §11 convention).

    ``cfg.decode_policy`` and decode-time drop masks never change the
    payload (DESIGN.md §14): robust reductions and peer exclusion happen
    AFTER the gather, on the same wire rows — the cost here is identical
    for "mean" and any trim/median policy over the same codec.
    """
    from repro.core import wire  # local import: wire consumes this module
    n_eff = wire.effective_nodes(cfg, n, mesh_sizes)
    codec = wire.resolve(cfg)
    return float(codec.comm_cost_bits(n_eff, d, cfg)
                 + codec.scatter_bits(n_eff, d, cfg))


# --- realized cost of one encoded round ----------------------------------- #

def measure_bits(encoded, spec: CommSpec, d: int) -> float:
    """Bits actually used by one sampled round under protocol ``spec``.

    ``encoded`` is a batched :class:`Encoded` (leading node axis).  The
    expectation of this quantity over encoder randomness equals the analytic
    ``cost`` (verified by tests/test_comm_cost.py).
    """
    n = encoded.y.shape[0]
    nsent = jnp.sum(encoded.nsent)
    if spec.protocol == "naive":
        return float(n * d * spec.r_bits)
    if spec.protocol == "varying":
        return float(n * spec.rbar_bits + n * d + spec.r_bits * nsent)
    if spec.protocol == "sparse":
        return float(n * spec.rbar_bits + (ceil_log2(d) + spec.r_bits) * nsent)
    if spec.protocol == "sparse_seed":
        return float(n * (spec.rbar_bits + spec.rseed_bits) + spec.r_bits * nsent)
    if spec.protocol == "binary":
        return float(n * 2 * spec.r_bits + n * d)
    if spec.protocol == "ternary":
        # 2 centers + the 2-bit plane + r bits per realized pass-through
        # coordinate (encoded.nsent counts the full-precision branch).
        return float(n * 2 * spec.r_bits + n * 2 * d + spec.r_bits * nsent)
    raise ValueError(spec.protocol)
