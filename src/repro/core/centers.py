"""Node-center (mu_i) policies.

The paper associates a scalar *node center* mu_i with each node (§3); the
encoder transmits deviations from it.  Policies:

* ``zero``    — mu_i = 0; data-independent, so r̄ = 0 bits (§4 footnote 1).
* ``mean``    — mu_i = (1/d) Σ_j X_i(j); used throughout §5.2.
* ``min``     — mu_i = min_j X_i(j); the Example 4 / Suresh et al. choice.
* ``optimal`` — Eq. (16): weighted mean with w_ij = 1/p_ij − 1, optimal for
  *fixed* probabilities; see :mod:`repro.core.optimal` for the alternating
  scheme that pairs it with optimal probabilities.
"""
from __future__ import annotations

import jax.numpy as jnp


def compute_centers(x, policy: str, probs=None):
    """Return mu with shape x.shape[:-1] (one scalar per node/vector).

    Args:
      x: (..., d) vectors (leading axes = nodes).
      policy: one of zero | mean | min | optimal.
      probs: (..., d) probabilities, required for ``optimal``.
    """
    if policy == "zero":
        return jnp.zeros(x.shape[:-1], x.dtype)
    if policy == "mean":
        return jnp.mean(x, axis=-1)
    if policy == "min":
        return jnp.min(x, axis=-1)
    if policy == "optimal":
        if probs is None:
            raise ValueError("optimal centers need probabilities (Eq. 16)")
        return optimal_centers(x, probs)
    raise ValueError(f"unknown center policy {policy!r}")


def optimal_centers(x, probs):
    """Optimal node centers for fixed probabilities, Eq. (16).

    mu_i = Σ_j w_ij X_i(j) / Σ_j w_ij with w_ij = 1/p_ij − 1.

    Coordinates with p_ij = 1 get zero weight (they are transmitted exactly
    and do not contribute to the MSE); if *all* coordinates of a node have
    p = 1 the center is irrelevant and we fall back to the plain mean.
    """
    p = jnp.clip(probs, 1e-12, 1.0)
    w = 1.0 / p - 1.0
    wsum = jnp.sum(w, axis=-1)
    mu = jnp.sum(w * x, axis=-1) / jnp.where(wsum > 0, wsum, 1.0)
    fallback = jnp.mean(x, axis=-1)
    return jnp.where(wsum > 0, mu, fallback)
