"""Reference single-host implementation of the full (alpha, beta, gamma) stack.

:class:`MeanEstimator` bundles an encoder spec, a communication-cost model
and the averaging decoder, exposing exactly the quantities the paper
analyses: an unbiased estimate Y of X = mean(X_i), its realized/expected
communication cost in bits, and its empirical/closed-form MSE.  This is the
oracle the distributed collectives (repro.core.collectives) and the
benchmarks are validated against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import centers as centers_lib
from repro.core import comm_cost, decoders, encoders
from repro.core import mse as mse_lib
from repro.core import optimal as optimal_lib
from repro.core import rotation as rotation_lib
from repro.core import types as t


@dataclasses.dataclass
class EstimateReport:
    estimate: jax.Array          # (d,) the decoded Y
    bits: float                  # realized communication cost (this round)
    expected_bits: float         # analytic C_{alpha,beta}
    expected_mse: float          # closed-form MSE at the given X (not rotated)
    nsent_total: int             # Σ_i |S_i|


class MeanEstimator:
    """(alpha, beta, gamma) with alpha from §3, beta from §4, gamma = averaging."""

    def __init__(self, enc: t.EncoderSpec = t.EncoderSpec(),
                 comm: t.CommSpec = t.CommSpec(), budget: Optional[float] = None):
        """``budget`` (B of §6) activates optimal probabilities when
        enc.probs == "optimal"; it bounds Σ_ij p_ij."""
        self.enc = enc
        self.comm = comm
        self.budget = budget
        if enc.probs == "optimal" and comm.protocol == "sparse_seed":
            # §4.4: the seed trick needs identically-distributed supports
            # (fixed k or uniform p); per-coordinate optimal probabilities
            # require transmitting indices (§4.3).
            raise ValueError("optimal probabilities require the 'sparse' "
                             "communication protocol (§4.3), not sparse_seed")

    # -- parameter selection (§6) ---------------------------------------- #
    def parameters_for(self, xs):
        """Return (probs or None, mus) per the spec's policies."""
        n, d = xs.shape
        if self.enc.kind in ("identity", "binary"):
            return None, None
        if self.enc.probs == "optimal":
            B = self.budget if self.budget is not None else self.enc.fraction * n * d
            if self.enc.center == "optimal":
                probs, mus, _ = optimal_lib.alternating_minimization(xs, B)
            else:
                mus = centers_lib.compute_centers(xs, self.enc.center)
                probs = optimal_lib.optimal_probs(xs, mus, B)
            return probs, mus
        mus = centers_lib.compute_centers(
            xs, self.enc.center if self.enc.center != "optimal" else "mean")
        if self.enc.center == "optimal":
            p0 = jnp.full(xs.shape, self.enc.fraction, xs.dtype)
            mus = centers_lib.optimal_centers(xs, p0)
        return None, mus

    # -- one estimation round --------------------------------------------- #
    def estimate(self, key, xs) -> EstimateReport:
        """Run encode → (bit-accounted) communicate → decode on (n, d) xs."""
        n, d = xs.shape
        kq, kenc = jax.random.split(key)
        work = xs
        if self.enc.rotation:
            work = rotation_lib.rotate(kq, xs)  # shared Q across nodes (§7.2)
        probs, mus = self.parameters_for(work)
        encd = encoders.encode_batch(kenc, work, self.enc, probs=probs, mus=mus)
        y = decoders.averaging_decoder(encd.y)
        if self.enc.rotation:
            y = rotation_lib.unrotate(kq, y, d)
        bits = comm_cost.measure_bits(encd, self.comm, work.shape[1])
        return EstimateReport(
            estimate=y,
            bits=bits,
            expected_bits=self.expected_bits(work, probs),
            expected_mse=float(self.expected_mse(work, probs, mus)),
            nsent_total=int(jnp.sum(encd.nsent)),
        )

    def expected_bits(self, xs, probs=None) -> float:
        n, d = xs.shape
        if self.enc.kind == "identity":
            return comm_cost.cost_naive(n, d, self.comm)
        if self.enc.kind == "binary":
            return comm_cost.cost_binary(n, d, self.comm)
        if self.enc.kind == "fixed_k":
            k = t.fixed_k_from_fraction(d, self.enc.fraction)
            return comm_cost.cost(self.comm, n=n, d=d, k=k)
        if probs is None:
            probs = jnp.full(xs.shape, self.enc.fraction, xs.dtype)
        return comm_cost.cost(self.comm, n=n, d=d, probs=probs,
                              p=float(self.enc.fraction))

    def expected_mse(self, xs, probs=None, mus=None):
        n, d = xs.shape
        if self.enc.kind == "identity":
            return jnp.zeros(())
        if self.enc.kind == "binary":
            return mse_lib.mse_binary(xs)
        if mus is None:
            _, mus = self.parameters_for(xs)
        if self.enc.kind == "fixed_k":
            k = t.fixed_k_from_fraction(d, self.enc.fraction)
            return mse_lib.mse_fixed_k(xs, k, mus)
        if probs is None:
            probs = jnp.full(xs.shape, self.enc.fraction, xs.dtype)
        if self.enc.kind == "bernoulli":
            return mse_lib.mse_bernoulli(xs, probs, mus)
        if self.enc.kind == "ternary":
            c1 = jnp.min(xs, axis=-1)
            c2 = jnp.max(xs, axis=-1)
            half = (1.0 - self.enc.fraction) / 2.0
            return mse_lib.mse_ternary(xs, half, half, c1, c2)
        raise ValueError(self.enc.kind)


def empirical_mse(key, xs, estimator: MeanEstimator, trials: int = 256):
    """Monte-Carlo MSE of the estimator — the Def. 2.2 expectation.

    Traced (jit-compatible) re-implementation of one estimate() round,
    without the Python-float bit accounting.
    """
    n, d = xs.shape
    x_true = jnp.mean(xs, axis=0)

    def one(k):
        kq, kenc = jax.random.split(k)
        work = rotation_lib.rotate(kq, xs) if estimator.enc.rotation else xs
        probs, mus = estimator.parameters_for(work)
        encd = encoders.encode_batch(kenc, work, estimator.enc,
                                     probs=probs, mus=mus)
        y = decoders.averaging_decoder(encd.y)
        if estimator.enc.rotation:
            y = rotation_lib.unrotate(kq, y, d)
        err = y - x_true
        return jnp.sum(err * err)

    keys = jax.random.split(key, trials)
    errs = jax.lax.map(jax.jit(one), keys)
    return jnp.mean(errs)
