"""Error feedback for compressed aggregation (beyond-paper extension).

The paper's encoders are *unbiased* but high-variance at aggressive budgets
(Lemma 3.2's (1/p − 1) factor).  Error feedback (Seide et al. 2014;
Stich et al. 2018) instead uses a *contractive biased* compressor and
recycles each node's residual into the next round:

    m_t  = C(x_t + e_t)              (transmitted message)
    e_{t+1} = (x_t + e_t) − m_t      (local residual, never transmitted)

For the fixed-k family, the contractive compressor is the **unscaled**
support selection (scale 1 instead of Eq. (4)'s d/k): then
E‖v − C(v)‖² = (1 − k/d)·‖v − μ1‖², a (k/d)-contraction on the centred
part, which makes the EF recursion stable (the unbiased d/k rescale is an
*expansion* — ‖v − C(v)‖ grows by (d/k − 1) on the support — and provably
diverges under EF; tests/distributed_checks/collectives_check.py's
``ef.converges`` check guards exactly this).

The time-average of EF estimates telescopes:  (1/T) Σ_t m̄_t =
x̄ + (e_0 − e_T)/T, so constant inputs are recovered at rate 1/T with zero
asymptotic bias, while per-round wire cost stays n·k·r.

State: one f32 residual buffer per compressed leaf, sharded like the
gradient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives
from repro.core import types as t
from repro.kernels.fixed_k_encode import ops as fk


def init_state(tree):
    """Zero residuals shaped like the gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_mean_ef(x, err, key, cfg: t.CompressionConfig):
    """One EF round over cfg.axes: returns (mean_estimate, new_err).

    Uses the block-structured fixed-k selection with scale=1 (contractive).
    ``shared_support`` keeps the k-length psum wire; ``gather_decode``
    all_gathers the per-node messages (independent supports).
    """
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32) + err.reshape(-1)
    d = flat.size
    if cfg.mode == "none" or d < cfg.min_compress_size:
        return jax.lax.pmean(x, cfg.axes), err

    nb = fk.num_blocks(d)
    kb = collectives.fixed_k_blocks(d, cfg.encoder.fraction)
    mu = collectives._center(flat, cfg.encoder.center)

    if cfg.mode == "shared_support":
        ids = fk.sample_blocks(key, nb, kb)
        vals = fk.fixed_k_encode(flat, ids, mu, scale=1.0)
        my_recon = fk.fixed_k_decode(vals, ids, mu, (d,))
        # one fused launch: μ rides the tail slot of the value buffer
        wire = jnp.concatenate([vals.reshape(-1), mu[None]]).astype(
            cfg.wire_dtype).astype(jnp.float32)
        gwire = jax.lax.pmean(wire, cfg.axes)
        gvals = gwire[:-1].reshape(-1, fk.BLOCK)
        est = fk.fixed_k_decode(gvals, ids, gwire[-1], shape)
    else:  # gather_decode: independent supports
        rank, n = collectives._axis_rank_size(cfg.axes)
        ids = fk.sample_blocks(jax.random.fold_in(key, rank), nb, kb)
        vals = fk.fixed_k_encode(flat, ids, mu, scale=1.0)
        my_recon = fk.fixed_k_decode(vals, ids, mu, (d,))
        wire = jnp.concatenate([vals.reshape(-1), mu[None]]).astype(
            cfg.wire_dtype)
        all_wire = collectives._gather_nested(wire, cfg.axes).reshape(
            n, kb * fk.BLOCK + 1).astype(jnp.float32)
        all_vals = all_wire[:, :-1].reshape(n, kb, fk.BLOCK)
        all_mu = all_wire[:, -1]

        def body(i, acc):
            ids_i = fk.sample_blocks(jax.random.fold_in(key, i), nb, kb)
            return acc.at[ids_i].add(all_vals[i])

        acc = jax.lax.fori_loop(0, n, body,
                                jnp.zeros((nb, fk.BLOCK), jnp.float32))
        est = ((acc / n + jnp.mean(all_mu)).reshape(-1)[:d]).reshape(shape)

    new_err = (flat - my_recon.reshape(-1)).reshape(shape)
    return est.astype(x.dtype), new_err
