"""DEPRECATED shim — error feedback lives in the wire layer now.

Error feedback (Seide et al. 2014; Stich et al. 2018) is a composable
wire-codec wrapper since the EFCodec refactor: :class:`repro.core.wire.ef
.EFCodec` wraps any registered codec (fixed-k, Bernoulli, the packed
binary/ternary planes, the §7.2 rotated compositions) with
residual-corrected contractive messages in the inner codec's exact wire
format.  Resolution is the one registry rule — set
``CompressionConfig.error_feedback=True`` and thread the residual through
:func:`repro.core.collectives.compressed_mean_stateful` (the bucketed
train step does this via ``repro.train.bucketing.init_ef_state`` /
``sync_grads_bucketed``).

The fixed-k-only ``compressed_mean_ef`` collective that used to live here
(and bypassed the codec registry) is gone; this shim forwards to the codec
round and will be removed once external callers migrate.
"""
from __future__ import annotations

import dataclasses

from repro.core import collectives
from repro.core import types as t


def compressed_mean_ef(x, err, key, cfg: t.CompressionConfig):
    """Deprecated: one EF round over cfg.axes; returns (estimate, new_err).

    Thin shim over the EF wire codec: forces ``error_feedback=True`` on
    ``cfg`` and runs the stateful codec round — use
    :func:`repro.core.collectives.compressed_mean_stateful` directly.
    """
    if not cfg.error_feedback:
        cfg = dataclasses.replace(cfg, error_feedback=True)
    return collectives.compressed_mean_stateful(x, err, key, cfg)
