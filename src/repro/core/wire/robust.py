"""Robust decode reductions: coordinate-wise f-of-n trimming on the
gathered per-peer reconstructions (docs/DESIGN.md §14).

The paper's averaging decoder γ (§2) is n-agnostic, and the gather codecs
already materialize all n per-node wire rows at decode time — so replacing
the per-coordinate average with a robust order-statistic reduction costs
nothing extra on the wire.  This module is that reduction, shared by every
gather codec through the :meth:`WireCodec.decode_rows_reduce` hook in
:mod:`repro.core.wire.base`:

  * ``mean``      — masked ascending-peer average; the only policy that
    also has a fused fast path (:meth:`decode_gathered`) when no peer is
    dropped.  The masked accumulation is ``where(keep_i, acc + Y_i, acc)``
    in ascending peer order, NOT ``acc + keep_i * Y_i`` — the ``where``
    form makes the masked decode bit-identical to a reference loop over
    only the surviving peers (multiplying by the mask would fold the
    dropped peer's row into the sum as ``+0.0``, which is not a float
    no-op: ``-0.0 + 0.0`` flips the sign bit, and NaN/Inf rows poison it).
  * ``trim(f)``   — coordinate-wise trimmed mean: drop the f largest and f
    smallest of the kept values per coordinate, average the remaining
    m − 2f (m = number of kept peers).  The f-of-n trimming idiom of
    approximate consensus (Dolev et al., JACM 1986): with c ≤ f corrupt
    rows and m > 2f every kept value after trimming lies inside the honest
    values' range per coordinate, so the estimate is contained in the
    honest convex hull (breakdown property, tests/test_robust_decode.py).
  * ``median``    — coordinate-wise median of the kept values (the
    midpoint pair of the kept ranks, averaged).
  * ``mean_trim(f)`` — the JACM86 fault-tolerant midpoint: the average of
    the smallest and largest survivors after trimming f from each end
    (ranks f and m−1−f of the kept values).

Dropped peers and traced masks.  ``keep`` is a traced (n,) 0/1 operand —
never a static argument — so a :class:`FailurePlan` can change the dropped
set every step with ZERO recompiles.  The order statistics still need the
kept values contiguous in rank order, which a plain value sort cannot give
(an adversarial NaN row sorts after any +inf sentinel for dropped rows):
the sort is a two-key lexicographic ``lax.sort`` on ``(1 − keep, value)``,
putting all kept rows first (value-sorted, NaNs last among them — jax
total order) and all dropped rows after.  Rank windows are then computed
against the traced kept count m.

All-dead / over-trimmed contract: when the reduction is undefined (m = 0,
or m ≤ 2f for the trimming policies) the result is NaN — the same loud
0/0 contract as :func:`repro.core.collectives.partial_mean`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import types as t

# the canonical policy parser lives next to the config field it validates.
parse_policy = t.parse_decode_policy


def _sorted_kept(stack, keep):
    """Peer-axis sort of ``stack`` with kept rows first.

    Returns ``(s, m)``: ``s`` is (n, d') with, per coordinate, the kept
    values in ascending jax total order (NaN last) occupying ranks
    0..m−1 and the dropped rows' values after them; ``m`` is the traced
    f32 kept count.  With ``keep=None`` this is a plain per-coordinate
    sort and m = n (static).
    """
    n = stack.shape[0]
    if keep is None:
        return jnp.sort(stack, axis=0), jnp.float32(n)
    keep = keep.astype(jnp.float32)
    key0 = jnp.broadcast_to((1.0 - keep)[:, None], stack.shape)
    _, s = jax.lax.sort((key0, stack), dimension=0, num_keys=2)
    return s, jnp.sum(keep)


def reduce_rows(stack, kind: str, f: int, keep=None):
    """One robust reduction over an (n, d') per-peer reconstruction stack.

    ``kind``/``f`` come from :func:`parse_policy`; ``keep`` is an optional
    traced (n,) 0/1 alive mask (1 = keep the peer's row).  Returns the
    (d',) f32 estimate; NaN where the reduction is undefined (see module
    docstring).  Permutation-invariant over the peer axis for the
    order-statistic policies by construction (sorting forgets peer order).
    """
    stack = stack.astype(jnp.float32)
    n = stack.shape[0]
    if kind == "mean":
        if keep is None:
            def body(i, acc):
                return acc + stack[i]
            return jax.lax.fori_loop(
                0, n, body, jnp.zeros(stack.shape[1:], jnp.float32)) / n
        keepf = keep.astype(jnp.float32)

        def body(i, acc):
            return jnp.where(keepf[i] > 0, acc + stack[i], acc)
        acc = jax.lax.fori_loop(0, n, body,
                                jnp.zeros(stack.shape[1:], jnp.float32))
        return acc / jnp.sum(keepf)
    if kind not in ("trim", "median", "mean_trim"):
        raise ValueError(f"unknown robust reduction kind {kind!r}")
    s, m = _sorted_kept(stack, keep)
    nan = jnp.float32(jnp.nan)
    if kind == "trim":
        ranks = jnp.arange(n, dtype=jnp.float32)[:, None]
        w = (ranks >= f) & (ranks < m - f)
        cnt = m - 2.0 * f
        est = jnp.sum(jnp.where(w, s, 0.0), axis=0) / cnt
        return jnp.where(cnt > 0, est, nan)
    mi = m.astype(jnp.int32)
    if kind == "median":
        lo, hi = (mi - 1) // 2, mi // 2
        guard = mi > 0
    else:  # mean_trim: midpoint of the extreme survivors after trimming
        lo, hi = jnp.int32(f), mi - 1 - f
        guard = mi > 2 * f
    take = lambda r: jnp.take_along_axis(  # noqa: E731
        s, jnp.broadcast_to(jnp.clip(r, 0, n - 1), (1,) + s.shape[1:]),
        axis=0)[0]
    est = 0.5 * (take(lo) + take(hi))
    return jnp.where(guard, est, nan)


def is_mean(cfg: t.CompressionConfig) -> bool:
    """True iff ``cfg`` decodes with the plain averaging decoder."""
    return parse_policy(cfg.decode_policy)[0] == "mean"
