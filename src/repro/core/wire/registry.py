"""The wire-codec registry: THE dispatch rule of the wire layer.

Every consumer of "what does this config put on the wire" — the collective
itself (:func:`repro.core.collectives.compressed_mean`), the bit
accounting (:func:`repro.core.comm_cost.cost_config`,
:func:`repro.train.bucketing.bucket_wire_bits`), the benchmark sweeps and
the config presets (repro.configs.registry) — resolves a codec here, so a
new protocol registers once instead of being threaded through four layers
by hand.

``gather_kind`` preserves the historical rule verbatim: configs whose
encoder cannot ride a modelled wire format (optimal Bernoulli
probabilities with implicit supports, optimal centers on the seed-trick
path) fall back to the dense simulation and are charged dense f32 bits —
never a compressed wire they don't actually ride.  The §6 *ternary*
optimal probabilities ARE wire-modelled (the branch choices ride the 2-bit
plane): they resolve to ``ternary_opt``.  Two wrappers compose on top of
the base codec: ``cfg.encoder.rotation`` wraps the §7.2 pre-transform
(:class:`repro.core.wire.rotated.RotatedCodec`), and
``cfg.error_feedback`` wraps the residual-recycling layer outermost
(:class:`repro.core.wire.ef.EFCodec` — EF∘rotation, so the residual stays
in model coordinates; docs/DESIGN.md §8).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import types as t
from repro.core.wire import base, codecs, ef, robust, rotated

_CODECS: Dict[str, base.WireCodec] = {}


def register(codec: base.WireCodec) -> base.WireCodec:
    """Register a codec instance under its ``name`` (last write wins)."""
    _CODECS[codec.name] = codec
    return codec


def get(name: str) -> base.WireCodec:
    if name not in _CODECS:
        raise KeyError(f"unknown wire codec {name!r}; have {names()}")
    return _CODECS[name]


def names() -> List[str]:
    return sorted(_CODECS)


# ---- the built-in codecs --------------------------------------------------- #

register(codecs.FixedKGatherCodec())
register(codecs.FixedKSharedCodec())
register(codecs.BernoulliCodec())
register(codecs.BinaryCodec())
register(codecs.TernaryCodec())
register(codecs.TernaryOptCodec())
register(codecs.DenseSimCodec())
# the shipped §7.2 presets (any other rotated composition is built on the
# fly by resolve(); registering these two gives them stable names for
# enumeration in tests/benchmarks).
register(rotated.RotatedCodec(get("binary")))
register(rotated.RotatedCodec(get("fixed_k")))
# the shipped error-feedback compositions (same deal: resolve() builds any
# other EF wrap on the fly; these get stable names for enumeration).
register(ef.EFCodec(get("fixed_k")))
register(ef.EFCodec(get("fixed_k_shared")))
register(ef.EFCodec(get("bernoulli")))
register(ef.EFCodec(get("binary")))
register(ef.EFCodec(get("ternary")))
register(ef.EFCodec(get("rotated_binary")))


# ---- dispatch --------------------------------------------------------------- #

def gather_kind(cfg: t.CompressionConfig) -> str:
    """The base wire format gather_decode mode will use for ``cfg``.

    One of "fixed_k" | "bernoulli" | "binary" | "ternary" | "ternary_opt"
    | "dense".
    """
    e = cfg.encoder
    if e.kind == "fixed_k":
        return "fixed_k"
    if (e.kind == "bernoulli" and e.probs == "uniform"
            and e.center in ("zero", "mean", "min")):
        # §4.4 seed trick: the uniform-p support is data-independent, so
        # it regenerates peer-side and only values + μ hit the wire.
        return "bernoulli"
    if e.kind == "binary":
        # §4.5: data-dependent branch probabilities, so the packed 1-bit
        # plane travels explicitly (no seed trick possible).
        return "binary"
    if e.kind == "ternary" and e.probs == "uniform":
        # §7.1: 2-bit plane + capacity-padded pass-through values.
        return "ternary"
    if e.kind == "ternary" and e.probs == "optimal":
        # §6 optimal (p1, p2): data-dependent, but the realized branches
        # ride the plane anyway and the pass mass stays Bernoulli(q) per
        # coordinate — same wire format and capacity rule as "ternary".
        return "ternary_opt"
    # data-dependent Bernoulli probabilities / optimal centers on the
    # seed-trick path: supports are implicit and cannot regenerate
    # peer-side — simulate densely, charge dense bits.
    return "dense"


def resolve(cfg: t.CompressionConfig) -> base.WireCodec:
    """The codec ``compressed_mean`` will execute for ``cfg``.

    Composition order (innermost to outermost): base codec → §7.2 rotation
    (``cfg.encoder.rotation``) → error feedback (``cfg.error_feedback``).
    EF outermost keeps its residual in model coordinates (docs/DESIGN.md
    §8).  Raises ValueError for modes without a wire codec ("none"
    short-circuits to an exact pmean before dispatch ever happens).
    """
    if cfg.mode == "shared_support":
        codec = get("fixed_k_shared")
    elif cfg.mode == "dense_sim":
        codec = get("dense")
    elif cfg.mode == "gather_decode":
        codec = get(gather_kind(cfg))
    else:
        raise ValueError(cfg.mode)
    if cfg.encoder.rotation:
        name = "rotated_" + codec.name
        codec = _CODECS.get(name) or rotated.RotatedCodec(codec)
    if cfg.error_feedback:
        name = "ef_" + codec.name
        codec = _CODECS.get(name) or ef.EFCodec(codec)
    if cfg.scatter_decode and not codec.scatter_supported:
        raise ValueError(
            f"scatter_decode requires a linear gather decode; codec "
            f"{codec.name!r} does not partition coordinate-wise "
            "(scatter_supported=False)")
    if codec.reduce == "psum" and not robust.is_mean(cfg):
        # robust order statistics need the individual per-peer wire rows;
        # a psum codec sums them inside the collective, so there is
        # nothing left to trim at decode time.
        raise ValueError(
            f"decode_policy {cfg.decode_policy!r} needs per-peer wire rows "
            f"(gather reduce); codec {codec.name!r} reduces by psum")
    return codec
