"""Wire-codec subsystem (docs/DESIGN.md §3, §8).

A :class:`~repro.core.wire.base.WireCodec` is one wire format: pack /
unpack / slots / bits / reduce kind (+ optional local codec state).  The
registry (:mod:`repro.core.wire.registry`) holds the built-in codecs — the
production base paths plus the shipped §7.2 rotated and error-feedback
compositions — and is the single dispatch rule consulted by collectives,
comm_cost, bucketing, configs and benchmarks.
"""
from repro.core.wire.base import (  # noqa: F401
    WireCodec, effective_nodes, scatter_axes, scatter_shard_len,
    scatter_word_align)
from repro.core.wire.ef import EFCodec  # noqa: F401
from repro.core.wire.registry import (  # noqa: F401
    gather_kind, get, names, register, resolve)
from repro.core.wire.robust import (  # noqa: F401
    parse_policy, reduce_rows)
from repro.core.wire.rotated import RotatedCodec  # noqa: F401
