"""WireCodec: the unit of the wire layer (docs/DESIGN.md §3).

The paper's protocols differ only in *what one node puts on the wire* and
*how peers decode it*; everything else — the star-gather scaffold, bucket
planning, bit accounting, benchmark sweeps — is protocol-independent.  A
:class:`WireCodec` captures exactly that per-protocol surface:

  * ``pack(flat, key, rank, cfg)``    — one node's wire buffer (any dtype);
  * ``unpack(row, peer, key, cfg, d)``— reconstruct peer ``peer``'s dense
    Y_i from its gathered row (regenerating seed-trick supports from
    ``fold_in(key, peer)`` where the protocol allows);
  * ``wire_slots(d, cfg)``            — static buffer length in elements;
  * ``wire_bits(n, d, cfg)``          — exact gathered payload bits for an
    n-node round: what the lowered HLO's collective result shape shows
    (the star-protocol convention the paper's C sums use);
  * ``reduce``                        — "all_gather" (star protocol) or
    "psum" (shared-support / dense-simulation paths whose wire is a plain
    all-reduce).

``mean_flat`` is the collective itself: gather codecs run the star gather
(pack → all_gather over cfg.axes → per-peer decode → average); "psum"
codecs run pack → pmean → ``decode_reduced`` (their wire is the reduced
buffer itself).  ``decode_gathered`` exists as a separate hook so codecs
with a fused decode (fixed-k's scatter-accumulate) keep their exact op
sequence — the refactor from the hand-rolled paths in
repro.core.collectives is bit-identical by construction: same PRNG
fold_in chain, same op order, same HLO.

Stateful codecs (docs/DESIGN.md §8): a codec may thread per-bucket state
through the round — error feedback's residual is the production case
(:mod:`repro.core.wire.ef`).  ``state_shape`` declares the state (None for
the stateless majority), ``init_state`` zeros it, and
``mean_flat_stateful`` is the (estimate, new_state) entry point every
caller that owns state uses (``repro.core.collectives
.compressed_mean_stateful``, ``repro.train.bucketing``).  The default
implementation makes every stateless codec trivially drivable through the
stateful API (state passes through untouched), so the train step has ONE
code path regardless of codec.  State is local by contract: it never
appears in the wire buffer, so the payload accounting below is unchanged
by statefulness (HLO-verified in tests/distributed_checks/ef_wire_check).

Accounting contract (verified by tests/test_wire_registry.py for every
registered codec):  ``comm_cost_bits == wire_bits + seed_bits`` — the
analytic §4 cost splits into bits that physically travel (the gathered
buffer, HLO-measurable) plus bits that ride the implicit PRNG (the §4.4
seed trick: supports/rotations regenerate peer-side from the shared key).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import types as t

Axes = Tuple[str, ...]


def axis_rank_size(axes: Axes):
    """Linear rank of this shard within the compression axes + node count."""
    rank = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        rank = rank * compat.axis_size(ax) + jax.lax.axis_index(ax)
        n *= compat.axis_size(ax)
    return rank, n


def gather_nested(v, axes: Axes):
    """all_gather over possibly-multiple axes, flattening the node dim."""
    out = v[None]
    for ax in reversed(axes):
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
    return out


def center(x, policy: str):
    """The node center μ_i used on the wire (data-independent policies only)."""
    if policy == "zero":
        return jnp.zeros((), jnp.float32)
    if policy == "mean":
        return jnp.mean(x).astype(jnp.float32)
    if policy == "min":
        return jnp.min(x).astype(jnp.float32)
    raise ValueError(f"center policy {policy!r} not supported on the wire "
                     "(optimal centers need the §6 solver — reference path only)")


class WireCodec:
    """One registered wire format; see the module docstring for the contract.

    Subclasses set ``name`` and ``reduce`` and implement the geometry,
    accounting and pack/unpack hooks.  Codecs are stateless: all parameters
    come from the :class:`repro.core.types.CompressionConfig` threaded into
    every call, so a single registered instance serves every bucket/config.
    """

    name: str = "?"
    reduce: str = "all_gather"          # "all_gather" | "psum"
    stateful: bool = False              # True iff state_shape is not None

    # ---- wire geometry & accounting -------------------------------------- #

    def wire_slots(self, d: int, cfg: t.CompressionConfig) -> int:
        """Static length of one node's wire buffer, in buffer elements."""
        raise NotImplementedError

    def wire_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Exact gathered payload bits of one n-node round (HLO-verified)."""
        raise NotImplementedError

    def seed_bits(self, n: int, cfg: t.CompressionConfig) -> float:
        """Bits riding the implicit PRNG instead of the wire (§4.4 seeds)."""
        return 0.0

    def cost_spec(self, d: int, cfg: t.CompressionConfig):
        """(CommSpec, kwargs) mapping this codec onto comm_cost.cost."""
        raise NotImplementedError

    def comm_cost_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Analytic §4 cost via comm_cost.cost — == wire_bits + seed_bits."""
        from repro.core import comm_cost
        spec, kw = self.cost_spec(d, cfg)
        return comm_cost.cost(spec, n=n, d=d, **kw)

    # ---- per-node wire format -------------------------------------------- #

    def pack(self, flat, key, rank, cfg: t.CompressionConfig):
        """Encode the local (d,) f32 vector into one flat wire buffer.

        ``key`` is the shared per-bucket key; protocols with per-node
        randomness fold ``rank`` in themselves (so peers can regenerate
        node i's draws from ``fold_in(key, i)`` alone).
        """
        raise NotImplementedError

    def unpack(self, row, peer, key, cfg: t.CompressionConfig, d: int):
        """Reconstruct peer ``peer``'s dense (d,) f32 Y_i from its row."""
        raise NotImplementedError

    def decode_gathered(self, rows, key, cfg: t.CompressionConfig,
                        d: int, n: int):
        """Averaging decoder over the gathered (n, slots) wire rows.

        Default: Y = (1/n) Σ_i unpack(row_i) — codecs with a fused decode
        (fixed-k scatter-accumulate) override this.
        """
        def body(i, acc):
            return acc + self.unpack(rows[i], i, key, cfg, d)

        acc = jax.lax.fori_loop(0, n, body, jnp.zeros((d,), jnp.float32))
        return acc / n

    def decode_reduced(self, wire, key, cfg: t.CompressionConfig, d: int):
        """Decode the *reduced* wire buffer of a "psum" codec.

        Only "psum" codecs implement this: their collective is a plain
        pmean of the packed buffer, and decoding the reduced buffer IS
        decoding the averaged messages (the decode is linear in the wire
        values).  Applied to one node's un-reduced buffer it reconstructs
        that node's own dense message — which is how the error-feedback
        wrapper obtains local contributions uniformly across reduce kinds.
        """
        raise NotImplementedError

    # ---- codec state (stateless by default; see wire/ef.py) -------------- #

    def state_shape(self, d: int, cfg: t.CompressionConfig):
        """Shape of the per-bucket local state threaded through one round,
        or None for stateless codecs.  State never travels on the wire."""
        return None

    def init_state(self, d: int, cfg: t.CompressionConfig):
        """Zero state for a d-vector bucket (None for stateless codecs)."""
        shp = self.state_shape(d, cfg)
        return None if shp is None else jnp.zeros(shp, jnp.float32)

    def mean_flat_stateful(self, flat, state, key, cfg: t.CompressionConfig):
        """One stateful round: returns (mean_estimate, new_state).

        Default: stateless codecs ignore and pass the state through, so
        every codec is drivable through this one entry point.
        """
        return self.mean_flat(flat, key, cfg), state

    # ---- the collective --------------------------------------------------- #

    def mean_flat(self, flat, key, cfg: t.CompressionConfig):
        """Estimate mean(flat) over cfg.axes; must run inside shard_map.

        Gather codecs run the star protocol (§2/§4.4) — one all_gather of
        the packed buffer per call, decode locally.  "psum" codecs pmean
        the packed buffer and decode the reduced wire.
        """
        d = flat.shape[0]
        rank, n = axis_rank_size(cfg.axes)
        buf = self.pack(flat, key, rank, cfg)
        if self.reduce == "psum":
            wire = jax.lax.pmean(buf, cfg.axes)
            return self.decode_reduced(wire, key, cfg, d)
        rows = gather_nested(buf, cfg.axes).reshape(n, buf.shape[0])
        return self.decode_gathered(rows, key, cfg, d, n)

    def mean(self, x, key, cfg: t.CompressionConfig):
        """Shape/dtype-preserving wrapper around :meth:`mean_flat`."""
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1).astype(jnp.float32)
        y = self.mean_flat(flat, key, cfg)
        return y.reshape(shape).astype(dtype)

    def __repr__(self):
        return f"<WireCodec {self.name} reduce={self.reduce}>"
