"""WireCodec: the unit of the wire layer (docs/DESIGN.md §3).

The paper's protocols differ only in *what one node puts on the wire* and
*how peers decode it*; everything else — the star-gather scaffold, bucket
planning, bit accounting, benchmark sweeps — is protocol-independent.  A
:class:`WireCodec` captures exactly that per-protocol surface:

  * ``pack(flat, key, rank, cfg)``    — one node's wire buffer (any dtype);
  * ``unpack(row, peer, key, cfg, d)``— reconstruct peer ``peer``'s dense
    Y_i from its gathered row (regenerating seed-trick supports from
    ``fold_in(key, peer)`` where the protocol allows);
  * ``wire_slots(d, cfg)``            — static buffer length in elements;
  * ``wire_bits(n, d, cfg)``          — exact gathered payload bits for an
    n-node round: what the lowered HLO's collective result shape shows
    (the star-protocol convention the paper's C sums use);
  * ``reduce``                        — "all_gather" (star protocol) or
    "psum" (shared-support / dense-simulation paths whose wire is a plain
    all-reduce).

``mean_flat`` is the collective itself: gather codecs run the star gather
(pack → all_gather over cfg.axes → per-peer decode → average); "psum"
codecs run pack → pmean → ``decode_reduced`` (their wire is the reduced
buffer itself).  ``decode_gathered`` exists as a separate hook so codecs
with a fused decode (fixed-k's scatter-accumulate) keep their exact op
sequence — the refactor from the hand-rolled paths in
repro.core.collectives is bit-identical by construction: same PRNG
fold_in chain, same op order, same HLO.

Stateful codecs (docs/DESIGN.md §8): a codec may thread per-bucket state
through the round — error feedback's residual is the production case
(:mod:`repro.core.wire.ef`).  ``state_shape`` declares the state (None for
the stateless majority), ``init_state`` zeros it, and
``mean_flat_stateful`` is the (estimate, new_state) entry point every
caller that owns state uses (``repro.core.collectives
.compressed_mean_stateful``, ``repro.train.bucketing``).  The default
implementation makes every stateless codec trivially drivable through the
stateful API (state passes through untouched), so the train step has ONE
code path regardless of codec.  State is local by contract: it never
appears in the wire buffer, so the payload accounting below is unchanged
by statefulness (HLO-verified in tests/distributed_checks/ef_wire_check).

Accounting contract (verified by tests/test_wire_registry.py for every
registered codec):  ``comm_cost_bits == wire_bits + seed_bits`` — the
analytic §4 cost splits into bits that physically travel (the gathered
buffer, HLO-measurable) plus bits that ride the implicit PRNG (the §4.4
seed trick: supports/rotations regenerate peer-side from the shared key).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import types as t
from repro.core.wire import robust

Axes = Tuple[str, ...]


def axis_rank_size(axes: Axes):
    """Linear rank of this shard within the compression axes + node count."""
    rank = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        rank = rank * compat.axis_size(ax) + jax.lax.axis_index(ax)
        n *= compat.axis_size(ax)
    return rank, n


def gather_nested(v, axes: Axes):
    """all_gather over possibly-multiple axes, flattening the node dim."""
    out = v[None]
    for ax in reversed(axes):
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
    return out


def scatter_axes(cfg: t.CompressionConfig) -> Axes:
    """The mesh axes a scatter decode shards over (DESIGN.md §11/§12).

    Hierarchical configs shard over the inner (fast, intra-host) axes —
    the decoded-shard all_gather rides a link the accounting treats as
    free.  Flat configs shard over the compression axes themselves: every
    node decodes its own ⌈d/n⌉ coordinate slice of all n peer rows, and
    the shard gather crosses the main mesh (billed by
    :meth:`WireCodec.scatter_bits`).
    """
    return cfg.inner_axes if cfg.inner_axes else cfg.axes


def scatter_shard_len(d: int, nshards: int, align: int = 1) -> int:
    """Length of one scatter-decode shard: ⌈d/nshards⌉ rounded up to ``align``.

    Word-aligned sharding (DESIGN.md §13): packed bit-plane codecs store
    ``align`` coordinates per uint32 word (32 for the 1-bit plane, 16 for
    the ternary 2-bit plane), so shard boundaries snap to word boundaries
    and each node touches only a contiguous word range of every peer's
    plane.  Every shard emits exactly this many coordinates (the tail
    shard zero-padded past d), so the reassembling all_gather concatenates
    fixed-size parts and truncates to d.
    """
    ds = -(-d // nshards)
    return -(-ds // align) * align


def scatter_word_align(cfg: t.CompressionConfig) -> int:
    """Shard alignment (coordinates per indivisible wire word) for cfg.

    1 for the linear codecs (any split works), 32 for the binary 1-bit
    plane, 16 for the ternary 2-bit plane; wrappers delegate to their
    inner codec.  ``scatter_shard_len(d, nshards, scatter_word_align(cfg))``
    is THE shard split every scatter consumer (decode, accounting,
    benchmarks, checks) must agree on.
    """
    from repro.core.wire import registry
    return registry.resolve(cfg).scatter_align(cfg)


def effective_nodes(cfg: t.CompressionConfig, n: int,
                    mesh_sizes=None) -> int:
    """The codec's effective node count: the cross-host group size.

    Flat configs (no ``inner_axes``) compress over all ``n`` nodes and are
    billed for n messages.  Hierarchical configs pre-reduce exactly over
    the inner axes, so only ``n / prod(inner sizes)`` compressed messages
    exist per round — THE node count every accounting consumer
    (:func:`repro.core.comm_cost.cost_config`,
    :func:`repro.train.bucketing.bucket_wire_bits`) must charge, or
    hierarchical presets get billed payload that never crosses the slow
    link.  ``mesh_sizes`` maps axis name → size and is required whenever
    ``cfg.inner_axes`` is non-empty (the flat world size alone cannot
    determine the split).
    """
    if not cfg.inner_axes:
        return int(n)
    if mesh_sizes is None:
        raise ValueError(
            f"config has inner_axes={cfg.inner_axes}: accounting needs "
            "mesh_sizes to derive the cross-host group size")
    m = 1
    for ax in cfg.inner_axes:
        if ax not in mesh_sizes:
            raise ValueError(
                f"inner axis {ax!r} missing from mesh_sizes {mesh_sizes}")
        m *= int(mesh_sizes[ax])
    if m <= 0 or n % m:
        raise ValueError(
            f"world size {n} not divisible by inner-group size {m} "
            f"(inner_axes={cfg.inner_axes}, mesh_sizes={mesh_sizes})")
    return int(n) // m


def center(x, policy: str):
    """The node center μ_i used on the wire (data-independent policies only)."""
    if policy == "zero":
        return jnp.zeros((), jnp.float32)
    if policy == "mean":
        return jnp.mean(x).astype(jnp.float32)
    if policy == "min":
        return jnp.min(x).astype(jnp.float32)
    raise ValueError(f"center policy {policy!r} not supported on the wire "
                     "(optimal centers need the §6 solver — reference path only)")


class WireCodec:
    """One registered wire format; see the module docstring for the contract.

    Subclasses set ``name`` and ``reduce`` and implement the geometry,
    accounting and pack/unpack hooks.  Codecs are stateless: all parameters
    come from the :class:`repro.core.types.CompressionConfig` threaded into
    every call, so a single registered instance serves every bucket/config.
    """

    name: str = "?"
    reduce: str = "all_gather"          # "all_gather" | "psum"
    stateful: bool = False              # True iff state_shape is not None
    # True iff the codec implements decode_gathered_shard — the linear
    # gather decoders whose averaging decode partitions coordinate-wise
    # (fixed_k, bernoulli, and wrappers that delegate to them).
    scatter_supported: bool = False

    # ---- wire geometry & accounting -------------------------------------- #

    def wire_slots(self, d: int, cfg: t.CompressionConfig) -> int:
        """Static length of one node's wire buffer, in buffer elements."""
        raise NotImplementedError

    def wire_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Exact gathered payload bits of one n-node round (HLO-verified)."""
        raise NotImplementedError

    def seed_bits(self, n: int, cfg: t.CompressionConfig) -> float:
        """Bits riding the implicit PRNG instead of the wire (§4.4 seeds)."""
        return 0.0

    def scatter_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Extra collective payload bits a FLAT scatter decode adds.

        Flat-mesh scatter (``cfg.scatter_decode`` with empty
        ``inner_axes``) runs its auxiliary collectives — the decoded-shard
        all_gather and any codec bookkeeping like Bernoulli's per-shard
        support counts — over the main compression axes, so their bytes
        cross the same link as the wire and must be billed
        (:func:`repro.core.comm_cost.cost_config` adds this term).
        Hierarchical scatter shards over the inner (fast) axes and stays
        billed at zero here, matching the §11 convention that intra-host
        traffic is free.  Zero for codecs/configs without flat scatter.
        """
        return 0.0

    def scatter_align(self, cfg: t.CompressionConfig) -> int:
        """Coordinates per indivisible wire word (shard-split alignment).

        Packed-plane codecs override this (32 for 1-bit, 16 for 2-bit
        symbols) so :func:`scatter_shard_len` snaps shard boundaries to
        uint32 word boundaries; wrappers delegate to their inner codec.
        """
        return 1

    def cost_spec(self, d: int, cfg: t.CompressionConfig):
        """(CommSpec, kwargs) mapping this codec onto comm_cost.cost."""
        raise NotImplementedError

    def comm_cost_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Analytic §4 cost via comm_cost.cost — == wire_bits + seed_bits."""
        from repro.core import comm_cost
        spec, kw = self.cost_spec(d, cfg)
        return comm_cost.cost(spec, n=n, d=d, **kw)

    # ---- per-node wire format -------------------------------------------- #

    def pack(self, flat, key, rank, cfg: t.CompressionConfig):
        """Encode the local (d,) f32 vector into one flat wire buffer.

        ``key`` is the shared per-bucket key; protocols with per-node
        randomness fold ``rank`` in themselves (so peers can regenerate
        node i's draws from ``fold_in(key, i)`` alone).
        """
        raise NotImplementedError

    def unpack(self, row, peer, key, cfg: t.CompressionConfig, d: int):
        """Reconstruct peer ``peer``'s dense (d,) f32 Y_i from its row."""
        raise NotImplementedError

    def decode_gathered(self, rows, key, cfg: t.CompressionConfig,
                        d: int, n: int):
        """Averaging decoder over the gathered (n, slots) wire rows.

        Default: Y = (1/n) Σ_i unpack(row_i) — codecs with a fused decode
        (fixed-k scatter-accumulate) override this.
        """
        def body(i, acc):
            return acc + self.unpack(rows[i], i, key, cfg, d)

        acc = jax.lax.fori_loop(0, n, body, jnp.zeros((d,), jnp.float32))
        return acc / n

    def decode_rows(self, rows, key, cfg: t.CompressionConfig,
                    d: int, n: int):
        """The (n, d) stack of per-peer dense reconstructions Y_i.

        The materialized-stack companion of :meth:`decode_gathered`: row i
        is exactly ``unpack(rows[i], i, ...)``.  This is the input of the
        robust decode reductions (DESIGN.md §14, :mod:`repro.core.wire
        .robust`) — order statistics need every peer's value per
        coordinate, so the fused sum-only decoders cannot serve them.
        """
        peers = jnp.arange(n, dtype=jnp.int32)
        return jax.vmap(
            lambda row, i: self.unpack(row, i, key, cfg, d))(rows, peers)

    def decode_rows_shard(self, rows, key, cfg: t.CompressionConfig,
                          d: int, n: int, start, ds: int, nshards: int):
        """One contiguous ``ds``-coordinate window of :meth:`decode_rows`.

        Returns the (n, ds) slice ``decode_rows(...)[:, start:start+ds]``
        with coordinates past d zero-padded (``nshards·ds ≥ d`` by
        :func:`scatter_shard_len`), so robust reductions compose with the
        §12/§13 reduce-scatter decode per coordinate-shard: the shard
        window sees exactly the flat stack's values, and the word-aligned
        shard splits of the bit-plane codecs are honored by the caller
        passing an aligned ``ds``.  ``start`` may be traced (shard·ds).
        """
        pad = nshards * ds - d
        peers = jnp.arange(n, dtype=jnp.int32)

        def one(row, i):
            y = jnp.pad(self.unpack(row, i, key, cfg, d), (0, pad))
            return jax.lax.dynamic_slice(y, (start,), (ds,))
        return jax.vmap(one)(rows, peers)

    def decode_rows_reduce(self, rows, key, cfg: t.CompressionConfig,
                           d: int, n: int, drop_mask=None):
        """Policy-dispatched flat decode over the gathered wire rows.

        THE decode-reduction hook (DESIGN.md §14): ``cfg.decode_policy``
        == "mean" with no ``drop_mask`` takes the codec's fused
        :meth:`decode_gathered` verbatim (bit-identical to the historical
        decode — the golden wire matrix and HLO pins never see this
        branch); any robust policy or a drop mask materializes the
        per-peer stack and runs :func:`repro.core.wire.robust
        .reduce_rows`.  ``drop_mask`` is a traced (n,) 0/1 operand — mask
        changes never recompile — and the masked mean renormalizes by the
        kept count per the ``partial_mean`` contract (NaN on all-dead).
        """
        kind, f = robust.parse_policy(cfg.decode_policy)
        if kind == "mean" and drop_mask is None:
            return self.decode_gathered(rows, key, cfg, d, n)
        stack = self.decode_rows(rows, key, cfg, d, n)
        return robust.reduce_rows(stack, kind, f, drop_mask)

    def decode_gathered_shard(self, rows, key, cfg: t.CompressionConfig,
                              d: int, n: int, shard, nshards: int):
        """One shard of the averaging decode (reduce-scatter decomposition).

        Returns this node's contiguous ``⌈d/nshards⌉``-slice of what
        :meth:`decode_gathered` would return (shard ``shard`` of
        ``nshards``; the last shard is zero-padded past d) — so that
        concatenating the shards in order and truncating to d reproduces
        the flat decode bit-for-bit.  Only codecs whose decode is a
        coordinate-wise sum over peer reconstructions can implement this
        (``scatter_supported``).
        """
        raise NotImplementedError(
            f"codec {self.name!r} does not support scatter_decode")

    def decode_reduced(self, wire, key, cfg: t.CompressionConfig, d: int):
        """Decode the *reduced* wire buffer of a "psum" codec.

        Only "psum" codecs implement this: their collective is a plain
        pmean of the packed buffer, and decoding the reduced buffer IS
        decoding the averaged messages (the decode is linear in the wire
        values).  Applied to one node's un-reduced buffer it reconstructs
        that node's own dense message — which is how the error-feedback
        wrapper obtains local contributions uniformly across reduce kinds.
        """
        raise NotImplementedError

    # ---- codec state (stateless by default; see wire/ef.py) -------------- #

    def state_shape(self, d: int, cfg: t.CompressionConfig):
        """Shape of the per-bucket local state threaded through one round,
        or None for stateless codecs.  State never travels on the wire."""
        return None

    def init_state(self, d: int, cfg: t.CompressionConfig):
        """Zero state for a d-vector bucket (None for stateless codecs)."""
        shp = self.state_shape(d, cfg)
        return None if shp is None else jnp.zeros(shp, jnp.float32)

    def mean_flat_stateful(self, flat, state, key, cfg: t.CompressionConfig,
                           drop_mask=None):
        """One stateful round: returns (mean_estimate, new_state).

        Default: stateless codecs ignore and pass the state through, so
        every codec is drivable through this one entry point.  Like
        :meth:`mean_flat`, the exact inner-axes pre-reduce of the
        hierarchical schedule happens here, before any codec layer runs.
        ``drop_mask`` as in :meth:`mean_flat`.
        """
        if cfg.inner_axes:
            flat = jax.lax.pmean(flat, cfg.inner_axes)
        return self._round_stateful(flat, state, key, cfg, drop_mask)

    # ---- the collective --------------------------------------------------- #

    def mean_flat(self, flat, key, cfg: t.CompressionConfig,
                  drop_mask=None):
        """Estimate mean(flat) over cfg.inner_axes + cfg.axes; must run
        inside shard_map.

        Two-level schedule (docs/DESIGN.md §11): the mean over the inner
        (fast) axes is exact — one pmean before the codec — and the codec
        round runs only across ``cfg.axes``, the slow link.  With empty
        ``inner_axes`` this is the historical flat round, op-for-op.

        ``drop_mask`` (DESIGN.md §14): optional traced (n,) 0/1 alive mask
        over the codec ranks of ``cfg.axes`` (1 = keep).  Dropped peers
        are excluded at decode time — their wire rows still travel (the
        collective shape is static), but the decode renormalizes over the
        kept rows per the ``partial_mean`` contract (NaN on all-dead).
        The mask is a traced operand: changing it never recompiles.  For
        hierarchical configs the drop unit is the cross-host peer — the
        inner (intra-host) pre-reduce is assumed healthy.
        """
        if cfg.inner_axes:
            flat = jax.lax.pmean(flat, cfg.inner_axes)
        return self._round(flat, key, cfg, drop_mask)

    def _round(self, flat, key, cfg: t.CompressionConfig, drop_mask=None):
        """One codec round across cfg.axes (input already inner-reduced).

        Gather codecs run the star protocol (§2/§4.4) — one all_gather of
        the packed buffer per call, decode locally.  "psum" codecs pmean
        the packed buffer and decode the reduced wire; with a drop mask
        the pmean becomes the mask-weighted partial mean of the packed
        buffers (their decode is affine in the wire values, so excluding
        a peer's buffer excludes its message).  Wrapper codecs (rotation,
        error feedback) override THIS hook, not the public entry points,
        so the inner-axes pre-reduce happens exactly once at the
        outermost layer.
        """
        d = flat.shape[0]
        rank, n = axis_rank_size(cfg.axes)
        buf = self.pack(flat, key, rank, cfg)
        if self.reduce == "psum":
            if drop_mask is None:
                wire = jax.lax.pmean(buf, cfg.axes)
            else:
                keep = drop_mask[rank].astype(jnp.float32)
                num = jax.lax.psum(buf.astype(jnp.float32) * keep, cfg.axes)
                den = jax.lax.psum(keep, cfg.axes)
                wire = (num / den).astype(buf.dtype)
            return self.decode_reduced(wire, key, cfg, d)
        return self.gather_decode(buf, key, cfg, d, n, drop_mask)

    def _round_stateful(self, flat, state, key, cfg: t.CompressionConfig,
                        drop_mask=None):
        """Stateful companion of :meth:`_round` (input inner-reduced)."""
        return self._round(flat, key, cfg, drop_mask), state

    def gather_decode(self, buf, key, cfg: t.CompressionConfig,
                      d: int, n: int, drop_mask=None):
        """all_gather the packed buffer over cfg.axes and decode.

        With ``cfg.scatter_decode`` the decode is reduce-scattered over
        :func:`scatter_axes` — the inner axes when present (hierarchical,
        1/m shard each, shard gather rides the fast inner link) or the
        compression axes themselves (flat mesh, ⌈d/n⌉ shard each, shard
        gather billed by :meth:`scatter_bits`).  Each node decodes only
        its contiguous shard and one all_gather of decoded shards
        reassembles the estimate.  Shards concatenate in shard-rank order
        and pads sit past d, so the result equals the flat decode
        bit-for-bit.

        Decode policy (DESIGN.md §14): the plain averaging decode with no
        ``drop_mask`` keeps the codec's fused paths verbatim; a robust
        ``cfg.decode_policy`` or a mask routes through the per-peer row
        stack (:meth:`decode_rows` / :meth:`decode_rows_shard`) and
        :func:`repro.core.wire.robust.reduce_rows`.  The robust scatter
        branch applies the reduction per coordinate-shard — coordinate-
        wise order statistics partition exactly like the averaging
        decode, so the §12/§13 word-aligned shard windows survive and the
        composed result equals the flat robust decode bit-for-bit.
        """
        rows = gather_nested(buf, cfg.axes).reshape(n, buf.shape[0])
        kind, f = robust.parse_policy(cfg.decode_policy)
        if cfg.scatter_decode:
            saxes = scatter_axes(cfg)
            shard, nshards = axis_rank_size(saxes)
            if kind == "mean" and drop_mask is None:
                part = self.decode_gathered_shard(rows, key, cfg, d, n,
                                                  shard, nshards)
            else:
                ds = scatter_shard_len(d, nshards, self.scatter_align(cfg))
                stack = self.decode_rows_shard(rows, key, cfg, d, n,
                                               shard * ds, ds, nshards)
                part = robust.reduce_rows(stack, kind, f, drop_mask)
            full = gather_nested(part, saxes).reshape(-1)
            return full[:d]
        return self.decode_rows_reduce(rows, key, cfg, d, n, drop_mask)

    def mean(self, x, key, cfg: t.CompressionConfig, drop_mask=None):
        """Shape/dtype-preserving wrapper around :meth:`mean_flat`."""
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1).astype(jnp.float32)
        y = self.mean_flat(flat, key, cfg, drop_mask)
        return y.reshape(shape).astype(dtype)

    def __repr__(self):
        return f"<WireCodec {self.name} reduce={self.reduce}>"
