"""WireCodec: the unit of the wire layer (docs/DESIGN.md §3).

The paper's protocols differ only in *what one node puts on the wire* and
*how peers decode it*; everything else — the star-gather scaffold, bucket
planning, bit accounting, benchmark sweeps — is protocol-independent.  A
:class:`WireCodec` captures exactly that per-protocol surface:

  * ``pack(flat, key, rank, cfg)``    — one node's wire buffer (any dtype);
  * ``unpack(row, peer, key, cfg, d)``— reconstruct peer ``peer``'s dense
    Y_i from its gathered row (regenerating seed-trick supports from
    ``fold_in(key, peer)`` where the protocol allows);
  * ``wire_slots(d, cfg)``            — static buffer length in elements;
  * ``wire_bits(n, d, cfg)``          — exact gathered payload bits for an
    n-node round: what the lowered HLO's collective result shape shows
    (the star-protocol convention the paper's C sums use);
  * ``reduce``                        — "all_gather" (star protocol) or
    "psum" (shared-support / dense-simulation paths whose wire is a plain
    all-reduce).

``mean_flat`` is the collective itself: the default implementation is the
star gather (pack → all_gather over cfg.axes → per-peer decode → average),
which "psum" codecs override wholesale.  ``decode_gathered`` exists as a
separate hook so codecs with a fused decode (fixed-k's scatter-accumulate)
keep their exact op sequence — the refactor from the hand-rolled paths in
repro.core.collectives is bit-identical by construction: same PRNG
fold_in chain, same op order, same HLO.

Accounting contract (verified by tests/test_wire_registry.py for every
registered codec):  ``comm_cost_bits == wire_bits + seed_bits`` — the
analytic §4 cost splits into bits that physically travel (the gathered
buffer, HLO-measurable) plus bits that ride the implicit PRNG (the §4.4
seed trick: supports/rotations regenerate peer-side from the shared key).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import types as t

Axes = Tuple[str, ...]


def axis_rank_size(axes: Axes):
    """Linear rank of this shard within the compression axes + node count."""
    rank = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        rank = rank * compat.axis_size(ax) + jax.lax.axis_index(ax)
        n *= compat.axis_size(ax)
    return rank, n


def gather_nested(v, axes: Axes):
    """all_gather over possibly-multiple axes, flattening the node dim."""
    out = v[None]
    for ax in reversed(axes):
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
    return out


def center(x, policy: str):
    """The node center μ_i used on the wire (data-independent policies only)."""
    if policy == "zero":
        return jnp.zeros((), jnp.float32)
    if policy == "mean":
        return jnp.mean(x).astype(jnp.float32)
    if policy == "min":
        return jnp.min(x).astype(jnp.float32)
    raise ValueError(f"center policy {policy!r} not supported on the wire "
                     "(optimal centers need the §6 solver — reference path only)")


class WireCodec:
    """One registered wire format; see the module docstring for the contract.

    Subclasses set ``name`` and ``reduce`` and implement the geometry,
    accounting and pack/unpack hooks.  Codecs are stateless: all parameters
    come from the :class:`repro.core.types.CompressionConfig` threaded into
    every call, so a single registered instance serves every bucket/config.
    """

    name: str = "?"
    reduce: str = "all_gather"          # "all_gather" | "psum"

    # ---- wire geometry & accounting -------------------------------------- #

    def wire_slots(self, d: int, cfg: t.CompressionConfig) -> int:
        """Static length of one node's wire buffer, in buffer elements."""
        raise NotImplementedError

    def wire_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Exact gathered payload bits of one n-node round (HLO-verified)."""
        raise NotImplementedError

    def seed_bits(self, n: int, cfg: t.CompressionConfig) -> float:
        """Bits riding the implicit PRNG instead of the wire (§4.4 seeds)."""
        return 0.0

    def cost_spec(self, d: int, cfg: t.CompressionConfig):
        """(CommSpec, kwargs) mapping this codec onto comm_cost.cost."""
        raise NotImplementedError

    def comm_cost_bits(self, n: int, d: int, cfg: t.CompressionConfig) -> float:
        """Analytic §4 cost via comm_cost.cost — == wire_bits + seed_bits."""
        from repro.core import comm_cost
        spec, kw = self.cost_spec(d, cfg)
        return comm_cost.cost(spec, n=n, d=d, **kw)

    # ---- per-node wire format -------------------------------------------- #

    def pack(self, flat, key, rank, cfg: t.CompressionConfig):
        """Encode the local (d,) f32 vector into one flat wire buffer.

        ``key`` is the shared per-bucket key; protocols with per-node
        randomness fold ``rank`` in themselves (so peers can regenerate
        node i's draws from ``fold_in(key, i)`` alone).
        """
        raise NotImplementedError

    def unpack(self, row, peer, key, cfg: t.CompressionConfig, d: int):
        """Reconstruct peer ``peer``'s dense (d,) f32 Y_i from its row."""
        raise NotImplementedError

    def decode_gathered(self, rows, key, cfg: t.CompressionConfig,
                        d: int, n: int):
        """Averaging decoder over the gathered (n, slots) wire rows.

        Default: Y = (1/n) Σ_i unpack(row_i) — codecs with a fused decode
        (fixed-k scatter-accumulate) override this.
        """
        def body(i, acc):
            return acc + self.unpack(rows[i], i, key, cfg, d)

        acc = jax.lax.fori_loop(0, n, body, jnp.zeros((d,), jnp.float32))
        return acc / n

    # ---- the collective --------------------------------------------------- #

    def mean_flat(self, flat, key, cfg: t.CompressionConfig):
        """Estimate mean(flat) over cfg.axes; must run inside shard_map.

        Default: the star protocol (§2/§4.4) — one all_gather of the packed
        buffer per call, decode locally.  "psum" codecs override.
        """
        d = flat.shape[0]
        rank, n = axis_rank_size(cfg.axes)
        buf = self.pack(flat, key, rank, cfg)
        rows = gather_nested(buf, cfg.axes).reshape(n, buf.shape[0])
        return self.decode_gathered(rows, key, cfg, d, n)

    def mean(self, x, key, cfg: t.CompressionConfig):
        """Shape/dtype-preserving wrapper around :meth:`mean_flat`."""
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1).astype(jnp.float32)
        y = self.mean_flat(flat, key, cfg)
        return y.reshape(shape).astype(dtype)

    def __repr__(self):
        return f"<WireCodec {self.name} reduce={self.reduce}>"
