"""The five production wire codecs (docs/DESIGN.md §3).

Each class below is the codec-registry form of a wire path that previously
lived as a hand-rolled function in :mod:`repro.core.collectives`; the PRNG
fold_in chains and op sequences are preserved exactly, so the refactor is
bit-identical (same estimates, same lowered HLO — verified by
tests/distributed_checks/quantized_wire_check.py and bucketing_check.py):

  * ``fixed_k``        — §4.4 Eq. (9) gather path: block-structured fixed-k
    values + μ tail; supports regenerate from fold_in(key, peer).
  * ``fixed_k_shared`` — TPU-native shared-support variant: one psum of the
    k-length value buffer (reduce kind "psum").
  * ``bernoulli``      — §4.4 Eq. (10) seed trick with capacity-padded
    value buffers (comm_cost.bernoulli_capacity).
  * ``binary``         — §4.5 Eq. (11) packed 1-bit sign plane
    (repro.core.bitplane), no seed term: the plane travels.
  * ``ternary``        — §7.1 Eq. (21) packed 2-bit plane + capacity-padded
    pass-through values.
  * ``ternary_opt``    — the §6 optimal per-coordinate (p1, p2) split on the
    same 2-bit plane and capacity rule (repro.core.optimal
    .ternary_optimal_probs).
  * ``dense``          — dense simulation: encode per node, exact pmean of
    the dense encodings (any encoder incl. the §6 optimal policies; charged
    naive f32 bits — the wire it actually rides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core import comm_cost
from repro.core import encoders
from repro.core import types as t
from repro.core.wire import base
from repro.kernels.bernoulli_wire import ops as bw_ops
from repro.kernels.fixed_k_encode import ops as fk


def _wire_r(cfg: t.CompressionConfig) -> int:
    """r: bits per wire float (16 for bf16, 32 for f32)."""
    return bitplane.wire_bits(cfg.wire_dtype)


def _seed_spec(cfg: t.CompressionConfig) -> t.CommSpec:
    """CommSpec of the §4.4 seed-trick paths at the configured wire dtype:
    the μ tail slot travels at wire precision (r̄ = r)."""
    r = _wire_r(cfg)
    return t.CommSpec(protocol="sparse_seed", r_bits=r, rbar_bits=r,
                      rseed_bits=t.DEFAULT_RSEED_BITS)


# --------------------------------------------------------------------------- #
# fixed-k (block-structured) — gather + shared-support variants.
# --------------------------------------------------------------------------- #

def fixed_k_blocks(d: int, fraction: float) -> int:
    """kb: number of sampled blocks for a d-vector at the given fraction."""
    nb = fk.num_blocks(d)
    return max(1, min(nb, int(round(fraction * nb))))


def fixed_k_wire_slots(d: int, fraction: float) -> int:
    """Wire-dtype elements of one fixed-k gather buffer: kb·BLOCK values + μ."""
    return fixed_k_blocks(d, fraction) * fk.BLOCK + 1


def fixed_k_pack(flat, key, cfg, *, scale=None):
    """THE fixed-k wire buffer: [kb·BLOCK values ‖ μ] at the wire dtype.

    ``key`` is the support seed exactly as sampled (the gather codec folds
    the rank in, the shared codec does not).  ``scale=None`` is the
    unbiased Eq. (4) rescale; ``scale=1.0`` the contractive scale-1 values
    of the error-feedback twin (repro.core.wire.ef) — same layout either
    way, so the codecs' unpack/decode hooks decode both.
    """
    d = flat.shape[0]
    nb = fk.num_blocks(d)
    kb = fixed_k_blocks(d, cfg.encoder.fraction)
    ids = fk.sample_blocks(key, nb, kb)
    mu = base.center(flat, cfg.encoder.center)
    vals = fk.fixed_k_encode(flat, ids, mu, scale=scale)
    return jnp.concatenate([vals.reshape(-1), mu[None]]).astype(
        cfg.wire_dtype)


class FixedKGatherCodec(base.WireCodec):
    """gather_decode fixed-k: independent supports, [values ‖ μ] per node.

    Wire per node: kb·BLOCK + 1 wire-dtype elements — the star protocol
    §4.4 with implicit seeds.  Decode regenerates every peer's support
    locally and averages the dense reconstructions:
    Y = mean μ_i + (1/n) Σ_i scatter(ids_i, vals_i).
    """

    name = "fixed_k"
    scatter_supported = True

    def wire_slots(self, d, cfg):
        return fixed_k_wire_slots(d, cfg.encoder.fraction)

    def wire_bits(self, n, d, cfg):
        return float(n * self.wire_slots(d, cfg) * _wire_r(cfg))

    def seed_bits(self, n, cfg):
        return float(n * t.DEFAULT_RSEED_BITS)

    def cost_spec(self, d, cfg):
        k = fixed_k_blocks(d, cfg.encoder.fraction) * fk.BLOCK
        return _seed_spec(cfg), {"k": k}

    def pack(self, flat, key, rank, cfg):
        return fixed_k_pack(flat, jax.random.fold_in(key, rank), cfg)

    def unpack(self, row, peer, key, cfg, d):
        row = row.astype(jnp.float32)
        nb = fk.num_blocks(d)
        kb = fixed_k_blocks(d, cfg.encoder.fraction)
        ids = fk.sample_blocks(jax.random.fold_in(key, peer), nb, kb)
        vals = row[:-1].reshape(kb, fk.BLOCK)
        dense = jnp.zeros((nb, fk.BLOCK), jnp.float32).at[ids].add(vals)
        return dense.reshape(-1)[:d] + row[-1]

    def decode_gathered(self, rows, key, cfg, d, n):
        # fused scatter-accumulate decode (one (nb, BLOCK) accumulator
        # instead of n dense intermediates) — the original op sequence.
        rows = rows.astype(jnp.float32)
        nb = fk.num_blocks(d)
        kb = fixed_k_blocks(d, cfg.encoder.fraction)
        all_vals = rows[:, :-1].reshape(n, kb, fk.BLOCK)
        all_mu = rows[:, -1]

        def body(i, acc):
            ids_i = fk.sample_blocks(jax.random.fold_in(key, i), nb, kb)
            return acc.at[ids_i].add(all_vals[i])

        acc = jax.lax.fori_loop(0, n, body,
                                jnp.zeros((nb, fk.BLOCK), jnp.float32))
        return (acc / n + jnp.mean(all_mu)).reshape(-1)[:d]

    def decode_gathered_shard(self, rows, key, cfg, d, n, shard, nshards):
        # reduce-scatter decomposition: accumulate only the blocks in this
        # node's contiguous ⌈nb/nshards⌉-block window.  Out-of-window ids
        # land in a dump row that is sliced off, so every in-window block
        # receives exactly the flat decode's adds in the same peer order —
        # the concatenated shards equal decode_gathered bit-for-bit.
        rows = rows.astype(jnp.float32)
        nb = fk.num_blocks(d)
        kb = fixed_k_blocks(d, cfg.encoder.fraction)
        nb_s = -(-nb // nshards)
        all_vals = rows[:, :-1].reshape(n, kb, fk.BLOCK)
        all_mu = rows[:, -1]
        lo = shard * nb_s

        def body(i, acc):
            ids_i = fk.sample_blocks(jax.random.fold_in(key, i), nb, kb)
            loc = ids_i - lo
            loc = jnp.where((loc >= 0) & (loc < nb_s), loc, nb_s)
            return acc.at[loc].add(all_vals[i])

        acc = jax.lax.fori_loop(0, n, body,
                                jnp.zeros((nb_s + 1, fk.BLOCK), jnp.float32))
        return (acc[:nb_s] / n + jnp.mean(all_mu)).reshape(-1)

    def scatter_bits(self, n, d, cfg):
        # flat scatter (DESIGN.md §12) adds ONE collective on the main
        # axes: the decoded f32 shard all_gather (the dump-row window is
        # analytic — no count exchange).  Hierarchical scatter rides the
        # inner axes and is billed free (§11 convention).
        if not cfg.scatter_decode or cfg.inner_axes:
            return 0.0
        nb_s = -(-fk.num_blocks(d) // n)
        return float(n * nb_s * fk.BLOCK * 32)


class FixedKSharedCodec(base.WireCodec):
    """shared_support fixed-k: one psum of [k wire values ‖ μ] + scatter.

    All nodes draw the *same* support (shared seed: ``key`` is not
    rank-folded), so the averaged wire values ride a plain psum —
    ring-bandwidth optimal.  MSE closed form:
    :func:`repro.core.mse.mse_fixed_k_shared`.
    """

    name = "fixed_k_shared"
    reduce = "psum"

    def wire_slots(self, d, cfg):
        return fixed_k_wire_slots(d, cfg.encoder.fraction)

    def wire_bits(self, n, d, cfg):
        # star-payload convention: n × the reduced buffer (what each node
        # contributes), matching the all-reduce payload accounting in
        # benchmarks/bench_collectives.py.
        return float(n * self.wire_slots(d, cfg) * _wire_r(cfg))

    def seed_bits(self, n, cfg):
        # Eq. (9) charges r̄_s per node; our SPMD realization shares one
        # seed (the per-step key), so this is the faithful-protocol bound.
        return float(n * t.DEFAULT_RSEED_BITS)

    def cost_spec(self, d, cfg):
        k = fixed_k_blocks(d, cfg.encoder.fraction) * fk.BLOCK
        return _seed_spec(cfg), {"k": k}

    def pack(self, flat, key, rank, cfg):
        # shared support: ``key`` is deliberately NOT rank-folded — every
        # node draws the same subset, so the wire values average under a
        # plain psum.  The psum runs at the wire dtype (r = 16
        # bits/coordinate, matching the paper's r and the bf16-native TPU
        # all-reduce); μ rides the tail slot so the bucket still costs one
        # launch.
        return fixed_k_pack(flat, key, cfg)

    def decode_reduced(self, wire, key, cfg, d):
        wire = wire.astype(jnp.float32)
        nb = fk.num_blocks(d)
        kb = fixed_k_blocks(d, cfg.encoder.fraction)
        ids = fk.sample_blocks(key, nb, kb)
        gvals = wire[:-1].reshape(-1, fk.BLOCK)
        return fk.fixed_k_decode(gvals, ids, wire[-1], (d,))

    def unpack(self, row, peer, key, cfg, d):
        # shared support ⇒ decoding one node's un-reduced buffer is peer-
        # independent: it reconstructs that node's own dense message.
        return self.decode_reduced(row, key, cfg, d)


# --------------------------------------------------------------------------- #
# Bernoulli (variable-size-support) — the §4.4 seed trick.
# --------------------------------------------------------------------------- #

def bernoulli_wire_slots(d: int, fraction: float) -> int:
    """Wire-dtype elements of one §4.4 Bernoulli buffer: cap values + μ."""
    return comm_cost.bernoulli_capacity(d, float(fraction)) + 1


def _bernoulli_support(key, d: int, p):
    """The S_i of Eq. (1) under uniform probs: data-independent, so any peer
    regenerates it from the shared per-step key + node index alone."""
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    return u < p


def bernoulli_pack(flat, key, p: float, cap: int, mu, *, scaled=True):
    """Compact the Eq. (1) encoding into a (cap,) value buffer.

    Sent coordinates land at their support-rank position; coordinates whose
    rank overflows ``cap`` (≈6σ tail, see comm_cost.bernoulli_capacity) are
    dropped — the decoder regenerates the same ranks and drops them too, so
    encode/decode stay consistent (cost: a ~1e-9-probability bias toward μ
    on the dropped coordinates).  ``scaled=False`` ships the raw values
    instead of the unbiased 1/p rescale — the error-feedback twin
    (repro.core.wire.ef); the layout is identical, so
    :func:`bernoulli_unpack` decodes both.

    Dispatches through :mod:`repro.kernels.bernoulli_wire` — the fused
    sample+select+rank-compact Pallas kernel on TPU, the byte-identical jnp
    reference elsewhere (golden wire matrix pins the bytes).
    """
    return bw_ops.encode(flat, key, p, cap, mu, scaled=scaled)


def bernoulli_unpack(buf, key, p: float, cap: int, mu, d: int):
    """Regenerate node ``key``'s support and reconstruct its dense Y_i."""
    sent = _bernoulli_support(key, d, p)
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    valid = sent & (pos < cap)
    vals = buf[jnp.clip(pos, 0, cap - 1)]
    return jnp.where(valid, vals, mu)


def bernoulli_buffer(flat, key, rank, cfg, *, scaled=True):
    """THE §4.4 Bernoulli wire buffer: [cap value slots ‖ μ] at wire dtype
    (support from fold_in(key, rank); ``scaled`` as in bernoulli_pack)."""
    d = flat.shape[0]
    p = float(cfg.encoder.fraction)
    cap = comm_cost.bernoulli_capacity(d, p)
    kenc = jax.random.fold_in(key, rank)
    mu = base.center(flat, cfg.encoder.center)
    buf = bernoulli_pack(flat, kenc, p, cap, mu, scaled=scaled)
    return jnp.concatenate([buf, mu[None]]).astype(cfg.wire_dtype)


class BernoulliCodec(base.WireCodec):
    """gather_decode for the uniform-p Bernoulli encoder, real §4.4 wire.

    Each node all_gathers one [cap value slots ‖ μ] buffer; peers
    regenerate the supports from fold_in(key, peer).  Bit accounting:
    comm_cost.cost_sparse_seed_capacity — the static-shape realization of
    Eq. (10).
    """

    name = "bernoulli"
    scatter_supported = True

    def wire_slots(self, d, cfg):
        return bernoulli_wire_slots(d, cfg.encoder.fraction)

    def wire_bits(self, n, d, cfg):
        return float(n * self.wire_slots(d, cfg) * _wire_r(cfg))

    def seed_bits(self, n, cfg):
        return float(n * t.DEFAULT_RSEED_BITS)

    def cost_spec(self, d, cfg):
        cap = comm_cost.bernoulli_capacity(d, float(cfg.encoder.fraction))
        return _seed_spec(cfg), {"cap": cap}

    def pack(self, flat, key, rank, cfg):
        return bernoulli_buffer(flat, key, rank, cfg)

    def unpack(self, row, peer, key, cfg, d):
        p = float(cfg.encoder.fraction)
        cap = comm_cost.bernoulli_capacity(d, p)
        row = row.astype(jnp.float32)
        return bernoulli_unpack(row[:-1], jax.random.fold_in(key, peer),
                                p, cap, row[-1], d)

    def decode_gathered(self, rows, key, cfg, d, n):
        # fused regenerate+unpack+accumulate: all peer supports in one
        # batched Threefry dispatch (CPU) or one Pallas kernel folding the
        # n buffers straight into a single (d,) accumulator (TPU) — never
        # n dense per-peer reconstructions.  Same estimate as the default
        # sequential fori decode up to summation order.
        p = float(cfg.encoder.fraction)
        cap = comm_cost.bernoulli_capacity(d, p)
        rows = rows.astype(jnp.float32)
        keys = jnp.stack([jax.random.fold_in(key, i) for i in range(n)])
        total = bw_ops.decode_sum(rows[:, :-1], rows[:, -1], keys,
                                  p, cap, d)
        return total / n

    def decode_gathered_shard(self, rows, key, cfg, d, n, shard, nshards):
        # reduce-scatter decomposition.  Support ranks are global (a sent
        # coordinate's value slot is its rank in the FULL support), so each
        # shard needs every peer's support count strictly before its
        # window: per-shard counts are all_gathered over the scatter axes
        # (inner when hierarchical, the main axes on the flat mesh) and
        # exclusive-cumsummed.  Shard supports regenerate via scattered
        # Threefry lanes (threefry.ref.uniform_at): only d/nshards draws
        # per peer instead of d, which is where the O(n·d) → O(n·d/m)
        # decode win comes from.
        p = float(cfg.encoder.fraction)
        cap = comm_cost.bernoulli_capacity(d, p)
        rows = rows.astype(jnp.float32)
        keys = jnp.stack([jax.random.fold_in(key, i) for i in range(n)])
        ds = -(-d // nshards)
        start = shard * ds
        sent = bw_ops.support_shard(keys, p, d, start, ds)
        counts = jnp.sum(sent.astype(jnp.int32), axis=1)
        allc = base.gather_nested(
            counts, base.scatter_axes(cfg)).reshape(nshards, n)
        prior = jnp.cumsum(allc, axis=0) - allc
        prior_here = jnp.take(prior, shard, axis=0)
        total = bw_ops.decode_sum_shard(rows[:, :-1], rows[:, -1], keys,
                                        sent, prior_here, start,
                                        p=p, cap=cap, d=d)
        return total / n

    def scatter_bits(self, n, d, cfg):
        # flat scatter (DESIGN.md §12) adds TWO collectives on the main
        # axes: the per-shard support counts (n i32 per node — the global
        # rank offsets) and the decoded f32 shard all_gather.
        # Hierarchical scatter rides the inner axes and is billed free
        # (§11 convention).
        if not cfg.scatter_decode or cfg.inner_axes:
            return 0.0
        ds = -(-d // n)
        return float(n * n * 32 + n * ds * 32)


# --------------------------------------------------------------------------- #
# Binary / ternary packed bit-plane codecs (§4.5 / §7.1).
# --------------------------------------------------------------------------- #

class BinaryCodec(base.WireCodec):
    """gather_decode for binary quantization with the packed 1-bit plane.

    Each node all_gathers one uint32 buffer of [sign plane ‖ vmin, vmax]
    (:mod:`repro.core.bitplane`).  No seed term: the branch choices are
    data-dependent, so the plane travels explicitly.
    """

    name = "binary"
    scatter_supported = True

    def wire_slots(self, d, cfg):
        return bitplane.binary_wire_words(d, cfg.wire_dtype)

    def wire_bits(self, n, d, cfg):
        return float(n * 32 * self.wire_slots(d, cfg))

    def cost_spec(self, d, cfg):
        return (t.CommSpec(protocol="binary", r_bits=_wire_r(cfg)),
                {"packed": True})

    def pack(self, flat, key, rank, cfg):
        return bitplane.binary_pack(flat, jax.random.fold_in(key, rank),
                                    cfg.wire_dtype)

    def unpack(self, row, peer, key, cfg, d):
        return bitplane.binary_unpack(row, d, cfg.wire_dtype)

    def scatter_align(self, cfg):
        return bitplane.BINARY_ALIGN

    def decode_gathered_shard(self, rows, key, cfg, d, n, shard, nshards):
        # reduce-scatter decomposition (DESIGN.md §13): shard boundaries
        # snap to uint32 word boundaries of the 1-bit plane (32
        # coords/word), so each node reads only its contiguous word window
        # of every peer's plane — one fused unpack+center-select+accumulate
        # pass (kernels/bitplane binary_accum) over the n×(ds/32) window.
        ds = base.scatter_shard_len(d, nshards, bitplane.BINARY_ALIGN)
        total = bitplane.binary_decode_shard(rows, d, cfg.wire_dtype,
                                             shard * ds, ds, nshards)
        return total / n

    def scatter_bits(self, n, d, cfg):
        # flat scatter adds ONE collective on the main axes: the decoded
        # f32 shard all_gather (no bookkeeping exchange — the plane itself
        # travels, so peers need no rank offsets).  Hierarchical scatter
        # rides the inner axes and is billed free (§11 convention).
        if not cfg.scatter_decode or cfg.inner_axes:
            return 0.0
        ds = base.scatter_shard_len(d, n, bitplane.BINARY_ALIGN)
        return float(n * ds * 32)


class TernaryCodec(base.WireCodec):
    """gather_decode for the ternary encoder (Eq. (21)) with a 2-bit plane.

    Wire per node: [2-bit branch plane ‖ cap pass-through value slots ‖
    c1, c2] in one uint32 buffer; the value segment is capacity-padded
    exactly like the Bernoulli §4.4 path.
    """

    name = "ternary"
    scatter_supported = True

    def _cap(self, d, cfg):
        return comm_cost.bernoulli_capacity(d, float(cfg.encoder.fraction))

    def wire_slots(self, d, cfg):
        return bitplane.ternary_wire_words(d, self._cap(d, cfg),
                                           cfg.wire_dtype)

    def wire_bits(self, n, d, cfg):
        return float(n * 32 * self.wire_slots(d, cfg))

    def cost_spec(self, d, cfg):
        return (t.CommSpec(protocol="ternary", r_bits=_wire_r(cfg)),
                {"packed": True, "cap": self._cap(d, cfg)})

    def pack(self, flat, key, rank, cfg):
        d = flat.shape[0]
        return bitplane.ternary_pack(flat, jax.random.fold_in(key, rank),
                                     float(cfg.encoder.fraction),
                                     self._cap(d, cfg), cfg.wire_dtype)

    def unpack(self, row, peer, key, cfg, d):
        return bitplane.ternary_unpack(row, d, self._cap(d, cfg),
                                       cfg.wire_dtype)

    def scatter_align(self, cfg):
        return bitplane.TERNARY_ALIGN

    def decode_gathered_shard(self, rows, key, cfg, d, n, shard, nshards):
        # reduce-scatter decomposition (DESIGN.md §13).  Shard boundaries
        # snap to 2-bit-plane word boundaries (16 coords/word).  Pass-
        # through value slots are addressed by GLOBAL support rank, so —
        # exactly like BernoulliCodec — each shard needs every peer's
        # pass-through count strictly before its window: per-shard counts
        # are all_gathered over the scatter axes and exclusive-cumsummed
        # into rank offsets.  Unlike Bernoulli there is no support to
        # regenerate: the counts come straight from the shard's own symbol
        # window.
        ds = base.scatter_shard_len(d, nshards, bitplane.TERNARY_ALIGN)
        start = shard * ds
        cap = self._cap(d, cfg)
        syms = bitplane.ternary_shard_syms(rows, d, start, ds, nshards)
        counts = jnp.sum((syms == 2).astype(jnp.int32), axis=1)
        allc = base.gather_nested(
            counts, base.scatter_axes(cfg)).reshape(nshards, n)
        prior = jnp.cumsum(allc, axis=0) - allc
        prior_here = jnp.take(prior, shard, axis=0)
        total = bitplane.ternary_decode_shard(rows, syms, prior_here, d,
                                              cap, cfg.wire_dtype, start)
        return total / n

    def scatter_bits(self, n, d, cfg):
        # flat scatter adds TWO collectives on the main axes: the
        # per-shard pass-through counts (n i32 per node — the global rank
        # offsets) and the decoded f32 shard all_gather.
        if not cfg.scatter_decode or cfg.inner_axes:
            return 0.0
        ds = base.scatter_shard_len(d, n, bitplane.TERNARY_ALIGN)
        return float(n * n * 32 + n * ds * 32)


class TernaryOptCodec(TernaryCodec):
    """gather_decode for the §6-optimal ternary encoder (probs="optimal").

    Per-coordinate optimal (p1, p2) — :func:`repro.core.optimal
    .ternary_optimal_probs`, the §6 "optimal parameters" move applied to
    the Eq. (21) plane — on the *same* wire format as ``ternary``: the
    branch probabilities are data-dependent, but the realized branch
    choices ride the 2-bit plane (which travels anyway), so the decoder
    never needs them.  The pass-through mass stays exactly
    Bernoulli(fraction) per coordinate under the optimal split, so the 6σ
    capacity rule, wire_slots/wire_bits and cost_spec are all inherited
    from :class:`TernaryCodec` unchanged — this codec is honestly
    wire-modelled, unlike the §6 Bernoulli optimal-probability policies
    (whose supports are implicit and still fall back to ``dense``).
    """

    name = "ternary_opt"

    def pack(self, flat, key, rank, cfg):
        d = flat.shape[0]
        return bitplane.ternary_pack(flat, jax.random.fold_in(key, rank),
                                     float(cfg.encoder.fraction),
                                     self._cap(d, cfg), cfg.wire_dtype,
                                     probs="optimal")


# --------------------------------------------------------------------------- #
# Dense simulation (any encoder) — the accounting-honest fallback.
# --------------------------------------------------------------------------- #

class DenseSimCodec(base.WireCodec):
    """Encode locally (independent), exact pmean of the dense encodings.

    Estimate-distribution-identical to gather_decode; supports every
    encoder (incl. the §6 optimal-probability policies, whose message
    sizes are data-dependent and not wire-modelled yet).  Charged naive
    dense f32 bits — the wire it actually rides.

    The wire is PINNED to float32: ``pack`` casts to f32 regardless of
    ``cfg.wire_dtype`` (a narrower psum buffer would change the reduce
    arithmetic and silently break estimate-distribution equality with
    gather_decode), and ``wire_bits`` charges the matching 32 bits/slot.
    ``cfg.wire_dtype`` therefore deliberately does NOT apply here; the
    contract is pinned by tests/test_dense_codec_contract.py.
    """

    name = "dense"
    reduce = "psum"

    #: the psum wire's element width in bits — always f32, see class doc.
    WIRE_BITS_PER_SLOT = 32

    def wire_slots(self, d, cfg):
        return d

    def wire_bits(self, n, d, cfg):
        # intentionally ignores cfg.wire_dtype: the buffer pack() emits is
        # f32 whatever the config says, and accounting follows the bytes.
        return float(n * d * self.WIRE_BITS_PER_SLOT)

    def cost_spec(self, d, cfg):
        return t.CommSpec(protocol="naive", r_bits=32), {}

    def pack(self, flat, key, rank, cfg):
        kenc = jax.random.fold_in(key, rank)
        return encoders.encode(kenc, flat, cfg.encoder).y.astype(jnp.float32)

    def decode_reduced(self, wire, key, cfg, d):
        return wire

    def unpack(self, row, peer, key, cfg, d):
        return row.astype(jnp.float32)
