"""Composable §7.2 pre-transform: seeded per-bucket Hadamard rotation.

:class:`RotatedCodec` wraps *any* registered :class:`~repro.core.wire.base
.WireCodec`: the bucket vector is rotated once by Q = (1/√d)HD before the
inner codec encodes, and the averaging decode is unrotated once at the end
— valid because averaging commutes with the (linear, orthogonal) Q, so

    E‖Qᵀ z̄ − X̄‖² = E‖z̄ − Q X̄‖²,

i.e. conditional on the rotation seed the composed protocol's MSE is the
inner codec's closed form evaluated at the rotated data (the §7.2
composition rule; see repro.core.mse.mse_rotated).  Rotation spreads the
information of spiky/anisotropic vectors evenly across coordinates, which
is exactly the regime where the min/max-bracketed quantizers (binary,
ternary) and uniform-support sparsifiers are at their worst — this is the
backbone of Suresh et al.'s rotated one-bit estimator and of DRIVE.

Wire overhead is **seed-only**: Q is identified by one shared seed derived
from the per-bucket key (rotation.rotation_key), which every peer already
holds — the SPMD analogue of the §4.4 seed trick.  The gathered payload is
therefore exactly the inner codec's buffer at the rotated length
``rotation.padded_dim(d)`` (== d's next power of two; equal to d whenever
d is already a power of two), which tests verify against the lowered HLO.
The analytic §4 cost adds one r̄_s seed term per node, mirroring how
Eq. (9)/(10) charge the support seeds that likewise never travel here.

Reduce kind is inherited: the rotation composes with gather codecs
(rotate → pack → all_gather → decode → unrotate) and with psum codecs
(rotate → psum wire → decode → unrotate) alike.
"""
from __future__ import annotations

from repro.core import rotation
from repro.core import types as t
from repro.core.wire import base
from repro.kernels.rotated_encode import ops as ro_ops


class RotatedCodec(base.WireCodec):
    """The inner codec applied in the rotated basis z = Qx (§7.2)."""

    def __init__(self, inner: base.WireCodec):
        if isinstance(inner, RotatedCodec):
            raise ValueError("rotation pre-transform does not nest")
        self.inner = inner
        self.name = "rotated_" + inner.name
        self.reduce = inner.reduce
        # codec state (e.g. a wrapped EFCodec's residual) is forwarded, so
        # rotation∘EF compositions thread their state through the rotation.
        self.stateful = inner.stateful
        # the rotated decode partitions iff the inner one does (the
        # unrotate happens on the reassembled estimate, outside the shards).
        self.scatter_supported = inner.scatter_supported

    # ---- geometry & accounting: the inner codec at padded_dim(d) ---------- #

    def wire_slots(self, d, cfg):
        return self.inner.wire_slots(rotation.padded_dim(d), cfg)

    def wire_bits(self, n, d, cfg):
        # HLO-exact: the gathered payload IS the inner buffer at dp — the
        # rotation itself ships nothing (seed-only overhead).
        return self.inner.wire_bits(n, rotation.padded_dim(d), cfg)

    def seed_bits(self, n, cfg):
        return (self.inner.seed_bits(n, cfg)
                + float(n * t.DEFAULT_RSEED_BITS))

    def cost_spec(self, d, cfg):
        return self.inner.cost_spec(rotation.padded_dim(d), cfg)

    def scatter_bits(self, n, d, cfg):
        # a flat scatter decode shards the ROTATED estimate, so the shard
        # gather bytes are the inner codec's at the padded length.
        return self.inner.scatter_bits(n, rotation.padded_dim(d), cfg)

    def comm_cost_bits(self, n, d, cfg):
        # inner analytic cost at the rotated length + the rotation seed
        # (r̄_s per node in the faithful star protocol; regenerated from
        # the shared key on SPMD hardware, like the §4.4 support seeds).
        return (self.inner.comm_cost_bits(n, rotation.padded_dim(d), cfg)
                + float(n * t.DEFAULT_RSEED_BITS))

    # ---- wire format: rotate before pack, unrotate after decode ----------- #

    def pack(self, flat, key, rank, cfg):
        if self.inner.name == "binary":
            # fused rotate+encode: one kernel pair instead of
            # FWHT / min-max / threshold / pack round trips on TPU; the
            # dispatcher falls back to exactly the chain below off-TPU
            # (repro.kernels.rotated_encode).
            return ro_ops.pack_binary(flat, key, rank, cfg.wire_dtype)
        z = rotation.rotate(rotation.rotation_key(key), flat)
        return self.inner.pack(z, key, rank, cfg)

    def unpack(self, row, peer, key, cfg, d):
        dp = rotation.padded_dim(d)
        z = self.inner.unpack(row, peer, key, cfg, dp)
        return rotation.unrotate(rotation.rotation_key(key), z, d)

    def decode_gathered(self, rows, key, cfg, d, n):
        # unrotate once, after the averaging decode (linearity of Q).
        dp = rotation.padded_dim(d)
        zbar = self.inner.decode_gathered(rows, key, cfg, dp, n)
        return rotation.unrotate(rotation.rotation_key(key), zbar, d)

    def scatter_align(self, cfg):
        return self.inner.scatter_align(cfg)

    def gather_decode(self, buf, key, cfg, d, n, drop_mask=None):
        # Rotated decodes scatter in ROTATED space (DESIGN.md §13): the
        # unrotated estimate is not coordinate-partitionable (every output
        # coordinate mixes all of z̄), so the shard decomposition — shard
        # decode, reassembling all_gather, truncation — runs entirely
        # inside the inner codec at the padded length, and the single
        # inverse rotation is applied to the reassembled z̄.  Flat-decode
        # configs take the exact historical op sequence through the same
        # delegation.  Robust decode policies and drop masks (§14) ride
        # the same delegation: the coordinate-wise reduction happens in
        # ROTATED space — trimming per rotated coordinate, where the §7.2
        # rotation has spread any coordinate-aligned outlier energy — and
        # the single inverse rotation maps the robust estimate back.
        dp = rotation.padded_dim(d)
        zbar = self.inner.gather_decode(buf, key, cfg, dp, n, drop_mask)
        return rotation.unrotate(rotation.rotation_key(key), zbar, d)

    def decode_rows_reduce(self, rows, key, cfg, d, n, drop_mask=None):
        # collective-free policy decode: the reduction runs in rotated
        # space at the padded length (same convention as gather_decode).
        dp = rotation.padded_dim(d)
        zbar = self.inner.decode_rows_reduce(rows, key, cfg, dp, n,
                                             drop_mask)
        return rotation.unrotate(rotation.rotation_key(key), zbar, d)

    def decode_reduced(self, wire, key, cfg, d):
        dp = rotation.padded_dim(d)
        zbar = self.inner.decode_reduced(wire, key, cfg, dp)
        return rotation.unrotate(rotation.rotation_key(key), zbar, d)

    # ---- codec state: forwarded in the rotated basis ---------------------- #

    def state_shape(self, d, cfg):
        return self.inner.state_shape(rotation.padded_dim(d), cfg)

    def _round_stateful(self, flat, state, key, cfg, drop_mask=None):
        # The state lives in the (per-step-reseeded) rotated basis — see
        # docs/DESIGN.md §8 for why EF∘rotation (EF outermost, as built by
        # registry.resolve) is the production order.  Overriding the
        # _round hooks (not mean_flat*) keeps the hierarchical inner-axes
        # pre-reduce at the one public entry point; delegating to the
        # inner codec's _round at the padded length dp means the
        # scatter-decode decomposition, when on, shards the ROTATED
        # estimate and reassembles all dp coordinates before unrotating.
        d = flat.shape[0]
        krot = rotation.rotation_key(key)
        z = rotation.rotate(krot, flat)
        zbar, new_state = self.inner._round_stateful(z, state, key, cfg,
                                                     drop_mask)
        return rotation.unrotate(krot, zbar, d), new_state

    def _round(self, flat, key, cfg, drop_mask=None):
        d = flat.shape[0]
        krot = rotation.rotation_key(key)
        z = rotation.rotate(krot, flat)
        zbar = self.inner._round(z, key, cfg, drop_mask)
        return rotation.unrotate(krot, zbar, d)
