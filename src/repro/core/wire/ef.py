"""Error feedback as a composable wire layer (docs/DESIGN.md §8).

:class:`EFCodec` wraps *any* registered wire codec the way
:class:`~repro.core.wire.rotated.RotatedCodec` wraps the §7.2 rotation:

    v_t   = x_t + e_t                       (residual-corrected input)
    wire  = twin_pack(v_t)                  (the inner codec's EXACT format)
    est_t = inner.decode(collective(wire))  (= mean_i m_i over the nodes)
    e_t+1 = v_t − inner.unpack(own wire)    (local; never transmitted)

so the estimate telescopes —  (1/T) Σ_t est_t = x̄ + (ē_0 − ē_T)/T  — and
constant inputs are recovered at rate 1/T with zero asymptotic bias, while
the wire payload is byte-identical to the un-wrapped codec (verified
against lowered HLO by tests/distributed_checks/ef_wire_check.py).

**Why a twin pack instead of delegating ``pack`` verbatim.**  EF is only
stable when the per-node message is *contractive*: ‖v − m(v)‖ must shrink
the centred energy.  The paper's encoders are unbiased *expansions* at
aggressive budgets (Lemma 3.2's (1/p − 1) factor): feeding their d/k- or
1/p-rescaled messages into the EF recursion provably diverges (the
residual picks up the (1/p − 1)-inflated noise each round —
tests/distributed_checks/collectives_check.py's ``ef.converges`` guards
exactly this).  Every inner codec therefore gets a *contractive twin*: a
message in the SAME wire format (same buffer layout, same slots, decoded
by the inner codec's unchanged ``unpack``) whose values are damped:

  * ``fixed_k`` / ``fixed_k_shared`` / ``bernoulli`` — the scale-1
    sparsifier: raw values on the sampled support, μ elsewhere.  This is
    the induced contraction of the unbiased encoder (damping the centred
    message by η = 1/(1 + ω) with ω = 1/p − 1 gives exactly scale 1):
    ‖v − m‖² = Σ_{j∉S} (v_j − μ)² ≤ ‖v − μ1‖², deterministically.
  * ``binary`` — Seide et al.'s 1-bit compressor: deterministic threshold
    at mean(v), cluster means in the two tail slots.  Within-cluster SS ≤
    SS around the mean, so ‖v − m‖ ≤ ‖v − v̄1‖ deterministically (the
    *stochastic* binary quantizer's variance exceeds the centred energy by
    ~2·log d on Gaussian-ish data — divergent under EF).
  * ``ternary`` / ``ternary_opt`` — deterministic hybrid: the ``cap``
    largest-|v − v̄| coordinates pass through exactly (the value segment is
    filled to capacity, never overflows), the rest 2-means like binary.
  * ``dense`` — the same rules applied densely, dispatched on the encoder
    kind.
  * ``rotated_*`` — rotate first, then the twin of the rotated codec's
    inner: EF∘rotation composes with the residual kept in model space.

Residuals absorb *all* local reconstruction error — wire-dtype rounding
and capacity-overflow drops included — because e' is computed from the
inner codec's own ``unpack`` of the bytes actually shipped.

Accounting delegates verbatim (wire_slots/wire_bits/seed_bits/cost_spec),
so ``comm_cost_bits == wire_bits + seed_bits`` holds by construction for
every wrapped codec, and ``bucket_wire_bits`` needs no EF special case.

Composition order: ``registry.resolve`` builds EF *outermost*
(EF∘rotation), which keeps the residual in model coordinates where the
telescoping identity is exact.  The reverse order
RotatedCodec(EFCodec(...)) also composes mechanically (RotatedCodec
forwards codec state), but its residual lives in the per-step-reseeded
rotated basis, where the telescoping holds only in expectation over the
rotations — see docs/DESIGN.md §8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core import encoders
from repro.core import rotation
from repro.core.wire import base, codecs, rotated


# --------------------------------------------------------------------------- #
# Contractive twin messages, one per inner wire format.  Every helper emits
# a buffer in the inner codec's exact layout; the inner ``unpack`` decodes it.
# --------------------------------------------------------------------------- #

def _two_means(v, select=None):
    """One deterministic 2-means step: threshold at the (selected) mean.

    Returns (c_lo, c_hi, hi_mask).  ``select`` restricts the clustering to a
    subset (the ternary twin's non-pass coordinates); excluded coordinates
    get an arbitrary side of the threshold and must be overwritten by the
    caller.  Cluster means minimize the within-cluster SS, so the decoded
    message m = hi ? c_hi : c_lo satisfies ‖v − m‖ ≤ ‖v − v̄1‖ (restricted
    to ``select``) — the deterministic contraction EF needs.
    """
    if select is None:
        select = jnp.ones(v.shape, bool)
    cnt = jnp.maximum(jnp.sum(select.astype(jnp.float32)), 1.0)
    thr = jnp.sum(jnp.where(select, v, 0.0)) / cnt
    hi = v >= thr
    n_hi = jnp.sum((select & hi).astype(jnp.float32))
    n_lo = jnp.sum((select & ~hi).astype(jnp.float32))
    c_hi = jnp.where(n_hi > 0,
                     jnp.sum(jnp.where(select & hi, v, 0.0))
                     / jnp.maximum(n_hi, 1.0), thr)
    c_lo = jnp.where(n_lo > 0,
                     jnp.sum(jnp.where(select & ~hi, v, 0.0))
                     / jnp.maximum(n_lo, 1.0), thr)
    return c_lo, c_hi, hi


def _fixed_k_twin(flat, key, rank, cfg, shared: bool):
    """Scale-1 fixed-k: [v − μ on support ‖ μ] — unpack gives v / μ."""
    kids = key if shared else jax.random.fold_in(key, rank)
    return codecs.fixed_k_pack(flat, kids, cfg, scale=1.0)


def _bernoulli_twin(flat, key, rank, cfg):
    """Scale-1 Bernoulli: raw values at their support-rank slots + μ tail."""
    return codecs.bernoulli_buffer(flat, key, rank, cfg, scaled=False)


def _wire_round(x, wire_dtype):
    """The exact value a float takes after the floats_to_words →
    words_to_floats wire round trip: identity at r = 32, round-through-
    the-wire-dtype at r = 16 (bit-equal to the bitcast pack/unpack pair
    by construction — both are ``astype(wire_dtype)`` then widen)."""
    x = jnp.asarray(x, jnp.float32)
    if bitplane.wire_bits(wire_dtype) == 32:
        return x
    return x.astype(wire_dtype).astype(jnp.float32)


def _binary_twin(flat, cfg):
    """Seide 1-bit: mean-threshold plane + the two cluster means as tail.

    Returns (buf, recon) with recon bit-for-bit ``binary_unpack(buf)`` —
    derived from the twin's own mask + centers through the wire-rounding
    identity, so the EF residual skips the plane unpack round trip
    (DESIGN.md §13).
    """
    c_lo, c_hi, hi = _two_means(flat)
    buf = bitplane.binary_words(hi, c_lo, c_hi, cfg.wire_dtype)
    recon = jnp.where(hi, _wire_round(c_hi, cfg.wire_dtype),
                      _wire_round(c_lo, cfg.wire_dtype))
    return buf, recon


def _ternary_twin(flat, cap, cfg):
    """Deterministic ternary: top-cap |v − v̄| pass through exactly, the
    rest 2-means.  Fills the value segment to capacity — no overflow.

    Returns (buf, recon) with recon bit-for-bit ``ternary_unpack(buf)``:
    the value segment is filled to capacity in support-rank order, so
    every pass-through slot is valid (the overflow fallback is
    unreachable) and the reconstruction is just the pass/branch select
    through the wire rounding — no plane unpack, no rank cumsum.
    """
    d = flat.shape[0]
    cap = min(cap, d)
    dev = jnp.abs(flat - jnp.mean(flat))
    # same membership as top_k(dev, cap) (ties → lowest index) but via the
    # O(d)-per-pass bit bisection — top_k was the ef_ternary pack hot spot.
    passm = bitplane.topcap_mask(dev, cap)
    c_lo, c_hi, hi = _two_means(flat, select=~passm)
    sym = jnp.where(passm, 2, jnp.where(hi, 1, 0)).astype(jnp.uint32)
    vbuf = bitplane.rank_scatter(flat, passm, cap)
    buf = bitplane.ternary_words(sym, vbuf, c_lo, c_hi, cfg.wire_dtype)
    wd = cfg.wire_dtype
    recon = jnp.where(passm, _wire_round(flat, wd),
                      jnp.where(hi, _wire_round(c_hi, wd),
                                _wire_round(c_lo, wd)))
    return buf, recon


def _dense_twin(flat, key, rank, cfg):
    """Dense contractive message, dispatched on the encoder kind."""
    kind = cfg.encoder.kind
    if kind == "identity":
        return flat.astype(jnp.float32)
    if kind == "binary":
        c_lo, c_hi, hi = _two_means(flat)
        return jnp.where(hi, c_hi, c_lo).astype(jnp.float32)
    if kind == "ternary":
        d = flat.shape[0]
        k = max(1, min(d, int(round(float(cfg.encoder.fraction) * d))))
        dev = jnp.abs(flat - jnp.mean(flat))
        _, top = jax.lax.top_k(dev, k)
        passm = jnp.zeros((d,), bool).at[top].set(True)
        c_lo, c_hi, hi = _two_means(flat, select=~passm)
        return jnp.where(passm, flat,
                         jnp.where(hi, c_hi, c_lo)).astype(jnp.float32)
    # Eq. (1) family (bernoulli / fixed_k, any probs policy): raw values on
    # the sampled support, center elsewhere — the per-coordinate induced
    # contraction (1 − p_j per coordinate).
    enc = encoders.encode(jax.random.fold_in(key, rank), flat, cfg.encoder)
    return jnp.where(enc.support, flat, enc.mu).astype(jnp.float32)


def _twin_pack(codec, flat, key, rank, cfg):
    """The contractive message for ``codec``, in its exact wire format.

    Extension point: a codec outside this module may define
    ``ef_twin_pack(flat, key, rank, cfg)`` (and ``ef_residual_bound``) to
    declare its own contractive twin — checked first, so new protocols
    compose with EF without this dispatch learning about them.
    """
    hook = getattr(codec, "ef_twin_pack", None)
    if hook is not None:
        return hook(flat, key, rank, cfg)
    if isinstance(codec, rotated.RotatedCodec):
        z = rotation.rotate(rotation.rotation_key(key), flat)
        return _twin_pack(codec.inner, z, key, rank, cfg)
    if isinstance(codec, codecs.FixedKGatherCodec):
        return _fixed_k_twin(flat, key, rank, cfg, shared=False)
    if isinstance(codec, codecs.FixedKSharedCodec):
        return _fixed_k_twin(flat, key, rank, cfg, shared=True)
    if isinstance(codec, codecs.BernoulliCodec):
        return _bernoulli_twin(flat, key, rank, cfg)
    if isinstance(codec, codecs.TernaryCodec):  # incl. TernaryOptCodec
        return _ternary_twin(flat, codec._cap(flat.shape[0], cfg), cfg)[0]
    if isinstance(codec, codecs.BinaryCodec):
        return _binary_twin(flat, cfg)[0]
    if isinstance(codec, codecs.DenseSimCodec):
        return _dense_twin(flat, key, rank, cfg)
    raise ValueError(
        f"error feedback has no contractive twin for codec {codec.name!r}; "
        "define ef_twin_pack/ef_residual_bound on the codec or leave "
        "error_feedback off for it")


def _twin_pack_recon(codec, flat, key, rank, cfg):
    """(wire buffer, local reconstruction) for the contractive twin.

    ``recon`` is bit-for-bit ``codec.unpack(buf, rank, key, cfg, d)``.
    For the plane codecs it is derived from the twin's own intermediates
    (mask + centers + pass values through :func:`_wire_round`) — skipping
    the plane unpack round trip that was the ef_rotated_binary hot spot —
    and the rotated wrapper recurses in rotated space with ONE inverse
    FWHT at the end.  Codecs without a fused twin recon fall back to
    pack + unpack, the historical op sequence.  Residual semantics are
    unchanged either way (golden wire bytes depend on the round-t residual
    and stay pinned).
    """
    hook = getattr(codec, "ef_twin_pack", None)
    if hook is not None:
        buf = hook(flat, key, rank, cfg)
        return buf, codec.unpack(buf, rank, key, cfg, flat.shape[0])
    if isinstance(codec, rotated.RotatedCodec):
        krot = rotation.rotation_key(key)
        z = rotation.rotate(krot, flat)
        buf, rz = _twin_pack_recon(codec.inner, z, key, rank, cfg)
        return buf, rotation.unrotate(krot, rz, flat.shape[0])
    if isinstance(codec, codecs.TernaryCodec):  # incl. TernaryOptCodec
        return _ternary_twin(flat, codec._cap(flat.shape[0], cfg), cfg)
    if isinstance(codec, codecs.BinaryCodec):
        return _binary_twin(flat, cfg)
    buf = _twin_pack(codec, flat, key, rank, cfg)
    return buf, codec.unpack(buf, rank, key, cfg, flat.shape[0])


def twin_recon_fused(codec) -> bool:
    """True iff the EF twin for inner ``codec`` derives its reconstruction
    from encode-side intermediates (no plane unpack round trip)."""
    if isinstance(codec, rotated.RotatedCodec):
        return twin_recon_fused(codec.inner)
    return isinstance(codec, (codecs.BinaryCodec, codecs.TernaryCodec))


def twin_recon(codec, flat, key, rank, cfg):
    """The EF residual reconstruction m(v) for inner ``codec``.

    Bench/test entry point for the production residual path: bit-equal to
    ``codec.unpack`` of the shipped twin buffer (pinned by
    tests/test_wire_registry.py), collective-free.
    """
    return _twin_pack_recon(codec, flat, key, rank, cfg)[1]


def _twin_bound(codec, flat, key, cfg):
    """Deterministic bound on ‖v − m(v)‖ for the twin message of ``codec``
    (tests/test_wire_registry.py's hypothesis property; f32 wire)."""
    hook = getattr(codec, "ef_residual_bound", None)
    if hook is not None:
        return hook(flat, key, cfg)
    if isinstance(codec, rotated.RotatedCodec):
        z = rotation.rotate(rotation.rotation_key(key), flat)
        return _twin_bound(codec.inner, z, key, cfg)
    if isinstance(codec, (codecs.FixedKGatherCodec, codecs.FixedKSharedCodec,
                          codecs.BernoulliCodec)):
        mu = base.center(flat, cfg.encoder.center)
        return jnp.linalg.norm(flat - mu)
    if isinstance(codec, codecs.DenseSimCodec) and \
            cfg.encoder.kind in ("bernoulli", "fixed_k"):
        enc = encoders.encode(jax.random.fold_in(key, 0), flat, cfg.encoder)
        return jnp.linalg.norm(flat - enc.mu)
    if isinstance(codec, codecs.DenseSimCodec) and \
            cfg.encoder.kind == "identity":
        return jnp.zeros(())
    # binary / ternary twins: within-cluster SS ≤ SS around the mean.
    return jnp.linalg.norm(flat - jnp.mean(flat))


# --------------------------------------------------------------------------- #
# The wrapper codec.
# --------------------------------------------------------------------------- #

class EFCodec(base.WireCodec):
    """Error feedback composed over any inner codec (residual state local)."""

    stateful = True

    def __init__(self, inner: base.WireCodec):
        if inner.stateful:
            raise ValueError("error feedback does not nest over a stateful "
                             f"codec ({inner.name})")
        self.inner = inner
        self.name = "ef_" + inner.name
        self.reduce = inner.reduce
        self.scatter_supported = inner.scatter_supported

    # ---- geometry & accounting: delegated verbatim ------------------------ #
    # The residual never touches the wire, so the payload IS the inner
    # codec's payload and the §4 accounting identity holds by construction.

    def wire_slots(self, d, cfg):
        return self.inner.wire_slots(d, cfg)

    def wire_bits(self, n, d, cfg):
        return self.inner.wire_bits(n, d, cfg)

    def seed_bits(self, n, cfg):
        return self.inner.seed_bits(n, cfg)

    def cost_spec(self, d, cfg):
        return self.inner.cost_spec(d, cfg)

    def comm_cost_bits(self, n, d, cfg):
        return self.inner.comm_cost_bits(n, d, cfg)

    def scatter_bits(self, n, d, cfg):
        return self.inner.scatter_bits(n, d, cfg)

    # ---- wire format: twin pack, inner decode ----------------------------- #

    def pack(self, flat, key, rank, cfg):
        """The contractive twin of the inner codec's message for ``flat``.

        ``flat`` is the residual-corrected vector v = x + e; the residual
        addition itself happens in :meth:`mean_flat_stateful`.
        """
        return _twin_pack(self.inner, flat, key, rank, cfg)

    def unpack(self, row, peer, key, cfg, d):
        return self.inner.unpack(row, peer, key, cfg, d)

    def decode_gathered(self, rows, key, cfg, d, n):
        return self.inner.decode_gathered(rows, key, cfg, d, n)

    def decode_gathered_shard(self, rows, key, cfg, d, n, shard, nshards):
        return self.inner.decode_gathered_shard(rows, key, cfg, d, n,
                                                shard, nshards)

    def decode_reduced(self, wire, key, cfg, d):
        return self.inner.decode_reduced(wire, key, cfg, d)

    def scatter_align(self, cfg):
        return self.inner.scatter_align(cfg)

    def gather_decode(self, buf, key, cfg, d, n, drop_mask=None):
        # full delegation (not just the decode hooks): RotatedCodec owns
        # its scatter decomposition inside gather_decode — shards live in
        # rotated space at the padded length — so EF hands the whole
        # gather+decode to the inner codec instead of re-running base's
        # scatter branch at the model d.  For non-rotated inners this is
        # op-for-op the base implementation.  Robust decode policies and
        # drop masks (§14) delegate the same way — the reduction runs over
        # the inner codec's reconstructions of the twin rows; a dropped
        # peer's residual stays local to that peer and re-enters through
        # its own future messages, so exclusion at decode time loses no
        # mass permanently.
        return self.inner.gather_decode(buf, key, cfg, d, n, drop_mask)

    def decode_rows_reduce(self, rows, key, cfg, d, n, drop_mask=None):
        return self.inner.decode_rows_reduce(rows, key, cfg, d, n,
                                             drop_mask)

    # ---- the stateful round ----------------------------------------------- #

    def state_shape(self, d, cfg):
        return (d,)

    def residual_bound(self, flat, key, cfg):
        """Deterministic bound on one zero-residual EF step's new residual:
        ‖e'‖ = ‖flat − m(flat)‖ ≤ the inner twin's worst-case per-step
        error (f32 wire; wire-dtype rounding adds its quantization noise).
        """
        return _twin_bound(self.inner, flat, key, cfg)

    def _round_stateful(self, flat, state, key, cfg, drop_mask=None):
        """One EF round: (estimate, new_residual); must run in shard_map.

        The new residual is v minus the reconstruction of the bytes this
        node actually shipped (bit-equal to the inner codec's ``unpack``
        of them, but derived from the twin's own intermediates where the
        format allows — :func:`_twin_pack_recon`), so wire-dtype rounding
        and capacity-overflow drops are recycled too, not just
        sparsification.
        Under the hierarchical schedule ``flat`` arrives already
        inner-reduced (base.mean_flat*), so the residual tracks the
        cross-host message — the only lossy step.
        """
        d = flat.shape[0]
        rank, n = base.axis_rank_size(cfg.axes)
        v = flat + state
        buf, recon = _twin_pack_recon(self.inner, v, key, rank, cfg)
        if self.reduce == "psum":
            if drop_mask is None:
                wire = jax.lax.pmean(buf, cfg.axes)
            else:
                # masked weighted psum, mirroring base._round: dropped
                # peers contribute zero to both numerator and count.
                keep = drop_mask[rank].astype(jnp.float32)
                num = jax.lax.psum(buf.astype(jnp.float32) * keep, cfg.axes)
                den = jax.lax.psum(keep, cfg.axes)
                wire = (num / den).astype(buf.dtype)
            est = self.inner.decode_reduced(wire, key, cfg, d)
        else:
            est = self.gather_decode(buf, key, cfg, d, n, drop_mask)
        return est, v - recon

    def _round(self, flat, key, cfg, drop_mask=None):
        """Stateless round: zero residual, state discarded.

        Keeps EF configs usable by payload/HLO measurements and benchmarks
        that lower ``compressed_mean``; training threads real residuals via
        ``compressed_mean_stateful``.
        """
        y, _ = self._round_stateful(flat, jnp.zeros_like(flat), key, cfg,
                                    drop_mask)
        return y
