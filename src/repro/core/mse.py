"""Closed-form MSE of the encoding protocols (Lemmas 3.2, 3.4, 7.2; Thm 6.1).

These are the paper's exact expressions; tests validate the *empirical*
mean-squared error of the encoders in :mod:`repro.core.encoders` against
them, which is the strongest faithfulness check available (the formulas are
the paper's central quantitative claims).

Conventions: X is (n, d); probs broadcastable to (n, d); mus (n,).
"""
from __future__ import annotations

import jax.numpy as jnp


def r_factor(xs, mus):
    """R = (1/n) Σ_i ||X_i − μ_i·1||²  (§5.2 / Thm 6.1)."""
    dev = xs - mus[:, None]
    return jnp.mean(jnp.sum(dev * dev, axis=-1))


def mse_bernoulli(xs, probs, mus):
    """Lemma 3.2:  MSE = (1/n²) Σ_ij (1/p_ij − 1)(X_i(j) − μ_i)².

    p_ij = 0 contributes 0 iff X_i(j) = μ_i (Remark 1 semantics); we honour
    that by zeroing those terms (the optimal solutions of §6.1 only assign
    p = 0 there).
    """
    n = xs.shape[0]
    probs = jnp.broadcast_to(jnp.asarray(probs, xs.dtype), xs.shape)
    dev2 = (xs - mus[:, None]) ** 2
    psafe = jnp.where(probs > 0, probs, 1.0)
    terms = jnp.where(probs > 0, (1.0 / psafe - 1.0) * dev2, jnp.where(dev2 > 0, jnp.inf, 0.0))
    return jnp.sum(terms) / n**2


def mse_fixed_k(xs, k, mus):
    """Lemma 3.4:  MSE = (1/n²) Σ_ij ((d−k)/k)(X_i(j) − μ_i)²."""
    n, d = xs.shape
    dev2 = (xs - mus[:, None]) ** 2
    return (d - k) / k * jnp.sum(dev2) / n**2


def mse_fixed_k_shared(xs, k, mus):
    """Shared-support fixed-k MSE (our TPU-native variant, DESIGN.md §2).

    When all nodes draw the *same* support D (|D| = k uniform), the errors
    couple coherently through the common indicator:

      Y(j) − X(j) = (1_{j∈D}·d/k − 1) · (1/n) Σ_i (X_i(j) − μ_i),

    so  MSE = ((d−k)/k) · Σ_j ( (1/n) Σ_i (X_i(j) − μ_i) )²   — *exact*:
    ||Y−X||² is a sum of per-coordinate squares, so only the second moment
    E[(1_{j∈D}·d/k − 1)²] = (k/d)(d/k−1)² + (1−k/d) = (d−k)/k enters; no
    cross-coordinate terms arise.

    Compare Lemma 3.4 (independent supports): the independent MSE averages
    per-node deviations *incoherently* ((1/n²)Σ_i Σ_j dev²), while the
    shared one squares the *coherent* node-mean deviation.  For i.i.d.
    gradient-noise-like deviations both are Θ((d/k−1)·R/n); when node
    deviations anti-correlate the shared variant wins.
    """
    d = xs.shape[1]
    mean_dev = jnp.mean(xs - mus[:, None], axis=0)  # (d,)
    return (d - k) / k * jnp.sum(mean_dev**2)


def mse_binary(xs):
    """Example 4 exact MSE:  (1/n²) Σ_ij (X^max_i − X_i(j))(X_i(j) − X^min_i)."""
    n = xs.shape[0]
    vmin = jnp.min(xs, axis=-1, keepdims=True)
    vmax = jnp.max(xs, axis=-1, keepdims=True)
    return jnp.sum((vmax - xs) * (xs - vmin)) / n**2


def mse_binary_bound(xs):
    """Example 4 / [10, Thm 1] bound:  d/(2n) · (1/n) Σ_i ||X_i||²."""
    n, d = xs.shape
    return d / (2 * n) * jnp.mean(jnp.sum(xs * xs, axis=-1))


def mse_ternary(xs, p1, p2, c1s, c2s):
    """Exact MSE of the ternary encoder Eq. (21)  (corrected Lemma 7.2).

    Per coordinate:  E[(Y−X)²] = p'(X−c1)² + p''(X−c2)²
                                 + (p'(X−c1) + p''(X−c2))² / (1−p'−p'').

    Note: Lemma 7.2 *as printed* states the third term as (p'c1 + p''c2)²,
    which fails the sanity check X = c1, p'' = 0 (a lossless configuration
    must have zero error, but the printed form gives (p'c1)² ≠ 0).  The
    paper omits the proof ("for brevity"); we derive, implement and
    empirically verify the corrected form above (see
    tests/test_mse_theory.py::test_ternary_matches_empirical).
    """
    n = xs.shape[0]
    p1 = jnp.broadcast_to(jnp.asarray(p1, xs.dtype), xs.shape)
    p2 = jnp.broadcast_to(jnp.asarray(p2, xs.dtype), xs.shape)
    d1 = xs - c1s[:, None]
    d2 = xs - c2s[:, None]
    rest = 1.0 - p1 - p2
    restsafe = jnp.where(rest > 0, rest, 1.0)
    terms = p1 * d1**2 + p2 * d2**2 + (p1 * d1 + p2 * d2) ** 2 / restsafe
    return jnp.sum(terms) / n**2


# --- §7.2: random-rotation pre-processing -------------------------------- #

def mse_rotated(xs, krot, base_mse_fn):
    """§7.2 composition rule: the rotated protocol's MSE, conditional on Q.

    With a shared orthogonal rotation Q (seed ``krot``), encoding z_i =
    Q·X_i, averaging in the rotated basis and unrotating the average gives

        E‖Qᵀ z̄ − X̄‖² = E‖z̄ − Q X̄‖²   (‖Qᵀv‖ = ‖v‖),

    i.e. *exactly* the base protocol's closed form evaluated at the rotated
    data — the §7.2 analogue of how Lemma 7.2 specializes Lemma 3.2; the
    unconditional MSE is the expectation of this quantity over Q.  For
    non-power-of-two d the rotated basis has padded_dim(d) coordinates and
    truncation makes the base form an upper bound (the discarded padding
    error is nonnegative); at power-of-two d it is exact.

    ``base_mse_fn`` maps the rotated (n, dp) stack to the base closed form
    (e.g. ``mse_binary``, or a lambda closing over k for ``mse_fixed_k``).
    """
    from repro.core import rotation
    return base_mse_fn(rotation.rotate(krot, xs))


def mse_rotated_binary(xs, krot):
    """Exact conditional MSE of rotated binary quantization (§7.2 ∘ Ex. 4):
    Example 4's closed form at QX.  Validated against the wire path in
    tests/test_rotation_wire.py and distributed_checks/rotated_wire_check."""
    return mse_rotated(xs, krot, mse_binary)


def mse_rotated_fixed_k(xs, k, krot):
    """Exact conditional MSE of rotated fixed-k (§7.2 ∘ Lemma 3.4): the
    Lemma 3.4 form at QX with the *rotated-basis* dimension dp.

    Note the dp ≥ d subtlety: rotation pads to dp = padded_dim(d), so the
    wire path samples k of dp coordinates and Lemma 3.4's (dp−k)/k factor
    applies in the rotated basis.
    """
    from repro.core import rotation
    zs = rotation.rotate(krot, xs)
    return mse_fixed_k(zs, k, jnp.mean(zs, axis=-1))


# --- §14: robust (trimmed) decode bounds ---------------------------------- #

def heterogeneity(xs):
    """Σ_i ‖X_i − X̄‖² — the data-dispersion term of the trimmed bounds."""
    dev = xs - jnp.mean(xs, axis=0, keepdims=True)
    return jnp.sum(dev * dev)


def mse_trimmed(base_mse, xs, f: int):
    """Clean-regime bound on the trim(f) decoder's MSE (DESIGN.md §14).

    The trimmed decode keeps, per coordinate, m = n − 2f of the peer
    reconstructions Y_ij and averages them: est_j = Σ_i w_ij Y_ij with
    w_ij ∈ {0, 1/m} and Σ_i w_ij = 1.  Writing the error against the true
    mean X̄_j and applying Cauchy–Schwarz over the ≤ n active terms,

        E(est_j − X̄_j)²  ≤  (n/m²)·Σ_i E(Y_ij − X̄_j)²
                          =  (n/m²)·Σ_i [ E(Y_ij − X_ij)² + (X_ij − X̄_j)² ],

    where Σ_ij E(Y_ij − X_ij)² = n²·MSE_mean (the per-node encoder noise
    whose (1/n²)-scaled sum is the plain decoder's Lemma 3.2/3.4 closed
    form) and Σ_ij (X_ij − X̄_j)² = :func:`heterogeneity` — the bias a
    selection rule can pick up because the trimmed mean of *honest* values
    need not be the honest mean when the data disagree across nodes.  With
    (n/m²) ≤ 1/(n − 2f) for m = n − 2f ≤ n:

        MSE_trim  ≤  (n²·MSE_mean + Σ_i ‖X_i − X̄‖²) / (n − 2f).

    Valid for ANY rule keeping n − 2f rows per coordinate — in particular
    for the clean (adversary-free) regime where all kept rows are honest.
    With adversaries present the bound applies to the kept honest rows via
    the JACM86 containment property (tested, not bounded in closed form
    here).  ``f = 0`` returns ``base_mse`` exactly: the trim(0) decoder IS
    the plain averaging decoder (types.parse_decode_policy normalizes it).
    """
    n = xs.shape[0]
    if f == 0:
        return base_mse
    if n <= 2 * f:
        raise ValueError(f"trim({f}) undefined for n={n}: needs n > 2f")
    return (n * n * base_mse + heterogeneity(xs)) / (n - 2 * f)


def mse_trimmed_bernoulli(xs, probs, mus, f: int):
    """:func:`mse_trimmed` over the Lemma 3.2 Bernoulli closed form."""
    return mse_trimmed(mse_bernoulli(xs, probs, mus), xs, f)


def mse_trimmed_binary(xs, f: int):
    """:func:`mse_trimmed` over the Example 4 binary closed form."""
    return mse_trimmed(mse_binary(xs), xs, f)


# --- Theorem 6.1 --------------------------------------------------------- #

def thm61_bounds(xs, mus, B):
    """MSE bounds of the optimal protocol under budget B (Thm 6.1, Eq. 19).

    Returns (lower, upper):  (1/B − 1)·R/n  ≤  MSE*  ≤  (|S|/B − 1)·R/n,
    with S = {(i,j): X_i(j) ≠ μ_i}.
    """
    n = xs.shape[0]
    R = r_factor(xs, mus)
    S = jnp.sum((xs - mus[:, None]) != 0)
    lower = (1.0 / B - 1.0) * R / n
    upper = (S / B - 1.0) * R / n
    return lower, upper


def thm61_exact_low_budget(xs, mus, B):
    """Eq. (20): exact optimal MSE when B ≤ Σ a_ij / max a_ij.

    MSE* = W²/(n²B) − R/n  with  a_ij = |X_i(j) − μ_i|, W = Σ a_ij.
    """
    n = xs.shape[0]
    a = jnp.abs(xs - mus[:, None])
    W = jnp.sum(a)
    R = r_factor(xs, mus)
    return W**2 / (n**2 * B) - R / n
