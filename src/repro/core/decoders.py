"""Decoding protocols gamma (§2).

The paper's analysis centres on the *averaging decoder* (Example 2); the
rotation pre-processing of §7.2 composes it with the inverse rotation
(Example 3 shows any invertible linear map gives an exact scheme when used
losslessly).
"""
from __future__ import annotations

import jax.numpy as jnp


def averaging_decoder(ys):
    """gamma(Y_1..Y_n) = (1/n) Σ Y_i  (Example 2).  ys: (n, d) -> (d,)."""
    return jnp.mean(ys, axis=0)


def weighted_partial_decoder(ys, alive):
    """Straggler-tolerant decode: average over the live subset only.

    Unbiased for the mean of the *live* nodes' vectors (the averaging
    decoder is n-agnostic — DESIGN.md §5).  ``alive``: (n,) bool/0-1 mask.
    """
    w = alive.astype(ys.dtype)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.einsum("n,nd->d", w, ys) / denom
