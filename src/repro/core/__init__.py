"""Core: the paper's randomized distributed mean estimation protocols."""
from repro.core.types import (  # noqa: F401
    CommSpec, CompressionConfig, EncoderSpec, fixed_k_from_fraction)
from repro.core.protocol import EstimateReport, MeanEstimator, empirical_mse  # noqa: F401
from repro.core.collectives import compressed_mean, partial_mean  # noqa: F401
from repro.core.wire import WireCodec  # noqa: F401
