"""Compressed mean estimation as a mesh collective (DESIGN.md §2).

These functions run *inside* ``jax.shard_map`` with the compression axes
manual.  They replace an exact ``pmean`` over those axes by the paper's
encode → communicate → decode pipeline:

* ``gather_decode``  — faithful star protocol (§2, §4.4): each node encodes
  independently (Def. 2.1, via fold_in(axis_index)); the compressed wire
  payloads are all_gathered; every node runs the averaging decoder locally.
  The §4.4 seed trick is realized for free: peers regenerate each other's
  support sets from the shared per-step key + peer index, so only values
  (and the μ_i scalars) hit the wire.

* ``shared_support`` — TPU-native variant: one support set for all nodes
  (shared seed), so the averaged wire values can ride a plain psum of a
  length-k buffer (ring-bandwidth optimal).  MSE closed form:
  :func:`repro.core.mse.mse_fixed_k_shared`.

* ``bernoulli wire`` — real §4.4 wire path for the variable-size-support
  encoder (Eq. (1), uniform p): the support S_i = {j : u_j < p} depends
  only on the node's PRNG stream, so peers regenerate it from
  fold_in(key, rank) and only a capacity-padded value buffer (cap ≈ p·d
  plus slack, :func:`repro.core.comm_cost.bernoulli_capacity`) plus μ_i
  travels — honest sub-d wire traffic instead of the dense simulation.

* ``binary / ternary wire`` — packed bit-plane wire paths (§4.5 Eq. (11) /
  §7.1 Eq. (21)): each node ships a 1-bit (binary) or 2-bit (ternary)
  symbol plane packed into uint32 words, with centers — and, for ternary,
  a capacity-padded pass-through value segment — fused into the same
  buffer (:mod:`repro.core.bitplane`).  The branch choices are
  data-dependent so the plane travels explicitly (no §4.4 seed trick);
  the wire is ~d bits/node instead of 32·d.

* ``dense_sim``      — encode per node, exact pmean of the dense encoded
  vectors: bit-identical estimates to gather_decode with no wire savings;
  supports every encoder (incl. the §6 optimal-probability policies) and
  is used for correctness tests and MSE studies under shard_map.

Wire fusion: every mode ships the μ_i scalar *inside* the value buffer
(one concatenated collective per call) so a bucketed train step issues
exactly one collective launch per bucket (repro.train.bucketing).

All functions take and return a single flat f32 vector; pytree plumbing
lives in repro.train (grad flattening / bucketing / per-leaf policies).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import bitplane
from repro.core import comm_cost
from repro.core import encoders
from repro.core import types as t
from repro.kernels.fixed_k_encode import ops as fk

Axes = Tuple[str, ...]


def _axis_rank_size(axes: Axes):
    """Linear rank of this shard within the compression axes + node count."""
    rank = jnp.zeros((), jnp.int32)
    n = 1
    for ax in axes:
        rank = rank * compat.axis_size(ax) + jax.lax.axis_index(ax)
        n *= compat.axis_size(ax)
    return rank, n


def _center(x, policy: str):
    if policy == "zero":
        return jnp.zeros((), jnp.float32)
    if policy == "mean":
        return jnp.mean(x).astype(jnp.float32)
    if policy == "min":
        return jnp.min(x).astype(jnp.float32)
    raise ValueError(f"center policy {policy!r} not supported in collectives "
                     "(optimal centers need the §6 solver — reference path only)")


# --------------------------------------------------------------------------- #
# fixed-k (block-structured) compressed mean — the production encoder.
# --------------------------------------------------------------------------- #

def fixed_k_blocks(d: int, fraction: float) -> int:
    """kb: number of sampled blocks for a d-vector at the given fraction."""
    nb = fk.num_blocks(d)
    return max(1, min(nb, int(round(fraction * nb))))


def fixed_k_wire_slots(d: int, fraction: float) -> int:
    """Wire-dtype elements of one fixed-k gather buffer: kb·BLOCK values + μ."""
    return fixed_k_blocks(d, fraction) * fk.BLOCK + 1


def bernoulli_wire_slots(d: int, fraction: float) -> int:
    """Wire-dtype elements of one §4.4 Bernoulli buffer: cap values + μ."""
    return comm_cost.bernoulli_capacity(d, float(fraction)) + 1


def _fixed_k_wire(x, key, cfg: t.CompressionConfig, shared: bool):
    """Encode the local vector: (values (kb, BLOCK), mu, block_ids)."""
    d = x.size
    nb = fk.num_blocks(d)
    kb = fixed_k_blocks(d, cfg.encoder.fraction)
    if shared:
        ksup = key  # same subset on every node
    else:
        rank, _ = _axis_rank_size(cfg.axes)
        ksup = jax.random.fold_in(key, rank)
    ids = fk.sample_blocks(ksup, nb, kb)
    mu = _center(x, cfg.encoder.center)
    vals = fk.fixed_k_encode(x, ids, mu)
    return vals.astype(cfg.wire_dtype), mu, ids


def fixed_k_mean_shared(x, key, cfg: t.CompressionConfig):
    """shared_support mode: one psum of [k wire values ‖ μ] + scatter-decode.

    Collective traffic: kb·BLOCK + 1 wire-dtype elements — versus d
    full-precision elements for exact pmean — in a single launch (μ rides
    the tail slot of the value buffer).
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    vals, mu, ids = _fixed_k_wire(flat, key, cfg, shared=True)
    # the psum runs at the wire dtype (r = 16 bits/coordinate, matching the
    # paper's r and the bf16-native TPU all-reduce)
    wire = jnp.concatenate([vals.reshape(-1),
                            mu.astype(cfg.wire_dtype)[None]])
    wire = jax.lax.pmean(wire, cfg.axes).astype(jnp.float32)
    vals = wire[:-1].reshape(-1, fk.BLOCK)
    mu = wire[-1]
    y = fk.fixed_k_decode(vals, ids, mu, shape)
    return y.astype(dtype)


def fixed_k_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode mode: independent supports, one all_gather of
    [values ‖ μ] per node.

    Wire per node: n·(kb·BLOCK + 1) wire-dtype elements (receives),
    kb·BLOCK + 1 sends — the star protocol §4.4 with implicit seeds.
    Decode regenerates every peer's support locally and averages the dense
    reconstructions:  Y = mean μ_i + (1/n) Σ_i scatter(ids_i, vals_i).
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.size
    nb = fk.num_blocks(d)
    kb = fixed_k_blocks(d, cfg.encoder.fraction)
    rank, n = _axis_rank_size(cfg.axes)
    my_ids = fk.sample_blocks(jax.random.fold_in(key, rank), nb, kb)
    mu = _center(flat, cfg.encoder.center)
    vals = fk.fixed_k_encode(flat, my_ids, mu)

    # ---- the wire: values + centers only (supports regenerate from seed).
    wire = jnp.concatenate([vals.reshape(-1), mu[None]]).astype(cfg.wire_dtype)
    all_wire = _gather_nested(wire, cfg.axes).reshape(
        n, kb * fk.BLOCK + 1).astype(jnp.float32)
    all_vals = all_wire[:, :-1].reshape(n, kb, fk.BLOCK)
    all_mu = all_wire[:, -1]

    # ---- decode: Y = mean μ_i + (1/n) Σ_i scatter(ids_i, vals_i).
    def body(i, acc):
        ids_i = fk.sample_blocks(jax.random.fold_in(key, i), nb, kb)
        return acc.at[ids_i].add(all_vals[i])

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((nb, fk.BLOCK), jnp.float32))
    y = (acc / n + jnp.mean(all_mu)).reshape(-1)[:d]
    return y.reshape(shape).astype(dtype)


# --------------------------------------------------------------------------- #
# Bernoulli (variable-size-support) wire path — the §4.4 seed trick.
# --------------------------------------------------------------------------- #

def _bernoulli_support(key, d: int, p):
    """The S_i of Eq. (1) under uniform probs: data-independent, so any peer
    regenerates it from the shared per-step key + node index alone."""
    u = jax.random.uniform(key, (d,), dtype=jnp.float32)
    return u < p


def bernoulli_pack(flat, key, p: float, cap: int, mu):
    """Compact the Eq. (1) encoding into a (cap,) value buffer.

    Sent coordinates land at their support-rank position; coordinates whose
    rank overflows ``cap`` (≈6σ tail, see comm_cost.bernoulli_capacity) are
    dropped — the decoder regenerates the same ranks and drops them too, so
    encode/decode stay consistent (cost: a ~1e-9-probability bias toward μ
    on the dropped coordinates).
    """
    d = flat.shape[0]
    sent = _bernoulli_support(key, d, p)
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    scaled = flat / p - (1.0 - p) / p * mu
    idx = jnp.where(sent & (pos < cap), pos, cap)  # cap == out-of-bounds
    return jnp.zeros((cap,), jnp.float32).at[idx].set(scaled, mode="drop")


def bernoulli_unpack(buf, key, p: float, cap: int, mu, d: int):
    """Regenerate node ``key``'s support and reconstruct its dense Y_i."""
    sent = _bernoulli_support(key, d, p)
    pos = jnp.cumsum(sent.astype(jnp.int32)) - 1
    valid = sent & (pos < cap)
    vals = buf[jnp.clip(pos, 0, cap - 1)]
    return jnp.where(valid, vals, mu)


def _star_mean_gather(x, key, cfg: t.CompressionConfig, pack_fn, unpack_fn):
    """Shared star-protocol scaffold for the variable-support wire paths.

    Pack the local (d,) f32 vector into one flat wire buffer, all_gather
    it over cfg.axes, reconstruct every peer's dense Y_i locally and
    average: Y = (1/n) Σ_i unpack(wire_i).  ``pack_fn(flat, kenc)`` builds
    the node's buffer; ``unpack_fn(row, i)`` decodes peer i's row.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.size
    rank, n = _axis_rank_size(cfg.axes)
    buf = pack_fn(flat, jax.random.fold_in(key, rank))
    all_buf = _gather_nested(buf, cfg.axes).reshape(n, buf.shape[0])

    def body(i, acc):
        return acc + unpack_fn(all_buf[i], i)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((d,), jnp.float32))
    return (acc / n).reshape(shape).astype(dtype)


def bernoulli_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for the Bernoulli encoder with a real wire format.

    Each node all_gathers one [cap value slots ‖ μ] buffer; peers
    regenerate the supports from fold_in(key, i).  Bit accounting:
    comm_cost.cost_sparse_seed_capacity(n, cap, spec) — the static-shape
    realization of Eq. (10).
    """
    d = x.size
    p = float(cfg.encoder.fraction)
    cap = comm_cost.bernoulli_capacity(d, p)

    def pack(flat, kenc):
        mu = _center(flat, cfg.encoder.center)
        buf = bernoulli_pack(flat, kenc, p, cap, mu)
        return jnp.concatenate([buf, mu[None]]).astype(cfg.wire_dtype)

    def unpack(row, i):
        row = row.astype(jnp.float32)
        return bernoulli_unpack(row[:-1], jax.random.fold_in(key, i),
                                p, cap, row[-1], d)

    return _star_mean_gather(x, key, cfg, pack, unpack)


# --------------------------------------------------------------------------- #
# Binary / ternary packed bit-plane wire paths (§4.5 / §7.1).
# --------------------------------------------------------------------------- #

def binary_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for binary quantization with the packed 1-bit plane.

    Each node all_gathers one uint32 buffer of [sign plane ‖ vmin, vmax]
    (:mod:`repro.core.bitplane`); every peer reconstructs the dense
    Y_i = vmin_i + bit_ij·Δ_i locally and averages.  Bit accounting:
    comm_cost.cost_binary_packed — Eq. (11)'s 2·n·r + n·d rounded up to
    wire words, no seed term (the plane is data-dependent and travels).
    """
    d = x.size
    return _star_mean_gather(
        x, key, cfg,
        lambda flat, kenc: bitplane.binary_pack(flat, kenc, cfg.wire_dtype),
        lambda row, i: bitplane.binary_unpack(row, d, cfg.wire_dtype))


def ternary_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for the ternary encoder (Eq. (21)) with a 2-bit plane.

    Wire per node: [2-bit branch plane ‖ cap pass-through value slots ‖
    c1, c2] in one uint32 buffer; the pass-through count is Binomial(d,
    p_pass), so the value segment is capacity-padded exactly like the
    Bernoulli §4.4 path.  Bit accounting: comm_cost.cost_ternary_packed.
    """
    d = x.size
    p_pass = float(cfg.encoder.fraction)
    cap = comm_cost.bernoulli_capacity(d, p_pass)
    return _star_mean_gather(
        x, key, cfg,
        lambda flat, kenc: bitplane.ternary_pack(flat, kenc, p_pass, cap,
                                                 cfg.wire_dtype),
        lambda row, i: bitplane.ternary_unpack(row, d, cap, cfg.wire_dtype))


def _gather_nested(v, axes: Axes):
    """all_gather over possibly-multiple axes, flattening the node dim."""
    out = v[None]
    for ax in reversed(axes):
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
    return out


# --------------------------------------------------------------------------- #
# dense simulation (any encoder) + dispatch.
# --------------------------------------------------------------------------- #

def dense_sim_mean(x, key, cfg: t.CompressionConfig):
    """Encode locally (independent), exact pmean of dense encodings.

    Estimate-distribution-identical to gather_decode; used to exercise the
    bernoulli / binary / ternary encoders under shard_map.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    rank, _ = _axis_rank_size(cfg.axes)
    kenc = jax.random.fold_in(key, rank)
    encd = encoders.encode(kenc, flat, cfg.encoder)
    y = jax.lax.pmean(encd.y.astype(jnp.float32), cfg.axes)
    return y.reshape(shape).astype(dtype)


def gather_wire_kind(cfg: t.CompressionConfig) -> str:
    """The wire format gather_decode mode will actually use for ``cfg``.

    One of "fixed_k" | "bernoulli" | "binary" | "ternary" | "dense".
    This is THE dispatch rule — compressed_mean routes through it, and
    accounting (repro.train.bucketing.bucket_wire_bits) must consult it so
    configs that fall back to the dense simulation (§6 optimal
    probabilities, optimal centers on the seed-trick path) are charged
    dense f32 bits, not the compressed wire they never ride.
    """
    e = cfg.encoder
    if e.kind == "fixed_k":
        return "fixed_k"
    if (e.kind == "bernoulli" and e.probs == "uniform"
            and e.center in ("zero", "mean", "min")):
        # §4.4 seed trick: the uniform-p support is data-independent, so
        # it regenerates peer-side and only values + μ hit the wire.
        return "bernoulli"
    if e.kind == "binary":
        # §4.5: data-dependent branch probabilities, so the packed 1-bit
        # plane travels explicitly (no seed trick possible).
        return "binary"
    if e.kind == "ternary" and e.probs == "uniform":
        # §7.1: 2-bit plane + capacity-padded pass-through values.
        return "ternary"
    # data-dependent probabilities (§6 optimal policies): message
    # sizes/planes are not wire-modelled yet — simulate densely.
    return "dense"


def compressed_mean(x, key, cfg: t.CompressionConfig):
    """Estimate mean(x) over cfg.axes under the configured protocol.

    Must be called inside shard_map with cfg.axes manual.  Unbiased:
    E[result] = pmean(x, cfg.axes) for every mode (Lemmas 3.1/3.3).
    """
    if cfg.mode == "none" or x.size < cfg.min_compress_size:
        return jax.lax.pmean(x, cfg.axes)
    if cfg.mode == "shared_support":
        return fixed_k_mean_shared(x, key, cfg)
    if cfg.mode == "gather_decode":
        fn = {"fixed_k": fixed_k_mean_gather,
              "bernoulli": bernoulli_mean_gather,
              "binary": binary_mean_gather,
              "ternary": ternary_mean_gather,
              "dense": dense_sim_mean}[gather_wire_kind(cfg)]
        return fn(x, key, cfg)
    if cfg.mode == "dense_sim":
        return dense_sim_mean(x, key, cfg)
    raise ValueError(cfg.mode)


def partial_mean(x, alive, axes: Axes):
    """Straggler-tolerant exact mean over the live nodes only.

    ``alive``: local 0/1 scalar.  Unbiased for the survivors' mean — the
    averaging decoder is n-agnostic (DESIGN.md §5).
    """
    num = jax.lax.psum(x * alive, axes)
    den = jnp.maximum(jax.lax.psum(alive, axes), 1.0)
    return num / den
