"""Compressed mean estimation as a mesh collective (docs/DESIGN.md §2–§3).

These functions run *inside* ``jax.shard_map`` with the compression axes
manual.  They replace an exact ``pmean`` over those axes by the paper's
encode → communicate → decode pipeline.

Since the WireCodec refactor the per-protocol wire formats live in
:mod:`repro.core.wire` — each protocol is a registered codec declaring
``pack``/``unpack``/``wire_slots``/``wire_bits`` and its reduce kind — and
:func:`compressed_mean` is a thin dispatcher over ``wire.resolve(cfg)``:

* ``fixed_k`` (gather_decode) — faithful star protocol (§2, §4.4): each
  node encodes independently (Def. 2.1, via fold_in(axis_index)); the
  compressed wire payloads are all_gathered; every node runs the averaging
  decoder locally.  The §4.4 seed trick is realized for free: peers
  regenerate each other's support sets from the shared per-step key + peer
  index, so only values (and the μ_i scalars) hit the wire.

* ``fixed_k_shared`` — TPU-native variant: one support set for all nodes
  (shared seed), so the averaged wire values ride a plain psum of a
  length-k buffer (ring-bandwidth optimal).  MSE closed form:
  :func:`repro.core.mse.mse_fixed_k_shared`.

* ``bernoulli`` — real §4.4 wire path for the variable-size-support
  encoder (Eq. (1), uniform p): supports regenerate from fold_in(key,
  rank) and only a capacity-padded value buffer plus μ_i travels.

* ``binary`` / ``ternary`` — packed bit-plane wire paths (§4.5 Eq. (11) /
  §7.1 Eq. (21)): a 1-bit (binary) or 2-bit (ternary) symbol plane packed
  into uint32 words, with centers — and, for ternary, a capacity-padded
  pass-through value segment — fused into the same buffer
  (:mod:`repro.core.bitplane`).  ``ternary_opt`` is the §6 per-coordinate
  optimal (p1, p2) split on the identical plane/capacity wire.

* ``dense`` — encode per node, exact pmean of the dense encoded vectors:
  bit-identical estimates to gather_decode with no wire savings; supports
  every encoder (incl. the §6 optimal-probability policies).

* ``rotated_*`` — any of the above composed with the §7.2 seeded
  per-bucket Hadamard rotation (:mod:`repro.core.wire.rotated`): rotate
  once before encode, unrotate once after the averaging decode, seed-only
  wire overhead.  Activated by ``cfg.encoder.rotation``.

* ``ef_*`` — any of the above composed with the error-feedback layer
  (:mod:`repro.core.wire.ef`): residual-corrected contractive messages in
  the inner codec's exact wire format, residual state local.  Activated by
  ``cfg.error_feedback``; thread the residual via
  :func:`compressed_mean_stateful`.

Wire fusion: every mode ships the per-node scalars *inside* the value
buffer (one concatenated collective per call) so a bucketed train step
issues exactly one collective launch per bucket (repro.train.bucketing).

All functions take and return a single flat f32 vector; pytree plumbing
lives in repro.train (grad flattening / bucketing / per-leaf policies).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import types as t
from repro.core import wire
from repro.core.wire import base as _wire_base
from repro.core.wire import codecs as _wire_codecs

Axes = Tuple[str, ...]

# Scaffold helpers live in repro.core.wire.base now; the historical names
# are kept for tests and external callers.
_axis_rank_size = _wire_base.axis_rank_size
_gather_nested = _wire_base.gather_nested
_center = _wire_base.center

# Wire-geometry helpers + the §4.4 Bernoulli buffer format (re-exported:
# tests and comm_cost docs reference them under these names).
fixed_k_blocks = _wire_codecs.fixed_k_blocks
fixed_k_wire_slots = _wire_codecs.fixed_k_wire_slots
bernoulli_wire_slots = _wire_codecs.bernoulli_wire_slots
bernoulli_pack = _wire_codecs.bernoulli_pack
bernoulli_unpack = _wire_codecs.bernoulli_unpack


# --------------------------------------------------------------------------- #
# Named per-codec entry points (thin wrappers over the registry).
# --------------------------------------------------------------------------- #

def fixed_k_mean_shared(x, key, cfg: t.CompressionConfig):
    """shared_support mode: one psum of [k wire values ‖ μ] + scatter-decode."""
    return wire.get("fixed_k_shared").mean(x, key, cfg)


def fixed_k_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode mode: independent supports, one all_gather of
    [values ‖ μ] per node."""
    return wire.get("fixed_k").mean(x, key, cfg)


def bernoulli_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for the Bernoulli encoder with the real §4.4 wire."""
    return wire.get("bernoulli").mean(x, key, cfg)


def binary_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for binary quantization with the packed 1-bit plane."""
    return wire.get("binary").mean(x, key, cfg)


def ternary_mean_gather(x, key, cfg: t.CompressionConfig):
    """gather_decode for the ternary encoder with the packed 2-bit plane."""
    return wire.get("ternary").mean(x, key, cfg)


def dense_sim_mean(x, key, cfg: t.CompressionConfig):
    """Encode locally (independent), exact pmean of dense encodings."""
    return wire.get("dense").mean(x, key, cfg)


# --------------------------------------------------------------------------- #
# Dispatch.
# --------------------------------------------------------------------------- #

def gather_wire_kind(cfg: t.CompressionConfig) -> str:
    """The base wire format gather_decode mode will actually use for ``cfg``.

    One of "fixed_k" | "bernoulli" | "binary" | "ternary" | "dense".
    Delegates to the codec registry (repro.core.wire.registry.gather_kind)
    — THE dispatch rule that compressed_mean, the accounting
    (comm_cost.cost_config, bucketing.bucket_wire_bits) and the presets all
    consult, so configs that fall back to the dense simulation (§6 optimal
    probabilities, optimal centers on the seed-trick path) are charged
    dense f32 bits, not the compressed wire they never ride.  The §7.2
    rotation flag composes on top and does not change the base kind.
    """
    return wire.gather_kind(cfg)


def _masked_exact_mean(x, drop_mask, cfg: t.CompressionConfig):
    """Exact survivors-only mean for the uncompressed paths.

    ``drop_mask`` is indexed by this node's rank over ``cfg.axes`` (the
    codec axes — the drop unit is the cross-host peer; inner pre-reduce
    peers are assumed healthy, docs/DESIGN.md §14) and reuses the
    :func:`partial_mean` contract: renormalize by the survivor count, NaN
    when everyone is dropped.
    """
    rank, _ = _axis_rank_size(tuple(cfg.axes))
    keep = drop_mask[rank].astype(x.dtype)
    return partial_mean(x * keep, keep, tuple(cfg.inner_axes) + tuple(cfg.axes))


def compressed_mean(x, key, cfg: t.CompressionConfig, drop_mask=None):
    """Estimate mean(x) over cfg.axes under the configured protocol.

    Must be called inside shard_map with cfg.axes manual.  Unbiased for
    every EF-free mode: E[result] = pmean(x, cfg.axes) (Lemmas 3.1/3.3;
    the rotated compositions inherit unbiasedness from QᵀQ = I).  Stateful
    codecs (``cfg.error_feedback``) run one zero-state round here with the
    state discarded — their contractive-twin messages are deliberately
    *biased* compressors, so a single EF round is biased and only payload
    /HLO measurement belongs on this entry point; training threads
    residuals through :func:`compressed_mean_stateful`, whose *time
    average* is what recovers the mean (docs/DESIGN.md §8).

    ``drop_mask`` is an optional traced (n,) 0/1 operand over the ranks of
    ``cfg.axes`` (1 = alive): dropped peers are excluded at decode time and
    the estimate renormalizes over the survivors (partial_mean contract —
    NaN when nobody survives).  It is data, never a static argument, so a
    FailurePlan can change the dropped set every step with zero recompiles
    (tests/distributed_checks/robust_decode_check.py pins the jit cache
    size).  The wire payload is unchanged — exclusion happens after the
    gather (docs/DESIGN.md §14).
    """
    if cfg.mode == "none" or x.size < cfg.min_compress_size:
        if drop_mask is None:
            return jax.lax.pmean(x, tuple(cfg.inner_axes) + tuple(cfg.axes))
        return _masked_exact_mean(x, drop_mask, cfg)
    return wire.resolve(cfg).mean(x, key, cfg, drop_mask)


def compressed_mean_stateful(x, state, key, cfg: t.CompressionConfig,
                             drop_mask=None):
    """One stateful round of the resolved codec: (estimate, new_state).

    The generalization of :func:`compressed_mean` for codecs that thread
    local per-bucket state — the error-feedback residual being the
    production case (repro.core.wire.ef).  ``state`` may be shaped like
    ``x`` or flat; it is threaded flat through the codec and returned in
    its original shape.  Stateless codecs pass the state through untouched,
    so callers that own state need no dispatch of their own.  ``drop_mask``
    as in :func:`compressed_mean`; a dropped peer's residual stays local
    and re-enters through its own future messages.
    """
    if cfg.mode == "none" or x.size < cfg.min_compress_size:
        if drop_mask is None:
            y = jax.lax.pmean(x, tuple(cfg.inner_axes) + tuple(cfg.axes))
        else:
            y = _masked_exact_mean(x, drop_mask, cfg)
        return y, state
    codec = wire.resolve(cfg)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    st = state.reshape(-1).astype(jnp.float32)
    y, st2 = codec.mean_flat_stateful(flat, st, key, cfg, drop_mask)
    return (y.reshape(shape).astype(dtype),
            st2.reshape(state.shape).astype(state.dtype))


def partial_mean(x, alive, axes: Axes):
    """Straggler-tolerant exact mean over the live nodes only.

    ``alive``: local 0/1 scalar.  Unbiased for the survivors' mean — the
    averaging decoder is n-agnostic (docs/DESIGN.md §5).

    All-dead contract: when every node is masked out the survivors' mean
    does not exist, and the result is NaN (0/0) by design.  The historical
    ``maximum(psum(alive), 1.0)`` denominator clamp silently returned an
    all-zero vector instead — indistinguishable from a genuine zero mean,
    so a failure-plan bug upstream (or a fully partitioned mesh) would
    train on fabricated zeros without any signal.  NaN poisons the step
    loudly and is checkable (``jnp.isnan``); callers that can tolerate
    total failure must branch on ``psum(alive) > 0`` themselves.  With at
    least one survivor the result is bit-identical to the clamped version
    (the clamp only engaged at den == 0).
    """
    num = jax.lax.psum(x * alive, axes)
    den = jax.lax.psum(alive, axes)
    return num / den
