"""Configuration types for the randomized distributed mean estimation core.

The vocabulary follows the paper (Konečný & Richtárik, 2016):

* *encoder* ``alpha``  — the randomized lossy transform applied per node (§3).
* *communication protocol* ``beta`` — the bit-level wire format (§4).
* *decoder* ``gamma`` — the server-side estimate; always averaging here (§2).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

# Number of bits for one floating point value on the wire ("r" in the paper).
# bf16 is the TPU-native wire dtype; the paper's plots use r=16 as well.
DEFAULT_R_BITS = 16
# Bits to send one node center mu_i ("r bar").
DEFAULT_RBAR_BITS = 16
# Bits for a random seed identifying a sampled support set ("r bar_s", §4.4).
DEFAULT_RSEED_BITS = 32

ENCODERS = ("identity", "bernoulli", "fixed_k", "binary", "ternary")
CENTERS = ("zero", "mean", "min", "optimal")
PROBS = ("uniform", "optimal")
MODES = ("none", "gather_decode", "shared_support", "dense_sim")

# Decode-side aggregation policies (DESIGN.md §14).  "mean" is the paper's
# averaging decoder γ (§2); the rest are the robust coordinate-wise
# reductions of the f-of-n trimming idiom (approximate consensus, JACM86):
# "trim(f)" / "mean_trim(f)" carry an integer trim count in the string.
DECODE_POLICIES = ("mean", "median", "trim", "mean_trim")
_POLICY_RE = re.compile(r"(trim|mean_trim)\((\d+)\)")


def parse_decode_policy(policy: str) -> Tuple[str, int]:
    """``cfg.decode_policy`` string → ``(kind, f)``.

    ``"mean"`` / ``"median"`` → ``("mean", 0)`` / ``("median", 0)``;
    ``"trim(f)"`` / ``"mean_trim(f)"`` → ``("trim", f)`` /
    ``("mean_trim", f)`` with integer f ≥ 0.

    Normalization rule: ``trim(0)`` IS the mean — a trimmed mean that trims
    nothing averages all n rows — so it parses to ``("mean", 0)`` and
    dispatches to the codec's fused averaging decode verbatim (bit-for-bit
    equality is pinned by tests/test_robust_decode.py).  ``mean_trim(0)``
    does NOT normalize: it is the midpoint (min+max)/2 of the untrimmed
    range, a different estimator.
    """
    m = _POLICY_RE.fullmatch(policy.strip())
    if m:
        kind, f = m.group(1), int(m.group(2))
        if kind == "trim" and f == 0:
            return "mean", 0
        return kind, f
    if policy in ("mean", "median"):
        return policy, 0
    raise ValueError(
        f"unknown decode_policy {policy!r}; want 'mean', 'median', "
        "'trim(f)' or 'mean_trim(f)' with integer f >= 0")


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Parameters of the encoding protocol alpha (§3).

    Attributes:
      kind: which member of the family.
        * ``identity``  — Example 1 (lossless).
        * ``bernoulli`` — variable-size-support protocol, Eq. (1).
        * ``fixed_k``   — fixed-size-support protocol, Eq. (4).
        * ``binary``    — Example 4 (recovers Suresh et al. [10]).
        * ``ternary``   — the k-ary (k=3) extension, Eq. (21).
      fraction: expected fraction of coordinates sent.  For ``bernoulli``
        with uniform probs this is ``p``; for ``fixed_k`` it is ``k/d``
        (``k = max(1, round(fraction*d))``).  Ignored by ``identity`` and
        ``binary``.
      probs: ``uniform`` (p_ij = p for all i, j) or ``optimal``
        (water-filled p_ij ∝ |X_i(j) − μ_i|, §6.1).
      center: node-center policy for μ_i — ``zero`` (data-independent,
        r̄ = 0), ``mean`` (per-node coordinate average, §5.2), ``min``
        (used by Example 4), or ``optimal`` (Eq. (16) /
        alternating minimization, §6).
      rotation: apply the randomized Hadamard pre-rotation (§7.2) before
        encoding and undo it after decoding.  Honored by the reference
        stack (repro.core.protocol) and by the wire layer: the codec
        registry wraps the resolved codec in the composable rotated
        pre-transform (repro.core.wire.rotated, seed-only wire overhead),
        rotating once per bucket.
    """

    kind: str = "fixed_k"
    fraction: float = 1.0 / DEFAULT_R_BITS  # paper's 1-bit point: p = 1/r
    probs: str = "uniform"
    center: str = "mean"
    rotation: bool = False

    def __post_init__(self):
        if self.kind not in ENCODERS:
            raise ValueError(f"unknown encoder kind {self.kind!r}; want one of {ENCODERS}")
        if self.probs not in PROBS:
            raise ValueError(f"unknown probs policy {self.probs!r}")
        if self.center not in CENTERS:
            raise ValueError(f"unknown center policy {self.center!r}")
        if not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Parameters of the communication protocol beta (§4).

    ``protocol`` selects the bit-cost model:
      * ``naive``         — d full floats per node (§4.1).
      * ``varying``       — 1 flag bit per coordinate + r bits when sent (§4.2).
      * ``sparse``        — (⌈log2 d⌉ + r) bits per sent coordinate (§4.3).
      * ``sparse_seed``   — r bits per sent coordinate + seed (§4.4; only for
                            fixed_k or uniform-p encoders).
      * ``binary``        — 2r + d bits per node (§4.5).
      * ``ternary``       — 2r + 2d + p_pass·d·r bits per node (§7.1,
                            Eq. (21): 2-bit plane + pass-through values).
    """

    protocol: str = "sparse_seed"
    r_bits: int = DEFAULT_R_BITS
    rbar_bits: int = DEFAULT_RBAR_BITS
    rseed_bits: int = DEFAULT_RSEED_BITS

    def __post_init__(self):
        if self.protocol not in ("naive", "varying", "sparse", "sparse_seed",
                                 "binary", "ternary"):
            raise ValueError(f"unknown communication protocol {self.protocol!r}")


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Gradient-bucketing knobs (:mod:`repro.train.bucketing`).

    The paper's cost model (§4–§6) charges per communicated coordinate, but
    a real train step also pays a fixed collective-launch overhead per
    call.  Bucketing flattens the grad pytree into a few fixed-capacity
    f32 buckets grouped by sync signature and issues ONE collective per
    bucket instead of one per leaf.

    Attributes:
      enabled: route train-step gradient sync through buckets.
      capacity: max f32 elements per bucket (default 4M ≈ 16 MiB of f32).
        A single leaf larger than this gets a dedicated oversize bucket —
        leaves are never split across buckets, so pack→scatter round-trips
        the pytree bit-exactly.
      overlap: pipeline the per-bucket collectives into the backward pass
        (:func:`repro.train.bucketing.overlap_params`): each bucket's
        pack→collective→unpack is emitted inside the gradient computation
        at the bucket's readiness point (``Bucket.ready`` — the
        backward-order index of its last-produced leaf) instead of after
        the full loss graph.  Numerically schedule-independent: same codec
        rounds, same PRNG ``fold_in`` chain, so overlapped grads equal the
        post-backward path bit-for-bit (tests/distributed_checks/
        overlap_check.py).  Engaged by the train step when
        ``microbatches == 1``; with grad accumulation the sync runs once on
        the accumulated grads after the scan (compressed codecs are
        nonlinear, so per-microbatch sync would change the estimate), and
        the post-backward path is used regardless of this flag.
    """

    enabled: bool = True
    capacity: int = 1 << 22
    overlap: bool = True

    def __post_init__(self):
        if self.capacity <= 0:
            raise ValueError(f"bucket capacity must be positive, got {self.capacity}")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """End-to-end configuration for compressed gradient aggregation.

    This is the knob surfaced by the training framework; it bundles an
    :class:`EncoderSpec` with the mesh-level execution ``mode``:

      * ``none``           — exact psum/pmean (baseline; Example 5 with p=1).
      * ``gather_decode``  — paper-faithful star protocol: all_gather the
        compressed representations over ``axes``, decode (average) locally.
        Encoders are independent across nodes (Def. 2.1) via
        ``fold_in(axis_index)``.
      * ``shared_support`` — TPU-native variant (DESIGN.md §2): all nodes
        sample the *same* fixed-k support, so the collective is a psum of a
        length-k buffer.  Violates Def. 2.1 independence deliberately; exact
        MSE in :func:`repro.core.mse.mse_fixed_k_shared`.
      * ``dense_sim``      — functional simulation: encode per node, exact
        pmean of the *dense* encoded vectors.  Bit-identical estimates to
        gather_decode but without the wire savings; used to test encoders
        under shard_map and to support variable-size-support encoders whose
        message sizes are data-dependent (not SPMD-shape friendly).

    ``axes`` are the mesh axes over which the mean is estimated (e.g.
    ``("data",)`` in-pod, ``("pod",)`` for cross-DCN-only compression, or
    ``("pod", "data")``).

    ``inner_axes`` select the two-level hierarchical schedule (docs/
    DESIGN.md §11): the mean over the *inner* (fast, intra-host) axes is
    taken exactly with one pmean before the codec runs, and the codec
    compresses only across ``axes`` (the slow, cross-host link).  The
    codec's effective node count is then the cross-host group size — the
    accounting helper is :func:`repro.core.wire.effective_nodes`.

    ``scatter_decode`` selects the reduce-scatter decode decomposition for
    the linear gather codecs (fixed_k / bernoulli and their rotated/EF
    wraps): each node decodes only its contiguous 1/m shard of the bucket
    and one all_gather of decoded shards reassembles the estimate, cutting
    per-node decode FLOPs and PRNG draws from O(n·d) to O(n·d/m).  The
    shard axes are ``inner_axes`` when non-empty (hierarchical schedule,
    DESIGN.md §11: m = the inner-group size, the shard gather rides the
    fast intra-host link for free) and ``axes`` themselves otherwise
    (flat mesh, DESIGN.md §12: m = n, the shard gather rides the main
    mesh and is billed by ``WireCodec.scatter_bits``).  Bit-exact vs the
    flat decode by construction (same per-coordinate arithmetic, only
    partitioned); requires a codec that declares ``scatter_supported``
    (validated by the registry at resolve time).
    """

    encoder: EncoderSpec = dataclasses.field(default_factory=EncoderSpec)
    mode: str = "none"
    axes: Tuple[str, ...] = ("data",)
    inner_axes: Tuple[str, ...] = ()
    scatter_decode: bool = False
    error_feedback: bool = False
    # Decode-side aggregation over the n per-peer reconstructions
    # (DESIGN.md §14): "mean" (the paper's averaging decoder γ, the fused
    # fast path), "median", "trim(f)" (coordinate-wise trimmed mean: drop
    # the f largest and f smallest of the n values per coordinate, average
    # the rest) or "mean_trim(f)" (the JACM86 fault-tolerant midpoint:
    # average of the smallest and largest survivors after trimming f from
    # each end).  Decode-only: the wire bytes of every codec are identical
    # across policies (golden wire matrix passes unregenerated), and the
    # robust policies require per-peer wire rows, so the registry rejects
    # them for the "psum" codecs (fixed_k_shared / dense) at resolve time.
    decode_policy: str = "mean"
    wire_dtype: str = "bfloat16"
    # Gradient bucketing (repro.train.bucketing): one collective per bucket
    # instead of one per pytree leaf.  Applies to every mode incl. "none"
    # (exact buckets batch the plain psum-means too).
    bucket: BucketSpec = dataclasses.field(default_factory=BucketSpec)
    # Leaves smaller than this many elements are aggregated exactly (psum):
    # biases/norm scales are a negligible fraction of the wire bytes and are
    # disproportionately harmed by sparsification.
    min_compress_size: int = 65536

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; want one of {MODES}")
        if self.mode == "shared_support" and self.encoder.kind not in ("fixed_k", "identity"):
            raise ValueError("shared_support mode requires the fixed_k encoder")
        overlap = set(self.inner_axes) & set(self.axes)
        if overlap:
            raise ValueError(
                f"inner_axes and axes must be disjoint; both contain "
                f"{sorted(overlap)}")
        parse_decode_policy(self.decode_policy)  # raises on bad strings


def fixed_k_from_fraction(d: int, fraction: float) -> int:
    """k = |S_i| for the fixed-size-support encoder, from a target fraction."""
    return max(1, min(d, int(round(fraction * d))))
