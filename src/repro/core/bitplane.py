"""Packed bit-plane wire formats for binary / ternary quantization.

This module realizes the paper's extreme operating point — ~1–2 bits per
coordinate (§4.5 Eq. (11), §7.1 Eq. (21)) — as honest SPMD wire buffers
instead of the dense f32 simulation.  Every buffer is a single flat uint32
vector so one bucket still costs one collective launch
(:mod:`repro.core.collectives` all_gathers it as-is).

Wire format (all segments uint32 words, concatenated)
-----------------------------------------------------

``binary`` (Example 4 / Suresh et al. [10]; 1 bit/coordinate):

  ============  =======================  =====================================
  words         count                    content
  ============  =======================  =====================================
  plane         PW = ceil(d/32)          sign plane: bit j of word j//32 at
                                         offset j%32 is 1 iff Y(j) = X^max
  tail centers  CW = ceil(2*r/32)        (vmin, vmax) at wire precision r
  ============  =======================  =====================================

``ternary`` (Eq. (21) with p1 = p2 = (1 − p_pass)/2, c1 = X^min,
c2 = X^max; 2 bits/coordinate + p_pass full-precision values):

  ============  =======================  =====================================
  words         count                    content
  ============  =======================  =====================================
  plane         PW = ceil(2d/32)         2-bit branch index per coordinate:
                                         0 → c1 ("down"), 1 → c2 ("up"),
                                         2 → pass-through (3 unused)
  values        VW = ceil(cap*r/32)      capacity-padded pass-through values
                                         Y(j) in support-rank order
  tail centers  CW = ceil(2*r/32)        (c1, c2) at wire precision r
  ============  =======================  =====================================

Tail-slot centers: the per-node scalars ride the same uint32 buffer
(bitcast f32, or two bf16 packed per word at r = 16), mirroring how μ rides
the value buffer in the fixed-k / Bernoulli paths — no second launch.

Pass-through handling: the pass-through count |{j : sym_j = 2}| is
Binomial(d, p_pass), not SPMD-static, so like the Bernoulli §4.4 path the
value segment is capacity-padded (:func:`repro.core.comm_cost
.bernoulli_capacity` with p = p_pass).  Coordinates whose support rank
overflows ``cap`` are dropped by the encoder and replaced by (c1 + c2)/2 by
the decoder — a P ≈ 1e-9 (6σ) event; both sides agree on the rank order so
the substitution is symmetric.  Unlike §4.4 there is NO seed term: the
plane itself travels (binary/ternary branch choices are data-dependent and
cannot regenerate peer-side).

Sampling is bit-identical to :mod:`repro.core.encoders` (same key, same
``jax.random.uniform`` draws), so at f32 wire precision
pack → unpack reproduces ``encode_binary(key, x).y`` /
``encode_ternary(key, x, …).y`` exactly — the gather collectives built on
these buffers agree with ``dense_sim_mean`` to float tolerance (verified in
tests/distributed_checks/quantized_wire_check.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encoders
from repro.core import types as t
from repro.kernels.bernoulli_wire import ref as bw_ref
from repro.kernels.bitplane import ops as bp_ops

WORD = 32


def wire_bits(wire_dtype) -> int:
    """Bits per wire float (r): 32 for float32, 16 for bfloat16/float16."""
    r = int(jnp.dtype(wire_dtype).itemsize) * 8
    if r not in (16, 32):
        raise ValueError(f"unsupported wire dtype {wire_dtype!r} (r={r})")
    return r


def float_words(count: int, wire_dtype) -> int:
    """uint32 words carrying ``count`` floats at wire precision."""
    return -(-count * wire_bits(wire_dtype) // WORD)


def floats_to_words(v, wire_dtype):
    """(m,) f32 -> (float_words(m),) uint32 at wire precision.

    f32 wire: bitcast.  16-bit wire: round to the wire dtype and pack two
    halves per word, little-endian (element 2i in the low half).
    """
    v = v.reshape(-1).astype(jnp.float32)
    if wire_bits(wire_dtype) == 32:
        return jax.lax.bitcast_convert_type(v, jnp.uint32)
    h = jax.lax.bitcast_convert_type(
        v.astype(wire_dtype), jnp.uint16).astype(jnp.uint32)
    h = jnp.pad(h, (0, (-h.shape[0]) % 2)).reshape(-1, 2)
    return h[:, 0] | (h[:, 1] << jnp.uint32(16))


def words_to_floats(w, count: int, wire_dtype):
    """Inverse of :func:`floats_to_words`; returns (count,) f32."""
    w = w.reshape(-1)
    if wire_bits(wire_dtype) == 32:
        return jax.lax.bitcast_convert_type(w, jnp.float32)[:count]
    halves = jnp.stack([w & jnp.uint32(0xFFFF), w >> jnp.uint32(16)],
                       axis=-1).reshape(-1)[:count]
    return jax.lax.bitcast_convert_type(
        halves.astype(jnp.uint16), jnp.dtype(wire_dtype)).astype(jnp.float32)


def rank_scatter(values, sent, cap: int):
    """Place ``values[j]`` of each sent coordinate at its support-rank slot.

    The capacity-padded value-segment layout shared by the Bernoulli §4.4
    buffer, the ternary pass-through segment and the error-feedback twins:
    ranks ≥ ``cap`` are dropped (the decoder regenerates the same ranks and
    drops them symmetrically).  Returns a (cap,) f32 buffer.

    Despite the name this is implemented as a rank-*select* gather
    (repro.kernels.bernoulli_wire.ref.rank_select): byte-identical slots to
    the historical d-wide ``.at[idx].set`` scatter, but ~10× faster on the
    CPU backend, where XLA lowers large scatters serially.
    """
    return bw_ref.rank_select(values.astype(jnp.float32), sent, cap)


def topcap_mask(scores, cap: int):
    """Boolean membership of the ``cap`` largest ``scores`` (ties → lowest
    index), without ``jax.lax.top_k``.

    ``scores`` must be non-negative f32 (|deviations|), so its uint32 bit
    pattern is order-isomorphic to its value: the cap-th largest score is
    found by a 32-step MSB-first bisection on the bit pattern — 32 fused
    compare+reduce passes instead of the O(d log d) sort XLA lowers
    ``top_k`` to on CPU (~5× faster at d = 2^20).  Ties at the threshold
    are resolved to the lowest indices, matching ``top_k``'s documented
    order, so the selected SET is identical for any input.
    """
    bits = scores.astype(jnp.float32).view(jnp.uint32)

    def body(k, thr):
        cand = thr | (jnp.uint32(1) << (31 - k))
        n_ge = jnp.sum((bits >= cand).astype(jnp.int32))
        return jnp.where(n_ge >= cap, cand, thr)

    # largest T with count(bits >= T) >= cap == the cap-th largest pattern
    thr = jax.lax.fori_loop(0, 32, body, jnp.uint32(0))
    need_ties = cap - jnp.sum((bits > thr).astype(jnp.int32))
    is_tie = bits == thr
    tie_rank = jnp.cumsum(is_tie.astype(jnp.int32))
    return (bits > thr) | (is_tie & (tie_rank <= need_ties))


# --------------------------------------------------------------------------- #
# Binary: 1-bit sign plane + (vmin, vmax) tail.
# --------------------------------------------------------------------------- #

def binary_wire_words(d: int, wire_dtype) -> int:
    """Total uint32 words of one node's binary wire buffer."""
    return bp_ops.num_words(d, 1) + float_words(2, wire_dtype)


def binary_words(bits, c_lo, c_hi, wire_dtype):
    """Assemble one binary wire buffer: [packed 1-bit plane ‖ (c_lo, c_hi)].

    THE binary buffer layout — both the stochastic encoder
    (:func:`binary_pack`) and the error-feedback twin
    (repro.core.wire.ef) emit through here, so
    :func:`binary_unpack` decodes either.
    """
    plane = bp_ops.pack_bits(bits.astype(jnp.uint32), 1)
    tail = floats_to_words(jnp.stack([c_lo, c_hi]), wire_dtype)
    return jnp.concatenate([plane, tail])


def binary_pack(flat, key, wire_dtype):
    """Encode (d,) f32 -> (binary_wire_words(d),) uint32 wire buffer.

    Uses encoders.encode_binary for the stochastic rounding (same PRNG
    stream as the dense simulation).
    """
    enc = encoders.encode_binary(key, flat)
    return binary_words(enc.support, enc.extras["vmin"], enc.extras["vmax"],
                        wire_dtype)


def binary_unpack(buf, d: int, wire_dtype):
    """Reconstruct the dense Y_i (f32) from one node's wire buffer."""
    pw = bp_ops.num_words(d, 1)
    bits = bp_ops.unpack_bits(buf[:pw], 1, d)
    c = words_to_floats(buf[pw:], 2, wire_dtype)
    return jnp.where(bits > 0, c[1], c[0])


# --------------------------------------------------------------------------- #
# Ternary: 2-bit branch plane + capacity-padded values + (c1, c2) tail.
# --------------------------------------------------------------------------- #

def ternary_wire_words(d: int, cap: int, wire_dtype) -> int:
    """Total uint32 words of one node's ternary wire buffer."""
    return (bp_ops.num_words(d, 2) + float_words(cap, wire_dtype)
            + float_words(2, wire_dtype))


def ternary_words(sym, vbuf, c1, c2, wire_dtype):
    """Assemble one ternary wire buffer: [2-bit plane ‖ values ‖ (c1, c2)].

    THE ternary buffer layout — the Eq. (21) encoders
    (:func:`ternary_pack`, uniform or §6-optimal split) and the
    error-feedback twin (repro.core.wire.ef) all emit through here, so
    :func:`ternary_unpack` decodes any of them.
    """
    plane = bp_ops.pack_bits(sym, 2)
    return jnp.concatenate([
        plane,
        floats_to_words(vbuf, wire_dtype),
        floats_to_words(jnp.stack([c1, c2]), wire_dtype),
    ])


def ternary_pack(flat, key, p_pass: float, cap: int, wire_dtype,
                 probs: str = "uniform"):
    """Encode (d,) f32 -> (ternary_wire_words(d, cap),) uint32 wire buffer.

    Delegates the sampling to encoders.encode (kind="ternary": c1 = min(x),
    c2 = max(x); ``probs`` picks the mid-split p1 = p2 = (1 − p_pass)/2 or
    the §6 per-coordinate optimal split) and packs its branch indices — so
    the decoded Y_i is bit-equal to the dense encoder's by construction
    (modulo the ~1e-9 capacity overflow and wire-precision rounding).  The
    buffer layout is independent of ``probs``: branch choices ride the
    plane, so the decoder needs no probabilities.
    """
    enc = encoders.encode(
        key, flat.astype(jnp.float32),
        t.EncoderSpec(kind="ternary", fraction=p_pass, probs=probs))
    sym = enc.extras["branch"]
    sent = sym == 2  # enc.y holds the pass-through value exactly there
    vbuf = rank_scatter(enc.y, sent, cap)
    return ternary_words(sym, vbuf, enc.extras["c1"], enc.extras["c2"],
                         wire_dtype)


def ternary_unpack(buf, d: int, cap: int, wire_dtype):
    """Reconstruct the dense Y_i (f32) from one node's ternary buffer."""
    pw = bp_ops.num_words(d, 2)
    vw = float_words(cap, wire_dtype)
    sym = bp_ops.unpack_bits(buf[:pw], 2, d)
    vals = words_to_floats(buf[pw:pw + vw], cap, wire_dtype)
    c = words_to_floats(buf[pw + vw:], 2, wire_dtype)
    pos = jnp.cumsum((sym == 2).astype(jnp.int32)) - 1
    valid = (sym == 2) & (pos < cap)
    v = vals[jnp.clip(pos, 0, cap - 1)]
    fallback = 0.5 * (c[0] + c[1])  # symmetric 6σ-overflow substitute
    return jnp.where(sym == 0, c[0],
                     jnp.where(sym == 1, c[1],
                               jnp.where(valid, v, fallback)))


# --------------------------------------------------------------------------- #
# Word-aligned shard decode (reduce-scatter decode, DESIGN.md §13).
#
# Shard boundaries snap to uint32 word boundaries (wire.scatter_shard_len
# with the alignments below), so each node touches only a contiguous word
# range of every peer's packed plane — never splitting a word across nodes.
# All helpers fold peers in ascending order, reproducing the sequential
# flat decode's per-coordinate f32 add chain bit-for-bit.
# --------------------------------------------------------------------------- #

BINARY_ALIGN = WORD           # 1-bit plane: 32 coordinates per uint32 word
TERNARY_ALIGN = WORD // 2     # 2-bit plane: 16 coordinates per uint32 word


def _plane_window(plane, nshards: int, ws: int, w0):
    """(n, pw) plane words -> the (n, ws) word window starting at word w0.

    Pads to the full nshards*ws aligned extent first, so the traced-offset
    dynamic_slice never clamps; pad words are zero (== symbol 0), matching
    the zero padding pack_bits applies inside the last real word.
    """
    n, pw = plane.shape
    plane = jnp.pad(plane, ((0, 0), (0, nshards * ws - pw)))
    return jax.lax.dynamic_slice(plane, (0, w0), (n, ws))


def binary_decode_shard(rows, d: int, wire_dtype, start, ds: int,
                        nshards: int, *, force_pallas: bool = False):
    """Sum of all peers' binary Y_i over coordinates [start, start+ds).

    The collective-free per-node work of the §13 scatter decode: one pass
    over the n×(ds/32) word window folding every peer into a single (ds,)
    f32 accumulator (fused kernel: repro.kernels.bitplane.ops.binary_accum)
    — bit-for-bit the [start:start+ds) slice of Σ_i binary_unpack(rows[i]),
    zeroed past d.  ``ds`` must be 32-aligned
    (wire.scatter_shard_len(d, nshards, BINARY_ALIGN)).
    """
    pw = bp_ops.num_words(d, 1)
    ws = ds // WORD
    win = _plane_window(rows[:, :pw], nshards, ws, start // WORD)
    c = jax.vmap(lambda tail: words_to_floats(tail, 2, wire_dtype))(
        rows[:, pw:])
    total = bp_ops.binary_accum(win, c[:, 0], c[:, 1], ds,
                                force_pallas=force_pallas)
    return jnp.where(jnp.arange(ds) + start < d, total, 0.0)


def ternary_shard_syms(rows, d: int, start, ds: int, nshards: int):
    """Every peer's 2-bit symbols over coordinates [start, start+ds).

    Returns (n, ds) uint32; symbols past d are 0 (plane zero padding), so
    per-shard pass-through counts need no extra masking.  ``ds`` must be
    16-aligned (wire.scatter_shard_len(d, nshards, TERNARY_ALIGN)).
    """
    pw = bp_ops.num_words(d, 2)
    per = TERNARY_ALIGN
    ws = ds // per
    win = _plane_window(rows[:, :pw], nshards, ws, start // per)
    shifts = jnp.arange(per, dtype=jnp.uint32) * jnp.uint32(2)
    sym = (win[:, :, None] >> shifts[None, None, :]) & jnp.uint32(3)
    return sym.reshape(rows.shape[0], ds)


def ternary_decode_shard(rows, syms, prior, d: int, cap: int, wire_dtype,
                         start):
    """Sum of all peers' ternary Y_i over this shard's coordinate window.

    ``syms`` is the (n, ds) window from :func:`ternary_shard_syms`;
    ``prior`` is (n,) int32 — each peer's pass-through count over all
    coordinates BEFORE ``start`` (from the per-shard counts all_gather +
    exclusive cumsum in TernaryCodec.decode_gathered_shard), which offsets
    the within-window ranks to the global support-rank positions of the
    flat decode.  Peers fold in ascending order; result is bit-for-bit the
    window slice of Σ_i ternary_unpack(rows[i]), zeroed past d.
    """
    n, ds = syms.shape
    pw = bp_ops.num_words(d, 2)
    vw = float_words(cap, wire_dtype)
    vals = jax.vmap(lambda r: words_to_floats(r[pw:pw + vw], cap,
                                              wire_dtype))(rows)
    c = jax.vmap(lambda r: words_to_floats(r[pw + vw:], 2, wire_dtype))(rows)

    def body(i, acc):
        sym = syms[i]
        sent = sym == 2
        pos = prior[i] + jnp.cumsum(sent.astype(jnp.int32)) - 1
        valid = sent & (pos < cap)
        v = vals[i][jnp.clip(pos, 0, cap - 1)]
        fallback = 0.5 * (c[i, 0] + c[i, 1])
        y = jnp.where(sym == 0, c[i, 0],
                      jnp.where(sym == 1, c[i, 1],
                                jnp.where(valid, v, fallback)))
        return acc + y

    total = jax.lax.fori_loop(0, n, body, jnp.zeros((ds,), jnp.float32))
    return jnp.where(jnp.arange(ds) + start < d, total, 0.0)
