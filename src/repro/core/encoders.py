"""The paper's family of randomized, unbiased encoding protocols (§3, §5, §7.1).

Every encoder maps one vector ``x`` in R^d to a (random) vector ``y`` in R^d
("Y_i" in the paper) together with an auxiliary structure describing what
would actually travel on the wire (support size / indices / centers), which
the communication-cost models in :mod:`repro.core.comm_cost` consume.

All encoders are *unbiased*: E[y] = x (Lemmas 3.1, 3.3, 7.1).  Tests verify
this property empirically and via the closed forms in
:mod:`repro.core.mse`.

Shapes: encoders operate on a single (d,) vector; use ``encode_batch`` (vmap
with per-node key folding) for a stack of n node vectors.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import centers as centers_lib
from repro.core import types as t


class Encoded(NamedTuple):
    """Result of encoding a single vector.

    y:       (d,) the dense decoded-view of the message (what the server
             reconstructs for this node before averaging).
    mu:      () node center actually used.
    support: (d,) bool — True where y(j) != mu (the set S_i of §3).  For
             bit-accounting; the sparse protocols transmit exactly these.
    nsent:   () int32 — |S_i|.
    extras:  dict of protocol-specific wire payloads (e.g. binary encoder's
             vmin/vmax scalars).
    """

    y: jax.Array
    mu: jax.Array
    support: jax.Array
    nsent: jax.Array
    extras: dict


# ---------------------------------------------------------------------------
# Eq. (1): variable-size-support encoder.
# ---------------------------------------------------------------------------

def encode_bernoulli(key, x, probs, mu) -> Encoded:
    """Variable-size-support protocol, Eq. (1).

    Y(j) = X(j)/p_j − (1−p_j)/p_j · mu   with prob p_j,
           mu                            otherwise.

    ``probs`` may be scalar or (d,).  p_j = 0 is honoured in the Remark-1
    sense: the coordinate is never sent and the decoder assumes mu (this is
    only unbiased when X(j) = mu, which is exactly when the optimal solution
    of §6.1 assigns p = 0).
    """
    x = jnp.asarray(x)
    probs = jnp.broadcast_to(jnp.asarray(probs, x.dtype), x.shape)
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    sent = u < probs  # P(sent) = p_j; p_j == 0 -> never sent.
    psafe = jnp.where(probs > 0, probs, 1.0)
    scaled = x / psafe - (1.0 - psafe) / psafe * mu
    y = jnp.where(sent, scaled, mu)
    return Encoded(y=y, mu=jnp.asarray(mu, x.dtype), support=sent,
                   nsent=jnp.sum(sent.astype(jnp.int32)), extras={})


# ---------------------------------------------------------------------------
# Eq. (4): fixed-size-support encoder.
# ---------------------------------------------------------------------------

def sample_support(key, d: int, k: int) -> jax.Array:
    """Uniformly sample a k-subset of {0..d-1} (the D_i of Eq. (4)).

    Returns sorted indices, shape (k,).  Gumbel-top-k == uniform sampling
    without replacement, O(d) work — this is the 'random seed' payload of
    §4.4: on SPMD hardware every peer can regenerate the subset from the
    shared key, so indices never travel on the wire.
    """
    g = jax.random.gumbel(key, (d,))
    _, idx = jax.lax.top_k(g, k)
    return jnp.sort(idx)


def encode_fixed_k(key, x, k: int, mu) -> Encoded:
    """Fixed-size-support protocol, Eq. (4).

    Y(j) = d·X(j)/k − (d−k)/k · mu  if j ∈ D_i (|D_i| = k, uniform), else mu.
    Communication cost is deterministic (§4.4) — the straggler-friendly
    member of the family.
    """
    x = jnp.asarray(x)
    d = x.shape[-1]
    idx = sample_support(key, d, k)
    support = jnp.zeros((d,), bool).at[idx].set(True)
    scaled = (d / k) * x - ((d - k) / k) * mu
    y = jnp.where(support, scaled, mu)
    return Encoded(y=y, mu=jnp.asarray(mu, x.dtype), support=support,
                   nsent=jnp.asarray(k, jnp.int32), extras={"indices": idx})


# ---------------------------------------------------------------------------
# Example 4: binary quantization (recovers Suresh et al. [10]).
# ---------------------------------------------------------------------------

def encode_binary(key, x) -> Encoded:
    """Stochastic binary quantization, Example 4 / Eq. (12).

    Special case of Eq. (1) with mu_i = X^min and p_j = (X(j)−X^min)/Δ:
    Y(j) = X^max w.p. (X(j)−X^min)/Δ else X^min.  1 bit/coordinate on the
    wire plus the two scalars (§4.5).
    """
    x = jnp.asarray(x)
    vmin = jnp.min(x)
    vmax = jnp.max(x)
    delta = vmax - vmin
    p = jnp.where(delta > 0, (x - vmin) / jnp.where(delta > 0, delta, 1.0), 0.0)
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    take_max = u < p
    y = jnp.where(take_max, vmax, vmin)
    return Encoded(y=y, mu=vmin, support=take_max,
                   nsent=jnp.asarray(x.shape[-1], jnp.int32),
                   extras={"vmin": vmin, "vmax": vmax})


# ---------------------------------------------------------------------------
# Eq. (21): ternary (k-ary with k=3) encoder, §7.1.
# ---------------------------------------------------------------------------

def encode_ternary(key, x, p1, p2, c1, c2) -> Encoded:
    """Ternary protocol, Eq. (21).

    Y(j) = c1 w.p. p1_j; c2 w.p. p2_j;
           (X(j) − p1_j·c1 − p2_j·c2) / (1 − p1_j − p2_j) otherwise.
    Unbiased for any centers c1, c2 (Lemma 7.1).
    """
    x = jnp.asarray(x)
    p1 = jnp.broadcast_to(jnp.asarray(p1, x.dtype), x.shape)
    p2 = jnp.broadcast_to(jnp.asarray(p2, x.dtype), x.shape)
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    rest = 1.0 - p1 - p2
    restsafe = jnp.where(rest > 0, rest, 1.0)
    y_rest = (x - p1 * c1 - p2 * c2) / restsafe
    y = jnp.where(u < p1, c1, jnp.where(u < p1 + p2, c2, y_rest))
    sent = u >= p1 + p2  # the full-precision branch
    # branch index (0 → c1, 1 → c2, 2 → pass-through): the symbol the
    # packed 2-bit wire plane ships (repro.core.bitplane).
    branch = jnp.where(u < p1, 0, jnp.where(u < p1 + p2, 1, 2))
    return Encoded(y=y, mu=jnp.asarray(c1, x.dtype), support=sent,
                   nsent=jnp.sum(sent.astype(jnp.int32)),
                   extras={"c1": jnp.asarray(c1), "c2": jnp.asarray(c2),
                           "branch": branch.astype(jnp.uint32)})


def encode_identity(x) -> Encoded:
    """Example 1: lossless identity encoder (p = 1, Example 5)."""
    x = jnp.asarray(x)
    return Encoded(y=x, mu=jnp.zeros((), x.dtype),
                   support=jnp.ones(x.shape, bool),
                   nsent=jnp.asarray(x.shape[-1], jnp.int32), extras={})


# ---------------------------------------------------------------------------
# Spec-driven dispatch + batched (n, d) API.
# ---------------------------------------------------------------------------

def encode(key, x, spec: t.EncoderSpec, probs=None, mu=None) -> Encoded:
    """Encode one vector according to an :class:`EncoderSpec`.

    ``probs``/``mu`` override the spec's policies when given (used by the
    §6 optimizers, which precompute them).
    """
    d = x.shape[-1]
    if spec.kind == "identity":
        return encode_identity(x)
    if spec.kind == "binary":
        return encode_binary(key, x)
    if mu is None:
        if spec.center == "optimal" and probs is None and spec.probs == "uniform":
            p0 = jnp.full(x.shape, spec.fraction, x.dtype)
            mu = centers_lib.compute_centers(x, "optimal", p0)
        elif spec.center == "optimal" and probs is not None:
            mu = centers_lib.compute_centers(x, "optimal", probs)
        else:
            policy = spec.center if spec.center != "optimal" else "mean"
            mu = centers_lib.compute_centers(x, policy)
    if spec.kind == "fixed_k":
        k = t.fixed_k_from_fraction(d, spec.fraction)
        return encode_fixed_k(key, x, k, mu)
    if spec.kind == "bernoulli":
        if probs is None:
            probs = spec.fraction
        return encode_bernoulli(key, x, probs, mu)
    if spec.kind == "ternary":
        # c1/c2 bracket the data like the binary encoder, with the
        # pass-through mass set by `fraction`.  probs="uniform" splits the
        # branch mass evenly; probs="optimal" uses the §6 per-coordinate
        # optimal split (optimal.ternary_optimal_probs) — the pass
        # probability stays `fraction` either way.
        c1 = jnp.min(x)
        c2 = jnp.max(x)
        if spec.probs == "optimal":
            from repro.core import optimal as optimal_lib
            p1, p2 = optimal_lib.ternary_optimal_probs(x, spec.fraction,
                                                       c1, c2)
            return encode_ternary(key, x, p1, p2, c1, c2)
        half = (1.0 - spec.fraction) / 2.0
        return encode_ternary(key, x, half, half, c1, c2)
    raise ValueError(f"unhandled encoder kind {spec.kind!r}")


def encode_batch(key, xs, spec: t.EncoderSpec, probs=None, mus=None) -> Encoded:
    """Independently encode a stack of node vectors (Def. 2.1 independence).

    Args:
      key: base PRNG key; node i uses fold_in(key, i).
      xs: (n, d) node vectors.
      probs: optional (n, d) probabilities.
      mus: optional (n,) centers.
    Returns an :class:`Encoded` with leading node axis n.
    """
    n = xs.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    if probs is None and mus is None:
        return jax.vmap(lambda k, x: encode(k, x, spec))(keys, xs)
    if probs is None:
        return jax.vmap(lambda k, x, m: encode(k, x, spec, mu=m))(keys, xs, mus)
    if mus is None:
        return jax.vmap(lambda k, x, p: encode(k, x, spec, probs=p))(keys, xs, probs)
    return jax.vmap(lambda k, x, p, m: encode(k, x, spec, probs=p, mu=m))(
        keys, xs, probs, mus)
