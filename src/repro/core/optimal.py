"""Optimal protocol parameters (§6).

Problem (14): minimize the MSE numerator Σ_ij (1/p_ij − 1)(X_i(j) − μ_i)²
subject to a communication budget Σ_ij p_ij ≤ B and 0 < p_ij ≤ 1, jointly
over probabilities and node centers.  The objective is biconvex; the paper
prescribes alternating minimization:

  step 1 (centers, closed form, Eq. 16):  μ_i = Σ_j w_ij X_ij / Σ_j w_ij,
          w_ij = 1/p_ij − 1;
  step 2 (probabilities, §6.1): water-filling — at optimum
          p_ij = min(1, a_ij/θ) with a_ij = |X_i(j) − μ_i| and θ set so the
          budget is tight.  (The paper derives the uncapped stationary point
          a_ij/p_ij = θ and notes the capped case has no closed form; the
          standard water-filling extension below solves the capped problem
          *exactly* — the objective is convex and separable, so KKT gives
          p = min(1, a/θ) with θ the unique root of Σ min(1, a/θ) = B.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import centers as centers_lib
from repro.core import mse as mse_lib


def optimal_probs(xs, mus, B: float, iters: int = 64):
    """Water-filled optimal probabilities for fixed centers (§6.1).

    Args:
      xs: (n, d) node vectors.
      mus: (n,) centers.
      B: communication budget — bound on Σ_ij p_ij  (0 < B ≤ n·d).
      iters: bisection iterations for θ (each halves the bracket; 64 reaches
        float64 resolution).

    Returns (n, d) probabilities with Σ p_ij ≤ B (tight unless capped at the
    |S| ceiling, in which case p = 1 on all of S — the zero-MSE regime).

    Coordinates with a_ij = 0 receive p = 0 (Remark-1 semantics: never sent,
    zero MSE contribution — see mse.mse_bernoulli).
    """
    a = jnp.abs(xs - mus[:, None]).astype(jnp.float64 if jax.config.x64_enabled else jnp.float32)
    S = jnp.sum(a > 0)
    B = jnp.minimum(jnp.asarray(B, a.dtype), S.astype(a.dtype))

    amax = jnp.max(a)
    # θ bracket: at θ→0+, Σ min(1, a/θ) → |S| ≥ B; at θ = Σa/B (uncapped
    # solution's θ), Σ min(1, a/θ) ≤ Σ a/θ = B.  Bisect within.
    lo = jnp.asarray(1e-30, a.dtype)
    hi = jnp.maximum(jnp.sum(a) / jnp.maximum(B, 1e-30), lo * 2)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        sent = jnp.sum(jnp.minimum(1.0, a / mid))
        # sent decreasing in θ: if sent > B we need larger θ.
        return jnp.where(sent > B, mid, lo), jnp.where(sent > B, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    p = jnp.minimum(1.0, a / theta)
    p = jnp.where(a > 0, p, 0.0)
    return p.astype(xs.dtype)


def optimal_probs_per_node(xs, mus, budgets):
    """Remark 5: per-node budgets B_1..B_n; each node solves its own §6.1
    problem independently (the practical federated deployment — no global
    coordination needed).  For B = Σ B_i the resulting MSE is lower-bounded
    by the jointly-optimal MSE of problem (14) (verified by property test).

    One ``vmap`` over nodes — a single trace regardless of n, and the
    budgets stay traced (jit-compatible; tests/test_optimal.py asserts a
    jit of this function compiles and matches the per-row solver).

    budgets: (n,) per-node bounds on Σ_j p_ij.
    """
    budgets = jnp.asarray(budgets)
    return jax.vmap(
        lambda x, m, b: optimal_probs(x[None, :], m[None], b)[0]
    )(xs, mus, budgets)


def ternary_optimal_probs(x, q, c1=None, c2=None):
    """§6-optimal per-coordinate (p1, p2) for the ternary encoder (§7.1).

    The Eq. (21) protocol leaves the split between the c1/c2 branches free:
    any (p1_j, p2_j) with p1_j + p2_j = 1 − q is unbiased (Lemma 7.1).
    At fixed pass mass q and centers c1 = min x, c2 = max x, the exact
    per-coordinate variance (corrected Lemma 7.2, see mse.mse_ternary) as a
    function of the mixture mean s_j = p1_j·c1 + p2_j·c2 is

        Var_j(s) = s·(c1 + c2) − (1 − q)·c1·c2 + (x_j − s)²/q − x_j²,

    convex in s with unconstrained minimizer s*_j = x_j − q·(c1 + c2)/2,
    clamped to the feasible [(1 − q)c1, (1 − q)c2].  The default mid-split
    p1 = p2 = (1 − q)/2 corresponds to s = (1 − q)(c1 + c2)/2 and is
    recovered iff x_j sits at the midpoint — so the optimal split never
    loses (tests/test_optimal.py asserts the dominance via mse_ternary).

    The pass branch keeps probability exactly q per coordinate regardless
    of the split, so the 6σ capacity sizing of the realized pass-through
    mass (comm_cost.bernoulli_capacity at p = q) is unchanged — which is
    what lets this ride the existing 2-bit-plane wire format as a plain
    codec (repro.core.wire.codecs.TernaryOptCodec).

    Returns (p1, p2) arrays shaped like ``x``.  Pass the caller's centers
    via ``c1``/``c2`` when already computed (encoders.encode does) so the
    split is optimized for exactly the centers shipped on the wire.
    """
    x = jnp.asarray(x, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    if c1 is None:
        c1 = jnp.min(x)
    if c2 is None:
        c2 = jnp.max(x)
    s = jnp.clip(x - q * (c1 + c2) / 2, (1.0 - q) * c1, (1.0 - q) * c2)
    span = c2 - c1
    p1 = jnp.where(span > 0, ((1.0 - q) * c2 - s) / jnp.where(span > 0, span, 1.0),
                   1.0 - q)  # degenerate constant vector: all mass on c1
    p1 = jnp.broadcast_to(p1, x.shape)
    return p1, (1.0 - q) - p1


def alternating_minimization(xs, B: float, iters: int = 20,
                             init_center: str = "mean") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """§6 alternating scheme for the joint (p, μ) problem (14).

    Returns (probs (n,d), mus (n,), mse_trace (iters,)).  The trace is
    non-increasing (each step solves its subproblem exactly), which
    tests/test_optimal.py asserts.
    """
    mus = centers_lib.compute_centers(xs, init_center)

    def step(carry, _):
        mus, _ = carry
        p = optimal_probs(xs, mus, B)
        mus_new = centers_lib.optimal_centers(xs, p)
        m = mse_lib.mse_bernoulli(xs, p, mus_new)
        return (mus_new, p), m

    (mus, probs), trace = jax.lax.scan(
        step, (mus, jnp.zeros_like(xs)), None, length=iters)
    return probs, mus, trace
