"""Optimal protocol parameters (§6).

Problem (14): minimize the MSE numerator Σ_ij (1/p_ij − 1)(X_i(j) − μ_i)²
subject to a communication budget Σ_ij p_ij ≤ B and 0 < p_ij ≤ 1, jointly
over probabilities and node centers.  The objective is biconvex; the paper
prescribes alternating minimization:

  step 1 (centers, closed form, Eq. 16):  μ_i = Σ_j w_ij X_ij / Σ_j w_ij,
          w_ij = 1/p_ij − 1;
  step 2 (probabilities, §6.1): water-filling — at optimum
          p_ij = min(1, a_ij/θ) with a_ij = |X_i(j) − μ_i| and θ set so the
          budget is tight.  (The paper derives the uncapped stationary point
          a_ij/p_ij = θ and notes the capped case has no closed form; the
          standard water-filling extension below solves the capped problem
          *exactly* — the objective is convex and separable, so KKT gives
          p = min(1, a/θ) with θ the unique root of Σ min(1, a/θ) = B.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import centers as centers_lib
from repro.core import mse as mse_lib


def optimal_probs(xs, mus, B: float, iters: int = 64):
    """Water-filled optimal probabilities for fixed centers (§6.1).

    Args:
      xs: (n, d) node vectors.
      mus: (n,) centers.
      B: communication budget — bound on Σ_ij p_ij  (0 < B ≤ n·d).
      iters: bisection iterations for θ (each halves the bracket; 64 reaches
        float64 resolution).

    Returns (n, d) probabilities with Σ p_ij ≤ B (tight unless capped at the
    |S| ceiling, in which case p = 1 on all of S — the zero-MSE regime).

    Coordinates with a_ij = 0 receive p = 0 (Remark-1 semantics: never sent,
    zero MSE contribution — see mse.mse_bernoulli).
    """
    a = jnp.abs(xs - mus[:, None]).astype(jnp.float64 if jax.config.x64_enabled else jnp.float32)
    S = jnp.sum(a > 0)
    B = jnp.minimum(jnp.asarray(B, a.dtype), S.astype(a.dtype))

    amax = jnp.max(a)
    # θ bracket: at θ→0+, Σ min(1, a/θ) → |S| ≥ B; at θ = Σa/B (uncapped
    # solution's θ), Σ min(1, a/θ) ≤ Σ a/θ = B.  Bisect within.
    lo = jnp.asarray(1e-30, a.dtype)
    hi = jnp.maximum(jnp.sum(a) / jnp.maximum(B, 1e-30), lo * 2)

    def body(_, loh):
        lo, hi = loh
        mid = 0.5 * (lo + hi)
        sent = jnp.sum(jnp.minimum(1.0, a / mid))
        # sent decreasing in θ: if sent > B we need larger θ.
        return jnp.where(sent > B, mid, lo), jnp.where(sent > B, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    p = jnp.minimum(1.0, a / theta)
    p = jnp.where(a > 0, p, 0.0)
    return p.astype(xs.dtype)


def optimal_probs_per_node(xs, mus, budgets):
    """Remark 5: per-node budgets B_1..B_n; each node solves its own §6.1
    problem independently (the practical federated deployment — no global
    coordination needed).  For B = Σ B_i the resulting MSE is lower-bounded
    by the jointly-optimal MSE of problem (14) (verified by property test).

    One ``vmap`` over nodes — a single trace regardless of n, and the
    budgets stay traced (jit-compatible; tests/test_optimal.py asserts a
    jit of this function compiles and matches the per-row solver).

    budgets: (n,) per-node bounds on Σ_j p_ij.
    """
    budgets = jnp.asarray(budgets)
    return jax.vmap(
        lambda x, m, b: optimal_probs(x[None, :], m[None], b)[0]
    )(xs, mus, budgets)


def alternating_minimization(xs, B: float, iters: int = 20,
                             init_center: str = "mean") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """§6 alternating scheme for the joint (p, μ) problem (14).

    Returns (probs (n,d), mus (n,), mse_trace (iters,)).  The trace is
    non-increasing (each step solves its subproblem exactly), which
    tests/test_optimal.py asserts.
    """
    mus = centers_lib.compute_centers(xs, init_center)

    def step(carry, _):
        mus, _ = carry
        p = optimal_probs(xs, mus, B)
        mus_new = centers_lib.optimal_centers(xs, p)
        m = mse_lib.mse_bernoulli(xs, p, mus_new)
        return (mus_new, p), m

    (mus, probs), trace = jax.lax.scan(
        step, (mus, jnp.zeros_like(xs)), None, length=iters)
    return probs, mus, trace
