"""Random-rotation pre-processing (§7.2 / Remark 3, after Suresh et al. [10]).

Q = (1/√d)·H·D with H the Walsh–Hadamard matrix and D = diag(±1) random.
Q is orthogonal (QQᵀ = I), identified by a single seed (the paper's point:
negligible communication overhead), and computable in O(d log d).

The FWHT itself lives in :mod:`repro.kernels.hadamard` (Pallas kernel with
pure-jnp oracle); this module provides the seeded rotate / unrotate pair
used by the reference protocol stack (repro.core.protocol) and by the
composable wire-layer pre-transform (repro.core.wire.rotated).

Shape handling:
* non-power-of-two d is zero-padded to the next power of two (standard
  practice; :func:`unrotate` truncates), and
* d beyond the kernel's MAX_D (2^20) is processed in independent MAX_D
  chunks — a block-diagonal orthogonal Q, still seed-identified, so
  bucket-sized vectors (default bucket capacity 4M) rotate in one call.

:func:`padded_dim` is the single source of truth for the rotated length:
wire codecs wrapping a rotation size their buffers at ``padded_dim(d)``
(repro.core.wire.rotated.RotatedCodec.wire_slots).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hadamard import ops as hadamard_ops

# Domain tag for deriving the shared per-bucket rotation seed from the
# per-step key: distinct from the node ranks (0..n-1) and bucket indices
# folded elsewhere, so rotation draws never collide with encoder draws.
_ROTATION_TAG = 0x524F54  # "ROT"


def rotation_key(key):
    """The shared rotation seed: same on every node of the bucket's axes."""
    return jax.random.fold_in(key, _ROTATION_TAG)


def padded_dim(d: int) -> int:
    """Length after rotation: next power of two, or — beyond the FWHT
    kernel's MAX_D — the next multiple of MAX_D (block-diagonal Q)."""
    dp = 1 << max(0, (d - 1).bit_length())
    if dp <= hadamard_ops.MAX_D:
        return dp
    return -(-d // hadamard_ops.MAX_D) * hadamard_ops.MAX_D


def _pad(x, dp: int):
    d = x.shape[-1]
    if dp == d:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, dp - d)]
    return jnp.pad(x, pad)


def rademacher_diag(key, d: int, dtype=jnp.float32):
    """The D of Q = (1/√d)HD: iid ±1 signs from a shared seed."""
    return jax.random.rademacher(key, (d,), dtype=dtype)


def _chunked_fwht(x):
    """FWHT over the last axis, block-diagonal in MAX_D chunks beyond it."""
    dp = x.shape[-1]
    c = min(dp, hadamard_ops.MAX_D)
    if dp == c:
        return hadamard_ops.fwht(x), c
    z = hadamard_ops.fwht(x.reshape(x.shape[:-1] + (dp // c, c)))
    return z.reshape(x.shape[:-1] + (dp,)), c


def rotate(key, x):
    """z = Qx.  x: (..., d) -> (..., padded_dim(d))."""
    xp = _pad(x, padded_dim(x.shape[-1]))
    dp = xp.shape[-1]
    signs = rademacher_diag(key, dp, xp.dtype)
    z, c = _chunked_fwht(xp * signs)
    return z / jnp.sqrt(jnp.asarray(c, xp.dtype))


def unrotate(key, z, d: int):
    """x = Q⁻¹z = Qᵀz = (1/√d)·D·H·z, truncated back to the original d."""
    dp = z.shape[-1]
    signs = rademacher_diag(key, dp, z.dtype)
    h, c = _chunked_fwht(z)
    x = signs * h / jnp.sqrt(jnp.asarray(c, z.dtype))
    return x[..., :d]
