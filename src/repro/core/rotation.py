"""Random-rotation pre-processing (§7.2 / Remark 3, after Suresh et al. [10]).

Q = (1/√d)·H·D with H the Walsh–Hadamard matrix and D = diag(±1) random.
Q is orthogonal (QQᵀ = I), identified by a single seed (the paper's point:
negligible communication overhead), and computable in O(d log d).

The FWHT itself lives in :mod:`repro.kernels.hadamard` (Pallas kernel with
pure-jnp oracle); this module provides the seeded rotate / unrotate pair
used by encoders and composes the Example-3 linear encoder/decoder.
Non-power-of-two d is handled by zero-padding to the next power of two
(standard practice; the decoder truncates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hadamard import ops as hadamard_ops


def _pad_pow2(x):
    d = x.shape[-1]
    dp = 1 << max(0, (d - 1).bit_length())
    if dp == d:
        return x, d
    pad = [(0, 0)] * (x.ndim - 1) + [(0, dp - d)]
    return jnp.pad(x, pad), d


def rademacher_diag(key, d: int, dtype=jnp.float32):
    """The D of Q = (1/√d)HD: iid ±1 signs from a shared seed."""
    return jax.random.rademacher(key, (d,), dtype=dtype)


def rotate(key, x):
    """z = Qx.  x: (..., d) -> (..., d_pow2)."""
    xp, _ = _pad_pow2(x)
    dp = xp.shape[-1]
    signs = rademacher_diag(key, dp, xp.dtype)
    z = hadamard_ops.fwht(xp * signs) / jnp.sqrt(jnp.asarray(dp, xp.dtype))
    return z


def unrotate(key, z, d: int):
    """x = Q⁻¹z = Qᵀz = (1/√d)·D·H·z, truncated back to the original d."""
    dp = z.shape[-1]
    signs = rademacher_diag(key, dp, z.dtype)
    x = signs * hadamard_ops.fwht(z) / jnp.sqrt(jnp.asarray(dp, z.dtype))
    return x[..., :d]
