"""Optimizers (AdamW, momentum SGD) over param pytrees, shard-agnostic.

States are created leaf-wise with the same local shapes as the params, so
inside shard_map they are sharded exactly like the weights (ZeRO-1 comes
for free wherever the weights are FSDP-sharded).  Master weights are the
f32 params themselves (layers cast to bf16 at use — models/common).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree, psum_axes=()):
    """L2 norm of a (possibly sharded) pytree: local sum-of-squares psum'd
    over the axes whose shards hold disjoint slices."""
    ss = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    if psum_axes:
        ss = jax.lax.psum(ss, psum_axes)
    return jnp.sqrt(ss)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params,
                 grad_norm=None):
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    if grad_norm is not None and cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (grad_norm + 1e-9))
    else:
        scale = 1.0
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/scales exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
